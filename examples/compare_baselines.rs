//! Baseline comparison across the full zoo: TF-style greedy vs TASO-style
//! backtracking search over the same rule library and cost model — the
//! deterministic half of Fig. 6 in seconds rather than hours. No AOT
//! artifacts required.
//!
//! ```bash
//! cargo run --release --example compare_baselines
//! ```

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::search::{greedy_optimise, taso_optimise, TasoConfig};
use rlflow::xfer::library::standard_library;

fn main() -> anyhow::Result<()> {
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    println!("search engine: transposition table + delta costing (worker count per run below)");
    println!(
        "{:<15} {:>12} {:>10} {:>10} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "Graph", "Base (ms)", "Greedy %", "TASO %", "Greedy s", "TASO s", "explored", "memohits", "workers"
    );
    for (info, g) in rlflow::zoo::all() {
        let (_, glog) = greedy_optimise(&g, &rules, &cost, 50);
        let (_, tlog) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        println!(
            "{:<15} {:>12.3} {:>9.1}% {:>9.1}% {:>9.2} {:>9.2} {:>9} {:>9} {:>8}",
            info.name,
            glog.initial_ms,
            glog.improvement_pct(),
            tlog.improvement_pct(),
            glog.elapsed_s,
            tlog.elapsed_s,
            tlog.graphs_explored,
            tlog.memo_hits,
            tlog.threads
        );
    }
    println!("\nExpected shape (paper Fig. 6): TASO >= greedy everywhere; the gap");
    println!("is largest on multi-branch CNNs (Inception/SqueezeNet) where");
    println!("backtracking pays off, and smallest on the transformers where the");
    println!("profitable sequence (add/norm fusion, QKV merge) is short.");
    Ok(())
}
