//! Quickstart: load a zoo graph, optimise it with the TASO-style search,
//! inspect what happened. No AOT artifacts required.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::search::{taso_optimise, TasoConfig};
use rlflow::xfer::library::standard_library;
use rlflow::zoo;

fn main() -> anyhow::Result<()> {
    // 1. A real evaluation graph: BERT-Base, built from primitive ops.
    let graph = zoo::bert_base();
    println!("BERT-Base: {} ops / {} nodes", graph.n_ops(), graph.n_live());

    // 2. The substitution library + analytic cost model (simulated RTX 2070).
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    println!("rule library: {} substitutions, {} applicable sites", rules.len(), rules.count_matches(&graph));
    println!("estimated runtime: {:.3} ms", cost.graph_runtime_ms(&graph));

    // 3. Optimise with cost-based backtracking search.
    let (optimised, log) = taso_optimise(&graph, &rules, &cost, &TasoConfig::default());
    println!(
        "optimised: {:.3} ms -> {:.3} ms ({:.1}% faster), {} graphs explored in {:.2}s",
        log.initial_ms,
        log.final_ms,
        log.improvement_pct(),
        log.graphs_explored,
        log.elapsed_s
    );
    for (rule, ms) in log.steps.iter().take(8) {
        println!("  {:<22} -> {:.3} ms", rule, ms);
    }

    // 4. The rewritten graph is still semantically valid.
    optimised.validate()?;
    println!("optimised graph validates ({} ops)", optimised.n_ops());

    // 5. Export in the ONNX-style JSON interchange format.
    let out = std::env::temp_dir().join("bert_optimised.json");
    rlflow::graph::onnx::save(&optimised, "bert-optimised", &out)?;
    println!("exported to {}", out.display());
    Ok(())
}
