//! TASO-style substitution mining (paper §3.2, Fig. 3): enumerate small
//! operator graphs, fingerprint them on random 4x4 tensors with the
//! reference interpreter, group by fingerprint, verify candidate pairs
//! exactly, and prune the trivial ones (input renaming / common subgraph).
//! Also re-verifies the curated library on a zoo graph. No artifacts needed.
//!
//! ```bash
//! cargo run --release --example rule_mining
//! ```

use rlflow::xfer::generator::{generate, verify_library};
use rlflow::xfer::library::standard_library;

fn main() -> anyhow::Result<()> {
    println!("== enumerative substitution generation (2 inputs, depth 2) ==");
    let (cands, stats) = generate(2, 2, 42);
    println!("  enumerated graphs : {}", stats.enumerated);
    println!("  fingerprint groups: {}", stats.groups);
    println!("  candidate pairs   : {}", stats.candidates);
    println!("  pruned (renaming) : {}  [Fig. 3a]", stats.pruned_renaming);
    println!("  pruned (common)   : {}  [Fig. 3b]", stats.pruned_common);
    println!("  verified          : {}", stats.verified);

    println!("\nfirst verified identities:");
    for c in cands.iter().filter(|c| c.verified).take(4) {
        println!("--- LHS ---\n{}--- RHS ---\n{}", c.lhs, c.rhs);
    }

    println!("== interpreter verification of the curated library ==");
    let lib = standard_library();
    let graphs = vec![rlflow::zoo::squeezenet1_1()];
    let report = verify_library(&lib, &graphs, 7)?;
    let mut verified_rules = 0;
    let mut sites = 0;
    for (name, n) in &report {
        if *n > 0 {
            verified_rules += 1;
            sites += n;
            println!("  {:<24} {} sites semantics-preserving", name, n);
        }
    }
    println!("\n{verified_rules} rules verified on {sites} SqueezeNet sites (rules with 0 sites have no match on this graph).");
    Ok(())
}
