//! **End-to-end driver** (DESIGN.md deliverable): the complete RLFlow
//! pipeline on BERT-Base, proving all three layers compose —
//!
//!   L3 Rust env/substitution engine  ->  random rollouts
//!   L1/L2 GNN auto-encoder artifact  ->  latent states
//!   L1/L2 MDN-RNN artifact           ->  world model (loss curve logged)
//!   L1/L2 controller artifact        ->  PPO **inside the dream**
//!   L3 real environment              ->  final evaluation vs TF/TASO
//!
//! Also measures the paper's §4.4 claim that stepping the imagined
//! environment is orders of magnitude faster than stepping the real one
//! (they report 10 ms vs 850 ms = 85x on ResNet-50).
//!
//! ```bash
//! cargo run --release --example optimize_bert [-- --smoke]
//! ```
//!
//! Runs on the backend seam: the PJRT artifacts when `make artifacts` has
//! produced them, the pure-Rust host backend otherwise — so this driver
//! works fully offline. The run is recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use rlflow::config::RunConfig;
use rlflow::coordinator::Pipeline;
use rlflow::cost::CostModel;
use rlflow::env::Env;
use rlflow::experiments::{eval_agent, train_model_based};
use rlflow::runtime::{backend_by_name, Backend};
use rlflow::search::{greedy_optimise, taso_optimise, TasoConfig};
use rlflow::util::Rng;
use rlflow::wm::DreamEnv;
use rlflow::xfer::library::standard_library;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut cfg = if smoke { RunConfig::smoke() } else { RunConfig::default() };
    cfg.graph = "bert".into();

    let backend = backend_by_name(&cfg.backend)?;
    println!("model-execution backend: {}", backend.name());
    let pipe = Pipeline::new(backend.as_ref())?;
    let graph = rlflow::zoo::bert_base();
    let rules = standard_library();
    let cost = CostModel::new(cfg.device);

    println!("== RLFlow end-to-end on BERT-Base ==");
    println!(
        "graph: {} ops, baseline runtime {:.3} ms, {} applicable substitutions",
        graph.n_ops(),
        cost.graph_runtime_ms(&graph),
        rules.count_matches(&graph)
    );

    // ---- deterministic baselines --------------------------------------
    let (_, tf_log) = greedy_optimise(&graph, &rules, &cost, 50);
    let (_, taso_log) = taso_optimise(&graph, &rules, &cost, &TasoConfig::default());
    println!(
        "baselines: TF-greedy {:.1}% | TASO {:.1}% runtime improvement",
        tf_log.improvement_pct(),
        taso_log.improvement_pct()
    );

    // ---- full model-based pipeline -------------------------------------
    let t0 = Instant::now();
    let agent = train_model_based(&pipe, &cfg, &graph, cfg.seed)?;
    println!("\npipeline stages:");
    for (stage, secs) in &agent.stage_seconds {
        println!("  {:<12} {:>7.1}s", stage, secs);
    }
    println!("total training wall-clock: {:.1}s", t0.elapsed().as_secs_f64());

    println!("\nworld-model loss (Fig. 8 analogue):");
    let curve = &agent.wm_curve;
    for i in (0..curve.len()).step_by((curve.len() / 8).max(1)) {
        println!(
            "  step {:>4}: total {:>8.4}  nll {:>8.4}  mask {:>6.4}",
            i, curve[i].total, curve[i].nll, curve[i].mask_bce
        );
    }
    println!("\ndream reward curve (Fig. 9 analogue):");
    let dc = &agent.dream_curve;
    for i in (0..dc.len()).step_by((dc.len() / 8).max(1)) {
        println!("  epoch {:>3}: predicted reward {:>8.3}", i, dc[i]);
    }

    // ---- evaluation in the real environment ---------------------------
    let (scores, history, real_step_s) =
        eval_agent(&pipe, &cfg, &agent, &graph, cfg.eval_episodes, cfg.seed)?;
    let (mean, std) = rlflow::util::stats::mean_std(&scores);
    println!("\nreal-environment evaluation ({} runs):", scores.len());
    println!("  RLFlow  : {:.2}% ± {:.2} runtime improvement", mean, std);
    println!("  TF      : {:.2}%", tf_log.improvement_pct());
    println!("  TASO    : {:.2}%", taso_log.improvement_pct());
    let mut counts = std::collections::HashMap::new();
    for (x, _) in &history {
        *counts.entry(*x).or_insert(0usize) += 1;
    }
    let mut named: Vec<(&str, usize)> = counts
        .iter()
        .filter_map(|(&x, &c)| rules.get(x).map(|r| (r.name(), c)))
        .collect();
    named.sort_by(|a, b| b.1.cmp(&a.1));
    println!("  transformations applied (Fig. 10 analogue): {:?}", named);

    // ---- dream vs real step time (the 85x claim) -----------------------
    let mut rng = Rng::new(cfg.seed);
    let mut dream = DreamEnv::new(backend.as_ref(), cfg.temperature, cfg.wm.reward_scale)?;
    let z0: Vec<Vec<f32>> = agent.episodes.iter().map(|e| e.z[0].clone()).collect();
    let xm0: Vec<Vec<f32>> = agent.episodes.iter().map(|e| e.xmasks[0].clone()).collect();
    dream.reset(&z0, &xm0)?;
    let steps = 50;
    let t0 = Instant::now();
    for _ in 0..steps {
        let actions: Vec<rlflow::agent::Action> =
            (0..dream.b).map(|_| rlflow::agent::Action::new(0, 0)).collect();
        let _ = dream.step(&agent.wm, &actions, &mut rng)?;
        dream.done.fill(false); // keep stepping for timing purposes
    }
    // Dream steps are batched (B_DREAM imagined environments per exec).
    let dream_step_s = t0.elapsed().as_secs_f64() / (steps * dream.b) as f64;

    // Real step cost: measured during eval (includes encode+policy+env).
    println!("\nstep-time comparison (paper §4.4: 10 ms dream vs 850 ms real = 85x):");
    println!("  real env step : {:>8.2} ms", real_step_s * 1e3);
    println!(
        "  dream step    : {:>8.3} ms (amortised over batch of {})",
        dream_step_s * 1e3,
        dream.b
    );
    println!("  speedup       : {:>8.1}x", real_step_s / dream_step_s);

    // Sample efficiency accounting (§4.4).
    let real_interactions: usize = agent.episodes.iter().map(|e| e.len()).sum();
    let dream_interactions = cfg.dream_epochs * cfg.dream_horizon * dream.b;
    println!("\nsample efficiency: {} real interactions collected once;", real_interactions);
    println!("controller consumed {} *imagined* interactions instead.", dream_interactions);

    let mut env = Env::new(graph.clone(), &rules, &cost, cfg.env.clone());
    let res = pipe.eval_real(&agent.gnn, &agent.ctrl, Some(&agent.wm), &mut env, true, &mut rng)?;
    if let Some(bg) = res.best_graph {
        let out = std::env::temp_dir().join("bert_rlflow.json");
        rlflow::graph::onnx::save(&bg, "bert-rlflow", &out)?;
        println!("\nbest graph exported to {}", out.display());
    }
    Ok(())
}
