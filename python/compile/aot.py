"""AOT exporter: lower every L2 function to HLO *text* + write the manifest.

This is the only place Python touches the build. ``make artifacts`` runs it
once; afterwards the Rust coordinator is self-contained.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md). All computations are lowered with
``return_tuple=True``; the Rust side unwraps the result tuple.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import hp, model

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _scalar():
    return spec((), F32)


def _exports():
    """(name, fn, arg_specs, arg_names, output_names) for every artifact."""
    Pg = model.GNN_LAYOUT.size
    Pw = model.WM_LAYOUT.size
    Pc = model.CTRL_LAYOUT.size
    N, F, Z, R = hp.MAX_NODES, hp.NODE_FEATS, hp.LATENT, hp.RNN_HIDDEN
    X1, L, K = hp.N_XFERS1, hp.MAX_LOCS, hp.MDN_K

    def graph_batch(b):
        return [
            (spec((b, N, F)), "feats"),
            (spec((b, N, N)), "adj"),
            (spec((b, N)), "mask"),
        ]

    def adam_state(p):
        return [
            (spec((p,)), "theta"),
            (spec((p,)), "m"),
            (spec((p,)), "v"),
            (_scalar(), "t"),
        ]

    exports = []

    def add(name, fn, args, outs):
        specs = [a for a, _ in args]
        names = [n for _, n in args]
        exports.append((name, fn, specs, names, outs))

    # ---- GNN auto-encoder ------------------------------------------------
    add("gnn_init", model.gnn_init, [(spec((), I32), "seed")], ["theta"])
    add(
        "gnn_ae_train",
        model.gnn_ae_train,
        adam_state(Pg) + graph_batch(hp.B_ENC) + [(_scalar(), "lr")],
        ["theta", "m", "v", "t", "loss"],
    )
    for b, suffix in [(hp.B_ONE, "_1"), (hp.B_ENC, "_b")]:
        add(
            f"gnn_encode{suffix}",
            model.gnn_encode,
            [(spec((Pg,)), "theta")] + graph_batch(b),
            ["z"],
        )

    # ---- MDN-RNN world model ----------------------------------------------
    add("wm_init", model.wm_init, [(spec((), I32), "seed")], ["theta"])
    B, T = hp.B_WM, hp.SEQ_LEN
    add(
        "wm_train",
        model.wm_train,
        adam_state(Pw)
        + [
            (spec((B, T, Z)), "z"),
            (spec((B, T, 2), I32), "a"),
            (spec((B, T, Z)), "z_next"),
            (spec((B, T)), "r"),
            (spec((B, T, X1)), "xmask"),
            (spec((B, T)), "done"),
            (spec((B, T)), "valid"),
            (_scalar(), "lr"),
        ],
        ["theta", "m", "v", "t", "total", "nll", "r_mse", "m_bce", "d_bce"],
    )
    wm_outs = [
        "log_pi",
        "mu",
        "log_sig",
        "reward",
        "xmask_logits",
        "done_logit",
        "h_next",
        "c_next",
    ]
    for b, suffix in [(hp.B_ONE, "_1"), (hp.B_DREAM, "_b")]:
        add(
            f"wm_step{suffix}",
            model.wm_step,
            [
                (spec((Pw,)), "theta"),
                (spec((b, Z)), "z"),
                (spec((b, 2), I32), "a"),
                (spec((b, R)), "h"),
                (spec((b, R)), "c"),
            ],
            wm_outs,
        )

    # ---- Controller --------------------------------------------------------
    add("ctrl_init", model.ctrl_init, [(spec((), I32), "seed")], ["theta"])
    for b, suffix in [(hp.B_ONE, "_1"), (hp.B_DREAM, "_b")]:
        add(
            f"ctrl_policy{suffix}",
            model.ctrl_policy,
            [
                (spec((Pc,)), "theta"),
                (spec((b, Z)), "z"),
                (spec((b, R)), "h"),
            ],
            ["xfer_logits", "loc_logits", "value"],
        )
    Bp = hp.B_PPO
    add(
        "ctrl_train",
        model.ctrl_train,
        adam_state(Pc)
        + [
            (spec((Bp, Z)), "z"),
            (spec((Bp, R)), "h"),
            (spec((Bp, 2), I32), "act"),
            (spec((Bp,)), "old_logp"),
            (spec((Bp,)), "adv"),
            (spec((Bp,)), "ret"),
            (spec((Bp, X1)), "xmask"),
            (spec((Bp, L)), "lmask"),
            (_scalar(), "lr"),
            (_scalar(), "clip"),
            (_scalar(), "ent_coef"),
        ],
        ["theta", "m", "v", "t", "pi_loss", "v_loss", "entropy", "approx_kl"],
    )
    return exports


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {
        "hp": hp.as_dict(),
        "param_sizes": {
            "gnn": model.GNN_LAYOUT.size,
            "wm": model.WM_LAYOUT.size,
            "ctrl": model.CTRL_LAYOUT.size,
        },
        "param_layouts": {
            "gnn": model.GNN_LAYOUT.describe(),
            "wm": model.WM_LAYOUT.describe(),
            "ctrl": model.CTRL_LAYOUT.describe(),
        },
        "artifacts": {},
    }

    for name, fn, specs, arg_names, outs in _exports():
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {
                    "name": n,
                    "shape": list(s.shape),
                    "dtype": str(s.dtype),
                }
                for s, n in zip(specs, arg_names)
            ],
            "outputs": outs,
        }
        manifest["artifacts"][name] = entry
        if only is not None and name not in only:
            continue
        print(f"lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {len(text)} chars -> {path}", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest -> {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
