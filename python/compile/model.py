"""L2: RLFlow's neural stack in JAX, calling the L1 Pallas kernels.

Three networks, mirroring the paper:

  * **GNN graph auto-encoder** (§3.3 "we use a graph neural network to
    generate a latent representation of the input computation graphs").
    Encoder: two fused message-passing layers -> masked mean pool -> latent z.
    Decoder (training only): per-node feature reconstruction + adjacency
    logits, so z is forced to carry graph structure. Plays the role of the
    V(AE) stage of Ha & Schmidhuber's pipeline.

  * **MDN-RNN world model** (§3.3.2): fused LSTM cell + per-dimension
    Gaussian-mixture head models P(z_{t+1} | a_t, z_t, h_t), with auxiliary
    heads for the reward, the next xfer validity mask, and episode
    termination — the three failure sources §4.7 calls out.

  * **Actor-critic controller** (§3.4): a trunk MLP over [z, h] with a
    transformation head, a location head *conditioned on the chosen
    transformation* (§3.1.3's two-step action factorisation), and a value
    head; trained with PPO (clipped surrogate).

Every parameter vector is a **flat f32 vector**; ``Layout`` records the
(name, shape) slices. The Rust side treats parameters as opaque buffers and
only ever threads them between artifacts, so flatness keeps the FFI surface
to a single literal per state tensor. Adam runs in-graph on the flat vector.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import hp
from .kernels.gnn import gnn_layer
from .kernels.lstm import lstm_cell
from .kernels.mdn import mdn_nll

Array = jax.Array

# ---------------------------------------------------------------------------
# Flat-parameter plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Layout:
    """Ordered (name, shape) slices of a flat parameter vector."""

    entries: Tuple[Tuple[str, Tuple[int, ...]], ...]

    @property
    def size(self) -> int:
        total = 0
        for _, shape in self.entries:
            n = 1
            for d in shape:
                n *= d
            total += n
        return total

    def unflatten(self, theta: Array) -> Dict[str, Array]:
        out, off = {}, 0
        for name, shape in self.entries:
            n = 1
            for d in shape:
                n *= d
            out[name] = theta[off : off + n].reshape(shape)
            off += n
        return out

    def describe(self) -> List[dict]:
        return [{"name": n, "shape": list(s)} for n, s in self.entries]


def _init_flat(layout: Layout, seed: Array) -> Array:
    """He-style init per slice, deterministic in the scalar ``seed``."""
    key = jax.random.PRNGKey(seed.astype(jnp.int32))
    chunks = []
    for i, (name, shape) in enumerate(layout.entries):
        k = jax.random.fold_in(key, i)
        n = 1
        for d in shape:
            n *= d
        if name.endswith("_b"):  # biases start at zero
            chunks.append(jnp.zeros((n,), jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else n
            scale = jnp.sqrt(2.0 / max(fan_in, 1)).astype(jnp.float32)
            chunks.append(scale * jax.random.normal(k, (n,), jnp.float32))
    return jnp.concatenate(chunks)


def adam_update(theta, m, v, t, grad, lr):
    """One Adam step on flat vectors. ``t`` is the f32 step counter."""
    t1 = t + 1.0
    m1 = hp.ADAM_B1 * m + (1.0 - hp.ADAM_B1) * grad
    v1 = hp.ADAM_B2 * v + (1.0 - hp.ADAM_B2) * grad * grad
    mhat = m1 / (1.0 - hp.ADAM_B1**t1)
    vhat = v1 / (1.0 - hp.ADAM_B2**t1)
    theta1 = theta - lr * mhat / (jnp.sqrt(vhat) + hp.ADAM_EPS)
    return theta1, m1, v1, t1


# ---------------------------------------------------------------------------
# GNN graph auto-encoder
# ---------------------------------------------------------------------------

GNN_LAYOUT = Layout(
    entries=(
        ("enc0_wn", (hp.NODE_FEATS, hp.GNN_HIDDEN)),
        ("enc0_ws", (hp.NODE_FEATS, hp.GNN_HIDDEN)),
        ("enc0_b", (hp.GNN_HIDDEN,)),
        ("enc1_wn", (hp.GNN_HIDDEN, hp.GNN_HIDDEN)),
        ("enc1_ws", (hp.GNN_HIDDEN, hp.GNN_HIDDEN)),
        ("enc1_b", (hp.GNN_HIDDEN,)),
        ("pool_w", (hp.GNN_HIDDEN, hp.LATENT)),
        ("pool_b", (hp.LATENT,)),
        ("dec_feat_w", (hp.GNN_HIDDEN, hp.NODE_FEATS)),
        ("dec_feat_b", (hp.NODE_FEATS,)),
        ("dec_adj_w", (hp.GNN_HIDDEN, hp.GNN_HIDDEN)),
    )
)


def _norm_adjacency(adj: Array, mask: Array) -> Array:
    """Symmetrise + self-loop + row-normalise, restricted to live nodes."""
    m2 = mask[:, None] * mask[None, :]
    a = (adj + adj.T) * m2 + jnp.eye(adj.shape[0]) * mask[:, None]
    deg = jnp.sum(a, axis=-1, keepdims=True)
    return a / jnp.maximum(deg, 1e-6)


def gnn_node_embed(p: Dict[str, Array], feats: Array, adj: Array, mask: Array) -> Array:
    """Per-node embeddings for one graph. feats [N,F], adj [N,N], mask [N]."""
    a = _norm_adjacency(adj, mask)
    h = gnn_layer(a, feats, p["enc0_wn"], p["enc0_ws"], p["enc0_b"])
    h = gnn_layer(a, h, p["enc1_wn"], p["enc1_ws"], p["enc1_b"])
    return h * mask[:, None]


def gnn_encode_one(p: Dict[str, Array], feats: Array, adj: Array, mask: Array) -> Array:
    h = gnn_node_embed(p, feats, adj, mask)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pooled = jnp.sum(h, axis=0) / denom
    return jnp.tanh(pooled @ p["pool_w"] + p["pool_b"])


def gnn_encode(theta: Array, feats: Array, adj: Array, mask: Array):
    """Batched encode: feats [B,N,F], adj [B,N,N], mask [B,N] -> z [B,Z]."""
    p = GNN_LAYOUT.unflatten(theta)
    return (jax.vmap(lambda f, a, m: gnn_encode_one(p, f, a, m))(feats, adj, mask),)


def gnn_ae_loss(theta: Array, feats: Array, adj: Array, mask: Array) -> Array:
    """Reconstruction loss forcing the embedding to carry graph structure."""
    p = GNN_LAYOUT.unflatten(theta)

    def one(f, a, m):
        h = gnn_node_embed(p, f, a, m)
        feat_hat = h @ p["dec_feat_w"] + p["dec_feat_b"]
        feat_mse = jnp.sum(((feat_hat - f) ** 2) * m[:, None]) / jnp.maximum(
            jnp.sum(m) * hp.NODE_FEATS, 1.0
        )
        logits = (h @ p["dec_adj_w"]) @ h.T
        m2 = m[:, None] * m[None, :]
        bce = jnp.sum(m2 * _bce(logits, a)) / jnp.maximum(jnp.sum(m2), 1.0)
        return feat_mse + bce

    return jnp.mean(jax.vmap(one)(feats, adj, mask))


def gnn_init(seed: Array) -> Tuple[Array]:
    return (_init_flat(GNN_LAYOUT, seed),)


def gnn_ae_train(theta, m, v, t, feats, adj, mask, lr):
    loss, grad = jax.value_and_grad(gnn_ae_loss)(theta, feats, adj, mask)
    theta1, m1, v1, t1 = adam_update(theta, m, v, t, grad, lr)
    return theta1, m1, v1, t1, loss


# ---------------------------------------------------------------------------
# MDN-RNN world model
# ---------------------------------------------------------------------------

_RNN_IN = hp.LATENT + 2 * hp.ACT_EMB

WM_LAYOUT = Layout(
    entries=(
        ("emb_xfer", (hp.N_XFERS1, hp.ACT_EMB)),
        ("emb_loc", (hp.MAX_LOCS, hp.ACT_EMB)),
        ("lstm_wx", (_RNN_IN, 4 * hp.RNN_HIDDEN)),
        ("lstm_wh", (hp.RNN_HIDDEN, 4 * hp.RNN_HIDDEN)),
        ("lstm_b", (4 * hp.RNN_HIDDEN,)),
        ("mdn_w", (hp.RNN_HIDDEN, hp.LATENT * hp.MDN_K * 3)),
        ("mdn_b", (hp.LATENT * hp.MDN_K * 3,)),
        ("rew_w", (hp.RNN_HIDDEN, 1)),
        ("rew_b", (1,)),
        ("mask_w", (hp.RNN_HIDDEN, hp.N_XFERS1)),
        ("mask_b", (hp.N_XFERS1,)),
        ("done_w", (hp.RNN_HIDDEN, 1)),
        ("done_b", (1,)),
    )
)


def _bce(logits, target):
    """Numerically stable elementwise binary cross-entropy from logits."""
    return (
        jnp.maximum(logits, 0.0)
        - logits * target
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def _wm_cell(p, z, a, h, c):
    """One world-model step. z [B,Z], a [B,2] int32, h/c [B,R]."""
    ex = p["emb_xfer"][a[:, 0]]
    el = p["emb_loc"][a[:, 1]]
    x = jnp.concatenate([z, ex, el], axis=-1)
    h1, c1 = lstm_cell(x, h, c, p["lstm_wx"], p["lstm_wh"], p["lstm_b"])
    mdn_raw = h1 @ p["mdn_w"] + p["mdn_b"]
    b = z.shape[0]
    mdn3 = mdn_raw.reshape(b, hp.LATENT, hp.MDN_K, 3)
    log_pi = mdn3[..., 0]
    mu = mdn3[..., 1]
    log_sig = jnp.clip(mdn3[..., 2], hp.LOGSIG_MIN, hp.LOGSIG_MAX)
    rew = (h1 @ p["rew_w"] + p["rew_b"])[:, 0]
    mask_logits = h1 @ p["mask_w"] + p["mask_b"]
    done_logit = (h1 @ p["done_w"] + p["done_b"])[:, 0]
    return (log_pi, mu, log_sig, rew, mask_logits, done_logit, h1, c1)


def wm_step(theta, z, a, h, c):
    """Inference artifact: single step; GMM sampling happens Rust-side."""
    p = WM_LAYOUT.unflatten(theta)
    return _wm_cell(p, z, a, h, c)


def wm_loss(theta, z, a, z_next, r, xmask, done, valid):
    """Teacher-forced sequence loss.

    z [B,T,Z]; a [B,T,2] i32; z_next [B,T,Z]; r [B,T]; xmask [B,T,X+1];
    done [B,T]; valid [B,T] (1 while the step is real, 0 on padding).
    """
    p = WM_LAYOUT.unflatten(theta)
    bsz = z.shape[0]
    h0 = jnp.zeros((bsz, hp.RNN_HIDDEN), jnp.float32)
    c0 = jnp.zeros((bsz, hp.RNN_HIDDEN), jnp.float32)

    def step(carry, inp):
        h, c = carry
        zt, at, znt, rt, xmt, dt, vt = inp
        log_pi, mu, log_sig, rew, mask_logits, done_logit, h1, c1 = _wm_cell(
            p, zt, at, h, c
        )
        nll = mdn_nll(log_pi, mu, log_sig, znt)  # [B]
        r_se = (rew - rt) ** 2
        m_bce = jnp.mean(_bce(mask_logits, xmt), axis=-1)
        d_bce = _bce(done_logit, dt)
        losses = jnp.stack(
            [
                jnp.sum(nll * vt),
                jnp.sum(r_se * vt),
                jnp.sum(m_bce * vt),
                jnp.sum(d_bce * vt),
                jnp.sum(vt),
            ]
        )
        return (h1, c1), losses

    seq = (
        z.transpose(1, 0, 2),
        a.transpose(1, 0, 2),
        z_next.transpose(1, 0, 2),
        r.T,
        xmask.transpose(1, 0, 2),
        done.T,
        valid.T,
    )
    (_, _), per_t = jax.lax.scan(step, (h0, c0), seq)
    tot = jnp.sum(per_t, axis=0)
    denom = jnp.maximum(tot[4], 1.0)
    nll, r_mse, m_bce, d_bce = (
        tot[0] / denom,
        tot[1] / denom,
        tot[2] / denom,
        tot[3] / denom,
    )
    total = nll + r_mse + m_bce + d_bce
    return total, (nll, r_mse, m_bce, d_bce)


def wm_init(seed: Array) -> Tuple[Array]:
    return (_init_flat(WM_LAYOUT, seed),)


def wm_train(theta, m, v, t, z, a, z_next, r, xmask, done, valid, lr):
    (total, aux), grad = jax.value_and_grad(wm_loss, has_aux=True)(
        theta, z, a, z_next, r, xmask, done, valid
    )
    theta1, m1, v1, t1 = adam_update(theta, m, v, t, grad, lr)
    nll, r_mse, m_bce, d_bce = aux
    return theta1, m1, v1, t1, total, nll, r_mse, m_bce, d_bce


# ---------------------------------------------------------------------------
# Actor-critic controller (PPO)
# ---------------------------------------------------------------------------

CTRL_LAYOUT = Layout(
    entries=(
        ("trunk_w", (hp.LATENT + hp.RNN_HIDDEN, hp.CTRL_HIDDEN)),
        ("trunk_b", (hp.CTRL_HIDDEN,)),
        ("xfer_w", (hp.CTRL_HIDDEN, hp.N_XFERS1)),
        ("xfer_b", (hp.N_XFERS1,)),
        ("loc_w", (hp.CTRL_HIDDEN, hp.N_XFERS1 * hp.MAX_LOCS)),
        ("loc_b", (hp.N_XFERS1 * hp.MAX_LOCS,)),
        ("val_w", (hp.CTRL_HIDDEN, 1)),
        ("val_b", (1,)),
    )
)


def _ctrl_forward(p, z, h):
    trunk = jnp.tanh(jnp.concatenate([z, h], axis=-1) @ p["trunk_w"] + p["trunk_b"])
    xlog = trunk @ p["xfer_w"] + p["xfer_b"]
    llog = (trunk @ p["loc_w"] + p["loc_b"]).reshape(
        trunk.shape[0], hp.N_XFERS1, hp.MAX_LOCS
    )
    value = (trunk @ p["val_w"] + p["val_b"])[:, 0]
    return xlog, llog, value


def ctrl_policy(theta, z, h):
    """Inference artifact: raw logits; masking + sampling are Rust-side."""
    p = CTRL_LAYOUT.unflatten(theta)
    return _ctrl_forward(p, z, h)


def _masked_log_softmax(logits, mask):
    neg = jnp.asarray(-1e9, logits.dtype)
    masked = jnp.where(mask > 0.5, logits, neg)
    return jax.nn.log_softmax(masked, axis=-1)


def ppo_loss(theta, z, h, act, old_logp, adv, ret, xmask, lmask, clip, ent_coef):
    """Clipped-surrogate PPO over the factorised (xfer, location) action.

    z [B,Z]; h [B,R]; act [B,2] i32; old_logp/adv/ret [B];
    xmask [B,X+1]; lmask [B,L] (locations valid for the *chosen* xfer).
    """
    p = CTRL_LAYOUT.unflatten(theta)
    # Hot-path optimisation (EXPERIMENTS.md §Perf/L2): materialising the
    # full [B, X+1, L] location-logit tensor costs ~50x more FLOPs than the
    # training loss needs — only the *chosen* transformation's location row
    # enters the likelihood. Gather the chosen slice of loc_w first.
    trunk = jnp.tanh(jnp.concatenate([z, h], axis=-1) @ p["trunk_w"] + p["trunk_b"])
    xlog = trunk @ p["xfer_w"] + p["xfer_b"]
    value = (trunk @ p["val_w"] + p["val_b"])[:, 0]
    loc_w3 = p["loc_w"].reshape(hp.CTRL_HIDDEN, hp.N_XFERS1, hp.MAX_LOCS)
    w_act = loc_w3[:, act[:, 0], :]  # [H, B, L]
    b_act = p["loc_b"].reshape(hp.N_XFERS1, hp.MAX_LOCS)[act[:, 0]]  # [B, L]
    chosen_llog = jnp.einsum("bh,hbl->bl", trunk, w_act) + b_act
    bidx = jnp.arange(z.shape[0])
    x_lsm = _masked_log_softmax(xlog, xmask)
    l_lsm = _masked_log_softmax(chosen_llog, lmask)
    # NO-OP has no location; its location logprob contributes 0.
    is_noop = (act[:, 0] == hp.N_XFERS).astype(jnp.float32)
    logp = x_lsm[bidx, act[:, 0]] + (1.0 - is_noop) * l_lsm[bidx, act[:, 1]]

    ratio = jnp.exp(logp - old_logp)
    adv_n = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)
    surr = jnp.minimum(ratio * adv_n, jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv_n)
    pi_loss = -jnp.mean(surr)
    v_loss = jnp.mean((value - ret) ** 2)

    x_probs = jnp.exp(x_lsm)
    x_ent = -jnp.sum(jnp.where(xmask > 0.5, x_probs * x_lsm, 0.0), axis=-1)
    l_probs = jnp.exp(l_lsm)
    l_ent = -jnp.sum(jnp.where(lmask > 0.5, l_probs * l_lsm, 0.0), axis=-1)
    entropy = jnp.mean(x_ent + (1.0 - is_noop) * l_ent)

    approx_kl = jnp.mean(old_logp - logp)
    total = pi_loss + 0.5 * v_loss - ent_coef * entropy
    return total, (pi_loss, v_loss, entropy, approx_kl)


def ctrl_init(seed: Array) -> Tuple[Array]:
    return (_init_flat(CTRL_LAYOUT, seed),)


def ctrl_train(
    theta, m, v, t, z, h, act, old_logp, adv, ret, xmask, lmask, lr, clip, ent_coef
):
    (_, aux), grad = jax.value_and_grad(ppo_loss, has_aux=True)(
        theta, z, h, act, old_logp, adv, ret, xmask, lmask, clip, ent_coef
    )
    theta1, m1, v1, t1 = adam_update(theta, m, v, t, grad, lr)
    pi_loss, v_loss, entropy, approx_kl = aux
    return theta1, m1, v1, t1, pi_loss, v_loss, entropy, approx_kl
