"""Shared hyperparameters for the RLFlow neural stack.

These constants define the *compiled* shapes of every AOT artifact. The Rust
coordinator reads them back from ``artifacts/manifest.json`` — never hardcode
them on the Rust side.

Scaling note (see DESIGN.md §Hardware-Adaptation): the MDN-RNN matches the
paper (256 hidden units, 8 Gaussians); graph-side dimensions are sized so a
CPU-only PJRT client trains the full pipeline in minutes.
"""

# ---- Graph encoding (L3 -> L2 contract) -----------------------------------
MAX_NODES = 320  # N: graphs are padded/validated to this many nodes (op nodes only)
NODE_FEATS = 32  # F: per-node feature width (op one-hot + scalar stats)
GNN_HIDDEN = 64  # H: hidden width of message-passing layers
GNN_LAYERS = 2
LATENT = 48      # Z: pooled graph latent fed to the world model / controller

# ---- Action space (mirrors paper §3.1.3) ----------------------------------
N_XFERS = 48          # X: substitution-rule slots
N_XFERS1 = N_XFERS + 1  # +1 NO-OP action (terminates the episode)
MAX_LOCS = 200        # L: per-xfer location limit (paper: "hardcoded ... 200")
ACT_EMB = 32          # embedding width for (xfer, location) fed to the RNN

# ---- World model (paper §3.3.2: 8 Gaussians, 256 hidden units) -------------
RNN_HIDDEN = 256  # R
MDN_K = 8         # K mixtures per latent dimension
LOGSIG_MIN = -5.0
LOGSIG_MAX = 2.0

# ---- Batch shapes baked into artifacts -------------------------------------
B_ENC = 8     # GNN auto-encoder train / bulk-encode batch
B_ONE = 1     # single-sample acting batch (real environment stepping)
SEQ_LEN = 16  # T: world-model training sequence length
B_WM = 16     # world-model training batch
B_DREAM = 16  # parallel imagined rollouts in the dream environment
B_PPO = 256   # flattened PPO minibatch

# ---- Controller -------------------------------------------------------------
CTRL_HIDDEN = 256

# ---- Kernel tiling (L1) ------------------------------------------------------
GNN_ROW_BLOCK = 32  # node-row tile for the fused message-passing kernel

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def as_dict() -> dict:
    """Everything above, for the manifest."""
    return {
        k: v
        for k, v in globals().items()
        if k.isupper() and isinstance(v, (int, float))
    }
