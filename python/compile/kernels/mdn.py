"""Pallas MDN negative-log-likelihood (L1 hot-spot #3).

Scoring the next latent under the per-dimension Gaussian mixture is the
world-model training loss (paper Fig. 8 plots exactly this quantity). The
fused kernel evaluates, for one batch row at a time, all Z*K mixture
components — normalisation (log-softmax over K), the squared Mahalanobis
term, and the log-sum-exp reduction — without materialising the [B, Z, K]
intermediates in HBM.

Numerical care: both reductions use the max-subtraction form of
log-sum-exp, matching ``jax.nn.log_softmax`` / ``jax.scipy.logsumexp`` so
the kernel is bit-comparable to the oracle within f32 rounding.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_LOG_2PI = float(jnp.log(2.0 * jnp.pi))


def _kernel(log_pi_ref, mu_ref, log_sig_ref, target_ref, o_ref):
    log_pi = log_pi_ref[...]  # [1, Z, K]
    mu = mu_ref[...]
    log_sig = log_sig_ref[...]
    target = target_ref[...]  # [1, Z]

    # log-softmax over the mixture axis.
    m = jnp.max(log_pi, axis=-1, keepdims=True)
    shifted = log_pi - m
    log_w = shifted - jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))

    z = (target[..., None] - mu) * jnp.exp(-log_sig)
    comp = log_w - 0.5 * z * z - log_sig - 0.5 * _LOG_2PI

    cm = jnp.max(comp, axis=-1, keepdims=True)
    ll = jnp.log(jnp.sum(jnp.exp(comp - cm), axis=-1)) + cm[..., 0]  # [1, Z]
    o_ref[...] = -jnp.mean(ll, axis=-1)


def _mdn_nll_fwd_impl(log_pi, mu, log_sig, target):
    b, z, k = log_pi.shape
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, z, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, z, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, z, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, z), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), log_pi.dtype),
        interpret=True,
    )(log_pi, mu, log_sig, target)


@jax.custom_vjp
def mdn_nll(log_pi, mu, log_sig, target):
    """Mean-over-dims GMM NLL per batch row; semantics ``ref.mdn_nll_ref``."""
    return _mdn_nll_fwd_impl(log_pi, mu, log_sig, target)


def _fwd(log_pi, mu, log_sig, target):
    return mdn_nll(log_pi, mu, log_sig, target), (log_pi, mu, log_sig, target)


def _bwd(res, g):
    _, vjp = jax.vjp(ref.mdn_nll_ref, *res)
    return vjp(g)


mdn_nll.defvjp(_fwd, _bwd)
