"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *correctness ground truth*: pytest asserts the Pallas kernels
(interpret mode) match these to float32 tolerance, and the kernels' custom
VJPs are derived from these functions so the training graphs differentiate
through mathematically identical code.
"""

import jax
import jax.numpy as jnp

_LOG_2PI = jnp.log(2.0 * jnp.pi)


def gnn_layer_ref(adj, h, w_nbr, w_self, b):
    """Fused message-passing layer.

    ``out = relu((adj @ h) @ w_nbr + h @ w_self + b)``

    Args:
      adj:    [N, N] normalised adjacency (rows sum to ~1; already masked).
      h:      [N, F_in] node features.
      w_nbr:  [F_in, F_out] neighbour-aggregation weight.
      w_self: [F_in, F_out] self-loop weight.
      b:      [F_out] bias.

    Returns: [N, F_out].
    """
    agg = adj @ h
    return jnp.maximum(agg @ w_nbr + h @ w_self + b, 0.0)


def lstm_cell_ref(x, h, c, w_x, w_h, b):
    """Standard fused LSTM cell, gate order (i, f, g, o).

    Args:
      x: [B, I] input.
      h: [B, R] previous hidden state.
      c: [B, R] previous cell state.
      w_x: [I, 4R], w_h: [R, 4R], b: [4R].

    Returns: (h_new [B, R], c_new [B, R]).
    """
    r = h.shape[-1]
    gates = x @ w_x + h @ w_h + b
    i = jax.nn.sigmoid(gates[..., 0 * r : 1 * r])
    f = jax.nn.sigmoid(gates[..., 1 * r : 2 * r])
    g = jnp.tanh(gates[..., 2 * r : 3 * r])
    o = jax.nn.sigmoid(gates[..., 3 * r : 4 * r])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def mdn_nll_ref(log_pi, mu, log_sig, target):
    """Per-sample negative log-likelihood of a per-dimension GMM.

    Mirrors Ha & Schmidhuber's MDN-RNN loss: every latent dimension has its
    own K-component 1-D Gaussian mixture.

    Args:
      log_pi:  [B, Z, K] unnormalised mixture logits.
      mu:      [B, Z, K] component means.
      log_sig: [B, Z, K] component log standard deviations.
      target:  [B, Z] next-step latent to score.

    Returns: [B] mean (over Z) negative log-likelihood.
    """
    log_w = jax.nn.log_softmax(log_pi, axis=-1)
    inv_sig = jnp.exp(-log_sig)
    z = (target[..., None] - mu) * inv_sig
    comp = log_w - 0.5 * z * z - log_sig - 0.5 * _LOG_2PI
    ll = jax.scipy.special.logsumexp(comp, axis=-1)  # [B, Z]
    return -jnp.mean(ll, axis=-1)
