"""Pallas fused message-passing layer (L1 hot-spot #1).

One kernel computes ``relu((adj @ h) @ w_nbr + h @ w_self + b)`` for a tile
of node rows at a time, so the aggregate->project->activate chain never
round-trips through HBM between steps.

TPU mapping (DESIGN.md §Hardware-Adaptation / §Perf): the grid walks node-row
tiles of ``GNN_ROW_BLOCK`` rows; each grid step holds one ``[BN, N]``
adjacency stripe, the full ``[N, F_in]`` feature panel and both weight
panels in VMEM — at the compiled shapes (N=160, F<=64) that is ~90 KiB,
far under the ~16 MiB VMEM budget, and both matmuls feed the MXU with
contracted dims >= 32. On this image the kernel runs through
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls), which
lowers the same body to plain HLO.

The public entry point ``gnn_layer`` is a ``jax.custom_vjp``: forward is the
Pallas kernel, backward is derived from the jnp oracle in ``ref.py`` (same
math, so gradients are exact for the kernel semantics).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

try:  # package-relative when imported as compile.kernels.gnn
    from .. import hp
except ImportError:  # pragma: no cover - direct import fallback
    import hp  # type: ignore


def _kernel(adj_ref, h_full_ref, h_tile_ref, w_nbr_ref, w_self_ref, b_ref, o_ref):
    """Body for one node-row tile.

    adj_ref:    [BN, N] stripe of the normalised adjacency.
    h_full_ref: [N, F_in] full feature panel (neighbour side).
    h_tile_ref: [BN, F_in] the same row tile as the output (self side).
    """
    agg = jnp.dot(adj_ref[...], h_full_ref[...])  # [BN, F_in] on the MXU
    proj = jnp.dot(agg, w_nbr_ref[...]) + jnp.dot(h_tile_ref[...], w_self_ref[...])
    o_ref[...] = jnp.maximum(proj + b_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def _gnn_layer_fwd_impl(adj, h, w_nbr, w_self, b, block=hp.GNN_ROW_BLOCK):
    n, f_in = h.shape
    f_out = w_nbr.shape[1]
    if n % block != 0:
        # Shapes are compile-time constants; pad defensively for odd test sizes.
        pad = (-n) % block
        adj = jnp.pad(adj, ((0, pad), (0, 0)))
        h_tile_src = jnp.pad(h, ((0, pad), (0, 0)))
        n_pad = n + pad
    else:
        h_tile_src = h
        n_pad = n
    grid = (n_pad // block,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, adj.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec(h.shape, lambda i: (0, 0)),
            pl.BlockSpec((block, f_in), lambda i: (i, 0)),
            pl.BlockSpec(w_nbr.shape, lambda i: (0, 0)),
            pl.BlockSpec(w_self.shape, lambda i: (0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, f_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, f_out), h.dtype),
        interpret=True,
    )(adj, h, h_tile_src, w_nbr, w_self, b)
    return out[:n]


@jax.custom_vjp
def gnn_layer(adj, h, w_nbr, w_self, b):
    """Fused GNN layer; see ``ref.gnn_layer_ref`` for exact semantics."""
    return _gnn_layer_fwd_impl(adj, h, w_nbr, w_self, b)


def _fwd(adj, h, w_nbr, w_self, b):
    return gnn_layer(adj, h, w_nbr, w_self, b), (adj, h, w_nbr, w_self, b)


def _bwd(res, g):
    _, vjp = jax.vjp(ref.gnn_layer_ref, *res)
    return vjp(g)


gnn_layer.defvjp(_fwd, _bwd)
