"""Pallas fused LSTM cell (L1 hot-spot #2).

The MDN-RNN world model steps this cell once per (state, action) pair — both
when training the model (inside a scan over the sequence axis) and on every
step of the imagined environment, so it is the single most-executed kernel
in the system.

Fusion rationale: a naive cell issues two GEMMs plus ~8 elementwise ops,
each a separate HBM round-trip for [B, 4R] intermediates. This kernel keeps
the gate block in VMEM: one grid step computes ``x @ w_x + h @ w_h + b`` and
applies all four gate nonlinearities before anything is written back. At
compiled shapes (B=16, R=256, I=Z+2*ACT_EMB=112) the VMEM working set is
w_x (112x1024) + w_h (256x1024) + activations ~= 1.7 MiB — comfortably
resident, with both GEMMs MXU-shaped (contracted dims 112/256, output lanes
1024). ``interpret=True`` on this image (see gnn.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    r = h_ref.shape[-1]
    gates = (
        jnp.dot(x_ref[...], wx_ref[...])
        + jnp.dot(h_ref[...], wh_ref[...])
        + b_ref[...]
    )
    i = jax.nn.sigmoid(gates[:, 0 * r : 1 * r])
    f = jax.nn.sigmoid(gates[:, 1 * r : 2 * r])
    g = jnp.tanh(gates[:, 2 * r : 3 * r])
    o = jax.nn.sigmoid(gates[:, 3 * r : 4 * r])
    c_new = f * c_ref[...] + i * g
    h_out_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


def _lstm_fwd_impl(x, h, c, w_x, w_h, b):
    bsz, r = h.shape
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bsz, r), h.dtype),
            jax.ShapeDtypeStruct((bsz, r), c.dtype),
        ),
        interpret=True,
    )(x, h, c, w_x, w_h, b)


@jax.custom_vjp
def lstm_cell(x, h, c, w_x, w_h, b):
    """Fused LSTM cell; semantics exactly ``ref.lstm_cell_ref``."""
    return _lstm_fwd_impl(x, h, c, w_x, w_h, b)


def _fwd(x, h, c, w_x, w_h, b):
    return lstm_cell(x, h, c, w_x, w_h, b), (x, h, c, w_x, w_h, b)


def _bwd(res, g):
    _, vjp = jax.vjp(ref.lstm_cell_ref, *res)
    return vjp(g)


lstm_cell.defvjp(_fwd, _bwd)
