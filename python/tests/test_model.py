"""L2 contracts: shapes, losses decrease, PPO/Adam sanity, layout round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import hp, model

jax.config.update("jax_platform_name", "cpu")


def key(i):
    return jax.random.PRNGKey(i)


def random_graph_batch(b, seed=0):
    ks = jax.random.split(key(seed), 3)
    feats = jax.random.normal(ks[0], (b, hp.MAX_NODES, hp.NODE_FEATS))
    adj = (jax.random.uniform(ks[1], (b, hp.MAX_NODES, hp.MAX_NODES)) < 0.03).astype(
        jnp.float32
    )
    n_live = 40
    mask = jnp.zeros((b, hp.MAX_NODES)).at[:, :n_live].set(1.0)
    feats = feats * mask[..., None]
    adj = adj * mask[:, :, None] * mask[:, None, :]
    return feats, adj, mask


class TestLayout:
    def test_sizes_positive(self):
        assert model.GNN_LAYOUT.size > 0
        assert model.WM_LAYOUT.size > 0
        assert model.CTRL_LAYOUT.size > 0

    def test_unflatten_round_trip(self):
        theta = jnp.arange(model.GNN_LAYOUT.size, dtype=jnp.float32)
        parts = model.GNN_LAYOUT.unflatten(theta)
        flat_again = jnp.concatenate([parts[n].reshape(-1) for n, _ in model.GNN_LAYOUT.entries])
        np.testing.assert_array_equal(theta, flat_again)

    def test_init_deterministic(self):
        a = model.gnn_init(jnp.int32(7))[0]
        b = model.gnn_init(jnp.int32(7))[0]
        c = model.gnn_init(jnp.int32(8))[0]
        np.testing.assert_array_equal(a, b)
        assert not np.allclose(a, c)

    def test_biases_zero_at_init(self):
        theta = model.wm_init(jnp.int32(0))[0]
        parts = model.WM_LAYOUT.unflatten(theta)
        np.testing.assert_array_equal(parts["lstm_b"], jnp.zeros_like(parts["lstm_b"]))


class TestGnn:
    def test_encode_shape_and_range(self):
        theta = model.gnn_init(jnp.int32(0))[0]
        feats, adj, mask = random_graph_batch(4)
        (z,) = model.gnn_encode(theta, feats, adj, mask)
        assert z.shape == (4, hp.LATENT)
        assert float(jnp.max(jnp.abs(z))) <= 1.0  # tanh output

    def test_encode_ignores_padded_nodes(self):
        """Changing features of masked-out nodes must not change z."""
        theta = model.gnn_init(jnp.int32(0))[0]
        feats, adj, mask = random_graph_batch(2)
        (z1,) = model.gnn_encode(theta, feats, adj, mask)
        feats2 = feats.at[:, 100:, :].set(99.0)  # nodes >= 40 are masked
        (z2,) = model.gnn_encode(theta, feats2, adj, mask)
        np.testing.assert_allclose(z1, z2, rtol=1e-5, atol=1e-5)

    def test_ae_train_reduces_loss(self):
        theta = model.gnn_init(jnp.int32(0))[0]
        m = jnp.zeros_like(theta)
        v = jnp.zeros_like(theta)
        t = jnp.float32(0)
        feats, adj, mask = random_graph_batch(hp.B_ENC)
        lr = jnp.float32(1e-3)
        first = None
        step = jax.jit(model.gnn_ae_train)
        for i in range(12):
            theta, m, v, t, loss = step(theta, m, v, t, feats, adj, mask, lr)
            if first is None:
                first = float(loss)
        assert float(loss) < first


class TestWorldModel:
    def _batch(self, b=hp.B_WM, t=hp.SEQ_LEN, seed=0):
        ks = jax.random.split(key(seed), 7)
        z = jax.random.normal(ks[0], (b, t, hp.LATENT))
        a = jax.random.randint(ks[1], (b, t, 2), 0, 10).astype(jnp.int32)
        z_next = z + 0.1 * jax.random.normal(ks[2], (b, t, hp.LATENT))
        r = 0.1 * jax.random.normal(ks[3], (b, t))
        xmask = (jax.random.uniform(ks[4], (b, t, hp.N_XFERS1)) < 0.5).astype(jnp.float32)
        done = jnp.zeros((b, t))
        valid = jnp.ones((b, t))
        return z, a, z_next, r, xmask, done, valid

    def test_step_shapes(self):
        theta = model.wm_init(jnp.int32(0))[0]
        b = 3
        z = jax.random.normal(key(0), (b, hp.LATENT))
        a = jnp.zeros((b, 2), jnp.int32)
        h = jnp.zeros((b, hp.RNN_HIDDEN))
        c = jnp.zeros((b, hp.RNN_HIDDEN))
        out = model.wm_step(theta, z, a, h, c)
        log_pi, mu, log_sig, rew, mask_logits, done_logit, h1, c1 = out
        assert log_pi.shape == (b, hp.LATENT, hp.MDN_K)
        assert mu.shape == (b, hp.LATENT, hp.MDN_K)
        assert rew.shape == (b,)
        assert mask_logits.shape == (b, hp.N_XFERS1)
        assert h1.shape == (b, hp.RNN_HIDDEN)
        assert bool(jnp.all(log_sig >= hp.LOGSIG_MIN - 1e-6))
        assert bool(jnp.all(log_sig <= hp.LOGSIG_MAX + 1e-6))

    def test_train_reduces_loss(self):
        theta = model.wm_init(jnp.int32(1))[0]
        m, v, t = jnp.zeros_like(theta), jnp.zeros_like(theta), jnp.float32(0)
        batch = self._batch()
        lr = jnp.float32(3e-4)
        step = jax.jit(model.wm_train)
        losses = []
        for i in range(8):
            theta, m, v, t, total, nll, r_mse, m_bce, d_bce = step(
                theta, m, v, t, *batch, lr
            )
            losses.append(float(total))
        assert losses[-1] < losses[0]

    def test_valid_mask_zeroes_padding(self):
        """Loss with all-invalid steps equals loss with denom clamp only."""
        theta = model.wm_init(jnp.int32(2))[0]
        z, a, z_next, r, xmask, done, valid = self._batch(seed=3)
        total, _ = model.wm_loss(theta, z, a, z_next, r, xmask, done, jnp.zeros_like(valid))
        assert float(total) == 0.0

    def test_hidden_state_evolves(self):
        theta = model.wm_init(jnp.int32(0))[0]
        z = jax.random.normal(key(1), (2, hp.LATENT))
        a = jnp.zeros((2, 2), jnp.int32)
        h = jnp.zeros((2, hp.RNN_HIDDEN))
        c = jnp.zeros((2, hp.RNN_HIDDEN))
        *_, h1, c1 = model.wm_step(theta, z, a, h, c)
        assert float(jnp.max(jnp.abs(h1))) > 0.0


class TestController:
    def test_policy_shapes(self):
        theta = model.ctrl_init(jnp.int32(0))[0]
        b = 5
        z = jax.random.normal(key(0), (b, hp.LATENT))
        h = jax.random.normal(key(1), (b, hp.RNN_HIDDEN))
        xlog, llog, value = model.ctrl_policy(theta, z, h)
        assert xlog.shape == (b, hp.N_XFERS1)
        assert llog.shape == (b, hp.N_XFERS1, hp.MAX_LOCS)
        assert value.shape == (b,)

    def _ppo_batch(self, b=hp.B_PPO, seed=0):
        ks = jax.random.split(key(seed), 8)
        z = jax.random.normal(ks[0], (b, hp.LATENT))
        h = jax.random.normal(ks[1], (b, hp.RNN_HIDDEN))
        act = jnp.stack(
            [
                jax.random.randint(ks[2], (b,), 0, hp.N_XFERS1),
                jax.random.randint(ks[3], (b,), 0, hp.MAX_LOCS),
            ],
            axis=-1,
        ).astype(jnp.int32)
        old_logp = -2.0 + 0.1 * jax.random.normal(ks[4], (b,))
        adv = jax.random.normal(ks[5], (b,))
        ret = jax.random.normal(ks[6], (b,))
        xmask = jnp.ones((b, hp.N_XFERS1))
        lmask = jnp.ones((b, hp.MAX_LOCS))
        return z, h, act, old_logp, adv, ret, xmask, lmask

    def test_train_step_runs_and_is_finite(self):
        theta = model.ctrl_init(jnp.int32(0))[0]
        m, v, t = jnp.zeros_like(theta), jnp.zeros_like(theta), jnp.float32(0)
        batch = self._ppo_batch()
        out = jax.jit(model.ctrl_train)(
            theta, m, v, t, *batch, jnp.float32(3e-4), jnp.float32(0.2), jnp.float32(0.01)
        )
        theta1 = out[0]
        assert bool(jnp.all(jnp.isfinite(theta1)))
        assert not np.allclose(np.asarray(theta1), np.asarray(theta))
        for s in out[4:]:
            assert bool(jnp.isfinite(s))

    def test_masked_actions_get_zero_probability(self):
        theta = model.ctrl_init(jnp.int32(0))[0]
        b = 4
        z = jax.random.normal(key(0), (b, hp.LATENT))
        h = jax.random.normal(key(1), (b, hp.RNN_HIDDEN))
        xlog, _, _ = model.ctrl_policy(theta, z, h)
        mask = jnp.zeros((b, hp.N_XFERS1)).at[:, :3].set(1.0)
        lsm = model._masked_log_softmax(xlog, mask)
        probs = jnp.exp(lsm)
        assert float(jnp.max(probs[:, 3:])) < 1e-20
        np.testing.assert_allclose(jnp.sum(probs, axis=-1), 1.0, rtol=1e-4)


class TestAdam:
    def test_matches_reference_formula(self):
        theta = jnp.array([1.0, -2.0, 3.0])
        g = jnp.array([0.5, 0.5, -0.5])
        m = jnp.zeros(3)
        v = jnp.zeros(3)
        theta1, m1, v1, t1 = model.adam_update(theta, m, v, jnp.float32(0), g, 0.1)
        # step 1: mhat = g, vhat = g^2 -> update ~= lr * sign(g)
        np.testing.assert_allclose(
            theta1, theta - 0.1 * g / (jnp.abs(g) + 1e-8 / 1.0), rtol=1e-4
        )
        assert float(t1) == 1.0

    def test_zero_grad_keeps_params(self):
        theta = jnp.array([1.0, 2.0])
        z = jnp.zeros(2)
        theta1, _, _, _ = model.adam_update(theta, z, z, jnp.float32(0), z, 0.1)
        np.testing.assert_allclose(theta1, theta)
