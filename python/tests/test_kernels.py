"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the core correctness signal of the compile path — if these pass, the
HLO that reaches the Rust runtime computes the same numbers the oracles do.
Hypothesis sweeps shapes and seeds; fixed tests pin the compiled shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import hp
from compile.kernels import gnn, lstm, mdn, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(jnp.float32)


# ---------------------------------------------------------------------------
# GNN fused message-passing layer
# ---------------------------------------------------------------------------


class TestGnnKernel:
    def test_compiled_shape(self):
        n, fi, fo = hp.MAX_NODES, hp.NODE_FEATS, hp.GNN_HIDDEN
        adj = jax.nn.softmax(rand(0, (n, n)), axis=-1)
        h = rand(1, (n, fi))
        wn, ws, b = rand(2, (fi, fo), 0.1), rand(3, (fi, fo), 0.1), rand(4, (fo,), 0.1)
        got = gnn.gnn_layer(adj, h, wn, ws, b)
        want = ref.gnn_layer_ref(adj, h, wn, ws, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        assert got.shape == (n, fo)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(4, 96),
        fi=st.integers(2, 48),
        fo=st.integers(2, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, n, fi, fo, seed):
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 5)
        adj = jax.random.uniform(ks[0], (n, n))
        h = jax.random.normal(ks[1], (n, fi))
        wn = 0.2 * jax.random.normal(ks[2], (fi, fo))
        ws = 0.2 * jax.random.normal(ks[3], (fi, fo))
        b = 0.2 * jax.random.normal(ks[4], (fo,))
        got = gnn.gnn_layer(adj, h, wn, ws, b)
        want = ref.gnn_layer_ref(adj, h, wn, ws, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_gradients_match_ref(self):
        n, fi, fo = 32, 8, 16
        adj, h = rand(0, (n, n)), rand(1, (n, fi))
        wn, ws, b = rand(2, (fi, fo), 0.1), rand(3, (fi, fo), 0.1), rand(4, (fo,), 0.1)

        def loss_k(wn, ws, b):
            return jnp.sum(gnn.gnn_layer(adj, h, wn, ws, b) ** 2)

        def loss_r(wn, ws, b):
            return jnp.sum(ref.gnn_layer_ref(adj, h, wn, ws, b) ** 2)

        gk = jax.grad(loss_k, argnums=(0, 1, 2))(wn, ws, b)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(wn, ws, b)
        for a, bb in zip(gk, gr):
            np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-4)

    def test_relu_region(self):
        """Outputs are exactly non-negative (relu semantics preserved)."""
        n, fi, fo = 40, 8, 8
        out = gnn.gnn_layer(
            rand(0, (n, n)), rand(1, (n, fi)), rand(2, (fi, fo)), rand(3, (fi, fo)), rand(4, (fo,))
        )
        assert float(jnp.min(out)) >= 0.0

    def test_non_multiple_of_block(self):
        """Row counts that don't divide GNN_ROW_BLOCK pad correctly."""
        n, fi, fo = hp.GNN_ROW_BLOCK + 7, 8, 8
        adj, h = rand(0, (n, n)), rand(1, (n, fi))
        wn, ws, b = rand(2, (fi, fo), 0.1), rand(3, (fi, fo), 0.1), rand(4, (fo,), 0.1)
        got = gnn.gnn_layer(adj, h, wn, ws, b)
        want = ref.gnn_layer_ref(adj, h, wn, ws, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Fused LSTM cell
# ---------------------------------------------------------------------------


class TestLstmKernel:
    def test_compiled_shape(self):
        b, i, r = hp.B_WM, hp.LATENT + 2 * hp.ACT_EMB, hp.RNN_HIDDEN
        x, h, c = rand(0, (b, i)), rand(1, (b, r)), rand(2, (b, r))
        wx, wh, bias = rand(3, (i, 4 * r), 0.05), rand(4, (r, 4 * r), 0.05), rand(5, (4 * r,), 0.05)
        h1, c1 = lstm.lstm_cell(x, h, c, wx, wh, bias)
        h2, c2 = ref.lstm_cell_ref(x, h, c, wx, wh, bias)
        np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 8),
        i=st.integers(1, 32),
        r=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, b, i, r, seed):
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 6)
        x = jax.random.normal(ks[0], (b, i))
        h = jax.random.normal(ks[1], (b, r))
        c = jax.random.normal(ks[2], (b, r))
        wx = 0.1 * jax.random.normal(ks[3], (i, 4 * r))
        wh = 0.1 * jax.random.normal(ks[4], (r, 4 * r))
        bias = 0.1 * jax.random.normal(ks[5], (4 * r,))
        h1, c1 = lstm.lstm_cell(x, h, c, wx, wh, bias)
        h2, c2 = ref.lstm_cell_ref(x, h, c, wx, wh, bias)
        np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-4)

    def test_state_bounded(self):
        """h is an o*tanh(c) product => |h| < 1 elementwise."""
        b, i, r = 4, 8, 16
        h1, _ = lstm.lstm_cell(
            rand(0, (b, i), 3.0), rand(1, (b, r), 3.0), rand(2, (b, r), 3.0),
            rand(3, (i, 4 * r)), rand(4, (r, 4 * r)), rand(5, (4 * r,)),
        )
        assert float(jnp.max(jnp.abs(h1))) < 1.0

    def test_gradients_match_ref(self):
        b, i, r = 4, 8, 16
        x, h, c = rand(0, (b, i)), rand(1, (b, r)), rand(2, (b, r))
        wx, wh, bias = rand(3, (i, 4 * r), 0.1), rand(4, (r, 4 * r), 0.1), rand(5, (4 * r,), 0.1)

        def lk(wx, wh):
            h1, c1 = lstm.lstm_cell(x, h, c, wx, wh, bias)
            return jnp.sum(h1) + jnp.sum(c1**2)

        def lr_(wx, wh):
            h1, c1 = ref.lstm_cell_ref(x, h, c, wx, wh, bias)
            return jnp.sum(h1) + jnp.sum(c1**2)

        gk = jax.grad(lk, argnums=(0, 1))(wx, wh)
        gr = jax.grad(lr_, argnums=(0, 1))(wx, wh)
        for a, bb in zip(gk, gr):
            np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MDN NLL
# ---------------------------------------------------------------------------


class TestMdnKernel:
    def test_compiled_shape(self):
        b, z, k = hp.B_WM, hp.LATENT, hp.MDN_K
        lp, mu = rand(0, (b, z, k)), rand(1, (b, z, k))
        ls, tg = rand(2, (b, z, k), 0.3), rand(3, (b, z))
        got = mdn.mdn_nll(lp, mu, ls, tg)
        want = ref.mdn_nll_ref(lp, mu, ls, tg)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        assert got.shape == (b,)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 8),
        z=st.integers(1, 32),
        k=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, b, z, k, seed):
        kk = jax.random.PRNGKey(seed)
        ks = jax.random.split(kk, 4)
        lp = jax.random.normal(ks[0], (b, z, k))
        mu = jax.random.normal(ks[1], (b, z, k))
        ls = 0.5 * jax.random.normal(ks[2], (b, z, k))
        tg = jax.random.normal(ks[3], (b, z))
        got = mdn.mdn_nll(lp, mu, ls, tg)
        want = ref.mdn_nll_ref(lp, mu, ls, tg)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_single_component_is_gaussian_nll(self):
        """K=1 must reduce to the plain Gaussian negative log-likelihood."""
        b, z = 3, 5
        mu = rand(0, (b, z, 1))
        ls = rand(1, (b, z, 1), 0.2)
        tg = rand(2, (b, z))
        lp = jnp.zeros((b, z, 1))
        got = mdn.mdn_nll(lp, mu, ls, tg)
        sig = jnp.exp(ls[..., 0])
        manual = 0.5 * ((tg - mu[..., 0]) / sig) ** 2 + ls[..., 0] + 0.5 * jnp.log(
            2 * jnp.pi
        )
        np.testing.assert_allclose(got, jnp.mean(manual, axis=-1), rtol=1e-5, atol=1e-5)

    def test_nll_decreases_when_target_on_mean(self):
        """Target sitting on a component mean scores better than far away."""
        b, z, k = 2, 4, 3
        mu = rand(0, (b, z, k))
        ls = jnp.zeros((b, z, k))
        lp = jnp.zeros((b, z, k))
        on_mean = mdn.mdn_nll(lp, mu, ls, mu[..., 0])
        far = mdn.mdn_nll(lp, mu, ls, mu[..., 0] + 10.0)
        assert bool(jnp.all(on_mean < far))

    def test_extreme_logits_stable(self):
        """Max-subtraction log-sum-exp keeps huge logits finite."""
        b, z, k = 2, 4, 3
        lp = jnp.full((b, z, k), 80.0)
        got = mdn.mdn_nll(lp, rand(0, (b, z, k)), rand(1, (b, z, k), 0.1), rand(2, (b, z)))
        assert bool(jnp.all(jnp.isfinite(got)))

    def test_gradients_match_ref(self):
        b, z, k = 4, 8, 4
        lp, mu = rand(0, (b, z, k)), rand(1, (b, z, k))
        ls, tg = rand(2, (b, z, k), 0.3), rand(3, (b, z))
        gk = jax.grad(lambda m: jnp.sum(mdn.mdn_nll(lp, m, ls, tg)))(mu)
        gr = jax.grad(lambda m: jnp.sum(ref.mdn_nll_ref(lp, m, ls, tg)))(mu)
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)
