"""AOT contract tests: manifest consistency and HLO text validity."""

import json
import os

import pytest

from compile import aot, hp, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built — run `make artifacts`")
    with open(path) as f:
        return json.load(f)


class TestExportTable:
    def test_every_export_named_uniquely(self):
        names = [e[0] for e in aot._exports()]
        assert len(names) == len(set(names))

    def test_arg_names_match_specs(self):
        for name, fn, specs, arg_names, outs in aot._exports():
            assert len(specs) == len(arg_names), name
            assert len(outs) > 0, name

    def test_param_args_match_layout_sizes(self):
        sizes = {
            "gnn": model.GNN_LAYOUT.size,
            "wm": model.WM_LAYOUT.size,
            "ctrl": model.CTRL_LAYOUT.size,
        }
        for name, fn, specs, arg_names, outs in aot._exports():
            if name.endswith("_init"):
                continue
            fam = name.split("_")[0]
            theta_specs = [s for s, n in zip(specs, arg_names) if n == "theta"]
            assert theta_specs, name
            assert theta_specs[0].shape == (sizes[fam],), name


class TestManifest:
    def test_hp_round_trip(self):
        m = manifest()
        assert m["hp"]["MAX_NODES"] == hp.MAX_NODES
        assert m["hp"]["N_XFERS"] == hp.N_XFERS
        assert m["hp"]["MAX_LOCS"] == hp.MAX_LOCS
        assert m["hp"]["RNN_HIDDEN"] == hp.RNN_HIDDEN
        assert m["hp"]["MDN_K"] == hp.MDN_K

    def test_all_artifacts_exist_on_disk(self):
        m = manifest()
        for name, entry in m["artifacts"].items():
            path = os.path.join(ART_DIR, entry["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, name

    def test_param_sizes_consistent(self):
        m = manifest()
        assert m["param_sizes"]["gnn"] == model.GNN_LAYOUT.size
        assert m["param_sizes"]["wm"] == model.WM_LAYOUT.size
        assert m["param_sizes"]["ctrl"] == model.CTRL_LAYOUT.size

    def test_layout_descriptions_cover_size(self):
        m = manifest()
        for fam, size in m["param_sizes"].items():
            tot = 0
            for e in m["param_layouts"][fam]:
                n = 1
                for d in e["shape"]:
                    n *= d
                tot += n
            assert tot == size, fam

    def test_expected_artifact_set(self):
        m = manifest()
        expected = {
            "gnn_init", "gnn_ae_train", "gnn_encode_1", "gnn_encode_b",
            "wm_init", "wm_train", "wm_step_1", "wm_step_b",
            "ctrl_init", "ctrl_policy_1", "ctrl_policy_b", "ctrl_train",
        }
        assert expected == set(m["artifacts"].keys())
