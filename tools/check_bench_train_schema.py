#!/usr/bin/env python3
"""Schema check for BENCH_train.json: the V1/V2 reduction-order columns.

A placeholder file (written when the bench has not run yet) must document
every required column in its `schema` block; a measured file must carry
the columns in every row, the per-order parity verdicts, and an
end-to-end entry per configuration. Exits non-zero with a message on the
first violation.
"""

import json
import sys

REQUIRED_MS = [
    "seed_scalar_ms",
    "v1_t1_ms",
    "v1_t4_ms",
    "v1_t8_ms",
    "v2_t1_ms",
    "v2_t4_ms",
    "v2_t8_ms",
]
REQUIRED_SPEEDUPS = ["speedup_v1_t8", "speedup_v2_t8", "speedup_v2_over_v1_t8"]
REQUIRED_CONFIGS = ["seed_scalar", "v1_t1", "v1_t4", "v1_t8", "v2_t1", "v2_t4", "v2_t8"]
REQUIRED_PARITY = ["v1_bitwise", "v2_bitwise", "v1_v2_max_rel_err"]


def fail(msg: str) -> None:
    print(f"BENCH_train.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_train.json"
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)

    if data.get("bench") != "fig_train_throughput":
        fail(f"unexpected bench name {data.get('bench')!r}")

    if data.get("placeholder", False):
        # Placeholder mode: the schema block must describe every column so
        # the measured file cannot silently drop one.
        schema = data.get("schema", {})
        for col in REQUIRED_MS + REQUIRED_SPEEDUPS:
            if f"rows[].{col}" not in schema:
                fail(f"placeholder schema is missing rows[].{col}")
        for key in REQUIRED_PARITY:
            if not any(k.startswith(f"parity.{key}") for k in schema):
                fail(f"placeholder schema is missing parity.{key}")
        print(f"{path}: placeholder schema documents all V1/V2 columns")
        return

    rows = data.get("rows", [])
    if not rows:
        fail("measured file has no rows")
    for row in rows:
        for col in REQUIRED_MS + REQUIRED_SPEEDUPS:
            if col not in row:
                fail(f"row {row.get('program')!r} is missing {col}")

    parity = data.get("parity")
    if not isinstance(parity, dict):
        fail("measured file is missing the parity object")
    for key in REQUIRED_PARITY:
        if key not in parity:
            fail(f"parity object is missing {key}")
    if parity["v1_bitwise"] is not True:
        fail("V1 outputs diverged across thread counts")
    if parity["v2_bitwise"] is not True:
        fail("V2 outputs diverged across thread counts")

    steps = data.get("end_to_end_train_steps_per_s", {})
    for cfg in REQUIRED_CONFIGS:
        if cfg not in steps:
            fail(f"end_to_end_train_steps_per_s is missing {cfg}")

    print(f"{path}: measured rows carry all V1/V2 columns and parity verdicts")


if __name__ == "__main__":
    main()
