//! Vendored, dependency-free shim of the `anyhow` API surface rlflow uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The offline build cannot fetch crates.io, so this crate keeps the
//! ergonomic error idiom without the dependency. Errors are a rendered
//! message (no backtraces, no downcasting); any `std::error::Error` value
//! converts via `?` exactly as with real anyhow.

use std::fmt;

/// A rendered error message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow's blanket conversion: `?` on any std error produces an
// `Error`. Coherent because `Error` itself does not implement
// `std::error::Error` (same trick as the real crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    fn fails(flag: bool) -> super::Result<u32> {
        super::ensure!(flag, "flag was {}", flag);
        Ok(7)
    }

    fn bails() -> super::Result<()> {
        super::bail!("nope: {}", 3);
    }

    fn io_question_mark() -> super::Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(s)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
        assert!(bails().unwrap_err().to_string().contains("nope: 3"));
    }

    #[test]
    fn std_errors_convert() {
        let e = io_question_mark().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = super::anyhow!("plain");
        let b = super::anyhow!("fmt {}", 2);
        let c = super::anyhow!(String::from("owned"));
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "fmt 2");
        assert_eq!(c.to_string(), "owned");
    }
}
