//! Vendored offline shim of the `xla` crate surface rlflow uses.
//!
//! Host-side [`Literal`]s are fully functional (shape-carrying f32/i32
//! buffers — everything batch-building code and its tests need). The PJRT
//! device types compile but their entry points return [`Error`]: running
//! AOT artifacts requires the real `xla_extension` backend, and every
//! caller in rlflow already skips gracefully when the engine cannot load
//! (`Engine::load` fails fast on `PjRtClient::cpu()`).

use std::fmt;

/// Error type; callers format it with `{:?}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn offline(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable in the offline build (link a real xla_extension to execute artifacts)"
    ))
}

// ---------------------------------------------------------------------------
// Literals (functional)
// ---------------------------------------------------------------------------

#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host tensor: dims + typed storage. Mirrors the subset of the real
/// `xla::Literal` API that rlflow calls.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

/// Element types storable in a [`Literal`].
pub trait NativeType: Copy + fmt::Debug {
    fn wrap(v: Vec<Self>) -> Storage;
    fn unwrap(s: &Storage) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::F32(v)
    }
    fn unwrap(s: &Storage) -> Option<&[Self]> {
        match s {
            Storage::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::I32(v)
    }
    fn unwrap(s: &Storage) -> Option<&[Self]> {
        match s {
            Storage::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { storage: T::wrap(vec![v]), dims: vec![] }
    }

    /// Rank-1 literal.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { storage: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!("reshape: {have} elements into dims {dims:?}")));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(t) => t.iter().map(|l| l.element_count()).sum(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error(format!("to_vec: wrong element type for {:?}", self.dims)))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.storage)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("get_first_element: empty or wrong type".to_string()))
    }

    /// Build a tuple literal (what executions return in the real backend).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { storage: Storage::Tuple(parts), dims: vec![] }
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(t) => Ok(t),
            _ => Err(Error("to_tuple: literal is not a tuple".to_string())),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT device types (stubbed: compile, error at runtime)
// ---------------------------------------------------------------------------

/// Parsed HLO module. Construction requires the real backend.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(offline(&format!("parse HLO {path}")))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(offline("to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(offline("execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(offline("execute_b"))
    }
}

/// PJRT client. `cpu()` fails fast in the offline build, which is how
/// `Engine::load` reports that artifacts cannot run.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(offline("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(offline("compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(offline("buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(Literal::vec1(&[1i32]).reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_round_trip() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }

    #[test]
    fn pjrt_is_offline() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
