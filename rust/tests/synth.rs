//! End-to-end properties of the `xfer::synth` pipeline:
//!
//!  * determinism — same config ⇒ bit-identical rule list, tier
//!    assignment and serialised ruleset bytes (round-trip included);
//!  * composition — synthesised rules drop into the incremental matcher
//!    (maintained match lists == full refresh at every step) and the
//!    parallel search (bit-identical for any thread count);
//!  * usefulness — greedy/taso with handwritten + synthesised tiers never
//!    end worse than the handwritten library alone.

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig};
use rlflow::graph::{canonical_hash, Graph, GraphBuilder, OpKind};
use rlflow::search::{greedy_optimise_threads, taso_optimise, TasoConfig};
use rlflow::util::Rng;
use rlflow::xfer::library::standard_library;
use rlflow::xfer::synth::{
    library_with_rules, load_rules, save_rules, synthesise, SynthConfig, Tier,
};
use rlflow::xfer::Rule;

fn smoke_cfg() -> SynthConfig {
    SynthConfig {
        alphabet: "ewise,act,shape,scale".into(),
        tier: Tier::All,
        ..SynthConfig::default()
    }
}

fn ruleset_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("rlflow_synth_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.json"))
}

/// Small host graph with sites for both handwritten rules (matmul/relu
/// fusion, transpose pairs, relu idempotence) and synthesised ones
/// (relu∘relu, transpose∘transpose, scale(2)∘scale(0.5), ...).
fn host_graph() -> Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[8, 8]);
    let r = b.relu(x).unwrap();
    let r2 = b.relu(r).unwrap();
    let t = b.op(OpKind::Transpose { perm: vec![1, 0] }, &[r2]).unwrap();
    let t2 = b.op(OpKind::Transpose { perm: vec![1, 0] }, &[t]).unwrap();
    let s = b.op(OpKind::Scale { factor: 2.0 }, &[t2]).unwrap();
    let s2 = b.op(OpKind::Scale { factor: 0.5 }, &[s]).unwrap();
    let w = b.weight(&[8, 8]);
    let mm = b
        .op(
            OpKind::MatMul {
                trans_a: false,
                trans_b: false,
                act: rlflow::graph::Activation::None,
            },
            &[s2, w],
        )
        .unwrap();
    let _ = b.relu(mm).unwrap();
    b.finish()
}

#[test]
fn synthesis_is_deterministic_and_round_trips() {
    let cfg = smoke_cfg();
    let a = synthesise(&cfg).unwrap();
    let b = synthesise(&cfg).unwrap();
    assert!(!a.rules.is_empty());
    assert_eq!(a.stats, b.stats, "pipeline counters must be reproducible");
    let sig = |out: &rlflow::xfer::synth::SynthOutput| {
        out.rules
            .iter()
            .map(|r| (r.name(), r.tier(), r.shape_generic()))
            .collect::<Vec<_>>()
    };
    assert_eq!(sig(&a), sig(&b), "rule list / tier assignment must be reproducible");

    // Serialised bytes are bit-identical across runs, and a round trip
    // through disk preserves every rule.
    let (p1, p2) = (ruleset_path("det1"), ruleset_path("det2"));
    save_rules(&p1, &a.rules, &cfg).unwrap();
    save_rules(&p2, &b.rules, &cfg).unwrap();
    let (bytes1, bytes2) = (std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    assert_eq!(bytes1, bytes2, "serialised ruleset bytes must be bit-identical");
    let back = load_rules(&p1).unwrap();
    assert_eq!(
        back.iter().map(|r| r.name()).collect::<Vec<_>>(),
        a.rules.iter().map(|r| r.name()).collect::<Vec<_>>()
    );
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}

#[test]
fn combined_ruleset_incremental_matches_full_refresh() {
    let cfg = smoke_cfg();
    let out = synthesise(&cfg).unwrap();
    let path = ruleset_path("inc");
    save_rules(&path, &out.rules, &cfg).unwrap();
    let rules = library_with_rules(path.to_str()).unwrap();
    std::fs::remove_file(&path).ok();
    let g = host_graph();

    // The synthesised rules must actually participate on this graph.
    let synth_sites: usize = rules
        .rules
        .iter()
        .filter(|r| r.name().starts_with("synth_"))
        .map(|r| r.find(&g).len())
        .sum();
    assert!(synth_sites > 0, "no synthesised rule matches the host graph");

    let cost = CostModel::new(DeviceProfile::rtx2070());
    let mut inc = Env::new(g.clone(), &rules, &cost, EnvConfig::default());
    let mut oracle =
        Env::new(g, &rules, &cost, EnvConfig { full_refresh: true, ..Default::default() });
    let mut rng = Rng::new(0x5717);
    for step in 0..8 {
        let obs = oracle.observe();
        let inc_obs = inc.observe();
        assert_eq!(obs.xfer_mask, inc_obs.xfer_mask, "step {step}");
        assert_eq!(obs.location_counts, inc_obs.location_counts, "step {step}");
        assert_eq!(
            inc.match_lists(),
            &inc.match_lists_reference()[..],
            "step {step}: maintained lists diverged from full refresh"
        );
        let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
        if valid.is_empty() {
            break;
        }
        let x = valid[rng.below(valid.len())];
        let l = rng.below(obs.location_counts[x]);
        let r_ref = oracle.step((x, l));
        let r_inc = inc.step((x, l));
        assert!(r_ref.info.valid && r_inc.info.valid, "step {step}");
        assert_eq!(r_ref.done, r_inc.done, "step {step}");
    }
}

#[test]
fn combined_ruleset_search_is_thread_invariant() {
    let cfg = smoke_cfg();
    let out = synthesise(&cfg).unwrap();
    let path = ruleset_path("threads");
    save_rules(&path, &out.rules, &cfg).unwrap();
    let rules = library_with_rules(path.to_str()).unwrap();
    std::fs::remove_file(&path).ok();
    let g = host_graph();
    let cost = CostModel::new(DeviceProfile::rtx2070());

    let (sg, slog) =
        taso_optimise(&g, &rules, &cost, &TasoConfig { threads: 1, ..Default::default() });
    for threads in [2, 4] {
        let (pg, plog) =
            taso_optimise(&g, &rules, &cost, &TasoConfig { threads, ..Default::default() });
        assert_eq!(slog.final_ms.to_bits(), plog.final_ms.to_bits(), "{threads} threads");
        assert_eq!(canonical_hash(&sg), canonical_hash(&pg), "{threads} threads");
        assert_eq!(slog.graphs_explored, plog.graphs_explored, "{threads} threads");
    }
    let (gg, glog) = greedy_optimise_threads(&g, &rules, &cost, 50, 1);
    let (pg, plog) = greedy_optimise_threads(&g, &rules, &cost, 50, 4);
    assert_eq!(glog.final_ms.to_bits(), plog.final_ms.to_bits());
    assert_eq!(canonical_hash(&gg), canonical_hash(&pg));
}

#[test]
fn combined_ruleset_never_ends_worse_than_handwritten() {
    let cfg = SynthConfig { tier: Tier::AlwaysSafe, ..smoke_cfg() };
    let out = synthesise(&cfg).unwrap();
    assert!(!out.rules.is_empty(), "always-safe tier is empty at smoke scale");
    let path = ruleset_path("cost");
    save_rules(&path, &out.rules, &cfg).unwrap();
    let combined = library_with_rules(path.to_str()).unwrap();
    std::fs::remove_file(&path).ok();
    let plain = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());

    // The host graph (where synthesised rules fire) plus one real zoo
    // graph: a strictly larger vocabulary must never strand the search on
    // a worse final cost.
    let graphs = vec![host_graph(), rlflow::zoo::squeezenet1_1()];
    for (i, g) in graphs.iter().enumerate() {
        let (_, plain_log) = greedy_optimise_threads(g, &plain, &cost, 50, 0);
        let (_, comb_log) = greedy_optimise_threads(g, &combined, &cost, 50, 0);
        assert!(
            comb_log.final_ms <= plain_log.final_ms * (1.0 + 1e-9),
            "graph {i}: greedy with synth rules regressed ({} -> {})",
            plain_log.final_ms,
            comb_log.final_ms
        );
    }
    let g = host_graph();
    let (_, plain_log) = taso_optimise(&g, &plain, &cost, &TasoConfig::default());
    let (_, comb_log) = taso_optimise(&g, &combined, &cost, &TasoConfig::default());
    assert!(
        comb_log.final_ms <= plain_log.final_ms * (1.0 + 1e-9),
        "taso with synth rules regressed ({} -> {})",
        plain_log.final_ms,
        comb_log.final_ms
    );
}
