//! Properties of the incremental environment core and the vectorised
//! EnvPool, pinned against the full-refresh `_reference` oracle
//! (`EnvConfig { full_refresh: true }`) over seeded random walks:
//!
//!  * incremental match lists == a from-scratch `Rule::find` refresh at
//!    every step (bitwise, ordering included);
//!  * observations and histories bitwise identical to the oracle;
//!  * delta-driven rewards/runtimes equal to the full-recompute oracle to
//!    1e-9 (f64 summation order is the only permitted difference);
//!  * `EnvPool` results bit-identical for any thread count given fixed
//!    seeds.

use rlflow::agent::collect_random_pool;
use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig, EnvPool, EnvPoolConfig, StateEncoder};
use rlflow::util::Rng;
use rlflow::xfer::library::standard_library;
use rlflow::zoo;

/// One convolutional + one transformer zoo graph: enough structural
/// diversity for the maintenance properties while keeping debug-build
/// walltime sane (a full-refresh oracle step is O(rules x graph)).
fn zoo_subset() -> Vec<rlflow::graph::Graph> {
    vec![zoo::squeezenet1_1(), zoo::bert_base()]
}

#[test]
fn incremental_env_bit_identical_to_reference_on_zoo_walks() {
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    for (gi, g) in zoo_subset().into_iter().enumerate() {
        let mut inc = Env::new(g.clone(), &rules, &cost, EnvConfig::default());
        let mut oracle =
            Env::new(g, &rules, &cost, EnvConfig { full_refresh: true, ..Default::default() });
        let mut rng = Rng::new(0x11C0 ^ gi as u64);
        let mut checked = 0;
        for step in 0..10 {
            // Observations must agree bitwise before acting.
            let obs = oracle.observe();
            let inc_obs = inc.observe();
            assert_eq!(obs.xfer_mask, inc_obs.xfer_mask, "graph {gi} step {step}");
            assert_eq!(obs.location_counts, inc_obs.location_counts, "graph {gi} step {step}");
            // The maintained lists must equal a from-scratch refresh.
            assert_eq!(
                inc.match_lists(),
                inc.match_lists_reference(),
                "graph {gi} step {step}: maintained lists diverged from full refresh"
            );
            let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
            if valid.is_empty() {
                break;
            }
            let x = valid[rng.below(valid.len())];
            let l = rng.below(obs.location_counts[x]);
            let r_ref = oracle.step((x, l));
            let r_inc = inc.step((x, l));
            assert!(r_ref.info.valid && r_inc.info.valid);
            assert_eq!(r_ref.done, r_inc.done);
            // Delta-driven rewards == full-recompute rewards (1e-9 on the
            // underlying f64 runtimes; the f32 rewards inherit it).
            assert!(
                (r_ref.reward - r_inc.reward).abs() < 1e-6,
                "graph {gi} step {step}: reward {} vs {}",
                r_inc.reward,
                r_ref.reward
            );
            assert!(
                (oracle.runtime_ms() - inc.runtime_ms()).abs() < 1e-9,
                "graph {gi} step {step}: runtime {} vs {}",
                inc.runtime_ms(),
                oracle.runtime_ms()
            );
            assert_eq!(r_ref.info.launches, r_inc.info.launches);
            checked += 1;
            if r_ref.done {
                break;
            }
        }
        assert_eq!(oracle.history(), inc.history());
        assert!(checked >= 5, "graph {gi}: walk too short ({checked} steps)");
        // The incremental env must actually have skipped re-finds (how
        // many depends on which op families the walk touches).
        let stats = inc.state().match_stats();
        assert!(stats.keeps > 0, "graph {gi}: no rule ever skipped, got {stats:?}");
        assert!(stats.refinds > 0, "graph {gi}: no rule ever re-found, got {stats:?}");
    }
}

#[test]
fn incremental_env_matches_reference_under_noise() {
    // The §3.1.4 noise model is a stateless per-kernel field, so the
    // incremental path resamples only the nodes a rewrite touched —
    // `delta_cost_fast` never falls back to a full recompute — and still
    // tracks the full-recompute oracle to f64 summation order (1e-9 on
    // runtimes; the f32 rewards inherit it at 1e-6).
    let rules = standard_library();
    let g = zoo::squeezenet1_1();
    let mk_cost = || CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 77);
    let (inc_cost, ref_cost) = (mk_cost(), mk_cost());
    // Noise must actually engage: the noisy initial runtime differs from
    // the clean one.
    let clean = CostModel::new(DeviceProfile::rtx2070());
    let mut inc = Env::new(g.clone(), &rules, &inc_cost, EnvConfig::default());
    let mut oracle = Env::new(
        g.clone(),
        &rules,
        &ref_cost,
        EnvConfig { full_refresh: true, ..Default::default() },
    );
    assert_ne!(
        inc.initial_runtime_ms().to_bits(),
        clean.graph_runtime_ms(&g).to_bits(),
        "noise field did not perturb the initial runtime"
    );
    let mut rng = Rng::new(0x5EED);
    let mut applied = 0;
    for _ in 0..6 {
        let obs = oracle.observe();
        assert_eq!(obs.xfer_mask, inc.observe().xfer_mask);
        let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
        if valid.is_empty() {
            break;
        }
        let x = valid[rng.below(valid.len())];
        let l = rng.below(obs.location_counts[x]);
        let r_ref = oracle.step((x, l));
        let r_inc = inc.step((x, l));
        assert!((r_ref.reward - r_inc.reward).abs() < 1e-6);
        assert!(
            (oracle.runtime_ms() - inc.runtime_ms()).abs() < 1e-9,
            "noisy runtime {} vs {}",
            inc.runtime_ms(),
            oracle.runtime_ms()
        );
        assert_eq!(r_ref.info.launches, r_inc.info.launches);
        applied += 1;
        if r_ref.done {
            break;
        }
    }
    assert!(applied >= 3, "noisy walk too short ({applied} steps)");
    assert_eq!(oracle.history(), inc.history());
}

#[test]
fn env_pool_episodes_bit_identical_for_any_thread_count() {
    let g = zoo::squeezenet1_1();
    let encoder = StateEncoder::new(320, 32);
    let collect = |threads: usize| {
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mut pool = EnvPool::new(
            &g,
            standard_library(),
            &cost,
            &EnvPoolConfig {
                n_envs: 4,
                threads,
                seed: 99,
                env: EnvConfig { max_steps: 6, ..Default::default() },
                ..Default::default()
            },
        );
        collect_random_pool(&mut pool, &encoder, 49, 8, 0.1)
    };
    let a = collect(1);
    for threads in [2, 4, 0] {
        let b = collect(threads);
        assert_eq!(a.len(), b.len(), "threads={threads}");
        for (ea, eb) in a.iter().zip(&b) {
            assert_eq!(ea.actions, eb.actions, "threads={threads}");
            assert_eq!(ea.dones, eb.dones, "threads={threads}");
            assert_eq!(
                ea.rewards.iter().map(|r| r.to_bits()).collect::<Vec<_>>(),
                eb.rewards.iter().map(|r| r.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
            assert_eq!(ea.xmasks, eb.xmasks, "threads={threads}");
        }
    }
}

#[test]
fn env_pool_batched_walks_match_lone_envs() {
    // Pool row i stepped through step_batch must equal a lone Env driven
    // by the same per-env seeded policy on its own cost model.
    let g = zoo::squeezenet1_1();
    let rules = standard_library();
    let base = CostModel::new(DeviceProfile::rtx2070());
    let mut pool = EnvPool::new(
        &g,
        standard_library(),
        &base,
        &EnvPoolConfig { n_envs: 3, threads: 2, seed: 5, ..Default::default() },
    );
    let b = pool.n_envs();
    for _ in 0..4 {
        let obs = pool.observe_batch();
        let actions: Vec<(usize, usize)> = obs
            .iter()
            .map(|o| {
                (0..rules.len())
                    .find(|&x| o.xfer_mask[x])
                    .map(|x| (x, 0))
                    .unwrap_or((rules.len(), 0))
            })
            .collect();
        let _ = pool.step_batch(&actions);
    }
    for i in 0..b {
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mut lone = Env::new(g.clone(), &rules, &cost, EnvConfig::default());
        for _ in 0..4 {
            let o = lone.observe();
            let a = (0..rules.len())
                .find(|&x| o.xfer_mask[x])
                .map(|x| (x, 0))
                .unwrap_or((lone.noop_action(), 0));
            let _ = lone.step(a);
        }
        assert_eq!(pool.state(i).history(), lone.history(), "env {i}");
        assert_eq!(
            pool.state(i).runtime_ms().to_bits(),
            lone.runtime_ms().to_bits(),
            "env {i}"
        );
    }
}
