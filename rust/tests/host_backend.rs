//! Offline tier-1 coverage of the backend seam: a miniature
//! collect -> GNN-AE -> encode -> WM -> dream-PPO -> eval cycle on the
//! pure-Rust [`HostBackend`] (no `manifest.json`, no `xla_extension`),
//! seeded-determinism pins for MDN sampling / dream rollouts / the full
//! training loop, and the manifest-contract test keeping the host
//! programs interchangeable with the PJRT artifacts.

use rlflow::agent::{Action, PpoCfg};
use rlflow::config::RunConfig;
use rlflow::coordinator::{collect_random_parallel, Pipeline};
use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig};
use rlflow::graph::{GraphBuilder, PadMode};
use rlflow::runtime::{Backend, Dt, HostBackend, HostConfig, ParamStore, TensorView};
use rlflow::util::Rng;
use rlflow::wm::{sample_mdn, DreamEnv};
use rlflow::xfer::library::standard_library;

/// Small host dimensions sized for the tiny test graph; the xfer slot
/// space still matches the real rule library so the env mapping is exact.
fn tiny_config() -> HostConfig {
    HostConfig {
        max_nodes: 48,
        node_feats: 32,
        gnn_hidden: 12,
        latent: 8,
        rnn_hidden: 12,
        mdn_k: 2,
        act_emb: 4,
        ctrl_hidden: 16,
        n_xfers1: standard_library().len() + 1,
        max_locs: 200,
        b_dream: 4,
        b_wm: 4,
        seq_len: 4,
        b_ppo: 16,
        b_enc: 4,
        kernels: rlflow::runtime::KernelCfg::default(),
    }
}

fn tiny_run_config() -> RunConfig {
    let mut cfg = RunConfig::smoke();
    cfg.backend = "host".into();
    cfg.collect_episodes = 4;
    cfg.ae_steps = 3;
    cfg.wm.total_steps = 4;
    cfg.dream_epochs = 2;
    cfg.dream_horizon = 4;
    cfg.ppo.epochs = 2;
    cfg.env.max_steps = 6;
    cfg
}

fn small_graph() -> rlflow::graph::Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 16, 16]);
    let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
    let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
    let r = b.relu(c2).unwrap();
    let _ = b.maxpool(r, 2, 2).unwrap();
    b.finish()
}

/// The acceptance-criterion test: the complete model-based loop runs
/// offline on the host backend, end to end.
#[test]
fn full_cycle_runs_offline_on_host_backend() {
    let backend = HostBackend::with_config(tiny_config());
    let cfg = tiny_run_config();
    let pipe = Pipeline::new(&backend).unwrap();
    let mut rng = Rng::new(cfg.seed);

    // 1. Random collection (backend-free).
    let mut episodes = collect_random_parallel(
        &small_graph(),
        &cfg.env,
        cfg.device,
        (pipe.encoder.max_nodes, pipe.encoder.n_feats),
        pipe.dims.x1,
        cfg.collect_episodes,
        cfg.collect_noop_prob,
        cfg.envs,
        cfg.collect_workers,
        cfg.seed,
    );
    assert_eq!(episodes.len(), cfg.collect_episodes);

    // 2. GNN auto-encoder.
    let mut gnn = ParamStore::init(&backend, "gnn", 0).unwrap();
    let ae_losses =
        pipe.train_gnn_ae(&mut gnn, &episodes, cfg.ae_steps, cfg.ae_lr, &mut rng).unwrap();
    assert_eq!(ae_losses.len(), cfg.ae_steps);
    assert!(ae_losses.iter().all(|l| l.is_finite()));

    // 3. Encode.
    pipe.encode_episodes(&gnn, &mut episodes).unwrap();
    assert!(episodes.iter().all(|e| e.z.len() == e.states.len()));
    assert!(episodes[0].z[0].iter().any(|v| v.abs() > 0.0));

    // 4. World model.
    let mut wm = ParamStore::init(&backend, "wm", 1).unwrap();
    let wm_curve = pipe.train_wm(&mut wm, &episodes, &cfg.wm, &mut rng).unwrap();
    assert_eq!(wm_curve.len(), cfg.wm.total_steps);
    assert!(wm_curve.iter().all(|l| l.total.is_finite()));

    // 5. Controller in the dream.
    let mut ctrl = ParamStore::init(&backend, "ctrl", 2).unwrap();
    let before = ctrl.theta.clone();
    let dream_curve = pipe
        .train_controller_dream(
            &mut ctrl,
            &wm,
            &episodes,
            cfg.dream_epochs,
            cfg.dream_horizon,
            cfg.temperature,
            cfg.wm.reward_scale,
            &cfg.ppo,
            &mut rng,
        )
        .unwrap();
    assert_eq!(dream_curve.len(), cfg.dream_epochs);
    assert_ne!(before, ctrl.theta, "dream PPO must move the controller");

    // 6. Real-environment evaluation.
    let rules = standard_library();
    let cost = CostModel::new(cfg.device);
    let mut env = Env::new(small_graph(), &rules, &cost, cfg.env.clone());
    let result = pipe.eval_real(&gnn, &ctrl, Some(&wm), &mut env, false, &mut rng).unwrap();
    assert!(result.steps > 0);
    assert!(result.mean_step_s > 0.0);
    assert!(result.best_improvement_pct >= 0.0);
}

#[test]
fn full_cycle_is_bit_deterministic_under_a_fixed_seed() {
    let run = || {
        let backend = HostBackend::with_config(tiny_config());
        let cfg = tiny_run_config();
        let pipe = Pipeline::new(&backend).unwrap();
        let agent =
            rlflow::experiments::train_model_based(&pipe, &cfg, &small_graph(), cfg.seed).unwrap();
        let mut rng = Rng::new(cfg.seed + 7);
        let rules = standard_library();
        let cost = CostModel::new(cfg.device);
        let mut env = Env::new(small_graph(), &rules, &cost, cfg.env.clone());
        let eval =
            pipe.eval_real(&agent.gnn, &agent.ctrl, Some(&agent.wm), &mut env, false, &mut rng)
                .unwrap();
        (agent.gnn.theta, agent.wm.theta, agent.ctrl.theta, eval.history, eval.steps)
    };
    let a = run();
    let b = run();
    assert_eq!(a.0, b.0, "gnn params must be bit-identical across runs");
    assert_eq!(a.1, b.1, "wm params must be bit-identical across runs");
    assert_eq!(a.2, b.2, "ctrl params must be bit-identical across runs");
    assert_eq!(a.3, b.3, "eval action history must replay identically");
    assert_eq!(a.4, b.4);
}

#[test]
fn sample_mdn_is_bit_deterministic_per_seed() {
    let (z, k) = (6, 3);
    let mut rng_p = Rng::new(11);
    let log_pi: Vec<f32> = (0..z * k).map(|_| rng_p.normal()).collect();
    let mu: Vec<f32> = (0..z * k).map(|_| rng_p.normal()).collect();
    let log_sig: Vec<f32> = (0..z * k).map(|_| rng_p.normal() * 0.3 - 1.0).collect();
    let draw = |seed: u64| {
        let mut rng = Rng::new(seed);
        (0..10)
            .flat_map(|_| sample_mdn(&log_pi, &mu, &log_sig, z, k, 1.3, &mut rng))
            .collect::<Vec<f32>>()
    };
    let a = draw(42);
    let b = draw(42);
    assert_eq!(a, b, "same seed must give bit-identical MDN samples");
    assert_ne!(a, draw(43), "different seeds must diverge");
}

#[test]
fn dream_rollout_is_bit_deterministic_per_seed() {
    let backend = HostBackend::with_config(tiny_config());
    let x1 = backend.hp("N_XFERS1").unwrap();
    let zdim = backend.hp("LATENT").unwrap();
    let wm = ParamStore::init(&backend, "wm", 4).unwrap();
    let z0 = vec![vec![0.2f32; zdim], vec![-0.1f32; zdim]];
    let xm0 = vec![vec![1.0f32; x1]; 2];

    let rollout = |seed: u64| {
        let mut dream = DreamEnv::new(&backend, 1.0, 10.0).unwrap();
        dream.reset(&z0, &xm0).unwrap();
        let mut rng = Rng::new(seed);
        let mut rewards = Vec::new();
        for step in 0..5 {
            let actions: Vec<Action> =
                (0..dream.b).map(|row| Action::new((row + step) % (x1 - 1), 0)).collect();
            let (r, _) = dream.step(&wm, &actions, &mut rng).unwrap();
            rewards.extend(r);
        }
        (rewards, dream.z.clone(), dream.xmask.clone())
    };
    let a = rollout(1);
    let b = rollout(1);
    assert_eq!(a.0, b.0, "dream rewards must be bit-identical");
    assert_eq!(a.1, b.1, "dream latents must be bit-identical");
    assert_eq!(a.2, b.2, "dream masks must be bit-identical");
    let c = rollout(2);
    assert_ne!(a.1, c.1, "different rollout seeds must diverge");
}

/// Manifest-contract test: every host program executes with inputs built
/// purely from its published [`rlflow::runtime::ArtifactSpec`] and returns
/// exactly the declared number of outputs — the property that keeps
/// `HostBackend` and `PjrtBackend` interchangeable behind the trait.
#[test]
fn host_programs_match_their_artifact_specs() {
    let backend = HostBackend::with_config(tiny_config());
    let manifest = backend.manifest();
    let mut names: Vec<&String> = manifest.artifacts.keys().collect();
    names.sort();
    assert_eq!(names.len(), 12, "expected the 12 host programs, got {names:?}");

    for name in names {
        let spec = manifest.artifact(name).unwrap();
        // Build arguments purely from the spec.
        let mut f32_bufs: Vec<Vec<f32>> = Vec::new();
        let mut i32_bufs: Vec<Vec<i32>> = Vec::new();
        for arg in &spec.inputs {
            match arg.dtype {
                Dt::F32 => f32_bufs.push(vec![0.0; arg.n_elems()]),
                Dt::I32 => i32_bufs.push(vec![1; arg.n_elems()]),
            }
        }
        let (mut fi, mut ii) = (0, 0);
        let mut args: Vec<TensorView> = Vec::new();
        for arg in &spec.inputs {
            match arg.dtype {
                Dt::F32 => {
                    args.push(TensorView::f32(&f32_bufs[fi], &arg.shape));
                    fi += 1;
                }
                Dt::I32 => {
                    args.push(TensorView::i32(&i32_bufs[ii], &arg.shape));
                    ii += 1;
                }
            }
        }
        let out = backend
            .exec(name, &args)
            .unwrap_or_else(|e| panic!("{name} rejected its own spec: {e}"));
        assert_eq!(
            out.len(),
            spec.outputs.len(),
            "{name}: output arity drifted from the spec"
        );
        for (t, oname) in out.iter().zip(&spec.outputs) {
            assert!(
                t.data.iter().all(|v| v.is_finite()),
                "{name}.{oname} produced non-finite values on spec-shaped zeros"
            );
        }
        // Dropping one argument must be rejected.
        if !args.is_empty() {
            let short = &args[..args.len() - 1];
            assert!(backend.exec(name, short).is_err(), "{name} accepted too few args");
        }
    }
}

#[test]
fn host_output_widths_follow_hyperparameters() {
    let backend = HostBackend::with_config(tiny_config());
    let (z, r) = (backend.hp("LATENT").unwrap(), backend.hp("RNN_HIDDEN").unwrap());
    let (x1, locs) = (backend.hp("N_XFERS1").unwrap(), backend.hp("MAX_LOCS").unwrap());
    let k = backend.hp("MDN_K").unwrap();
    let b = backend.hp("B_DREAM").unwrap();

    let ctrl = ParamStore::init(&backend, "ctrl", 1).unwrap();
    let zb = vec![0.1f32; b * z];
    let hb = vec![0.0f32; b * r];
    let out = backend
        .exec_with_params(
            "ctrl_policy_b",
            &ctrl,
            &[TensorView::f32(&zb, &[b, z]), TensorView::f32(&hb, &[b, r])],
        )
        .unwrap();
    assert_eq!(out[0].data.len(), b * x1);
    assert_eq!(out[0].shape, vec![b, x1]);
    assert_eq!(out[1].data.len(), b * x1 * locs);
    assert_eq!(out[2].data.len(), b);

    let wm = ParamStore::init(&backend, "wm", 2).unwrap();
    let ab = vec![0i32; b * 2];
    let cb = vec![0.0f32; b * r];
    let out = backend
        .exec_with_params(
            "wm_step_b",
            &wm,
            &[
                TensorView::f32(&zb, &[b, z]),
                TensorView::i32(&ab, &[b, 2]),
                TensorView::f32(&hb, &[b, r]),
                TensorView::f32(&cb, &[b, r]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 8);
    assert_eq!(out[0].data.len(), b * z * k);
    assert_eq!(out[4].data.len(), b * x1);
    assert_eq!(out[6].data.len(), b * r);
    assert!(out[6].data.iter().any(|v| v.abs() > 0.0), "hidden state did not evolve");
}

#[test]
fn exec_with_params_equals_explicit_theta() {
    let backend = HostBackend::with_config(tiny_config());
    let (z, r) = (backend.hp("LATENT").unwrap(), backend.hp("RNN_HIDDEN").unwrap());
    let ctrl = ParamStore::init(&backend, "ctrl", 3).unwrap();
    let z1 = vec![0.3f32; z];
    let h1 = vec![0.1f32; r];
    let rest = [TensorView::f32(&z1, &[1, z]), TensorView::f32(&h1, &[1, r])];
    let a = backend.exec_with_params("ctrl_policy_1", &ctrl, &rest).unwrap();
    let n = ctrl.theta.len();
    let mut args = vec![TensorView::f32(&ctrl.theta, &[n])];
    args.extend(rest.iter().cloned());
    let b = backend.exec("ctrl_policy_1", &args).unwrap();
    assert_eq!(a[0].data, b[0].data);
    assert_eq!(a[2].data, b[2].data);
}

#[test]
fn init_deterministic_and_distinct_per_family() {
    let backend = HostBackend::with_config(tiny_config());
    let a = ParamStore::init(&backend, "ctrl", 42).unwrap();
    let b = ParamStore::init(&backend, "ctrl", 42).unwrap();
    let c = ParamStore::init(&backend, "ctrl", 43).unwrap();
    assert_eq!(a.theta, b.theta);
    assert_ne!(a.theta, c.theta);
    // Families draw from distinct streams even at equal seeds.
    let g = ParamStore::init(&backend, "gnn", 42).unwrap();
    assert_ne!(a.theta.len(), 0);
    assert_ne!(g.theta.get(..4), a.theta.get(..4));
}

/// Backend-level reduction-order contract: a `HostConfig`-pinned order is
/// bit-deterministic within itself at any thread count, and the V1↔V2
/// pair agrees within a relative-error bound on the same encode inputs.
#[test]
fn reduction_orders_are_deterministic_and_parity_bounded() {
    use rlflow::runtime::KernelCfg;
    let encode = |kernels: KernelCfg| -> Vec<f32> {
        let backend = HostBackend::with_config(HostConfig { kernels, ..tiny_config() });
        let (n, f) = (backend.hp("MAX_NODES").unwrap(), backend.hp("NODE_FEATS").unwrap());
        let b = backend.hp("B_ENC").unwrap();
        let gnn = ParamStore::init(&backend, "gnn", 5).unwrap();
        let mut rng = Rng::new(23);
        let feats: Vec<f32> = (0..b * n * f).map(|_| rng.normal() * 0.5).collect();
        let adj: Vec<f32> =
            (0..b * n * n).map(|i| if i % 11 == 0 { 1.0 } else { 0.0 }).collect();
        let mask: Vec<f32> = (0..b * n).map(|i| if i % n < 5 { 1.0 } else { 0.0 }).collect();
        let out = backend
            .exec_with_params(
                "gnn_encode_b",
                &gnn,
                &[
                    TensorView::f32(&feats, &[b, n, f]),
                    TensorView::f32(&adj, &[b, n, n]),
                    TensorView::f32(&mask, &[b, n]),
                ],
            )
            .unwrap();
        out[0].data.clone()
    };
    let v1 = encode(KernelCfg::blocked(2));
    assert_eq!(v1, encode(KernelCfg::blocked(8)), "V1 must be thread-count invariant");
    let v2 = encode(KernelCfg::v2(2));
    assert_eq!(v2, encode(KernelCfg::v2(8)), "V2 must be thread-count invariant");
    assert_eq!(
        v2,
        encode(KernelCfg::v2(3).with_lane_groups(8)),
        "V2 must be lane-width invariant"
    );
    for (i, (&x, &y)) in v1.iter().zip(&v2).enumerate() {
        let tol = 1e-5 + 1e-4 * x.abs().max(y.abs());
        assert!((x - y).abs() <= tol, "z[{i}]: V1 {x} vs V2 {y} exceeds tol {tol}");
    }
}

#[test]
fn model_free_ppo_iteration_runs_on_host() {
    let backend = HostBackend::with_config(tiny_config());
    let pipe = Pipeline::new(&backend).unwrap();
    let mut rng = Rng::new(7);
    let gnn = ParamStore::init(&backend, "gnn", 0).unwrap();
    let mut ctrl = ParamStore::init(&backend, "ctrl", 3).unwrap();
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let mut env = Env::new(
        small_graph(),
        &rules,
        &cost,
        EnvConfig { max_steps: 5, ..Default::default() },
    );
    let before = ctrl.theta.clone();
    let (mean_reward, stats) = pipe
        .model_free_iteration(&gnn, &mut ctrl, &mut env, 2, &PpoCfg::default(), &mut rng)
        .unwrap();
    assert!(mean_reward.is_finite());
    assert!(stats.entropy.is_finite());
    assert_ne!(before, ctrl.theta, "PPO update should move parameters");
}
