//! Chaos battery: seeded fault schedules driven through the
//! `rlflow::util::failpoint` registry, asserting the crash-safety
//! contracts end to end — no hang, no torn state, no lost committed
//! result, bit-deterministic recovery.
//!
//! Every test here arms real (non-`test.*`) failpoint sites, so every
//! test takes a [`failpoint::scoped`] guard for its whole body: scopes
//! serialise against each other process-wide, keeping one test's faults
//! out of another's IO. `RLFLOW_CHAOS_SEED` (default 1) varies the
//! seeded schedules; CI runs the battery under more than one seed.

use std::path::PathBuf;

use rlflow::config::RunConfig;
use rlflow::coordinator::{
    train_async, train_reference, train_reference_ckpt, AsyncTrainCfg, Checkpoint,
    CheckpointCfg,
};
use rlflow::graph::{GraphBuilder, PadMode};
use rlflow::runtime::{Backend, HostBackend, HostConfig};
use rlflow::search::SearchLog;
use rlflow::serve::persist::{CacheEntry, Persister};
use rlflow::util::failpoint;
use rlflow::xfer::library::standard_library;

fn chaos_seed() -> u64 {
    std::env::var("RLFLOW_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rlflow-chaos-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn small_graph() -> rlflow::graph::Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 16, 16]);
    let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
    let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
    let r = b.relu(c2).unwrap();
    let _ = b.maxpool(r, 2, 2).unwrap();
    b.finish()
}

fn tiny_config() -> HostConfig {
    HostConfig {
        max_nodes: 48,
        node_feats: 32,
        gnn_hidden: 12,
        latent: 8,
        rnn_hidden: 12,
        mdn_k: 2,
        act_emb: 4,
        ctrl_hidden: 16,
        n_xfers1: standard_library().len() + 1,
        max_locs: 200,
        b_dream: 4,
        b_wm: 4,
        seq_len: 4,
        b_ppo: 16,
        b_enc: 4,
        kernels: rlflow::runtime::KernelCfg::default(),
    }
}

fn factory() -> anyhow::Result<Box<dyn Backend>> {
    Ok(Box::new(HostBackend::with_config(tiny_config())))
}

fn tiny_run_config() -> RunConfig {
    let mut cfg = RunConfig::smoke();
    cfg.backend = "host".into();
    cfg.envs = 4;
    cfg.collect_episodes = 8;
    cfg.ae_steps = 2;
    cfg.wm.total_steps = 2;
    cfg.dream_epochs = 1;
    cfg.dream_horizon = 3;
    cfg.ppo.epochs = 1;
    cfg.eval_episodes = 1;
    cfg.env.max_steps = 4;
    cfg
}

fn acfg(stage_threads: usize) -> AsyncTrainCfg {
    AsyncTrainCfg { rounds: 2, stage_threads, staging_cap: 2, jitter: None }
}

fn entry(fp: u64) -> CacheEntry {
    let g = small_graph();
    let root = rlflow::graph::canonical_hash(&g);
    CacheEntry {
        fp,
        root,
        graph: g,
        log: SearchLog {
            steps: vec![("fuse".into(), 1.25)],
            initial_ms: 2.0,
            final_ms: 1.25,
            elapsed_s: 0.0,
            graphs_explored: 7,
            table_size: 9,
            memo_hits: 3,
            threads: 4,
            from_cache: false,
        },
    }
}

fn fps(replay: &rlflow::serve::persist::Replay) -> Vec<u64> {
    replay.entries.iter().map(|e| e.fp).collect()
}

/// A torn (short) append loses only the torn entry: committed entries
/// before it survive, a committed entry after it gets its own clean
/// line (the daemon keeps running past persist failures), and a restart
/// replays exactly the committed set.
#[test]
fn torn_append_loses_only_the_torn_entry() {
    let _fp = failpoint::scoped("serve.log.append=short(9)@2");
    let dir = tmpdir("torn-append");
    {
        let (mut p, _) = Persister::open(&dir, 1000).unwrap();
        p.append(&entry(1)).unwrap();
        let err = p.append(&entry(2)).unwrap_err();
        assert!(err.to_string().contains("short write"), "got: {err}");
        // The daemon carries on: the next committed entry must not merge
        // into the torn tail.
        p.append(&entry(3)).unwrap();
    }
    let (_p, replay) = Persister::open(&dir, 1000).unwrap();
    assert_eq!(fps(&replay), vec![1, 3], "committed entries survive, the torn one is skipped");
    assert_eq!(replay.skipped_lines, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A failed compaction is atomic: whether the snapshot dies writing the
/// temp file or renaming it into place, the old snapshot and the
/// untruncated log still reconstruct the full committed state.
#[test]
fn failed_compaction_keeps_old_snapshot_and_log() {
    for site in ["serve.snapshot.write", "serve.snapshot.rename"] {
        let _fp = failpoint::scoped(&format!("{site}=err@1"));
        let dir = tmpdir(&format!("snap-fail-{site}"));
        {
            let (mut p, _) = Persister::open(&dir, 1000).unwrap();
            p.append(&entry(1)).unwrap();
            p.snapshot(&[entry(1)], &Default::default()).unwrap_err();
            // First snapshot failed (injected); the log still holds 1.
            p.append(&entry(2)).unwrap();
        }
        let (_p, replay) = Persister::open(&dir, 1000).unwrap();
        assert_eq!(fps(&replay), vec![1, 2], "{site}: committed entries lost");

        // The snapshot succeeds once the fault passes, and the next
        // generation replays the compacted image.
        {
            let (mut p, replay) = Persister::open(&dir, 1000).unwrap();
            p.snapshot(&replay.entries, &Default::default()).unwrap();
        }
        let (_p, replay) = Persister::open(&dir, 1000).unwrap();
        assert_eq!(fps(&replay), vec![1, 2], "{site}: compacted image diverged");
        assert!(!replay.recovered_from_bak);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Random seeded append faults: whatever the schedule tears, a restart
/// replays exactly the appends that reported success, in order — and the
/// same seed reproduces the identical surviving set.
#[test]
fn seeded_append_faults_never_lose_committed_entries() {
    let seed = chaos_seed();
    let run = |tag: &str| -> (Vec<u64>, Vec<u64>) {
        let _fp = failpoint::scoped(&format!("serve.log.append=short(11)%0.4~{seed}"));
        let dir = tmpdir(tag);
        let mut committed = Vec::new();
        {
            let (mut p, _) = Persister::open(&dir, 1000).unwrap();
            for fp in 1..=20u64 {
                if p.append(&entry(fp)).is_ok() {
                    committed.push(fp);
                }
            }
        }
        let (_p, replay) = Persister::open(&dir, 1000).unwrap();
        let survived = fps(&replay);
        let _ = std::fs::remove_dir_all(&dir);
        (committed, survived)
    };
    let (committed, survived) = run("seeded-a");
    assert!(!committed.is_empty(), "p=0.4 over 20 appends must commit some");
    assert_eq!(survived, committed, "a committed append must survive restart");
    let (committed2, survived2) = run("seeded-b");
    assert_eq!((committed2, survived2), (committed, survived), "seed {seed} must replay");
}

/// Checkpoint writes are atomic: a fault at the write or the
/// rename aborts the run with a typed error and leaves no loadable
/// half-checkpoint behind; a fault at a *later* boundary leaves the
/// earlier checkpoint as the newest valid resume point.
#[test]
fn checkpoint_faults_never_leave_torn_state() {
    let graph = small_graph();
    let cfg = tiny_run_config();

    // Fault at the first boundary: no checkpoint may exist at all.
    {
        let _fp = failpoint::scoped("ckpt.rename=err@1");
        let dir = tmpdir("ckpt-rename");
        let ck = CheckpointCfg { dir: dir.clone(), every: 1 };
        let err = train_reference_ckpt(&factory, &cfg, &acfg(1), &graph, Some(&ck), None)
            .unwrap_err();
        assert!(err.to_string().contains("ckpt.rename"), "got: {err}");
        assert!(Checkpoint::load_latest(&dir).unwrap().is_none(), "half-checkpoint loadable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Fault at the second boundary: round 1's checkpoint stays the
    // newest valid resume point, and resuming from it reproduces the
    // uninterrupted run bit-for-bit.
    {
        let dir = tmpdir("ckpt-write2");
        {
            let _fp = failpoint::scoped("ckpt.write=err@2");
            let ck = CheckpointCfg { dir: dir.clone(), every: 1 };
            let err = train_reference_ckpt(&factory, &cfg, &acfg(1), &graph, Some(&ck), None)
                .unwrap_err();
            assert!(err.to_string().contains("ckpt.write"), "got: {err}");
        }
        let cp = Checkpoint::load_latest(&dir).unwrap().expect("round-1 checkpoint survives");
        assert_eq!(cp.next_round, 1);
        let resumed =
            train_reference_ckpt(&factory, &cfg, &acfg(1), &graph, None, Some(cp)).unwrap();
        let reference = train_reference(&factory, &cfg, &acfg(1), &graph).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&resumed.gnn.theta), bits(&reference.gnn.theta), "gnn diverged");
        assert_eq!(bits(&resumed.wm.theta), bits(&reference.wm.theta), "wm diverged");
        assert_eq!(bits(&resumed.ctrl.theta), bits(&reference.ctrl.theta), "ctrl diverged");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A panicking pipeline stage is a typed `stage '...' panicked` error,
/// never a hang: the dying stage's close guards release every peer and
/// the join layer converts the panic payload.
#[test]
fn stage_panic_is_a_typed_error_never_a_hang() {
    let graph = small_graph();
    let cfg = tiny_run_config();
    for spec in ["stage.send=panic@3", "stage.recv=panic@5"] {
        let _fp = failpoint::scoped(spec);
        let err = train_async(&factory, &cfg, &acfg(4), &graph).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{spec}: got: {err}");
        assert!(err.to_string().contains("injected panic"), "{spec}: got: {err}");
    }
}

/// A worker that panics with a claimed job in hand is respawned: the
/// victim request gets a typed `timeout` (its reply channel died, not
/// the daemon), the retry client turns that into a second attempt that
/// succeeds, and the pool never shrinks to zero.
#[test]
fn worker_panic_respawns_and_daemon_keeps_serving() {
    use rlflow::serve::{
        client, encode_control, encode_optimize, Method, OptimizeRequest, Provenance, Response,
        RetryCfg, ServerConfig,
    };
    let _fp = failpoint::scoped("serve.worker=panic@1");

    let mut cfg = ServerConfig::new("127.0.0.1:0");
    cfg.workers = 1; // a panic without respawn would kill the whole pool
    cfg.core.threads = 1;
    let handle = rlflow::serve::spawn(cfg).unwrap();
    let addr = handle.addr.to_string();
    let timeout = std::time::Duration::from_secs(60);

    let req = OptimizeRequest {
        graph: small_graph(),
        graph_name: "small".into(),
        method: Method::Greedy { max_steps: 8 },
        cost_noise: 0.0,
        noise_seed: 0,
        timeout_ms: None,
    };
    let line = encode_optimize(&req).unwrap();
    // Attempt 1 hits the panicking worker and comes back as a typed,
    // retryable failure; attempt 2 lands on the respawned worker.
    let retry = RetryCfg { retries: 3, budget_ms: 30_000, seed: chaos_seed() };
    let (resp, attempts) = client::roundtrip_retry(&addr, &line, timeout, &retry).unwrap();
    match resp {
        Response::Result { provenance, .. } => assert_eq!(provenance, Provenance::Fresh),
        other => panic!("expected a served result after retries, got {other:?}"),
    }
    assert!(attempts >= 2, "the first attempt must have been the victim");

    // The pool is alive and the first serving was cached.
    match client::roundtrip(&addr, &line, timeout).unwrap() {
        Response::Result { provenance, .. } => assert_eq!(provenance, Provenance::Cache),
        other => panic!("expected cached result, got {other:?}"),
    }
    match client::roundtrip(&addr, &encode_control("shutdown"), timeout).unwrap() {
        Response::Ok(_) => {}
        other => panic!("expected ok, got {other:?}"),
    }
    handle.join().unwrap();
}

/// Persist failures never kill servings: with the append path erroring,
/// the daemon core still answers fresh and cached requests (it only
/// warns), and a restart simply misses the unpersisted entry.
#[test]
fn persist_failures_degrade_to_warnings_not_errors() {
    use rlflow::serve::{Method, OptimizeRequest, Provenance, ServeConfig, ServeCore};
    let _fp = failpoint::scoped("serve.log.append=err");
    let dir = tmpdir("persist-degrade");
    let req = OptimizeRequest {
        graph: small_graph(),
        graph_name: "small".into(),
        method: Method::Greedy { max_steps: 8 },
        cost_noise: 0.0,
        noise_seed: 0,
        timeout_ms: None,
    };
    {
        let core = ServeCore::open(&ServeConfig {
            cache_dir: Some(dir.clone()),
            threads: 1,
            ..Default::default()
        })
        .unwrap();
        let first = core.optimize(&req, None).unwrap();
        assert_eq!(first.provenance, Provenance::Fresh, "persist failure must not fail serving");
        let second = core.optimize(&req, None).unwrap();
        assert_eq!(second.provenance, Provenance::Cache);
    }
    // Nothing was persisted — the restart serves fresh again, cleanly.
    let core = ServeCore::open(&ServeConfig {
        cache_dir: Some(dir.clone()),
        threads: 1,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(core.replayed(), 0);
    let again = core.optimize(&req, None).unwrap();
    assert_eq!(again.provenance, Provenance::Fresh);
    let _ = std::fs::remove_dir_all(&dir);
}
