//! ONNX-codec hardening suite — the serve daemon feeds `onnx::import`
//! arbitrary network bytes, so the codec must (a) round-trip every real
//! graph bit-identically and (b) return `Err`, never panic, on anything
//! malformed.
//!
//! * Zoo-wide property: `import(export(g))` preserves the canonical hash
//!   for every evaluation graph, and `export ∘ import ∘ export` is
//!   byte-stable — the foundation of the serve layer's warm-restart
//!   determinism contract (persisted graphs survive a disk round trip
//!   with identical response bytes).
//! * Malformed-input suite: truncated documents, wrong field types,
//!   dangling/forward references, out-of-range ports and adversarial
//!   attributes (zero strides, zero-input `addn`, overflow-sized
//!   reshapes) all return typed errors.
//! * Seeded mutation fuzz: hundreds of random single-byte corruptions of
//!   a real model document must never panic the parser or importer.

use rlflow::graph::{canonical_hash, onnx};
use rlflow::util::json::{parse, Json};
use rlflow::util::Rng;

// ---------------------------------------------------------------------------
// Round-trip properties
// ---------------------------------------------------------------------------

#[test]
fn zoo_graphs_round_trip_bit_identically() {
    for (info, g) in rlflow::zoo::all() {
        let model = onnx::export(&g, info.name).unwrap();
        let back = onnx::import(&model).unwrap();
        assert_eq!(
            canonical_hash(&back),
            canonical_hash(&g),
            "{}: import(export(g)) must preserve the canonical hash",
            info.name
        );
        // Byte stability: once a graph has been through the codec, another
        // round trip reproduces the exact document (what makes persisted
        // cache entries deterministic on disk and on the wire).
        let model2 = onnx::export(&back, info.name).unwrap();
        assert_eq!(
            model2.to_string_compact(),
            model.to_string_compact(),
            "{}: export∘import∘export must be byte-stable",
            info.name
        );
        // And the textual form survives parse() unchanged.
        let reparsed = parse(&model.to_string_compact()).unwrap();
        let back2 = onnx::import(&reparsed).unwrap();
        assert_eq!(canonical_hash(&back2), canonical_hash(&g), "{}: text round trip", info.name);
    }
}

// ---------------------------------------------------------------------------
// Malformed-input suite
// ---------------------------------------------------------------------------

fn sample_model_text() -> String {
    let mut b = rlflow::graph::GraphBuilder::new();
    let x = b.input(&[1, 3, 8, 8]);
    let c = b.conv(x, 4, 3, 1, rlflow::graph::PadMode::Same).unwrap();
    let _ = b.relu(c).unwrap();
    onnx::export(&b.finish(), "sample").unwrap().to_string_compact()
}

/// Import a raw document string; the suite only cares that this returns
/// (`Ok` or `Err`) instead of panicking, and most cases assert `Err`.
fn import_text(text: &str) -> anyhow::Result<rlflow::graph::Graph> {
    onnx::import(&parse(text)?)
}

#[test]
fn truncated_documents_error_cleanly() {
    let text = sample_model_text();
    // Every prefix of a valid document is invalid JSON or an incomplete
    // model; none may panic.
    for cut in [1, text.len() / 4, text.len() / 2, text.len() - 1] {
        assert!(import_text(&text[..cut]).is_err(), "prefix of {cut} bytes must be rejected");
    }
}

#[test]
fn wrong_field_types_error_cleanly() {
    let text = sample_model_text();
    for (from, to) in [
        ("\"nodes\":[", "\"nodes\":{"),               // array -> object
        ("\"op\":\"input\"", "\"op\":42"),            // string -> number
        ("\"stride\":1", "\"stride\":\"wide\""),      // number -> string
        ("\"shape\":[", "\"shape\":\"["),             // array -> string
        ("[[0,0],", "[0,"),                           // ref pair -> bare number
    ] {
        let mutated = text.replacen(from, to, 1);
        assert_ne!(mutated, text, "pattern '{from}' must occur in the sample");
        assert!(import_text(&mutated).is_err(), "mutation '{from}' -> '{to}' must be rejected");
    }
    // Entirely wrong top-level shapes.
    assert!(import_text("null").is_err());
    assert!(import_text("[]").is_err());
    assert!(import_text("{\"nodes\":null}").is_err());
}

fn node(op: &str, extra: &[(&str, Json)], inputs: &[(usize, usize)], outs: Json) -> Json {
    let mut j = Json::obj();
    j.set("op", Json::Str(op.into()));
    for (k, v) in extra {
        j.set(k, v.clone());
    }
    j.set(
        "inputs",
        Json::Arr(
            inputs
                .iter()
                .map(|&(n, p)| Json::Arr(vec![Json::Num(n as f64), Json::Num(p as f64)]))
                .collect(),
        ),
    );
    j.set("outs", outs);
    j
}

fn input_node() -> Json {
    let mut d = Json::obj();
    d.set("dtype", Json::Str("f32".into()));
    d.set("shape", Json::from_usizes(&[2, 4]));
    let mut j = Json::obj();
    j.set("op", Json::Str("input".into()));
    j.set("outs", Json::Arr(vec![d]));
    j
}

fn model(nodes: Vec<Json>) -> Json {
    let mut m = Json::obj();
    m.set("ir_version", Json::Num(1.0));
    m.set("producer", Json::Str("test".into()));
    m.set("graph_name", Json::Str("adversarial".into()));
    m.set("nodes", Json::Arr(nodes));
    m
}

fn relu_outs() -> Json {
    let mut d = Json::obj();
    d.set("dtype", Json::Str("f32".into()));
    d.set("shape", Json::from_usizes(&[2, 4]));
    Json::Arr(vec![d])
}

#[test]
fn dangling_and_forward_references_error_cleanly() {
    // Node 1 references node 7 (absent) and node 1 (itself/forward).
    for bad_ref in [7usize, 1] {
        let m = model(vec![input_node(), node("relu", &[], &[(bad_ref, 0)], relu_outs())]);
        let err = onnx::import(&m).unwrap_err().to_string();
        assert!(err.contains("forward reference"), "got: {err}");
    }
}

#[test]
fn out_of_range_ports_error_cleanly() {
    // Port 9 of a single-output producer: must error, not wrap into u16.
    let m = model(vec![input_node(), node("relu", &[], &[(0, 70000)], relu_outs())]);
    assert!(onnx::import(&m).is_err(), "port beyond u16 must be rejected");
    let m2 = model(vec![input_node(), node("relu", &[], &[(0, 9)], relu_outs())]);
    assert!(onnx::import(&m2).is_err(), "nonexistent port must be rejected");
}

#[test]
fn adversarial_attributes_error_cleanly() {
    // stride 0 would divide by zero in conv output-shape inference.
    let conv = node(
        "conv2d",
        &[
            ("stride", Json::Num(0.0)),
            ("pad", Json::Str("same".into())),
            ("act", Json::Str("none".into())),
        ],
        &[(0, 0)],
        relu_outs(),
    );
    assert!(onnx::import(&model(vec![input_node(), conv])).is_err(), "stride 0 must be rejected");

    // addn with n = 0 would index an empty input list in inference.
    let addn = node("addn", &[("n", Json::Num(0.0))], &[], relu_outs());
    assert!(onnx::import(&model(vec![input_node(), addn])).is_err(), "addn n=0 must be rejected");

    // split into 0 parts.
    let split = node(
        "split",
        &[("axis", Json::Num(0.0)), ("parts", Json::Num(0.0))],
        &[(0, 0)],
        relu_outs(),
    );
    assert!(onnx::import(&model(vec![input_node(), split])).is_err(), "parts 0 must be rejected");

    // A reshape whose element product overflows u64 must be caught by the
    // checked product, not wrap or panic.
    let huge = Json::Arr(vec![Json::Num(1e15); 5]);
    let mut reshape = Json::obj();
    reshape.set("op", Json::Str("reshape".into()));
    reshape.set("shape", huge);
    reshape.set("inputs", Json::Arr(vec![Json::Arr(vec![Json::Num(0.0), Json::Num(0.0)])]));
    reshape.set("outs", relu_outs());
    assert!(
        onnx::import(&model(vec![input_node(), reshape])).is_err(),
        "overflow-sized reshape must be rejected"
    );

    // Oversized tensor descriptors are rejected before inference.
    let mut d = Json::obj();
    d.set("dtype", Json::Str("f32".into()));
    d.set("shape", Json::from_usizes(&[1 << 20, 1 << 20, 1 << 20]));
    let mut src = Json::obj();
    src.set("op", Json::Str("input".into()));
    src.set("outs", Json::Arr(vec![d]));
    assert!(onnx::import(&model(vec![src])).is_err(), "oversized descriptor must be rejected");
}

#[test]
fn deeply_nested_documents_error_cleanly() {
    // The parser's depth bound protects the importer from a stack bomb.
    let bomb = format!("{}1{}", "[".repeat(50_000), "]".repeat(50_000));
    assert!(parse(&bomb).is_err(), "nesting bomb must be rejected by the parser");
}

// ---------------------------------------------------------------------------
// Seeded mutation fuzz
// ---------------------------------------------------------------------------

#[test]
fn random_byte_corruptions_never_panic() {
    let text = sample_model_text();
    assert!(text.is_ascii(), "the model document is ASCII by construction");
    let mut rng = Rng::new(0x0115_C0DE);
    let mut still_valid = 0usize;
    for _ in 0..300 {
        let mut bytes = text.clone().into_bytes();
        // 1..=4 single-byte corruptions, printable-ASCII so the result
        // stays valid UTF-8 and exercises parser/importer, not str
        // validation.
        for _ in 0..(1 + rng.below(4)) {
            let pos = rng.below(bytes.len());
            bytes[pos] = (0x20 + rng.below(95)) as u8;
        }
        let mutated = String::from_utf8(bytes).expect("ascii mutations stay utf-8");
        // The only requirement: no panic. Some mutations (e.g. inside the
        // producer string) legitimately still import.
        if import_text(&mutated).is_ok() {
            still_valid += 1;
        }
        // Also shove each mutant through the serve request decoder, which
        // wraps the same codec behind the wire-format limits.
        let line = format!("{{\"type\":\"optimize\",\"graph\":{mutated}}}");
        let _ = rlflow::serve::decode_request(&line);
    }
    // Sanity: the corpus wasn't trivially all-valid (the loop really
    // exercised error paths).
    assert!(still_valid < 300, "every mutation importing cleanly is implausible");
}
