//! End-to-end integration: the full model-based pipeline on a small graph
//! with smoke-scale settings, exercising every artifact. Skips when
//! artifacts are absent.

use rlflow::agent::PpoCfg;
use rlflow::config::RunConfig;
use rlflow::coordinator::{collect_random_parallel, Pipeline};
use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig};
use rlflow::graph::{GraphBuilder, PadMode};
use rlflow::runtime::{Manifest, ParamStore, PjrtBackend};
use rlflow::util::Rng;
use rlflow::xfer::library::standard_library;

fn engine() -> Option<PjrtBackend> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(PjrtBackend::load_default().expect("pjrt backend"))
}

fn small_graph() -> rlflow::graph::Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 16, 16]);
    let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
    let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
    let c3 = b.conv(c2, 8, 1, 1, PadMode::Same).unwrap();
    let r = b.relu(c3).unwrap();
    let _ = b.maxpool(r, 2, 2).unwrap();
    b.finish()
}

#[test]
fn model_based_pipeline_end_to_end() {
    let Some(eng) = engine() else { return };
    let cfg = RunConfig::smoke();
    let pipe = Pipeline::new(&eng).unwrap();
    let mut rng = Rng::new(cfg.seed);

    // 1. Random collection (parallel, engine-free).
    let mut episodes = collect_random_parallel(
        &small_graph(),
        &cfg.env,
        cfg.device,
        (pipe.encoder.max_nodes, pipe.encoder.n_feats),
        pipe.dims.x1,
        cfg.collect_episodes,
        cfg.collect_noop_prob,
        cfg.envs,
        cfg.collect_workers,
        cfg.seed,
    );
    assert_eq!(episodes.len(), cfg.collect_episodes);

    // 2. GNN auto-encoder.
    let mut gnn = ParamStore::init(&eng, "gnn", 0).unwrap();
    let ae_losses = pipe
        .train_gnn_ae(&mut gnn, &episodes, cfg.ae_steps, cfg.ae_lr, &mut rng)
        .unwrap();
    assert_eq!(ae_losses.len(), cfg.ae_steps);
    assert!(ae_losses.iter().all(|l| l.is_finite()));

    // 3. Encode.
    pipe.encode_episodes(&gnn, &mut episodes).unwrap();
    assert!(episodes.iter().all(|e| e.z.len() == e.states.len()));
    assert!(episodes[0].z[0].iter().any(|v| v.abs() > 0.0));

    // 4. World model.
    let mut wm = ParamStore::init(&eng, "wm", 1).unwrap();
    let wm_curve = pipe.train_wm(&mut wm, &episodes, &cfg.wm, &mut rng).unwrap();
    assert_eq!(wm_curve.len(), cfg.wm.total_steps);
    assert!(wm_curve.iter().all(|l| l.total.is_finite()));

    // 5. Controller in the dream.
    let mut ctrl = ParamStore::init(&eng, "ctrl", 2).unwrap();
    let dream_curve = pipe
        .train_controller_dream(
            &mut ctrl,
            &wm,
            &episodes,
            cfg.dream_epochs,
            cfg.dream_horizon,
            cfg.temperature,
            cfg.wm.reward_scale,
            &cfg.ppo,
            &mut rng,
        )
        .unwrap();
    assert_eq!(dream_curve.len(), cfg.dream_epochs);

    // 6. Real-environment evaluation.
    let rules = standard_library();
    let cost = CostModel::new(cfg.device);
    let mut env = Env::new(small_graph(), &rules, &cost, cfg.env.clone());
    let result = pipe
        .eval_real(&gnn, &ctrl, Some(&wm), &mut env, false, &mut rng)
        .unwrap();
    assert!(result.steps > 0);
    assert!(result.best_improvement_pct >= 0.0);
    assert!(result.mean_step_s > 0.0);
}

#[test]
fn model_free_ppo_iteration_runs() {
    let Some(eng) = engine() else { return };
    let pipe = Pipeline::new(&eng).unwrap();
    let mut rng = Rng::new(7);
    let gnn = ParamStore::init(&eng, "gnn", 0).unwrap();
    let mut ctrl = ParamStore::init(&eng, "ctrl", 3).unwrap();
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let mut env = Env::new(
        small_graph(),
        &rules,
        &cost,
        EnvConfig { max_steps: 6, ..Default::default() },
    );
    let before = ctrl.theta.clone();
    let (mean_reward, stats) = pipe
        .model_free_iteration(&gnn, &mut ctrl, &mut env, 2, &PpoCfg::default(), &mut rng)
        .unwrap();
    assert!(mean_reward.is_finite());
    assert!(stats.entropy.is_finite());
    assert_ne!(before, ctrl.theta, "PPO update should move parameters");
}
