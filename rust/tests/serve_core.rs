//! Serve-subsystem contracts, pinned without (and then once with) a
//! socket:
//!
//! * **Coalescing** — N concurrent identical requests execute exactly one
//!   search; every caller receives byte-identical payload bytes.
//! * **Warm-restart determinism** — a core reopened on a persisted
//!   cache dir answers previously-served requests from cache,
//!   bit-identically, with hit/miss counters carried across the restart.
//! * **Admission control** — queue overflow is the typed `overloaded`
//!   error on the wire, never a hang.
//! * **End-to-end** — the TCP daemon on a loopback port serves the same
//!   contracts through the newline-delimited JSON protocol.

use std::path::PathBuf;
use std::sync::{Arc, Barrier};

use rlflow::graph::{GraphBuilder, PadMode};
use rlflow::serve::{
    BoundedQueue, ErrorCode, Method, OptimizeRequest, Provenance, PushError, Response,
    ServeConfig, ServeCore, ServerConfig,
};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rlflow-serve-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A small CNN fragment with real substitution opportunities, so served
/// searches exercise actual rewrites (not just empty logs).
fn small_graph() -> rlflow::graph::Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 8, 8]);
    let c1 = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
    let r1 = b.relu(c1).unwrap();
    let c2 = b.conv(r1, 4, 3, 1, PadMode::Same).unwrap();
    let _ = b.relu(c2).unwrap();
    b.finish()
}

fn small_request() -> OptimizeRequest {
    OptimizeRequest {
        graph: small_graph(),
        graph_name: "small".into(),
        method: Method::Greedy { max_steps: 8 },
        cost_noise: 0.0,
        noise_seed: 0,
        timeout_ms: None,
    }
}

fn single_thread_core(cache_dir: Option<PathBuf>) -> ServeCore {
    ServeCore::open(&ServeConfig { cache_dir, threads: 1, ..Default::default() }).unwrap()
}

#[test]
fn concurrent_identical_requests_coalesce_to_one_search() {
    const N: usize = 8;
    let core = Arc::new(single_thread_core(None));
    let barrier = Arc::new(Barrier::new(N));
    let mut payloads = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..N)
            .map(|_| {
                let core = Arc::clone(&core);
                let barrier = Arc::clone(&barrier);
                scope.spawn(move || {
                    let req = small_request();
                    barrier.wait();
                    let out = core.optimize(&req, None).expect("serving must not fail");
                    (out.provenance, out.payload("small").unwrap().to_string_compact())
                })
            })
            .collect();
        for h in handles {
            payloads.push(h.join().unwrap());
        }
    });

    // Exactly one live search ran, whatever the interleaving: every other
    // request either coalesced onto it or hit the memo it stored.
    let stats = core.stats(0);
    assert_eq!(stats.fresh_searches, 1, "N identical requests must run one search");
    assert_eq!(stats.requests, N as u64);
    assert_eq!(
        stats.fresh_searches + stats.served_from_cache + stats.coalesced,
        N as u64,
        "every request must be accounted to exactly one provenance"
    );
    let fresh = payloads.iter().filter(|(p, _)| *p == Provenance::Fresh).count();
    assert_eq!(fresh, 1, "exactly one caller may observe `fresh`");
    // All N callers got the same bytes.
    let first = &payloads[0].1;
    assert!(payloads.iter().all(|(_, bytes)| bytes == first), "payload bytes must be identical");
    assert_eq!(core.cache().stats().result_misses, 1, "only the leader consulted the memo cold");
}

#[test]
fn warm_restart_serves_bit_identical_responses() {
    let dir = tmpdir("warm-restart");
    let req = small_request();

    // First process: one fresh search, one memo hit, then a snapshot.
    let (cold_bytes, warm_bytes) = {
        let core = single_thread_core(Some(dir.clone()));
        assert_eq!(core.replayed(), 0);
        let first = core.optimize(&req, None).unwrap();
        assert_eq!(first.provenance, Provenance::Fresh);
        let second = core.optimize(&req, None).unwrap();
        assert_eq!(second.provenance, Provenance::Cache);
        core.flush().unwrap();
        (
            first.payload("small").unwrap().to_string_compact(),
            second.payload("small").unwrap().to_string_compact(),
        )
    };
    assert_eq!(cold_bytes, warm_bytes, "provenance must not leak into the payload");

    // Second process, same cache dir: the replayed memo answers the same
    // request bit-identically, and the counters carried over.
    let core2 = single_thread_core(Some(dir.clone()));
    assert_eq!(core2.replayed(), 1, "the persisted result must replay");
    let prior = core2.cache_stats();
    assert_eq!(prior.result_hits, 1, "first process's hit survives the restart");
    assert_eq!(prior.result_misses, 1, "first process's miss survives the restart");
    let restarted = core2.optimize(&req, None).unwrap();
    assert_eq!(restarted.provenance, Provenance::Cache, "warm restart must hit");
    assert_eq!(
        restarted.payload("small").unwrap().to_string_compact(),
        cold_bytes,
        "warm-restarted response must be byte-identical to the pre-restart process"
    );
    assert_eq!(core2.cache_stats().result_hits, 2);

    // Third generation (restart of a restart, log-only replay this time).
    drop(core2);
    let core3 = single_thread_core(Some(dir.clone()));
    assert_eq!(core3.replayed(), 1);
    let again = core3.optimize(&req, None).unwrap();
    assert_eq!(again.provenance, Provenance::Cache);
    assert_eq!(again.payload("small").unwrap().to_string_compact(), cold_bytes);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn queue_overflow_is_the_typed_overloaded_error() {
    let q: BoundedQueue<u32> = BoundedQueue::new(2);
    q.push(1).unwrap();
    q.push(2).unwrap();
    let err = q.push(3).unwrap_err();
    assert_eq!(err, PushError::Overloaded { depth: 2 });
    // ... and the server maps it to the protocol's typed error, so a
    // client sees an explicit response, never a hang.
    let resp = Response::error(ErrorCode::Overloaded, "queue full (2 queued)");
    let line = resp.encode();
    assert!(line.contains("\"code\":\"overloaded\""), "wire line was {line}");
    match Response::decode(&line).unwrap() {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::Overloaded),
        other => panic!("decoded wrong variant: {other:?}"),
    }
}

#[test]
fn persisted_stats_accumulate_across_generations() {
    let dir = tmpdir("stats-accumulate");
    let req = small_request();
    {
        let core = single_thread_core(Some(dir.clone()));
        core.optimize(&req, None).unwrap(); // miss
        core.optimize(&req, None).unwrap(); // hit
        core.flush().unwrap();
    }
    {
        let core = single_thread_core(Some(dir.clone()));
        core.optimize(&req, None).unwrap(); // hit (replayed memo)
        core.flush().unwrap();
    }
    let core = single_thread_core(Some(dir.clone()));
    let stats = core.cache_stats();
    assert_eq!(stats.result_hits, 2, "hits from both generations accumulate");
    assert_eq!(stats.result_misses, 1, "the one cold miss is never recounted");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression: queued-deadline expiry must be decided atomically with
/// the dequeue (`BoundedQueue::pop_where`), not checked after the pop.
/// A request whose deadline has already passed when a worker claims it
/// gets the typed `timeout` error and never runs a search — with the
/// old pop-then-check sequence the verdict could flip between the claim
/// and the check.
#[test]
fn queued_deadline_expiry_is_atomic_with_the_claim() {
    use rlflow::serve::{client, encode_control, encode_optimize};

    let mut cfg = ServerConfig::new("127.0.0.1:0");
    cfg.workers = 1;
    cfg.core.threads = 1;
    let handle = rlflow::serve::spawn(cfg).unwrap();
    let addr = handle.addr.to_string();
    let timeout = std::time::Duration::from_secs(60);

    // A zero-millisecond budget has always expired by claim time, so the
    // classification under the queue lock must come back `Expired`.
    let mut req = small_request();
    req.timeout_ms = Some(0);
    match client::roundtrip(&addr, &encode_optimize(&req).unwrap(), timeout).unwrap() {
        Response::Error { code, message } => {
            assert_eq!(code, ErrorCode::Timeout, "got: {message}");
            assert!(message.contains("queued"), "got: {message}");
        }
        other => panic!("expected timeout error, got {other:?}"),
    }

    // The expired job was answered without running: no search happened,
    // and the timeout was counted.
    match client::roundtrip(&addr, &encode_control("stats"), timeout).unwrap() {
        Response::Stats(stats) => {
            assert_eq!(
                stats.get("fresh_searches").unwrap().as_usize().unwrap(),
                0,
                "an expired job must never reach the search"
            );
            assert_eq!(stats.get("timeouts").unwrap().as_usize().unwrap(), 1);
        }
        other => panic!("expected stats, got {other:?}"),
    }

    // A sane budget still serves normally afterwards.
    match client::roundtrip(&addr, &encode_optimize(&small_request()).unwrap(), timeout).unwrap()
    {
        Response::Result { provenance, .. } => assert_eq!(provenance, Provenance::Fresh),
        other => panic!("expected result, got {other:?}"),
    }

    match client::roundtrip(&addr, &encode_control("shutdown"), timeout).unwrap() {
        Response::Ok(_) => {}
        other => panic!("expected ok, got {other:?}"),
    }
    handle.join().unwrap();
}

/// Fuzz `results.log` truncation at every byte boundary of the final
/// entry: reopening must never fail and must keep every fully-persisted
/// entry. The final entry survives iff all of its bytes reached disk (a
/// missing trailing newline alone is repaired, not dropped); a torn tail
/// loses only the torn entry, never the committed ones before it.
#[test]
fn log_truncation_at_every_byte_boundary_recovers() {
    use rlflow::search::SearchLog;
    use rlflow::serve::persist::{CacheEntry, Persister};

    fn entry(fp: u64) -> CacheEntry {
        let g = small_graph();
        let root = rlflow::graph::canonical_hash(&g);
        CacheEntry {
            fp,
            root,
            graph: g,
            log: SearchLog {
                steps: vec![("fuse".into(), 1.25)],
                initial_ms: 2.0,
                final_ms: 1.25,
                elapsed_s: 0.0,
                graphs_explored: 7,
                table_size: 9,
                memo_hits: 3,
                threads: 4,
                from_cache: false,
            },
        }
    }

    let dir = tmpdir("trunc-fuzz");
    {
        let (mut p, _) = Persister::open(&dir, 1000).unwrap();
        p.append(&entry(1)).unwrap();
        p.append(&entry(2)).unwrap();
    }
    let log_path = dir.join("results.log");
    let orig = std::fs::read(&log_path).unwrap();
    let line1_end = orig.iter().position(|&b| b == b'\n').unwrap() + 1;
    assert!(line1_end < orig.len(), "expected two log lines");

    for cut in line1_end..=orig.len() {
        std::fs::write(&log_path, &orig[..cut]).unwrap();
        let (_p, replay) = Persister::open(&dir, 1000).unwrap();
        // Only the final newline is recoverable; any missing payload byte
        // tears the entry.
        let want = if cut >= orig.len() - 1 { 2 } else { 1 };
        assert_eq!(
            replay.entries.len(),
            want,
            "cut at byte {cut} of {}: wrong entry count",
            orig.len()
        );
        assert_eq!(replay.entries[0].fp, 1, "cut at byte {cut}: committed entry lost");
        if want == 2 {
            assert_eq!(replay.entries[1].fp, 2, "cut at byte {cut}: final entry mangled");
        }
        assert_eq!(
            replay.skipped_lines,
            usize::from(want == 1 && cut > line1_end),
            "cut at byte {cut}: unexpected skip count"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// End-to-end over a loopback socket
// ---------------------------------------------------------------------------

#[test]
fn daemon_end_to_end_on_loopback() {
    use rlflow::serve::{client, encode_control, encode_optimize};

    let dir = tmpdir("e2e");
    let mut cfg = ServerConfig::new("127.0.0.1:0");
    cfg.workers = 2;
    cfg.core.threads = 1;
    cfg.core.cache_dir = Some(dir.clone());
    let handle = rlflow::serve::spawn(cfg.clone()).unwrap();
    let addr = handle.addr.to_string();
    let timeout = std::time::Duration::from_secs(60);

    // Liveness.
    match client::roundtrip(&addr, &encode_control("ping"), timeout).unwrap() {
        Response::Pong => {}
        other => panic!("expected pong, got {other:?}"),
    }

    // First serving is fresh, second is a cache hit with identical bytes.
    let line = encode_optimize(&small_request()).unwrap();
    let first = match client::roundtrip(&addr, &line, timeout).unwrap() {
        Response::Result { payload, provenance, .. } => {
            assert_eq!(provenance, Provenance::Fresh);
            payload.to_string_compact()
        }
        other => panic!("expected result, got {other:?}"),
    };
    let second = match client::roundtrip(&addr, &line, timeout).unwrap() {
        Response::Result { payload, provenance, .. } => {
            assert_eq!(provenance, Provenance::Cache);
            payload.to_string_compact()
        }
        other => panic!("expected result, got {other:?}"),
    };
    assert_eq!(first, second, "cache hit must return the fresh serving's bytes");

    // Malformed lines get a typed bad_request, and the daemon survives.
    match client::roundtrip(&addr, "{\"type\":\"warp\"}", timeout).unwrap() {
        Response::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
        other => panic!("expected bad_request, got {other:?}"),
    }

    // Stats reflect the traffic.
    match client::roundtrip(&addr, &encode_control("stats"), timeout).unwrap() {
        Response::Stats(stats) => {
            assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), 2);
            assert_eq!(stats.get("fresh_searches").unwrap().as_usize().unwrap(), 1);
            assert_eq!(stats.get("served_from_cache").unwrap().as_usize().unwrap(), 1);
            assert_eq!(stats.get("bad_requests").unwrap().as_usize().unwrap(), 1);
        }
        other => panic!("expected stats, got {other:?}"),
    }

    // Graceful drain via the control request.
    match client::roundtrip(&addr, &encode_control("shutdown"), timeout).unwrap() {
        Response::Ok(detail) => assert_eq!(detail, "draining"),
        other => panic!("expected ok, got {other:?}"),
    }
    handle.join().unwrap();

    // Warm restart on the same cache dir: the hit survives the process.
    let handle2 = rlflow::serve::spawn(cfg).unwrap();
    let addr2 = handle2.addr.to_string();
    match client::roundtrip(&addr2, &line, timeout).unwrap() {
        Response::Result { payload, provenance, .. } => {
            assert_eq!(provenance, Provenance::Cache, "warm restart must hit");
            assert_eq!(payload.to_string_compact(), first, "restart must be bit-identical");
        }
        other => panic!("expected result, got {other:?}"),
    }
    match client::roundtrip(&addr2, &encode_control("shutdown"), timeout).unwrap() {
        Response::Ok(_) => {}
        other => panic!("expected ok, got {other:?}"),
    }
    handle2.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
