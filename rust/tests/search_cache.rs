//! Properties of the cross-run search memoisation layer
//! (`search::memo::SearchCache`) and the location-sharded expansion engine:
//!
//!  * a repeated identical search is a pure result-memo lookup, returning
//!    bit-identical graphs and costs with an observable hit-rate;
//!  * different search configs never share cache entries (fingerprint
//!    isolation);
//!  * warm cost-memo runs on *different* roots reuse persisted costs while
//!    agreeing with fresh-cache runs on what they find;
//!  * location-level sharding is thread-count invariant even when a single
//!    match-heavy rule dominates the work;
//!  * all of the above holds with §3.1.4 measurement noise enabled (the
//!    noise field is part of the fingerprint).

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::graph::{canonical_hash, Graph, GraphBuilder, PadMode};
use rlflow::search::{
    greedy_optimise_cached, greedy_optimise_threads, taso_optimise, taso_optimise_cached,
    SearchCache, TasoConfig,
};
use rlflow::xfer::library::standard_library;

fn fixture() -> Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 16, 16]);
    let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
    let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
    let c3 = b.conv(c2, 8, 1, 1, PadMode::Same).unwrap();
    let _ = b.relu(c3).unwrap();
    b.finish()
}

/// A graph whose substitution surface is dominated by ONE rule with many
/// locations (`fuse_conv_relu` across every block) — the straggler shape
/// that (graph, rule)-pair sharding serialised behind a single worker.
fn conv_relu_heavy() -> Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 4, 16, 16]);
    let mut cur = x;
    for _ in 0..6 {
        let c = b.conv(cur, 4, 1, 1, PadMode::Same).unwrap();
        cur = b.relu(c).unwrap();
    }
    b.finish()
}

fn small_cfg() -> TasoConfig {
    TasoConfig { depth: 4, beam: 3, ..Default::default() }
}

#[test]
fn second_identical_search_is_pure_lookup() {
    let g = fixture();
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let cache = SearchCache::new();

    let (g1, log1) = taso_optimise_cached(&g, &rules, &cost, &small_cfg(), &cache);
    assert!(!log1.from_cache);
    let (g2, log2) = taso_optimise_cached(&g, &rules, &cost, &small_cfg(), &cache);
    assert!(log2.from_cache, "second identical taso search must be a lookup");
    assert_eq!(log1.final_ms.to_bits(), log2.final_ms.to_bits());
    assert_eq!(log1.initial_ms.to_bits(), log2.initial_ms.to_bits());
    assert_eq!(canonical_hash(&g1), canonical_hash(&g2));
    assert_eq!(log1.steps, log2.steps);
    assert_eq!(log1.graphs_explored, log2.graphs_explored);

    let (h1, glog1) = greedy_optimise_cached(&g, &rules, &cost, 50, 0, &cache);
    assert!(!glog1.from_cache, "greedy uses a different fingerprint than taso");
    let (h2, glog2) = greedy_optimise_cached(&g, &rules, &cost, 50, 0, &cache);
    assert!(glog2.from_cache);
    assert_eq!(glog1.final_ms.to_bits(), glog2.final_ms.to_bits());
    assert_eq!(canonical_hash(&h1), canonical_hash(&h2));

    let stats = cache.stats();
    assert_eq!(stats.result_hits, 2, "one taso + one greedy repeat");
    assert_eq!(stats.result_misses, 2);
    assert_eq!(stats.result_entries, 2);
    assert!(stats.cost_entries > 0, "transposition tables must persist");
    assert_eq!(stats.evictions, 0);
}

#[test]
fn config_fingerprints_are_isolated() {
    // Different TasoConfigs must never share entries: each config gets its
    // own result slot and its own cost shard.
    let g = fixture();
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let cache = SearchCache::new();

    let (_, a) = taso_optimise_cached(&g, &rules, &cost, &small_cfg(), &cache);
    let alpha_cfg = TasoConfig { alpha: 1.10, ..small_cfg() };
    let (_, b) = taso_optimise_cached(&g, &rules, &cost, &alpha_cfg, &cache);
    let beam_cfg = TasoConfig { beam: 2, ..small_cfg() };
    let (_, c) = taso_optimise_cached(&g, &rules, &cost, &beam_cfg, &cache);
    assert!(!a.from_cache && !b.from_cache && !c.from_cache);

    let stats = cache.stats();
    assert_eq!(stats.result_hits, 0, "no config may alias another's entry");
    assert_eq!(stats.result_misses, 3);
    assert_eq!(stats.result_entries, 3);

    // The thread count is NOT part of the fingerprint: results are
    // bit-identical for every worker count, so a different `threads`
    // value hits the same entry.
    let threads_cfg = TasoConfig { threads: 2, ..small_cfg() };
    let (_, d) = taso_optimise_cached(&g, &rules, &cost, &threads_cfg, &cache);
    assert!(d.from_cache, "thread count must not split the cache");
    assert_eq!(a.final_ms.to_bits(), d.final_ms.to_bits());
}

#[test]
fn warm_cost_memo_reuses_entries_and_agrees_with_cold_runs() {
    // Optimise a graph, then a *different* root that shares derived
    // candidates (the optimised graph itself, reachable mid-search). The
    // warm run must (a) observably hit the persisted cost memo and (b)
    // agree with a fresh-cache run of the same search.
    let g = fixture();
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let cache = SearchCache::new();

    let (opt, first) = greedy_optimise_cached(&g, &rules, &cost, 50, 0, &cache);
    // Re-rooting the same config on a graph the first search derived:
    // its candidates overlap the persisted shard.
    let (warm_g, warm) = greedy_optimise_cached(&opt, &rules, &cost, 50, 0, &cache);
    assert!(!warm.from_cache, "different root must not hit the result memo");
    assert!(
        warm.memo_hits > 0,
        "warm run should reuse persisted costs (got {} hits, {} explored)",
        warm.memo_hits,
        warm.graphs_explored
    );

    let fresh_cache = SearchCache::new();
    let (cold_g, cold) = greedy_optimise_cached(&opt, &rules, &cost, 50, 0, &fresh_cache);
    // Same search semantics: identical step trail and final structure; the
    // warm run's memoised candidate costs may differ from freshly-derived
    // ones in the last f64 ulps (first-derivation-canonical contract), so
    // the cost pin is relative.
    assert_eq!(canonical_hash(&warm_g), canonical_hash(&cold_g));
    let rel = (warm.final_ms - cold.final_ms).abs() / cold.final_ms.max(1e-12);
    assert!(rel < 1e-9, "warm {} vs cold {}", warm.final_ms, cold.final_ms);
    assert_eq!(
        warm.steps.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>(),
        cold.steps.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>()
    );
    let _ = first;
}

#[test]
fn location_sharding_is_thread_invariant_on_match_heavy_rule() {
    // One rule, many locations: exactly the shape that used to straggle.
    // Any worker count must reproduce the sequential run to the bit.
    let g = conv_relu_heavy();
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());

    let (sg, slog) = greedy_optimise_threads(&g, &rules, &cost, 20, 1);
    assert!(
        slog.steps.iter().filter(|(n, _)| n == "fuse_conv_relu").count() >= 4,
        "fixture must actually be fuse_conv_relu heavy: {:?}",
        slog.steps
    );
    for threads in [2, 3, 5] {
        let (pg, plog) = greedy_optimise_threads(&g, &rules, &cost, 20, threads);
        assert_eq!(slog.final_ms.to_bits(), plog.final_ms.to_bits(), "threads={threads}");
        assert_eq!(canonical_hash(&sg), canonical_hash(&pg), "threads={threads}");
        assert_eq!(slog.graphs_explored, plog.graphs_explored, "threads={threads}");
        assert_eq!(slog.steps, plog.steps, "threads={threads}");
    }

    let (sg, slog) = taso_optimise(&g, &rules, &cost, &TasoConfig { threads: 1, ..small_cfg() });
    for threads in [2, 4] {
        let (pg, plog) =
            taso_optimise(&g, &rules, &cost, &TasoConfig { threads, ..small_cfg() });
        assert_eq!(slog.final_ms.to_bits(), plog.final_ms.to_bits(), "threads={threads}");
        assert_eq!(canonical_hash(&sg), canonical_hash(&pg), "threads={threads}");
        assert_eq!(slog.steps, plog.steps, "threads={threads}");
    }
}

#[test]
fn noisy_searches_cache_and_stay_thread_invariant() {
    // The noise field (std + seed) is part of the config fingerprint, so
    // noisy searches memoise like clean ones — and never alias across
    // seeds.
    let g = fixture();
    let rules = standard_library();
    let noisy = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 21);
    let cache = SearchCache::new();

    let (g1, log1) = taso_optimise_cached(&g, &rules, &noisy, &small_cfg(), &cache);
    let (g2, log2) = taso_optimise_cached(&g, &rules, &noisy, &small_cfg(), &cache);
    assert!(log2.from_cache, "same noise config must hit");
    assert_eq!(log1.final_ms.to_bits(), log2.final_ms.to_bits());
    assert_eq!(canonical_hash(&g1), canonical_hash(&g2));

    let other_seed = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 22);
    let (_, log3) = taso_optimise_cached(&g, &rules, &other_seed, &small_cfg(), &cache);
    assert!(!log3.from_cache, "a different noise seed is a different config");

    // Parallel noisy expansion matches sequential bitwise.
    let (sg, slog) =
        taso_optimise(&g, &rules, &noisy, &TasoConfig { threads: 1, ..small_cfg() });
    let (pg, plog) =
        taso_optimise(&g, &rules, &noisy, &TasoConfig { threads: 3, ..small_cfg() });
    assert_eq!(slog.final_ms.to_bits(), plog.final_ms.to_bits());
    assert_eq!(canonical_hash(&sg), canonical_hash(&pg));
    assert_eq!(slog.steps, plog.steps);
}

#[test]
fn zoo_graph_repeat_matches_cold_run_with_observable_hits() {
    // The acceptance-shaped check: repeated optimisation of a real zoo
    // graph through one persistent cache reuses it (hit-rate > 0) and
    // returns results bit-identical to the cold run.
    let g = rlflow::zoo::squeezenet1_1();
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let cache = SearchCache::new();
    let cfg = TasoConfig { depth: 3, beam: 3, ..Default::default() };

    let (cold_g, cold) = taso_optimise_cached(&g, &rules, &cost, &cfg, &cache);
    let (warm_g, warm) = taso_optimise_cached(&g, &rules, &cost, &cfg, &cache);
    assert!(warm.from_cache);
    assert_eq!(cold.final_ms.to_bits(), warm.final_ms.to_bits());
    assert_eq!(canonical_hash(&cold_g), canonical_hash(&warm_g));
    assert_eq!(cold.steps, warm.steps);
    let stats = cache.stats();
    assert!(stats.result_hits > 0, "hit-rate must be observable: {stats:?}");
}
