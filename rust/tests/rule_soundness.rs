//! Zoo-wide differential soundness suite for the handwritten rule library.
//!
//! Every rule in `library.rs`/`library_ext.rs` is applied at match sites on
//! every zoo graph; each rewrite is checked with the local differential
//! equivalence oracle (`interp::locally_equivalent`), which evaluates only
//! the removed/added cones on shared random boundary inputs instead of
//! interpreting the full model (299x299 convolutions in debug mode are not
//! an option).
//!
//! The default test budgets interpreter work with `interp::rewrite_flops`:
//! it checks every cheap site, plus — for each rule that matched anywhere —
//! that rule's globally cheapest site up to a larger fallback budget, so no
//! matching rule goes unchecked just because its cones are mid-sized. The
//! `#[ignore]`d exhaustive variant checks every site of every rule on every
//! graph with no budget (run with `cargo test -- --ignored` when you have
//! time to burn).

use rlflow::graph::Graph;
use rlflow::interp::{locally_equivalent, rewrite_flops};
use rlflow::xfer::library::standard_library;
use rlflow::xfer::{apply_rule, Location, Rule};

/// Sites at or below this cone cost are always checked.
const CHEAP_FLOPS: u64 = 500_000;
/// Per-rule fallback: the cheapest site of an otherwise-unchecked rule is
/// checked when it costs at most this much.
const FALLBACK_FLOPS: u64 = 8_000_000;
/// Random boundary draws per checked site.
const TRIALS: usize = 2;
/// Relative tolerance; rewrites like BN-folding reassociate f32 arithmetic.
const TOL: f32 = 3e-3;

fn site_seed(rule: &str, graph: &str, idx: usize) -> u64 {
    let mut h = 0xCBF29CE484222325u64;
    for b in rule.bytes().chain(graph.bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x100000001B3);
    }
    h ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

/// One applicable site, with the rewrite pre-applied and costed.
struct Site {
    graph_name: &'static str,
    loc: Location,
    flops: u64,
}

/// Check one site, returning an error string on unsoundness.
fn check_site(rule: &dyn Rule, g: &Graph, site: &Site, idx: usize) -> Result<(), String> {
    let mut g2 = g.clone();
    let report = apply_rule(&mut g2, rule, &site.loc)
        .map_err(|e| format!("{} on {}: apply failed: {e}", rule.name(), site.graph_name))?;
    let seed = site_seed(rule.name(), site.graph_name, idx);
    match locally_equivalent(g, &g2, &report, TRIALS, seed, TOL) {
        Ok(true) => Ok(()),
        Ok(false) => Err(format!(
            "{} on {} at {:?}: rewrite changed semantics",
            rule.name(),
            site.graph_name,
            site.loc
        )),
        Err(e) => Err(format!(
            "{} on {} at {:?}: differential check errored: {e}",
            rule.name(),
            site.graph_name,
            site.loc
        )),
    }
}

/// Enumerate (and cost) every site of every library rule on every zoo graph.
/// Returns the zoo plus, per rule, its site list.
fn all_sites() -> (Vec<(&'static str, Graph)>, Vec<(usize, Vec<Site>)>) {
    let zoo: Vec<(&'static str, Graph)> =
        rlflow::zoo::all().into_iter().map(|(info, g)| (info.name, g)).collect();
    let lib = standard_library();
    let mut per_rule = Vec::new();
    for (ri, rule) in lib.rules.iter().enumerate() {
        let mut sites = Vec::new();
        for (name, g) in &zoo {
            for loc in rule.find(g) {
                let mut g2 = g.clone();
                let flops = match apply_rule(&mut g2, rule.as_ref(), &loc) {
                    Ok(report) => rewrite_flops(g, &g2, &report),
                    // Apply failures are real bugs; surface them via a
                    // zero-cost site the checker is guaranteed to pick up.
                    Err(_) => 0,
                };
                sites.push(Site { graph_name: name, loc, flops });
            }
        }
        per_rule.push((ri, sites));
    }
    (zoo, per_rule)
}

#[test]
fn zoo_rules_are_locally_sound_within_budget() {
    let (zoo, per_rule) = all_sites();
    let lib = standard_library();
    let graph_by_name = |n: &str| &zoo.iter().find(|(name, _)| *name == n).unwrap().1;

    let mut failures: Vec<String> = Vec::new();
    let mut checked_sites = 0usize;
    let mut checked_rules = 0usize;
    let mut matching_rules = 0usize;
    for (ri, sites) in &per_rule {
        let rule = lib.rules[*ri].as_ref();
        if sites.is_empty() {
            continue;
        }
        matching_rules += 1;
        let mut rule_checked = false;
        for (idx, site) in sites.iter().enumerate() {
            if site.flops <= CHEAP_FLOPS {
                if let Err(e) = check_site(rule, graph_by_name(site.graph_name), site, idx) {
                    failures.push(e);
                }
                checked_sites += 1;
                rule_checked = true;
            }
        }
        if !rule_checked {
            // All sites were expensive: check the cheapest one if the
            // fallback budget covers it.
            let (idx, cheapest) = sites
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.flops)
                .expect("non-empty site list");
            if cheapest.flops <= FALLBACK_FLOPS {
                if let Err(e) = check_site(rule, graph_by_name(cheapest.graph_name), cheapest, idx)
                {
                    failures.push(e);
                }
                checked_sites += 1;
                rule_checked = true;
            }
        }
        if rule_checked {
            checked_rules += 1;
        }
    }
    assert!(failures.is_empty(), "unsound rewrites:\n{}", failures.join("\n"));
    // The budget must leave a meaningful fraction of the library covered —
    // if these floors break, the budgets (or the zoo) changed character.
    assert!(checked_sites >= 30, "only {checked_sites} sites fit the budget");
    assert!(
        checked_rules * 2 >= matching_rules,
        "only {checked_rules}/{matching_rules} matching rules were checked"
    );
}

/// Exhaustive variant: every site of every rule on every zoo graph, no
/// flop budget. Hours of debug-mode interpreter time; run explicitly via
/// `cargo test --release -- --ignored zoo_rules_are_locally_sound_everywhere`.
#[test]
#[ignore]
fn zoo_rules_are_locally_sound_everywhere() {
    let (zoo, per_rule) = all_sites();
    let lib = standard_library();
    let graph_by_name = |n: &str| &zoo.iter().find(|(name, _)| *name == n).unwrap().1;
    let mut failures = Vec::new();
    for (ri, sites) in &per_rule {
        let rule = lib.rules[*ri].as_ref();
        for (idx, site) in sites.iter().enumerate() {
            if let Err(e) = check_site(rule, graph_by_name(site.graph_name), site, idx) {
                failures.push(e);
            }
        }
    }
    assert!(failures.is_empty(), "unsound rewrites:\n{}", failures.join("\n"));
}
