//! Tier-1 concurrency battery for the async actor/learner pipeline's
//! determinism contract: for every (env count, stage-thread count) in
//! the sweep, `train_async == train_reference == replay_trace(own
//! trace)` — final params compared bit-for-bit — plus torn-trace and
//! partial-batch recovery (typed errors, never a silent shorter run),
//! and the crash-safety contract: interrupt at any round boundary +
//! `--resume` reproduces the uninterrupted run bit-for-bit, for the
//! synchronous engine and the threaded pipeline alike.

use rlflow::config::RunConfig;
use rlflow::coordinator::{
    replay_trace, train_async, train_reference, AsyncOutcome, AsyncTrainCfg, Edge,
    ScheduleTrace,
};
use rlflow::graph::{GraphBuilder, PadMode};
use rlflow::runtime::{Backend, HostBackend, HostConfig};
use rlflow::xfer::library::standard_library;

/// Small host dimensions sized for the tiny test graph (mirrors
/// `tests/host_backend.rs`); the xfer slot space still matches the real
/// rule library so the env mapping is exact.
fn tiny_config() -> HostConfig {
    HostConfig {
        max_nodes: 48,
        node_feats: 32,
        gnn_hidden: 12,
        latent: 8,
        rnn_hidden: 12,
        mdn_k: 2,
        act_emb: 4,
        ctrl_hidden: 16,
        n_xfers1: standard_library().len() + 1,
        max_locs: 200,
        b_dream: 4,
        b_wm: 4,
        seq_len: 4,
        b_ppo: 16,
        b_enc: 4,
        kernels: rlflow::runtime::KernelCfg::default(),
    }
}

fn factory() -> anyhow::Result<Box<dyn Backend>> {
    Ok(Box::new(HostBackend::with_config(tiny_config())))
}

fn small_graph() -> rlflow::graph::Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 16, 16]);
    let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
    let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
    let r = b.relu(c2).unwrap();
    let _ = b.maxpool(r, 2, 2).unwrap();
    b.finish()
}

fn tiny_run_config(envs: usize) -> RunConfig {
    let mut cfg = RunConfig::smoke();
    cfg.backend = "host".into();
    cfg.envs = envs;
    cfg.collect_episodes = 8;
    cfg.ae_steps = 2;
    cfg.wm.total_steps = 2;
    cfg.dream_epochs = 1;
    cfg.dream_horizon = 3;
    cfg.ppo.epochs = 1;
    cfg.eval_episodes = 1;
    cfg.env.max_steps = 4;
    cfg
}

fn acfg(stage_threads: usize) -> AsyncTrainCfg {
    AsyncTrainCfg { rounds: 2, stage_threads, staging_cap: 2, jitter: None }
}

/// Bit-exact f32 vector equality (`==` would treat -0.0 == 0.0 and hide
/// NaN drift).
fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_outcomes_identical(a: &AsyncOutcome, b: &AsyncOutcome, what: &str) {
    assert_eq!(bits(&a.gnn.theta), bits(&b.gnn.theta), "{what}: gnn params differ");
    assert_eq!(bits(&a.wm.theta), bits(&b.wm.theta), "{what}: wm params differ");
    assert_eq!(bits(&a.ctrl.theta), bits(&b.ctrl.theta), "{what}: ctrl params differ");
    assert_eq!(bits(&a.gnn.m), bits(&b.gnn.m), "{what}: gnn Adam state differs");
    assert_eq!(bits(&a.ctrl.v), bits(&b.ctrl.v), "{what}: ctrl Adam state differs");
    assert_eq!(bits(&a.ae_losses), bits(&b.ae_losses), "{what}: AE loss curves differ");
    assert_eq!(bits(&a.dream_curve), bits(&b.dream_curve), "{what}: dream curves differ");
    assert_eq!(a.evals.len(), b.evals.len(), "{what}: eval round counts differ");
    for (ra, rb) in a.evals.iter().zip(&b.evals) {
        let sa: Vec<u64> =
            ra.results.iter().map(|r| r.best_improvement_pct.to_bits()).collect();
        let sb: Vec<u64> =
            rb.results.iter().map(|r| r.best_improvement_pct.to_bits()).collect();
        assert_eq!(sa, sb, "{what}: eval scores differ in round {}", ra.round);
    }
}

/// The property sweep: every (envs, stage_threads) combination matches
/// the synchronous reference bit-for-bit, its canonical trace equals the
/// reference schedule's, and replaying its own trace reproduces it.
#[test]
fn async_reference_and_replay_agree_across_the_sweep() {
    let graph = small_graph();
    for envs in [1usize, 4, 8] {
        let cfg = tiny_run_config(envs);
        let reference = train_reference(&factory, &cfg, &acfg(1), &graph).unwrap();
        for stage_threads in [1usize, 2, 4] {
            let what = format!("envs={envs} stage_threads={stage_threads}");
            let out = train_async(&factory, &cfg, &acfg(stage_threads), &graph).unwrap();
            assert_outcomes_identical(&out, &reference, &format!("{what} vs reference"));
            assert_eq!(
                out.trace.canonical(),
                reference.trace.canonical(),
                "{what}: canonical traces diverge — the schedules carried different data"
            );
            let replayed =
                replay_trace(&factory, &cfg, &acfg(stage_threads), &graph, &out.trace).unwrap();
            assert_outcomes_identical(&replayed, &out, &format!("{what} vs own-trace replay"));
        }
    }
}

/// The recorded trace is complete: every edge carries one event per
/// round (staging/ae additionally one per shard), and the header matches
/// the run.
#[test]
fn recorded_trace_is_complete_and_well_formed() {
    let graph = small_graph();
    let cfg = tiny_run_config(4);
    let out = train_async(&factory, &cfg, &acfg(2), &graph).unwrap();
    let t = &out.trace;
    assert_eq!((t.seed, t.envs, t.rounds), (cfg.seed, 4, 2));
    assert_eq!(t.events_on(Edge::Staging).count(), 8, "2 rounds x 4 shards");
    assert_eq!(t.events_on(Edge::AeIn).count(), 8);
    for edge in [Edge::EncIn, Edge::WmIn, Edge::DreamIn, Edge::EvalIn] {
        assert_eq!(t.events_on(edge).count(), 2, "one {} handoff per round", edge.as_str());
    }
    // Round trip through the on-disk format is lossless.
    assert_eq!(&ScheduleTrace::from_text(&t.to_text()).unwrap(), t);
}

/// Torn-trace recovery: a truncated trace file is a typed load error,
/// and a trace missing a staging block is a typed "partial batch" replay
/// error — neither can silently replay a shorter schedule.
#[test]
fn torn_traces_and_partial_batches_are_typed_errors() {
    let graph = small_graph();
    let cfg = tiny_run_config(4);
    let out = train_async(&factory, &cfg, &acfg(2), &graph).unwrap();

    // Tear the file mid-way: parsing must refuse it.
    let text = out.trace.to_text();
    let torn: String = text.lines().take(5).map(|l| format!("{l}\n")).collect();
    let err = ScheduleTrace::from_text(&torn).unwrap_err();
    assert!(err.to_string().contains("torn trace"), "got: {err}");

    // Drop one shard's staging block (a partial batch): replay must
    // refuse before training anything.
    let mut partial = out.trace.clone();
    let victim = partial
        .events
        .iter()
        .position(|h| h.edge == Edge::Staging && h.round == 1 && h.shard == 2)
        .expect("sweep trace has the (1, 2) staging block");
    partial.events.remove(victim);
    let err = replay_trace(&factory, &cfg, &acfg(2), &graph, &partial).unwrap_err();
    assert!(err.to_string().contains("partial batch"), "got: {err}");

    // A trace recorded under a different run identity must be refused.
    let mut foreign = out.trace.clone();
    foreign.seed ^= 1;
    let err = replay_trace(&factory, &cfg, &acfg(2), &graph, &foreign).unwrap_err();
    assert!(err.to_string().contains("does not match this run"), "got: {err}");
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("rlflow-ckpt-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Crash-safe resume, synchronous engine: `every: 1` writes a checkpoint
/// at every round boundary; resuming from each of them — including the
/// final boundary, where no rounds remain — reproduces the uninterrupted
/// run bit-for-bit, and checkpointing itself never perturbs results. A
/// checkpoint from a different run identity is refused.
#[test]
fn sync_resume_from_every_boundary_is_bit_identical() {
    use rlflow::coordinator::{train_reference_ckpt, Checkpoint, CheckpointCfg};
    let graph = small_graph();
    let cfg = tiny_run_config(4);
    let reference = train_reference(&factory, &cfg, &acfg(1), &graph).unwrap();

    let dir = ckpt_dir("sync");
    let ck = CheckpointCfg { dir: dir.clone(), every: 1 };
    let full = train_reference_ckpt(&factory, &cfg, &acfg(1), &graph, Some(&ck), None).unwrap();
    assert_outcomes_identical(&full, &reference, "checkpointing perturbed the run");

    for boundary in [1u32, 2] {
        let cp = Checkpoint::load(&dir.join(format!("ckpt-{boundary:05}.rlck"))).unwrap();
        assert_eq!(cp.next_round, boundary);
        let resumed =
            train_reference_ckpt(&factory, &cfg, &acfg(1), &graph, None, Some(cp)).unwrap();
        assert_outcomes_identical(
            &resumed,
            &reference,
            &format!("resume from boundary {boundary}"),
        );
    }

    // A checkpoint never resumes a run with a different identity.
    let mut other = cfg.clone();
    other.seed ^= 1;
    let cp = Checkpoint::load(&dir.join("ckpt-00001.rlck")).unwrap();
    let err = train_reference_ckpt(&factory, &other, &acfg(1), &graph, None, Some(cp)).unwrap_err();
    assert!(err.to_string().contains("seed"), "got: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash-safe resume, async engine: the stage threads assemble the same
/// checkpoint state the synchronous engine snapshots; interrupting at the
/// first round boundary and resuming matches the uninterrupted reference
/// bit-for-bit at 1 and 4 stage threads, and an async-written checkpoint
/// also resumes the synchronous engine (the format is engine-agnostic).
#[test]
fn async_resume_matches_uninterrupted_run() {
    use rlflow::coordinator::{train_async_ckpt, train_reference_ckpt, Checkpoint, CheckpointCfg};
    let graph = small_graph();
    let cfg = tiny_run_config(4);
    let reference = train_reference(&factory, &cfg, &acfg(1), &graph).unwrap();

    for stage_threads in [1usize, 4] {
        let dir = ckpt_dir(&format!("async-{stage_threads}"));
        let ck = CheckpointCfg { dir: dir.clone(), every: 1 };
        let what = format!("{stage_threads} stage threads");
        let full = train_async_ckpt(&factory, &cfg, &acfg(stage_threads), &graph, Some(&ck), None)
            .unwrap();
        assert_outcomes_identical(&full, &reference, &format!("{what}: checkpointing perturbed"));

        let cp = Checkpoint::load(&dir.join("ckpt-00001.rlck")).unwrap();
        let resumed =
            train_async_ckpt(&factory, &cfg, &acfg(stage_threads), &graph, None, Some(cp))
                .unwrap();
        assert_outcomes_identical(&resumed, &reference, &format!("{what}: async resume"));

        if stage_threads == 4 {
            let cp = Checkpoint::load(&dir.join("ckpt-00001.rlck")).unwrap();
            let cross =
                train_reference_ckpt(&factory, &cfg, &acfg(1), &graph, None, Some(cp)).unwrap();
            assert_outcomes_identical(&cross, &reference, "sync resume of an async checkpoint");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
