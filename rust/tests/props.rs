//! Property-based tests over the core invariants, using seeded randomised
//! generation (the offline build has no proptest crate; `rlflow::util::Rng`
//! provides deterministic, replayable exploration — failures print the
//! offending seed).

use std::collections::HashMap;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig};
use rlflow::graph::{canonical_hash, Activation, Graph, GraphBuilder, OpKind, PadMode, PortRef};
use rlflow::interp::semantically_equal;
use rlflow::util::Rng;
use rlflow::xfer::library::standard_library;
use rlflow::xfer::{apply_rule, RuleSet};

/// Random small-but-varied graph: conv/linear/attention fragments glued by
/// elementwise ops. Always valid by construction.
fn random_graph(rng: &mut Rng) -> Graph {
    let mut b = GraphBuilder::new();
    match rng.below(3) {
        0 => {
            // CNN-ish.
            let x = b.input(&[1, 3, 8, 8]);
            let mut cur = x;
            for _ in 0..(1 + rng.below(3)) {
                cur = match rng.below(4) {
                    0 => b.conv_bn_relu(cur, 4 + rng.below(4), 3, 1, PadMode::Same).unwrap(),
                    1 => {
                        let c = b.conv(cur, 4 + rng.below(4), 1, 1, PadMode::Same).unwrap();
                        b.relu(c).unwrap()
                    }
                    2 => b.maxpool(cur, 2, 1).unwrap(),
                    _ => {
                        let c1 = b.conv(cur, 4, 3, 1, PadMode::Same).unwrap();
                        let c2 = b.conv(cur, 4, 3, 1, PadMode::Same).unwrap();
                        b.concat(1, &[c1, c2]).unwrap()
                    }
                };
            }
        }
        1 => {
            // Transformer-ish.
            let x = b.input(&[1, 4, 16]);
            let mut cur = x;
            for _ in 0..(1 + rng.below(2)) {
                cur = b.transformer_encoder(cur, 2, 2).unwrap();
            }
        }
        _ => {
            // Elementwise algebra.
            let x = b.input(&[2, 8]);
            let y = b.input(&[2, 8]);
            let mut cur = b.add(x, y).unwrap();
            for _ in 0..(1 + rng.below(4)) {
                cur = match rng.below(4) {
                    0 => b.add(cur, x).unwrap(),
                    1 => b.relu(cur).unwrap(),
                    2 => b.linear(cur, 8, Activation::None).unwrap(),
                    _ => b.op(OpKind::Tanh, &[cur]).unwrap(),
                };
            }
        }
    }
    b.finish()
}

#[test]
fn prop_rule_application_preserves_semantics() {
    // For random graphs and random applicable rules, the rewritten graph
    // computes the same function (interpreter, random inputs).
    let lib = standard_library();
    let mut rng = Rng::new(0xFEED);
    let mut applications = 0;
    for trial in 0..40 {
        let g = random_graph(&mut rng);
        let applicable: Vec<(usize, Vec<_>)> = (0..lib.len())
            .map(|i| (i, lib.get(i).unwrap().find(&g)))
            .filter(|(_, locs)| !locs.is_empty())
            .collect();
        if applicable.is_empty() {
            continue;
        }
        let (ri, locs) = &applicable[rng.below(applicable.len())];
        let rule = lib.get(*ri).unwrap();
        let loc = &locs[rng.below(locs.len())];
        let mut g2 = g.clone();
        apply_rule(&mut g2, rule, loc).unwrap_or_else(|e| panic!("trial {trial}: {} failed: {e}", rule.name()));
        g2.validate().unwrap();
        assert!(
            semantically_equal(&g, &g2, 2, 0x1234 + trial as u64, 2e-3).unwrap(),
            "trial {trial}: rule {} changed semantics at {:?}",
            rule.name(),
            loc
        );
        applications += 1;
    }
    assert!(applications > 20, "too few rule applications exercised: {applications}");
}

#[test]
fn prop_hash_invariant_under_source_reordering() {
    // Building the same structure with sources declared in different order
    // must hash identically (tensor-renaming invariance, Fig. 3a).
    let build = |weights_first: bool| {
        let mut g = Graph::new();
        let (x, w) = if weights_first {
            let w = g.add_source(OpKind::Weight, rlflow::graph::TensorDesc::f32(&[8, 4]));
            let x = g.add_source(OpKind::Input, rlflow::graph::TensorDesc::f32(&[2, 8]));
            (x, w)
        } else {
            let x = g.add_source(OpKind::Input, rlflow::graph::TensorDesc::f32(&[2, 8]));
            let w = g.add_source(OpKind::Weight, rlflow::graph::TensorDesc::f32(&[8, 4]));
            (x, w)
        };
        let mm = g
            .add(
                OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
                &[PortRef::of(x), PortRef::of(w)],
            )
            .unwrap();
        g.add(OpKind::Relu, &[PortRef::of(mm)]).unwrap();
        g
    };
    assert_eq!(canonical_hash(&build(true)), canonical_hash(&build(false)));
}

#[test]
fn prop_hash_stable_under_rule_round_trips() {
    // fuse + unfuse pairs must return to the original canonical hash.
    let lib = standard_library();
    let pairs = [
        ("fuse_conv_relu", "unfuse_conv_relu"),
        ("fuse_add_ln", "unfuse_add_ln"),
        ("fuse_matmul_bias", "unfuse_linear"),
    ];
    let mut rng = Rng::new(0xABCD);
    for trial in 0..30 {
        let g = random_graph(&mut rng);
        for (fwd, bwd) in pairs {
            let f = lib.get(lib.index_of(fwd).unwrap()).unwrap();
            let b = lib.get(lib.index_of(bwd).unwrap()).unwrap();
            let locs = f.find(&g);
            if locs.is_empty() {
                continue;
            }
            let mut g2 = g.clone();
            apply_rule(&mut g2, f, &locs[0]).unwrap();
            let locs_b = b.find(&g2);
            assert!(!locs_b.is_empty(), "trial {trial}: {bwd} can't invert {fwd}");
            // Find the inverse location restoring the hash.
            let restored = locs_b.iter().any(|lb| {
                let mut g3 = g2.clone();
                apply_rule(&mut g3, b, lb).is_ok() && canonical_hash(&g3) == canonical_hash(&g)
            });
            assert!(restored, "trial {trial}: {fwd}/{bwd} round trip failed");
        }
    }
}

#[test]
fn prop_env_masks_always_admit_action() {
    // Whatever sequence of valid actions is taken, the mask always admits
    // at least the NO-OP, and every masked-valid action succeeds.
    let lib = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let mut rng = Rng::new(0x5EED);
    for _ in 0..15 {
        let g = random_graph(&mut rng);
        let mut env = Env::new(g, &lib, &cost, EnvConfig { max_steps: 10, ..Default::default() });
        loop {
            let obs = env.observe();
            assert!(obs.xfer_mask[env.noop_action()], "NO-OP must stay valid");
            let valid: Vec<usize> = (0..lib.len()).filter(|&i| obs.xfer_mask[i]).collect();
            if valid.is_empty() || rng.f32() < 0.2 {
                let res = env.step((env.noop_action(), 0));
                assert!(res.done);
                break;
            }
            let x = valid[rng.below(valid.len())];
            assert!(obs.location_counts[x] > 0, "masked-valid xfer has no locations");
            let l = rng.below(obs.location_counts[x]);
            let res = env.step((x, l));
            assert!(res.info.valid, "masked-valid action failed to apply");
            if res.done {
                break;
            }
        }
    }
}

#[test]
fn prop_cost_positive_and_fusion_never_hurts_launches() {
    let lib = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let fusions = ["fuse_conv_relu", "fuse_add_ln", "fuse_add_add", "fuse_matmul_bias"];
    let mut rng = Rng::new(0xC057);
    for _ in 0..25 {
        let g = random_graph(&mut rng);
        let before = cost.graph_cost(&g);
        assert!(before.runtime_ms > 0.0);
        assert!(before.peak_bytes >= 0.0);
        for name in fusions {
            let rule = lib.get(lib.index_of(name).unwrap()).unwrap();
            for loc in rule.find(&g).into_iter().take(2) {
                let mut g2 = g.clone();
                apply_rule(&mut g2, rule, &loc).unwrap();
                let after = cost.graph_cost(&g2);
                assert!(
                    after.launches <= before.launches,
                    "{name} increased launches {} -> {}",
                    before.launches,
                    after.launches
                );
            }
        }
    }
}

#[test]
fn prop_topo_order_valid_after_arbitrary_rule_sequences() {
    let lib = standard_library();
    let mut rng = Rng::new(0x70B0);
    for _ in 0..15 {
        let mut g = random_graph(&mut rng);
        for _ in 0..6 {
            let applicable: Vec<(usize, Vec<_>)> = (0..lib.len())
                .map(|i| (i, lib.get(i).unwrap().find(&g)))
                .filter(|(_, l)| !l.is_empty())
                .collect();
            if applicable.is_empty() {
                break;
            }
            let (ri, locs) = &applicable[rng.below(applicable.len())];
            let loc = &locs[rng.below(locs.len())];
            apply_rule(&mut g, lib.get(*ri).unwrap(), loc).unwrap();
            // Full structural validation after every rewrite.
            g.validate().unwrap();
            let order = g.topo_order().unwrap();
            let pos: HashMap<_, _> = order.iter().enumerate().map(|(i, &id)| (id, i)).collect();
            for id in g.live_ids() {
                for inp in &g.node(id).inputs {
                    assert!(pos[&inp.node] < pos[&id], "topo violation after rewrite");
                }
            }
        }
    }
}

#[test]
fn prop_onnx_round_trip_random_graphs() {
    let mut rng = Rng::new(0x0881);
    for _ in 0..20 {
        let g = random_graph(&mut rng);
        let json = rlflow::graph::onnx::export(&g, "prop").unwrap();
        let g2 = rlflow::graph::onnx::import(&json).unwrap();
        assert_eq!(canonical_hash(&g), canonical_hash(&g2));
        assert_eq!(g.n_ops(), g2.n_ops());
    }
}

/// One convolutional + one transformer zoo graph: enough structural
/// diversity for the search-equivalence properties while keeping the
/// debug-build test walltime sane (debug asserts validate every candidate).
fn zoo_subset() -> Vec<(rlflow::zoo::GraphInfo, Graph)> {
    rlflow::zoo::all()
        .into_iter()
        .filter(|(i, _)| i.name == "SqueezeNet1.1" || i.name == "BERT-Base")
        .collect()
}

#[test]
fn prop_parallel_search_bit_identical_to_sequential_on_zoo() {
    // The parallel memoised engine merges worker output in canonical order,
    // so `threads: 1` (the sequential reference) and any worker count must
    // produce the same optimisation to the bit: same final cost, same final
    // graph (canonical hash), same explored count, same step log.
    let lib = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    for (info, g) in zoo_subset() {
        let cfg = |threads| rlflow::search::TasoConfig {
            depth: 3,
            beam: 3,
            threads,
            ..Default::default()
        };
        let (sg, slog) = rlflow::search::taso_optimise(&g, &lib, &cost, &cfg(1));
        let (pg, plog) = rlflow::search::taso_optimise(&g, &lib, &cost, &cfg(4));
        assert_eq!(
            slog.final_ms.to_bits(),
            plog.final_ms.to_bits(),
            "{}: parallel taso diverged from sequential",
            info.name
        );
        assert_eq!(canonical_hash(&sg), canonical_hash(&pg), "{}", info.name);
        assert_eq!(slog.graphs_explored, plog.graphs_explored, "{}", info.name);
        assert_eq!(slog.steps, plog.steps, "{}", info.name);

        let (sg, slog) = rlflow::search::greedy_optimise_threads(&g, &lib, &cost, 8, 1);
        let (pg, plog) = rlflow::search::greedy_optimise_threads(&g, &lib, &cost, 8, 4);
        assert_eq!(
            slog.final_ms.to_bits(),
            plog.final_ms.to_bits(),
            "{}: parallel greedy diverged from sequential",
            info.name
        );
        assert_eq!(canonical_hash(&sg), canonical_hash(&pg), "{}", info.name);
        assert_eq!(slog.graphs_explored, plog.graphs_explored, "{}", info.name);
        assert_eq!(slog.steps, plog.steps, "{}", info.name);
    }
}

#[test]
fn prop_search_engine_matches_reference_oracle() {
    // Memoisation + delta costing must not change what the search finds.
    // Near-ties between candidates may resolve differently (delta vs full
    // recompute differ in the last f64 bits, and exact ties across
    // differently-derived graphs are ordering-sensitive), so the pinned
    // agreement is relative cost, not bitwise equality — bitwise equality
    // is pinned against the `threads: 1` run in the test above.
    let lib = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    for (info, g) in zoo_subset() {
        let cfg = rlflow::search::TasoConfig { depth: 3, beam: 3, ..Default::default() };
        let (_, elog) = rlflow::search::taso_optimise(&g, &lib, &cost, &cfg);
        let (_, rlog) = rlflow::search::taso_optimise_reference(&g, &lib, &cost, &cfg);
        let rel = (elog.final_ms - rlog.final_ms).abs() / rlog.final_ms.max(1e-12);
        assert!(
            rel < 1e-6,
            "{}: engine {} vs reference {}",
            info.name,
            elog.final_ms,
            rlog.final_ms
        );
        assert_eq!(elog.initial_ms.to_bits(), rlog.initial_ms.to_bits(), "{}", info.name);
    }
}

#[test]
fn prop_delta_cost_agrees_with_full_recompute() {
    // Along random rule-application walks, the incremental cost must track
    // the full oracle to 1e-9 at every step — including chained drift.
    let lib = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let mut rng = Rng::new(0xDE17A);
    let mut checked = 0;
    for _ in 0..20 {
        let mut g = random_graph(&mut rng);
        let mut tracked_ms = cost.graph_runtime_ms(&g);
        for _ in 0..6 {
            let applicable: Vec<(usize, Vec<_>)> = (0..lib.len())
                .map(|i| (i, lib.get(i).unwrap().find(&g)))
                .filter(|(_, l)| !l.is_empty())
                .collect();
            if applicable.is_empty() {
                break;
            }
            let (ri, locs) = &applicable[rng.below(applicable.len())];
            let loc = &locs[rng.below(locs.len())];
            let mut g2 = g.clone();
            let report = apply_rule(&mut g2, lib.get(*ri).unwrap(), loc).unwrap();
            let delta = cost.delta_runtime_ms(&g, tracked_ms, &g2, &report);
            let full = cost.graph_runtime_ms(&g2);
            assert!(
                (delta - full).abs() < 1e-9,
                "delta {delta} vs full {full} after {}",
                lib.get(*ri).unwrap().name()
            );
            g = g2;
            tracked_ms = delta; // chain the incremental path on purpose
            checked += 1;
        }
    }
    assert!(checked > 40, "too few delta checks exercised: {checked}");
}

#[test]
fn prop_search_never_worse_than_input() {
    let lib: RuleSet = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let mut rng = Rng::new(0x5EA2);
    for _ in 0..10 {
        let g = random_graph(&mut rng);
        let base = cost.graph_runtime_ms(&g);
        let (og, glog) = rlflow::search::greedy_optimise(&g, &lib, &cost, 20);
        assert!(glog.final_ms <= base + 1e-9);
        og.validate().unwrap();
        let (tg, tlog) = rlflow::search::taso_optimise(
            &g,
            &lib,
            &cost,
            &rlflow::search::TasoConfig { depth: 4, beam: 4, ..Default::default() },
        );
        assert!(tlog.final_ms <= base + 1e-9);
        tg.validate().unwrap();
    }
}
