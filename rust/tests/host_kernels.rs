//! Tier-1 coverage of the host kernel layer: blocked/threaded V1 kernels
//! bit-identical to the seed scalar reference at every thread count —
//! from the raw GEMMs up through whole programs and the full training
//! loop — the V2 lane-tiled order bit-identical across thread counts
//! and lane widths, the V1↔V2 toleranced parity oracle (GEMMs and full
//! train steps), plus the Workspace zero-alloc steady state and the
//! batched-exec equivalences (`exec_batch`, arbitrary-width
//! `act_batch`/`WorldModel::step`).

use rlflow::agent::{Action, ObsBatch, PolicyNet};
use rlflow::config::RunConfig;
use rlflow::coordinator::Pipeline;
use rlflow::graph::{GraphBuilder, PadMode};
use rlflow::runtime::{
    Backend, HostBackend, HostConfig, KernelCfg, ParamStore, TensorView,
};
use rlflow::util::Rng;
use rlflow::wm::WorldModel;
use rlflow::xfer::library::standard_library;

fn tiny_config(kernels: KernelCfg) -> HostConfig {
    HostConfig {
        max_nodes: 48,
        node_feats: 32,
        gnn_hidden: 12,
        latent: 8,
        rnn_hidden: 12,
        mdn_k: 2,
        act_emb: 4,
        ctrl_hidden: 16,
        n_xfers1: standard_library().len() + 1,
        max_locs: 200,
        b_dream: 4,
        b_wm: 4,
        seq_len: 4,
        b_ppo: 16,
        b_enc: 4,
        kernels,
    }
}

fn small_graph() -> rlflow::graph::Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 16, 16]);
    let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
    let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
    let r = b.relu(c2).unwrap();
    let _ = b.maxpool(r, 2, 2).unwrap();
    b.finish()
}

fn tiny_run_config() -> RunConfig {
    let mut cfg = RunConfig::smoke();
    cfg.backend = "host".into();
    cfg.collect_episodes = 3;
    cfg.ae_steps = 2;
    cfg.wm.total_steps = 3;
    cfg.dream_epochs = 2;
    cfg.dream_horizon = 3;
    cfg.ppo.epochs = 2;
    cfg.env.max_steps = 5;
    cfg
}

/// The acceptance pin: the complete training loop produces bit-identical
/// parameters on the seed scalar kernels and on the blocked kernels at
/// thread counts 1, 2 and 8.
#[test]
fn full_training_loop_is_bit_identical_across_kernel_modes_and_threads() {
    let run = |kernels: KernelCfg| {
        let backend = HostBackend::with_config(tiny_config(kernels));
        let cfg = tiny_run_config();
        let pipe = Pipeline::new(&backend).unwrap();
        let agent =
            rlflow::experiments::train_model_based(&pipe, &cfg, &small_graph(), cfg.seed).unwrap();
        (agent.gnn.theta, agent.wm.theta, agent.ctrl.theta)
    };
    let seed = run(KernelCfg::reference());
    for threads in [1, 2, 8] {
        let got = run(KernelCfg::blocked(threads));
        assert_eq!(seed.0, got.0, "gnn theta drifted at {threads} threads");
        assert_eq!(seed.1, got.1, "wm theta drifted at {threads} threads");
        assert_eq!(seed.2, got.2, "ctrl theta drifted at {threads} threads");
    }
}

/// Finite-difference gradient check through the fused linear+tanh path:
/// loss = Σ tanh(x w + b)², dw assembled with the blocked kernels.
#[test]
fn fused_forward_backward_matches_finite_difference() {
    use rlflow::runtime::host::kernels::{acc_xt_dy, linear_into, tanh_backward_inplace, Act};
    let kc = KernelCfg::blocked(4);
    let (m, k, n) = (4, 5, 3);
    let mut rng = Rng::new(17);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() * 0.7).collect();
    let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let forward = |w: &[f32], y: &mut Vec<f32>| {
        y.resize(m * n, 0.0);
        linear_into(&kc, &x, w, Some(&b), m, k, n, Act::Tanh, y);
    };
    let mut y = Vec::new();
    forward(&w, &mut y);
    // dL/dy = 2y, through the tanh epilogue, then dw = xᵀ dpre.
    let mut dpre: Vec<f32> = y.iter().map(|v| 2.0 * v).collect();
    tanh_backward_inplace(&mut dpre, &y);
    let mut dw = vec![0.0f32; k * n];
    acc_xt_dy(&kc, &x, &dpre, m, k, n, &mut dw);
    let loss = |w: &[f32]| -> f32 {
        let mut y = Vec::new();
        forward(w, &mut y);
        y.iter().map(|v| v * v).sum()
    };
    let eps = 1e-3f32;
    for i in 0..w.len() {
        let orig = w[i];
        w[i] = orig + eps;
        let lp = loss(&w);
        w[i] = orig - eps;
        let lm = loss(&w);
        w[i] = orig;
        let num = (lp - lm) / (2.0 * eps);
        assert!(
            (num - dw[i]).abs() < 2e-2,
            "dw[{i}]: analytic {} vs numeric {}",
            dw[i],
            num
        );
    }
}

/// `exec_batch` returns exactly what per-call `exec` returns.
#[test]
fn exec_batch_equals_sequential_exec() {
    let backend = HostBackend::with_config(tiny_config(KernelCfg::default()));
    let (z, r) = (backend.hp("LATENT").unwrap(), backend.hp("RNN_HIDDEN").unwrap());
    let b = backend.hp("B_DREAM").unwrap();
    let ctrl = ParamStore::init(&backend, "ctrl", 3).unwrap();
    let n = ctrl.theta.len();
    let mut rng = Rng::new(5);
    let zs: Vec<Vec<f32>> =
        (0..3).map(|_| (0..b * z).map(|_| rng.normal() * 0.3).collect()).collect();
    let hs: Vec<Vec<f32>> =
        (0..3).map(|_| (0..b * r).map(|_| rng.normal() * 0.2).collect()).collect();
    let calls: Vec<Vec<TensorView>> = zs
        .iter()
        .zip(&hs)
        .map(|(zb, hb)| {
            vec![
                TensorView::f32(&ctrl.theta, &[n]),
                TensorView::f32(zb, &[b, z]),
                TensorView::f32(hb, &[b, r]),
            ]
        })
        .collect();
    let batched = backend.exec_batch("ctrl_policy_b", &calls).unwrap();
    for (args, out) in calls.iter().zip(&batched) {
        let single = backend.exec("ctrl_policy_b", args).unwrap();
        assert_eq!(single.len(), out.len());
        for (a, bb) in single.iter().zip(out) {
            assert_eq!(a.data, bb.data);
        }
    }
    // Per-program stats counted every batched call.
    assert!(backend.stats()["ctrl_policy_b"].calls >= 6);
}

/// Arbitrary-width `act_batch` (chunk + pad through `ctrl_policy_b`)
/// yields bit-identical per-row results to one-row calls.
#[test]
fn act_batch_arbitrary_width_matches_per_row_calls() {
    let backend = HostBackend::with_config(tiny_config(KernelCfg::default()));
    let policy = PolicyNet::new(&backend).unwrap();
    let ctrl = ParamStore::init(&backend, "ctrl", 1).unwrap();
    let d = policy.dims;
    // Width 6 = one full B_DREAM chunk + one padded chunk (B_DREAM = 4).
    let b = 6;
    let mut rng = Rng::new(9);
    let z: Vec<f32> = (0..b * d.zdim).map(|_| rng.normal() * 0.4).collect();
    let h: Vec<f32> = (0..b * d.rdim).map(|_| rng.normal() * 0.2).collect();
    let mut xmask = vec![1.0f32; b * d.x1];
    xmask[d.x1..2 * d.x1].fill(0.0); // one all-masked row exercises the NO-OP fallback
    let mut seed_rng = Rng::new(77);
    let mut rngs: Vec<Rng> = (0..b).map(|i| seed_rng.fork(i as u64)).collect();
    let batched = policy
        .act_rows(
            &ctrl,
            &ObsBatch { z: &z, h: &h, xmask: &xmask },
            |_, _| vec![true; d.max_locs],
            &mut rngs.clone(),
            false,
        )
        .unwrap();
    for row in 0..b {
        let single = policy
            .act_batch(
                &ctrl,
                &ObsBatch {
                    z: &z[row * d.zdim..(row + 1) * d.zdim],
                    h: &h[row * d.rdim..(row + 1) * d.rdim],
                    xmask: &xmask[row * d.x1..(row + 1) * d.x1],
                },
                |_, _| vec![true; d.max_locs],
                &mut rngs[row],
                false,
            )
            .unwrap();
        assert_eq!(single[0].action, batched[row].action, "row {row} action diverged");
        assert_eq!(single[0].logp, batched[row].logp, "row {row} logp diverged");
        assert_eq!(single[0].value, batched[row].value, "row {row} value diverged");
    }
}

/// Arbitrary-width `WorldModel::step` (chunk + pad through `wm_step_b`)
/// yields bit-identical per-row results to `wm_step_1` calls.
#[test]
fn wm_step_arbitrary_width_matches_per_row_calls() {
    let backend = HostBackend::with_config(tiny_config(KernelCfg::default()));
    let world = WorldModel::new(&backend).unwrap();
    let wm = ParamStore::init(&backend, "wm", 2).unwrap();
    let d = world.dims;
    let b = 7; // not 1, not B_DREAM
    let mut rng = Rng::new(13);
    let z: Vec<f32> = (0..b * d.zdim).map(|_| rng.normal() * 0.5).collect();
    let h: Vec<f32> = (0..b * d.rdim).map(|_| rng.normal() * 0.2).collect();
    let c: Vec<f32> = (0..b * d.rdim).map(|_| rng.normal() * 0.2).collect();
    let actions: Vec<Action> =
        (0..b).map(|i| Action::new(i % (d.x1 - 1), i % 5)).collect();
    let batched = world.step(&wm, &z, &actions, &h, &c).unwrap();
    let zk = d.zdim * d.k;
    for row in 0..b {
        let single = world
            .step(
                &wm,
                &z[row * d.zdim..(row + 1) * d.zdim],
                &actions[row..row + 1],
                &h[row * d.rdim..(row + 1) * d.rdim],
                &c[row * d.rdim..(row + 1) * d.rdim],
            )
            .unwrap();
        assert_eq!(single.log_pi, batched.log_pi[row * zk..(row + 1) * zk]);
        assert_eq!(single.mu, batched.mu[row * zk..(row + 1) * zk]);
        assert_eq!(single.rewards[0], batched.rewards[row]);
        assert_eq!(single.h1, batched.h1[row * d.rdim..(row + 1) * d.rdim]);
        assert_eq!(single.c1, batched.c1[row * d.rdim..(row + 1) * d.rdim]);
    }
}

/// The zero-alloc acceptance pin: after one warm call per program, the
/// steady-state `exec_with_params`/`train_step` hot paths allocate no
/// scratch — every Workspace checkout is served from the free list, and
/// the per-program `ExecStats` counters prove it.
#[test]
fn steady_state_exec_allocates_no_scratch() {
    let backend = HostBackend::with_config(tiny_config(KernelCfg::default()));
    let (z, r) = (backend.hp("LATENT").unwrap(), backend.hp("RNN_HIDDEN").unwrap());
    let ctrl = ParamStore::init(&backend, "ctrl", 0).unwrap();
    let z1 = vec![0.3f32; z];
    let h1 = vec![0.1f32; r];
    let rest = [TensorView::f32(&z1, &[1, z]), TensorView::f32(&h1, &[1, r])];
    // Warm-up: first call populates the arena.
    backend.exec_with_params("ctrl_policy_1", &ctrl, &rest).unwrap();
    let warm = backend.stats()["ctrl_policy_1"];
    for _ in 0..5 {
        backend.exec_with_params("ctrl_policy_1", &ctrl, &rest).unwrap();
    }
    let now = backend.stats()["ctrl_policy_1"];
    assert_eq!(
        warm.alloc_bytes, now.alloc_bytes,
        "steady-state ctrl_policy_1 must allocate no scratch"
    );
    assert!(
        now.scratch_reuse > warm.scratch_reuse,
        "steady-state calls must reuse workspace buffers"
    );

    // Same property on the train hot path (in-place Adam absorb).
    let mut store = ParamStore::init(&backend, "ctrl", 4).unwrap();
    let b = backend.hp("B_PPO").unwrap();
    let (x1, locs) = (backend.hp("N_XFERS1").unwrap(), backend.hp("MAX_LOCS").unwrap());
    let zb = vec![0.2f32; b * z];
    let hb = vec![0.0f32; b * r];
    let act = vec![0i32; b * 2];
    let logp = vec![-1.0f32; b];
    let adv: Vec<f32> = (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let ret = vec![0.2f32; b];
    let xm = vec![1.0f32; b * x1];
    let lm = vec![1.0f32; b * locs];
    let rest: Vec<TensorView> = vec![
        TensorView::f32(&zb, &[b, z]),
        TensorView::f32(&hb, &[b, r]),
        TensorView::i32(&act, &[b, 2]),
        TensorView::f32(&logp, &[b]),
        TensorView::f32(&adv, &[b]),
        TensorView::f32(&ret, &[b]),
        TensorView::f32(&xm, &[b, x1]),
        TensorView::f32(&lm, &[b, locs]),
        TensorView::ScalarF32(1e-3),
        TensorView::ScalarF32(0.2),
        TensorView::ScalarF32(0.01),
    ];
    backend.train_step("ctrl_train", &mut store, &rest).unwrap();
    let warm = backend.stats()["ctrl_train"];
    let v0 = store.version;
    for _ in 0..4 {
        backend.train_step("ctrl_train", &mut store, &rest).unwrap();
    }
    let now = backend.stats()["ctrl_train"];
    assert_eq!(
        warm.alloc_bytes, now.alloc_bytes,
        "steady-state ctrl_train must allocate no scratch"
    );
    assert!(now.scratch_reuse > warm.scratch_reuse);
    assert_eq!(store.version, v0 + 4, "in-place train steps must bump the version");
    assert_eq!(store.t, 5.0, "t advances once per step");
}

/// The in-place host `train_step` produces exactly what the exec-path
/// value contract produces (theta absorb round trip).
#[test]
fn in_place_train_step_matches_exec_path() {
    let backend = HostBackend::with_config(tiny_config(KernelCfg::default()));
    let (n_lat, r) = (backend.hp("LATENT").unwrap(), backend.hp("RNN_HIDDEN").unwrap());
    let b = backend.hp("B_PPO").unwrap();
    let (x1, locs) = (backend.hp("N_XFERS1").unwrap(), backend.hp("MAX_LOCS").unwrap());
    let zb = vec![0.1f32; b * n_lat];
    let hb = vec![0.0f32; b * r];
    let act: Vec<i32> = (0..b).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect();
    let logp = vec![-1.2f32; b];
    let adv: Vec<f32> = (0..b).map(|i| (i as f32 % 3.0) - 1.0).collect();
    let ret = vec![0.1f32; b];
    let xm = vec![1.0f32; b * x1];
    let lm = vec![1.0f32; b * locs];
    let rest: Vec<TensorView> = vec![
        TensorView::f32(&zb, &[b, n_lat]),
        TensorView::f32(&hb, &[b, r]),
        TensorView::i32(&act, &[b, 2]),
        TensorView::f32(&logp, &[b]),
        TensorView::f32(&adv, &[b]),
        TensorView::f32(&ret, &[b]),
        TensorView::f32(&xm, &[b, x1]),
        TensorView::f32(&lm, &[b, locs]),
        TensorView::ScalarF32(3e-3),
        TensorView::ScalarF32(0.2),
        TensorView::ScalarF32(0.01),
    ];
    // In-place path.
    let mut fast = ParamStore::init(&backend, "ctrl", 11).unwrap();
    let fast_out = backend.train_step("ctrl_train", &mut fast, &rest).unwrap();
    // Exec path (the PJRT-style value contract).
    let mut slow = ParamStore::init(&backend, "ctrl", 11).unwrap();
    let mut args = slow.train_args();
    args.extend(rest.iter().cloned());
    let out = backend.exec("ctrl_train", &args).unwrap();
    drop(args);
    slow.absorb(&out).unwrap();
    assert_eq!(fast.theta, slow.theta, "in-place theta must match the exec path");
    assert_eq!(fast.m, slow.m);
    assert_eq!(fast.v, slow.v);
    assert_eq!(fast.t, slow.t);
    assert_eq!(fast_out[0].data, out[4].data, "loss outputs must line up (shifted by 4)");
    // Unknown/non-train programs are rejected.
    assert!(backend.train_step("ctrl_policy_1", &mut fast, &rest).is_err());
}

/// Elementwise toleranced comparison for the V1↔V2 parity oracle.
fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol,
            "{what}[{i}]: V1 {x} vs V2 {y} exceeds tol {tol}"
        );
    }
}

/// The V2 acceptance pin: the complete training loop produces
/// bit-identical parameters under `V2LaneTiled` for every (thread count,
/// lane width) combination — the order is fixed by the version, not by
/// the execution resources.
#[test]
fn v2_full_training_loop_is_bit_identical_across_threads_and_lane_widths() {
    let run = |kernels: KernelCfg| {
        let backend = HostBackend::with_config(tiny_config(kernels));
        let cfg = tiny_run_config();
        let pipe = Pipeline::new(&backend).unwrap();
        let agent =
            rlflow::experiments::train_model_based(&pipe, &cfg, &small_graph(), cfg.seed).unwrap();
        (agent.gnn.theta, agent.wm.theta, agent.ctrl.theta)
    };
    let base = run(KernelCfg::v2(1).with_lane_groups(1));
    for (threads, lanes) in [(2, 2), (8, 4), (3, 8)] {
        let got = run(KernelCfg::v2(threads).with_lane_groups(lanes));
        assert_eq!(base.0, got.0, "gnn theta drifted at threads={threads} lanes={lanes}");
        assert_eq!(base.1, got.1, "wm theta drifted at threads={threads} lanes={lanes}");
        assert_eq!(base.2, got.2, "ctrl theta drifted at threads={threads} lanes={lanes}");
    }
}

/// Property sweep over odd/remainder GEMM shapes × thread counts × lane
/// widths: V2 is bit-self-consistent everywhere, and V1↔V2 agree within
/// a relative-error bound on every kernel.
#[test]
fn v2_kernels_bit_consistent_and_parity_bounded() {
    use rlflow::runtime::host::kernels::{acc_xt_dy, dy_wt_acc, dy_wt_into, linear_into, Act};
    let shapes =
        [(1, 1, 1), (1, 9, 1), (2, 8, 16), (3, 5, 7), (5, 16, 9), (4, 33, 17), (33, 130, 21)];
    for &(m, k, n) in &shapes {
        let mut rng = Rng::new((m * 1_000 + k * 10 + n) as u64);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() * 0.7).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal() * 0.3).collect();
        let run = |kc: &KernelCfg| {
            let mut y = vec![0.0f32; m * n];
            linear_into(kc, &x, &w, Some(&bias), m, k, n, Act::Tanh, &mut y);
            let mut dw = vec![0.0f32; k * n];
            acc_xt_dy(kc, &x, &dy, m, k, n, &mut dw);
            let mut dx = vec![0.0f32; m * k];
            dy_wt_into(kc, &dy, &w, m, n, k, &mut dx);
            let mut dx2 = dx.clone();
            dy_wt_acc(kc, &dy, &w, m, n, k, &mut dx2);
            (y, dw, dx, dx2)
        };
        let base = run(&KernelCfg::v2(1).with_lane_groups(1));
        for threads in [1, 2, 3, 8] {
            for lanes in [1, 2, 4, 8] {
                let got = run(&KernelCfg::v2(threads).with_lane_groups(lanes));
                assert_eq!(
                    base, got,
                    "V2 bits drifted at {m}x{k}x{n} threads={threads} lanes={lanes}"
                );
            }
        }
        let v1 = run(&KernelCfg::blocked(2));
        assert_close(&v1.0, &base.0, 1e-5, 1e-4, "linear+tanh");
        assert_close(&v1.1, &base.1, 1e-5, 1e-4, "acc_xt_dy");
        assert_close(&v1.2, &base.2, 1e-5, 1e-4, "dy_wt_into");
        assert_close(&v1.3, &base.3, 1e-5, 1e-4, "dy_wt_acc");
    }
}

/// The cross-version oracle at full-program scale: several in-place
/// train steps per family (`gnn_ae_train`, `ctrl_train`, `wm_train`) on
/// identical inputs leave V1 and V2 parameters within a finite-
/// difference-style relative bound of each other.
#[test]
fn v1_v2_parity_holds_through_full_train_steps() {
    let run = |kernels: KernelCfg| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let backend = HostBackend::with_config(tiny_config(kernels));
        let z = backend.hp("LATENT").unwrap();
        let r = backend.hp("RNN_HIDDEN").unwrap();
        let (n, f) = (backend.hp("MAX_NODES").unwrap(), backend.hp("NODE_FEATS").unwrap());
        let (x1, locs) = (backend.hp("N_XFERS1").unwrap(), backend.hp("MAX_LOCS").unwrap());
        let mut rng = Rng::new(99);

        // gnn_ae_train over a sparse synthetic state batch.
        let be = backend.hp("B_ENC").unwrap();
        let mut gnn = ParamStore::init(&backend, "gnn", 7).unwrap();
        let theta0 = gnn.theta.clone();
        let feats: Vec<f32> = (0..be * n * f).map(|_| rng.normal() * 0.5).collect();
        let adj: Vec<f32> =
            (0..be * n * n).map(|i| if i % 13 == 0 { 1.0 } else { 0.0 }).collect();
        let mask: Vec<f32> = (0..be * n).map(|i| if i % n < 6 { 1.0 } else { 0.0 }).collect();
        let rest: Vec<TensorView> = vec![
            TensorView::f32(&feats, &[be, n, f]),
            TensorView::f32(&adj, &[be, n, n]),
            TensorView::f32(&mask, &[be, n]),
            TensorView::ScalarF32(1e-3),
        ];
        for _ in 0..3 {
            backend.train_step("gnn_ae_train", &mut gnn, &rest).unwrap();
        }
        assert_ne!(gnn.theta, theta0, "gnn params must move");

        // ctrl_train on a fixed synthetic PPO batch.
        let b = backend.hp("B_PPO").unwrap();
        let mut ctrl = ParamStore::init(&backend, "ctrl", 11).unwrap();
        let zb: Vec<f32> = (0..b * z).map(|_| rng.normal() * 0.4).collect();
        let hb: Vec<f32> = (0..b * r).map(|_| rng.normal() * 0.2).collect();
        let act: Vec<i32> =
            (0..b).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect();
        let logp: Vec<f32> = (0..b).map(|_| -1.0 + rng.normal() * 0.1).collect();
        let adv: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let ret: Vec<f32> = (0..b).map(|_| rng.normal() * 0.3).collect();
        let xm = vec![1.0f32; b * x1];
        let lm = vec![1.0f32; b * locs];
        let rest: Vec<TensorView> = vec![
            TensorView::f32(&zb, &[b, z]),
            TensorView::f32(&hb, &[b, r]),
            TensorView::i32(&act, &[b, 2]),
            TensorView::f32(&logp, &[b]),
            TensorView::f32(&adv, &[b]),
            TensorView::f32(&ret, &[b]),
            TensorView::f32(&xm, &[b, x1]),
            TensorView::f32(&lm, &[b, locs]),
            TensorView::ScalarF32(1e-3),
            TensorView::ScalarF32(0.2),
            TensorView::ScalarF32(0.01),
        ];
        for _ in 0..3 {
            backend.train_step("ctrl_train", &mut ctrl, &rest).unwrap();
        }

        // wm_train on a fixed synthetic sequence batch with invalid holes.
        let (bw, t) = (backend.hp("B_WM").unwrap(), backend.hp("SEQ_LEN").unwrap());
        let mut wm = ParamStore::init(&backend, "wm", 3).unwrap();
        let zs: Vec<f32> = (0..bw * t * z).map(|_| rng.normal() * 0.5).collect();
        let a: Vec<i32> =
            (0..bw * t).flat_map(|i| [(i % x1) as i32, (i % 7) as i32]).collect();
        let z_next: Vec<f32> = zs.iter().map(|v| 0.9 * v).collect();
        let rt: Vec<f32> = (0..bw * t).map(|_| rng.normal() * 0.1).collect();
        let xmt: Vec<f32> = (0..bw * t * x1).map(|i| (i % 2) as f32).collect();
        let dt = vec![0.0f32; bw * t];
        let valid: Vec<f32> =
            (0..bw * t).map(|i| if i % 5 == 4 { 0.0 } else { 1.0 }).collect();
        let rest: Vec<TensorView> = vec![
            TensorView::f32(&zs, &[bw, t, z]),
            TensorView::i32(&a, &[bw, t, 2]),
            TensorView::f32(&z_next, &[bw, t, z]),
            TensorView::f32(&rt, &[bw, t]),
            TensorView::f32(&xmt, &[bw, t, x1]),
            TensorView::f32(&dt, &[bw, t]),
            TensorView::f32(&valid, &[bw, t]),
            TensorView::ScalarF32(1e-3),
        ];
        for _ in 0..3 {
            backend.train_step("wm_train", &mut wm, &rest).unwrap();
        }

        (gnn.theta, ctrl.theta, wm.theta)
    };
    let v1 = run(KernelCfg::blocked(2));
    let v2 = run(KernelCfg::v2(8));
    assert_close(&v1.0, &v2.0, 5e-4, 5e-3, "gnn theta");
    assert_close(&v1.1, &v2.1, 5e-4, 5e-3, "ctrl theta");
    assert_close(&v1.2, &v2.2, 5e-4, 5e-3, "wm theta");
}
