//! Integration tests against the real AOT artifacts via the PJRT backend
//! (require `make artifacts` to have run; they skip gracefully otherwise).
//! The same program contract runs offline in `tests/host_backend.rs`.

use rlflow::runtime::{Backend, Manifest, ParamStore, PjrtBackend, TensorView};

fn backend() -> Option<PjrtBackend> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(PjrtBackend::load_default().expect("pjrt backend"))
}

#[test]
fn gnn_init_and_encode() {
    let Some(eng) = backend() else { return };
    let gnn = ParamStore::init(&eng, "gnn", 0).unwrap();
    assert!(gnn.n_params() > 1000);

    let n = eng.manifest().hp_usize("MAX_NODES").unwrap();
    let f = eng.manifest().hp_usize("NODE_FEATS").unwrap();
    let z = eng.manifest().hp_usize("LATENT").unwrap();
    let feats = vec![0.1f32; n * f];
    let adj = vec![0.0f32; n * n];
    let mut mask = vec![0.0f32; n];
    mask[..10].fill(1.0);
    let out = eng
        .exec_with_params(
            "gnn_encode_1",
            &gnn,
            &[
                TensorView::f32(&feats, &[1, n, f]),
                TensorView::f32(&adj, &[1, n, n]),
                TensorView::f32(&mask, &[1, n]),
            ],
        )
        .unwrap();
    let zv = &out[0].data;
    assert_eq!(zv.len(), z);
    assert!(zv.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
}

#[test]
fn init_deterministic_across_calls() {
    let Some(eng) = backend() else { return };
    let a = ParamStore::init(&eng, "ctrl", 42).unwrap();
    let b = ParamStore::init(&eng, "ctrl", 42).unwrap();
    let c = ParamStore::init(&eng, "ctrl", 43).unwrap();
    assert_eq!(a.theta, b.theta);
    assert_ne!(a.theta, c.theta);
}

#[test]
fn wm_step_shapes_and_finiteness() {
    let Some(eng) = backend() else { return };
    let wm = ParamStore::init(&eng, "wm", 1).unwrap();
    let zdim = eng.manifest().hp_usize("LATENT").unwrap();
    let r = eng.manifest().hp_usize("RNN_HIDDEN").unwrap();
    let k = eng.manifest().hp_usize("MDN_K").unwrap();
    let x1 = eng.manifest().hp_usize("N_XFERS1").unwrap();

    let z = vec![0.3f32; zdim];
    let a = [2i32, 7];
    let h = vec![0.0f32; r];
    let c = vec![0.0f32; r];
    let out = eng
        .exec_with_params(
            "wm_step_1",
            &wm,
            &[
                TensorView::f32(&z, &[1, zdim]),
                TensorView::i32(&a, &[1, 2]),
                TensorView::f32(&h, &[1, r]),
                TensorView::f32(&c, &[1, r]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 8);
    assert_eq!(out[0].data.len(), zdim * k);
    assert_eq!(out[4].data.len(), x1);
    let h1 = &out[6].data;
    assert_eq!(h1.len(), r);
    assert!(h1.iter().any(|v| v.abs() > 0.0), "hidden state did not evolve");
    for o in &out {
        assert!(o.data.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn ctrl_policy_logits() {
    let Some(eng) = backend() else { return };
    let ctrl = ParamStore::init(&eng, "ctrl", 2).unwrap();
    let zdim = eng.manifest().hp_usize("LATENT").unwrap();
    let r = eng.manifest().hp_usize("RNN_HIDDEN").unwrap();
    let x1 = eng.manifest().hp_usize("N_XFERS1").unwrap();
    let l = eng.manifest().hp_usize("MAX_LOCS").unwrap();

    let z = vec![0.1f32; zdim];
    let h = vec![0.0f32; r];
    let out = eng
        .exec_with_params(
            "ctrl_policy_1",
            &ctrl,
            &[TensorView::f32(&z, &[1, zdim]), TensorView::f32(&h, &[1, r])],
        )
        .unwrap();
    assert_eq!(out[0].data.len(), x1);
    assert_eq!(out[1].data.len(), x1 * l);
    assert_eq!(out[2].data.len(), 1);
}

#[test]
fn wm_train_step_decreases_loss() {
    let Some(eng) = backend() else { return };
    let mut wm = ParamStore::init(&eng, "wm", 3).unwrap();
    let zdim = eng.manifest().hp_usize("LATENT").unwrap();
    let x1 = eng.manifest().hp_usize("N_XFERS1").unwrap();
    let (b, t) = (
        eng.manifest().hp_usize("B_WM").unwrap(),
        eng.manifest().hp_usize("SEQ_LEN").unwrap(),
    );

    // Deterministic synthetic batch: z_next = 0.9 * z.
    let mut rng = rlflow::util::Rng::new(9);
    let z: Vec<f32> = (0..b * t * zdim).map(|_| rng.normal() * 0.5).collect();
    let z_next: Vec<f32> = z.iter().map(|v| 0.9 * v).collect();
    let a: Vec<i32> = (0..b * t * 2).map(|i| (i % 5) as i32).collect();
    let r_: Vec<f32> = vec![0.05; b * t];
    let xm: Vec<f32> = vec![1.0; b * t * x1];
    let done = vec![0.0f32; b * t];
    let valid = vec![1.0f32; b * t];

    let run_step = |wm: &mut ParamStore| -> f32 {
        let mut args = wm.train_args();
        args.extend([
            TensorView::f32(&z, &[b, t, zdim]),
            TensorView::i32(&a, &[b, t, 2]),
            TensorView::f32(&z_next, &[b, t, zdim]),
            TensorView::f32(&r_, &[b, t]),
            TensorView::f32(&xm, &[b, t, x1]),
            TensorView::f32(&done, &[b, t]),
            TensorView::f32(&valid, &[b, t]),
            TensorView::ScalarF32(1e-3),
        ]);
        let out = eng.exec("wm_train", &args).unwrap();
        drop(args);
        wm.absorb(&out).unwrap();
        out[4].data[0]
    };

    let first_loss = run_step(&mut wm);
    assert_eq!(wm.t, 1.0);
    let mut last_loss = first_loss;
    for _ in 0..4 {
        last_loss = run_step(&mut wm);
    }
    assert!(last_loss < first_loss, "wm loss {first_loss} -> {last_loss}");
    assert!(last_loss.is_finite());
}

#[test]
fn engine_stats_recorded() {
    let Some(eng) = backend() else { return };
    let _ = ParamStore::init(&eng, "gnn", 0).unwrap();
    let stats = eng.stats();
    let s = stats.get("gnn_init").unwrap();
    assert_eq!(s.calls, 1);
    assert!(s.total_s > 0.0);
}
