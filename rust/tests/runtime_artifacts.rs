//! Integration tests against the real AOT artifacts (require
//! `make artifacts` to have run; they skip gracefully otherwise).

use rlflow::runtime::{lit_f32, lit_i32, lit_scalar_f32, scalar_f32, to_vec_f32, Engine, Manifest, ParamStore};

fn engine() -> Option<Engine> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::load_default().expect("engine"))
}

#[test]
fn gnn_init_and_encode() {
    let Some(eng) = engine() else { return };
    let gnn = ParamStore::init(&eng, "gnn", 0).unwrap();
    assert!(gnn.n_params() > 1000);

    let n = eng.manifest.hp_usize("MAX_NODES").unwrap();
    let f = eng.manifest.hp_usize("NODE_FEATS").unwrap();
    let z = eng.manifest.hp_usize("LATENT").unwrap();
    let feats = lit_f32(&vec![0.1; n * f], &[1, n, f]).unwrap();
    let adj = lit_f32(&vec![0.0; n * n], &[1, n, n]).unwrap();
    let mut mask = vec![0.0f32; n];
    mask[..10].fill(1.0);
    let mask = lit_f32(&mask, &[1, n]).unwrap();
    let out = eng
        .exec("gnn_encode_1", &[gnn.theta_lit().unwrap(), feats, adj, mask])
        .unwrap();
    let zv = to_vec_f32(&out[0]).unwrap();
    assert_eq!(zv.len(), z);
    assert!(zv.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
}

#[test]
fn init_deterministic_across_calls() {
    let Some(eng) = engine() else { return };
    let a = ParamStore::init(&eng, "ctrl", 42).unwrap();
    let b = ParamStore::init(&eng, "ctrl", 42).unwrap();
    let c = ParamStore::init(&eng, "ctrl", 43).unwrap();
    assert_eq!(a.theta, b.theta);
    assert_ne!(a.theta, c.theta);
}

#[test]
fn wm_step_shapes_and_finiteness() {
    let Some(eng) = engine() else { return };
    let wm = ParamStore::init(&eng, "wm", 1).unwrap();
    let zdim = eng.manifest.hp_usize("LATENT").unwrap();
    let r = eng.manifest.hp_usize("RNN_HIDDEN").unwrap();
    let k = eng.manifest.hp_usize("MDN_K").unwrap();
    let x1 = eng.manifest.hp_usize("N_XFERS1").unwrap();

    let z = lit_f32(&vec![0.3; zdim], &[1, zdim]).unwrap();
    let a = lit_i32(&[2, 7], &[1, 2]).unwrap();
    let h = lit_f32(&vec![0.0; r], &[1, r]).unwrap();
    let c = lit_f32(&vec![0.0; r], &[1, r]).unwrap();
    let out = eng
        .exec("wm_step_1", &[wm.theta_lit().unwrap(), z, a, h, c])
        .unwrap();
    assert_eq!(out.len(), 8);
    let log_pi = to_vec_f32(&out[0]).unwrap();
    assert_eq!(log_pi.len(), zdim * k);
    let mask_logits = to_vec_f32(&out[4]).unwrap();
    assert_eq!(mask_logits.len(), x1);
    let h1 = to_vec_f32(&out[6]).unwrap();
    assert_eq!(h1.len(), r);
    assert!(h1.iter().any(|v| v.abs() > 0.0), "hidden state did not evolve");
    for o in &out {
        assert!(to_vec_f32(o).map(|v| v.iter().all(|x| x.is_finite())).unwrap_or(true));
    }
}

#[test]
fn ctrl_policy_logits() {
    let Some(eng) = engine() else { return };
    let ctrl = ParamStore::init(&eng, "ctrl", 2).unwrap();
    let zdim = eng.manifest.hp_usize("LATENT").unwrap();
    let r = eng.manifest.hp_usize("RNN_HIDDEN").unwrap();
    let x1 = eng.manifest.hp_usize("N_XFERS1").unwrap();
    let l = eng.manifest.hp_usize("MAX_LOCS").unwrap();

    let z = lit_f32(&vec![0.1; zdim], &[1, zdim]).unwrap();
    let h = lit_f32(&vec![0.0; r], &[1, r]).unwrap();
    let out = eng.exec("ctrl_policy_1", &[ctrl.theta_lit().unwrap(), z, h]).unwrap();
    assert_eq!(to_vec_f32(&out[0]).unwrap().len(), x1);
    assert_eq!(to_vec_f32(&out[1]).unwrap().len(), x1 * l);
    assert_eq!(to_vec_f32(&out[2]).unwrap().len(), 1);
}

#[test]
fn wm_train_step_decreases_loss() {
    let Some(eng) = engine() else { return };
    let mut wm = ParamStore::init(&eng, "wm", 3).unwrap();
    let zdim = eng.manifest.hp_usize("LATENT").unwrap();
    let x1 = eng.manifest.hp_usize("N_XFERS1").unwrap();
    let (b, t) = (
        eng.manifest.hp_usize("B_WM").unwrap(),
        eng.manifest.hp_usize("SEQ_LEN").unwrap(),
    );

    // Deterministic synthetic batch: z_next = 0.9 * z.
    let mut rng = rlflow::util::Rng::new(9);
    let z: Vec<f32> = (0..b * t * zdim).map(|_| rng.normal() * 0.5).collect();
    let z_next: Vec<f32> = z.iter().map(|v| 0.9 * v).collect();
    let a: Vec<i32> = (0..b * t * 2).map(|i| (i % 5) as i32).collect();
    let r_: Vec<f32> = vec![0.05; b * t];
    let xm: Vec<f32> = vec![1.0; b * t * x1];
    let done = vec![0.0f32; b * t];
    let valid = vec![1.0f32; b * t];

    let mut args = wm.train_args().unwrap();
    args.push(lit_f32(&z, &[b, t, zdim]).unwrap());
    args.push(lit_i32(&a, &[b, t, 2]).unwrap());
    args.push(lit_f32(&z_next, &[b, t, zdim]).unwrap());
    args.push(lit_f32(&r_, &[b, t]).unwrap());
    args.push(lit_f32(&xm, &[b, t, x1]).unwrap());
    args.push(lit_f32(&done, &[b, t]).unwrap());
    args.push(lit_f32(&valid, &[b, t]).unwrap());
    args.push(lit_scalar_f32(1e-3));

    let out = eng.exec("wm_train", &args).unwrap();
    let first_loss = scalar_f32(&out[4]).unwrap();
    wm.absorb(&out).unwrap();
    assert_eq!(wm.t, 1.0);

    let mut last_loss = first_loss;
    for _ in 0..4 {
        let mut args = wm.train_args().unwrap();
        args.push(lit_f32(&z, &[b, t, zdim]).unwrap());
        args.push(lit_i32(&a, &[b, t, 2]).unwrap());
        args.push(lit_f32(&z_next, &[b, t, zdim]).unwrap());
        args.push(lit_f32(&r_, &[b, t]).unwrap());
        args.push(lit_f32(&xm, &[b, t, x1]).unwrap());
        args.push(lit_f32(&done, &[b, t]).unwrap());
        args.push(lit_f32(&valid, &[b, t]).unwrap());
        args.push(lit_scalar_f32(1e-3));
        let out = eng.exec("wm_train", &args).unwrap();
        last_loss = scalar_f32(&out[4]).unwrap();
        wm.absorb(&out).unwrap();
    }
    assert!(last_loss < first_loss, "wm loss {first_loss} -> {last_loss}");
    assert!(last_loss.is_finite());
}

#[test]
fn engine_stats_recorded() {
    let Some(eng) = engine() else { return };
    let _ = ParamStore::init(&eng, "gnn", 0).unwrap();
    let stats = eng.stats();
    let s = stats.get("gnn_init").unwrap();
    assert_eq!(s.calls, 1);
    assert!(s.total_s > 0.0);
}
