//! Stress battery for the async pipeline's plumbing: the bounded staging
//! buffer under a deliberately slow consumer (backpressure, never drop
//! or reorder within a shard), the streaming env-pool fan-out against
//! its batched oracle, the seeded "jittery stage" harness shaking
//! stage timing while asserting schedule-trace equality, and panic
//! containment (a dying stage closes its channels so peers exit with a
//! typed error instead of hanging).

use std::time::Duration;

use rlflow::config::RunConfig;
use rlflow::coordinator::{train_async, AsyncTrainCfg, StageChannel};
use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{EnvPool, EnvPoolConfig};
use rlflow::graph::{GraphBuilder, PadMode};
use rlflow::runtime::{Backend, HostBackend, HostConfig};
use rlflow::xfer::library::standard_library;

fn small_graph() -> rlflow::graph::Graph {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 16, 16]);
    let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
    let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
    let r = b.relu(c2).unwrap();
    let _ = b.maxpool(r, 2, 2).unwrap();
    b.finish()
}

/// A slow consumer must backpressure the producers through the bounded
/// buffer — never drop an item, never exceed capacity, never reorder
/// within a producer ("shard") — and every producer must run to
/// completion despite blocking on a full buffer.
#[test]
fn slow_consumer_backpressures_without_drops_or_shard_reorder() {
    const SHARDS: usize = 4;
    const PER_SHARD: usize = 50;
    let chan: StageChannel<(usize, usize)> = StageChannel::new(3);

    let received = std::thread::scope(|s| {
        let producers: Vec<_> = (0..SHARDS)
            .map(|shard| {
                let chan = &chan;
                s.spawn(move || {
                    for seq in 0..PER_SHARD {
                        chan.send((shard, seq)).expect("consumer closed early");
                    }
                })
            })
            .collect();
        let consumer = s.spawn(|| {
            let mut got = Vec::new();
            while let Some(item) = chan.recv() {
                // The bound holds at every observation point.
                assert!(
                    chan.depth() <= chan.capacity(),
                    "buffer depth {} exceeded capacity {}",
                    chan.depth(),
                    chan.capacity()
                );
                got.push(item);
                // Deliberately slower than the producers.
                std::thread::sleep(Duration::from_micros(200));
            }
            got
        });
        // Producers finish only because the consumer drains them; only
        // then does EOF reach the consumer.
        for p in producers {
            p.join().expect("producer panicked");
        }
        chan.close();
        consumer.join().expect("consumer panicked")
    });

    assert_eq!(received.len(), SHARDS * PER_SHARD, "backpressure must never drop");
    let mut next = [0usize; SHARDS];
    for (shard, seq) in received {
        assert_eq!(seq, next[shard], "shard {shard} items arrived out of order");
        next[shard] += 1;
    }
    assert!(next.iter().all(|&n| n == PER_SHARD));
}

/// A sender blocked on a full buffer is woken by `close` and gets its
/// item back instead of losing it.
#[test]
fn close_releases_a_blocked_producer_with_its_item() {
    let chan: StageChannel<u32> = StageChannel::new(1);
    chan.send(1).unwrap();
    std::thread::scope(|s| {
        let blocked = s.spawn(|| chan.send(2));
        std::thread::sleep(Duration::from_millis(20));
        chan.close();
        let err = blocked.join().unwrap().unwrap_err();
        assert_eq!(err.0, 2, "the refused item is handed back");
    });
    assert_eq!(chan.recv(), Some(1), "already-queued work still drains");
    assert_eq!(chan.recv(), None);
}

/// A panicking stage must never strand its peers: the [`CloseGuard`]s it
/// holds close both of its channels on unwind, so a consumer blocked in
/// `recv()` drains the queue and sees EOF, a producer blocked on a full
/// buffer gets its item back with a typed close error, and the panic
/// payload converts to a typed [`StageFailed`] — never a hang.
#[test]
fn panicking_stage_closes_channels_and_frees_both_peers() {
    use rlflow::coordinator::StageFailed;
    let input: StageChannel<u32> = StageChannel::new(1);
    let output: StageChannel<u32> = StageChannel::new(1);

    std::thread::scope(|s| {
        // Upstream producer: sends until the channel refuses.
        let producer = s.spawn(|| {
            let mut sent = 0u32;
            loop {
                if input.send(sent).is_err() {
                    return sent;
                }
                sent += 1;
            }
        });
        // Downstream consumer: drains until EOF.
        let consumer = s.spawn(|| {
            let mut got = Vec::new();
            while let Some(v) = output.recv() {
                got.push(v);
            }
            got
        });
        // The failing middle stage: forwards one item, then panics while
        // holding close guards on both sides (as the real stages do).
        let middle = s.spawn(|| {
            let _gi = input.close_guard();
            let _go = output.close_guard();
            let v = input.recv().expect("producer feeds the stage");
            output.send(v).expect("consumer is draining");
            panic!("injected stage failure");
        });

        let payload = middle.join().expect_err("middle stage must panic");
        let failed = StageFailed::from_panic("middle", payload);
        assert!(failed.to_string().contains("stage 'middle' panicked"), "got: {failed}");
        assert!(failed.to_string().contains("injected stage failure"), "got: {failed}");
        assert!(producer.join().unwrap() >= 1, "producer observed the close, not a hang");
        assert_eq!(consumer.join().unwrap(), vec![0], "the forwarded item still drains");
    });
}

/// `map_envs_streaming` is the same computation as `map_envs` — one
/// result per env, identical per-env values — only delivery differs.
#[test]
fn streaming_env_pool_matches_batched_map_envs() {
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let mk = |threads| {
        EnvPool::new(
            &small_graph(),
            standard_library(),
            &cost,
            &EnvPoolConfig { n_envs: 8, threads, seed: 7, ..Default::default() },
        )
    };
    // Each env takes its first valid action for 3 steps and reports the
    // rewards plus an RNG draw (exercising the per-env stream).
    let drive = |_i: usize, env: &mut rlflow::env::Env, rng: &mut rlflow::util::Rng| {
        let mut rewards = Vec::new();
        for _ in 0..3 {
            let obs = env.observe();
            let a = (0..obs.xfer_mask.len() - 1)
                .find(|&x| obs.xfer_mask[x])
                .map(|x| (x, 0))
                .unwrap_or((env.noop_action(), 0));
            rewards.push(env.step(a).reward.to_bits());
        }
        (rewards, rng.next_u64())
    };

    let batched = mk(4).map_envs(&drive);

    let streamed: std::sync::Mutex<Vec<Option<(Vec<u32>, u64)>>> =
        std::sync::Mutex::new(vec![None; 8]);
    mk(4).map_envs_streaming(&drive, |i, r| {
        let mut out = streamed.lock().unwrap();
        assert!(out[i].is_none(), "sink called twice for shard {i}");
        out[i] = Some(r);
    });
    let streamed: Vec<_> =
        streamed.into_inner().unwrap().into_iter().map(|o| o.expect("missing shard")).collect();
    assert_eq!(streamed, batched);

    // Single-threaded streaming agrees too (the sequential code path).
    let seq: std::sync::Mutex<Vec<Option<(Vec<u32>, u64)>>> = std::sync::Mutex::new(vec![None; 8]);
    mk(1).map_envs_streaming(&drive, |i, r| {
        seq.lock().unwrap()[i] = Some(r);
    });
    let seq: Vec<_> =
        seq.into_inner().unwrap().into_iter().map(|o| o.expect("missing shard")).collect();
    assert_eq!(seq, batched);
}

fn tiny_config() -> HostConfig {
    HostConfig {
        max_nodes: 48,
        node_feats: 32,
        gnn_hidden: 12,
        latent: 8,
        rnn_hidden: 12,
        mdn_k: 2,
        act_emb: 4,
        ctrl_hidden: 16,
        n_xfers1: standard_library().len() + 1,
        max_locs: 200,
        b_dream: 4,
        b_wm: 4,
        seq_len: 4,
        b_ppo: 16,
        b_enc: 4,
        kernels: rlflow::runtime::KernelCfg::default(),
    }
}

fn factory() -> anyhow::Result<Box<dyn Backend>> {
    Ok(Box::new(HostBackend::with_config(tiny_config())))
}

/// The jittery-stage harness: seeded 0–2 ms sleeps at every handoff
/// randomise stage *timing* without touching any data. Final params and
/// the canonical schedule trace must be bit-identical to the unjittered
/// run — the schedule decides when, never what.
#[test]
fn seeded_timing_jitter_never_changes_results() {
    let graph = small_graph();
    let mut cfg = RunConfig::smoke();
    cfg.backend = "host".into();
    cfg.envs = 4;
    cfg.collect_episodes = 8;
    cfg.ae_steps = 2;
    cfg.wm.total_steps = 2;
    cfg.dream_epochs = 1;
    cfg.dream_horizon = 3;
    cfg.ppo.epochs = 1;
    cfg.eval_episodes = 1;
    cfg.env.max_steps = 4;

    let run = |jitter| {
        let acfg = AsyncTrainCfg { rounds: 2, stage_threads: 4, staging_cap: 1, jitter };
        train_async(&factory, &cfg, &acfg, &graph).unwrap()
    };
    let calm = run(None);
    for seed in [7u64, 1234] {
        let shaken = run(Some(seed));
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&calm.gnn.theta), bits(&shaken.gnn.theta), "jitter {seed}: gnn");
        assert_eq!(bits(&calm.wm.theta), bits(&shaken.wm.theta), "jitter {seed}: wm");
        assert_eq!(bits(&calm.ctrl.theta), bits(&shaken.ctrl.theta), "jitter {seed}: ctrl");
        assert_eq!(
            calm.trace.canonical(),
            shaken.trace.canonical(),
            "jitter {seed}: canonical traces diverge"
        );
    }
}
