//! The coordinator: end-to-end training/evaluation pipelines (Fig. 2's
//! data flow) built on the backend seam ([`crate::runtime::Backend`]) and
//! the environment — the same pipeline runs on the PJRT artifacts or the
//! pure-Rust host backend.
//!
//! Model-based pipeline (the paper's RLFlow agent):
//!   1. random rollouts in the real env          -> `collect`
//!   2. GNN auto-encoder training                -> `train_gnn_ae`
//!   3. encode all states to latents             -> `encode_episodes`
//!   4. MDN-RNN world-model training (Fig. 8)    -> `train_wm`
//!   5. controller PPO *inside the dream* (Fig 9)-> `train_controller_dream`
//!   6. evaluation in the real env               -> `eval_real`
//!
//! Model-free baseline (§4.4): PPO directly in the real environment via
//! `train_model_free` — same controller artifacts, h ≡ 0.
//!
//! Asynchronous execution (`rlflow train --async`): `pipeline_async`
//! runs the same macro-stages as pipelined micro-stages over bounded
//! channels (`stage`), recording every cross-stage handoff to a
//! replayable schedule trace (`trace`) — same seeds + same trace ⇒
//! bit-identical final params.
//!
//! Crash safety (`rlflow train --checkpoint-every/--resume`):
//! `checkpoint` captures the complete cross-round training state in an
//! atomic, checksummed file at round boundaries; interrupting at any
//! boundary and resuming is bit-identical to the uninterrupted run.

pub mod checkpoint;
pub mod pipeline;
pub mod pipeline_async;
pub mod stage;
pub mod trace;

pub use checkpoint::{Checkpoint, CheckpointAssembler, CheckpointCfg};
pub use pipeline::{EvalResult, Pipeline};
pub use pipeline_async::{
    replay_trace, train_async, train_async_ckpt, train_reference, train_reference_ckpt,
    AsyncOutcome, AsyncTrainCfg, BackendFactory, RoundEval,
};
pub use stage::{CloseGuard, StageChannel, StageClosed, StageFailed};
pub use trace::{Edge, Handoff, ScheduleTrace, TraceCursor, TraceSink, SHARD_BATCH};

use crate::util::Rng;

/// Deterministic fan-out of worker seeds from a root seed.
pub fn worker_seeds(root: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(root);
    (0..n).map(|i| rng.fork(i as u64).next_u64()).collect()
}

/// Collect random episodes from a batch of `n_envs` identical
/// environments driven through [`crate::env::EnvPool`] on `n_workers`
/// scoped threads (no backend is touched here, so collection scales
/// across cores while encoding stays on the backend thread). All
/// environments share one read-only cost-cache snapshot; the episode set
/// is bit-identical for any worker count given a fixed seed.
#[allow(clippy::too_many_arguments)]
pub fn collect_random_parallel(
    graph: &crate::graph::Graph,
    env_cfg: &crate::env::EnvConfig,
    device: crate::cost::DeviceProfile,
    encoder_dims: (usize, usize),
    n_slots: usize,
    n_episodes: usize,
    noop_prob: f32,
    n_envs: usize,
    n_workers: usize,
    seed: u64,
) -> Vec<crate::agent::Episode> {
    let rules = crate::xfer::library::standard_library();
    let base_cost = crate::cost::CostModel::new(device);
    let mut pool = crate::env::EnvPool::new(
        graph,
        rules,
        &base_cost,
        &crate::env::EnvPoolConfig {
            n_envs: n_envs.max(1).min(n_episodes.max(1)),
            env: env_cfg.clone(),
            threads: n_workers,
            seed,
            noise_std: 0.0,
        },
    );
    let encoder = crate::env::StateEncoder::new(encoder_dims.0, encoder_dims.1);
    crate::agent::collect_random_pool(&mut pool, &encoder, n_slots, n_episodes, noop_prob)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::DeviceProfile;
    use crate::env::EnvConfig;
    use crate::graph::{GraphBuilder, PadMode};

    #[test]
    fn parallel_collection_yields_requested_count() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv_bn_relu(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.maxpool(c, 2, 2).unwrap();
        let g = b.finish();
        let eps = collect_random_parallel(
            &g,
            &EnvConfig { max_steps: 4, ..Default::default() },
            DeviceProfile::rtx2070(),
            (320, 32),
            49,
            6,
            0.1,
            3,
            3,
            42,
        );
        assert_eq!(eps.len(), 6);
        assert!(eps.iter().all(|e| !e.is_empty()));
    }

    #[test]
    fn worker_seeds_distinct() {
        let seeds = worker_seeds(7, 8);
        let mut s = seeds.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), seeds.len());
    }
}
