//! Asynchronous actor/learner pipeline with a replayable schedule-trace
//! determinism contract.
//!
//! The synchronous pipeline (`experiments::train_model_based`) runs the
//! paper's macro-stages strictly alternating: collect → GNN-AE → encode
//! → WM → dream-PPO → eval, each stage idle while another runs. This
//! module decomposes the same flow into pipelined micro-stages over
//! bounded channels ([`StageChannel`]) on `std::thread::scope` — no
//! async runtime, consistent with the crate's dependency-free rule:
//!
//! ```text
//!  EnvPool shards ──streaming──▶ staging ──▶ AE ──▶ encoder ──▶ WM ──▶ dream ──▶ eval
//!  (collect, round r+1)         (bounded)  (round r)  ...               (round r-k)
//! ```
//!
//! Work is split into `rounds` batches: env shards stream round `r+1`
//! trajectory blocks through the bounded staging buffer while the
//! learner stages still train on round `r`; the GNN encoder runs as its
//! own stage; world-model dreaming overlaps real-env evaluation of the
//! previous round's params.
//!
//! **Determinism contract.** The dataflow is *round-synchronous*: every
//! stage consumes exactly (all shard blocks of round `r`, the params of
//! version `r`/`r+1`), so timing decides only *when* a handoff happens,
//! never *what* it carries. Each handoff is recorded to a
//! [`ScheduleTrace`] (edge, round, shard, param version consumed), and
//! [`replay_trace`] re-executes the same handoff sequence through the
//! sequential engine — so **same seeds + same trace ⇒ bit-identical
//! final params**, the crate's oracle discipline (search, envs, kernels)
//! extended across concurrency. [`train_reference`] is the synchronous
//! oracle: the identical per-round arithmetic under the canonical
//! schedule.
//!
//! Every stage thread builds its *own* backend instance through the
//! [`BackendFactory`] (backends hold single-threaded interior state —
//! `RefCell` stats and workspaces — and cannot be shared across
//! threads); host-backend programs are pure functions of (params, args),
//! so per-thread instances produce bit-identical numerics to one shared
//! instance, which is what lets the sequential engine use a single
//! backend for all stages.
//!
//! Randomness: collection uses the pool's per-env forked streams
//! (persistent across rounds); AE/WM/dream each own a persistent
//! per-stage stream advancing in round order; eval derives a fresh
//! stream per round. No stream is shared between stages, so stage
//! overlap cannot reorder draws.
//!
//! **Crash safety.** Both engines checkpoint at round boundaries
//! through [`super::checkpoint`]: the sequential engine writes directly
//! after each due round; the threaded engine routes per-stage state
//! deposits through a [`CheckpointAssembler`] (stages cross a boundary
//! at different wall-clock times). Resuming from a checkpoint and
//! running the remaining rounds is bit-identical to the uninterrupted
//! run — every piece of cross-round state (params + Adam moments, stage
//! RNG streams, per-env collector streams, replay pools, eval history,
//! trace prefix) is restored exactly. A stage thread that *panics*
//! closes its channels via drop guards ([`StageChannel::close_guard`])
//! so peers exit promptly, and the join layer converts the panic into a
//! typed [`StageFailed`] error instead of aborting or hanging.

use std::collections::HashMap;

use crate::agent::{collect_random_episodes, uniform_policy_version, CompactState, Episode};
use crate::config::RunConfig;
use crate::cost::CostModel;
use crate::env::{EnvPool, EnvPoolConfig, StateEncoder};
use crate::graph::Graph;
use crate::runtime::{Backend, ParamStore};
use crate::util::Rng;
use crate::wm::{WmLosses, WmTrainer};
use crate::xfer::library::standard_library;

use super::checkpoint::{
    AeCkpt, Checkpoint, CheckpointAssembler, CheckpointCfg, DreamCkpt, WmCkpt,
};
use super::pipeline::{EvalResult, Pipeline};
use super::stage::{StageChannel, StageFailed};
use super::trace::{Edge, ScheduleTrace, TraceCursor, TraceSink, SHARD_BATCH};

/// Builds one backend instance per stage thread. Backends hold
/// single-threaded interior state, so every stage constructs its own;
/// each call must return an identically-configured backend (host
/// programs are pure functions of params + args, so per-instance
/// numerics are bit-identical).
pub type BackendFactory = dyn Fn() -> anyhow::Result<Box<dyn Backend>> + Sync;

// Domain separators for the per-stage RNG streams (arbitrary, distinct).
const STREAM_AE: u64 = 0x5AE0_11AE_5AE0_11AE;
const STREAM_WM: u64 = 0x3D97_00AA_C0FF_EE01;
const STREAM_DREAM: u64 = 0xD2EA_A10D_2EAA_10D2;
const STREAM_EVAL: u64 = 0xE7A1_5EED_E7A1_5EED;
const STREAM_EVAL_POOL: u64 = 0x9001_BEEF_9001_BEEF;

/// Shape of an async training run.
#[derive(Debug, Clone)]
pub struct AsyncTrainCfg {
    /// Number of pipelined batches the run's budgets split into
    /// (collect episodes, AE steps, WM steps, dream epochs each split
    /// round-robin across rounds).
    pub rounds: usize,
    /// Worker threads inside the parallel stages (the collector's
    /// `EnvPool` fan-out). Never affects results — pinned by
    /// `tests/pipeline_async.rs`.
    pub stage_threads: usize,
    /// Staging-buffer capacity in shard blocks: bounds how far the
    /// collector runs ahead of the auto-encoder (backpressure, never
    /// drop).
    pub staging_cap: usize,
    /// Test-only seeded timing jitter: `Some(seed)` sleeps 0–2 ms at
    /// each handoff, deterministically per (round, shard), to shake the
    /// schedule without touching any data. Must not change results.
    pub jitter: Option<u64>,
}

impl AsyncTrainCfg {
    /// The async knobs a [`RunConfig`] carries.
    pub fn from_run(cfg: &RunConfig) -> Self {
        Self {
            rounds: cfg.async_rounds,
            stage_threads: cfg.async_stage_threads,
            staging_cap: cfg.async_staging_cap,
            jitter: None,
        }
    }
}

/// Real-env evaluation results for one round's params.
#[derive(Debug, Clone, Default)]
pub struct RoundEval {
    /// Round whose (GNN, WM, controller) version `round + 1` was evaluated.
    pub round: u32,
    /// Per-episode results (`cfg.eval_episodes` pool rows).
    pub results: Vec<EvalResult>,
}

/// Everything an async (or reference, or replayed) run produces.
#[derive(Debug, Clone)]
pub struct AsyncOutcome {
    /// Final GNN auto-encoder params (version `rounds`).
    pub gnn: ParamStore,
    /// Final world-model params (version `rounds`).
    pub wm: ParamStore,
    /// Final controller params (version `rounds`).
    pub ctrl: ParamStore,
    /// AE loss per training step, concatenated across rounds.
    pub ae_losses: Vec<f32>,
    /// WM losses per training step, concatenated across rounds.
    pub wm_curve: Vec<WmLosses>,
    /// Mean predicted dream reward per PPO epoch, concatenated.
    pub dream_curve: Vec<f32>,
    /// Per-round real-env evaluations.
    pub evals: Vec<RoundEval>,
    /// The recorded schedule (replayable via [`replay_trace`]).
    pub trace: ScheduleTrace,
}

// ---------------------------------------------------------------------------
// Work plan: deterministic per-round budget split
// ---------------------------------------------------------------------------

/// Round-robin split: part `i` of `parts` gets `total/parts` plus one of
/// the `total % parts` leftovers.
fn quota(total: usize, parts: usize, i: usize) -> usize {
    total / parts + usize::from(i < total % parts)
}

/// The per-round work plan derived from (RunConfig, AsyncTrainCfg) —
/// pure arithmetic, identical for every executor.
struct Plan {
    rounds: usize,
    n_envs: usize,
    /// `env_counts[r][i]`: episodes env shard `i` collects in round `r`.
    env_counts: Vec<Vec<usize>>,
    ae_steps: Vec<usize>,
    wm_steps: Vec<usize>,
    dream_epochs: Vec<usize>,
}

impl Plan {
    fn new(cfg: &RunConfig, acfg: &AsyncTrainCfg) -> anyhow::Result<Plan> {
        anyhow::ensure!(cfg.collect_episodes >= 1, "async training needs collect_episodes >= 1");
        let rounds = acfg.rounds.max(1);
        // Same clamp as collect_random_parallel: never more envs than episodes.
        let n_envs = cfg.envs.max(1).min(cfg.collect_episodes);
        let env_counts = (0..rounds)
            .map(|r| {
                let in_round = quota(cfg.collect_episodes, rounds, r);
                (0..n_envs).map(|i| quota(in_round, n_envs, i)).collect()
            })
            .collect();
        Ok(Plan {
            rounds,
            n_envs,
            env_counts,
            ae_steps: (0..rounds).map(|r| quota(cfg.ae_steps, rounds, r)).collect(),
            wm_steps: (0..rounds).map(|r| quota(cfg.wm.total_steps, rounds, r)).collect(),
            dream_epochs: (0..rounds).map(|r| quota(cfg.dream_epochs, rounds, r)).collect(),
        })
    }
}

/// splitmix64 finaliser over (seed, stream, round): stateless derivation
/// of per-round seeds, independent of every persistent stream.
fn mix(seed: u64, stream: u64, round: u64) -> u64 {
    let mut z = seed ^ stream ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic 0–2 ms sleep per (round, shard) when jitter is on.
fn jitter_sleep(jitter: Option<u64>, round: u32, shard: u32) {
    if let Some(seed) = jitter {
        let ms = mix(seed, u64::from(round) << 32 | u64::from(shard), 0xDE1A) % 3;
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// One (round, env shard) block of collected trajectories.
struct EpisodeBlock {
    round: u32,
    shard: u32,
    episodes: Vec<Episode>,
}

// ---------------------------------------------------------------------------
// Stage state: identical arithmetic for the threaded and sequential engines
// ---------------------------------------------------------------------------

struct AeStage {
    gnn: ParamStore,
    rng: Rng,
    /// Growing pool of every collected state (AE samples minibatches
    /// from all data seen so far, mirroring the synchronous stage).
    states: Vec<CompactState>,
    losses: Vec<f32>,
    /// GNN version = AE rounds completed.
    version: u32,
}

impl AeStage {
    fn new(backend: &dyn Backend, seed: u64) -> anyhow::Result<Self> {
        Ok(Self {
            gnn: ParamStore::init(backend, "gnn", seed as i32)?,
            rng: Rng::new(mix(seed, STREAM_AE, 0)),
            states: Vec::new(),
            losses: Vec::new(),
            version: 0,
        })
    }

    /// Overwrite every field from a checkpoint: params + Adam moments,
    /// RNG stream, the growing state pool, losses, version.
    fn restore(&mut self, cp: &Checkpoint) {
        self.gnn = cp.ae.gnn.clone();
        self.rng = Rng::from_state(cp.ae.rng);
        self.states = cp.ae.states.clone();
        self.losses = cp.ae.losses.clone();
        self.version = cp.ae.version;
    }

    /// Snapshot every field into checkpoint form (the inverse of
    /// [`AeStage::restore`]).
    fn snapshot(&self) -> AeCkpt {
        AeCkpt {
            gnn: self.gnn.clone(),
            rng: self.rng.state(),
            version: self.version,
            losses: self.losses.clone(),
            states: self.states.clone(),
        }
    }

    fn round(
        &mut self,
        pipe: &Pipeline,
        plan: &Plan,
        cfg: &RunConfig,
        r: usize,
        blocks: &[EpisodeBlock],
    ) -> anyhow::Result<()> {
        for b in blocks {
            for ep in &b.episodes {
                self.states.extend(ep.states.iter().cloned());
            }
        }
        let pool: Vec<&CompactState> = self.states.iter().collect();
        let mut losses =
            pipe.train_gnn_ae_states(&mut self.gnn, &pool, plan.ae_steps[r], cfg.ae_lr, &mut self.rng)?;
        self.losses.append(&mut losses);
        self.version = r as u32 + 1;
        Ok(())
    }
}

/// Encoder stage: fills `ep.z` for one round's episodes under the GNN of
/// version `r + 1`. Stateless — per-row encoding is independent, so
/// per-round encoding is bit-identical to one big pass per round.
fn encode_round(
    pipe: &Pipeline,
    gnn: &ParamStore,
    blocks: Vec<EpisodeBlock>,
) -> anyhow::Result<Vec<Episode>> {
    let mut episodes: Vec<Episode> = blocks.into_iter().flat_map(|b| b.episodes).collect();
    pipe.encode_episodes(gnn, &mut episodes)?;
    Ok(episodes)
}

struct WmStage {
    wm: ParamStore,
    rng: Rng,
    /// All encoded episodes so far (WM samples windows from the full set).
    episodes: Vec<Episode>,
    curve: Vec<WmLosses>,
    /// Global step counter: the polynomial lr schedule indexes total
    /// steps across rounds, exactly as the synchronous `train_wm` does.
    step: usize,
}

impl WmStage {
    fn new(backend: &dyn Backend, seed: u64) -> anyhow::Result<Self> {
        Ok(Self {
            wm: ParamStore::init(backend, "wm", seed as i32 + 1)?,
            rng: Rng::new(mix(seed, STREAM_WM, 0)),
            episodes: Vec::new(),
            curve: Vec::new(),
            step: 0,
        })
    }

    fn restore(&mut self, cp: &Checkpoint) {
        self.wm = cp.wm.wm.clone();
        self.rng = Rng::from_state(cp.wm.rng);
        self.episodes = cp.wm.episodes.clone();
        self.curve = cp.wm.curve.clone();
        self.step = cp.wm.step as usize;
    }

    fn snapshot(&self) -> WmCkpt {
        WmCkpt {
            wm: self.wm.clone(),
            rng: self.rng.state(),
            step: self.step as u64,
            curve: self.curve.clone(),
            episodes: self.episodes.clone(),
        }
    }

    /// Train this round's step budget; returns the dream seed pool
    /// (initial latents + masks of every encoded episode so far).
    #[allow(clippy::type_complexity)]
    fn round(
        &mut self,
        pipe: &Pipeline,
        plan: &Plan,
        cfg: &RunConfig,
        r: usize,
        episodes: Vec<Episode>,
    ) -> anyhow::Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        self.episodes.extend(episodes);
        // A PPO-side invariant worth holding here too: one WM batch set
        // never mixes collection-policy versions.
        let _version = uniform_policy_version(&self.episodes)?;
        let trainer = WmTrainer::new(pipe.backend)?;
        for _ in 0..plan.wm_steps[r] {
            let lr = cfg.wm.lr_at(self.step);
            self.curve.push(trainer.train_step(
                &mut self.wm,
                &self.episodes,
                lr,
                cfg.wm.reward_scale,
                &mut self.rng,
            )?);
            self.step += 1;
        }
        let z0 = self.episodes.iter().filter(|e| !e.z.is_empty()).map(|e| e.z[0].clone()).collect();
        let xm0 =
            self.episodes.iter().filter(|e| !e.z.is_empty()).map(|e| e.xmasks[0].clone()).collect();
        Ok((z0, xm0))
    }
}

struct DreamStage {
    ctrl: ParamStore,
    rng: Rng,
    curve: Vec<f32>,
}

impl DreamStage {
    fn new(backend: &dyn Backend, seed: u64) -> anyhow::Result<Self> {
        Ok(Self {
            ctrl: ParamStore::init(backend, "ctrl", seed as i32 + 2)?,
            rng: Rng::new(mix(seed, STREAM_DREAM, 0)),
            curve: Vec::new(),
        })
    }

    fn restore(&mut self, cp: &Checkpoint) {
        self.ctrl = cp.dream.ctrl.clone();
        self.rng = Rng::from_state(cp.dream.rng);
        self.curve = cp.dream.curve.clone();
    }

    fn snapshot(&self) -> DreamCkpt {
        DreamCkpt {
            ctrl: self.ctrl.clone(),
            rng: self.rng.state(),
            curve: self.curve.clone(),
        }
    }

    fn round(
        &mut self,
        pipe: &Pipeline,
        plan: &Plan,
        cfg: &RunConfig,
        r: usize,
        wm: &ParamStore,
        z0: &[Vec<f32>],
        xm0: &[Vec<f32>],
    ) -> anyhow::Result<()> {
        let mut curve = pipe.train_controller_dream_seeded(
            &mut self.ctrl,
            wm,
            z0,
            xm0,
            plan.dream_epochs[r],
            cfg.dream_horizon,
            cfg.temperature,
            cfg.wm.reward_scale,
            &cfg.ppo,
            &mut self.rng,
        )?;
        self.curve.append(&mut curve);
        Ok(())
    }
}

struct EvalStage {
    evals: Vec<RoundEval>,
}

impl EvalStage {
    #[allow(clippy::too_many_arguments)]
    fn round(
        &mut self,
        pipe: &Pipeline,
        cfg: &RunConfig,
        graph: &Graph,
        r: usize,
        gnn: &ParamStore,
        ctrl: &ParamStore,
        wm: &ParamStore,
    ) -> anyhow::Result<()> {
        let cost = CostModel::new(cfg.device);
        let mut pool = EnvPool::new(
            graph,
            standard_library(),
            &cost,
            &EnvPoolConfig {
                n_envs: cfg.eval_episodes.max(1),
                env: cfg.env.clone(),
                threads: 1,
                seed: mix(cfg.seed, STREAM_EVAL_POOL, r as u64),
                noise_std: 0.0,
            },
        );
        // Stateless per-round stream: eval overlap with later rounds'
        // training can never perturb draws.
        let mut rng = Rng::new(mix(cfg.seed, STREAM_EVAL, r as u64));
        let results = pipe.eval_real_pool(gnn, ctrl, Some(wm), &mut pool, cfg.eval_greedy, &mut rng)?;
        self.evals.push(RoundEval { round: r as u32, results });
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Threaded executor
// ---------------------------------------------------------------------------

/// How a stage thread finished: `Done` with its product, or `Cancelled`
/// because a neighbouring channel closed under it (the causing error is
/// reported by the stage that failed).
enum StageExit<T> {
    Done(T),
    Cancelled,
}

/// Fold one stage's result into (first error, payload). Stage order of
/// the call sites (collect → … → eval) makes the *most upstream* real
/// error the one reported.
fn unpack<T>(r: anyhow::Result<StageExit<T>>, first_err: &mut Option<anyhow::Error>) -> Option<T> {
    match r {
        Ok(StageExit::Done(v)) => Some(v),
        Ok(StageExit::Cancelled) => None,
        Err(e) => {
            if first_err.is_none() {
                *first_err = Some(e);
            }
            None
        }
    }
}

struct EncJob {
    round: u32,
    gnn: ParamStore,
    blocks: Vec<EpisodeBlock>,
}

struct WmJob {
    round: u32,
    gnn: ParamStore,
    episodes: Vec<Episode>,
}

struct DreamJob {
    round: u32,
    gnn: ParamStore,
    wm: ParamStore,
    z0: Vec<Vec<f32>>,
    xm0: Vec<Vec<f32>>,
}

struct EvalJob {
    round: u32,
    gnn: ParamStore,
    wm: ParamStore,
    ctrl: ParamStore,
}

struct AeOut {
    gnn: ParamStore,
    losses: Vec<f32>,
}

struct WmOut {
    wm: ParamStore,
    curve: Vec<WmLosses>,
}

struct DreamOut {
    ctrl: ParamStore,
    curve: Vec<f32>,
}

/// Dims the collector needs from the backend manifest (read once up
/// front; the collector itself never touches a backend).
struct CollectDims {
    max_nodes: usize,
    node_feats: usize,
    n_slots: usize,
}

#[allow(clippy::too_many_arguments)]
fn run_collect(
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    plan: &Plan,
    graph: &Graph,
    dims: &CollectDims,
    staging: &StageChannel<EpisodeBlock>,
    sink: &TraceSink,
    start: usize,
    resume: Option<&Checkpoint>,
    asm: Option<&CheckpointAssembler>,
) -> anyhow::Result<StageExit<()>> {
    let cost = CostModel::new(cfg.device);
    let mut pool = EnvPool::new(
        graph,
        standard_library(),
        &cost,
        &EnvPoolConfig {
            n_envs: plan.n_envs,
            env: cfg.env.clone(),
            threads: acfg.stage_threads,
            seed: cfg.seed,
            noise_std: 0.0,
        },
    );
    if let Some(cp) = resume {
        pool.restore_rng_states(&cp.env_rngs)?;
    }
    let encoder = StateEncoder::new(dims.max_nodes, dims.node_feats);
    for r in start..plan.rounds {
        let counts = &plan.env_counts[r];
        let cancelled = std::sync::atomic::AtomicBool::new(false);
        pool.map_envs_streaming(
            |i, env, rng| {
                collect_random_episodes(
                    env,
                    &encoder,
                    dims.n_slots,
                    counts[i],
                    cfg.collect_noop_prob,
                    rng,
                )
            },
            |i, episodes| {
                jitter_sleep(acfg.jitter, r as u32, i as u32);
                sink.record(Edge::Staging, r as u32, i as u32, 0);
                let block = EpisodeBlock { round: r as u32, shard: i as u32, episodes };
                if staging.send(block).is_err() {
                    cancelled.store(true, std::sync::atomic::Ordering::Relaxed);
                }
            },
        );
        if cancelled.load(std::sync::atomic::Ordering::Relaxed) {
            return Ok(StageExit::Cancelled);
        }
        if let Some(a) = asm {
            if a.due(r as u32) {
                a.deposit_env(r as u32, pool.rng_states())?;
            }
        }
    }
    Ok(StageExit::Done(()))
}

#[allow(clippy::too_many_arguments)]
fn run_ae(
    factory: &BackendFactory,
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    plan: &Plan,
    staging: &StageChannel<EpisodeBlock>,
    out: &StageChannel<EncJob>,
    sink: &TraceSink,
    start: usize,
    resume: Option<&Checkpoint>,
    asm: Option<&CheckpointAssembler>,
) -> anyhow::Result<StageExit<AeOut>> {
    let backend = factory()?;
    let pipe = Pipeline::new(backend.as_ref())?;
    let mut stage = AeStage::new(backend.as_ref(), cfg.seed)?;
    if let Some(cp) = resume {
        stage.restore(cp);
    }
    let mut stash: HashMap<(u32, u32), EpisodeBlock> = HashMap::new();
    for r in start..plan.rounds {
        // Drain staging eagerly into the stash, then assemble round r in
        // canonical shard order. The stash is unbounded, so the staging
        // buffer's backpressure bounds the *collector*, never this loop.
        let mut blocks: Vec<EpisodeBlock> = Vec::with_capacity(plan.n_envs);
        for shard in 0..plan.n_envs as u32 {
            loop {
                if let Some(b) = stash.remove(&(r as u32, shard)) {
                    blocks.push(b);
                    break;
                }
                match staging.recv() {
                    Some(b) => {
                        stash.insert((b.round, b.shard), b);
                    }
                    None => return Ok(StageExit::Cancelled),
                }
            }
        }
        for b in &blocks {
            sink.record(Edge::AeIn, r as u32, b.shard, stage.version);
        }
        stage.round(&pipe, plan, cfg, r, &blocks)?;
        if let Some(a) = asm {
            if a.due(r as u32) {
                a.deposit_ae(r as u32, stage.snapshot())?;
            }
        }
        jitter_sleep(acfg.jitter, r as u32, SHARD_BATCH);
        let job = EncJob { round: r as u32, gnn: stage.gnn.clone(), blocks };
        if out.send(job).is_err() {
            return Ok(StageExit::Cancelled);
        }
    }
    Ok(StageExit::Done(AeOut { gnn: stage.gnn, losses: stage.losses }))
}

fn run_enc(
    factory: &BackendFactory,
    plan: &Plan,
    input: &StageChannel<EncJob>,
    out: &StageChannel<WmJob>,
    sink: &TraceSink,
    start: usize,
) -> anyhow::Result<StageExit<()>> {
    let backend = factory()?;
    let pipe = Pipeline::new(backend.as_ref())?;
    for r in start..plan.rounds {
        let Some(job) = input.recv() else { return Ok(StageExit::Cancelled) };
        debug_assert_eq!(job.round as usize, r);
        sink.record(Edge::EncIn, job.round, SHARD_BATCH, job.round + 1);
        let episodes = encode_round(&pipe, &job.gnn, job.blocks)?;
        if out.send(WmJob { round: job.round, gnn: job.gnn, episodes }).is_err() {
            return Ok(StageExit::Cancelled);
        }
    }
    Ok(StageExit::Done(()))
}

#[allow(clippy::too_many_arguments)]
fn run_wm(
    factory: &BackendFactory,
    cfg: &RunConfig,
    plan: &Plan,
    input: &StageChannel<WmJob>,
    out: &StageChannel<DreamJob>,
    sink: &TraceSink,
    start: usize,
    resume: Option<&Checkpoint>,
    asm: Option<&CheckpointAssembler>,
) -> anyhow::Result<StageExit<WmOut>> {
    let backend = factory()?;
    let pipe = Pipeline::new(backend.as_ref())?;
    let mut stage = WmStage::new(backend.as_ref(), cfg.seed)?;
    if let Some(cp) = resume {
        stage.restore(cp);
    }
    for r in start..plan.rounds {
        let Some(job) = input.recv() else { return Ok(StageExit::Cancelled) };
        sink.record(Edge::WmIn, job.round, SHARD_BATCH, job.round);
        let (z0, xm0) = stage.round(&pipe, plan, cfg, r, job.episodes)?;
        if let Some(a) = asm {
            if a.due(r as u32) {
                a.deposit_wm(r as u32, stage.snapshot())?;
            }
        }
        let dream = DreamJob { round: job.round, gnn: job.gnn, wm: stage.wm.clone(), z0, xm0 };
        if out.send(dream).is_err() {
            return Ok(StageExit::Cancelled);
        }
    }
    Ok(StageExit::Done(WmOut { wm: stage.wm, curve: stage.curve }))
}

#[allow(clippy::too_many_arguments)]
fn run_dream(
    factory: &BackendFactory,
    cfg: &RunConfig,
    plan: &Plan,
    input: &StageChannel<DreamJob>,
    out: &StageChannel<EvalJob>,
    sink: &TraceSink,
    start: usize,
    resume: Option<&Checkpoint>,
    asm: Option<&CheckpointAssembler>,
) -> anyhow::Result<StageExit<DreamOut>> {
    let backend = factory()?;
    let pipe = Pipeline::new(backend.as_ref())?;
    let mut stage = DreamStage::new(backend.as_ref(), cfg.seed)?;
    if let Some(cp) = resume {
        stage.restore(cp);
    }
    for r in start..plan.rounds {
        let Some(job) = input.recv() else { return Ok(StageExit::Cancelled) };
        sink.record(Edge::DreamIn, job.round, SHARD_BATCH, job.round + 1);
        stage.round(&pipe, plan, cfg, r, &job.wm, &job.z0, &job.xm0)?;
        if let Some(a) = asm {
            if a.due(r as u32) {
                a.deposit_dream(r as u32, stage.snapshot())?;
            }
        }
        let eval =
            EvalJob { round: job.round, gnn: job.gnn, wm: job.wm, ctrl: stage.ctrl.clone() };
        if out.send(eval).is_err() {
            return Ok(StageExit::Cancelled);
        }
    }
    Ok(StageExit::Done(DreamOut { ctrl: stage.ctrl, curve: stage.curve }))
}

#[allow(clippy::too_many_arguments)]
fn run_eval(
    factory: &BackendFactory,
    cfg: &RunConfig,
    plan: &Plan,
    graph: &Graph,
    input: &StageChannel<EvalJob>,
    sink: &TraceSink,
    start: usize,
    resume: Option<&Checkpoint>,
    asm: Option<&CheckpointAssembler>,
) -> anyhow::Result<StageExit<Vec<RoundEval>>> {
    let backend = factory()?;
    let pipe = Pipeline::new(backend.as_ref())?;
    let mut stage =
        EvalStage { evals: resume.map(|cp| cp.evals.clone()).unwrap_or_default() };
    for r in start..plan.rounds {
        let Some(job) = input.recv() else { return Ok(StageExit::Cancelled) };
        sink.record(Edge::EvalIn, job.round, SHARD_BATCH, job.round + 1);
        stage.round(&pipe, cfg, graph, r, &job.gnn, &job.ctrl, &job.wm)?;
        if let Some(a) = asm {
            if a.due(r as u32) {
                a.deposit_evals(r as u32, stage.evals.clone())?;
            }
        }
    }
    Ok(StageExit::Done(stage.evals))
}

/// Join a stage thread, converting a panic into the typed
/// [`StageFailed`] error (the thread's [`CloseGuard`]s have already
/// closed its channels by the time `join` returns, so every peer is
/// guaranteed to exit and this call never hangs the scope).
///
/// [`CloseGuard`]: super::stage::CloseGuard
fn join_stage<T>(
    h: std::thread::ScopedJoinHandle<'_, anyhow::Result<StageExit<T>>>,
    stage: &'static str,
) -> anyhow::Result<StageExit<T>> {
    match h.join() {
        Ok(r) => r,
        Err(payload) => Err(StageFailed::from_panic(stage, payload).into()),
    }
}

/// Run the pipelined async trainer: six stage threads (collect, AE,
/// encode, WM, dream, eval) over bounded channels, recording the
/// schedule trace as it runs. See the module docs for the determinism
/// contract; `tests/pipeline_async.rs` pins
/// `train_async == train_reference == replay_trace(own trace)` across
/// stage-thread and env sweeps.
pub fn train_async(
    factory: &BackendFactory,
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    graph: &Graph,
) -> anyhow::Result<AsyncOutcome> {
    train_async_ckpt(factory, cfg, acfg, graph, None, None)
}

/// [`train_async`] with crash safety: write a checkpoint after every
/// round `r` with `(r + 1) % ckpt.every == 0` (stages deposit their
/// state into a [`CheckpointAssembler`]; whichever stage crosses the
/// boundary last triggers the atomic write), and/or continue a run from
/// a [`Checkpoint`]. Interrupting at any round boundary and resuming is
/// bit-identical to the uninterrupted run — `tests/pipeline_async.rs`
/// pins this for stage-thread counts 1 and 4.
pub fn train_async_ckpt(
    factory: &BackendFactory,
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    graph: &Graph,
    ckpt: Option<&CheckpointCfg>,
    resume: Option<Checkpoint>,
) -> anyhow::Result<AsyncOutcome> {
    let plan = Plan::new(cfg, acfg)?;
    if let Some(cp) = &resume {
        cp.validate_run(cfg.seed, plan.rounds as u32, plan.n_envs as u32)?;
    }
    let start = resume.as_ref().map(|cp| cp.next_round as usize).unwrap_or(0);
    let dims = {
        let backend = factory()?;
        let pipe = Pipeline::new(backend.as_ref())?;
        CollectDims {
            max_nodes: pipe.encoder.max_nodes,
            node_feats: pipe.encoder.n_feats,
            n_slots: pipe.dims.x1,
        }
    };
    let mut trace0 = ScheduleTrace::new(cfg.seed, plan.n_envs as u32, plan.rounds as u32);
    if let Some(cp) = &resume {
        trace0.events = cp.trace_events.clone();
    }
    let sink = TraceSink::new(trace0);
    let asm = ckpt.map(|c| {
        CheckpointAssembler::new(
            c.clone(),
            cfg.seed,
            plan.rounds as u32,
            plan.n_envs as u32,
            sink.clone(),
        )
    });
    let asm = asm.as_ref();
    let resume = resume.as_ref();
    let staging: StageChannel<EpisodeBlock> = StageChannel::new(acfg.staging_cap);
    let to_enc: StageChannel<EncJob> = StageChannel::new(2);
    let to_wm: StageChannel<WmJob> = StageChannel::new(2);
    let to_dream: StageChannel<DreamJob> = StageChannel::new(2);
    let to_eval: StageChannel<EvalJob> = StageChannel::new(2);

    let (collect_r, ae_r, enc_r, wm_r, dream_r, eval_r) = std::thread::scope(|s| {
        // Each stage holds drop guards on the channels it touches:
        // leaving — by return, error, *or panic* — closes its input
        // (cancelling upstream) and its output (EOF or cancel
        // downstream), so failures propagate as channel closures, never
        // deadlocks.
        let h_collect = s.spawn(|| {
            let _g = staging.close_guard();
            run_collect(cfg, acfg, &plan, graph, &dims, &staging, &sink, start, resume, asm)
        });
        let h_ae = s.spawn(|| {
            let _g_in = staging.close_guard();
            let _g_out = to_enc.close_guard();
            run_ae(factory, cfg, acfg, &plan, &staging, &to_enc, &sink, start, resume, asm)
        });
        let h_enc = s.spawn(|| {
            let _g_in = to_enc.close_guard();
            let _g_out = to_wm.close_guard();
            run_enc(factory, &plan, &to_enc, &to_wm, &sink, start)
        });
        let h_wm = s.spawn(|| {
            let _g_in = to_wm.close_guard();
            let _g_out = to_dream.close_guard();
            run_wm(factory, cfg, &plan, &to_wm, &to_dream, &sink, start, resume, asm)
        });
        let h_dream = s.spawn(|| {
            let _g_in = to_dream.close_guard();
            let _g_out = to_eval.close_guard();
            run_dream(factory, cfg, &plan, &to_dream, &to_eval, &sink, start, resume, asm)
        });
        let h_eval = s.spawn(|| {
            let _g = to_eval.close_guard();
            run_eval(factory, cfg, &plan, graph, &to_eval, &sink, start, resume, asm)
        });
        (
            join_stage(h_collect, "collect"),
            join_stage(h_ae, "ae"),
            join_stage(h_enc, "enc"),
            join_stage(h_wm, "wm"),
            join_stage(h_dream, "dream"),
            join_stage(h_eval, "eval"),
        )
    });

    let mut first_err = None;
    let collect_ok = unpack(collect_r, &mut first_err);
    let ae = unpack(ae_r, &mut first_err);
    let enc_ok = unpack(enc_r, &mut first_err);
    let wm = unpack(wm_r, &mut first_err);
    let dream = unpack(dream_r, &mut first_err);
    let evals = unpack(eval_r, &mut first_err);
    if let Some(e) = first_err {
        return Err(e);
    }
    match (collect_ok, ae, enc_ok, wm, dream, evals) {
        (Some(()), Some(ae), Some(()), Some(wm), Some(dream), Some(evals)) => Ok(AsyncOutcome {
            gnn: ae.gnn,
            wm: wm.wm,
            ctrl: dream.ctrl,
            ae_losses: ae.losses,
            wm_curve: wm.curve,
            dream_curve: dream.curve,
            evals,
            trace: sink.snapshot(),
        }),
        _ => anyhow::bail!("async pipeline cancelled without a recorded error"),
    }
}

// ---------------------------------------------------------------------------
// Sequential engine: the reference oracle and the replay mode
// ---------------------------------------------------------------------------

enum Schedule<'t> {
    /// Round-major canonical order — the synchronous reference.
    Canonical,
    /// Follow a recorded trace's staging order, verifying every learner
    /// handoff against it.
    Replay(&'t ScheduleTrace),
}

/// Check a trace's staging events against the plan: right header, every
/// (round, shard) block present exactly once, per-shard rounds
/// ascending. Returns the staging order to execute.
fn validate_staging(
    trace: &ScheduleTrace,
    plan: &Plan,
    seed: u64,
) -> anyhow::Result<Vec<(u32, u32)>> {
    anyhow::ensure!(
        trace.seed == seed
            && trace.envs as usize == plan.n_envs
            && trace.rounds as usize == plan.rounds,
        "trace header (seed={} envs={} rounds={}) does not match this run \
         (seed={} envs={} rounds={})",
        trace.seed,
        trace.envs,
        trace.rounds,
        seed,
        plan.n_envs,
        plan.rounds
    );
    let mut next_round = vec![0u32; plan.n_envs];
    let mut order = Vec::with_capacity(plan.rounds * plan.n_envs);
    for h in trace.events_on(Edge::Staging) {
        anyhow::ensure!(
            (h.shard as usize) < plan.n_envs,
            "corrupt trace: staging event for unknown shard {}",
            h.shard
        );
        anyhow::ensure!(
            h.round == next_round[h.shard as usize],
            "partial batch: shard {} jumps from round {} to round {} in the trace",
            h.shard,
            next_round[h.shard as usize],
            h.round
        );
        anyhow::ensure!(h.version == 0, "corrupt trace: staging blocks carry policy version 0");
        next_round[h.shard as usize] += 1;
        order.push((h.round, h.shard));
    }
    for (shard, &got) in next_round.iter().enumerate() {
        anyhow::ensure!(
            got as usize == plan.rounds,
            "partial batch: shard {shard} has {got}/{} blocks in the trace",
            plan.rounds
        );
    }
    Ok(order)
}

fn emit(
    trace: &mut ScheduleTrace,
    cursor: &mut Option<TraceCursor>,
    edge: Edge,
    round: u32,
    shard: u32,
    version: u32,
) -> anyhow::Result<()> {
    if let Some(c) = cursor {
        c.expect(edge, round, shard, version)?;
    }
    trace.record(super::trace::Handoff { edge, round, shard, version });
    Ok(())
}

/// One learner round of the sequential engine — byte-for-byte the same
/// stage arithmetic the threaded executor runs, on one backend.
#[allow(clippy::too_many_arguments)]
fn seq_round(
    pipe: &Pipeline,
    cfg: &RunConfig,
    plan: &Plan,
    graph: &Graph,
    r: usize,
    blocks: Vec<EpisodeBlock>,
    ae: &mut AeStage,
    wm: &mut WmStage,
    dream: &mut DreamStage,
    eval: &mut EvalStage,
    trace: &mut ScheduleTrace,
    cursor: &mut Option<TraceCursor>,
) -> anyhow::Result<()> {
    let round = r as u32;
    for b in &blocks {
        emit(trace, cursor, Edge::AeIn, round, b.shard, ae.version)?;
    }
    ae.round(pipe, plan, cfg, r, &blocks)?;
    emit(trace, cursor, Edge::EncIn, round, SHARD_BATCH, round + 1)?;
    let episodes = encode_round(pipe, &ae.gnn, blocks)?;
    emit(trace, cursor, Edge::WmIn, round, SHARD_BATCH, round)?;
    let (z0, xm0) = wm.round(pipe, plan, cfg, r, episodes)?;
    emit(trace, cursor, Edge::DreamIn, round, SHARD_BATCH, round + 1)?;
    dream.round(pipe, plan, cfg, r, &wm.wm, &z0, &xm0)?;
    emit(trace, cursor, Edge::EvalIn, round, SHARD_BATCH, round + 1)?;
    eval.round(pipe, cfg, graph, r, &ae.gnn, &dream.ctrl, &wm.wm)
}

/// Capture the sequential engine's complete cross-round state at the
/// boundary after round `next_round - 1` (the exact inverse of the
/// restore block in [`run_sequential`]).
#[allow(clippy::too_many_arguments)]
fn seq_snapshot(
    cfg: &RunConfig,
    plan: &Plan,
    next_round: usize,
    ae: &AeStage,
    wm: &WmStage,
    dream: &DreamStage,
    eval: &EvalStage,
    pool: &EnvPool,
    trace: &ScheduleTrace,
) -> Checkpoint {
    Checkpoint {
        seed: cfg.seed,
        rounds: plan.rounds as u32,
        n_envs: plan.n_envs as u32,
        next_round: next_round as u32,
        ae: ae.snapshot(),
        wm: wm.snapshot(),
        dream: dream.snapshot(),
        evals: eval.evals.clone(),
        env_rngs: pool.rng_states(),
        trace_events: trace.events.clone(),
    }
}

fn run_sequential(
    factory: &BackendFactory,
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    graph: &Graph,
    schedule: Schedule,
    ckpt: Option<&CheckpointCfg>,
    resume: Option<Checkpoint>,
) -> anyhow::Result<AsyncOutcome> {
    let plan = Plan::new(cfg, acfg)?;
    if let Some(cp) = &resume {
        cp.validate_run(cfg.seed, plan.rounds as u32, plan.n_envs as u32)?;
        anyhow::ensure!(
            matches!(schedule, Schedule::Canonical),
            "resume cannot be combined with trace replay"
        );
    }
    let start = resume.as_ref().map(|cp| cp.next_round as usize).unwrap_or(0);
    let backend = factory()?;
    let pipe = Pipeline::new(backend.as_ref())?;
    let staging_order: Vec<(u32, u32)> = match &schedule {
        Schedule::Canonical => (start as u32..plan.rounds as u32)
            .flat_map(|r| (0..plan.n_envs as u32).map(move |s| (r, s)))
            .collect(),
        Schedule::Replay(t) => validate_staging(t, &plan, cfg.seed)?,
    };
    let mut cursor = match &schedule {
        Schedule::Replay(t) => Some(TraceCursor::new(t)),
        Schedule::Canonical => None,
    };
    let mut trace = ScheduleTrace::new(cfg.seed, plan.n_envs as u32, plan.rounds as u32);

    let cost = CostModel::new(cfg.device);
    let mut pool = EnvPool::new(
        graph,
        standard_library(),
        &cost,
        &EnvPoolConfig {
            n_envs: plan.n_envs,
            env: cfg.env.clone(),
            threads: 1,
            seed: cfg.seed,
            noise_std: 0.0,
        },
    );
    let encoder = StateEncoder::new(pipe.encoder.max_nodes, pipe.encoder.n_feats);
    let n_slots = pipe.dims.x1;

    let mut ae = AeStage::new(backend.as_ref(), cfg.seed)?;
    let mut wm = WmStage::new(backend.as_ref(), cfg.seed)?;
    let mut dream = DreamStage::new(backend.as_ref(), cfg.seed)?;
    let mut eval = EvalStage { evals: Vec::new() };
    if let Some(cp) = &resume {
        ae.restore(cp);
        wm.restore(cp);
        dream.restore(cp);
        eval.evals = cp.evals.clone();
        pool.restore_rng_states(&cp.env_rngs)?;
        trace.events = cp.trace_events.clone();
    }

    let mut stash: HashMap<(u32, u32), Vec<Episode>> = HashMap::new();
    let mut arrived = vec![0usize; plan.rounds];
    for slot in arrived.iter_mut().take(start) {
        *slot = plan.n_envs;
    }
    let mut next_round = start;
    for (round, shard) in staging_order {
        // Collect the block exactly as the threaded collector would:
        // this env's RNG stream advances through its rounds in order
        // (validate_staging guarantees per-shard ascending rounds), and
        // streams are per-env, so cross-shard order is irrelevant.
        let count = plan.env_counts[round as usize][shard as usize];
        let episodes = pool.map_env_at(shard as usize, |env, rng| {
            collect_random_episodes(env, &encoder, n_slots, count, cfg.collect_noop_prob, rng)
        });
        emit(&mut trace, &mut cursor, Edge::Staging, round, shard, 0)?;
        stash.insert((round, shard), episodes);
        arrived[round as usize] += 1;
        // Learner stages run as soon as their round is complete —
        // round-major, exactly the order the threaded learners consume.
        while next_round < plan.rounds && arrived[next_round] == plan.n_envs {
            let blocks: Vec<EpisodeBlock> = (0..plan.n_envs as u32)
                .map(|s| EpisodeBlock {
                    round: next_round as u32,
                    shard: s,
                    episodes: stash.remove(&(next_round as u32, s)).expect("round was complete"),
                })
                .collect();
            seq_round(
                &pipe, cfg, &plan, graph, next_round, blocks, &mut ae, &mut wm, &mut dream,
                &mut eval, &mut trace, &mut cursor,
            )?;
            next_round += 1;
            if let Some(c) = ckpt {
                if c.every > 0 && next_round % c.every == 0 {
                    seq_snapshot(cfg, &plan, next_round, &ae, &wm, &dream, &eval, &pool, &trace)
                        .write(&c.dir)?;
                }
            }
        }
    }
    anyhow::ensure!(next_round == plan.rounds, "incomplete schedule: {next_round} rounds ran");
    if let Some(c) = &cursor {
        c.finished()?;
    }
    Ok(AsyncOutcome {
        gnn: ae.gnn,
        wm: wm.wm,
        ctrl: dream.ctrl,
        ae_losses: ae.losses,
        wm_curve: wm.curve,
        dream_curve: dream.curve,
        evals: eval.evals,
        trace,
    })
}

/// The synchronous reference oracle: the async pipeline's per-round
/// arithmetic under the canonical (round-major) schedule, one thread,
/// one backend. `train_async` must match it bit-for-bit.
pub fn train_reference(
    factory: &BackendFactory,
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    graph: &Graph,
) -> anyhow::Result<AsyncOutcome> {
    run_sequential(factory, cfg, acfg, graph, Schedule::Canonical, None, None)
}

/// [`train_reference`] with crash safety: the sequential engine writes
/// an atomic checkpoint directly at every due round boundary and can
/// continue from one. This is what `rlflow train --checkpoint-every`
/// (without `--async`) runs; the resume contract matches
/// [`train_async_ckpt`] — interrupt + resume is bit-identical to the
/// uninterrupted run.
pub fn train_reference_ckpt(
    factory: &BackendFactory,
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    graph: &Graph,
    ckpt: Option<&CheckpointCfg>,
    resume: Option<Checkpoint>,
) -> anyhow::Result<AsyncOutcome> {
    run_sequential(factory, cfg, acfg, graph, Schedule::Canonical, ckpt, resume)
}

/// Replay a recorded schedule: re-execute the trace's handoff sequence
/// through the sequential engine, verifying every learner handoff
/// against the trace (divergence, torn traces and partial batches are
/// typed errors). Same seeds + same trace ⇒ bit-identical params to the
/// run that recorded it.
pub fn replay_trace(
    factory: &BackendFactory,
    cfg: &RunConfig,
    acfg: &AsyncTrainCfg,
    graph: &Graph,
    trace: &ScheduleTrace,
) -> anyhow::Result<AsyncOutcome> {
    run_sequential(factory, cfg, acfg, graph, Schedule::Replay(trace), None, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_splits_round_robin() {
        assert_eq!((0..4).map(|i| quota(10, 4, i)).collect::<Vec<_>>(), vec![3, 3, 2, 2]);
        assert_eq!((0..4).map(|i| quota(2, 4, i)).collect::<Vec<_>>(), vec![1, 1, 0, 0]);
        assert_eq!((0..4).map(|i| quota(0, 4, i)).sum::<usize>(), 0);
    }

    #[test]
    fn plan_budgets_are_conserved() {
        let cfg = RunConfig { collect_episodes: 7, envs: 3, ae_steps: 5, ..RunConfig::smoke() };
        let acfg = AsyncTrainCfg { rounds: 3, stage_threads: 1, staging_cap: 2, jitter: None };
        let plan = Plan::new(&cfg, &acfg).unwrap();
        assert_eq!(plan.n_envs, 3);
        let collected: usize = plan.env_counts.iter().flatten().sum();
        assert_eq!(collected, 7, "every episode is collected exactly once");
        assert_eq!(plan.ae_steps.iter().sum::<usize>(), 5);
        assert_eq!(plan.wm_steps.iter().sum::<usize>(), cfg.wm.total_steps);
        assert_eq!(plan.dream_epochs.iter().sum::<usize>(), cfg.dream_epochs);
    }

    #[test]
    fn validate_staging_rejects_partial_batches() {
        let cfg = RunConfig { collect_episodes: 4, envs: 2, ..RunConfig::smoke() };
        let acfg = AsyncTrainCfg { rounds: 2, stage_threads: 1, staging_cap: 2, jitter: None };
        let plan = Plan::new(&cfg, &acfg).unwrap();
        let mut t = ScheduleTrace::new(cfg.seed, 2, 2);
        for (r, s) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
            t.record(super::super::trace::Handoff {
                edge: Edge::Staging,
                round: r,
                shard: s,
                version: 0,
            });
        }
        assert!(validate_staging(&t, &plan, cfg.seed).is_ok());
        let mut missing = t.clone();
        missing.events.pop();
        let err = validate_staging(&missing, &plan, cfg.seed).unwrap_err();
        assert!(err.to_string().contains("partial batch"), "got: {err}");
        let mut reordered = t.clone();
        reordered.events.swap(1, 3); // shard 1 round 1 before round 0
        assert!(validate_staging(&reordered, &plan, cfg.seed).is_err());
        assert!(validate_staging(&t, &plan, cfg.seed ^ 1).is_err(), "seed mismatch must fail");
    }
}
