//! Crash-safe training checkpoints: the complete state of a round-based
//! training run (sequential or async), serialised to a versioned binary
//! file with the same atomic-write discipline as `serve/persist.rs`.
//!
//! A checkpoint captures everything the round engines thread between
//! rounds: the three [`ParamStore`]s (thetas *and* Adam moments), every
//! persistent RNG stream (per-stage learner streams and per-env
//! collector streams), the replay pools the learner stages accumulate
//! (AE state pool, WM episode pool), loss curves, eval history, the
//! round counter, and the schedule-trace prefix. Restoring one and
//! running the remaining rounds is bit-identical to never having
//! stopped — pinned by `tests/pipeline_async.rs`.
//!
//! On-disk format (`ckpt-NNNNN.rlck`, all little-endian):
//!
//! ```text
//! magic "RLCK" | u32 format version | u64 body length | body | u64 FNV-1a(body)
//! ```
//!
//! Floats are stored as raw bit patterns, so a round trip is exact. The
//! trailing hash plus the length prefix mean a torn or bit-flipped file
//! *never* loads: [`Checkpoint::load_latest`] skips invalid files with
//! a warning and falls back to the newest valid one. Writes go through
//! tmp + flush + `sync_all` + rename (failpoint sites `ckpt.write`,
//! `ckpt.fsync`, `ckpt.rename`, and `ckpt.done` after a successful
//! rename), so a kill at any instant leaves either the old set of
//! checkpoints or the old set plus one complete new file.

use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::agent::{CompactState, Episode};
use crate::graph::{onnx, Graph};
use crate::runtime::ParamStore;
use crate::util::failpoint;
use crate::wm::WmLosses;

use super::pipeline::EvalResult;
use super::pipeline_async::RoundEval;
use super::trace::{Edge, Handoff, ScheduleTrace, TraceSink};

const MAGIC: &[u8; 4] = b"RLCK";
const FORMAT_VERSION: u32 = 1;

/// Where and how often the round engines write checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    /// Directory checkpoint files are written into (created on demand).
    pub dir: PathBuf,
    /// Write after every N completed rounds (0 disables).
    pub every: usize,
}

/// Auto-encoder stage state at a round boundary.
#[derive(Debug, Clone)]
pub struct AeCkpt {
    /// GNN params + Adam moments.
    pub gnn: ParamStore,
    /// The stage's persistent RNG stream.
    pub rng: [u64; 4],
    /// Rounds of AE training completed (the published param version).
    pub version: u32,
    /// Per-step AE loss curve so far.
    pub losses: Vec<f32>,
    /// Accumulated state pool the AE trains on.
    pub states: Vec<CompactState>,
}

/// World-model stage state at a round boundary.
#[derive(Debug, Clone)]
pub struct WmCkpt {
    /// WM params + Adam moments.
    pub wm: ParamStore,
    /// The stage's persistent RNG stream.
    pub rng: [u64; 4],
    /// Global WM optimiser step (drives the LR schedule).
    pub step: u64,
    /// Per-step WM loss curve so far.
    pub curve: Vec<WmLosses>,
    /// Accumulated encoded-episode pool the WM trains on.
    pub episodes: Vec<Episode>,
}

/// Dream-PPO stage state at a round boundary.
#[derive(Debug, Clone)]
pub struct DreamCkpt {
    /// Controller params + Adam moments.
    pub ctrl: ParamStore,
    /// The stage's persistent RNG stream.
    pub rng: [u64; 4],
    /// Per-epoch dream return curve so far.
    pub curve: Vec<f32>,
}

/// Complete round-boundary state of a round-based training run.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Run seed (resume refuses a mismatched config).
    pub seed: u64,
    /// Total rounds the run was planned with.
    pub rounds: u32,
    /// Collector env-shard count the run was planned with.
    pub n_envs: u32,
    /// First round *not* yet completed; resume starts here.
    pub next_round: u32,
    /// Auto-encoder stage state.
    pub ae: AeCkpt,
    /// World-model stage state.
    pub wm: WmCkpt,
    /// Dream-PPO stage state.
    pub dream: DreamCkpt,
    /// Eval history for completed rounds.
    pub evals: Vec<RoundEval>,
    /// Per-env collector RNG streams, in shard order.
    pub env_rngs: Vec<[u64; 4]>,
    /// Schedule-trace events for completed rounds (the prefix a resumed
    /// run's recorded trace continues from).
    pub trace_events: Vec<Handoff>,
}

// ---- byte-level encoding ------------------------------------------------

fn fnv64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }
    fn rng(&mut self, s: [u64; 4]) {
        for w in s {
            self.u64(w);
        }
    }
}

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.b.len(),
            "checkpoint truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.b.len() - self.pos
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn len(&mut self) -> anyhow::Result<usize> {
        Ok(self.u32()? as usize)
    }
    fn str(&mut self) -> anyhow::Result<String> {
        let n = self.len()?;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }
    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.len()?;
        (0..n).map(|_| self.f32()).collect()
    }
    fn rng(&mut self) -> anyhow::Result<[u64; 4]> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }
}

fn enc_params(e: &mut Enc, p: &ParamStore) {
    e.str(&p.family);
    e.f32(p.t);
    e.u64(p.version);
    e.f32s(&p.theta);
    e.f32s(&p.m);
    e.f32s(&p.v);
}

fn dec_params(d: &mut Dec) -> anyhow::Result<ParamStore> {
    let family = d.str()?;
    let t = d.f32()?;
    let version = d.u64()?;
    let theta = d.f32s()?;
    let m = d.f32s()?;
    let v = d.f32s()?;
    anyhow::ensure!(
        m.len() == theta.len() && v.len() == theta.len(),
        "{family}: checkpoint moment vectors disagree with theta length"
    );
    Ok(ParamStore { family, theta, m, v, t, version })
}

fn enc_state(e: &mut Enc, s: &CompactState) {
    e.u32(s.n_live as u32);
    e.f32s(&s.feats);
    e.u32(s.edges.len() as u32);
    for &(a, b) in &s.edges {
        e.u16(a);
        e.u16(b);
    }
}

fn dec_state(d: &mut Dec) -> anyhow::Result<CompactState> {
    let n_live = d.u32()? as usize;
    let feats = d.f32s()?;
    let n = d.len()?;
    let edges = (0..n)
        .map(|_| Ok((d.u16()?, d.u16()?)))
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(CompactState { n_live, feats, edges })
}

fn enc_episode(e: &mut Enc, ep: &Episode) {
    e.u32(ep.states.len() as u32);
    for s in &ep.states {
        enc_state(e, s);
    }
    e.u32(ep.xmasks.len() as u32);
    for m in &ep.xmasks {
        e.f32s(m);
    }
    e.u32(ep.actions.len() as u32);
    for &(a, b) in &ep.actions {
        e.u16(a);
        e.u16(b);
    }
    e.f32s(&ep.rewards);
    e.f32s(&ep.dones);
    e.u32(ep.z.len() as u32);
    for z in &ep.z {
        e.f32s(z);
    }
    e.u64(ep.policy_version);
}

fn dec_episode(d: &mut Dec) -> anyhow::Result<Episode> {
    let states = (0..d.len()?).map(|_| dec_state(d)).collect::<anyhow::Result<Vec<_>>>()?;
    let xmasks = (0..d.len()?).map(|_| d.f32s()).collect::<anyhow::Result<Vec<_>>>()?;
    let n = d.len()?;
    let actions =
        (0..n).map(|_| Ok((d.u16()?, d.u16()?))).collect::<anyhow::Result<Vec<_>>>()?;
    let rewards = d.f32s()?;
    let dones = d.f32s()?;
    let z = (0..d.len()?).map(|_| d.f32s()).collect::<anyhow::Result<Vec<_>>>()?;
    let policy_version = d.u64()?;
    Ok(Episode { states, xmasks, actions, rewards, dones, z, policy_version })
}

fn enc_eval(e: &mut Enc, r: &EvalResult) -> anyhow::Result<()> {
    e.f64(r.best_improvement_pct);
    e.f64(r.final_improvement_pct);
    e.u64(r.steps as u64);
    e.u32(r.history.len() as u32);
    for &(x, l) in &r.history {
        e.u64(x as u64);
        e.u64(l as u64);
    }
    e.f64(r.mean_step_s);
    match &r.best_graph {
        Some(g) => {
            e.u8(1);
            e.str(&onnx::export(g, "checkpoint")?.to_string_compact());
        }
        None => e.u8(0),
    }
    Ok(())
}

fn dec_eval(d: &mut Dec) -> anyhow::Result<EvalResult> {
    let best_improvement_pct = d.f64()?;
    let final_improvement_pct = d.f64()?;
    let steps = d.u64()? as usize;
    let n = d.len()?;
    let history = (0..n)
        .map(|_| Ok((d.u64()? as usize, d.u64()? as usize)))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let mean_step_s = d.f64()?;
    let best_graph: Option<Graph> = match d.u8()? {
        0 => None,
        _ => Some(onnx::import(&crate::util::json::parse(&d.str()?)?)?),
    };
    Ok(EvalResult {
        best_improvement_pct,
        final_improvement_pct,
        steps,
        history,
        mean_step_s,
        best_graph,
    })
}

fn enc_handoff(e: &mut Enc, h: &Handoff) {
    let rank = Edge::ALL.iter().position(|x| *x == h.edge).unwrap() as u8;
    e.u8(rank);
    e.u32(h.round);
    e.u32(h.shard);
    e.u32(h.version);
}

fn dec_handoff(d: &mut Dec) -> anyhow::Result<Handoff> {
    let rank = d.u8()? as usize;
    anyhow::ensure!(rank < Edge::ALL.len(), "checkpoint trace edge rank {rank} out of range");
    Ok(Handoff { edge: Edge::ALL[rank], round: d.u32()?, shard: d.u32()?, version: d.u32()? })
}

impl Checkpoint {
    /// Serialise to the framed `RLCK` byte format.
    pub fn encode(&self) -> anyhow::Result<Vec<u8>> {
        let mut e = Enc::default();
        e.u64(self.seed);
        e.u32(self.rounds);
        e.u32(self.n_envs);
        e.u32(self.next_round);
        enc_params(&mut e, &self.ae.gnn);
        enc_params(&mut e, &self.wm.wm);
        enc_params(&mut e, &self.dream.ctrl);
        e.rng(self.ae.rng);
        e.u32(self.ae.version);
        e.f32s(&self.ae.losses);
        e.u32(self.ae.states.len() as u32);
        for s in &self.ae.states {
            enc_state(&mut e, s);
        }
        e.rng(self.wm.rng);
        e.u64(self.wm.step);
        e.u32(self.wm.curve.len() as u32);
        for l in &self.wm.curve {
            e.f32(l.total);
            e.f32(l.nll);
            e.f32(l.reward_mse);
            e.f32(l.mask_bce);
            e.f32(l.done_bce);
        }
        e.u32(self.wm.episodes.len() as u32);
        for ep in &self.wm.episodes {
            enc_episode(&mut e, ep);
        }
        e.rng(self.dream.rng);
        e.f32s(&self.dream.curve);
        e.u32(self.evals.len() as u32);
        for re in &self.evals {
            e.u32(re.round);
            e.u32(re.results.len() as u32);
            for r in &re.results {
                enc_eval(&mut e, r)?;
            }
        }
        e.u32(self.env_rngs.len() as u32);
        for &s in &self.env_rngs {
            e.rng(s);
        }
        e.u32(self.trace_events.len() as u32);
        for h in &self.trace_events {
            enc_handoff(&mut e, h);
        }
        let body = e.buf;
        let mut out = Vec::with_capacity(body.len() + 24);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(body.len() as u64).to_le_bytes());
        out.extend_from_slice(&body);
        out.extend_from_slice(&fnv64(&body).to_le_bytes());
        Ok(out)
    }

    /// Parse the framed byte format, rejecting torn or corrupt files
    /// (bad magic, short body, hash mismatch, trailing garbage).
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
        anyhow::ensure!(bytes.len() >= 24, "checkpoint too short to hold a frame");
        anyhow::ensure!(&bytes[..4] == MAGIC, "bad checkpoint magic");
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "unsupported checkpoint format version {version} (this build reads {FORMAT_VERSION})"
        );
        let body_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        anyhow::ensure!(
            bytes.len() == 16 + body_len + 8,
            "checkpoint torn: frame promises {} body bytes, file holds {}",
            body_len,
            bytes.len().saturating_sub(24)
        );
        let body = &bytes[16..16 + body_len];
        let want = u64::from_le_bytes(bytes[16 + body_len..].try_into().unwrap());
        anyhow::ensure!(fnv64(body) == want, "checkpoint integrity hash mismatch");
        let mut d = Dec { b: body, pos: 0 };
        let seed = d.u64()?;
        let rounds = d.u32()?;
        let n_envs = d.u32()?;
        let next_round = d.u32()?;
        let gnn = dec_params(&mut d)?;
        let wm_params = dec_params(&mut d)?;
        let ctrl = dec_params(&mut d)?;
        let ae_rng = d.rng()?;
        let ae_version = d.u32()?;
        let ae_losses = d.f32s()?;
        let ae_states =
            (0..d.len()?).map(|_| dec_state(&mut d)).collect::<anyhow::Result<Vec<_>>>()?;
        let wm_rng = d.rng()?;
        let wm_step = d.u64()?;
        let wm_curve = (0..d.len()?)
            .map(|_| {
                Ok(WmLosses {
                    total: d.f32()?,
                    nll: d.f32()?,
                    reward_mse: d.f32()?,
                    mask_bce: d.f32()?,
                    done_bce: d.f32()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let wm_episodes =
            (0..d.len()?).map(|_| dec_episode(&mut d)).collect::<anyhow::Result<Vec<_>>>()?;
        let dream_rng = d.rng()?;
        let dream_curve = d.f32s()?;
        let evals = (0..d.len()?)
            .map(|_| {
                let round = d.u32()?;
                let results =
                    (0..d.len()?).map(|_| dec_eval(&mut d)).collect::<anyhow::Result<Vec<_>>>()?;
                Ok(RoundEval { round, results })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let env_rngs = (0..d.len()?).map(|_| d.rng()).collect::<anyhow::Result<Vec<_>>>()?;
        let trace_events =
            (0..d.len()?).map(|_| dec_handoff(&mut d)).collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(d.pos == body.len(), "checkpoint has {} trailing bytes", body.len() - d.pos);
        Ok(Checkpoint {
            seed,
            rounds,
            n_envs,
            next_round,
            ae: AeCkpt {
                gnn,
                rng: ae_rng,
                version: ae_version,
                losses: ae_losses,
                states: ae_states,
            },
            wm: WmCkpt {
                wm: wm_params,
                rng: wm_rng,
                step: wm_step,
                curve: wm_curve,
                episodes: wm_episodes,
            },
            dream: DreamCkpt { ctrl, rng: dream_rng, curve: dream_curve },
            evals,
            env_rngs,
            trace_events,
        })
    }

    /// File name for the checkpoint at this round boundary.
    pub fn file_name(&self) -> String {
        format!("ckpt-{:05}.rlck", self.next_round)
    }

    /// Atomically write into `dir` (tmp + flush + fsync + rename, same
    /// discipline as the serve cache): a kill at any instant leaves
    /// either no new file or one complete, hash-valid file. Fires the
    /// `ckpt.write` / `ckpt.fsync` / `ckpt.rename` failpoints around the
    /// respective syscalls and `ckpt.done` after the rename commits.
    pub fn write(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating checkpoint dir {}: {e}", dir.display()))?;
        let bytes = self.encode()?;
        let name = self.file_name();
        let path = dir.join(&name);
        let tmp = dir.join(format!("{name}.tmp"));
        failpoint::check("ckpt.write")?;
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.flush()?;
            failpoint::check("ckpt.fsync")?;
            f.sync_all()?;
        }
        failpoint::check("ckpt.rename")?;
        std::fs::rename(&tmp, &path)?;
        failpoint::fire("ckpt.done");
        Ok(path)
    }

    /// Load and validate one checkpoint file.
    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {}: {e}", path.display()))?;
        Self::decode(&bytes).map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))
    }

    /// Load the newest valid checkpoint in `dir`, skipping torn or
    /// corrupt files with a warning (a half-written checkpoint is never
    /// loaded — it fails the frame/hash checks). Returns `Ok(None)` for
    /// an empty or absent directory.
    pub fn load_latest(dir: &Path) -> anyhow::Result<Option<Checkpoint>> {
        let rd = match std::fs::read_dir(dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => anyhow::bail!("reading checkpoint dir {}: {e}", dir.display()),
        };
        let mut names: Vec<String> = rd
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("ckpt-") && n.ends_with(".rlck"))
            .collect();
        names.sort();
        while let Some(name) = names.pop() {
            match Self::load(&dir.join(&name)) {
                Ok(cp) => return Ok(Some(cp)),
                Err(e) => eprintln!("rlflow: skipping invalid checkpoint: {e}"),
            }
        }
        Ok(None)
    }

    /// Refuse to resume into a run whose plan shape differs from the
    /// checkpointed one.
    pub fn validate_run(&self, seed: u64, rounds: u32, n_envs: u32) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.seed == seed && self.rounds == rounds && self.n_envs == n_envs,
            "checkpoint was taken by a run with seed={} rounds={} envs={}, \
             this run has seed={seed} rounds={rounds} envs={n_envs}",
            self.seed,
            self.rounds,
            self.n_envs
        );
        anyhow::ensure!(
            self.next_round <= rounds,
            "checkpoint is ahead of the plan: next round {} of {rounds}",
            self.next_round
        );
        Ok(())
    }
}

// ---- threaded-engine assembly -------------------------------------------

#[derive(Default)]
struct Pending {
    env_rngs: Option<Vec<[u64; 4]>>,
    ae: Option<AeCkpt>,
    wm: Option<WmCkpt>,
    dream: Option<DreamCkpt>,
    evals: Option<Vec<RoundEval>>,
}

impl Pending {
    fn complete(&self) -> bool {
        self.env_rngs.is_some()
            && self.ae.is_some()
            && self.wm.is_some()
            && self.dream.is_some()
            && self.evals.is_some()
    }
}

/// Checkpoint collector for the threaded engine, where the six stage
/// threads cross a given round boundary at different wall-clock times:
/// each stage deposits a clone of its state immediately after finishing
/// a due round, and whichever deposit completes the set serialises and
/// writes the checkpoint. Deposited state is captured *at* the boundary,
/// so stages are free to run ahead while the file is written.
pub struct CheckpointAssembler {
    cfg: CheckpointCfg,
    seed: u64,
    rounds: u32,
    n_envs: u32,
    sink: TraceSink,
    pending: Mutex<HashMap<u32, Pending>>,
}

impl CheckpointAssembler {
    /// Build an assembler for one run. `sink` is the run's shared trace
    /// sink; the checkpoint stores its events filtered to completed
    /// rounds, in canonical order.
    pub fn new(cfg: CheckpointCfg, seed: u64, rounds: u32, n_envs: u32, sink: TraceSink) -> Self {
        Self { cfg, seed, rounds, n_envs, sink, pending: Mutex::new(HashMap::new()) }
    }

    /// Whether completing `round` should deposit checkpoint state.
    pub fn due(&self, round: u32) -> bool {
        self.cfg.every > 0 && (round as usize + 1) % self.cfg.every == 0
    }

    fn put(
        &self,
        round: u32,
        fill: impl FnOnce(&mut Pending),
    ) -> anyhow::Result<Option<PathBuf>> {
        if !self.due(round) {
            return Ok(None);
        }
        let ready = {
            let mut map = self.pending.lock().unwrap();
            let p = map.entry(round).or_default();
            fill(p);
            if p.complete() {
                map.remove(&round)
            } else {
                None
            }
        };
        match ready {
            Some(p) => self.write_round(round, p).map(Some),
            None => Ok(None),
        }
    }

    /// Collector deposit: per-env RNG streams after finishing `round`.
    pub fn deposit_env(&self, round: u32, rngs: Vec<[u64; 4]>) -> anyhow::Result<Option<PathBuf>> {
        self.put(round, |p| p.env_rngs = Some(rngs))
    }

    /// AE-stage deposit after finishing `round`.
    pub fn deposit_ae(&self, round: u32, ae: AeCkpt) -> anyhow::Result<Option<PathBuf>> {
        self.put(round, |p| p.ae = Some(ae))
    }

    /// WM-stage deposit after finishing `round`.
    pub fn deposit_wm(&self, round: u32, wm: WmCkpt) -> anyhow::Result<Option<PathBuf>> {
        self.put(round, |p| p.wm = Some(wm))
    }

    /// Dream-stage deposit after finishing `round`.
    pub fn deposit_dream(&self, round: u32, dream: DreamCkpt) -> anyhow::Result<Option<PathBuf>> {
        self.put(round, |p| p.dream = Some(dream))
    }

    /// Eval-stage deposit after finishing `round` (the full history so
    /// far).
    pub fn deposit_evals(
        &self,
        round: u32,
        evals: Vec<RoundEval>,
    ) -> anyhow::Result<Option<PathBuf>> {
        self.put(round, |p| p.evals = Some(evals))
    }

    fn write_round(&self, round: u32, p: Pending) -> anyhow::Result<PathBuf> {
        // Every stage has finished `round`, so all handoffs for rounds
        // <= round are recorded; later rounds (stages running ahead) are
        // filtered out. Canonical order keeps the stored prefix
        // schedule-independent.
        let snap = self.sink.snapshot();
        let events: Vec<Handoff> =
            snap.events.into_iter().filter(|h| h.round <= round).collect();
        let trace = ScheduleTrace { seed: self.seed, envs: self.n_envs, rounds: self.rounds, events };
        let cp = Checkpoint {
            seed: self.seed,
            rounds: self.rounds,
            n_envs: self.n_envs,
            next_round: round + 1,
            ae: p.ae.unwrap(),
            wm: p.wm.unwrap(),
            dream: p.dream.unwrap(),
            evals: p.evals.unwrap(),
            env_rngs: p.env_rngs.unwrap(),
            trace_events: trace.canonical().events,
        };
        cp.write(&self.cfg.dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, PadMode};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rlflow-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn params(family: &str, n: usize) -> ParamStore {
        ParamStore {
            family: family.into(),
            theta: (0..n).map(|i| i as f32 * 0.5 - 1.0).collect(),
            m: (0..n).map(|i| i as f32 * -0.25).collect(),
            v: (0..n).map(|i| i as f32 * 0.125).collect(),
            t: 3.0,
            version: 7,
        }
    }

    fn sample() -> Checkpoint {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        let g = b.finish();
        let state = CompactState { n_live: 2, feats: vec![0.5; 8], edges: vec![(0, 1)] };
        let ep = Episode {
            states: vec![state.clone(), state.clone()],
            xmasks: vec![vec![1.0, 0.0], vec![0.0, 1.0]],
            actions: vec![(3, 9)],
            rewards: vec![0.25],
            dones: vec![1.0],
            z: vec![vec![0.1, -0.2], vec![0.3, 0.4]],
            policy_version: 0,
        };
        Checkpoint {
            seed: 42,
            rounds: 4,
            n_envs: 2,
            next_round: 2,
            ae: AeCkpt {
                gnn: params("gnn", 5),
                rng: [1, 2, 3, 4],
                version: 2,
                losses: vec![0.9, 0.8],
                states: vec![state],
            },
            wm: WmCkpt {
                wm: params("wm", 3),
                rng: [5, 6, 7, 8],
                step: 11,
                curve: vec![WmLosses {
                    total: 1.0,
                    nll: 0.5,
                    reward_mse: 0.25,
                    mask_bce: 0.125,
                    done_bce: 0.0625,
                }],
                episodes: vec![ep],
            },
            dream: DreamCkpt { ctrl: params("ctrl", 4), rng: [9, 10, 11, 12], curve: vec![1.5] },
            evals: vec![RoundEval {
                round: 0,
                results: vec![EvalResult {
                    best_improvement_pct: 3.25,
                    final_improvement_pct: 1.5,
                    steps: 6,
                    history: vec![(2, 17)],
                    mean_step_s: 0.001,
                    best_graph: Some(g),
                }],
            }],
            env_rngs: vec![[13, 14, 15, 16], [17, 18, 19, 20]],
            trace_events: vec![Handoff { edge: Edge::Staging, round: 0, shard: 1, version: 0 }],
        }
    }

    #[test]
    fn encode_decode_round_trip_is_exact() {
        let cp = sample();
        let back = Checkpoint::decode(&cp.encode().unwrap()).unwrap();
        assert_eq!(back.seed, cp.seed);
        assert_eq!(back.next_round, 2);
        assert_eq!(back.ae.gnn.theta, cp.ae.gnn.theta);
        assert_eq!(back.ae.gnn.m, cp.ae.gnn.m);
        assert_eq!(back.ae.gnn.version, 7);
        assert_eq!(back.ae.rng, cp.ae.rng);
        assert_eq!(back.wm.step, 11);
        assert_eq!(back.wm.episodes[0].actions, cp.wm.episodes[0].actions);
        assert_eq!(back.wm.episodes[0].z, cp.wm.episodes[0].z);
        assert_eq!(back.dream.ctrl.v, cp.dream.ctrl.v);
        assert_eq!(back.env_rngs, cp.env_rngs);
        assert_eq!(back.trace_events, cp.trace_events);
        let e = &back.evals[0].results[0];
        assert_eq!(e.best_improvement_pct.to_bits(), 3.25f64.to_bits());
        assert_eq!(e.history, vec![(2, 17)]);
        assert!(e.best_graph.is_some());
        // Re-encoding the decoded checkpoint is a byte-level fixed point.
        assert_eq!(back.encode().unwrap(), cp.encode().unwrap());
    }

    #[test]
    fn torn_and_corrupt_files_never_load() {
        let bytes = sample().encode().unwrap();
        for cut in [0, 3, 16, bytes.len() / 2, bytes.len() - 1] {
            assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "cut at {cut} must not load");
        }
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        assert!(Checkpoint::decode(&flipped).is_err(), "bit flip must fail the hash");
        let mut extended = bytes;
        extended.push(0);
        assert!(Checkpoint::decode(&extended).is_err(), "trailing garbage must not load");
    }

    #[test]
    fn load_latest_skips_invalid_and_prefers_newest() {
        let dir = tmpdir("latest");
        let mut a = sample();
        a.next_round = 1;
        a.write(&dir).unwrap();
        let mut b = sample();
        b.next_round = 2;
        b.write(&dir).unwrap();
        // Newest file is torn garbage: must be skipped, not loaded.
        std::fs::write(dir.join("ckpt-00003.rlck"), b"RLCKgarbage").unwrap();
        let cp = Checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!(cp.next_round, 2, "newest *valid* checkpoint wins");
        assert!(Checkpoint::load_latest(&tmpdir("empty")).unwrap().is_none());
        assert!(Checkpoint::load_latest(Path::new("/definitely/not/here")).unwrap().is_none());
    }

    #[test]
    fn validate_run_rejects_mismatched_plans() {
        let cp = sample();
        cp.validate_run(42, 4, 2).unwrap();
        assert!(cp.validate_run(43, 4, 2).is_err());
        assert!(cp.validate_run(42, 5, 2).is_err());
        assert!(cp.validate_run(42, 4, 3).is_err());
    }
}
