//! Schedule traces: the async pipeline's determinism contract.
//!
//! Every cross-stage handoff in `pipeline_async` — a collected shard
//! block entering the staging buffer, a round of blocks consumed by the
//! auto-encoder, an encoded round handed to the world model, and so on
//! — is recorded as a [`Handoff`] (edge, batch round, env shard, param
//! version consumed). The recorded [`ScheduleTrace`] is the *complete*
//! description of the asynchronous schedule: replaying it through the
//! sequential engine re-executes the same handoff sequence, so
//! **same seeds + same trace ⇒ bit-identical final params**.
//!
//! The on-disk format is a self-describing text file:
//!
//! ```text
//! rlflow-trace v1 seed=42 envs=4 rounds=2 events=14
//! staging 0 1 0
//! staging 0 0 0
//! ae 0 0 0
//! ae 0 1 0
//! enc 0 - 1
//! ...
//! ```
//!
//! One line per event: `<edge> <round> <shard> <version>`, where shard
//! `-` is the [`SHARD_BATCH`] sentinel for whole-round handoffs. The
//! header's `events=N` count makes truncation detectable: a torn trace
//! (fewer lines than the header promises, or a malformed line) is a
//! typed load error, never a silent partial replay.

use std::path::Path;
use std::sync::{Arc, Mutex};

/// Sentinel shard id for handoffs that carry a whole round rather than
/// a single env shard (encoder/WM/dream/eval inputs).
pub const SHARD_BATCH: u32 = u32::MAX;

/// A cross-stage edge in the async pipeline's stage graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Edge {
    /// Collector shard → bounded staging buffer.
    Staging,
    /// Staging buffer → GNN auto-encoder trainer (per shard block).
    AeIn,
    /// Auto-encoder → encoder stage (whole round + fresh GNN params).
    EncIn,
    /// Encoder → world-model trainer (whole encoded round).
    WmIn,
    /// World model → dream-PPO controller trainer (whole round).
    DreamIn,
    /// Dream trainer → real-env evaluation (whole round).
    EvalIn,
}

impl Edge {
    /// All edges in canonical (upstream → downstream) order.
    pub const ALL: [Edge; 6] =
        [Edge::Staging, Edge::AeIn, Edge::EncIn, Edge::WmIn, Edge::DreamIn, Edge::EvalIn];

    /// Stable text name used in the trace file format.
    pub fn as_str(self) -> &'static str {
        match self {
            Edge::Staging => "staging",
            Edge::AeIn => "ae",
            Edge::EncIn => "enc",
            Edge::WmIn => "wm",
            Edge::DreamIn => "dream",
            Edge::EvalIn => "eval",
        }
    }

    /// Parse a trace-file edge name.
    pub fn parse(s: &str) -> anyhow::Result<Edge> {
        Edge::ALL
            .into_iter()
            .find(|e| e.as_str() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown trace edge {s:?}"))
    }

    fn rank(self) -> usize {
        Edge::ALL.iter().position(|e| *e == self).unwrap()
    }
}

/// One recorded cross-stage handoff.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Handoff {
    /// Which stage-graph edge the payload crossed.
    pub edge: Edge,
    /// Batch round the payload belongs to.
    pub round: u32,
    /// Env shard of the payload, or [`SHARD_BATCH`] for whole rounds.
    pub shard: u32,
    /// Param version consumed by the receiving stage (training rounds
    /// completed for the stage's input params; 0 = init).
    pub version: u32,
}

/// A complete recorded schedule: run identity (seed, env count, round
/// count) plus every handoff in the order the trace clock observed it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduleTrace {
    /// Run seed the schedule was recorded under.
    pub seed: u64,
    /// Number of env shards in the collector pool.
    pub envs: u32,
    /// Number of training rounds.
    pub rounds: u32,
    /// Handoffs in recorded order.
    pub events: Vec<Handoff>,
}

impl ScheduleTrace {
    /// An empty trace for a run with the given identity.
    pub fn new(seed: u64, envs: u32, rounds: u32) -> Self {
        Self { seed, envs, rounds, events: Vec::new() }
    }

    /// Append one handoff.
    pub fn record(&mut self, h: Handoff) {
        self.events.push(h);
    }

    /// Events on one edge, in recorded order.
    pub fn events_on(&self, edge: Edge) -> impl Iterator<Item = &Handoff> {
        self.events.iter().filter(move |h| h.edge == edge)
    }

    /// The schedule-independent normal form: events stably sorted by
    /// (edge, round, shard). Two runs of the same seed are equivalent
    /// iff their canonical traces are equal — timing may permute the
    /// recorded order of *independent* handoffs, never their content.
    pub fn canonical(&self) -> ScheduleTrace {
        let mut events = self.events.clone();
        events.sort_by_key(|h| (h.edge.rank(), h.round, h.shard, h.version));
        ScheduleTrace { events, ..*self }
    }

    /// Serialise to the `rlflow-trace v1` text format.
    pub fn to_text(&self) -> String {
        let mut out = format!(
            "rlflow-trace v1 seed={} envs={} rounds={} events={}\n",
            self.seed,
            self.envs,
            self.rounds,
            self.events.len()
        );
        for h in &self.events {
            out.push_str(h.edge.as_str());
            if h.shard == SHARD_BATCH {
                out.push_str(&format!(" {} - {}\n", h.round, h.version));
            } else {
                out.push_str(&format!(" {} {} {}\n", h.round, h.shard, h.version));
            }
        }
        out
    }

    /// Parse the text format, rejecting torn traces: a header event
    /// count that does not match the number of well-formed event lines
    /// is an error, so a truncated file can never replay as a shorter
    /// schedule.
    pub fn from_text(text: &str) -> anyhow::Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty trace file"))?;
        let mut fields = header.split_whitespace();
        anyhow::ensure!(
            fields.next() == Some("rlflow-trace") && fields.next() == Some("v1"),
            "not an rlflow-trace v1 header: {header:?}"
        );
        let mut seed = None;
        let mut envs = None;
        let mut rounds = None;
        let mut n_events = None;
        for kv in fields {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("malformed trace header field {kv:?}"))?;
            match k {
                "seed" => seed = Some(v.parse::<u64>()?),
                "envs" => envs = Some(v.parse::<u32>()?),
                "rounds" => rounds = Some(v.parse::<u32>()?),
                "events" => n_events = Some(v.parse::<usize>()?),
                other => anyhow::bail!("unknown trace header field {other:?}"),
            }
        }
        let (seed, envs, rounds, n_events) = match (seed, envs, rounds, n_events) {
            (Some(s), Some(e), Some(r), Some(n)) => (s, e, r, n),
            _ => anyhow::bail!("trace header missing seed/envs/rounds/events: {header:?}"),
        };
        let mut events = Vec::with_capacity(n_events);
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(
                parts.len() == 4,
                "torn trace: malformed event on line {} ({line:?})",
                i + 2
            );
            let edge = Edge::parse(parts[0])?;
            let round = parts[1].parse::<u32>()?;
            let shard =
                if parts[2] == "-" { SHARD_BATCH } else { parts[2].parse::<u32>()? };
            let version = parts[3].parse::<u32>()?;
            events.push(Handoff { edge, round, shard, version });
        }
        anyhow::ensure!(
            events.len() == n_events,
            "torn trace: header promises {n_events} events, file holds {}",
            events.len()
        );
        Ok(Self { seed, envs, rounds, events })
    }

    /// Write the trace file.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_text())
            .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))
    }

    /// Load and parse a trace file.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading trace {}: {e}", path.display()))?;
        Self::from_text(&text)
    }
}

/// Thread-shared recording handle: every stage thread appends handoffs
/// through the same sink, so the recorded order is the order the trace
/// clock (the sink's mutex) observed them in.
#[derive(Clone)]
pub struct TraceSink(Arc<Mutex<ScheduleTrace>>);

impl TraceSink {
    /// Wrap a trace for shared recording.
    pub fn new(trace: ScheduleTrace) -> Self {
        Self(Arc::new(Mutex::new(trace)))
    }

    /// Record one handoff.
    pub fn record(&self, edge: Edge, round: u32, shard: u32, version: u32) {
        self.0.lock().unwrap().record(Handoff { edge, round, shard, version });
    }

    /// Clone out the trace recorded so far.
    pub fn snapshot(&self) -> ScheduleTrace {
        self.0.lock().unwrap().clone()
    }
}

/// Replay-side verifier: per-edge FIFO cursors over an existing trace.
/// Each handoff the replaying engine is about to perform is checked
/// against the next expected event on that edge; any divergence (or a
/// trace that ends early) is a typed error rather than a silent drift.
pub struct TraceCursor {
    queues: Vec<std::collections::VecDeque<Handoff>>,
}

impl TraceCursor {
    /// Build cursors over `trace`, one FIFO per edge.
    pub fn new(trace: &ScheduleTrace) -> Self {
        let mut queues = vec![std::collections::VecDeque::new(); Edge::ALL.len()];
        for h in &trace.events {
            queues[h.edge.rank()].push_back(*h);
        }
        Self { queues }
    }

    /// Consume the next expected event on `edge`, verifying it matches
    /// the handoff the engine is about to perform.
    pub fn expect(&mut self, edge: Edge, round: u32, shard: u32, version: u32) -> anyhow::Result<()> {
        let got = self.queues[edge.rank()].pop_front().ok_or_else(|| {
            anyhow::anyhow!(
                "torn trace: no more {} events, but replay needs round {round} shard {shard}",
                edge.as_str()
            )
        })?;
        let want = Handoff { edge, round, shard, version };
        anyhow::ensure!(
            got == want,
            "trace divergence on {} edge: trace has round {} shard {} version {}, \
             replay performs round {round} shard {shard} version {version}",
            edge.as_str(),
            got.round,
            got.shard,
            got.version
        );
        Ok(())
    }

    /// Verify the whole trace was consumed (no events left over).
    pub fn finished(&self) -> anyhow::Result<()> {
        for (q, edge) in self.queues.iter().zip(Edge::ALL) {
            anyhow::ensure!(
                q.is_empty(),
                "trace divergence: {} unreplayed events left on the {} edge",
                q.len(),
                edge.as_str()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ScheduleTrace {
        let mut t = ScheduleTrace::new(42, 2, 1);
        t.record(Handoff { edge: Edge::Staging, round: 0, shard: 1, version: 0 });
        t.record(Handoff { edge: Edge::Staging, round: 0, shard: 0, version: 0 });
        t.record(Handoff { edge: Edge::AeIn, round: 0, shard: 0, version: 0 });
        t.record(Handoff { edge: Edge::AeIn, round: 0, shard: 1, version: 0 });
        t.record(Handoff { edge: Edge::EncIn, round: 0, shard: SHARD_BATCH, version: 1 });
        t
    }

    #[test]
    fn text_round_trip_is_lossless() {
        let t = sample();
        let parsed = ScheduleTrace::from_text(&t.to_text()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn truncated_file_is_a_torn_trace_error() {
        let text = sample().to_text();
        let cut: String =
            text.lines().take(4).map(|l| format!("{l}\n")).collect();
        let err = ScheduleTrace::from_text(&cut).unwrap_err();
        assert!(err.to_string().contains("torn trace"), "got: {err}");
    }

    #[test]
    fn malformed_event_line_is_a_torn_trace_error() {
        let mut text = sample().to_text();
        text.push_str("staging 1\n");
        let err = ScheduleTrace::from_text(&text).unwrap_err();
        assert!(err.to_string().contains("torn trace"), "got: {err}");
    }

    #[test]
    fn canonical_is_schedule_independent() {
        let t = sample();
        let mut reordered = t.clone();
        reordered.events.swap(0, 1); // staging arrivals raced the other way
        assert_ne!(reordered, t);
        assert_eq!(reordered.canonical(), t.canonical());
    }

    #[test]
    fn cursor_flags_divergence_and_leftovers() {
        let t = sample();
        let mut c = TraceCursor::new(&t);
        c.expect(Edge::Staging, 0, 1, 0).unwrap();
        assert!(c.expect(Edge::Staging, 0, 9, 0).is_err(), "wrong shard must diverge");
        let mut c2 = TraceCursor::new(&t);
        c2.expect(Edge::Staging, 0, 1, 0).unwrap();
        assert!(c2.finished().is_err(), "unconsumed events must be flagged");
    }
}
