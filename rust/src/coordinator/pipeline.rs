//! Pipeline stages. One `Pipeline` owns the backend handle, the typed
//! policy/world-model APIs and the state encoder; every stage is a pure
//! function over parameter stores + episodes, so the CLI, the examples and
//! the experiment drivers compose them freely — on either backend.

use std::time::Instant;

use crate::agent::{
    gae, Action, ActionSpace, Episode, ObsBatch, PolicyDims, PolicyNet, PpoBuffer, PpoCfg,
    PpoStats,
};
use crate::env::{Env, EnvPool, StateEncoder};
use crate::graph::Graph;
use crate::runtime::{Backend, ParamStore, TensorView};
use crate::util::Rng;
use crate::wm::{DreamEnv, WmLosses, WmTrainCfg, WmTrainer, WorldModel};

pub struct Pipeline<'e> {
    pub backend: &'e dyn Backend,
    pub dims: PolicyDims,
    pub policy: PolicyNet<'e>,
    pub world: WorldModel<'e>,
    pub encoder: StateEncoder,
    n: usize,
    f: usize,
    b_enc: usize,
}

#[derive(Debug, Clone, Default)]
pub struct EvalResult {
    /// Best runtime improvement over the episode, percent (Fig. 6's metric).
    pub best_improvement_pct: f64,
    pub final_improvement_pct: f64,
    pub steps: usize,
    /// (xfer slot, location) actions taken — Fig. 10's heatmap data.
    pub history: Vec<(usize, usize)>,
    /// Mean wall-clock seconds per environment step (Fig. 7 numerator).
    pub mean_step_s: f64,
    pub best_graph: Option<Graph>,
}

/// Owned dense (feats, adj, mask) buffers for one GNN batch.
struct StateBatch {
    b: usize,
    n: usize,
    f: usize,
    feats: Vec<f32>,
    adj: Vec<f32>,
    mask: Vec<f32>,
}

impl StateBatch {
    fn views(&self) -> [TensorView<'_>; 3] {
        [
            TensorView::f32(&self.feats, &[self.b, self.n, self.f]),
            TensorView::f32(&self.adj, &[self.b, self.n, self.n]),
            TensorView::f32(&self.mask, &[self.b, self.n]),
        ]
    }
}

impl<'e> Pipeline<'e> {
    /// How many `B_ENC` state batches [`Self::encode_episodes`] stages per
    /// `exec_with_params_batch` call: enough to amortise dispatch, small
    /// enough to keep staged input memory bounded on long episode sets.
    pub const ENC_CHUNK_GROUP: usize = 4;

    pub fn new(backend: &'e dyn Backend) -> anyhow::Result<Self> {
        let n = backend.hp("MAX_NODES")?;
        let f = backend.hp("NODE_FEATS")?;
        Ok(Self {
            backend,
            dims: PolicyDims::from_manifest(backend.manifest())?,
            policy: PolicyNet::new(backend)?,
            world: WorldModel::new(backend)?,
            encoder: StateEncoder::new(n, f),
            n,
            f,
            b_enc: backend.hp("B_ENC")?,
        })
    }

    // ------------------------------------------------------------------
    // Stage 2: GNN auto-encoder
    // ------------------------------------------------------------------

    fn batch_states(&self, states: &[&crate::agent::CompactState]) -> StateBatch {
        let b = states.len();
        let (n, f) = (self.n, self.f);
        let mut batch = StateBatch {
            b,
            n,
            f,
            feats: vec![0.0f32; b * n * f],
            adj: vec![0.0f32; b * n * n],
            mask: vec![0.0f32; b * n],
        };
        for (i, s) in states.iter().enumerate() {
            s.write_dense(
                n,
                f,
                &mut batch.feats[i * n * f..(i + 1) * n * f],
                &mut batch.adj[i * n * n..(i + 1) * n * n],
                &mut batch.mask[i * n..(i + 1) * n],
            );
        }
        batch
    }

    /// Train the graph auto-encoder on random state minibatches.
    pub fn train_gnn_ae(
        &self,
        gnn: &mut ParamStore,
        episodes: &[Episode],
        steps: usize,
        lr: f32,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<f32>> {
        let pool: Vec<&crate::agent::CompactState> =
            episodes.iter().flat_map(|e| e.states.iter()).collect();
        self.train_gnn_ae_states(gnn, &pool, steps, lr, rng)
    }

    /// [`Pipeline::train_gnn_ae`] on an explicit state pool. The async
    /// pipeline's AE stage accumulates states across rounds and samples
    /// from the growing pool directly; the episode-based entry point
    /// above delegates here, so both paths share one sampling loop.
    pub fn train_gnn_ae_states(
        &self,
        gnn: &mut ParamStore,
        pool: &[&crate::agent::CompactState],
        steps: usize,
        lr: f32,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!pool.is_empty(), "no states to train on");
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let batch: Vec<&crate::agent::CompactState> =
                (0..self.b_enc).map(|_| pool[rng.below(pool.len())]).collect();
            let state_batch = self.batch_states(&batch);
            let mut rest: Vec<TensorView> = state_batch.views().to_vec();
            rest.push(TensorView::ScalarF32(lr));
            // In-place Adam absorb on the host backend (no theta copies).
            let out = self.backend.train_step("gnn_ae_train", gnn, &rest)?;
            drop(rest);
            losses.push(out[0].data[0]);
        }
        Ok(losses)
    }

    // ------------------------------------------------------------------
    // Stage 3: latent encoding
    // ------------------------------------------------------------------

    /// Fill `ep.z` for every state of every episode (batched).
    ///
    /// Chunks of `B_ENC` states are dispatched several-at-a-time through
    /// [`exec_with_params_batch`](Backend::exec_with_params_batch) —
    /// bounding staged memory to [`Self::ENC_CHUNK_GROUP`] batches while
    /// amortising per-call dispatch. Chunking and the pad-by-first-state
    /// rule are unchanged, so every latent stays bit-identical to the
    /// one-call-per-chunk history.
    pub fn encode_episodes(
        &self,
        gnn: &ParamStore,
        episodes: &mut [Episode],
    ) -> anyhow::Result<()> {
        // Flatten (episode, state) indices.
        let mut slots: Vec<(usize, usize)> = Vec::new();
        for (ei, ep) in episodes.iter().enumerate() {
            for si in 0..ep.states.len() {
                slots.push((ei, si));
            }
        }
        for ep in episodes.iter_mut() {
            ep.z = vec![Vec::new(); ep.states.len()];
        }
        let zd = self.dims.zdim;
        for group in slots.chunks(self.b_enc * Self::ENC_CHUNK_GROUP) {
            let batches: Vec<StateBatch> = group
                .chunks(self.b_enc)
                .map(|chunk| {
                    let mut states: Vec<&crate::agent::CompactState> = chunk
                        .iter()
                        .map(|&(ei, si)| &episodes[ei].states[si])
                        .collect();
                    // Pad the final partial batch by repeating the first state.
                    while states.len() < self.b_enc {
                        states.push(states[0]);
                    }
                    self.batch_states(&states)
                })
                .collect();
            let rests: Vec<Vec<TensorView>> =
                batches.iter().map(|b| b.views().to_vec()).collect();
            let outs = self.backend.exec_with_params_batch("gnn_encode_b", gnn, &rests)?;
            for (chunk, out) in group.chunks(self.b_enc).zip(&outs) {
                let zs = &out[0].data;
                for (i, &(ei, si)) in chunk.iter().enumerate() {
                    episodes[ei].z[si] = zs[i * zd..(i + 1) * zd].to_vec();
                }
            }
        }
        Ok(())
    }

    /// Encode one live environment state (the acting path).
    pub fn encode_state(&self, gnn: &ParamStore, g: &Graph) -> anyhow::Result<Vec<f32>> {
        let e = self.encoder.encode(g);
        let out = self.backend.exec_with_params(
            "gnn_encode_1",
            gnn,
            &[
                TensorView::f32(&e.feats, &[1, self.n, self.f]),
                TensorView::f32(&e.adj, &[1, self.n, self.n]),
                TensorView::f32(&e.mask, &[1, self.n]),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    /// Encode several live graphs in one batched pass: full `B_ENC`-wide
    /// groups go through `gnn_encode_b` and any remainder rows go through
    /// `gnn_encode_1` — never padded, so a pass with few alive rows costs
    /// exactly the per-row path it replaced (each GNN forward is O(n²F));
    /// each program family is dispatched as a single
    /// [`exec_with_params_batch`](Backend::exec_with_params_batch). Rows
    /// encode independently, so each returned latent is bit-identical to
    /// a lone `encode_state` call on that graph.
    pub fn encode_graphs(
        &self,
        gnn: &ParamStore,
        graphs: &[&Graph],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        if graphs.is_empty() {
            return Ok(Vec::new());
        }
        let (n, f, be) = (self.n, self.f, self.b_enc);
        let zd = self.dims.zdim;
        let full = graphs.len() / be * be;
        let pack = |chunk: &[&Graph]| -> StateBatch {
            let b = chunk.len();
            let mut batch = StateBatch {
                b,
                n,
                f,
                feats: vec![0.0f32; b * n * f],
                adj: vec![0.0f32; b * n * n],
                mask: vec![0.0f32; b * n],
            };
            for (slot, &g) in chunk.iter().enumerate() {
                let e = self.encoder.encode(g);
                batch.feats[slot * n * f..(slot + 1) * n * f].copy_from_slice(&e.feats);
                batch.adj[slot * n * n..(slot + 1) * n * n].copy_from_slice(&e.adj);
                batch.mask[slot * n..(slot + 1) * n].copy_from_slice(&e.mask);
            }
            batch
        };
        let mut zs = Vec::with_capacity(graphs.len());
        if full > 0 {
            let batches: Vec<StateBatch> =
                graphs[..full].chunks_exact(be).map(pack).collect();
            let rests: Vec<Vec<TensorView>> =
                batches.iter().map(|b| b.views().to_vec()).collect();
            let outs = self.backend.exec_with_params_batch("gnn_encode_b", gnn, &rests)?;
            for out in outs {
                for slot in 0..be {
                    zs.push(out[0].data[slot * zd..(slot + 1) * zd].to_vec());
                }
            }
        }
        if full < graphs.len() {
            let singles: Vec<StateBatch> =
                graphs[full..].iter().map(|&g| pack(&[g])).collect();
            let rests: Vec<Vec<TensorView>> =
                singles.iter().map(|b| b.views().to_vec()).collect();
            let outs = self.backend.exec_with_params_batch("gnn_encode_1", gnn, &rests)?;
            for out in outs {
                zs.push(out[0].data[..zd].to_vec());
            }
        }
        Ok(zs)
    }

    // ------------------------------------------------------------------
    // Stage 4: world-model training
    // ------------------------------------------------------------------

    pub fn train_wm(
        &self,
        wm: &mut ParamStore,
        episodes: &[Episode],
        cfg: &WmTrainCfg,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<WmLosses>> {
        let trainer = WmTrainer::new(self.backend)?;
        let mut curve = Vec::with_capacity(cfg.total_steps);
        for step in 0..cfg.total_steps {
            let lr = cfg.lr_at(step);
            curve.push(trainer.train_step(wm, episodes, lr, cfg.reward_scale, rng)?);
        }
        Ok(curve)
    }

    // ------------------------------------------------------------------
    // Stage 5: controller training inside the dream
    // ------------------------------------------------------------------

    /// PPO entirely inside the imagined environment. Returns the mean
    /// *predicted* episode reward per epoch (Fig. 9's curve).
    #[allow(clippy::too_many_arguments)]
    pub fn train_controller_dream(
        &self,
        ctrl: &mut ParamStore,
        wm: &ParamStore,
        episodes: &[Episode],
        epochs: usize,
        horizon: usize,
        temperature: f32,
        reward_scale: f32,
        ppo: &PpoCfg,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<f32>> {
        // Seed pool: initial latents + masks of real episodes.
        let z0: Vec<Vec<f32>> = episodes
            .iter()
            .filter(|e| !e.z.is_empty())
            .map(|e| e.z[0].clone())
            .collect();
        let xm0: Vec<Vec<f32>> = episodes
            .iter()
            .filter(|e| !e.z.is_empty())
            .map(|e| e.xmasks[0].clone())
            .collect();
        self.train_controller_dream_seeded(
            ctrl,
            wm,
            &z0,
            &xm0,
            epochs,
            horizon,
            temperature,
            reward_scale,
            ppo,
            rng,
        )
    }

    /// [`Pipeline::train_controller_dream`] on an explicit dream seed
    /// pool (initial latents + xfer masks). The async pipeline's WM
    /// stage ships the seed pool alongside its params, so the dream
    /// stage never needs the episodes themselves; the episode-based
    /// entry point above delegates here.
    #[allow(clippy::too_many_arguments)]
    pub fn train_controller_dream_seeded(
        &self,
        ctrl: &mut ParamStore,
        wm: &ParamStore,
        z0: &[Vec<f32>],
        xm0: &[Vec<f32>],
        epochs: usize,
        horizon: usize,
        temperature: f32,
        reward_scale: f32,
        ppo: &PpoCfg,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!z0.is_empty(), "no encoded episodes to seed the dream");
        anyhow::ensure!(z0.len() == xm0.len(), "dream seed latents and masks must pair up");

        let mut dream = DreamEnv::new(self.backend, temperature, reward_scale)?;
        let all_locs = vec![1.0f32; self.dims.max_locs];
        let mut curve = Vec::with_capacity(epochs);

        for _ in 0..epochs {
            dream.reset(z0, xm0)?;
            let b = dream.b;
            // Per-row trajectories.
            let mut traj: Vec<PpoRowTraj> = (0..b).map(|_| PpoRowTraj::default()).collect();
            for _ in 0..horizon {
                if dream.all_done() {
                    break;
                }
                let alive: Vec<usize> = (0..b).filter(|&r| !dream.done[r]).collect();
                let acts = self.policy.act_batch(
                    ctrl,
                    &ObsBatch { z: &dream.z, h: &dream.h, xmask: &dream.xmask },
                    |_, _| all_locs.iter().map(|&v| v >= 0.5).collect(),
                    rng,
                    false,
                )?;
                let pre_z: Vec<Vec<f32>> = (0..b).map(|r| dream.row_z(r)).collect();
                let pre_h: Vec<Vec<f32>> = (0..b).map(|r| dream.row_h(r)).collect();
                let pre_xm: Vec<Vec<f32>> = (0..b).map(|r| dream.row_xmask(r)).collect();
                let actions: Vec<Action> = acts.iter().map(|a| a.action).collect();
                let (rewards, dones) = dream.step(wm, &actions, rng)?;
                for &r in &alive {
                    traj[r].push(
                        pre_z[r].clone(),
                        pre_h[r].clone(),
                        pre_xm[r].clone(),
                        acts[r].action,
                        acts[r].logp,
                        acts[r].value,
                        rewards[r],
                        dones[r],
                    );
                }
            }
            // Assemble PPO buffer with per-row GAE. Every trajectory in
            // this epoch was acted under the current ctrl params; the
            // buffer's version tag enforces that no later push mixes in
            // data from another policy version.
            let mut buffer = PpoBuffer::default();
            buffer.note_version(ctrl.version)?;
            let mut epoch_reward = 0.0f32;
            let mut rows = 0;
            for t in traj.into_iter().filter(|t| !t.rewards.is_empty()) {
                epoch_reward += t.rewards.iter().sum::<f32>();
                rows += 1;
                let mut values = t.values.clone();
                values.push(0.0); // bootstrap: terminal or horizon-capped
                let mut dones = t.dones.clone();
                *dones.last_mut().unwrap() = 1.0;
                let (adv, ret) = gae(&t.rewards, &values, &dones, ppo.gamma, ppo.lam);
                for i in 0..t.rewards.len() {
                    buffer.push(
                        t.z[i].clone(),
                        t.h[i].clone(),
                        t.actions[i],
                        t.logps[i],
                        adv[i],
                        ret[i],
                        t.xmasks[i].clone(),
                        all_locs.clone(),
                    );
                }
            }
            if !buffer.is_empty() {
                let _ =
                    crate::agent::ppo_update(self.backend, ctrl, &buffer, &self.dims, ppo, rng)?;
            }
            curve.push(if rows > 0 { epoch_reward / rows as f32 } else { 0.0 });
        }
        Ok(curve)
    }

    // ------------------------------------------------------------------
    // Stage 6: evaluation in the real environment
    // ------------------------------------------------------------------

    /// Run the trained controller against the real environment. When `wm`
    /// is provided the recurrent context h advances through the world
    /// model (the paper's a_t = pi([z_t, h_t]) controller); with `None`
    /// the model-free configuration (h = 0) is used.
    pub fn eval_real(
        &self,
        gnn: &ParamStore,
        ctrl: &ParamStore,
        wm: Option<&ParamStore>,
        env: &mut Env,
        greedy: bool,
        rng: &mut Rng,
    ) -> anyhow::Result<EvalResult> {
        env.reset();
        let space = ActionSpace::new(self.dims.x1, env.noop_action());
        let mut h = vec![0.0f32; self.dims.rdim];
        let mut c = vec![0.0f32; self.dims.rdim];
        let mut best = env.improvement_pct();
        let mut best_graph = env.graph().clone();
        let mut step_times = Vec::new();
        loop {
            let t0 = Instant::now();
            let z = self.encode_state(gnn, env.graph())?;
            let xmask = env.padded_xfer_mask(self.dims.x1);
            let acts = self.policy.act_batch(
                ctrl,
                &ObsBatch { z: &z, h: &h, xmask: &xmask },
                |_, x| env.location_mask(x),
                rng,
                greedy,
            )?;
            let action = acts[0].action;
            let res = env.step(space.to_env(action));
            if let Some(wm_store) = wm {
                let out = self.world.step(wm_store, &z, &[action], &h, &c)?;
                h = out.h1;
                c = out.c1;
            }
            step_times.push(t0.elapsed().as_secs_f64());
            if env.improvement_pct() > best {
                best = env.improvement_pct();
                best_graph = env.graph().clone();
            }
            if res.done {
                break;
            }
        }
        Ok(EvalResult {
            best_improvement_pct: best,
            final_improvement_pct: env.improvement_pct(),
            steps: env.steps_taken(),
            history: env.history().to_vec(),
            mean_step_s: step_times.iter().sum::<f64>() / step_times.len().max(1) as f64,
            best_graph: Some(best_graph),
        })
    }

    /// [`Pipeline::eval_real`] over a whole [`EnvPool`]: B independent
    /// evaluation episodes advance together, one batched `step_where` per
    /// pass. Policy/world-model program calls stay on the backend thread
    /// (the PJRT engine is not shared across threads) but are *batched*
    /// across the alive rows — one [`Pipeline::encode_graphs`] pass, one
    /// [`PolicyNet::act_rows`] forward and one batched
    /// [`WorldModel::step`] per pool pass instead of per-row program
    /// calls; the environment work — matching and costing — fans out
    /// across the pool's workers. Each env keeps its own forked RNG
    /// stream, so per-row results are bit-identical to the per-row path
    /// and don't depend on when other rows terminate, nor on the pool's
    /// thread count.
    pub fn eval_real_pool(
        &self,
        gnn: &ParamStore,
        ctrl: &ParamStore,
        wm: Option<&ParamStore>,
        pool: &mut EnvPool,
        greedy: bool,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<EvalResult>> {
        pool.reset_all();
        let b = pool.n_envs();
        let space = ActionSpace::new(self.dims.x1, pool.noop_action());
        let mut rngs: Vec<Rng> = (0..b).map(|i| rng.fork(i as u64)).collect();
        let mut h = vec![vec![0.0f32; self.dims.rdim]; b];
        let mut c = vec![vec![0.0f32; self.dims.rdim]; b];
        let mut done = vec![false; b];
        let mut best: Vec<f64> = (0..b).map(|i| pool.state(i).improvement_pct()).collect();
        let mut best_graph: Vec<Graph> = (0..b).map(|i| pool.state(i).graph().clone()).collect();
        let mut step_secs = vec![0.0f64; b];
        while done.iter().any(|d| !d) {
            let t0 = Instant::now();
            let alive: Vec<usize> = (0..b).filter(|&i| !done[i]).collect();
            let ab = alive.len();
            // One batched encode over the alive rows.
            let graphs: Vec<&Graph> = alive.iter().map(|&i| pool.state(i).graph()).collect();
            let z_alive = self.encode_graphs(gnn, &graphs)?;
            // Flat alive-row observation batch for one policy forward.
            let mut zflat = Vec::with_capacity(ab * self.dims.zdim);
            let mut hflat = Vec::with_capacity(ab * self.dims.rdim);
            let mut xmflat = Vec::with_capacity(ab * self.dims.x1);
            for (ai, &i) in alive.iter().enumerate() {
                zflat.extend_from_slice(&z_alive[ai]);
                hflat.extend_from_slice(&h[i]);
                xmflat.extend(pool.state(i).padded_xfer_mask(self.dims.x1));
            }
            // Per-row RNG streams advance exactly as on the per-row path:
            // swap the alive streams out, sample, swap them back.
            let mut alive_rngs: Vec<Rng> = alive.iter().map(|&i| rngs[i].clone()).collect();
            let acts = self.policy.act_rows(
                ctrl,
                &ObsBatch { z: &zflat, h: &hflat, xmask: &xmflat },
                |ai, x| pool.state(alive[ai]).location_mask(x),
                &mut alive_rngs,
                greedy,
            )?;
            for (ai, &i) in alive.iter().enumerate() {
                std::mem::swap(&mut rngs[i], &mut alive_rngs[ai]);
            }
            let mut slot_actions: Vec<Option<Action>> = vec![None; b];
            for (ai, &i) in alive.iter().enumerate() {
                slot_actions[i] = Some(acts[ai].action);
            }
            // One batched environment pass.
            let env_actions: Vec<Option<(usize, usize)>> =
                slot_actions.iter().map(|a| a.map(|a| space.to_env(a))).collect();
            let results = pool.step_where(&env_actions);
            // Advance the recurrent world-model context for stepped rows
            // *inside* the timed pass, so mean_step_s stays comparable to
            // the single-env eval_real (which also times the wm step) —
            // one batched wm step over the stepped rows.
            if let Some(wm_store) = wm {
                // (alive index, env index) pairs — no rescan of `alive`.
                let stepped: Vec<(usize, usize)> = alive
                    .iter()
                    .copied()
                    .enumerate()
                    .filter(|&(_, i)| results[i].is_some())
                    .collect();
                if !stepped.is_empty() {
                    let mut zw = Vec::with_capacity(stepped.len() * self.dims.zdim);
                    let mut hw = Vec::with_capacity(stepped.len() * self.dims.rdim);
                    let mut cw = Vec::with_capacity(stepped.len() * self.dims.rdim);
                    let mut actions = Vec::with_capacity(stepped.len());
                    for &(ai, i) in &stepped {
                        zw.extend_from_slice(&z_alive[ai]);
                        hw.extend_from_slice(&h[i]);
                        cw.extend_from_slice(&c[i]);
                        actions.push(slot_actions[i].expect("stepped row had an action"));
                    }
                    let out = self.world.step(wm_store, &zw, &actions, &hw, &cw)?;
                    for (si, &(_, i)) in stepped.iter().enumerate() {
                        let r = self.dims.rdim;
                        h[i].copy_from_slice(&out.h1[si * r..(si + 1) * r]);
                        c[i].copy_from_slice(&out.c1[si * r..(si + 1) * r]);
                    }
                }
            }
            let n_stepped = results.iter().filter(|r| r.is_some()).count().max(1);
            let pass_s = t0.elapsed().as_secs_f64();
            for i in 0..b {
                let Some(res) = &results[i] else { continue };
                step_secs[i] += pass_s / n_stepped as f64;
                let impr = pool.state(i).improvement_pct();
                if impr > best[i] {
                    best[i] = impr;
                    best_graph[i] = pool.state(i).graph().clone();
                }
                if res.done {
                    done[i] = true;
                }
            }
        }
        Ok((0..b)
            .zip(best_graph)
            .map(|(i, bg)| {
                let state = pool.state(i);
                EvalResult {
                    best_improvement_pct: best[i],
                    final_improvement_pct: state.improvement_pct(),
                    steps: state.steps_taken(),
                    history: state.history().to_vec(),
                    mean_step_s: step_secs[i] / state.steps_taken().max(1) as f64,
                    best_graph: Some(bg),
                }
            })
            .collect())
    }

    // ------------------------------------------------------------------
    // Model-free baseline: PPO directly in the real environment
    // ------------------------------------------------------------------

    /// One model-free PPO iteration: collect `n_episodes` on-policy
    /// episodes (h = 0) and update. Returns (mean episode reward, stats).
    pub fn model_free_iteration(
        &self,
        gnn: &ParamStore,
        ctrl: &mut ParamStore,
        env: &mut Env,
        n_episodes: usize,
        ppo: &PpoCfg,
        rng: &mut Rng,
    ) -> anyhow::Result<(f32, PpoStats)> {
        let space = ActionSpace::new(self.dims.x1, env.noop_action());
        let h0 = vec![0.0f32; self.dims.rdim];
        let mut buffer = PpoBuffer::default();
        // One iteration = one on-policy batch: every episode below acts
        // under the same ctrl version (the update happens after).
        buffer.note_version(ctrl.version)?;
        let mut total_reward = 0.0f32;
        for _ in 0..n_episodes {
            env.reset();
            let mut traj = PpoRowTraj::default();
            loop {
                let z = self.encode_state(gnn, env.graph())?;
                let xmask = env.padded_xfer_mask(self.dims.x1);
                let acts = self.policy.act_batch(
                    ctrl,
                    &ObsBatch { z: &z, h: &h0, xmask: &xmask },
                    |_, x| env.location_mask(x),
                    rng,
                    false,
                )?;
                let a = &acts[0];
                let lmask: Vec<f32> = if space.is_noop(a.action) {
                    vec![1.0; self.dims.max_locs]
                } else {
                    env.location_mask(a.action.slot)
                        .iter()
                        .map(|&m| if m { 1.0 } else { 0.0 })
                        .collect()
                };
                let res = env.step(space.to_env(a.action));
                traj.push(z, h0.clone(), xmask, a.action, a.logp, a.value, res.reward, res.done);
                traj.lmasks.push(lmask);
                if res.done {
                    break;
                }
            }
            total_reward += traj.rewards.iter().sum::<f32>();
            let mut values = traj.values.clone();
            values.push(0.0);
            let mut dones = traj.dones.clone();
            *dones.last_mut().unwrap() = 1.0;
            let (adv, ret) = gae(&traj.rewards, &values, &dones, ppo.gamma, ppo.lam);
            for i in 0..traj.rewards.len() {
                buffer.push(
                    traj.z[i].clone(),
                    traj.h[i].clone(),
                    traj.actions[i],
                    traj.logps[i],
                    adv[i],
                    ret[i],
                    traj.xmasks[i].clone(),
                    traj.lmasks[i].clone(),
                );
            }
        }
        let stats = crate::agent::ppo_update(self.backend, ctrl, &buffer, &self.dims, ppo, rng)?;
        Ok((total_reward / n_episodes.max(1) as f32, stats))
    }
}

/// Scratch per-trajectory storage for PPO collection.
#[derive(Debug, Default, Clone)]
struct PpoRowTraj {
    z: Vec<Vec<f32>>,
    h: Vec<Vec<f32>>,
    xmasks: Vec<Vec<f32>>,
    lmasks: Vec<Vec<f32>>,
    actions: Vec<Action>,
    logps: Vec<f32>,
    values: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
}

impl PpoRowTraj {
    #[allow(clippy::too_many_arguments)]
    fn push(
        &mut self,
        z: Vec<f32>,
        h: Vec<f32>,
        xmask: Vec<f32>,
        action: Action,
        logp: f32,
        value: f32,
        reward: f32,
        done: bool,
    ) {
        self.z.push(z);
        self.h.push(h);
        self.xmasks.push(xmask);
        self.actions.push(action);
        self.logps.push(logp);
        self.values.push(value);
        self.rewards.push(reward);
        self.dones.push(if done { 1.0 } else { 0.0 });
    }
}
