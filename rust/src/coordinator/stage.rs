//! Bounded blocking channels for the async training pipeline.
//!
//! `StageChannel` is the only synchronisation primitive the async
//! pipeline uses between stages: a fixed-capacity FIFO built on
//! `Mutex` + `Condvar` (the crate is dependency-free — no async
//! runtime, no crossbeam). Its contract is exactly what the schedule
//! trace needs:
//!
//! * **Backpressure, never drop**: `send` blocks while the buffer is
//!   full; an item handed to `send` is either enqueued or returned in
//!   the [`StageClosed`] error — it is never silently discarded.
//! * **Per-producer FIFO**: items from one producer thread are
//!   received in the order that producer sent them (the queue is a
//!   strict FIFO; interleaving *across* producers is scheduling-
//!   dependent, which is what the trace records).
//! * **Close wakes everyone**: after [`StageChannel::close`], blocked
//!   senders fail fast with [`StageClosed`] and receivers drain the
//!   remaining items before observing end-of-stream (`None`).
//! * **Panic closes too**: a stage that panics mid-round must not leave
//!   peers blocked forever. Each stage thread holds a [`CloseGuard`]
//!   per channel it touches; unwinding drops the guards, closing the
//!   channels, so peers exit and the join layer reports a typed
//!   [`StageFailed`] instead of hanging.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Error returned by [`StageChannel::send`] on a closed channel; the
/// rejected item is handed back so the producer can account for it.
#[derive(Debug)]
pub struct StageClosed<T>(pub T);

impl<T> std::fmt::Display for StageClosed<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stage channel closed")
    }
}

/// Typed failure for a pipeline stage thread that panicked: the stage
/// name plus the rendered panic payload. Converts into `anyhow::Error`
/// via `?` like any `std::error::Error`, so callers of `train_async`
/// see `stage 'wm' panicked: ...` rather than a propagated abort (and
/// never a hang — see [`CloseGuard`]).
#[derive(Debug, Clone)]
pub struct StageFailed {
    /// Name of the stage that panicked (`collect`, `ae`, `enc`, `wm`,
    /// `dream`, `eval`).
    pub stage: &'static str,
    /// Rendered panic payload (the panic message when it was a string).
    pub panic: String,
}

impl StageFailed {
    /// Build from a `std::thread` join error payload.
    pub fn from_panic(stage: &'static str, payload: Box<dyn std::any::Any + Send>) -> Self {
        let panic = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".to_string());
        Self { stage, panic }
    }
}

impl std::fmt::Display for StageFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stage '{}' panicked: {}", self.stage, self.panic)
    }
}

impl std::error::Error for StageFailed {}

/// Closes a [`StageChannel`] when dropped — on normal return *and* on
/// panic. Every async-pipeline stage thread holds one per channel it
/// produces into or consumes from, making "a dying stage releases its
/// peers" a structural guarantee rather than a code path.
pub struct CloseGuard<'a, T>(&'a StageChannel<T>);

impl<T> Drop for CloseGuard<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

struct ChanState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded MPSC/MPMC blocking channel connecting two pipeline stages.
pub struct StageChannel<T> {
    state: Mutex<ChanState<T>>,
    /// Signalled when an item arrives or the channel closes (receivers wait here).
    ready: Condvar,
    /// Signalled when an item leaves or the channel closes (senders wait here).
    space: Condvar,
    cap: usize,
}

impl<T> StageChannel<T> {
    /// Create a channel holding at most `cap` in-flight items (min 1).
    pub fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(ChanState { items: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            space: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Guard that closes this channel when dropped, whether the holder
    /// returns normally or unwinds from a panic.
    pub fn close_guard(&self) -> CloseGuard<'_, T> {
        CloseGuard(self)
    }

    /// Enqueue `item`, blocking while the buffer is full. Returns the
    /// item back inside [`StageClosed`] if the channel was closed
    /// before space opened up.
    pub fn send(&self, item: T) -> Result<(), StageClosed<T>> {
        crate::util::failpoint::fire("stage.send");
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(StageClosed(item));
            }
            if st.items.len() < self.cap {
                st.items.push_back(item);
                self.ready.notify_one();
                return Ok(());
            }
            st = self.space.wait(st).unwrap();
        }
    }

    /// Dequeue the next item, blocking while the buffer is empty.
    /// Returns `None` only after the channel is closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        crate::util::failpoint::fire("stage.recv");
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.space.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Close the channel: blocked senders fail with [`StageClosed`],
    /// receivers drain the remaining items then observe `None`.
    /// Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.ready.notify_all();
        self.space.notify_all();
    }

    /// Number of items currently buffered (racy snapshot; exact only
    /// when producers and consumers are quiescent).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// The configured capacity bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_capacity() {
        let ch = StageChannel::new(4);
        for i in 0..4 {
            ch.send(i).unwrap();
        }
        assert_eq!(ch.depth(), 4);
        for i in 0..4 {
            assert_eq!(ch.recv(), Some(i));
        }
        ch.close();
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn send_blocks_until_space_then_succeeds() {
        let ch = StageChannel::new(1);
        ch.send(1u32).unwrap();
        std::thread::scope(|s| {
            let producer = s.spawn(|| ch.send(2).is_ok());
            // The producer is blocked on the full buffer; draining one
            // item must release it.
            std::thread::sleep(std::time::Duration::from_millis(10));
            assert_eq!(ch.recv(), Some(1));
            assert!(producer.join().unwrap());
        });
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn close_wakes_blocked_sender_with_item_returned() {
        let ch = StageChannel::new(1);
        ch.send(7u32).unwrap();
        std::thread::scope(|s| {
            let producer = s.spawn(|| ch.send(8));
            std::thread::sleep(std::time::Duration::from_millis(10));
            ch.close();
            let err = producer.join().unwrap().unwrap_err();
            assert_eq!(err.0, 8, "the rejected item must be handed back");
        });
        // The item enqueued before close still drains.
        assert_eq!(ch.recv(), Some(7));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn panicking_guard_holder_releases_blocked_sender() {
        let ch = StageChannel::new(1);
        ch.send(1u32).unwrap();
        std::thread::scope(|s| {
            let producer = s.spawn(|| ch.send(2));
            let dying = s.spawn(|| {
                let _g = ch.close_guard();
                std::thread::sleep(std::time::Duration::from_millis(10));
                panic!("stage died mid-round");
            });
            let payload = dying.join().unwrap_err();
            let err = StageFailed::from_panic("test", payload);
            assert!(err.to_string().contains("stage 'test' panicked"), "got: {err}");
            assert!(err.to_string().contains("stage died mid-round"), "got: {err}");
            // The guard's drop closed the channel: the blocked sender is
            // released with its item handed back, not left hanging.
            let rejected = producer.join().unwrap().unwrap_err();
            assert_eq!(rejected.0, 2);
        });
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn close_drains_then_signals_end_of_stream() {
        let ch = StageChannel::new(4);
        ch.send("a").unwrap();
        ch.send("b").unwrap();
        ch.close();
        assert!(ch.send("c").is_err());
        assert_eq!(ch.recv(), Some("a"));
        assert_eq!(ch.recv(), Some("b"));
        assert_eq!(ch.recv(), None);
    }
}
