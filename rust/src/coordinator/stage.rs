//! Bounded blocking channels for the async training pipeline.
//!
//! `StageChannel` is the only synchronisation primitive the async
//! pipeline uses between stages: a fixed-capacity FIFO built on
//! `Mutex` + `Condvar` (the crate is dependency-free — no async
//! runtime, no crossbeam). Its contract is exactly what the schedule
//! trace needs:
//!
//! * **Backpressure, never drop**: `send` blocks while the buffer is
//!   full; an item handed to `send` is either enqueued or returned in
//!   the [`StageClosed`] error — it is never silently discarded.
//! * **Per-producer FIFO**: items from one producer thread are
//!   received in the order that producer sent them (the queue is a
//!   strict FIFO; interleaving *across* producers is scheduling-
//!   dependent, which is what the trace records).
//! * **Close wakes everyone**: after [`StageChannel::close`], blocked
//!   senders fail fast with [`StageClosed`] and receivers drain the
//!   remaining items before observing end-of-stream (`None`).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Error returned by [`StageChannel::send`] on a closed channel; the
/// rejected item is handed back so the producer can account for it.
#[derive(Debug)]
pub struct StageClosed<T>(pub T);

impl<T> std::fmt::Display for StageClosed<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stage channel closed")
    }
}

struct ChanState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded MPSC/MPMC blocking channel connecting two pipeline stages.
pub struct StageChannel<T> {
    state: Mutex<ChanState<T>>,
    /// Signalled when an item arrives or the channel closes (receivers wait here).
    ready: Condvar,
    /// Signalled when an item leaves or the channel closes (senders wait here).
    space: Condvar,
    cap: usize,
}

impl<T> StageChannel<T> {
    /// Create a channel holding at most `cap` in-flight items (min 1).
    pub fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(ChanState { items: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            space: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue `item`, blocking while the buffer is full. Returns the
    /// item back inside [`StageClosed`] if the channel was closed
    /// before space opened up.
    pub fn send(&self, item: T) -> Result<(), StageClosed<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(StageClosed(item));
            }
            if st.items.len() < self.cap {
                st.items.push_back(item);
                self.ready.notify_one();
                return Ok(());
            }
            st = self.space.wait(st).unwrap();
        }
    }

    /// Dequeue the next item, blocking while the buffer is empty.
    /// Returns `None` only after the channel is closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.space.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Close the channel: blocked senders fail with [`StageClosed`],
    /// receivers drain the remaining items then observe `None`.
    /// Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.ready.notify_all();
        self.space.notify_all();
    }

    /// Number of items currently buffered (racy snapshot; exact only
    /// when producers and consumers are quiescent).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// The configured capacity bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_capacity() {
        let ch = StageChannel::new(4);
        for i in 0..4 {
            ch.send(i).unwrap();
        }
        assert_eq!(ch.depth(), 4);
        for i in 0..4 {
            assert_eq!(ch.recv(), Some(i));
        }
        ch.close();
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn send_blocks_until_space_then_succeeds() {
        let ch = StageChannel::new(1);
        ch.send(1u32).unwrap();
        std::thread::scope(|s| {
            let producer = s.spawn(|| ch.send(2).is_ok());
            // The producer is blocked on the full buffer; draining one
            // item must release it.
            std::thread::sleep(std::time::Duration::from_millis(10));
            assert_eq!(ch.recv(), Some(1));
            assert!(producer.join().unwrap());
        });
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn close_wakes_blocked_sender_with_item_returned() {
        let ch = StageChannel::new(1);
        ch.send(7u32).unwrap();
        std::thread::scope(|s| {
            let producer = s.spawn(|| ch.send(8));
            std::thread::sleep(std::time::Duration::from_millis(10));
            ch.close();
            let err = producer.join().unwrap().unwrap_err();
            assert_eq!(err.0, 8, "the rejected item must be handed back");
        });
        // The item enqueued before close still drains.
        assert_eq!(ch.recv(), Some(7));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn close_drains_then_signals_end_of_stream() {
        let ch = StageChannel::new(4);
        ch.send("a").unwrap();
        ch.send("b").unwrap();
        ch.close();
        assert!(ch.send("c").is_err());
        assert_eq!(ch.recv(), Some("a"));
        assert_eq!(ch.recv(), Some("b"));
        assert_eq!(ch.recv(), None);
    }
}
