//! # RLFlow
//!
//! Reproduction of *"RLFlow: Optimising Neural Network Subgraph
//! Transformation with World Models"* (Parker, Alabed & Yoneki, 2022) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The Rust crate is Layer 3: the complete optimisation system — the
//! computation-graph IR, the TASO-style substitution engine, the analytic
//! cost model, the Gym-style RL environment, the search baselines, and the
//! coordinator that drives the AOT-compiled neural artifacts (GNN encoder,
//! MDN-RNN world model, PPO controller) through the PJRT C API. Python is
//! build-time only.
//!
//! Quick tour (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use rlflow::zoo;
//! use rlflow::cost::{CostModel, DeviceProfile};
//! use rlflow::search::greedy_optimise;
//! use rlflow::xfer::library::standard_library;
//!
//! let graph = zoo::bert_base();
//! let cost = CostModel::new(DeviceProfile::rtx2070());
//! let rules = standard_library();
//! let (optimised, _log) = greedy_optimise(&graph, &rules, &cost, 100);
//! println!("runtime: {:.3} ms -> {:.3} ms",
//!          cost.graph_runtime_ms(&graph), cost.graph_runtime_ms(&optimised));
//! ```
//!
//! The repository-root `README.md` covers the build/test/bench entry
//! points and the `rlflow` CLI; `ARCHITECTURE.md` maps the modules, the
//! `runtime::Backend` seam, and the incremental match/cost dataflow.
//!
//! Public seams at a glance:
//!
//! * [`graph`] — the arena-based computation-graph IR + canonical hashing.
//! * [`xfer`] — the substitution engine: rules, matcher, [`xfer::ApplyReport`]
//!   / [`xfer::DirtyRegion`] incremental-rewrite contracts.
//! * [`cost`] — the roofline cost model with snapshot/overlay memo sharing
//!   and exact incremental deltas (noise included).
//! * [`search`] — the deterministic baselines on the parallel memoised
//!   engine, plus the persistent cross-run [`search::SearchCache`].
//! * [`serve`] — the `rlflow serve` daemon: optimisation-as-a-service
//!   with a disk-backed cache, request coalescing and admission control.
//! * [`env`] — the Gym-style environment, incremental match maintenance
//!   and the vectorised [`env::EnvPool`].
//! * [`runtime`] — the [`runtime::Backend`] execution seam (pure-Rust host
//!   backend or PJRT artifacts).
//! * [`agent`] / [`wm`] / [`coordinator`] — PPO controller, MDN-RNN world
//!   model, and the training pipeline that drives them.
//! * [`experiments`] — one driver per paper table/figure.

// New public items must carry rustdoc; the doc build is part of CI
// (`cargo doc --no-deps`). Pre-existing undocumented items surface as
// warnings and are burned down opportunistically, module by module.
#![warn(missing_docs)]

pub mod agent;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod env;
pub mod experiments;
pub mod graph;
pub mod interp;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod util;
pub mod wm;
pub mod xfer;
pub mod zoo;
