//! # RLFlow
//!
//! Reproduction of *"RLFlow: Optimising Neural Network Subgraph
//! Transformation with World Models"* (Parker, Alabed & Yoneki, 2022) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The Rust crate is Layer 3: the complete optimisation system — the
//! computation-graph IR, the TASO-style substitution engine, the analytic
//! cost model, the Gym-style RL environment, the search baselines, and the
//! coordinator that drives the AOT-compiled neural artifacts (GNN encoder,
//! MDN-RNN world model, PPO controller) through the PJRT C API. Python is
//! build-time only.
//!
//! Quick tour (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use rlflow::zoo;
//! use rlflow::cost::{CostModel, DeviceProfile};
//! use rlflow::search::greedy_optimise;
//! use rlflow::xfer::library::standard_library;
//!
//! let graph = zoo::bert_base();
//! let cost = CostModel::new(DeviceProfile::rtx2070());
//! let rules = standard_library();
//! let (optimised, _log) = greedy_optimise(&graph, &rules, &cost, 100);
//! println!("runtime: {:.3} ms -> {:.3} ms",
//!          cost.graph_runtime_ms(&graph), cost.graph_runtime_ms(&optimised));
//! ```

pub mod agent;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod env;
pub mod experiments;
pub mod graph;
pub mod interp;
pub mod runtime;
pub mod search;
pub mod util;
pub mod wm;
pub mod xfer;
pub mod zoo;
