//! The imagined environment (§3.3): controller training happens entirely
//! inside these latent rollouts — no calls into the real graph environment.
//!
//! A step advances the [`WorldModel`], samples the next latent from the MDN
//! with temperature τ, reads the predicted reward, thresholds the predicted
//! xfer-validity logits into the next action mask, and thresholds the done
//! head. All three failure modes §4.7 analyses (imperfect reward, invalid
//! next state, wrong mask) are therefore reproducible here.

use crate::agent::{Action, ActionSpace};
use crate::runtime::{Backend, ParamStore};
use crate::util::Rng;

use super::mdn::sample_mdn;
use super::model::WorldModel;

pub struct DreamEnv<'e> {
    pub model: WorldModel<'e>,
    pub temperature: f32,
    pub b: usize,
    space: ActionSpace,
    /// Reward scale used at WM training time (predictions are unscaled by it).
    pub reward_scale: f32,
    pub z: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    /// Current per-row xfer mask (f32 0/1), `b * x1`.
    pub xmask: Vec<f32>,
    pub done: Vec<bool>,
}

impl<'e> DreamEnv<'e> {
    pub fn new(
        backend: &'e dyn Backend,
        temperature: f32,
        reward_scale: f32,
    ) -> anyhow::Result<Self> {
        let model = WorldModel::new(backend)?;
        let d = model.dims;
        let b = d.b_dream;
        Ok(Self {
            model,
            temperature,
            b,
            space: ActionSpace::slots_only(d.x1),
            reward_scale,
            z: vec![0.0; b * d.zdim],
            h: vec![0.0; b * d.rdim],
            c: vec![0.0; b * d.rdim],
            xmask: vec![1.0; b * d.x1],
            done: vec![false; b],
        })
    }

    /// Reset every row from real initial latents + masks (cycled if fewer
    /// provided than the dream batch).
    pub fn reset(&mut self, z0: &[Vec<f32>], xmask0: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(!z0.is_empty() && z0.len() == xmask0.len(), "dream reset needs seeds");
        let (zdim, x1) = (self.model.dims.zdim, self.model.dims.x1);
        for row in 0..self.b {
            let src = row % z0.len();
            anyhow::ensure!(z0[src].len() == zdim, "latent width mismatch");
            anyhow::ensure!(xmask0[src].len() == x1, "mask width mismatch");
            self.z[row * zdim..(row + 1) * zdim].copy_from_slice(&z0[src]);
            self.xmask[row * x1..(row + 1) * x1].copy_from_slice(&xmask0[src]);
        }
        self.h.fill(0.0);
        self.c.fill(0.0);
        self.done.fill(false);
        Ok(())
    }

    /// The slot-space action geometry (NO-OP slot mapping).
    pub fn space(&self) -> ActionSpace {
        self.space
    }

    /// One imagined step for the whole batch. Returns (rewards, dones).
    pub fn step(
        &mut self,
        wm: &ParamStore,
        actions: &[Action],
        rng: &mut Rng,
    ) -> anyhow::Result<(Vec<f32>, Vec<bool>)> {
        anyhow::ensure!(actions.len() == self.b, "dream step: wrong batch size");
        let d = self.model.dims;
        let out = self.model.step(wm, &self.z, actions, &self.h, &self.c)?;

        let zk = d.zdim * d.k;
        let mut rewards = vec![0.0f32; self.b];
        let mut dones = vec![false; self.b];
        for row in 0..self.b {
            if self.done[row] {
                dones[row] = true;
                continue;
            }
            // NO-OP terminates in the real env; mirror that exactly.
            let noop_taken = self.space.is_noop(actions[row]);
            let z_next = sample_mdn(
                &out.log_pi[row * zk..(row + 1) * zk],
                &out.mu[row * zk..(row + 1) * zk],
                &out.log_sig[row * zk..(row + 1) * zk],
                d.zdim,
                d.k,
                self.temperature,
                rng,
            );
            self.z[row * d.zdim..(row + 1) * d.zdim].copy_from_slice(&z_next);
            rewards[row] = if noop_taken { 0.0 } else { out.rewards[row] * self.reward_scale };
            // Predicted next-state xfer mask; NO-OP slot always valid.
            for xi in 0..d.x1 {
                let logit = out.mask_logits[row * d.x1 + xi];
                self.xmask[row * d.x1 + xi] =
                    if xi == self.space.noop_slot() || logit > 0.0 { 1.0 } else { 0.0 };
            }
            let done_pred = out.done_logits[row] > 0.0;
            dones[row] = noop_taken || done_pred;
            self.done[row] = dones[row];
        }
        self.h = out.h1;
        self.c = out.c1;
        Ok((rewards, dones))
    }

    pub fn all_done(&self) -> bool {
        self.done.iter().all(|&d| d)
    }

    /// Row-major copies of the current latent/hidden state (PPO features).
    pub fn row_z(&self, row: usize) -> Vec<f32> {
        let zdim = self.model.dims.zdim;
        self.z[row * zdim..(row + 1) * zdim].to_vec()
    }

    pub fn row_h(&self, row: usize) -> Vec<f32> {
        let rdim = self.model.dims.rdim;
        self.h[row * rdim..(row + 1) * rdim].to_vec()
    }

    pub fn row_xmask(&self, row: usize) -> Vec<f32> {
        let x1 = self.model.dims.x1;
        self.xmask[row * x1..(row + 1) * x1].to_vec()
    }
}
