//! The imagined environment (§3.3): controller training happens entirely
//! inside these latent rollouts — no calls into the real graph environment.
//!
//! A step runs `wm_step_b`, samples the next latent from the MDN with
//! temperature τ, reads the predicted reward, thresholds the predicted
//! xfer-validity logits into the next action mask, and thresholds the done
//! head. All three failure modes §4.7 analyses (imperfect reward, invalid
//! next state, wrong mask) are therefore reproducible here.

use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Engine, ParamStore};
use crate::util::Rng;

use super::mdn::sample_mdn;

pub struct DreamEnv<'e> {
    pub engine: &'e Engine,
    pub temperature: f32,
    pub b: usize,
    zdim: usize,
    rdim: usize,
    x1: usize,
    k: usize,
    /// Reward scale used at WM training time (predictions are unscaled by it).
    pub reward_scale: f32,
    pub z: Vec<f32>,
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    /// Current per-row xfer mask (f32 0/1), `b * x1`.
    pub xmask: Vec<f32>,
    pub done: Vec<bool>,
}

impl<'e> DreamEnv<'e> {
    pub fn new(engine: &'e Engine, temperature: f32, reward_scale: f32) -> anyhow::Result<Self> {
        let b = engine.manifest.hp_usize("B_DREAM")?;
        let zdim = engine.manifest.hp_usize("LATENT")?;
        let rdim = engine.manifest.hp_usize("RNN_HIDDEN")?;
        let x1 = engine.manifest.hp_usize("N_XFERS1")?;
        let k = engine.manifest.hp_usize("MDN_K")?;
        Ok(Self {
            engine,
            temperature,
            b,
            zdim,
            rdim,
            x1,
            k,
            reward_scale,
            z: vec![0.0; b * zdim],
            h: vec![0.0; b * rdim],
            c: vec![0.0; b * rdim],
            xmask: vec![1.0; b * x1],
            done: vec![false; b],
        })
    }

    /// Reset every row from real initial latents + masks (cycled if fewer
    /// provided than the dream batch).
    pub fn reset(&mut self, z0: &[Vec<f32>], xmask0: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(!z0.is_empty() && z0.len() == xmask0.len(), "dream reset needs seeds");
        for row in 0..self.b {
            let src = row % z0.len();
            anyhow::ensure!(z0[src].len() == self.zdim, "latent width mismatch");
            anyhow::ensure!(xmask0[src].len() == self.x1, "mask width mismatch");
            self.z[row * self.zdim..(row + 1) * self.zdim].copy_from_slice(&z0[src]);
            self.xmask[row * self.x1..(row + 1) * self.x1].copy_from_slice(&xmask0[src]);
        }
        self.h.fill(0.0);
        self.c.fill(0.0);
        self.done.fill(false);
        Ok(())
    }

    pub fn noop(&self) -> usize {
        self.x1 - 1
    }

    /// One imagined step for the whole batch. Returns (rewards, dones).
    pub fn step(
        &mut self,
        wm: &ParamStore,
        actions: &[(usize, usize)],
        rng: &mut Rng,
    ) -> anyhow::Result<(Vec<f32>, Vec<bool>)> {
        anyhow::ensure!(actions.len() == self.b, "dream step: wrong batch size");
        let mut a = Vec::with_capacity(self.b * 2);
        for &(x, l) in actions {
            a.push(x as i32);
            a.push(l as i32);
        }
        let theta = self.engine.device_theta(wm)?;
        let out = self.engine.exec_with_theta(
            "wm_step_b",
            &theta,
            &[
                lit_f32(&self.z, &[self.b, self.zdim])?,
                lit_i32(&a, &[self.b, 2])?,
                lit_f32(&self.h, &[self.b, self.rdim])?,
                lit_f32(&self.c, &[self.b, self.rdim])?,
            ],
        )?;
        let log_pi = to_vec_f32(&out[0])?;
        let mu = to_vec_f32(&out[1])?;
        let log_sig = to_vec_f32(&out[2])?;
        let rewards_pred = to_vec_f32(&out[3])?;
        let mask_logits = to_vec_f32(&out[4])?;
        let done_logits = to_vec_f32(&out[5])?;
        let h1 = to_vec_f32(&out[6])?;
        let c1 = to_vec_f32(&out[7])?;

        let zk = self.zdim * self.k;
        let mut rewards = vec![0.0f32; self.b];
        let mut dones = vec![false; self.b];
        for row in 0..self.b {
            if self.done[row] {
                dones[row] = true;
                continue;
            }
            // NO-OP terminates in the real env; mirror that exactly.
            let noop_taken = actions[row].0 == self.noop();
            let z_next = sample_mdn(
                &log_pi[row * zk..(row + 1) * zk],
                &mu[row * zk..(row + 1) * zk],
                &log_sig[row * zk..(row + 1) * zk],
                self.zdim,
                self.k,
                self.temperature,
                rng,
            );
            self.z[row * self.zdim..(row + 1) * self.zdim].copy_from_slice(&z_next);
            rewards[row] = if noop_taken { 0.0 } else { rewards_pred[row] * self.reward_scale };
            // Predicted next-state xfer mask; NO-OP slot always valid.
            for xi in 0..self.x1 {
                let logit = mask_logits[row * self.x1 + xi];
                self.xmask[row * self.x1 + xi] =
                    if xi == self.noop() || logit > 0.0 { 1.0 } else { 0.0 };
            }
            let done_pred = done_logits[row] > 0.0;
            dones[row] = noop_taken || done_pred;
            self.done[row] = dones[row];
        }
        self.h = h1;
        self.c = c1;
        Ok((rewards, dones))
    }

    pub fn all_done(&self) -> bool {
        self.done.iter().all(|&d| d)
    }

    /// Row-major copies of the current latent/hidden state (PPO features).
    pub fn row_z(&self, row: usize) -> Vec<f32> {
        self.z[row * self.zdim..(row + 1) * self.zdim].to_vec()
    }

    pub fn row_h(&self, row: usize) -> Vec<f32> {
        self.h[row * self.rdim..(row + 1) * self.rdim].to_vec()
    }

    pub fn row_xmask(&self, row: usize) -> Vec<f32> {
        self.xmask[row * self.x1..(row + 1) * self.x1].to_vec()
    }
}
