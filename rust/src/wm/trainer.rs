//! World-model training (§3.3.2, Fig. 8): teacher-forced sequence batches
//! sampled from collected episodes, driven through the `wm_train` program
//! with the paper's 2nd-degree polynomial learning-rate decay.

use crate::agent::buffer::{sample_windows, Episode};
use crate::runtime::{Backend, ParamStore, TensorView};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct WmLosses {
    pub total: f32,
    pub nll: f32,
    pub reward_mse: f32,
    pub mask_bce: f32,
    pub done_bce: f32,
}

#[derive(Debug, Clone, Copy)]
pub struct WmTrainCfg {
    pub lr_start: f32,
    pub lr_end: f32,
    /// Polynomial decay power (paper §4.7: 2nd-degree).
    pub decay_power: f32,
    pub total_steps: usize,
    /// Rewards are divided by this before regression (keeps MSE in range
    /// against the -100 invalid penalty).
    pub reward_scale: f32,
}

impl Default for WmTrainCfg {
    fn default() -> Self {
        Self {
            lr_start: 1e-3,
            lr_end: 1e-5,
            decay_power: 2.0,
            total_steps: 300,
            reward_scale: 10.0,
        }
    }
}

impl WmTrainCfg {
    pub fn lr_at(&self, step: usize) -> f32 {
        let p = (step as f32 / self.total_steps.max(1) as f32).min(1.0);
        self.lr_end + (self.lr_start - self.lr_end) * (1.0 - p).powf(self.decay_power)
    }
}

/// An owned `[b, t]` teacher-forcing batch; [`WmBatch::views`] borrows it
/// as the seven tensor arguments following `(theta, m, v, t)`.
pub struct WmBatch {
    b: usize,
    t: usize,
    zdim: usize,
    x1: usize,
    z: Vec<f32>,
    a: Vec<i32>,
    z_next: Vec<f32>,
    r: Vec<f32>,
    xm: Vec<f32>,
    done: Vec<f32>,
    valid: Vec<f32>,
}

impl WmBatch {
    pub fn views(&self) -> Vec<TensorView<'_>> {
        let (b, t) = (self.b, self.t);
        vec![
            TensorView::f32(&self.z, &[b, t, self.zdim]),
            TensorView::i32(&self.a, &[b, t, 2]),
            TensorView::f32(&self.z_next, &[b, t, self.zdim]),
            TensorView::f32(&self.r, &[b, t]),
            TensorView::f32(&self.xm, &[b, t, self.x1]),
            TensorView::f32(&self.done, &[b, t]),
            TensorView::f32(&self.valid, &[b, t]),
        ]
    }
}

pub struct WmTrainer<'e> {
    pub backend: &'e dyn Backend,
    b: usize,
    t: usize,
    zdim: usize,
    x1: usize,
}

impl<'e> WmTrainer<'e> {
    pub fn new(backend: &'e dyn Backend) -> anyhow::Result<Self> {
        Ok(Self {
            backend,
            b: backend.hp("B_WM")?,
            t: backend.hp("SEQ_LEN")?,
            zdim: backend.hp("LATENT")?,
            x1: backend.hp("N_XFERS1")?,
        })
    }

    /// Assemble the 7 batch tensors from sampled episode windows.
    /// Requires `ep.z` to be filled by the encoder pass.
    pub fn make_batch(
        &self,
        episodes: &[Episode],
        reward_scale: f32,
        rng: &mut Rng,
    ) -> anyhow::Result<WmBatch> {
        let (b, t, zd, x1) = (self.b, self.t, self.zdim, self.x1);
        let windows = sample_windows(episodes, b, rng);
        let mut batch = WmBatch {
            b,
            t,
            zdim: zd,
            x1,
            z: vec![0.0; b * t * zd],
            a: vec![0; b * t * 2],
            z_next: vec![0.0; b * t * zd],
            r: vec![0.0; b * t],
            xm: vec![0.0; b * t * x1],
            done: vec![0.0; b * t],
            valid: vec![0.0; b * t],
        };

        for (bi, (ep, start)) in windows.into_iter().enumerate() {
            anyhow::ensure!(
                ep.z.len() == ep.states.len() && !ep.z.is_empty(),
                "episode latents not encoded"
            );
            for ti in 0..t {
                let s = start + ti;
                if s >= ep.len() {
                    break;
                }
                let base = (bi * t + ti) * zd;
                batch.z[base..base + zd].copy_from_slice(&ep.z[s]);
                batch.z_next[base..base + zd].copy_from_slice(&ep.z[s + 1]);
                batch.a[(bi * t + ti) * 2] = ep.actions[s].0 as i32;
                batch.a[(bi * t + ti) * 2 + 1] = ep.actions[s].1 as i32;
                batch.r[bi * t + ti] = ep.rewards[s] / reward_scale;
                // Mask target: validity of the NEXT state (what the dream
                // env needs to predict after taking a_t).
                let xm_base = (bi * t + ti) * x1;
                batch.xm[xm_base..xm_base + x1].copy_from_slice(&ep.xmasks[s + 1]);
                batch.done[bi * t + ti] = ep.dones[s];
                batch.valid[bi * t + ti] = 1.0;
            }
        }
        Ok(batch)
    }

    /// One gradient step; returns the component losses (Fig. 8's curve).
    /// Driven through [`Backend::train_step`], so the host backend updates
    /// the store's Adam state in place.
    pub fn train_step(
        &self,
        wm: &mut ParamStore,
        episodes: &[Episode],
        lr: f32,
        reward_scale: f32,
        rng: &mut Rng,
    ) -> anyhow::Result<WmLosses> {
        let batch = self.make_batch(episodes, reward_scale, rng)?;
        let mut rest = batch.views();
        rest.push(TensorView::ScalarF32(lr));
        let out = self.backend.train_step("wm_train", wm, &rest)?;
        drop(rest);
        Ok(WmLosses {
            total: out[0].data[0],
            nll: out[1].data[0],
            reward_mse: out[2].data[0],
            mask_bce: out[3].data[0],
            done_bce: out[4].data[0],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polynomial_decay_schedule() {
        let cfg = WmTrainCfg {
            lr_start: 1.0,
            lr_end: 0.0,
            decay_power: 2.0,
            total_steps: 100,
            reward_scale: 1.0,
        };
        assert!((cfg.lr_at(0) - 1.0).abs() < 1e-6);
        assert!((cfg.lr_at(50) - 0.25).abs() < 1e-6);
        assert!(cfg.lr_at(100) < 1e-6);
        assert!(cfg.lr_at(200) < 1e-6); // clamps past the horizon
    }
}
