//! World-model training (§3.3.2, Fig. 8): teacher-forced sequence batches
//! sampled from collected episodes, driven through the `wm_train` artifact
//! with the paper's 2nd-degree polynomial learning-rate decay.

use xla::Literal;

use crate::agent::buffer::{sample_windows, Episode};
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, scalar_f32, Engine, ParamStore};
use crate::util::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct WmLosses {
    pub total: f32,
    pub nll: f32,
    pub reward_mse: f32,
    pub mask_bce: f32,
    pub done_bce: f32,
}

#[derive(Debug, Clone, Copy)]
pub struct WmTrainCfg {
    pub lr_start: f32,
    pub lr_end: f32,
    /// Polynomial decay power (paper §4.7: 2nd-degree).
    pub decay_power: f32,
    pub total_steps: usize,
    /// Rewards are divided by this before regression (keeps MSE in range
    /// against the -100 invalid penalty).
    pub reward_scale: f32,
}

impl Default for WmTrainCfg {
    fn default() -> Self {
        Self { lr_start: 1e-3, lr_end: 1e-5, decay_power: 2.0, total_steps: 300, reward_scale: 10.0 }
    }
}

impl WmTrainCfg {
    pub fn lr_at(&self, step: usize) -> f32 {
        let p = (step as f32 / self.total_steps.max(1) as f32).min(1.0);
        self.lr_end + (self.lr_start - self.lr_end) * (1.0 - p).powf(self.decay_power)
    }
}

pub struct WmTrainer<'e> {
    pub engine: &'e Engine,
    b: usize,
    t: usize,
    zdim: usize,
    x1: usize,
}

impl<'e> WmTrainer<'e> {
    pub fn new(engine: &'e Engine) -> anyhow::Result<Self> {
        Ok(Self {
            engine,
            b: engine.manifest.hp_usize("B_WM")?,
            t: engine.manifest.hp_usize("SEQ_LEN")?,
            zdim: engine.manifest.hp_usize("LATENT")?,
            x1: engine.manifest.hp_usize("N_XFERS1")?,
        })
    }

    /// Assemble the 7 batch tensors from sampled episode windows.
    /// Requires `ep.z` to be filled by the encoder pass.
    pub fn make_batch(
        &self,
        episodes: &[Episode],
        reward_scale: f32,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<Literal>> {
        let (b, t, zd, x1) = (self.b, self.t, self.zdim, self.x1);
        let windows = sample_windows(episodes, b, rng);
        let mut z = vec![0.0f32; b * t * zd];
        let mut a = vec![0i32; b * t * 2];
        let mut z_next = vec![0.0f32; b * t * zd];
        let mut r = vec![0.0f32; b * t];
        let mut xm = vec![0.0f32; b * t * x1];
        let mut done = vec![0.0f32; b * t];
        let mut valid = vec![0.0f32; b * t];

        for (bi, (ep, start)) in windows.into_iter().enumerate() {
            anyhow::ensure!(
                ep.z.len() == ep.states.len() && !ep.z.is_empty(),
                "episode latents not encoded"
            );
            for ti in 0..t {
                let s = start + ti;
                if s >= ep.len() {
                    break;
                }
                let base = (bi * t + ti) * zd;
                z[base..base + zd].copy_from_slice(&ep.z[s]);
                z_next[base..base + zd].copy_from_slice(&ep.z[s + 1]);
                a[(bi * t + ti) * 2] = ep.actions[s].0 as i32;
                a[(bi * t + ti) * 2 + 1] = ep.actions[s].1 as i32;
                r[bi * t + ti] = ep.rewards[s] / reward_scale;
                // Mask target: validity of the NEXT state (what the dream
                // env needs to predict after taking a_t).
                let xm_base = (bi * t + ti) * x1;
                xm[xm_base..xm_base + x1].copy_from_slice(&ep.xmasks[s + 1]);
                done[bi * t + ti] = ep.dones[s];
                valid[bi * t + ti] = 1.0;
            }
        }
        Ok(vec![
            lit_f32(&z, &[b, t, zd])?,
            lit_i32(&a, &[b, t, 2])?,
            lit_f32(&z_next, &[b, t, zd])?,
            lit_f32(&r, &[b, t])?,
            lit_f32(&xm, &[b, t, x1])?,
            lit_f32(&done, &[b, t])?,
            lit_f32(&valid, &[b, t])?,
        ])
    }

    /// One gradient step; returns the component losses (Fig. 8's curve).
    pub fn train_step(
        &self,
        wm: &mut ParamStore,
        episodes: &[Episode],
        lr: f32,
        reward_scale: f32,
        rng: &mut Rng,
    ) -> anyhow::Result<WmLosses> {
        let mut args = wm.train_args()?;
        args.extend(self.make_batch(episodes, reward_scale, rng)?);
        args.push(lit_scalar_f32(lr));
        let out = self.engine.exec("wm_train", &args)?;
        wm.absorb(&out)?;
        Ok(WmLosses {
            total: scalar_f32(&out[4])?,
            nll: scalar_f32(&out[5])?,
            reward_mse: scalar_f32(&out[6])?,
            mask_bce: scalar_f32(&out[7])?,
            done_bce: scalar_f32(&out[8])?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polynomial_decay_schedule() {
        let cfg = WmTrainCfg { lr_start: 1.0, lr_end: 0.0, decay_power: 2.0, total_steps: 100, reward_scale: 1.0 };
        assert!((cfg.lr_at(0) - 1.0).abs() < 1e-6);
        assert!((cfg.lr_at(50) - 0.25).abs() < 1e-6);
        assert!(cfg.lr_at(100) < 1e-6);
        assert!(cfg.lr_at(200) < 1e-6); // clamps past the horizon
    }
}
