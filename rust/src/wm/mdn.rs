//! GMM sampling with temperature (§3.3.2, Fig. 4).
//!
//! The `wm_step_*` artifacts return raw MDN parameters; sampling happens
//! here in Rust so the temperature sweep (Table 3) never re-exports
//! artifacts. Per Ha & Schmidhuber: mixture logits are divided by τ before
//! the softmax and the chosen component's σ is scaled by √τ — τ→0 gives
//! deterministic predictions, larger τ more diverse futures.

use crate::util::Rng;

/// Sample one latent vector from per-dimension K-component mixtures.
///
/// `log_pi`, `mu`, `log_sig` are `[z_dim * k]` row-major (dimension-major).
pub fn sample_mdn(
    log_pi: &[f32],
    mu: &[f32],
    log_sig: &[f32],
    z_dim: usize,
    k: usize,
    temperature: f32,
    rng: &mut Rng,
) -> Vec<f32> {
    debug_assert_eq!(log_pi.len(), z_dim * k);
    let tau = temperature.max(1e-4);
    let sqrt_tau = tau.sqrt();
    let mut out = Vec::with_capacity(z_dim);
    let all_true = vec![true; k];
    for d in 0..z_dim {
        let row = &log_pi[d * k..(d + 1) * k];
        let scaled: Vec<f32> = row.iter().map(|&l| l / tau).collect();
        let comp = rng.sample_logits_masked(&scaled, &all_true);
        let m = mu[d * k + comp];
        let s = log_sig[d * k + comp].exp();
        out.push(m + s * sqrt_tau * rng.normal());
    }
    out
}

/// Deterministic mode of the mixture (argmax component mean) — used for
/// greedy latent rollouts and tests.
pub fn mdn_mode(log_pi: &[f32], mu: &[f32], z_dim: usize, k: usize) -> Vec<f32> {
    (0..z_dim)
        .map(|d| {
            let row = &log_pi[d * k..(d + 1) * k];
            let best = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            mu[d * k + best]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_temperature_concentrates_on_mode() {
        let (z, k) = (4, 3);
        // Component 1 dominant everywhere, mu distinct.
        let log_pi: Vec<f32> = (0..z * k).map(|i| if i % k == 1 { 5.0 } else { -5.0 }).collect();
        let mu: Vec<f32> = (0..z * k).map(|i| (i % k) as f32 * 10.0).collect();
        let log_sig = vec![-6.0; z * k];
        let mut rng = Rng::new(0);
        let s = sample_mdn(&log_pi, &mu, &log_sig, z, k, 0.01, &mut rng);
        let mode = mdn_mode(&log_pi, &mu, z, k);
        for (a, b) in s.iter().zip(&mode) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }

    #[test]
    fn high_temperature_diversifies_components() {
        let (z, k) = (1, 2);
        let log_pi = vec![2.0, -2.0]; // component 0 preferred
        let mu = vec![0.0, 100.0];
        let log_sig = vec![-6.0, -6.0];
        let mut rng = Rng::new(1);
        let mut saw_minor = false;
        for _ in 0..500 {
            let s = sample_mdn(&log_pi, &mu, &log_sig, z, k, 3.0, &mut rng);
            if s[0] > 50.0 {
                saw_minor = true;
                break;
            }
        }
        assert!(saw_minor, "tau=3 should occasionally pick the minor component");
        // At tau=0.05 the minor component should effectively never appear.
        let mut rng = Rng::new(2);
        for _ in 0..500 {
            let s = sample_mdn(&log_pi, &mu, &log_sig, z, k, 0.05, &mut rng);
            assert!(s[0] < 50.0);
        }
    }

    #[test]
    fn sigma_scales_with_sqrt_tau() {
        let (z, k) = (1, 1);
        let log_pi = vec![0.0];
        let mu = vec![0.0];
        let log_sig = vec![0.0]; // sigma = 1
        let spread = |tau: f32, seed: u64| {
            let mut rng = Rng::new(seed);
            let xs: Vec<f32> = (0..4000)
                .map(|_| sample_mdn(&log_pi, &mu, &log_sig, z, k, tau, &mut rng)[0])
                .collect();
            let mean = xs.iter().sum::<f32>() / xs.len() as f32;
            (xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32).sqrt()
        };
        let s1 = spread(1.0, 3);
        let s4 = spread(4.0, 3);
        assert!((s4 / s1 - 2.0).abs() < 0.2, "sqrt-tau scaling: {s1} vs {s4}");
    }
}
