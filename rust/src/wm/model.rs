//! Typed `wm_step_*` API: one [`WorldModel`] wraps a backend and exposes
//! the MDN-RNN transition as a method over latents, [`Action`]s and the
//! recurrent `(h, c)` context — callers never touch program names or raw
//! argument packing.

use crate::agent::Action;
use crate::runtime::{Backend, Manifest, ParamStore, TensorView};

/// World-model dimensions read once from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct WmDims {
    pub zdim: usize,
    pub rdim: usize,
    pub x1: usize,
    pub k: usize,
    pub b_dream: usize,
}

impl WmDims {
    pub fn from_manifest(m: &Manifest) -> anyhow::Result<Self> {
        Ok(Self {
            zdim: m.hp_usize("LATENT")?,
            rdim: m.hp_usize("RNN_HIDDEN")?,
            x1: m.hp_usize("N_XFERS1")?,
            k: m.hp_usize("MDN_K")?,
            b_dream: m.hp_usize("B_DREAM")?,
        })
    }
}

/// One batched transition's outputs, row-major over the batch.
#[derive(Debug, Clone)]
pub struct WmStepOut {
    pub log_pi: Vec<f32>,      // [b, zdim * k], dimension-major
    pub mu: Vec<f32>,          // [b, zdim * k]
    pub log_sig: Vec<f32>,     // [b, zdim * k]
    pub rewards: Vec<f32>,     // [b]
    pub mask_logits: Vec<f32>, // [b, x1]
    pub done_logits: Vec<f32>, // [b]
    pub h1: Vec<f32>,          // [b, rdim]
    pub c1: Vec<f32>,          // [b, rdim]
}

/// Typed transition API over the `wm_step_1` / `wm_step_b` programs.
pub struct WorldModel<'b> {
    pub backend: &'b dyn Backend,
    pub dims: WmDims,
}

impl<'b> WorldModel<'b> {
    pub fn new(backend: &'b dyn Backend) -> anyhow::Result<Self> {
        Ok(Self { backend, dims: WmDims::from_manifest(backend.manifest())? })
    }

    /// Advance the recurrent model one step for `actions.len()` rows
    /// (1 or B_DREAM — the two exported batch widths).
    pub fn step(
        &self,
        wm: &ParamStore,
        z: &[f32],
        actions: &[Action],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<WmStepOut> {
        let d = &self.dims;
        let b = actions.len();
        anyhow::ensure!(
            z.len() == b * d.zdim && h.len() == b * d.rdim && c.len() == b * d.rdim,
            "wm step: bad state sizes for batch {b}"
        );
        let program = if b == 1 {
            "wm_step_1"
        } else if b == d.b_dream {
            "wm_step_b"
        } else {
            anyhow::bail!("wm step: batch {b} matches neither 1 nor B_DREAM {}", d.b_dream)
        };
        let mut a = Vec::with_capacity(b * 2);
        for act in actions {
            a.push(act.slot as i32);
            a.push(act.loc as i32);
        }
        let out = self.backend.exec_with_params(
            program,
            wm,
            &[
                TensorView::f32(z, &[b, d.zdim]),
                TensorView::i32(&a, &[b, 2]),
                TensorView::f32(h, &[b, d.rdim]),
                TensorView::f32(c, &[b, d.rdim]),
            ],
        )?;
        anyhow::ensure!(out.len() == 8, "wm step: expected 8 outputs, got {}", out.len());
        let mut it = out.into_iter().map(|t| t.data);
        Ok(WmStepOut {
            log_pi: it.next().unwrap(),
            mu: it.next().unwrap(),
            log_sig: it.next().unwrap(),
            rewards: it.next().unwrap(),
            mask_logits: it.next().unwrap(),
            done_logits: it.next().unwrap(),
            h1: it.next().unwrap(),
            c1: it.next().unwrap(),
        })
    }
}
