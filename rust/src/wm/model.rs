//! Typed `wm_step_*` API: one [`WorldModel`] wraps a backend and exposes
//! the MDN-RNN transition as a method over latents, [`Action`]s and the
//! recurrent `(h, c)` context — callers never touch program names or raw
//! argument packing.

use crate::agent::Action;
use crate::runtime::{Backend, Manifest, ParamStore, TensorView};

/// World-model dimensions read once from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct WmDims {
    pub zdim: usize,
    pub rdim: usize,
    pub x1: usize,
    pub k: usize,
    pub b_dream: usize,
}

impl WmDims {
    pub fn from_manifest(m: &Manifest) -> anyhow::Result<Self> {
        Ok(Self {
            zdim: m.hp_usize("LATENT")?,
            rdim: m.hp_usize("RNN_HIDDEN")?,
            x1: m.hp_usize("N_XFERS1")?,
            k: m.hp_usize("MDN_K")?,
            b_dream: m.hp_usize("B_DREAM")?,
        })
    }
}

/// One batched transition's outputs, row-major over the batch.
#[derive(Debug, Clone)]
pub struct WmStepOut {
    pub log_pi: Vec<f32>,      // [b, zdim * k], dimension-major
    pub mu: Vec<f32>,          // [b, zdim * k]
    pub log_sig: Vec<f32>,     // [b, zdim * k]
    pub rewards: Vec<f32>,     // [b]
    pub mask_logits: Vec<f32>, // [b, x1]
    pub done_logits: Vec<f32>, // [b]
    pub h1: Vec<f32>,          // [b, rdim]
    pub c1: Vec<f32>,          // [b, rdim]
}

/// Typed transition API over the `wm_step_1` / `wm_step_b` programs.
pub struct WorldModel<'b> {
    pub backend: &'b dyn Backend,
    pub dims: WmDims,
}

impl<'b> WorldModel<'b> {
    pub fn new(backend: &'b dyn Backend) -> anyhow::Result<Self> {
        Ok(Self { backend, dims: WmDims::from_manifest(backend.manifest())? })
    }

    /// Advance the recurrent model one step for `actions.len()` rows.
    ///
    /// `b == 1` and `b == B_DREAM` map directly onto the exported
    /// programs; any other width (e.g. the alive rows of an EnvPool
    /// evaluation pass) is chunked into `B_DREAM`-wide calls — the last
    /// chunk padded by repeating its first row — and dispatched as one
    /// [`exec_with_params_batch`](crate::runtime::Backend::exec_with_params_batch).
    /// Rows are computed independently by the backend programs, so the
    /// per-row outputs are bit-identical to `b` separate `wm_step_1`
    /// calls.
    pub fn step(
        &self,
        wm: &ParamStore,
        z: &[f32],
        actions: &[Action],
        h: &[f32],
        c: &[f32],
    ) -> anyhow::Result<WmStepOut> {
        let d = &self.dims;
        let b = actions.len();
        anyhow::ensure!(
            z.len() == b * d.zdim && h.len() == b * d.rdim && c.len() == b * d.rdim,
            "wm step: bad state sizes for batch {b}"
        );
        let mut a = Vec::with_capacity(b * 2);
        for act in actions {
            a.push(act.slot as i32);
            a.push(act.loc as i32);
        }
        if b == 1 || b == d.b_dream {
            let program = if b == 1 { "wm_step_1" } else { "wm_step_b" };
            let out = self.backend.exec_with_params(
                program,
                wm,
                &[
                    TensorView::f32(z, &[b, d.zdim]),
                    TensorView::i32(&a, &[b, 2]),
                    TensorView::f32(h, &[b, d.rdim]),
                    TensorView::f32(c, &[b, d.rdim]),
                ],
            )?;
            anyhow::ensure!(out.len() == 8, "wm step: expected 8 outputs, got {}", out.len());
            let mut it = out.into_iter().map(|t| t.data);
            return Ok(WmStepOut {
                log_pi: it.next().unwrap(),
                mu: it.next().unwrap(),
                log_sig: it.next().unwrap(),
                rewards: it.next().unwrap(),
                mask_logits: it.next().unwrap(),
                done_logits: it.next().unwrap(),
                h1: it.next().unwrap(),
                c1: it.next().unwrap(),
            });
        }
        // Chunk + pad to the exported B_DREAM width.
        let bb = d.b_dream;
        let n_chunks = b.div_ceil(bb);
        struct Chunk {
            z: Vec<f32>,
            a: Vec<i32>,
            h: Vec<f32>,
            c: Vec<f32>,
        }
        let mut chunks: Vec<Chunk> = Vec::with_capacity(n_chunks);
        for ci in 0..n_chunks {
            let lo = ci * bb;
            let hi = (lo + bb).min(b);
            let mut ch = Chunk {
                z: Vec::with_capacity(bb * d.zdim),
                a: Vec::with_capacity(bb * 2),
                h: Vec::with_capacity(bb * d.rdim),
                c: Vec::with_capacity(bb * d.rdim),
            };
            for row in lo..hi {
                ch.z.extend_from_slice(&z[row * d.zdim..(row + 1) * d.zdim]);
                ch.a.extend_from_slice(&a[row * 2..(row + 1) * 2]);
                ch.h.extend_from_slice(&h[row * d.rdim..(row + 1) * d.rdim]);
                ch.c.extend_from_slice(&c[row * d.rdim..(row + 1) * d.rdim]);
            }
            for _ in hi..lo + bb {
                ch.z.extend_from_slice(&z[lo * d.zdim..(lo + 1) * d.zdim]);
                ch.a.extend_from_slice(&a[lo * 2..(lo + 1) * 2]);
                ch.h.extend_from_slice(&h[lo * d.rdim..(lo + 1) * d.rdim]);
                ch.c.extend_from_slice(&c[lo * d.rdim..(lo + 1) * d.rdim]);
            }
            chunks.push(ch);
        }
        let rests: Vec<Vec<TensorView>> = chunks
            .iter()
            .map(|ch| {
                vec![
                    TensorView::f32(&ch.z, &[bb, d.zdim]),
                    TensorView::i32(&ch.a, &[bb, 2]),
                    TensorView::f32(&ch.h, &[bb, d.rdim]),
                    TensorView::f32(&ch.c, &[bb, d.rdim]),
                ]
            })
            .collect();
        let outs = self.backend.exec_with_params_batch("wm_step_b", wm, &rests)?;
        let zk = d.zdim * d.k;
        let mut res = WmStepOut {
            log_pi: Vec::with_capacity(b * zk),
            mu: Vec::with_capacity(b * zk),
            log_sig: Vec::with_capacity(b * zk),
            rewards: Vec::with_capacity(b),
            mask_logits: Vec::with_capacity(b * d.x1),
            done_logits: Vec::with_capacity(b),
            h1: Vec::with_capacity(b * d.rdim),
            c1: Vec::with_capacity(b * d.rdim),
        };
        for (ci, out) in outs.into_iter().enumerate() {
            anyhow::ensure!(out.len() == 8, "wm step: expected 8 outputs, got {}", out.len());
            let real = (b - ci * bb).min(bb);
            res.log_pi.extend_from_slice(&out[0].data[..real * zk]);
            res.mu.extend_from_slice(&out[1].data[..real * zk]);
            res.log_sig.extend_from_slice(&out[2].data[..real * zk]);
            res.rewards.extend_from_slice(&out[3].data[..real]);
            res.mask_logits.extend_from_slice(&out[4].data[..real * d.x1]);
            res.done_logits.extend_from_slice(&out[5].data[..real]);
            res.h1.extend_from_slice(&out[6].data[..real * d.rdim]);
            res.c1.extend_from_slice(&out[7].data[..real * d.rdim]);
        }
        Ok(res)
    }
}
