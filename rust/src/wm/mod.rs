//! World model (§3.3): MDN-RNN training, GMM sampling with temperature,
//! and the imagined (dream) environment the controller trains in.

pub mod dream;
pub mod mdn;
pub mod trainer;

pub use dream::DreamEnv;
pub use mdn::{mdn_mode, sample_mdn};
pub use trainer::{WmLosses, WmTrainCfg, WmTrainer};
