//! World model (§3.3): the typed `wm_step_*` API, MDN-RNN training, GMM
//! sampling with temperature, and the imagined (dream) environment the
//! controller trains in.

pub mod dream;
pub mod mdn;
pub mod model;
pub mod trainer;

pub use dream::DreamEnv;
pub use mdn::{mdn_mode, sample_mdn};
pub use model::{WmDims, WmStepOut, WorldModel};
pub use trainer::{WmBatch, WmLosses, WmTrainCfg, WmTrainer};
