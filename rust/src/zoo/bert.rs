//! BERT-Base encoder stack (Devlin et al., 2019), batch 1, sequence 128.
//!
//! 12 transformer encoder layers of hidden size 768 with 12 heads, built
//! entirely from primitive ops (separate Q/K/V linears, scaled dot-product
//! attention, post-LN residual blocks). The repeated Add -> LayerNorm pairs
//! are the exact pattern RLFlow's §4.10 fusion discovers.
//!
//! The embedding front-end is represented by the pre-embedded input tensor
//! [1, 128, 768] (token/position lookup is not a graph-optimisation target
//! in TASO either).

use crate::graph::{Graph, GraphBuilder};

pub const SEQ: usize = 128;
pub const HIDDEN: usize = 768;
pub const HEADS: usize = 12;
pub const LAYERS: usize = 12;

pub fn bert_base() -> Graph {
    build().expect("bert construction is static")
}

fn build() -> anyhow::Result<Graph> {
    let mut b = GraphBuilder::new();
    let mut x = b.input(&[1, SEQ, HIDDEN]);
    for _ in 0..LAYERS {
        x = b.transformer_encoder(x, HEADS, 4)?;
    }
    let g = b.finish();
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn twelve_encoder_layers() {
        let g = bert_base();
        let lns = g
            .live_ids()
            .filter(|&id| matches!(g.node(id).op, OpKind::LayerNorm))
            .count();
        assert_eq!(lns, 2 * LAYERS);
        let softmaxes = g
            .live_ids()
            .filter(|&id| matches!(g.node(id).op, OpKind::Softmax { .. }))
            .count();
        assert_eq!(softmaxes, LAYERS);
    }

    #[test]
    fn output_shape_is_hidden_states() {
        let g = bert_base();
        let outs = g.output_ids();
        assert_eq!(outs.len(), 1);
        assert_eq!(g.node(outs[0]).outs[0].shape, vec![1, SEQ, HIDDEN]);
    }

    #[test]
    fn add_layernorm_chains_exist() {
        // The §4.10 target: LayerNorm whose x input is an Add.
        let g = bert_base();
        let mut pairs = 0;
        for id in g.live_ids() {
            if matches!(g.node(id).op, OpKind::LayerNorm) {
                let src = g.node(id).inputs[0].node;
                if matches!(g.node(src).op, OpKind::Add) {
                    pairs += 1;
                }
            }
        }
        assert_eq!(pairs, 2 * LAYERS);
    }
}
