//! InceptionV3 (Szegedy et al., CVPR 2016), NCHW, batch 1.
//!
//! Full stem plus the canonical mixed blocks: 3x InceptionA, reduction B,
//! 2x InceptionC (the 7x7-factorised branches use 1x7/7x1 pairs collapsed
//! to 7x7-SAME convs to stay within the square-kernel IR), reduction D and
//! 2x InceptionE. Multi-branch concats everywhere — the richest rule-match
//! surface in the zoo, which is also why TASO historically does *better*
//! than RL here (§4.4).

use crate::graph::{Graph, GraphBuilder, PadMode, PortRef};

fn cbr(b: &mut GraphBuilder, x: PortRef, co: usize, k: usize, stride: usize, pad: PadMode) -> anyhow::Result<PortRef> {
    b.conv_bn_relu(x, co, k, stride, pad)
}

fn inception_a(b: &mut GraphBuilder, x: PortRef, pool_ch: usize) -> anyhow::Result<PortRef> {
    let b1 = cbr(b, x, 64, 1, 1, PadMode::Same)?;

    let b2 = cbr(b, x, 48, 1, 1, PadMode::Same)?;
    let b2 = cbr(b, b2, 64, 5, 1, PadMode::Same)?;

    let b3 = cbr(b, x, 64, 1, 1, PadMode::Same)?;
    let b3 = cbr(b, b3, 96, 3, 1, PadMode::Same)?;
    let b3 = cbr(b, b3, 96, 3, 1, PadMode::Same)?;

    let b4 = b.avgpool(x, 3, 1)?;
    let b4 = cbr(b, b4, pool_ch, 1, 1, PadMode::Same)?;

    b.concat(1, &[b1, b2, b3, b4])
}

fn reduction_b(b: &mut GraphBuilder, x: PortRef) -> anyhow::Result<PortRef> {
    let b1 = cbr(b, x, 384, 3, 2, PadMode::Valid)?;

    let b2 = cbr(b, x, 64, 1, 1, PadMode::Same)?;
    let b2 = cbr(b, b2, 96, 3, 1, PadMode::Same)?;
    let b2 = cbr(b, b2, 96, 3, 2, PadMode::Valid)?;

    let b3 = b.op(
        crate::graph::OpKind::MaxPool { k: 3, stride: 2, pad: PadMode::Valid },
        &[x],
    )?;
    b.concat(1, &[b1, b2, b3])
}

fn inception_c(b: &mut GraphBuilder, x: PortRef, mid: usize) -> anyhow::Result<PortRef> {
    let b1 = cbr(b, x, 192, 1, 1, PadMode::Same)?;

    // 7x7 factorised branch (collapsed to square 7x7 SAME).
    let b2 = cbr(b, x, mid, 1, 1, PadMode::Same)?;
    let b2 = cbr(b, b2, 192, 7, 1, PadMode::Same)?;

    let b3 = cbr(b, x, mid, 1, 1, PadMode::Same)?;
    let b3 = cbr(b, b3, mid, 7, 1, PadMode::Same)?;
    let b3 = cbr(b, b3, 192, 7, 1, PadMode::Same)?;

    let b4 = b.avgpool(x, 3, 1)?;
    let b4 = cbr(b, b4, 192, 1, 1, PadMode::Same)?;

    b.concat(1, &[b1, b2, b3, b4])
}

fn reduction_d(b: &mut GraphBuilder, x: PortRef) -> anyhow::Result<PortRef> {
    let b1 = cbr(b, x, 192, 1, 1, PadMode::Same)?;
    let b1 = cbr(b, b1, 320, 3, 2, PadMode::Valid)?;

    let b2 = cbr(b, x, 192, 1, 1, PadMode::Same)?;
    let b2 = cbr(b, b2, 192, 7, 1, PadMode::Same)?;
    let b2 = cbr(b, b2, 192, 3, 2, PadMode::Valid)?;

    let b3 = b.op(
        crate::graph::OpKind::MaxPool { k: 3, stride: 2, pad: PadMode::Valid },
        &[x],
    )?;
    b.concat(1, &[b1, b2, b3])
}

fn inception_e(b: &mut GraphBuilder, x: PortRef) -> anyhow::Result<PortRef> {
    let b1 = cbr(b, x, 320, 1, 1, PadMode::Same)?;

    // Split 3x3 branch (1x3 + 3x1 in the original; square-collapsed).
    let b2 = cbr(b, x, 384, 1, 1, PadMode::Same)?;
    let b2a = cbr(b, b2, 384, 3, 1, PadMode::Same)?;
    let b2b = cbr(b, b2, 384, 3, 1, PadMode::Same)?;
    let b2cat = b.concat(1, &[b2a, b2b])?;

    let b3 = cbr(b, x, 448, 1, 1, PadMode::Same)?;
    let b3 = cbr(b, b3, 384, 3, 1, PadMode::Same)?;
    let b3a = cbr(b, b3, 384, 3, 1, PadMode::Same)?;
    let b3b = cbr(b, b3, 384, 3, 1, PadMode::Same)?;
    let b3cat = b.concat(1, &[b3a, b3b])?;

    let b4 = b.avgpool(x, 3, 1)?;
    let b4 = cbr(b, b4, 192, 1, 1, PadMode::Same)?;

    b.concat(1, &[b1, b2cat, b3cat, b4])
}

pub fn inception_v3() -> Graph {
    build().expect("inception construction is static")
}

fn build() -> anyhow::Result<Graph> {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 299, 299]);
    // Stem.
    let mut y = cbr(&mut b, x, 32, 3, 2, PadMode::Valid)?;
    y = cbr(&mut b, y, 32, 3, 1, PadMode::Valid)?;
    y = cbr(&mut b, y, 64, 3, 1, PadMode::Same)?;
    y = b.maxpool(y, 3, 2)?;
    y = cbr(&mut b, y, 80, 1, 1, PadMode::Same)?;
    y = cbr(&mut b, y, 192, 3, 1, PadMode::Valid)?;
    y = b.maxpool(y, 3, 2)?;

    // Mixed blocks.
    y = inception_a(&mut b, y, 32)?;
    y = inception_a(&mut b, y, 64)?;
    y = inception_a(&mut b, y, 64)?;
    y = reduction_b(&mut b, y)?;
    y = inception_c(&mut b, y, 128)?;
    y = inception_c(&mut b, y, 192)?;
    y = reduction_d(&mut b, y)?;
    y = inception_e(&mut b, y)?;
    y = inception_e(&mut b, y)?;

    // Head.
    let s = b.shape(y)?.clone();
    let pooled = b.avgpool(y, s[2], s[2])?;
    let flat = b.reshape(pooled, &[1, s[1]])?;
    b.linear(flat, 1000, crate::graph::Activation::None)?;
    let g = b.finish();
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn block_structure_present() {
        let g = inception_v3();
        let concats = g
            .live_ids()
            .filter(|&id| matches!(g.node(id).op, OpKind::Concat { .. }))
            .count();
        // 3xA + B + 2xC + D + 2xE(3 concats each) = 3+1+2+1+6 = 13.
        assert_eq!(concats, 13);
    }

    #[test]
    fn op_budget() {
        let g = inception_v3();
        assert!(g.n_ops() <= 320, "{} ops", g.n_ops());
        assert!(g.n_ops() > 150, "{} ops", g.n_ops());
    }
}
