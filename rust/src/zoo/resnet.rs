//! ResNet-18 and ResNet-50 (He et al., CVPR 2016), NCHW, batch 1.
//!
//! ResNet-18 uses basic blocks (two 3x3 convs), ResNet-50 bottleneck blocks
//! (1x1 -> 3x3 -> 1x1) with stage depths [3, 4, 6, 3]. Projection shortcuts
//! where shape changes, identity adds elsewhere — the residual `Add` nodes
//! are what several substitution rules target.

use crate::graph::{Graph, GraphBuilder, PadMode, PortRef};

fn stem(b: &mut GraphBuilder) -> anyhow::Result<PortRef> {
    let x = b.input(&[1, 3, 224, 224]);
    let c = b.conv_bn_relu(x, 64, 7, 2, PadMode::Same)?;
    b.maxpool(c, 3, 2)
}

/// Basic residual block: 3x3 conv-bn-relu, 3x3 conv-bn, shortcut, add, relu.
fn basic_block(
    b: &mut GraphBuilder,
    x: PortRef,
    channels: usize,
    stride: usize,
) -> anyhow::Result<PortRef> {
    let c1 = b.conv_bn_relu(x, channels, 3, stride, PadMode::Same)?;
    let c2 = b.conv(c1, channels, 3, 1, PadMode::Same)?;
    let c2 = b.batchnorm(c2)?;
    let shortcut = if stride != 1 || in_channels(b, x)? != channels {
        let s = b.conv(x, channels, 1, stride, PadMode::Same)?;
        b.batchnorm(s)?
    } else {
        x
    };
    let sum = b.add(c2, shortcut)?;
    b.relu(sum)
}

/// Bottleneck block: 1x1 reduce, 3x3, 1x1 expand (4x), shortcut, add, relu.
fn bottleneck(
    b: &mut GraphBuilder,
    x: PortRef,
    mid: usize,
    stride: usize,
) -> anyhow::Result<PortRef> {
    let out_ch = mid * 4;
    let c1 = b.conv_bn_relu(x, mid, 1, 1, PadMode::Same)?;
    let c2 = b.conv_bn_relu(c1, mid, 3, stride, PadMode::Same)?;
    let c3 = b.conv(c2, out_ch, 1, 1, PadMode::Same)?;
    let c3 = b.batchnorm(c3)?;
    let shortcut = if stride != 1 || in_channels(b, x)? != out_ch {
        let s = b.conv(x, out_ch, 1, stride, PadMode::Same)?;
        b.batchnorm(s)?
    } else {
        x
    };
    let sum = b.add(c3, shortcut)?;
    b.relu(sum)
}

fn in_channels(b: &GraphBuilder, x: PortRef) -> anyhow::Result<usize> {
    Ok(b.shape(x)?[1])
}

fn head(b: &mut GraphBuilder, x: PortRef, classes: usize) -> anyhow::Result<PortRef> {
    let s = b.shape(x)?.clone();
    let pooled = b.avgpool(x, s[2], s[2])?; // global average pool
    let flat = b.reshape(pooled, &[1, s[1]])?;
    b.linear(flat, classes, crate::graph::Activation::None)
}

pub fn resnet18() -> Graph {
    build_resnet18().expect("resnet18 construction is static")
}

fn build_resnet18() -> anyhow::Result<Graph> {
    let mut b = GraphBuilder::new();
    let mut x = stem(&mut b)?;
    for (channels, blocks, first_stride) in [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)] {
        for i in 0..blocks {
            let stride = if i == 0 { first_stride } else { 1 };
            x = basic_block(&mut b, x, channels, stride)?;
        }
    }
    head(&mut b, x, 1000)?;
    let g = b.finish();
    g.validate()?;
    Ok(g)
}

pub fn resnet50() -> Graph {
    build_resnet50().expect("resnet50 construction is static")
}

fn build_resnet50() -> anyhow::Result<Graph> {
    let mut b = GraphBuilder::new();
    let mut x = stem(&mut b)?;
    for (mid, blocks, first_stride) in [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)] {
        for i in 0..blocks {
            let stride = if i == 0 { first_stride } else { 1 };
            x = bottleneck(&mut b, x, mid, stride)?;
        }
    }
    head(&mut b, x, 1000)?;
    let g = b.finish();
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    fn conv_count(g: &Graph) -> usize {
        g.live_ids()
            .filter(|&id| matches!(g.node(id).op, OpKind::Conv2d { .. }))
            .count()
    }

    #[test]
    fn resnet18_has_expected_convs() {
        // stem 1 + 8 basic blocks x 2 + 3 projection shortcuts = 20.
        assert_eq!(conv_count(&resnet18()), 20);
    }

    #[test]
    fn resnet50_has_expected_convs() {
        // stem 1 + 16 bottlenecks x 3 + 4 projections = 53.
        assert_eq!(conv_count(&resnet50()), 53);
    }

    #[test]
    fn output_is_logits() {
        for g in [resnet18(), resnet50()] {
            let outs = g.output_ids();
            assert_eq!(outs.len(), 1);
            assert_eq!(g.node(outs[0]).outs[0].shape, vec![1, 1000]);
        }
    }
}
