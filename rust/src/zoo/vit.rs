//! ViT-Base (Dosovitskiy et al., ICLR 2021), batch 1, 224x224, patch 16.
//!
//! Patch embedding as a strided 16x16 conv, flatten to [1, 196, 768],
//! then 12 transformer encoder layers (12 heads, MLP ratio 4) and a
//! classification head. Same encoder block as BERT — which is why §4.10's
//! fused substitutions transfer between the two (paper Fig. 11 caption).

use crate::graph::{Activation, Graph, GraphBuilder, PadMode};

pub const IMG: usize = 224;
pub const PATCH: usize = 16;
pub const HIDDEN: usize = 768;
pub const HEADS: usize = 12;
pub const LAYERS: usize = 12;

pub fn vit_base() -> Graph {
    build().expect("vit construction is static")
}

fn build() -> anyhow::Result<Graph> {
    let n_patches = (IMG / PATCH) * (IMG / PATCH); // 196
    let mut b = GraphBuilder::new();
    let img = b.input(&[1, 3, IMG, IMG]);
    // Patch embedding: 16x16/16 conv -> [1, 768, 14, 14].
    let emb = b.conv(img, HIDDEN, PATCH, PATCH, PadMode::Valid)?;
    let flat = b.reshape(emb, &[1, HIDDEN, n_patches])?;
    let tokens = b.transpose(flat, &[0, 2, 1])?; // [1, 196, 768]
    // Learned position embedding.
    let pos = b.weight(&[1, n_patches, HIDDEN]);
    let mut x = b.add(tokens, pos)?;
    for _ in 0..LAYERS {
        x = b.transformer_encoder(x, HEADS, 4)?;
    }
    let ln = b.layernorm(x)?;
    // Classification head over the token representations (the downstream
    // readout picks the CLS row; graph-wise this is a per-token linear).
    let cls_in = b.reshape(ln, &[n_patches, HIDDEN])?;
    b.linear(cls_in, 1000, Activation::None)?;
    let g = b.finish();
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn patch_embed_is_strided_conv() {
        let g = vit_base();
        let has = g.live_ids().any(|id| {
            matches!(g.node(id).op, OpKind::Conv2d { stride, .. } if stride == PATCH)
        });
        assert!(has);
    }

    #[test]
    fn encoder_depth() {
        let g = vit_base();
        let softmaxes = g
            .live_ids()
            .filter(|&id| matches!(g.node(id).op, OpKind::Softmax { .. }))
            .count();
        assert_eq!(softmaxes, LAYERS);
    }

    #[test]
    fn op_budget() {
        let g = vit_base();
        assert!(g.n_ops() <= 320, "{} ops", g.n_ops());
    }
}
