//! SqueezeNet 1.1 (Iandola et al., 2016), NCHW, batch 1.
//!
//! Fire modules: a 1x1 squeeze conv feeding parallel 1x1 and 3x3 expand
//! convs whose outputs concatenate — the concat-of-parallel-convs pattern
//! several TASO merge rules exploit.

use crate::graph::{Graph, GraphBuilder, PadMode, PortRef};

fn fire(
    b: &mut GraphBuilder,
    x: PortRef,
    squeeze: usize,
    expand: usize,
) -> anyhow::Result<PortRef> {
    let s = b.conv(x, squeeze, 1, 1, PadMode::Same)?;
    let s = b.relu(s)?;
    let e1 = b.conv(s, expand, 1, 1, PadMode::Same)?;
    let e1 = b.relu(e1)?;
    let e3 = b.conv(s, expand, 3, 1, PadMode::Same)?;
    let e3 = b.relu(e3)?;
    b.concat(1, &[e1, e3])
}

pub fn squeezenet1_1() -> Graph {
    build().expect("squeezenet construction is static")
}

fn build() -> anyhow::Result<Graph> {
    let mut b = GraphBuilder::new();
    let x = b.input(&[1, 3, 224, 224]);
    let c = b.conv(x, 64, 3, 2, PadMode::Valid)?;
    let c = b.relu(c)?;
    let mut y = b.maxpool(c, 3, 2)?;

    y = fire(&mut b, y, 16, 64)?;
    y = fire(&mut b, y, 16, 64)?;
    y = b.maxpool(y, 3, 2)?;
    y = fire(&mut b, y, 32, 128)?;
    y = fire(&mut b, y, 32, 128)?;
    y = b.maxpool(y, 3, 2)?;
    y = fire(&mut b, y, 48, 192)?;
    y = fire(&mut b, y, 48, 192)?;
    y = fire(&mut b, y, 64, 256)?;
    y = fire(&mut b, y, 64, 256)?;

    // Classifier: 1x1 conv to classes, relu, global average pool.
    let c10 = b.conv(y, 1000, 1, 1, PadMode::Same)?;
    let c10 = b.relu(c10)?;
    let s = b.shape(c10)?.clone();
    let pooled = b.avgpool(c10, s[2], s[2])?;
    b.reshape(pooled, &[1, 1000])?;
    let g = b.finish();
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn conv_count_matches_architecture() {
        // 1 stem + 8 fires x 3 + 1 classifier = 26.
        let g = squeezenet1_1();
        let convs = g
            .live_ids()
            .filter(|&id| matches!(g.node(id).op, OpKind::Conv2d { .. }))
            .count();
        assert_eq!(convs, 26);
    }

    #[test]
    fn has_concat_fire_outputs() {
        let g = squeezenet1_1();
        let concats = g
            .live_ids()
            .filter(|&id| matches!(g.node(id).op, OpKind::Concat { .. }))
            .count();
        assert_eq!(concats, 8);
    }
}
