//! Model zoo: the six evaluation graphs of paper Table 1.
//!
//! | Graph         | Type          | Layers | Unique |
//! |---------------|---------------|--------|--------|
//! | InceptionV3   | Convolutional | 43     | 12     |
//! | ResNet-18     | Convolutional | 18     | 6      |
//! | ResNet-50     | Convolutional | 50     | 6      |
//! | SqueezeNet1.1 | Convolutional | 21     | 3      |
//! | BERT-Base     | Transformer   | 12     | 3      |
//! | ViT-Base      | Transformer   | 16     | 5      |
//!
//! "Layers" follows the paper's counting convention (named architectural
//! layers, not graph ops); `GraphInfo` reports both so Table 1 can print the
//! paper's columns alongside the actual op counts.
//!
//! All models are built at inference batch size 1 (TASO's setting) from
//! primitive ops — BatchNorm is kept explicit so conv+bn fusion rules have
//! work to do, and attention is composed from matmul/softmax so the
//! transformer substitutions of §4.10 apply.

mod bert;
mod inception;
mod resnet;
mod squeezenet;
mod vit;

pub use bert::bert_base;
pub use inception::inception_v3;
pub use resnet::{resnet18, resnet50};
pub use squeezenet::squeezenet1_1;
pub use vit::vit_base;

use crate::graph::Graph;

#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub name: &'static str,
    pub family: &'static str,
    /// Paper Table 1 "Layers".
    pub layers: usize,
    /// Paper Table 1 "Unique Layers".
    pub unique_layers: usize,
}

/// All six evaluation graphs with their Table 1 metadata.
pub fn all() -> Vec<(GraphInfo, Graph)> {
    vec![
        (
            GraphInfo { name: "InceptionV3", family: "Convolutional", layers: 43, unique_layers: 12 },
            inception_v3(),
        ),
        (
            GraphInfo { name: "ResNet-18", family: "Convolutional", layers: 18, unique_layers: 6 },
            resnet18(),
        ),
        (
            GraphInfo { name: "ResNet-50", family: "Convolutional", layers: 50, unique_layers: 6 },
            resnet50(),
        ),
        (
            GraphInfo { name: "SqueezeNet1.1", family: "Convolutional", layers: 21, unique_layers: 3 },
            squeezenet1_1(),
        ),
        (
            GraphInfo { name: "BERT-Base", family: "Transformer", layers: 12, unique_layers: 3 },
            bert_base(),
        ),
        (
            GraphInfo { name: "ViT-Base", family: "Transformer", layers: 16, unique_layers: 5 },
            vit_base(),
        ),
    ]
}

/// Look a zoo graph up by (case-insensitive) name.
pub fn by_name(name: &str) -> anyhow::Result<Graph> {
    let lower = name.to_lowercase();
    Ok(match lower.as_str() {
        "inceptionv3" | "inception" => inception_v3(),
        "resnet18" | "resnet-18" => resnet18(),
        "resnet50" | "resnet-50" => resnet50(),
        "squeezenet" | "squeezenet1.1" => squeezenet1_1(),
        "bert" | "bert-base" => bert_base(),
        "vit" | "vit-base" => vit_base(),
        _ => anyhow::bail!(
            "unknown graph '{}' (expected one of inceptionv3, resnet18, resnet50, squeezenet, bert, vit)",
            name
        ),
    })
}

pub const NAMES: [&str; 6] = ["inceptionv3", "resnet18", "resnet50", "squeezenet", "bert", "vit"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_graphs_validate() {
        for (info, g) in all() {
            g.validate().unwrap_or_else(|e| panic!("{} invalid: {e}", info.name));
            assert!(g.n_ops() > 10, "{} suspiciously small", info.name);
        }
    }

    #[test]
    fn all_graphs_fit_encoder_budget() {
        // MAX_NODES=320 op nodes (sources are not encoded).
        for (info, g) in all() {
            assert!(
                g.n_ops() <= 320,
                "{}: {} ops exceeds encoder budget",
                info.name,
                g.n_ops()
            );
        }
    }

    #[test]
    fn by_name_round_trip() {
        for name in NAMES {
            by_name(name).unwrap();
        }
        assert!(by_name("alexnet").is_err());
    }

    #[test]
    fn transformers_have_layernorm() {
        use crate::graph::OpKind;
        for g in [bert_base(), vit_base()] {
            let has_ln = g
                .live_ids()
                .any(|id| matches!(g.node(id).op, OpKind::LayerNorm));
            assert!(has_ln);
        }
    }

    #[test]
    fn cnns_have_batchnorm_or_pool() {
        use crate::graph::OpKind;
        for g in [resnet18(), resnet50(), inception_v3()] {
            let has = g.live_ids().any(|id| {
                matches!(g.node(id).op, OpKind::BatchNorm | OpKind::MaxPool { .. })
            });
            assert!(has);
        }
    }
}
