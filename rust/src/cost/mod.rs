//! Analytic cost model — the stand-in for TASO's cuDNN-based runtime
//! measurement (DESIGN.md §Hardware-Adaptation).
//!
//! Per operator we compute (FLOPs, bytes moved, kernel launches) and map
//! them to time with a roofline under a [`DeviceProfile`]:
//!
//! `t_op = launch_overhead + max(flops / (peak * eff_op), bytes / bandwidth)`
//!
//! Exactly the quantities the paper's reward functions consume (Eq. 2/3 use
//! runtime and memory-access deltas; §4.3 additionally logs FLOPS and kernel
//! launches). Fusion rules win for the same reason they win on a GPU: fewer
//! launches and less intermediate HBM traffic. An optional seeded noise
//! model reproduces the measurement variance the paper discusses in §3.1.4.

pub mod device;
pub mod op_cost;

pub use device::DeviceProfile;
pub use op_cost::{op_cost, OpCost};

use std::cell::RefCell;
use std::collections::HashMap;

use crate::graph::{Graph, NodeId, OpKind};
use crate::util::Rng;
use crate::xfer::ApplyReport;

/// Cost summary for a whole graph.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GraphCost {
    pub runtime_ms: f64,
    pub flops: f64,
    /// Bytes moved through memory (activations + weights read, outputs written).
    pub mem_bytes: f64,
    pub launches: u64,
    /// Peak resident memory during execution (weights + live activations).
    pub peak_bytes: f64,
}

/// Immutable, thread-shareable snapshot of a cost model's per-op memo
/// cache. Workers built from one snapshot (search depth expansion,
/// [`crate::env::EnvPool`] environments) share the frozen base map behind
/// an `Arc` and keep only their privately-computed entries in a small
/// overlay — no per-worker copy of the whole cache (ROADMAP: shared
/// read-only snapshot + per-worker overlay).
#[derive(Clone)]
pub struct CostSnapshot {
    pub device: DeviceProfile,
    base: std::sync::Arc<HashMap<u64, OpCost>>,
}

pub struct CostModel {
    pub device: DeviceProfile,
    /// Std-dev of multiplicative measurement noise (0 = deterministic).
    pub noise_std: f64,
    noise_rng: RefCell<Rng>,
    /// Shared read-only base of the per-op memo (possibly empty). Behind a
    /// `RefCell` so [`CostModel::snapshot`] can rebase through `&self`;
    /// the map itself is frozen once published in an `Arc`.
    base: RefCell<std::sync::Arc<HashMap<u64, OpCost>>>,
    /// Private overlay: entries computed by this model and absent from
    /// `base`. Keyed by (attr hash, input shapes hash) like `base`.
    cache: RefCell<HashMap<u64, OpCost>>,
}

/// Clones duplicate the device, the noise configuration *and state*, a
/// cheap handle on the shared base cache, and a snapshot of the private
/// overlay — parallel workers each own a clone (the `RefCell` interior
/// makes `CostModel` deliberately `!Sync`), warm-starting from whatever
/// the parent has already costed.
impl Clone for CostModel {
    fn clone(&self) -> Self {
        Self {
            device: self.device,
            noise_std: self.noise_std,
            noise_rng: RefCell::new(self.noise_rng.borrow().clone()),
            base: RefCell::new(std::sync::Arc::clone(&self.base.borrow())),
            cache: RefCell::new(self.cache.borrow().clone()),
        }
    }
}

impl CostModel {
    pub fn new(device: DeviceProfile) -> Self {
        Self {
            device,
            noise_std: 0.0,
            noise_rng: RefCell::new(Rng::new(0)),
            base: RefCell::new(std::sync::Arc::new(HashMap::new())),
            cache: RefCell::new(HashMap::new()),
        }
    }

    /// Enable multiplicative measurement noise (paper §3.1.4: "non-negligible
    /// variance of the runtime on real hardware").
    pub fn with_noise(mut self, std: f64, seed: u64) -> Self {
        self.noise_std = std;
        self.noise_rng = RefCell::new(Rng::new(seed));
        self
    }

    /// Freeze base + overlay into one shared read-only snapshot, and
    /// *rebase* this model onto it: the overlay drains into the new base,
    /// so repeated snapshots (one per search depth / pool construction)
    /// cost O(1) once no new (op, shape) keys are being discovered — the
    /// per-depth cache copying the ROADMAP called out never recurs in
    /// steady state. Values are a deterministic function of the key, so
    /// neither the rebase nor sharing across threads can change any
    /// result.
    pub fn snapshot(&self) -> CostSnapshot {
        let mut overlay = self.cache.borrow_mut();
        if !overlay.is_empty() {
            let mut merged = (**self.base.borrow()).clone();
            for (k, v) in overlay.drain() {
                merged.entry(k).or_insert(v);
            }
            *self.base.borrow_mut() = std::sync::Arc::new(merged);
        }
        CostSnapshot { device: self.device, base: std::sync::Arc::clone(&self.base.borrow()) }
    }

    /// A fresh deterministic (noise-free) model sharing the snapshot's
    /// frozen cache, with an empty private overlay. Per-env noise is
    /// layered on by the caller via [`CostModel::with_noise`].
    pub fn from_snapshot(snap: &CostSnapshot) -> Self {
        Self {
            device: snap.device,
            noise_std: 0.0,
            noise_rng: RefCell::new(Rng::new(0)),
            base: RefCell::new(std::sync::Arc::clone(&snap.base)),
            cache: RefCell::new(HashMap::new()),
        }
    }

    fn cached_op_cost(&self, g: &Graph, id: crate::graph::NodeId) -> OpCost {
        let node = g.node(id);
        let mut key = node.op.attr_hash();
        for p in &node.inputs {
            if let Ok(d) = g.out_desc(*p) {
                for &dim in &d.shape {
                    key = key
                        .rotate_left(13)
                        .wrapping_mul(0x100000001B3)
                        .wrapping_add(dim as u64);
                }
            }
        }
        if let Some(c) = self.base.borrow().get(&key) {
            return *c;
        }
        if let Some(c) = self.cache.borrow().get(&key) {
            return *c;
        }
        let descs: Vec<&crate::graph::TensorDesc> = node
            .inputs
            .iter()
            .filter_map(|p| g.out_desc(*p).ok())
            .collect();
        let c = op_cost(&node.op, &descs, &node.outs);
        self.cache.borrow_mut().insert(key, c);
        c
    }

    /// Node-wise constness: a node is constant when every transitive source
    /// feeding it is a `Weight`. Constant subtrees (folded BN scales,
    /// concatenated kernels, composed 1x1 weights...) are precomputed at
    /// model-load time — TASO does the same — so they cost zero runtime.
    ///
    /// Runs on every candidate the search baselines cost, so it uses an
    /// explicit-stack DFS over flat arena-indexed state instead of the
    /// HashMap-heavy `Graph::topo_order`. Nodes on a cycle resolve to
    /// non-constant (such graphs are invalid and never costed for real).
    pub fn const_set(&self, g: &Graph) -> Vec<bool> {
        const UNSEEN: u8 = 0;
        const OPEN: u8 = 1; // on the DFS stack
        const CONST: u8 = 2;
        const VAR: u8 = 3;
        let n = g.n_slots();
        let mut state = vec![UNSEEN; n];
        // (node index, next input position) resume points.
        let mut stack: Vec<(u32, u32)> = Vec::new();
        for root in g.live_ids() {
            if state[root.index()] != UNSEEN {
                continue;
            }
            state[root.index()] = OPEN;
            stack.push((root.0, 0));
            while let Some((idx, ip)) = stack.pop() {
                let node = &g.nodes[idx as usize];
                if ip == 0 {
                    let leaf = match node.op {
                        OpKind::Weight => Some(CONST),
                        OpKind::Input => Some(VAR),
                        _ if node.inputs.is_empty() => Some(VAR),
                        _ => None,
                    };
                    if let Some(s) = leaf {
                        state[idx as usize] = s;
                        continue;
                    }
                }
                if (ip as usize) < node.inputs.len() {
                    let child = node.inputs[ip as usize].node.index();
                    stack.push((idx, ip + 1));
                    if state[child] == UNSEEN {
                        state[child] = OPEN;
                        stack.push((child as u32, 0));
                    }
                } else {
                    // An OPEN child here means a cycle: treat as non-const.
                    state[idx as usize] = if node
                        .inputs
                        .iter()
                        .all(|p| state[p.node.index()] == CONST)
                    {
                        CONST
                    } else {
                        VAR
                    };
                }
            }
        }
        state.into_iter().map(|s| s == CONST).collect()
    }

    /// Hot-path cost: runtime / flops / traffic / launches, *without* the
    /// peak-memory analysis (which needs a liveness sweep). This is what
    /// the search baselines and the environment reward evaluate thousands
    /// of times per episode — see EXPERIMENTS.md §Perf/L3.
    pub fn graph_cost_fast(&self, g: &Graph) -> GraphCost {
        let mut total = GraphCost::default();
        let is_const = self.const_set(g);
        for id in g.live_ids() {
            if is_const[id.index()] {
                continue;
            }
            let node = g.node(id);
            if matches!(node.op, OpKind::Input | OpKind::Weight) {
                continue;
            }
            let c = self.cached_op_cost(g, id);
            total.flops += c.flops;
            total.mem_bytes += c.bytes;
            total.launches += c.launches;
            total.runtime_ms += self.device.op_time_ms(&c);
        }
        if self.noise_std > 0.0 {
            let n = 1.0 + self.noise_std * self.noise_rng.borrow_mut().normal() as f64;
            total.runtime_ms *= n.max(0.5);
        }
        total
    }

    /// Full cost report for a graph.
    pub fn graph_cost(&self, g: &Graph) -> GraphCost {
        let mut total = GraphCost::default();
        let mut weight_bytes = 0f64;
        let mut act_bytes_max = 0f64;
        let is_const = self.const_set(g);
        let cons = g.consumers();
        // A constant node is *resident* iff some non-constant op reads it
        // (it is the materialised, precomputed parameter).
        let resident = |id: crate::graph::NodeId| -> bool {
            cons.get(&id)
                .map(|v| v.iter().any(|(c, _)| !is_const[c.index()]))
                .unwrap_or(false)
        };
        for id in g.live_ids() {
            let node = g.node(id);
            match node.op {
                OpKind::Input => {}
                OpKind::Weight => {
                    if resident(id) {
                        weight_bytes += node.outs[0].bytes() as f64;
                    }
                }
                _ if is_const[id.index()] => {
                    if resident(id) {
                        weight_bytes += node.outs.iter().map(|t| t.bytes() as f64).sum::<f64>();
                    }
                }
                _ => {
                    let c = self.cached_op_cost(g, id);
                    total.flops += c.flops;
                    total.mem_bytes += c.bytes;
                    total.launches += c.launches;
                    total.runtime_ms += self.device.op_time_ms(&c);
                    let out_b: f64 = node.outs.iter().map(|t| t.bytes() as f64).sum();
                    act_bytes_max = act_bytes_max.max(out_b);
                }
            }
        }
        // Peak memory approximation: all weights resident + the two largest
        // activation frontiers (double-buffered producer/consumer).
        total.peak_bytes = weight_bytes + 2.0 * act_bytes_max + self.activation_frontier(g);
        if self.noise_std > 0.0 {
            let n = 1.0 + self.noise_std * self.noise_rng.borrow_mut().normal() as f64;
            total.runtime_ms *= n.max(0.5);
        }
        total
    }

    /// Largest sum of simultaneously-live activation bytes along the topo order.
    fn activation_frontier(&self, g: &Graph) -> f64 {
        let order = match g.topo_order() {
            Ok(o) => o,
            Err(_) => return 0.0,
        };
        let consumers = g.consumers();
        let mut remaining: HashMap<crate::graph::NodeId, usize> = HashMap::new();
        for id in g.live_ids() {
            remaining.insert(id, consumers.get(&id).map_or(0, |v| v.len()));
        }
        let is_const = self.const_set(g);
        let mut live = 0f64;
        let mut peak = 0f64;
        let mut alive: HashMap<crate::graph::NodeId, f64> = HashMap::new();
        for id in order {
            let node = g.node(id);
            if matches!(node.op, OpKind::Weight) || is_const[id.index()] {
                continue;
            }
            let bytes: f64 = node.outs.iter().map(|t| t.bytes() as f64).sum();
            live += bytes;
            alive.insert(id, bytes);
            peak = peak.max(live);
            for p in &node.inputs {
                if let Some(r) = remaining.get_mut(&p.node) {
                    *r = r.saturating_sub(1);
                    if *r == 0 {
                        if let Some(b) = alive.remove(&p.node) {
                            live -= b;
                        }
                    }
                }
            }
        }
        peak
    }

    /// Estimated end-to-end runtime in milliseconds (the paper's `RT`).
    pub fn graph_runtime_ms(&self, g: &Graph) -> f64 {
        self.graph_cost_fast(g).runtime_ms
    }

    /// Fold a worker's freshly-computed per-op memo entries (its private
    /// overlay) back into this model's overlay, so op costs computed
    /// inside a parallel pass are not recomputed at the next one. Entries
    /// already frozen in this model's base are skipped. Values are a
    /// deterministic function of the key, so merge order cannot affect any
    /// result.
    pub fn absorb_cache(&self, worker: &CostModel) {
        let theirs = worker.cache.borrow();
        let base = self.base.borrow();
        let mut ours = self.cache.borrow_mut();
        for (k, v) in theirs.iter() {
            if !base.contains_key(k) {
                ours.entry(*k).or_insert(*v);
            }
        }
    }

    /// Hot-field contribution of one node: `None` for sources, constant-
    /// folded subtrees and dead slots. Mirrors exactly which nodes
    /// [`CostModel::graph_cost_fast`] accumulates.
    fn node_hot_cost(&self, g: &Graph, id: NodeId, is_const: &[bool]) -> Option<OpCost> {
        let node = g.node(id);
        if node.dead || is_const[id.index()] || matches!(node.op, OpKind::Input | OpKind::Weight) {
            return None;
        }
        Some(self.cached_op_cost(g, id))
    }

    /// Runtime contribution of one node: zero when [`node_hot_cost`] is
    /// `None`; the roofline time otherwise.
    ///
    /// [`node_hot_cost`]: CostModel::node_hot_cost
    fn node_time_ms(&self, g: &Graph, id: NodeId, is_const: &[bool]) -> f64 {
        self.node_hot_cost(g, id, is_const)
            .map(|c| self.device.op_time_ms(&c))
            .unwrap_or(0.0)
    }

    /// Incremental runtime after one rule application: start from the
    /// parent's runtime and re-cost only the nodes whose contribution the
    /// rewrite changed — the nodes the [`ApplyReport`] says were removed or
    /// added, plus survivors whose constness flipped (a rewrite can promote
    /// a subtree to weight-only arithmetic, or demote one back).
    ///
    /// Surviving nodes outside that set keep their contribution: rules only
    /// rewire inputs through `splice`, which enforces descriptor equality,
    /// so their per-op cost key (op attrs + input shapes) cannot change.
    ///
    /// The result equals `graph_runtime_ms(after)` up to f64 summation
    /// order (the full recompute stays the oracle; `tests/props.rs` pins
    /// the agreement to 1e-9). With measurement noise enabled the delta
    /// identity does not hold, so this falls back to the full recompute.
    pub fn delta_runtime_ms(
        &self,
        before: &Graph,
        before_ms: f64,
        after: &Graph,
        report: &ApplyReport,
    ) -> f64 {
        self.delta_runtime_ms_with(before, &self.const_set(before), before_ms, after, report)
    }

    /// [`CostModel::delta_runtime_ms`] with the parent's const set supplied
    /// by the caller — it is identical for every candidate expanded from
    /// one parent graph, so the search computes it once per frontier entry
    /// instead of once per (rule, location) site.
    pub fn delta_runtime_ms_with(
        &self,
        before: &Graph,
        const_before: &[bool],
        before_ms: f64,
        after: &Graph,
        report: &ApplyReport,
    ) -> f64 {
        if self.noise_std > 0.0 {
            return self.graph_runtime_ms(after);
        }
        let const_after = self.const_set(after);
        let mut ms = before_ms;
        for &id in &report.removed {
            ms -= self.node_time_ms(before, id, const_before);
        }
        for &id in &report.added {
            ms += self.node_time_ms(after, id, &const_after);
        }
        let prefix = report.prev_slots.min(const_after.len());
        for idx in 0..prefix {
            if const_before[idx] == const_after[idx] {
                continue;
            }
            let id = NodeId(idx as u32);
            // Removed/added slots are already handled above; a flip only
            // matters for nodes live on both sides.
            if before.node(id).dead || after.node(id).dead {
                continue;
            }
            ms -= self.node_time_ms(before, id, const_before);
            ms += self.node_time_ms(after, id, &const_after);
        }
        ms
    }

    /// Estimated inference memory in GiB (Table 2's "Mem. usage").
    pub fn graph_memory_gib(&self, g: &Graph) -> f64 {
        self.graph_cost(g).peak_bytes / (1024.0 * 1024.0 * 1024.0)
    }

    /// Incremental hot-path cost after one rule application: start from
    /// the parent's [`GraphCost`] and re-cost only the nodes the rewrite
    /// touched — [`CostModel::delta_runtime_ms`]'s contract extended to
    /// every field [`CostModel::graph_cost_fast`] fills (runtime, flops,
    /// traffic, launches; `peak_bytes` stays 0 like the fast path). The
    /// environment's §3.1.4 reward consumes this so a step costs O(touched)
    /// instead of O(graph). Launch counts are integers, so they match the
    /// full recompute *exactly*; the float fields agree up to f64
    /// summation order (`tests/env_incremental.rs` pins 1e-9). Under
    /// measurement noise the delta identity does not hold, so this falls
    /// back to the full recompute (same policy as `delta_runtime_ms`).
    pub fn delta_cost_fast(
        &self,
        before: &Graph,
        before_cost: &GraphCost,
        after: &Graph,
        report: &ApplyReport,
    ) -> GraphCost {
        if self.noise_std > 0.0 {
            return self.graph_cost_fast(after);
        }
        let const_before = self.const_set(before);
        let const_after = self.const_set(after);
        let mut runtime_ms = before_cost.runtime_ms;
        let mut flops = before_cost.flops;
        let mut mem_bytes = before_cost.mem_bytes;
        let mut launches = before_cost.launches as i64;
        {
            let mut fold = |g: &Graph, id: NodeId, is_const: &[bool], sign: f64| {
                if let Some(c) = self.node_hot_cost(g, id, is_const) {
                    runtime_ms += sign * self.device.op_time_ms(&c);
                    flops += sign * c.flops;
                    mem_bytes += sign * c.bytes;
                    launches += sign as i64 * c.launches as i64;
                }
            };
            for &id in &report.removed {
                fold(before, id, &const_before, -1.0);
            }
            for &id in &report.added {
                fold(after, id, &const_after, 1.0);
            }
            // Survivors whose constness flipped contribute on one side only.
            let prefix = report.prev_slots.min(const_after.len());
            for idx in 0..prefix {
                if const_before[idx] == const_after[idx] {
                    continue;
                }
                let id = NodeId(idx as u32);
                if before.node(id).dead || after.node(id).dead {
                    continue;
                }
                fold(before, id, &const_before, -1.0);
                fold(after, id, &const_after, 1.0);
            }
        }
        GraphCost {
            runtime_ms,
            flops,
            mem_bytes,
            launches: launches.max(0) as u64,
            peak_bytes: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder, PadMode};

    fn conv_graph(fused: bool) -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16, 32, 32]);
        if fused {
            let ci = 16;
            let w = b.weight(&[32, ci, 3, 3]);
            b.op(
                crate::graph::OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::Relu },
                &[x, w],
            )
            .unwrap();
        } else {
            let c = b.conv(x, 32, 3, 1, PadMode::Same).unwrap();
            b.relu(c).unwrap();
        }
        b.finish()
    }

    #[test]
    fn fused_conv_relu_cheaper() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let unfused = cm.graph_runtime_ms(&conv_graph(false));
        let fused = cm.graph_runtime_ms(&conv_graph(true));
        assert!(fused < unfused, "fused {fused} !< unfused {unfused}");
    }

    #[test]
    fn costs_positive_and_monotone_in_size() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let small = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let big = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 64, 64]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let ts = cm.graph_runtime_ms(&small);
        let tb = cm.graph_runtime_ms(&big);
        assert!(ts > 0.0);
        assert!(tb > ts);
    }

    #[test]
    fn noise_is_seeded_and_bounded() {
        let g = conv_graph(false);
        let base = CostModel::new(DeviceProfile::rtx2070()).graph_runtime_ms(&g);
        let a = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 1).graph_runtime_ms(&g);
        let b = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 1).graph_runtime_ms(&g);
        assert_eq!(a, b, "same seed, same noise");
        assert!((a / base - 1.0).abs() < 0.5);
    }

    #[test]
    fn const_subtrees_cost_nothing() {
        // conv(x, mul(w, reshape(scale))) — the weight arithmetic is
        // load-time precomputable and must not add launches or flops.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let folded = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            let w = b.weight(&[8, 8, 3, 3]);
            let s = b.weight(&[8]);
            let sr = b.reshape(s, &[8, 1, 1, 1]).unwrap();
            let w2 = b.op(crate::graph::OpKind::Mul, &[w, sr]).unwrap();
            b.op(
                crate::graph::OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None },
                &[x, w2],
            )
            .unwrap();
            b.finish()
        };
        let plain = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let cf = cm.graph_cost(&folded);
        let cp = cm.graph_cost(&plain);
        assert_eq!(cf.launches, cp.launches);
        assert!((cf.runtime_ms - cp.runtime_ms).abs() < 1e-9);
    }

    #[test]
    fn fast_and_full_costs_agree_on_hot_fields() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        for (_, g) in crate::zoo::all() {
            let fast = cm.graph_cost_fast(&g);
            let full = cm.graph_cost(&g);
            assert_eq!(fast.launches, full.launches);
            assert!((fast.runtime_ms - full.runtime_ms).abs() < 1e-9);
            assert!((fast.flops - full.flops).abs() < 1e-3);
            assert!((fast.mem_bytes - full.mem_bytes).abs() < 1e-3);
        }
    }

    #[test]
    fn delta_runtime_matches_full_recompute() {
        // Every applicable rule site on a mixed graph: the incremental cost
        // must agree with the full oracle to float-sum precision.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let lib = crate::xfer::library::standard_library();
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
        let _ = b.relu(c2).unwrap();
        let g = b.finish();
        let base = cm.graph_runtime_ms(&g);
        let mut checked = 0;
        for ri in 0..lib.len() {
            let rule = lib.get(ri).unwrap();
            for loc in rule.find(&g) {
                let mut g2 = g.clone();
                let Ok(report) = crate::xfer::apply_rule(&mut g2, rule, &loc) else {
                    continue;
                };
                let delta = cm.delta_runtime_ms(&g, base, &g2, &report);
                let full = cm.graph_runtime_ms(&g2);
                assert!(
                    (delta - full).abs() < 1e-9,
                    "{}: delta {delta} vs full {full}",
                    rule.name()
                );
                checked += 1;
            }
        }
        assert!(checked > 3, "too few rule sites exercised: {checked}");
    }

    #[test]
    fn delta_runtime_with_noise_falls_back_to_oracle() {
        let cm = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 9);
        let lib = crate::xfer::library::standard_library();
        let g = conv_graph(false);
        let rule = lib.get(lib.index_of("fuse_conv_relu").unwrap()).unwrap();
        let loc = rule.find(&g)[0].clone();
        let mut g2 = g.clone();
        let report = crate::xfer::apply_rule(&mut g2, rule, &loc).unwrap();
        let delta = cm.delta_runtime_ms(&g, 1234.5, &g2, &report);
        // Under noise the fallback ignores `before_ms` entirely.
        assert!(delta > 0.0 && delta < 1234.5);
    }

    #[test]
    fn clone_replays_noise_and_shares_no_state() {
        let g = conv_graph(false);
        let a = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 3);
        let b = a.clone();
        assert_eq!(a.graph_runtime_ms(&g), b.graph_runtime_ms(&g));
        // Advancing one clone's rng must not affect the other.
        let _ = a.graph_runtime_ms(&g);
        let c = b.clone();
        assert_eq!(b.graph_runtime_ms(&g), c.graph_runtime_ms(&g));
    }

    #[test]
    fn const_set_matches_topo_reference() {
        // The DFS const_set must agree with a straightforward topo-order
        // evaluation on every zoo graph.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        for (_, g) in crate::zoo::all() {
            let fast = cm.const_set(&g);
            let mut reference = vec![false; g.n_slots()];
            for id in g.topo_order().unwrap() {
                let n = g.node(id);
                reference[id.index()] = match n.op {
                    OpKind::Weight => true,
                    OpKind::Input => false,
                    _ => {
                        !n.inputs.is_empty()
                            && n.inputs.iter().all(|p| reference[p.node.index()])
                    }
                };
            }
            assert_eq!(fast, reference);
        }
    }

    #[test]
    fn snapshot_workers_agree_with_parent() {
        // A model built from a snapshot (shared base + empty overlay) must
        // cost every zoo graph bit-identically to the parent, and
        // absorbing its overlay back must not duplicate base entries.
        let parent = CostModel::new(DeviceProfile::rtx2070());
        let bert = crate::zoo::bert_base();
        let parent_ms = parent.graph_runtime_ms(&bert);
        let snap = parent.snapshot();
        let worker = CostModel::from_snapshot(&snap);
        assert_eq!(worker.graph_runtime_ms(&bert).to_bits(), parent_ms.to_bits());
        // Everything bert needs is frozen in the base: the worker's
        // overlay stays empty.
        assert!(worker.cache.borrow().is_empty(), "worker overlay grew on warm keys");
        // New ops land in the overlay and absorb back without duplicates.
        let vit = crate::zoo::vit_base();
        let fresh = worker.graph_runtime_ms(&vit);
        assert!(!worker.cache.borrow().is_empty());
        parent.absorb_cache(&worker);
        assert_eq!(parent.graph_runtime_ms(&vit).to_bits(), fresh.to_bits());
    }

    #[test]
    fn snapshot_rebases_and_preserves_costs() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let g = conv_graph(false);
        let before = cm.graph_runtime_ms(&g);
        let snap = cm.snapshot();
        // The overlay drained into the (now shared) base...
        assert!(cm.cache.borrow().is_empty());
        assert!(!snap.base.is_empty());
        // ...costs are unchanged, and a second snapshot is O(1): it hands
        // back the very same frozen map.
        assert_eq!(cm.graph_runtime_ms(&g).to_bits(), before.to_bits());
        let snap2 = cm.snapshot();
        assert!(std::sync::Arc::ptr_eq(&snap.base, &snap2.base));
    }

    #[test]
    fn delta_cost_fast_matches_full_recompute() {
        // All hot fields, every applicable rule site: launches exact,
        // floats to 1e-9 (same tolerance delta_runtime_ms pins).
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let lib = crate::xfer::library::standard_library();
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
        let _ = b.relu(c2).unwrap();
        let g = b.finish();
        let base = cm.graph_cost_fast(&g);
        let mut checked = 0;
        for ri in 0..lib.len() {
            let rule = lib.get(ri).unwrap();
            for loc in rule.find(&g) {
                let mut g2 = g.clone();
                let Ok(report) = crate::xfer::apply_rule(&mut g2, rule, &loc) else {
                    continue;
                };
                let delta = cm.delta_cost_fast(&g, &base, &g2, &report);
                let full = cm.graph_cost_fast(&g2);
                assert_eq!(delta.launches, full.launches, "{}", rule.name());
                assert!((delta.runtime_ms - full.runtime_ms).abs() < 1e-9, "{}", rule.name());
                assert!((delta.flops - full.flops).abs() < 1e-3, "{}", rule.name());
                assert!((delta.mem_bytes - full.mem_bytes).abs() < 1e-3, "{}", rule.name());
                checked += 1;
            }
        }
        assert!(checked > 3, "too few rule sites exercised: {checked}");
    }

    #[test]
    fn delta_cost_fast_with_noise_falls_back_to_oracle() {
        let cm = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 9);
        let lib = crate::xfer::library::standard_library();
        let g = conv_graph(false);
        let rule = lib.get(lib.index_of("fuse_conv_relu").unwrap()).unwrap();
        let loc = rule.find(&g)[0].clone();
        let mut g2 = g.clone();
        let report = crate::xfer::apply_rule(&mut g2, rule, &loc).unwrap();
        let stale = GraphCost { runtime_ms: 1234.5, ..Default::default() };
        let delta = cm.delta_cost_fast(&g, &stale, &g2, &report);
        // Under noise the fallback ignores the stale parent cost entirely.
        assert!(delta.runtime_ms > 0.0 && delta.runtime_ms < 1234.5);
        assert!(delta.launches > 0);
    }

    #[test]
    fn memory_includes_weights() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let g = conv_graph(false);
        let c = cm.graph_cost(&g);
        // 32*16*3*3 weight floats at minimum.
        assert!(c.peak_bytes > (32 * 16 * 3 * 3 * 4) as f64);
    }
}
