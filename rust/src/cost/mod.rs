//! Analytic cost model — the stand-in for TASO's cuDNN-based runtime
//! measurement (DESIGN.md §Hardware-Adaptation).
//!
//! Per operator we compute (FLOPs, bytes moved, kernel launches) and map
//! them to time with a roofline under a [`DeviceProfile`]:
//!
//! `t_op = launch_overhead + max(flops / (peak * eff_op), bytes / bandwidth)`
//!
//! Exactly the quantities the paper's reward functions consume (Eq. 2/3 use
//! runtime and memory-access deltas; §4.3 additionally logs FLOPS and kernel
//! launches). Fusion rules win for the same reason they win on a GPU: fewer
//! launches and less intermediate HBM traffic.
//!
//! # Measurement noise (§3.1.4)
//!
//! An optional seeded noise model reproduces the measurement variance the
//! paper discusses in §3.1.4. Noise is a *per-kernel field*, not a stream:
//! each (op attrs, input shapes) key gets a multiplicative factor that is a
//! pure function of `(noise seed, key)` — the same kernel measures the same
//! within one noise stream, the way a fixed benchmarking session would.
//! A per-stream common factor (a function of the seed alone) sits on top of
//! the independent per-kernel jitter so whole-graph runtimes keep
//! `O(noise_std)` relative variance across streams instead of averaging it
//! away over hundreds of kernels (see `noise_factor`).
//! Because the field is stateless, every incremental path stays exact under
//! noise: [`CostModel::delta_runtime_ms`] / [`CostModel::delta_cost_fast`]
//! resample only the nodes a rewrite touched and still agree with the full
//! recompute to f64 summation order, and parallel search workers sharing a
//! noisy model remain bit-identical for any thread count (the sequential
//! downgrade the pre-memoisation engine needed is gone).

pub mod device;
pub mod op_cost;

pub use device::DeviceProfile;
pub use op_cost::{op_cost, OpCost};

use std::cell::RefCell;
use std::collections::HashMap;

use crate::graph::{Graph, NodeId, OpKind};
use crate::util::Rng;
use crate::xfer::ApplyReport;

/// Cost summary for a whole graph.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GraphCost {
    /// Estimated end-to-end runtime in milliseconds (the paper's `RT`).
    pub runtime_ms: f64,
    /// Total floating-point operations executed.
    pub flops: f64,
    /// Bytes moved through memory (activations + weights read, outputs written).
    pub mem_bytes: f64,
    /// Kernel launches issued.
    pub launches: u64,
    /// Peak resident memory during execution (weights + live activations).
    pub peak_bytes: f64,
}

/// Immutable, thread-shareable snapshot of a cost model's per-op memo
/// cache. Workers built from one snapshot (search depth expansion,
/// [`crate::env::EnvPool`] environments) share the frozen base map behind
/// an `Arc` and keep only their privately-computed entries in a small
/// overlay — no per-worker copy of the whole cache (ROADMAP: shared
/// read-only snapshot + per-worker overlay).
#[derive(Clone)]
pub struct CostSnapshot {
    /// Device profile the frozen entries were computed for.
    pub device: DeviceProfile,
    base: std::sync::Arc<HashMap<u64, OpCost>>,
}

/// The analytic cost model, with an internal per-op memo cache and an
/// optional §3.1.4 measurement-noise field (see the module docs).
pub struct CostModel {
    /// Hardware parameters of the roofline (see [`DeviceProfile`]).
    pub device: DeviceProfile,
    /// Std-dev of multiplicative measurement noise (0 = deterministic).
    pub noise_std: f64,
    /// Seed of the per-kernel noise field (meaningful when `noise_std > 0`).
    noise_seed: u64,
    /// Shared read-only base of the per-op memo (possibly empty). Behind a
    /// `RefCell` so [`CostModel::snapshot`] can rebase through `&self`;
    /// the map itself is frozen once published in an `Arc`.
    base: RefCell<std::sync::Arc<HashMap<u64, OpCost>>>,
    /// Private overlay: entries computed by this model and absent from
    /// `base`. Keyed by (attr hash, input shapes hash) like `base`.
    cache: RefCell<HashMap<u64, OpCost>>,
}

/// Clones duplicate the device, the noise configuration (the noise field is
/// stateless, so a clone *is* the same field), a cheap handle on the shared
/// base cache, and a snapshot of the private overlay — parallel workers each
/// own a clone (the `RefCell` interior makes `CostModel` deliberately
/// `!Sync`), warm-starting from whatever the parent has already costed.
impl Clone for CostModel {
    fn clone(&self) -> Self {
        Self {
            device: self.device,
            noise_std: self.noise_std,
            noise_seed: self.noise_seed,
            base: RefCell::new(std::sync::Arc::clone(&self.base.borrow())),
            cache: RefCell::new(self.cache.borrow().clone()),
        }
    }
}

impl CostModel {
    /// A deterministic (noise-free) cost model for `device` with an empty
    /// memo cache.
    pub fn new(device: DeviceProfile) -> Self {
        Self {
            device,
            noise_std: 0.0,
            noise_seed: 0,
            base: RefCell::new(std::sync::Arc::new(HashMap::new())),
            cache: RefCell::new(HashMap::new()),
        }
    }

    /// Enable multiplicative measurement noise (paper §3.1.4: "non-negligible
    /// variance of the runtime on real hardware"). The field is a pure
    /// function of `(seed, kernel key)` — see the module docs.
    pub fn with_noise(mut self, std: f64, seed: u64) -> Self {
        self.noise_std = std;
        self.noise_seed = seed;
        self
    }

    /// Copy another model's noise configuration onto this one. Workers
    /// rebuilt from a [`CostSnapshot`] use this to inherit the parent's
    /// noise field (snapshots themselves are always noise-free: the memoised
    /// [`OpCost`] entries hold clean roofline quantities and noise is
    /// applied at time-accumulation).
    pub fn with_noise_of(self, other: &CostModel) -> Self {
        self.with_noise(other.noise_std, other.noise_seed)
    }

    /// Fingerprint of everything that determines this model's *values*:
    /// the device profile and the noise configuration. Two models with equal
    /// fingerprints cost every graph bit-identically, which is what lets the
    /// persistent [`crate::search::SearchCache`] key memoised costs by
    /// search-config fingerprint.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xC057_F1E1D;
        let mut fold = |v: u64| {
            h = (h ^ v)
                .rotate_left(23)
                .wrapping_mul(0x100000001B3)
                .wrapping_add(0x9E3779B97F4A7C15);
        };
        for b in self.device.name.bytes() {
            fold(b as u64);
        }
        fold(self.device.peak_flops.to_bits());
        fold(self.device.mem_bw.to_bits());
        fold(self.device.launch_overhead_s.to_bits());
        fold(self.noise_std.to_bits());
        fold(if self.noise_std > 0.0 { self.noise_seed } else { 0 });
        h
    }

    /// Per-kernel multiplicative noise factor: a pure function of the noise
    /// seed and the op-cost key, clamped below like the measurement model it
    /// replaces (a kernel cannot measure faster than half its roofline).
    ///
    /// The factor has two components: independent per-kernel jitter, and a
    /// **per-stream common factor** drawn from the seed alone. Without the
    /// common component, summing hundreds of independent kernel draws would
    /// average graph-level variance down by `1/sqrt(n_kernels)` — an order
    /// of magnitude below the §3.1.4 measurement variance the stream-based
    /// model reproduced. The common factor restores `O(noise_std)` relative
    /// variance on whole-graph runtimes across streams (per-env seeds,
    /// experiment seeds) while remaining a pure function of the seed, so
    /// every delta stays exact.
    fn noise_factor(&self, key: u64) -> f64 {
        let mut common = Rng::new(self.noise_seed ^ 0x5EEDFACE_0BADF00D);
        let mut kernel = Rng::new(self.noise_seed ^ key.wrapping_mul(0xD6E8FEB86659FD93));
        let c = 1.0 + self.noise_std * common.normal() as f64;
        let k = 1.0 + self.noise_std * kernel.normal() as f64;
        (c * k).max(0.5)
    }

    /// Roofline time of one memoised op, with the noise field applied when
    /// enabled. Every accumulation path (full, fast, delta) routes through
    /// this so they stay mutually exact under noise.
    fn noisy_op_time_ms(&self, key: u64, c: &OpCost) -> f64 {
        let t = self.device.op_time_ms(c);
        if self.noise_std > 0.0 {
            t * self.noise_factor(key)
        } else {
            t
        }
    }

    /// Freeze base + overlay into one shared read-only snapshot, and
    /// *rebase* this model onto it: the overlay drains into the new base,
    /// so repeated snapshots (one per search depth / pool construction)
    /// cost O(1) once no new (op, shape) keys are being discovered — the
    /// per-depth cache copying the ROADMAP called out never recurs in
    /// steady state. Values are a deterministic function of the key, so
    /// neither the rebase nor sharing across threads can change any
    /// result.
    pub fn snapshot(&self) -> CostSnapshot {
        let mut overlay = self.cache.borrow_mut();
        if !overlay.is_empty() {
            let mut merged = (**self.base.borrow()).clone();
            for (k, v) in overlay.drain() {
                merged.entry(k).or_insert(v);
            }
            *self.base.borrow_mut() = std::sync::Arc::new(merged);
        }
        CostSnapshot { device: self.device, base: std::sync::Arc::clone(&self.base.borrow()) }
    }

    /// A fresh deterministic (noise-free) model sharing the snapshot's
    /// frozen cache, with an empty private overlay. Per-env noise is
    /// layered on by the caller via [`CostModel::with_noise`] /
    /// [`CostModel::with_noise_of`].
    pub fn from_snapshot(snap: &CostSnapshot) -> Self {
        Self {
            device: snap.device,
            noise_std: 0.0,
            noise_seed: 0,
            base: RefCell::new(std::sync::Arc::clone(&snap.base)),
            cache: RefCell::new(HashMap::new()),
        }
    }

    /// Memo key of one node's op cost: op attrs mixed with the input port
    /// shapes. Also keys the per-kernel noise field.
    fn op_key(g: &Graph, id: crate::graph::NodeId) -> u64 {
        let node = g.node(id);
        let mut key = node.op.attr_hash();
        for p in &node.inputs {
            if let Ok(d) = g.out_desc(*p) {
                for &dim in &d.shape {
                    key = key
                        .rotate_left(13)
                        .wrapping_mul(0x100000001B3)
                        .wrapping_add(dim as u64);
                }
            }
        }
        key
    }

    fn cached_op_cost_keyed(&self, key: u64, g: &Graph, id: crate::graph::NodeId) -> OpCost {
        if let Some(c) = self.base.borrow().get(&key) {
            return *c;
        }
        if let Some(c) = self.cache.borrow().get(&key) {
            return *c;
        }
        let node = g.node(id);
        let descs: Vec<&crate::graph::TensorDesc> = node
            .inputs
            .iter()
            .filter_map(|p| g.out_desc(*p).ok())
            .collect();
        let c = op_cost(&node.op, &descs, &node.outs);
        self.cache.borrow_mut().insert(key, c);
        c
    }

    fn cached_op_cost(&self, g: &Graph, id: crate::graph::NodeId) -> OpCost {
        self.cached_op_cost_keyed(Self::op_key(g, id), g, id)
    }

    /// Node-wise constness: a node is constant when every transitive source
    /// feeding it is a `Weight`. Constant subtrees (folded BN scales,
    /// concatenated kernels, composed 1x1 weights...) are precomputed at
    /// model-load time — TASO does the same — so they cost zero runtime.
    ///
    /// Runs on every candidate the search baselines cost, so it uses an
    /// explicit-stack DFS over flat arena-indexed state instead of the
    /// HashMap-heavy `Graph::topo_order`. Nodes on a cycle resolve to
    /// non-constant (such graphs are invalid and never costed for real).
    pub fn const_set(&self, g: &Graph) -> Vec<bool> {
        const UNSEEN: u8 = 0;
        const OPEN: u8 = 1; // on the DFS stack
        const CONST: u8 = 2;
        const VAR: u8 = 3;
        let n = g.n_slots();
        let mut state = vec![UNSEEN; n];
        // (node index, next input position) resume points.
        let mut stack: Vec<(u32, u32)> = Vec::new();
        for root in g.live_ids() {
            if state[root.index()] != UNSEEN {
                continue;
            }
            state[root.index()] = OPEN;
            stack.push((root.0, 0));
            while let Some((idx, ip)) = stack.pop() {
                let node = &g.nodes[idx as usize];
                if ip == 0 {
                    let leaf = match node.op {
                        OpKind::Weight => Some(CONST),
                        OpKind::Input => Some(VAR),
                        _ if node.inputs.is_empty() => Some(VAR),
                        _ => None,
                    };
                    if let Some(s) = leaf {
                        state[idx as usize] = s;
                        continue;
                    }
                }
                if (ip as usize) < node.inputs.len() {
                    let child = node.inputs[ip as usize].node.index();
                    stack.push((idx, ip + 1));
                    if state[child] == UNSEEN {
                        state[child] = OPEN;
                        stack.push((child as u32, 0));
                    }
                } else {
                    // An OPEN child here means a cycle: treat as non-const.
                    state[idx as usize] = if node
                        .inputs
                        .iter()
                        .all(|p| state[p.node.index()] == CONST)
                    {
                        CONST
                    } else {
                        VAR
                    };
                }
            }
        }
        state.into_iter().map(|s| s == CONST).collect()
    }

    /// Hot-path cost: runtime / flops / traffic / launches, *without* the
    /// peak-memory analysis (which needs a liveness sweep). This is what
    /// the search baselines and the environment reward evaluate thousands
    /// of times per episode — see EXPERIMENTS.md §Perf/L3.
    pub fn graph_cost_fast(&self, g: &Graph) -> GraphCost {
        let mut total = GraphCost::default();
        let is_const = self.const_set(g);
        for id in g.live_ids() {
            if is_const[id.index()] {
                continue;
            }
            let node = g.node(id);
            if matches!(node.op, OpKind::Input | OpKind::Weight) {
                continue;
            }
            let key = Self::op_key(g, id);
            let c = self.cached_op_cost_keyed(key, g, id);
            total.flops += c.flops;
            total.mem_bytes += c.bytes;
            total.launches += c.launches;
            total.runtime_ms += self.noisy_op_time_ms(key, &c);
        }
        total
    }

    /// Full cost report for a graph.
    pub fn graph_cost(&self, g: &Graph) -> GraphCost {
        let mut total = GraphCost::default();
        let mut weight_bytes = 0f64;
        let mut act_bytes_max = 0f64;
        let is_const = self.const_set(g);
        let cons = g.consumers_vec();
        // A constant node is *resident* iff some non-constant op reads it
        // (it is the materialised, precomputed parameter).
        let resident = |id: crate::graph::NodeId| -> bool {
            cons[id.index()].iter().any(|(c, _)| !is_const[c.index()])
        };
        for id in g.live_ids() {
            let node = g.node(id);
            match node.op {
                OpKind::Input => {}
                OpKind::Weight => {
                    if resident(id) {
                        weight_bytes += node.outs[0].bytes() as f64;
                    }
                }
                _ if is_const[id.index()] => {
                    if resident(id) {
                        weight_bytes += node.outs.iter().map(|t| t.bytes() as f64).sum::<f64>();
                    }
                }
                _ => {
                    let key = Self::op_key(g, id);
                    let c = self.cached_op_cost_keyed(key, g, id);
                    total.flops += c.flops;
                    total.mem_bytes += c.bytes;
                    total.launches += c.launches;
                    total.runtime_ms += self.noisy_op_time_ms(key, &c);
                    let out_b: f64 = node.outs.iter().map(|t| t.bytes() as f64).sum();
                    act_bytes_max = act_bytes_max.max(out_b);
                }
            }
        }
        // Peak memory approximation: all weights resident + the two largest
        // activation frontiers (double-buffered producer/consumer).
        total.peak_bytes = weight_bytes + 2.0 * act_bytes_max + self.activation_frontier(g);
        total
    }

    /// Largest sum of simultaneously-live activation bytes along the topo order.
    fn activation_frontier(&self, g: &Graph) -> f64 {
        let order = match g.topo_order() {
            Ok(o) => o,
            Err(_) => return 0.0,
        };
        let consumers = g.consumers_vec();
        let mut remaining: Vec<usize> = consumers.iter().map(|v| v.len()).collect();
        let is_const = self.const_set(g);
        let mut live = 0f64;
        let mut peak = 0f64;
        // Dense arena-indexed frontier: alive[i] holds the resident bytes
        // of node i (0.0 once its last consumer has fired).
        let mut alive: Vec<f64> = vec![0.0; remaining.len()];
        for id in order {
            let node = g.node(id);
            if matches!(node.op, OpKind::Weight) || is_const[id.index()] {
                continue;
            }
            let bytes: f64 = node.outs.iter().map(|t| t.bytes() as f64).sum();
            live += bytes;
            alive[id.index()] = bytes;
            peak = peak.max(live);
            for p in &node.inputs {
                let r = &mut remaining[p.node.index()];
                *r = r.saturating_sub(1);
                if *r == 0 {
                    live -= std::mem::take(&mut alive[p.node.index()]);
                }
            }
        }
        peak
    }

    /// Estimated end-to-end runtime in milliseconds (the paper's `RT`).
    pub fn graph_runtime_ms(&self, g: &Graph) -> f64 {
        self.graph_cost_fast(g).runtime_ms
    }

    /// Fold a worker's freshly-computed per-op memo entries (its private
    /// overlay) back into this model's overlay, so op costs computed
    /// inside a parallel pass are not recomputed at the next one. Entries
    /// already frozen in this model's base are skipped. Values are a
    /// deterministic function of the key, so merge order cannot affect any
    /// result.
    pub fn absorb_cache(&self, worker: &CostModel) {
        let theirs = worker.cache.borrow();
        let base = self.base.borrow();
        let mut ours = self.cache.borrow_mut();
        for (k, v) in theirs.iter() {
            if !base.contains_key(k) {
                ours.entry(*k).or_insert(*v);
            }
        }
    }

    /// Hot-field contribution of one node (with its memo/noise key): `None`
    /// for sources, constant-folded subtrees and dead slots. Mirrors exactly
    /// which nodes [`CostModel::graph_cost_fast`] accumulates.
    fn node_hot_cost(&self, g: &Graph, id: NodeId, is_const: &[bool]) -> Option<(u64, OpCost)> {
        let node = g.node(id);
        if node.dead || is_const[id.index()] || matches!(node.op, OpKind::Input | OpKind::Weight) {
            return None;
        }
        let key = Self::op_key(g, id);
        Some((key, self.cached_op_cost_keyed(key, g, id)))
    }

    /// Runtime contribution of one node: zero when [`node_hot_cost`] is
    /// `None`; the (noise-field-adjusted) roofline time otherwise.
    ///
    /// [`node_hot_cost`]: CostModel::node_hot_cost
    fn node_time_ms(&self, g: &Graph, id: NodeId, is_const: &[bool]) -> f64 {
        self.node_hot_cost(g, id, is_const)
            .map(|(key, c)| self.noisy_op_time_ms(key, &c))
            .unwrap_or(0.0)
    }

    /// Incremental runtime after one rule application: start from the
    /// parent's runtime and re-cost only the nodes whose contribution the
    /// rewrite changed — the nodes the [`ApplyReport`] says were removed or
    /// added, plus survivors whose constness flipped (a rewrite can promote
    /// a subtree to weight-only arithmetic, or demote one back).
    ///
    /// Surviving nodes outside that set keep their contribution: rules only
    /// rewire inputs through `splice`, which enforces descriptor equality,
    /// so their per-op cost key (op attrs + input shapes) cannot change.
    ///
    /// The result equals `graph_runtime_ms(after)` up to f64 summation
    /// order (the full recompute stays the oracle; `tests/props.rs` pins
    /// the agreement to 1e-9). The identity holds under measurement noise
    /// too: the noise field is per-kernel and stateless, so only the
    /// touched nodes are resampled (see the module docs).
    pub fn delta_runtime_ms(
        &self,
        before: &Graph,
        before_ms: f64,
        after: &Graph,
        report: &ApplyReport,
    ) -> f64 {
        self.delta_runtime_ms_with(before, &self.const_set(before), before_ms, after, report)
    }

    /// [`CostModel::delta_runtime_ms`] with the parent's const set supplied
    /// by the caller — it is identical for every candidate expanded from
    /// one parent graph, so the search computes it once per frontier entry
    /// instead of once per (rule, location) site.
    pub fn delta_runtime_ms_with(
        &self,
        before: &Graph,
        const_before: &[bool],
        before_ms: f64,
        after: &Graph,
        report: &ApplyReport,
    ) -> f64 {
        let const_after = self.const_set(after);
        let mut ms = before_ms;
        for &id in &report.removed {
            ms -= self.node_time_ms(before, id, const_before);
        }
        for &id in &report.added {
            ms += self.node_time_ms(after, id, &const_after);
        }
        let prefix = report.prev_slots.min(const_after.len());
        for idx in 0..prefix {
            if const_before[idx] == const_after[idx] {
                continue;
            }
            let id = NodeId(idx as u32);
            // Removed/added slots are already handled above; a flip only
            // matters for nodes live on both sides.
            if before.node(id).dead || after.node(id).dead {
                continue;
            }
            ms -= self.node_time_ms(before, id, const_before);
            ms += self.node_time_ms(after, id, &const_after);
        }
        ms
    }

    /// Estimated inference memory in GiB (Table 2's "Mem. usage").
    pub fn graph_memory_gib(&self, g: &Graph) -> f64 {
        self.graph_cost(g).peak_bytes / (1024.0 * 1024.0 * 1024.0)
    }

    /// Incremental hot-path cost after one rule application: start from
    /// the parent's [`GraphCost`] and re-cost only the nodes the rewrite
    /// touched — [`CostModel::delta_runtime_ms`]'s contract extended to
    /// every field [`CostModel::graph_cost_fast`] fills (runtime, flops,
    /// traffic, launches; `peak_bytes` stays 0 like the fast path). The
    /// environment's §3.1.4 reward consumes this so a step costs O(touched)
    /// instead of O(graph). Launch counts are integers, so they match the
    /// full recompute *exactly*; the float fields agree up to f64
    /// summation order (`tests/env_incremental.rs` pins 1e-9). The
    /// identity holds under measurement noise too — the per-kernel noise
    /// field resamples only the touched nodes (same contract as
    /// `delta_runtime_ms`).
    pub fn delta_cost_fast(
        &self,
        before: &Graph,
        before_cost: &GraphCost,
        after: &Graph,
        report: &ApplyReport,
    ) -> GraphCost {
        let const_before = self.const_set(before);
        let const_after = self.const_set(after);
        let mut runtime_ms = before_cost.runtime_ms;
        let mut flops = before_cost.flops;
        let mut mem_bytes = before_cost.mem_bytes;
        let mut launches = before_cost.launches as i64;
        {
            let mut fold = |g: &Graph, id: NodeId, is_const: &[bool], sign: f64| {
                if let Some((key, c)) = self.node_hot_cost(g, id, is_const) {
                    runtime_ms += sign * self.noisy_op_time_ms(key, &c);
                    flops += sign * c.flops;
                    mem_bytes += sign * c.bytes;
                    launches += sign as i64 * c.launches as i64;
                }
            };
            for &id in &report.removed {
                fold(before, id, &const_before, -1.0);
            }
            for &id in &report.added {
                fold(after, id, &const_after, 1.0);
            }
            // Survivors whose constness flipped contribute on one side only.
            let prefix = report.prev_slots.min(const_after.len());
            for idx in 0..prefix {
                if const_before[idx] == const_after[idx] {
                    continue;
                }
                let id = NodeId(idx as u32);
                if before.node(id).dead || after.node(id).dead {
                    continue;
                }
                fold(before, id, &const_before, -1.0);
                fold(after, id, &const_after, 1.0);
            }
        }
        GraphCost {
            runtime_ms,
            flops,
            mem_bytes,
            launches: launches.max(0) as u64,
            peak_bytes: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder, PadMode};

    fn conv_graph(fused: bool) -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16, 32, 32]);
        if fused {
            let ci = 16;
            let w = b.weight(&[32, ci, 3, 3]);
            b.op(
                crate::graph::OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::Relu },
                &[x, w],
            )
            .unwrap();
        } else {
            let c = b.conv(x, 32, 3, 1, PadMode::Same).unwrap();
            b.relu(c).unwrap();
        }
        b.finish()
    }

    #[test]
    fn fused_conv_relu_cheaper() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let unfused = cm.graph_runtime_ms(&conv_graph(false));
        let fused = cm.graph_runtime_ms(&conv_graph(true));
        assert!(fused < unfused, "fused {fused} !< unfused {unfused}");
    }

    #[test]
    fn costs_positive_and_monotone_in_size() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let small = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let big = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 64, 64]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let ts = cm.graph_runtime_ms(&small);
        let tb = cm.graph_runtime_ms(&big);
        assert!(ts > 0.0);
        assert!(tb > ts);
    }

    #[test]
    fn noise_is_seeded_and_bounded() {
        let g = conv_graph(false);
        let base = CostModel::new(DeviceProfile::rtx2070()).graph_runtime_ms(&g);
        let a = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 1).graph_runtime_ms(&g);
        let b = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 1).graph_runtime_ms(&g);
        assert_eq!(a, b, "same seed, same noise");
        assert!((a / base - 1.0).abs() < 0.5);
        // Different seeds give a different field; noise actually engages.
        let c = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 2).graph_runtime_ms(&g);
        assert_ne!(a.to_bits(), c.to_bits(), "noise field should depend on the seed");
        assert_ne!(a.to_bits(), base.to_bits(), "noise should perturb the clean runtime");
    }

    #[test]
    fn noise_field_is_stateless() {
        // The per-kernel field is a pure function: repeated costings of the
        // same graph on the same model are bit-identical (no stream state),
        // which is what keeps incremental deltas and parallel workers exact.
        let g = conv_graph(false);
        let cm = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 7);
        let a = cm.graph_runtime_ms(&g);
        let b = cm.graph_runtime_ms(&g);
        assert_eq!(a.to_bits(), b.to_bits());
        // And fast/full paths agree on the noisy runtime too.
        let fast = cm.graph_cost_fast(&g).runtime_ms;
        let full = cm.graph_cost(&g).runtime_ms;
        assert!((fast - full).abs() < 1e-9, "fast {fast} vs full {full}");
    }

    #[test]
    fn const_subtrees_cost_nothing() {
        // conv(x, mul(w, reshape(scale))) — the weight arithmetic is
        // load-time precomputable and must not add launches or flops.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let folded = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            let w = b.weight(&[8, 8, 3, 3]);
            let s = b.weight(&[8]);
            let sr = b.reshape(s, &[8, 1, 1, 1]).unwrap();
            let w2 = b.op(crate::graph::OpKind::Mul, &[w, sr]).unwrap();
            b.op(
                crate::graph::OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None },
                &[x, w2],
            )
            .unwrap();
            b.finish()
        };
        let plain = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let cf = cm.graph_cost(&folded);
        let cp = cm.graph_cost(&plain);
        assert_eq!(cf.launches, cp.launches);
        assert!((cf.runtime_ms - cp.runtime_ms).abs() < 1e-9);
    }

    #[test]
    fn fast_and_full_costs_agree_on_hot_fields() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        for (_, g) in crate::zoo::all() {
            let fast = cm.graph_cost_fast(&g);
            let full = cm.graph_cost(&g);
            assert_eq!(fast.launches, full.launches);
            assert!((fast.runtime_ms - full.runtime_ms).abs() < 1e-9);
            assert!((fast.flops - full.flops).abs() < 1e-3);
            assert!((fast.mem_bytes - full.mem_bytes).abs() < 1e-3);
        }
    }

    #[test]
    fn delta_runtime_matches_full_recompute() {
        // Every applicable rule site on a mixed graph: the incremental cost
        // must agree with the full oracle to float-sum precision.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let lib = crate::xfer::library::standard_library();
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
        let _ = b.relu(c2).unwrap();
        let g = b.finish();
        let base = cm.graph_runtime_ms(&g);
        let mut checked = 0;
        for ri in 0..lib.len() {
            let rule = lib.get(ri).unwrap();
            for loc in rule.find(&g) {
                let mut g2 = g.clone();
                let Ok(report) = crate::xfer::apply_rule(&mut g2, rule, &loc) else {
                    continue;
                };
                let delta = cm.delta_runtime_ms(&g, base, &g2, &report);
                let full = cm.graph_runtime_ms(&g2);
                assert!(
                    (delta - full).abs() < 1e-9,
                    "{}: delta {delta} vs full {full}",
                    rule.name()
                );
                checked += 1;
            }
        }
        assert!(checked > 3, "too few rule sites exercised: {checked}");
    }

    #[test]
    fn delta_runtime_with_noise_matches_noisy_oracle() {
        // The noise-aware delta resamples only the touched nodes and must
        // agree with the noisy full recompute to f64 summation order — no
        // full-refresh fallback.
        let cm = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 9);
        let lib = crate::xfer::library::standard_library();
        let g = conv_graph(false);
        let base = cm.graph_runtime_ms(&g);
        let mut checked = 0;
        for ri in 0..lib.len() {
            let rule = lib.get(ri).unwrap();
            for loc in rule.find(&g) {
                let mut g2 = g.clone();
                let Ok(report) = crate::xfer::apply_rule(&mut g2, rule, &loc) else {
                    continue;
                };
                let delta = cm.delta_runtime_ms(&g, base, &g2, &report);
                let full = cm.graph_runtime_ms(&g2);
                assert!(
                    (delta - full).abs() < 1e-9,
                    "{}: noisy delta {delta} vs full {full}",
                    rule.name()
                );
                // The noisy oracle itself differs from the clean runtime.
                let clean = CostModel::new(DeviceProfile::rtx2070()).graph_runtime_ms(&g2);
                assert_ne!(full.to_bits(), clean.to_bits(), "{}", rule.name());
                checked += 1;
            }
        }
        assert!(checked > 0, "no rule site exercised");
    }

    #[test]
    fn clone_replays_noise_and_shares_no_state() {
        let g = conv_graph(false);
        let a = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 3);
        let b = a.clone();
        assert_eq!(a.graph_runtime_ms(&g), b.graph_runtime_ms(&g));
        // The field is stateless: using one clone must not affect the other,
        // and `with_noise_of` transplants the exact same field.
        let _ = a.graph_runtime_ms(&g);
        let c = CostModel::new(DeviceProfile::rtx2070()).with_noise_of(&b);
        assert_eq!(b.graph_runtime_ms(&g), c.graph_runtime_ms(&g));
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(
            a.fingerprint(),
            CostModel::new(DeviceProfile::rtx2070()).fingerprint(),
            "noise configuration must show up in the fingerprint"
        );
    }

    #[test]
    fn const_set_matches_topo_reference() {
        // The DFS const_set must agree with a straightforward topo-order
        // evaluation on every zoo graph.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        for (_, g) in crate::zoo::all() {
            let fast = cm.const_set(&g);
            let mut reference = vec![false; g.n_slots()];
            for id in g.topo_order().unwrap() {
                let n = g.node(id);
                reference[id.index()] = match n.op {
                    OpKind::Weight => true,
                    OpKind::Input => false,
                    _ => {
                        !n.inputs.is_empty()
                            && n.inputs.iter().all(|p| reference[p.node.index()])
                    }
                };
            }
            assert_eq!(fast, reference);
        }
    }

    #[test]
    fn snapshot_workers_agree_with_parent() {
        // A model built from a snapshot (shared base + empty overlay) must
        // cost every zoo graph bit-identically to the parent, and
        // absorbing its overlay back must not duplicate base entries.
        let parent = CostModel::new(DeviceProfile::rtx2070());
        let bert = crate::zoo::bert_base();
        let parent_ms = parent.graph_runtime_ms(&bert);
        let snap = parent.snapshot();
        let worker = CostModel::from_snapshot(&snap);
        assert_eq!(worker.graph_runtime_ms(&bert).to_bits(), parent_ms.to_bits());
        // Everything bert needs is frozen in the base: the worker's
        // overlay stays empty.
        assert!(worker.cache.borrow().is_empty(), "worker overlay grew on warm keys");
        // New ops land in the overlay and absorb back without duplicates.
        let vit = crate::zoo::vit_base();
        let fresh = worker.graph_runtime_ms(&vit);
        assert!(!worker.cache.borrow().is_empty());
        parent.absorb_cache(&worker);
        assert_eq!(parent.graph_runtime_ms(&vit).to_bits(), fresh.to_bits());
    }

    #[test]
    fn snapshot_rebases_and_preserves_costs() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let g = conv_graph(false);
        let before = cm.graph_runtime_ms(&g);
        let snap = cm.snapshot();
        // The overlay drained into the (now shared) base...
        assert!(cm.cache.borrow().is_empty());
        assert!(!snap.base.is_empty());
        // ...costs are unchanged, and a second snapshot is O(1): it hands
        // back the very same frozen map.
        assert_eq!(cm.graph_runtime_ms(&g).to_bits(), before.to_bits());
        let snap2 = cm.snapshot();
        assert!(std::sync::Arc::ptr_eq(&snap.base, &snap2.base));
    }

    #[test]
    fn delta_cost_fast_matches_full_recompute() {
        // All hot fields, every applicable rule site: launches exact,
        // floats to 1e-9 (same tolerance delta_runtime_ms pins).
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let lib = crate::xfer::library::standard_library();
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
        let _ = b.relu(c2).unwrap();
        let g = b.finish();
        let base = cm.graph_cost_fast(&g);
        let mut checked = 0;
        for ri in 0..lib.len() {
            let rule = lib.get(ri).unwrap();
            for loc in rule.find(&g) {
                let mut g2 = g.clone();
                let Ok(report) = crate::xfer::apply_rule(&mut g2, rule, &loc) else {
                    continue;
                };
                let delta = cm.delta_cost_fast(&g, &base, &g2, &report);
                let full = cm.graph_cost_fast(&g2);
                assert_eq!(delta.launches, full.launches, "{}", rule.name());
                assert!((delta.runtime_ms - full.runtime_ms).abs() < 1e-9, "{}", rule.name());
                assert!((delta.flops - full.flops).abs() < 1e-3, "{}", rule.name());
                assert!((delta.mem_bytes - full.mem_bytes).abs() < 1e-3, "{}", rule.name());
                checked += 1;
            }
        }
        assert!(checked > 3, "too few rule sites exercised: {checked}");
    }

    #[test]
    fn delta_cost_fast_with_noise_matches_noisy_oracle() {
        // All hot fields stay exact under noise: launches/flops/bytes are
        // noise-free, the runtime resamples only the touched kernels.
        let cm = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 9);
        let lib = crate::xfer::library::standard_library();
        let g = conv_graph(false);
        let base = cm.graph_cost_fast(&g);
        let rule = lib.get(lib.index_of("fuse_conv_relu").unwrap()).unwrap();
        let loc = rule.find(&g)[0].clone();
        let mut g2 = g.clone();
        let report = crate::xfer::apply_rule(&mut g2, rule, &loc).unwrap();
        let delta = cm.delta_cost_fast(&g, &base, &g2, &report);
        let full = cm.graph_cost_fast(&g2);
        assert_eq!(delta.launches, full.launches);
        assert!((delta.runtime_ms - full.runtime_ms).abs() < 1e-9);
        assert!((delta.flops - full.flops).abs() < 1e-3);
        assert!((delta.mem_bytes - full.mem_bytes).abs() < 1e-3);
    }

    #[test]
    fn memory_includes_weights() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let g = conv_graph(false);
        let c = cm.graph_cost(&g);
        // 32*16*3*3 weight floats at minimum.
        assert!(c.peak_bytes > (32 * 16 * 3 * 3 * 4) as f64);
    }
}
