//! Analytic cost model — the stand-in for TASO's cuDNN-based runtime
//! measurement (DESIGN.md §Hardware-Adaptation).
//!
//! Per operator we compute (FLOPs, bytes moved, kernel launches) and map
//! them to time with a roofline under a [`DeviceProfile`]:
//!
//! `t_op = launch_overhead + max(flops / (peak * eff_op), bytes / bandwidth)`
//!
//! Exactly the quantities the paper's reward functions consume (Eq. 2/3 use
//! runtime and memory-access deltas; §4.3 additionally logs FLOPS and kernel
//! launches). Fusion rules win for the same reason they win on a GPU: fewer
//! launches and less intermediate HBM traffic. An optional seeded noise
//! model reproduces the measurement variance the paper discusses in §3.1.4.

pub mod device;
pub mod op_cost;

pub use device::DeviceProfile;
pub use op_cost::{op_cost, OpCost};

use std::cell::RefCell;
use std::collections::HashMap;

use crate::graph::{Graph, NodeId, OpKind};
use crate::util::Rng;
use crate::xfer::ApplyReport;

/// Cost summary for a whole graph.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GraphCost {
    pub runtime_ms: f64,
    pub flops: f64,
    /// Bytes moved through memory (activations + weights read, outputs written).
    pub mem_bytes: f64,
    pub launches: u64,
    /// Peak resident memory during execution (weights + live activations).
    pub peak_bytes: f64,
}

pub struct CostModel {
    pub device: DeviceProfile,
    /// Std-dev of multiplicative measurement noise (0 = deterministic).
    pub noise_std: f64,
    noise_rng: RefCell<Rng>,
    /// Per-op memoisation keyed by (attr hash, input shapes hash).
    cache: RefCell<HashMap<u64, OpCost>>,
}

/// Clones duplicate the device, the noise configuration *and state*, and a
/// snapshot of the per-op memo cache — parallel search workers each own a
/// clone (the `RefCell` interior makes `CostModel` deliberately `!Sync`),
/// warm-starting from whatever the parent has already costed.
impl Clone for CostModel {
    fn clone(&self) -> Self {
        Self {
            device: self.device,
            noise_std: self.noise_std,
            noise_rng: RefCell::new(self.noise_rng.borrow().clone()),
            cache: RefCell::new(self.cache.borrow().clone()),
        }
    }
}

impl CostModel {
    pub fn new(device: DeviceProfile) -> Self {
        Self { device, noise_std: 0.0, noise_rng: RefCell::new(Rng::new(0)), cache: RefCell::new(HashMap::new()) }
    }

    /// Enable multiplicative measurement noise (paper §3.1.4: "non-negligible
    /// variance of the runtime on real hardware").
    pub fn with_noise(mut self, std: f64, seed: u64) -> Self {
        self.noise_std = std;
        self.noise_rng = RefCell::new(Rng::new(seed));
        self
    }

    fn cached_op_cost(&self, g: &Graph, id: crate::graph::NodeId) -> OpCost {
        let node = g.node(id);
        let mut key = node.op.attr_hash();
        for p in &node.inputs {
            if let Ok(d) = g.out_desc(*p) {
                for &dim in &d.shape {
                    key = key
                        .rotate_left(13)
                        .wrapping_mul(0x100000001B3)
                        .wrapping_add(dim as u64);
                }
            }
        }
        if let Some(c) = self.cache.borrow().get(&key) {
            return *c;
        }
        let descs: Vec<&crate::graph::TensorDesc> = node
            .inputs
            .iter()
            .filter_map(|p| g.out_desc(*p).ok())
            .collect();
        let c = op_cost(&node.op, &descs, &node.outs);
        self.cache.borrow_mut().insert(key, c);
        c
    }

    /// Node-wise constness: a node is constant when every transitive source
    /// feeding it is a `Weight`. Constant subtrees (folded BN scales,
    /// concatenated kernels, composed 1x1 weights...) are precomputed at
    /// model-load time — TASO does the same — so they cost zero runtime.
    ///
    /// Runs on every candidate the search baselines cost, so it uses an
    /// explicit-stack DFS over flat arena-indexed state instead of the
    /// HashMap-heavy `Graph::topo_order`. Nodes on a cycle resolve to
    /// non-constant (such graphs are invalid and never costed for real).
    pub fn const_set(&self, g: &Graph) -> Vec<bool> {
        const UNSEEN: u8 = 0;
        const OPEN: u8 = 1; // on the DFS stack
        const CONST: u8 = 2;
        const VAR: u8 = 3;
        let n = g.n_slots();
        let mut state = vec![UNSEEN; n];
        // (node index, next input position) resume points.
        let mut stack: Vec<(u32, u32)> = Vec::new();
        for root in g.live_ids() {
            if state[root.index()] != UNSEEN {
                continue;
            }
            state[root.index()] = OPEN;
            stack.push((root.0, 0));
            while let Some((idx, ip)) = stack.pop() {
                let node = &g.nodes[idx as usize];
                if ip == 0 {
                    let leaf = match node.op {
                        OpKind::Weight => Some(CONST),
                        OpKind::Input => Some(VAR),
                        _ if node.inputs.is_empty() => Some(VAR),
                        _ => None,
                    };
                    if let Some(s) = leaf {
                        state[idx as usize] = s;
                        continue;
                    }
                }
                if (ip as usize) < node.inputs.len() {
                    let child = node.inputs[ip as usize].node.index();
                    stack.push((idx, ip + 1));
                    if state[child] == UNSEEN {
                        state[child] = OPEN;
                        stack.push((child as u32, 0));
                    }
                } else {
                    // An OPEN child here means a cycle: treat as non-const.
                    state[idx as usize] = if node
                        .inputs
                        .iter()
                        .all(|p| state[p.node.index()] == CONST)
                    {
                        CONST
                    } else {
                        VAR
                    };
                }
            }
        }
        state.into_iter().map(|s| s == CONST).collect()
    }

    /// Hot-path cost: runtime / flops / traffic / launches, *without* the
    /// peak-memory analysis (which needs a liveness sweep). This is what
    /// the search baselines and the environment reward evaluate thousands
    /// of times per episode — see EXPERIMENTS.md §Perf/L3.
    pub fn graph_cost_fast(&self, g: &Graph) -> GraphCost {
        let mut total = GraphCost::default();
        let is_const = self.const_set(g);
        for id in g.live_ids() {
            if is_const[id.index()] {
                continue;
            }
            let node = g.node(id);
            if matches!(node.op, OpKind::Input | OpKind::Weight) {
                continue;
            }
            let c = self.cached_op_cost(g, id);
            total.flops += c.flops;
            total.mem_bytes += c.bytes;
            total.launches += c.launches;
            total.runtime_ms += self.device.op_time_ms(&c);
        }
        if self.noise_std > 0.0 {
            let n = 1.0 + self.noise_std * self.noise_rng.borrow_mut().normal() as f64;
            total.runtime_ms *= n.max(0.5);
        }
        total
    }

    /// Full cost report for a graph.
    pub fn graph_cost(&self, g: &Graph) -> GraphCost {
        let mut total = GraphCost::default();
        let mut weight_bytes = 0f64;
        let mut act_bytes_max = 0f64;
        let is_const = self.const_set(g);
        let cons = g.consumers();
        // A constant node is *resident* iff some non-constant op reads it
        // (it is the materialised, precomputed parameter).
        let resident = |id: crate::graph::NodeId| -> bool {
            cons.get(&id)
                .map(|v| v.iter().any(|(c, _)| !is_const[c.index()]))
                .unwrap_or(false)
        };
        for id in g.live_ids() {
            let node = g.node(id);
            match node.op {
                OpKind::Input => {}
                OpKind::Weight => {
                    if resident(id) {
                        weight_bytes += node.outs[0].bytes() as f64;
                    }
                }
                _ if is_const[id.index()] => {
                    if resident(id) {
                        weight_bytes += node.outs.iter().map(|t| t.bytes() as f64).sum::<f64>();
                    }
                }
                _ => {
                    let c = self.cached_op_cost(g, id);
                    total.flops += c.flops;
                    total.mem_bytes += c.bytes;
                    total.launches += c.launches;
                    total.runtime_ms += self.device.op_time_ms(&c);
                    let out_b: f64 = node.outs.iter().map(|t| t.bytes() as f64).sum();
                    act_bytes_max = act_bytes_max.max(out_b);
                }
            }
        }
        // Peak memory approximation: all weights resident + the two largest
        // activation frontiers (double-buffered producer/consumer).
        total.peak_bytes = weight_bytes + 2.0 * act_bytes_max + self.activation_frontier(g);
        if self.noise_std > 0.0 {
            let n = 1.0 + self.noise_std * self.noise_rng.borrow_mut().normal() as f64;
            total.runtime_ms *= n.max(0.5);
        }
        total
    }

    /// Largest sum of simultaneously-live activation bytes along the topo order.
    fn activation_frontier(&self, g: &Graph) -> f64 {
        let order = match g.topo_order() {
            Ok(o) => o,
            Err(_) => return 0.0,
        };
        let consumers = g.consumers();
        let mut remaining: HashMap<crate::graph::NodeId, usize> = HashMap::new();
        for id in g.live_ids() {
            remaining.insert(id, consumers.get(&id).map_or(0, |v| v.len()));
        }
        let is_const = self.const_set(g);
        let mut live = 0f64;
        let mut peak = 0f64;
        let mut alive: HashMap<crate::graph::NodeId, f64> = HashMap::new();
        for id in order {
            let node = g.node(id);
            if matches!(node.op, OpKind::Weight) || is_const[id.index()] {
                continue;
            }
            let bytes: f64 = node.outs.iter().map(|t| t.bytes() as f64).sum();
            live += bytes;
            alive.insert(id, bytes);
            peak = peak.max(live);
            for p in &node.inputs {
                if let Some(r) = remaining.get_mut(&p.node) {
                    *r = r.saturating_sub(1);
                    if *r == 0 {
                        if let Some(b) = alive.remove(&p.node) {
                            live -= b;
                        }
                    }
                }
            }
        }
        peak
    }

    /// Estimated end-to-end runtime in milliseconds (the paper's `RT`).
    pub fn graph_runtime_ms(&self, g: &Graph) -> f64 {
        self.graph_cost_fast(g).runtime_ms
    }

    /// Fold a worker clone's per-op memo entries back into this model's
    /// cache, so op costs computed inside a parallel search depth are not
    /// recomputed at the next one. Values are a deterministic function of
    /// the key, so merge order cannot affect any result.
    pub fn absorb_cache(&self, worker: &CostModel) {
        let theirs = worker.cache.borrow();
        let mut ours = self.cache.borrow_mut();
        for (k, v) in theirs.iter() {
            ours.entry(*k).or_insert(*v);
        }
    }

    /// Runtime contribution of one node: zero for sources, constant-folded
    /// subtrees and dead slots; the roofline time otherwise. Mirrors
    /// exactly which nodes [`CostModel::graph_cost_fast`] accumulates.
    fn node_time_ms(&self, g: &Graph, id: NodeId, is_const: &[bool]) -> f64 {
        let node = g.node(id);
        if node.dead || is_const[id.index()] || matches!(node.op, OpKind::Input | OpKind::Weight) {
            return 0.0;
        }
        self.device.op_time_ms(&self.cached_op_cost(g, id))
    }

    /// Incremental runtime after one rule application: start from the
    /// parent's runtime and re-cost only the nodes whose contribution the
    /// rewrite changed — the nodes the [`ApplyReport`] says were removed or
    /// added, plus survivors whose constness flipped (a rewrite can promote
    /// a subtree to weight-only arithmetic, or demote one back).
    ///
    /// Surviving nodes outside that set keep their contribution: rules only
    /// rewire inputs through `splice`, which enforces descriptor equality,
    /// so their per-op cost key (op attrs + input shapes) cannot change.
    ///
    /// The result equals `graph_runtime_ms(after)` up to f64 summation
    /// order (the full recompute stays the oracle; `tests/props.rs` pins
    /// the agreement to 1e-9). With measurement noise enabled the delta
    /// identity does not hold, so this falls back to the full recompute.
    pub fn delta_runtime_ms(
        &self,
        before: &Graph,
        before_ms: f64,
        after: &Graph,
        report: &ApplyReport,
    ) -> f64 {
        self.delta_runtime_ms_with(before, &self.const_set(before), before_ms, after, report)
    }

    /// [`CostModel::delta_runtime_ms`] with the parent's const set supplied
    /// by the caller — it is identical for every candidate expanded from
    /// one parent graph, so the search computes it once per frontier entry
    /// instead of once per (rule, location) site.
    pub fn delta_runtime_ms_with(
        &self,
        before: &Graph,
        const_before: &[bool],
        before_ms: f64,
        after: &Graph,
        report: &ApplyReport,
    ) -> f64 {
        if self.noise_std > 0.0 {
            return self.graph_runtime_ms(after);
        }
        let const_after = self.const_set(after);
        let mut ms = before_ms;
        for &id in &report.removed {
            ms -= self.node_time_ms(before, id, const_before);
        }
        for &id in &report.added {
            ms += self.node_time_ms(after, id, &const_after);
        }
        let prefix = report.prev_slots.min(const_after.len());
        for idx in 0..prefix {
            if const_before[idx] == const_after[idx] {
                continue;
            }
            let id = NodeId(idx as u32);
            // Removed/added slots are already handled above; a flip only
            // matters for nodes live on both sides.
            if before.node(id).dead || after.node(id).dead {
                continue;
            }
            ms -= self.node_time_ms(before, id, const_before);
            ms += self.node_time_ms(after, id, &const_after);
        }
        ms
    }

    /// Estimated inference memory in GiB (Table 2's "Mem. usage").
    pub fn graph_memory_gib(&self, g: &Graph) -> f64 {
        self.graph_cost(g).peak_bytes / (1024.0 * 1024.0 * 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder, PadMode};

    fn conv_graph(fused: bool) -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16, 32, 32]);
        if fused {
            let ci = 16;
            let w = b.weight(&[32, ci, 3, 3]);
            b.op(
                crate::graph::OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::Relu },
                &[x, w],
            )
            .unwrap();
        } else {
            let c = b.conv(x, 32, 3, 1, PadMode::Same).unwrap();
            b.relu(c).unwrap();
        }
        b.finish()
    }

    #[test]
    fn fused_conv_relu_cheaper() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let unfused = cm.graph_runtime_ms(&conv_graph(false));
        let fused = cm.graph_runtime_ms(&conv_graph(true));
        assert!(fused < unfused, "fused {fused} !< unfused {unfused}");
    }

    #[test]
    fn costs_positive_and_monotone_in_size() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let small = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let big = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 64, 64]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let ts = cm.graph_runtime_ms(&small);
        let tb = cm.graph_runtime_ms(&big);
        assert!(ts > 0.0);
        assert!(tb > ts);
    }

    #[test]
    fn noise_is_seeded_and_bounded() {
        let g = conv_graph(false);
        let base = CostModel::new(DeviceProfile::rtx2070()).graph_runtime_ms(&g);
        let a = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 1).graph_runtime_ms(&g);
        let b = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 1).graph_runtime_ms(&g);
        assert_eq!(a, b, "same seed, same noise");
        assert!((a / base - 1.0).abs() < 0.5);
    }

    #[test]
    fn const_subtrees_cost_nothing() {
        // conv(x, mul(w, reshape(scale))) — the weight arithmetic is
        // load-time precomputable and must not add launches or flops.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let folded = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            let w = b.weight(&[8, 8, 3, 3]);
            let s = b.weight(&[8]);
            let sr = b.reshape(s, &[8, 1, 1, 1]).unwrap();
            let w2 = b.op(crate::graph::OpKind::Mul, &[w, sr]).unwrap();
            b.op(
                crate::graph::OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None },
                &[x, w2],
            )
            .unwrap();
            b.finish()
        };
        let plain = {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 8, 16, 16]);
            b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
            b.finish()
        };
        let cf = cm.graph_cost(&folded);
        let cp = cm.graph_cost(&plain);
        assert_eq!(cf.launches, cp.launches);
        assert!((cf.runtime_ms - cp.runtime_ms).abs() < 1e-9);
    }

    #[test]
    fn fast_and_full_costs_agree_on_hot_fields() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        for (_, g) in crate::zoo::all() {
            let fast = cm.graph_cost_fast(&g);
            let full = cm.graph_cost(&g);
            assert_eq!(fast.launches, full.launches);
            assert!((fast.runtime_ms - full.runtime_ms).abs() < 1e-9);
            assert!((fast.flops - full.flops).abs() < 1e-3);
            assert!((fast.mem_bytes - full.mem_bytes).abs() < 1e-3);
        }
    }

    #[test]
    fn delta_runtime_matches_full_recompute() {
        // Every applicable rule site on a mixed graph: the incremental cost
        // must agree with the full oracle to float-sum precision.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let lib = crate::xfer::library::standard_library();
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
        let _ = b.relu(c2).unwrap();
        let g = b.finish();
        let base = cm.graph_runtime_ms(&g);
        let mut checked = 0;
        for ri in 0..lib.len() {
            let rule = lib.get(ri).unwrap();
            for loc in rule.find(&g) {
                let mut g2 = g.clone();
                let Ok(report) = crate::xfer::apply_rule(&mut g2, rule, &loc) else {
                    continue;
                };
                let delta = cm.delta_runtime_ms(&g, base, &g2, &report);
                let full = cm.graph_runtime_ms(&g2);
                assert!(
                    (delta - full).abs() < 1e-9,
                    "{}: delta {delta} vs full {full}",
                    rule.name()
                );
                checked += 1;
            }
        }
        assert!(checked > 3, "too few rule sites exercised: {checked}");
    }

    #[test]
    fn delta_runtime_with_noise_falls_back_to_oracle() {
        let cm = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 9);
        let lib = crate::xfer::library::standard_library();
        let g = conv_graph(false);
        let rule = lib.get(lib.index_of("fuse_conv_relu").unwrap()).unwrap();
        let loc = rule.find(&g)[0].clone();
        let mut g2 = g.clone();
        let report = crate::xfer::apply_rule(&mut g2, rule, &loc).unwrap();
        let delta = cm.delta_runtime_ms(&g, 1234.5, &g2, &report);
        // Under noise the fallback ignores `before_ms` entirely.
        assert!(delta > 0.0 && delta < 1234.5);
    }

    #[test]
    fn clone_replays_noise_and_shares_no_state() {
        let g = conv_graph(false);
        let a = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 3);
        let b = a.clone();
        assert_eq!(a.graph_runtime_ms(&g), b.graph_runtime_ms(&g));
        // Advancing one clone's rng must not affect the other.
        let _ = a.graph_runtime_ms(&g);
        let c = b.clone();
        assert_eq!(b.graph_runtime_ms(&g), c.graph_runtime_ms(&g));
    }

    #[test]
    fn const_set_matches_topo_reference() {
        // The DFS const_set must agree with a straightforward topo-order
        // evaluation on every zoo graph.
        let cm = CostModel::new(DeviceProfile::rtx2070());
        for (_, g) in crate::zoo::all() {
            let fast = cm.const_set(&g);
            let mut reference = vec![false; g.n_slots()];
            for id in g.topo_order().unwrap() {
                let n = g.node(id);
                reference[id.index()] = match n.op {
                    OpKind::Weight => true,
                    OpKind::Input => false,
                    _ => {
                        !n.inputs.is_empty()
                            && n.inputs.iter().all(|p| reference[p.node.index()])
                    }
                };
            }
            assert_eq!(fast, reference);
        }
    }

    #[test]
    fn memory_includes_weights() {
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let g = conv_graph(false);
        let c = cm.graph_cost(&g);
        // 32*16*3*3 weight floats at minimum.
        assert!(c.peak_bytes > (32 * 16 * 3 * 3 * 4) as f64);
    }
}
