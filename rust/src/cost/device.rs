//! Device profiles: the hardware parameters of the roofline model.
//!
//! `rtx2070` approximates the paper's testbed (§4.1: NVIDIA GeForce RTX
//! 2070); `cpu_xeon` exists for ablations; `tpu_v4ish` backs the DESIGN.md
//! §Perf discussion of real-TPU kernel estimates.

use super::op_cost::OpCost;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Peak f32 throughput in FLOP/s.
    pub peak_flops: f64,
    /// Memory bandwidth in bytes/s.
    pub mem_bw: f64,
    /// Fixed overhead per kernel launch, in seconds.
    pub launch_overhead_s: f64,
}

impl DeviceProfile {
    pub fn rtx2070() -> Self {
        Self {
            name: "rtx2070",
            peak_flops: 7.5e12, // 7.5 TFLOP/s fp32
            mem_bw: 448e9,      // 448 GB/s GDDR6
            launch_overhead_s: 12e-6,
        }
    }

    pub fn cpu_xeon() -> Self {
        Self {
            name: "cpu_xeon",
            peak_flops: 0.5e12,
            mem_bw: 80e9,
            launch_overhead_s: 0.5e-6,
        }
    }

    pub fn tpu_v4ish() -> Self {
        Self {
            name: "tpu_v4ish",
            peak_flops: 137e12, // bf16 MXU roofline, reported as flops-equivalent
            mem_bw: 1200e9,
            launch_overhead_s: 2e-6,
        }
    }

    /// Roofline time for one operator, in milliseconds.
    pub fn op_time_ms(&self, c: &OpCost) -> f64 {
        let compute_s = c.flops / (self.peak_flops * c.efficiency);
        let memory_s = c.bytes / self.mem_bw;
        let overhead_s = c.launches as f64 * self.launch_overhead_s;
        (overhead_s + compute_s.max(memory_s)) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_op_uses_flops() {
        let d = DeviceProfile::rtx2070();
        let c = OpCost { flops: 7.5e9, bytes: 1e3, launches: 0, efficiency: 1.0 };
        // 7.5e9 flops at 7.5e12 flop/s = 1 ms.
        let t = d.op_time_ms(&c);
        assert!((t - 1.0).abs() < 0.01, "{t}");
    }

    #[test]
    fn memory_bound_op_uses_bandwidth() {
        let d = DeviceProfile::rtx2070();
        let c = OpCost { flops: 1e3, bytes: 448e6, launches: 0, efficiency: 1.0 };
        let t = d.op_time_ms(&c);
        assert!((t - 1.0).abs() < 0.01, "{t}");
    }

    #[test]
    fn launches_add_fixed_cost() {
        let d = DeviceProfile::rtx2070();
        let one = OpCost { flops: 0.0, bytes: 0.0, launches: 1, efficiency: 1.0 };
        let ten = OpCost { flops: 0.0, bytes: 0.0, launches: 10, efficiency: 1.0 };
        assert!((d.op_time_ms(&ten) - 10.0 * d.op_time_ms(&one)).abs() < 1e-12);
    }
}
