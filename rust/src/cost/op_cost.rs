//! Per-operator cost: FLOPs, bytes moved, launch count, and an efficiency
//! factor modelling how well the op maps onto the device's compute units
//! (GEMM-like ops run near peak; elementwise and memory-shuffling ops do
//! not). The numbers feed the roofline in [`super::device`].

use crate::graph::{OpKind, TensorDesc};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    pub flops: f64,
    pub bytes: f64,
    pub launches: u64,
    /// Fraction of peak compute this op achieves (0, 1].
    pub efficiency: f64,
}

impl OpCost {
    fn zero() -> Self {
        Self { flops: 0.0, bytes: 0.0, launches: 0, efficiency: 1.0 }
    }
}

fn io_bytes(inputs: &[&TensorDesc], outputs: &[TensorDesc]) -> f64 {
    let read: usize = inputs.iter().map(|t| t.bytes()).sum();
    let write: usize = outputs.iter().map(|t| t.bytes()).sum();
    (read + write) as f64
}

/// Fused activations add one pass of elementwise flops but no extra launch
/// or memory round-trip — that asymmetry is exactly why fusion rules win.
fn act_flops(act: crate::graph::Activation, n: usize) -> f64 {
    match act {
        crate::graph::Activation::None => 0.0,
        crate::graph::Activation::Relu => n as f64,
        crate::graph::Activation::Gelu => 8.0 * n as f64,
    }
}

pub fn op_cost(op: &OpKind, inputs: &[&TensorDesc], outputs: &[TensorDesc]) -> OpCost {
    use OpKind::*;
    let bytes = io_bytes(inputs, outputs);
    let out_elems: usize = outputs.iter().map(|t| t.n_elems()).sum();
    match op {
        Input | Weight => OpCost::zero(),
        ConvBias { act, .. } => {
            let w = inputs[1];
            let (ci, kh, kw) = (w.shape[1], w.shape[2], w.shape[3]);
            let macs = outputs[0].n_elems() as f64 * (ci * kh * kw) as f64;
            OpCost {
                // bias add rides the conv epilogue: +1 flop/elem, no launch.
                flops: 2.0 * macs + out_elems as f64 + act_flops(*act, out_elems),
                bytes,
                launches: 1,
                efficiency: 0.85,
            }
        }
        Conv2d { act, .. } => {
            let w = inputs[1];
            let (ci, kh, kw) = (w.shape[1], w.shape[2], w.shape[3]);
            let macs = outputs[0].n_elems() as f64 * (ci * kh * kw) as f64;
            OpCost {
                flops: 2.0 * macs + act_flops(*act, out_elems),
                bytes,
                launches: 1,
                efficiency: 0.85, // cuDNN implicit-GEMM territory
            }
        }
        MatMul { act, .. } => {
            let a = inputs[0];
            let k = a.shape[a.rank() - if matches!(op, MatMul { trans_a: true, .. }) { 2 } else { 1 }];
            let macs = outputs[0].n_elems() as f64 * k as f64;
            OpCost {
                flops: 2.0 * macs + act_flops(*act, out_elems),
                bytes,
                launches: 1,
                efficiency: 0.9,
            }
        }
        Linear { act } => {
            let k = inputs[1].shape[0];
            let macs = outputs[0].n_elems() as f64 * k as f64;
            OpCost {
                flops: 2.0 * macs + out_elems as f64 + act_flops(*act, out_elems),
                bytes,
                launches: 1,
                efficiency: 0.9,
            }
        }
        Add | Mul => OpCost { flops: out_elems as f64, bytes, launches: 1, efficiency: 0.12 },
        AddN { n } => OpCost {
            // One fused pass over n inputs: (n-1) adds per element.
            flops: (n.saturating_sub(1) * out_elems) as f64,
            bytes,
            launches: 1,
            efficiency: 0.12,
        },
        Relu | Sigmoid | Tanh | Identity | Scale { .. } => OpCost {
            flops: out_elems as f64,
            bytes,
            launches: if matches!(op, Identity) { 0 } else { 1 },
            efficiency: 0.12,
        },
        Gelu => OpCost { flops: 8.0 * out_elems as f64, bytes, launches: 1, efficiency: 0.12 },
        BatchNorm => OpCost { flops: 2.0 * out_elems as f64, bytes, launches: 1, efficiency: 0.12 },
        MaxPool { k, .. } | AvgPool { k, .. } => OpCost {
            flops: (k * k * out_elems) as f64,
            bytes,
            launches: 1,
            efficiency: 0.2,
        },
        Concat { .. } => OpCost { flops: 0.0, bytes, launches: 1, efficiency: 1.0 },
        // Split compiles to strided views over the producer's buffer.
        Split { .. } => OpCost { flops: 0.0, bytes: 0.0, launches: 0, efficiency: 1.0 },
        Reshape { .. } => OpCost { flops: 0.0, bytes: 0.0, launches: 0, efficiency: 1.0 },
        Transpose { .. } => OpCost { flops: 0.0, bytes, launches: 1, efficiency: 1.0 },
        Softmax { axis } => {
            let _ = axis;
            OpCost { flops: 5.0 * out_elems as f64, bytes, launches: 1, efficiency: 0.15 }
        }
        LayerNorm => OpCost { flops: 8.0 * out_elems as f64, bytes, launches: 1, efficiency: 0.15 },
        FusedAddLayerNorm => OpCost {
            // add + layernorm flops, but ONE launch and no intermediate
            // round-trip — the §4.10 transformer fusion payoff.
            flops: 9.0 * out_elems as f64,
            bytes,
            launches: 1,
            efficiency: 0.15,
        },
        Enlarge { .. } => OpCost { flops: 0.0, bytes, launches: 1, efficiency: 1.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, PadMode};

    #[test]
    fn conv_flops_formula() {
        let x = TensorDesc::f32(&[1, 16, 32, 32]);
        let w = TensorDesc::f32(&[32, 16, 3, 3]);
        let out = vec![TensorDesc::f32(&[1, 32, 32, 32])];
        let c = op_cost(
            &OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None },
            &[&x, &w],
            &out,
        );
        let expect = 2.0 * (32 * 32 * 32) as f64 * (16 * 3 * 3) as f64;
        assert_eq!(c.flops, expect);
        assert_eq!(c.launches, 1);
    }

    #[test]
    fn fused_add_ln_beats_separate() {
        let x = TensorDesc::f32(&[1, 128, 768]);
        let g = TensorDesc::f32(&[768]);
        let out = vec![x.clone()];
        let fused = op_cost(&OpKind::FusedAddLayerNorm, &[&x, &x, &g, &g], &out);
        let add = op_cost(&OpKind::Add, &[&x, &x], &out);
        let ln = op_cost(&OpKind::LayerNorm, &[&x, &g, &g], &out);
        assert!(fused.launches < add.launches + ln.launches);
        assert!(fused.bytes < add.bytes + ln.bytes);
    }

    #[test]
    fn reshape_is_free() {
        let x = TensorDesc::f32(&[4, 4]);
        let c = op_cost(&OpKind::Reshape { shape: vec![16] }, &[&x], &[TensorDesc::f32(&[16])]);
        assert_eq!(c.launches, 0);
        assert_eq!(c.bytes, 0.0);
    }

    #[test]
    fn addn_single_launch() {
        let x = TensorDesc::f32(&[64, 64]);
        let out = vec![x.clone()];
        let c = op_cost(&OpKind::AddN { n: 4 }, &[&x, &x, &x, &x], &out);
        assert_eq!(c.launches, 1);
        assert_eq!(c.flops, 3.0 * 64.0 * 64.0);
    }
}
