//! PJRT execution engine: load HLO text -> compile -> execute.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (serialized protos from jax >= 0.5 carry 64-bit ids
//! the bundled xla_extension 0.5.1 rejects), computations were lowered with
//! `return_tuple=True` so every execution returns one tuple literal that we
//! decompose host-side.
//!
//! Executables are compiled lazily on first use and cached; per-artifact
//! wall-clock accounting backs the §Perf analysis and the paper's
//! dream-vs-real step-time comparison (§4.4: 10 ms vs 850 ms).

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactSpec, Dt, Manifest};

#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub calls: u64,
    pub total_s: f64,
    pub compile_s: f64,
}

pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, std::rc::Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
    /// Device-resident parameter buffers keyed by (family, version):
    /// uploading a 10 MB theta literal per policy call dominated acting
    /// latency (EXPERIMENTS.md §Perf/L3) — parameters change only at train
    /// steps, so they stay on device between calls. The host literal is
    /// kept alongside: `BufferFromHostLiteral` transfers asynchronously and
    /// the source literal must outlive the transfer (the vendored C shim
    /// awaits readiness in `execute` for exactly this reason).
    params: RefCell<HashMap<(String, u64), std::rc::Rc<(PjRtBuffer, Literal)>>>,
}

impl Engine {
    pub fn load(manifest: Manifest) -> anyhow::Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            params: RefCell::new(HashMap::new()),
        })
    }

    /// Load with the default artifact directory.
    pub fn load_default() -> anyhow::Result<Self> {
        Self::load(Manifest::load(Manifest::default_dir())?)
    }

    fn executable(&self, name: &str) -> anyhow::Result<std::rc::Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let path = self.manifest.hlo_path(name)?;
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_s += dt;
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Eagerly compile a set of artifacts (avoids first-call latency spikes).
    pub fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact. Argument count and (for f32/i32 tensors)
    /// element counts are validated against the manifest.
    pub fn exec(&self, name: &str, args: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(
            args.len() == spec.inputs.len(),
            "{name}: got {} args, manifest says {}",
            args.len(),
            spec.inputs.len()
        );
        for (lit, arg) in args.iter().zip(&spec.inputs) {
            let got = lit.element_count();
            anyhow::ensure!(
                got == arg.n_elems(),
                "{name}.{}: literal has {} elems, expected {} {:?}",
                arg.name,
                got,
                arg.n_elems(),
                arg.shape
            );
        }
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let outs = exe
            .execute(args)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{name}: got {} outputs, manifest says {}",
            parts.len(),
            spec.outputs.len()
        );
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total_s += dt;
        Ok(parts)
    }

    /// Upload a literal to the device.
    pub fn upload(&self, lit: &Literal) -> anyhow::Result<PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
    }

    /// Device-resident copy of a parameter store's theta, cached by
    /// (family, version). Superseded versions are evicted.
    pub fn device_theta(
        &self,
        store: &super::params::ParamStore,
    ) -> anyhow::Result<std::rc::Rc<(PjRtBuffer, Literal)>> {
        let key = (store.family.clone(), store.version);
        if let Some(b) = self.params.borrow().get(&key) {
            return Ok(b.clone());
        }
        let lit = store.theta_lit()?;
        let buf = self.upload(&lit)?;
        let entry = std::rc::Rc::new((buf, lit));
        let mut cache = self.params.borrow_mut();
        cache.retain(|(fam, _), _| fam != &store.family);
        cache.insert(key, entry.clone());
        Ok(entry)
    }

    /// Execute with a device-resident leading argument (theta) and host
    /// literals for the rest — the acting hot path.
    pub fn exec_with_theta(
        &self,
        name: &str,
        theta: &(PjRtBuffer, Literal),
        rest: &[Literal],
    ) -> anyhow::Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(
            rest.len() + 1 == spec.inputs.len(),
            "{name}: got {} args, manifest says {}",
            rest.len() + 1,
            spec.inputs.len()
        );
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let mut bufs: Vec<PjRtBuffer> = Vec::with_capacity(rest.len());
        for lit in rest {
            bufs.push(self.upload(lit)?);
        }
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(rest.len() + 1);
        args.push(&theta.0);
        args.extend(bufs.iter());
        let outs = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute_b {name}: {e:?}"))?;
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total_s += dt;
        Ok(parts)
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    pub fn spec(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<Literal> {
    anyhow::ensure!(shape.iter().product::<usize>() == data.len(), "lit_f32 shape mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<Literal> {
    anyhow::ensure!(shape.iter().product::<usize>() == data.len(), "lit_i32 shape mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

pub fn zeros_like_spec(spec: &super::manifest::ArgSpec) -> anyhow::Result<Literal> {
    match spec.dtype {
        Dt::F32 => lit_f32(&vec![0.0; spec.n_elems()], &spec.shape),
        Dt::I32 => lit_i32(&vec![0; spec.n_elems()], &spec.shape),
    }
}

pub fn to_vec_f32(l: &Literal) -> anyhow::Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to f32 vec: {e:?}"))
}

pub fn scalar_f32(l: &Literal) -> anyhow::Result<f32> {
    l.get_first_element::<f32>()
        .map_err(|e| anyhow::anyhow!("literal scalar: {e:?}"))
}
