//! PJRT implementation of [`Backend`]: load HLO text -> compile -> execute.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (serialized protos from jax >= 0.5 carry 64-bit ids
//! the bundled xla_extension 0.5.1 rejects), computations were lowered with
//! `return_tuple=True` so every execution returns one tuple literal that we
//! decompose host-side.
//!
//! Executables are compiled lazily on first use and cached; per-artifact
//! wall-clock accounting backs the §Perf analysis and the paper's
//! dream-vs-real step-time comparison (§4.4: 10 ms vs 850 ms). In the
//! offline build (vendored `xla` shim) construction fails fast at
//! `PjRtClient::cpu()` — use [`HostBackend`](super::HostBackend) there.

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::interp::Tensor;

use super::backend::{validate_args, Backend, ExecStats, TensorView};
use super::manifest::Manifest;
use super::params::ParamStore;

pub struct PjrtBackend {
    client: PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, std::rc::Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
    /// Device-resident parameter buffers keyed by (family, version):
    /// uploading a 10 MB theta literal per policy call dominated acting
    /// latency (EXPERIMENTS.md §Perf/L3) — parameters change only at train
    /// steps, so they stay on device between calls. The host literal is
    /// kept alongside: `BufferFromHostLiteral` transfers asynchronously and
    /// the source literal must outlive the transfer (the vendored C shim
    /// awaits readiness in `execute` for exactly this reason).
    params: RefCell<HashMap<(String, u64), std::rc::Rc<(PjRtBuffer, Literal)>>>,
}

impl PjrtBackend {
    pub fn load(manifest: Manifest) -> anyhow::Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            params: RefCell::new(HashMap::new()),
        })
    }

    /// Load with the default artifact directory.
    pub fn load_default() -> anyhow::Result<Self> {
        Self::load(Manifest::load(Manifest::default_dir())?)
    }

    fn executable(&self, name: &str) -> anyhow::Result<std::rc::Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let path = self.manifest.hlo_path(name)?;
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.borrow_mut().entry(name.to_string()).or_default().compile_s += dt;
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    fn record(&self, name: &str, dt: f64) {
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total_s += dt;
    }

    /// Execute an artifact over raw literals (the legacy low-level path;
    /// argument counts were already validated by the caller).
    fn exec_literals(&self, name: &str, args: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let n_outputs = self.manifest.artifact(name)?.outputs.len();
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let outs = exe
            .execute(args)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == n_outputs,
            "{name}: got {} outputs, manifest says {}",
            parts.len(),
            n_outputs
        );
        self.record(name, t0.elapsed().as_secs_f64());
        Ok(parts)
    }

    /// Upload a literal to the device.
    fn upload(&self, lit: &Literal) -> anyhow::Result<PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
    }

    /// Device-resident copy of a parameter store's theta, cached by
    /// (family, version). Superseded versions are evicted.
    fn device_theta(
        &self,
        store: &ParamStore,
    ) -> anyhow::Result<std::rc::Rc<(PjRtBuffer, Literal)>> {
        let key = (store.family.clone(), store.version);
        if let Some(b) = self.params.borrow().get(&key) {
            return Ok(b.clone());
        }
        let lit = lit_f32(&store.theta, &[store.theta.len()])?;
        let buf = self.upload(&lit)?;
        let entry = std::rc::Rc::new((buf, lit));
        let mut cache = self.params.borrow_mut();
        cache.retain(|(fam, _), _| fam != &store.family);
        cache.insert(key, entry.clone());
        Ok(entry)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn exec(&self, program: &str, args: &[TensorView]) -> anyhow::Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(program)?;
        validate_args(program, spec, args)?;
        let lits = args.iter().map(view_to_literal).collect::<anyhow::Result<Vec<_>>>()?;
        let outs = self.exec_literals(program, &lits)?;
        outs.iter().map(literal_to_tensor).collect()
    }

    fn exec_with_params(
        &self,
        program: &str,
        params: &ParamStore,
        rest: &[TensorView],
    ) -> anyhow::Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(program)?;
        // Same contract enforcement as the host side: validate theta + rest
        // against the full spec before anything reaches the device.
        {
            let n = params.theta.len();
            let mut full: Vec<TensorView> = Vec::with_capacity(rest.len() + 1);
            full.push(TensorView::f32(&params.theta, &[n]));
            full.extend(rest.iter().cloned());
            validate_args(program, spec, &full)?;
        }
        let theta = self.device_theta(params)?;
        let exe = self.executable(program)?;
        let t0 = Instant::now();
        let mut bufs: Vec<PjRtBuffer> = Vec::with_capacity(rest.len());
        for view in rest {
            bufs.push(self.upload(&view_to_literal(view)?)?);
        }
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(rest.len() + 1);
        args.push(&theta.0);
        args.extend(bufs.iter());
        let outs = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute_b {program}: {e:?}"))?;
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {program}: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {program}: {e:?}"))?;
        self.record(program, t0.elapsed().as_secs_f64());
        parts.iter().map(literal_to_tensor).collect()
    }

    fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }
}

// ---------------------------------------------------------------------------
// Literal conversion helpers
// ---------------------------------------------------------------------------

fn view_to_literal(view: &TensorView) -> anyhow::Result<Literal> {
    match view {
        TensorView::F32 { data, shape } => lit_f32(data, shape),
        TensorView::I32 { data, shape } => lit_i32(data, shape),
        TensorView::ScalarF32(v) => Ok(Literal::scalar(*v)),
        TensorView::ScalarI32(v) => Ok(Literal::scalar(*v)),
    }
}

/// XLA result shapes live in the HLO program, not the literal API surface
/// we use — outputs come back flat and callers index by element.
fn literal_to_tensor(l: &Literal) -> anyhow::Result<Tensor> {
    let data = l
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to f32 vec: {e:?}"))?;
    let n = data.len();
    Tensor::from_vec(&[n], data)
}

fn lit_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<Literal> {
    anyhow::ensure!(shape.iter().product::<usize>() == data.len(), "lit_f32 shape mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
}

fn lit_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<Literal> {
    anyhow::ensure!(shape.iter().product::<usize>() == data.len(), "lit_i32 shape mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
}
