//! `artifacts/manifest.json` — the L2 -> L3 contract.
//!
//! aot.py writes it; this module is the only Rust code that knows its
//! schema. All hyperparameters (MAX_NODES, N_XFERS, ...) reach the Rust
//! side exclusively through here — DESIGN.md forbids hardcoding them.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::parse;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dt {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dt,
}

impl ArgSpec {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub hp: HashMap<String, f64>,
    pub param_sizes: HashMap<String, usize>,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(dir: P) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {} (run `make artifacts`): {e}", path.display()))?;
        let j = parse(&text)?;

        let mut hp = HashMap::new();
        for (k, v) in j.get("hp")?.as_obj()? {
            hp.insert(k.clone(), v.as_f64()?);
        }
        let mut param_sizes = HashMap::new();
        for (k, v) in j.get("param_sizes")?.as_obj()? {
            param_sizes.insert(k.clone(), v.as_usize()?);
        }
        let mut artifacts = HashMap::new();
        for (name, a) in j.get("artifacts")?.as_obj()? {
            let inputs = a
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(ArgSpec {
                        name: i.get("name")?.as_str()?.to_string(),
                        shape: i.get("shape")?.usize_array()?,
                        dtype: match i.get("dtype")?.as_str()? {
                            "float32" => Dt::F32,
                            "int32" => Dt::I32,
                            d => anyhow::bail!("unsupported dtype {}", d),
                        },
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|o| Ok(o.as_str()?.to_string()))
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec { file: a.get("file")?.as_str()?.to_string(), inputs, outputs },
            );
        }
        Ok(Self { dir, hp, param_sizes, artifacts })
    }

    pub fn hp_usize(&self, key: &str) -> anyhow::Result<usize> {
        let v = *self
            .hp
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("hp '{}' missing from manifest", key))?;
        Ok(v as usize)
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{}' not in manifest", name))
    }

    pub fn hlo_path(&self, name: &str) -> anyhow::Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Standard artifact directory: $RLFLOW_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("RLFLOW_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert!(m.hp_usize("MAX_NODES").unwrap() >= 64);
        assert!(m.hp_usize("N_XFERS").unwrap() >= 32);
        assert_eq!(m.hp_usize("MAX_LOCS").unwrap(), 200);
        let spec = m.artifact("wm_step_1").unwrap();
        assert_eq!(spec.outputs.len(), 8);
        assert!(m.hlo_path("wm_step_1").unwrap().exists());
    }

    #[test]
    fn missing_artifact_errors() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert!(m.artifact("nope").is_err());
    }
}
