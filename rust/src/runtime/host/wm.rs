//! Host MDN-RNN world model: an LSTM over `[z, action-embedding, location]`
//! with five heads — per-dimension K-component mixture density (log_pi, mu,
//! log_sig), reward, next-state xfer-mask logits and a done logit. Mirrors
//! the `wm_*` artifact contract: `wm_init`, `wm_step_1`, `wm_step_b`,
//! `wm_train`.
//!
//! Training is teacher-forced with per-step truncated backpropagation (the
//! incoming `h, c` of each step are treated as constants): every parameter
//! tensor — input/recurrent weights, action embeddings and all heads —
//! still receives gradient at every step, while keeping the backward pass
//! a single LSTM-cell rule.

use super::nn::{
    acc_rows, acc_xt_dy, adam_step, dy_wt, linear, log_sum_exp, sigmoid, softmax_inplace,
    softplus, ParamLayout,
};

const LN_2PI: f32 = 1.837_877_1;

pub struct WmNet {
    pub zdim: usize,
    pub rdim: usize,
    pub k: usize,
    pub x1: usize,
    pub locs: usize,
    /// Action-embedding width; LSTM input is `zdim + de + 1`.
    pub de: usize,
    pub layout: ParamLayout,
}

/// One batched step's outputs (all row-major over the batch).
pub struct WmHeads {
    pub log_pi: Vec<f32>,      // [b, Z*K], dimension-major (d*K + k)
    pub mu: Vec<f32>,          // [b, Z*K]
    pub log_sig: Vec<f32>,     // [b, Z*K]
    pub reward: Vec<f32>,      // [b]
    pub mask_logits: Vec<f32>, // [b, X1]
    pub done_logits: Vec<f32>, // [b]
    pub h1: Vec<f32>,          // [b, R]
    pub c1: Vec<f32>,          // [b, R]
}

pub struct WmStepLosses {
    pub total: f32,
    pub nll: f32,
    pub reward_mse: f32,
    pub mask_bce: f32,
    pub done_bce: f32,
}

/// Forward activations of one batched LSTM step, kept for backward.
struct CellFwd {
    x: Vec<f32>,       // [b, I]
    h_prev: Vec<f32>,  // [b, R]
    c_prev: Vec<f32>,  // [b, R]
    gi: Vec<f32>,      // [b, R] sigmoid(i)
    gf: Vec<f32>,      // [b, R] sigmoid(f)
    gg: Vec<f32>,      // [b, R] tanh(g)
    go: Vec<f32>,      // [b, R] sigmoid(o)
    tanh_c1: Vec<f32>, // [b, R]
    sig_tanh: Vec<f32>, // [b, Z*K] tanh of the raw log_sig head
    heads: WmHeads,
    ax: Vec<usize>,    // [b] clamped xfer slots (embedding rows)
}

impl WmNet {
    pub fn new(zdim: usize, rdim: usize, k: usize, x1: usize, locs: usize, de: usize) -> Self {
        let i_dim = zdim + de + 1;
        let zk = zdim * k;
        let mut layout = ParamLayout::new();
        layout.add("emb", x1 * de, x1);
        layout.add("wxh", i_dim * 4 * rdim, i_dim);
        layout.add("whh", rdim * 4 * rdim, rdim);
        layout.add("bh", 4 * rdim, 0);
        layout.add("wpi", rdim * zk, rdim);
        layout.add("bpi", zk, 0);
        layout.add("wmu", rdim * zk, rdim);
        layout.add("bmu", zk, 0);
        layout.add("wsig", rdim * zk, rdim);
        layout.add("bsig", zk, 0);
        layout.add("wr", rdim, rdim);
        layout.add("br", 1, 0);
        layout.add("wmk", rdim * x1, rdim);
        layout.add("bmk", x1, 0);
        layout.add("wd", rdim, rdim);
        layout.add("bd", 1, 0);
        Self { zdim, rdim, k, x1, locs, de, layout }
    }

    pub fn n_params(&self) -> usize {
        self.layout.total()
    }

    pub fn init(&self, seed: i32) -> Vec<f32> {
        let mut theta =
            self.layout.init(0x776D ^ (seed as u64).wrapping_mul(0x9E3779B97F4A7C15), |_| 0.0);
        // Forget-gate bias starts at 1 (standard LSTM trick).
        let r = self.rdim;
        self.layout.view_mut(&mut theta, "bh")[r..2 * r].fill(1.0);
        theta
    }

    fn i_dim(&self) -> usize {
        self.zdim + self.de + 1
    }

    /// One batched forward step.
    fn cell_forward(
        &self,
        theta: &[f32],
        z: &[f32],
        a: &[i32],
        h: &[f32],
        c: &[f32],
        b: usize,
    ) -> CellFwd {
        let (zd, r, i_dim, zk) = (self.zdim, self.rdim, self.i_dim(), self.zdim * self.k);
        // Assemble the LSTM input rows.
        let emb = self.layout.view(theta, "emb");
        let mut x = vec![0.0f32; b * i_dim];
        let mut ax = vec![0usize; b];
        for row in 0..b {
            let slot = (a[row * 2].max(0) as usize).min(self.x1 - 1);
            let loc = a[row * 2 + 1].max(0) as f32 / self.locs.max(1) as f32;
            ax[row] = slot;
            let xr = &mut x[row * i_dim..(row + 1) * i_dim];
            xr[..zd].copy_from_slice(&z[row * zd..(row + 1) * zd]);
            xr[zd..zd + self.de].copy_from_slice(&emb[slot * self.de..(slot + 1) * self.de]);
            xr[zd + self.de] = loc;
        }

        let mut gates = {
            let wxh = self.layout.view(theta, "wxh");
            linear(&x, wxh, self.layout.view(theta, "bh"), b, i_dim, 4 * r)
        };
        let zero_bias = vec![0.0f32; 4 * r];
        let rec = linear(h, self.layout.view(theta, "whh"), &zero_bias, b, r, 4 * r);
        for (g, rc) in gates.iter_mut().zip(&rec) {
            *g += rc;
        }

        let mut gi = vec![0.0f32; b * r];
        let mut gf = vec![0.0f32; b * r];
        let mut gg = vec![0.0f32; b * r];
        let mut go = vec![0.0f32; b * r];
        let mut c1 = vec![0.0f32; b * r];
        let mut tanh_c1 = vec![0.0f32; b * r];
        let mut h1 = vec![0.0f32; b * r];
        for row in 0..b {
            for j in 0..r {
                let base = row * 4 * r;
                let i_v = sigmoid(gates[base + j]);
                let f_v = sigmoid(gates[base + r + j]);
                let g_v = gates[base + 2 * r + j].tanh();
                let o_v = sigmoid(gates[base + 3 * r + j]);
                let c_v = f_v * c[row * r + j] + i_v * g_v;
                let tc = c_v.tanh();
                gi[row * r + j] = i_v;
                gf[row * r + j] = f_v;
                gg[row * r + j] = g_v;
                go[row * r + j] = o_v;
                c1[row * r + j] = c_v;
                tanh_c1[row * r + j] = tc;
                h1[row * r + j] = o_v * tc;
            }
        }

        let log_pi =
            linear(&h1, self.layout.view(theta, "wpi"), self.layout.view(theta, "bpi"), b, r, zk);
        let mu =
            linear(&h1, self.layout.view(theta, "wmu"), self.layout.view(theta, "bmu"), b, r, zk);
        let sig_raw =
            linear(&h1, self.layout.view(theta, "wsig"), self.layout.view(theta, "bsig"), b, r, zk);
        let sig_tanh: Vec<f32> = sig_raw.iter().map(|v| v.tanh()).collect();
        // log_sig in [-4, 2]: bounded yet smooth, so gradients never die.
        let log_sig: Vec<f32> = sig_tanh.iter().map(|t| 3.0 * t - 1.0).collect();
        let reward =
            linear(&h1, self.layout.view(theta, "wr"), self.layout.view(theta, "br"), b, r, 1);
        let mask_logits = {
            let wmk = self.layout.view(theta, "wmk");
            linear(&h1, wmk, self.layout.view(theta, "bmk"), b, r, self.x1)
        };
        let done_logits =
            linear(&h1, self.layout.view(theta, "wd"), self.layout.view(theta, "bd"), b, r, 1);

        CellFwd {
            x,
            h_prev: h.to_vec(),
            c_prev: c.to_vec(),
            gi,
            gf,
            gg,
            go,
            tanh_c1,
            sig_tanh,
            heads: WmHeads { log_pi, mu, log_sig, reward, mask_logits, done_logits, h1, c1 },
            ax,
        }
    }

    /// The `wm_step_*` forward.
    pub fn step(
        &self,
        theta: &[f32],
        z: &[f32],
        a: &[i32],
        h: &[f32],
        c: &[f32],
        b: usize,
    ) -> WmHeads {
        self.cell_forward(theta, z, a, h, c, b).heads
    }

    /// One teacher-forced Adam step over `[b, t]` sequence batches
    /// (`wm_train`). Returns the component losses.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        theta: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        t_adam: f32,
        z: &[f32],
        a: &[i32],
        z_next: &[f32],
        r_target: &[f32],
        xm_target: &[f32],
        done_target: &[f32],
        valid: &[f32],
        b: usize,
        t_len: usize,
        lr: f32,
    ) -> WmStepLosses {
        let (zd, r, i_dim, k, x1) = (self.zdim, self.rdim, self.i_dim(), self.k, self.x1);
        let zk = zd * k;
        let denom = valid.iter().sum::<f32>().max(1.0);

        let mut grad = vec![0.0f32; theta.len()];
        let mut demb = vec![0.0f32; x1 * self.de];
        let mut dwxh = vec![0.0f32; i_dim * 4 * r];
        let mut dwhh = vec![0.0f32; r * 4 * r];
        let mut dbh = vec![0.0f32; 4 * r];
        let mut dwpi = vec![0.0f32; r * zk];
        let mut dbpi = vec![0.0f32; zk];
        let mut dwmu = vec![0.0f32; r * zk];
        let mut dbmu = vec![0.0f32; zk];
        let mut dwsig = vec![0.0f32; r * zk];
        let mut dbsig = vec![0.0f32; zk];
        let mut dwr = vec![0.0f32; r];
        let mut dbr = vec![0.0f32; 1];
        let mut dwmk = vec![0.0f32; r * x1];
        let mut dbmk = vec![0.0f32; x1];
        let mut dwd = vec![0.0f32; r];
        let mut dbd = vec![0.0f32; 1];

        let (mut nll, mut r_mse, mut m_bce, mut d_bce) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let mut h = vec![0.0f32; b * r];
        let mut c = vec![0.0f32; b * r];

        for ti in 0..t_len {
            // Gather the time-slice into step-batch layout.
            let mut zs = vec![0.0f32; b * zd];
            let mut as_ = vec![0i32; b * 2];
            for row in 0..b {
                let s = (row * t_len + ti) * zd;
                zs[row * zd..(row + 1) * zd].copy_from_slice(&z[s..s + zd]);
                as_[row * 2] = a[(row * t_len + ti) * 2];
                as_[row * 2 + 1] = a[(row * t_len + ti) * 2 + 1];
            }
            let fwd = self.cell_forward(theta, &zs, &as_, &h, &c, b);

            // ---- losses + head gradients ---------------------------------
            let mut dlp = vec![0.0f32; b * zk];
            let mut dmu = vec![0.0f32; b * zk];
            let mut dls = vec![0.0f32; b * zk];
            let mut drh = vec![0.0f32; b];
            let mut dmk = vec![0.0f32; b * x1];
            let mut ddn = vec![0.0f32; b];
            for row in 0..b {
                let wv = valid[row * t_len + ti] / denom;
                if wv == 0.0 {
                    continue;
                }
                // MDN NLL over each latent dimension.
                let wdim = wv / zd as f32;
                for d in 0..zd {
                    let base = row * zk + d * k;
                    let raw = &fwd.heads.log_pi[base..base + k];
                    let lse_pi = log_sum_exp(raw);
                    let x_t = z_next[(row * t_len + ti) * zd + d];
                    let mut lp = vec![0.0f32; k];
                    for kk in 0..k {
                        let lsg = fwd.heads.log_sig[base + kk];
                        let sg = lsg.exp();
                        let dev = (x_t - fwd.heads.mu[base + kk]) / sg;
                        lp[kk] = (raw[kk] - lse_pi) - lsg - 0.5 * LN_2PI - 0.5 * dev * dev;
                    }
                    let nll_d = -log_sum_exp(&lp);
                    nll += nll_d * wdim;
                    let mut gamma = lp;
                    softmax_inplace(&mut gamma);
                    for kk in 0..k {
                        let pi_k = (raw[kk] - lse_pi).exp();
                        let lsg = fwd.heads.log_sig[base + kk];
                        let sg = lsg.exp();
                        let dev = (x_t - fwd.heads.mu[base + kk]) / sg;
                        dlp[base + kk] = (pi_k - gamma[kk]) * wdim;
                        dmu[base + kk] =
                            gamma[kk] * (fwd.heads.mu[base + kk] - x_t) / (sg * sg) * wdim;
                        dls[base + kk] = gamma[kk] * (1.0 - dev * dev) * wdim;
                    }
                }
                // Reward regression.
                let dr = fwd.heads.reward[row] - r_target[row * t_len + ti];
                r_mse += dr * dr * wv;
                drh[row] = 2.0 * dr * wv;
                // Next-state mask BCE.
                let wmask = wv / x1 as f32;
                for xi in 0..x1 {
                    let logit = fwd.heads.mask_logits[row * x1 + xi];
                    let target = xm_target[(row * t_len + ti) * x1 + xi];
                    m_bce += (softplus(logit) - target * logit) * wmask;
                    dmk[row * x1 + xi] = (sigmoid(logit) - target) * wmask;
                }
                // Done BCE.
                let dl = fwd.heads.done_logits[row];
                let dt = done_target[row * t_len + ti];
                d_bce += (softplus(dl) - dt * dl) * wv;
                ddn[row] = (sigmoid(dl) - dt) * wv;
            }

            // ---- backward: heads -> h1 -> one LSTM cell -------------------
            // log_sig = 3 * tanh(sig_raw) - 1.
            let mut dsig_raw = dls;
            for (d, th) in dsig_raw.iter_mut().zip(&fwd.sig_tanh) {
                *d *= 3.0 * (1.0 - th * th);
            }
            let h1 = &fwd.heads.h1;
            acc_xt_dy(h1, &dlp, b, r, zk, &mut dwpi);
            acc_rows(&dlp, b, zk, &mut dbpi);
            acc_xt_dy(h1, &dmu, b, r, zk, &mut dwmu);
            acc_rows(&dmu, b, zk, &mut dbmu);
            acc_xt_dy(h1, &dsig_raw, b, r, zk, &mut dwsig);
            acc_rows(&dsig_raw, b, zk, &mut dbsig);
            acc_xt_dy(h1, &drh, b, r, 1, &mut dwr);
            acc_rows(&drh, b, 1, &mut dbr);
            acc_xt_dy(h1, &dmk, b, r, x1, &mut dwmk);
            acc_rows(&dmk, b, x1, &mut dbmk);
            acc_xt_dy(h1, &ddn, b, r, 1, &mut dwd);
            acc_rows(&ddn, b, 1, &mut dbd);

            let mut dh1 = dy_wt(&dlp, self.layout.view(theta, "wpi"), b, zk, r);
            let wmu = self.layout.view(theta, "wmu");
            for (dst, add) in dh1.iter_mut().zip(dy_wt(&dmu, wmu, b, zk, r)) {
                *dst += add;
            }
            let wsig = self.layout.view(theta, "wsig");
            for (dst, add) in dh1.iter_mut().zip(dy_wt(&dsig_raw, wsig, b, zk, r)) {
                *dst += add;
            }
            let wr = self.layout.view(theta, "wr");
            for (dst, add) in dh1.iter_mut().zip(dy_wt(&drh, wr, b, 1, r)) {
                *dst += add;
            }
            let wmk = self.layout.view(theta, "wmk");
            for (dst, add) in dh1.iter_mut().zip(dy_wt(&dmk, wmk, b, x1, r)) {
                *dst += add;
            }
            let wd = self.layout.view(theta, "wd");
            for (dst, add) in dh1.iter_mut().zip(dy_wt(&ddn, wd, b, 1, r)) {
                *dst += add;
            }

            let mut dgates = vec![0.0f32; b * 4 * r];
            for row in 0..b {
                for j in 0..r {
                    let idx = row * r + j;
                    let o_v = fwd.go[idx];
                    let tc = fwd.tanh_c1[idx];
                    let dh = dh1[idx];
                    let do_pre = dh * tc * o_v * (1.0 - o_v);
                    let dc1 = dh * o_v * (1.0 - tc * tc);
                    let i_v = fwd.gi[idx];
                    let f_v = fwd.gf[idx];
                    let g_v = fwd.gg[idx];
                    let di_pre = dc1 * g_v * i_v * (1.0 - i_v);
                    let df_pre = dc1 * fwd.c_prev[idx] * f_v * (1.0 - f_v);
                    let dg_pre = dc1 * i_v * (1.0 - g_v * g_v);
                    let base = row * 4 * r;
                    dgates[base + j] = di_pre;
                    dgates[base + r + j] = df_pre;
                    dgates[base + 2 * r + j] = dg_pre;
                    dgates[base + 3 * r + j] = do_pre;
                }
            }
            acc_xt_dy(&fwd.x, &dgates, b, i_dim, 4 * r, &mut dwxh);
            acc_xt_dy(&fwd.h_prev, &dgates, b, r, 4 * r, &mut dwhh);
            acc_rows(&dgates, b, 4 * r, &mut dbh);
            let dx = dy_wt(&dgates, self.layout.view(theta, "wxh"), b, 4 * r, i_dim);
            for row in 0..b {
                let slot = fwd.ax[row];
                for e in 0..self.de {
                    demb[slot * self.de + e] += dx[row * i_dim + zd + e];
                }
            }

            // Teacher forcing: advance the (detached) recurrent state.
            h = fwd.heads.h1;
            c = fwd.heads.c1;
        }

        self.layout.scatter(&mut grad, "emb", &demb);
        self.layout.scatter(&mut grad, "wxh", &dwxh);
        self.layout.scatter(&mut grad, "whh", &dwhh);
        self.layout.scatter(&mut grad, "bh", &dbh);
        self.layout.scatter(&mut grad, "wpi", &dwpi);
        self.layout.scatter(&mut grad, "bpi", &dbpi);
        self.layout.scatter(&mut grad, "wmu", &dwmu);
        self.layout.scatter(&mut grad, "bmu", &dbmu);
        self.layout.scatter(&mut grad, "wsig", &dwsig);
        self.layout.scatter(&mut grad, "bsig", &dbsig);
        self.layout.scatter(&mut grad, "wr", &dwr);
        self.layout.scatter(&mut grad, "br", &dbr);
        self.layout.scatter(&mut grad, "wmk", &dwmk);
        self.layout.scatter(&mut grad, "bmk", &dbmk);
        self.layout.scatter(&mut grad, "wd", &dwd);
        self.layout.scatter(&mut grad, "bd", &dbd);
        adam_step(theta, m, v, t_adam, &grad, lr);

        WmStepLosses {
            total: nll + r_mse + m_bce + d_bce,
            nll,
            reward_mse: r_mse,
            mask_bce: m_bce,
            done_bce: d_bce,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn net() -> WmNet {
        WmNet::new(4, 6, 2, 5, 10, 3)
    }

    #[test]
    fn step_shapes_and_evolution() {
        let n = net();
        let theta = n.init(1);
        let b = 2;
        let z = vec![0.3f32; b * 4];
        let a = vec![1i32, 2, 4, 0];
        let h = vec![0.0f32; b * 6];
        let c = vec![0.0f32; b * 6];
        let out = n.step(&theta, &z, &a, &h, &c, b);
        assert_eq!(out.log_pi.len(), b * 4 * 2);
        assert_eq!(out.mask_logits.len(), b * 5);
        assert_eq!(out.h1.len(), b * 6);
        assert!(out.h1.iter().any(|v| v.abs() > 0.0), "hidden state did not evolve");
        assert!(out.log_sig.iter().all(|v| (-4.0..=2.0).contains(v)));
        // Deterministic.
        let again = n.step(&theta, &z, &a, &h, &c, b);
        assert_eq!(out.h1, again.h1);
        assert_eq!(out.log_pi, again.log_pi);
    }

    #[test]
    fn train_decreases_loss_on_synthetic_dynamics() {
        // z_next = 0.9 z, constant small reward, all-valid masks.
        let n = net();
        let mut theta = n.init(3);
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let (b, t) = (3, 4);
        let mut rng = Rng::new(9);
        let z: Vec<f32> = (0..b * t * 4).map(|_| rng.normal() * 0.5).collect();
        let z_next: Vec<f32> = z.iter().map(|x| 0.9 * x).collect();
        let a: Vec<i32> = (0..b * t * 2).map(|i| (i % 5) as i32).collect();
        let r: Vec<f32> = vec![0.05; b * t];
        let xm = vec![1.0f32; b * t * 5];
        let done = vec![0.0f32; b * t];
        let valid = vec![1.0f32; b * t];
        let first = n
            .train_step(
                &mut theta, &mut m, &mut v, 1.0, &z, &a, &z_next, &r, &xm, &done, &valid, b, t,
                1e-2,
            )
            .total;
        let mut last = first;
        for step in 2..=60 {
            last = n
                .train_step(
                    &mut theta, &mut m, &mut v, step as f32, &z, &a, &z_next, &r, &xm, &done,
                    &valid, b, t, 1e-2,
                )
                .total;
        }
        assert!(last.is_finite() && last < first, "wm loss {first} -> {last}");
        assert!(theta.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn invalid_steps_carry_no_gradient() {
        let n = net();
        let theta0 = n.init(5);
        let mut theta = theta0.clone();
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let (b, t) = (2, 3);
        let losses = n.train_step(
            &mut theta,
            &mut m,
            &mut v,
            1.0,
            &vec![0.5; b * t * 4],
            &vec![0i32; b * t * 2],
            &vec![0.4; b * t * 4],
            &vec![0.1; b * t],
            &vec![1.0; b * t * 5],
            &vec![0.0; b * t],
            &vec![0.0; b * t], // nothing valid
            b,
            t,
            1e-2,
        );
        assert_eq!(losses.total, 0.0);
        assert_eq!(theta, theta0, "all-invalid batch must be a no-op");
    }
}
