//! Host MDN-RNN world model: an LSTM over `[z, action-embedding, location]`
//! with five heads — per-dimension K-component mixture density (log_pi, mu,
//! log_sig), reward, next-state xfer-mask logits and a done logit. Mirrors
//! the `wm_*` artifact contract: `wm_init`, `wm_step_1`, `wm_step_b`,
//! `wm_train`.
//!
//! Training is teacher-forced with per-step truncated backpropagation (the
//! incoming `h, c` of each step are treated as constants): every parameter
//! tensor — input/recurrent weights, action embeddings and all heads —
//! still receives gradient at every step, while keeping the backward pass
//! a single LSTM-cell rule.
//!
//! Dense math runs through [`super::kernels`]; every intermediate — gate
//! planes, head activations during training, per-step gather buffers —
//! cycles through the caller's [`Workspace`], so a steady-state `wm_train`
//! or `wm_step` call allocates nothing beyond its program outputs.

use super::kernels::{
    acc_xt_dy, dy_wt_acc, dy_wt_into, linear_into, v2_accumulate_grads, Act, KernelCfg,
    ReductionOrder, Workspace,
};
use super::nn::{acc_rows, adam_step, log_sum_exp, sigmoid, softmax_inplace, softplus, ParamLayout};

const LN_2PI: f32 = 1.837_877_1;

pub struct WmNet {
    pub zdim: usize,
    pub rdim: usize,
    pub k: usize,
    pub x1: usize,
    pub locs: usize,
    /// Action-embedding width; LSTM input is `zdim + de + 1`.
    pub de: usize,
    pub layout: ParamLayout,
}

/// One batched step's outputs (all row-major over the batch).
pub struct WmHeads {
    pub log_pi: Vec<f32>,      // [b, Z*K], dimension-major (d*K + k)
    pub mu: Vec<f32>,          // [b, Z*K]
    pub log_sig: Vec<f32>,     // [b, Z*K]
    pub reward: Vec<f32>,      // [b]
    pub mask_logits: Vec<f32>, // [b, X1]
    pub done_logits: Vec<f32>, // [b]
    pub h1: Vec<f32>,          // [b, R]
    pub c1: Vec<f32>,          // [b, R]
}

pub struct WmStepLosses {
    pub total: f32,
    pub nll: f32,
    pub reward_mse: f32,
    pub mask_bce: f32,
    pub done_bce: f32,
}

/// Forward activations of one batched LSTM step, kept for backward.
struct CellFwd {
    x: Vec<f32>,        // [b, I]
    h_prev: Vec<f32>,   // [b, R]
    c_prev: Vec<f32>,   // [b, R]
    gi: Vec<f32>,       // [b, R] sigmoid(i)
    gf: Vec<f32>,       // [b, R] sigmoid(f)
    gg: Vec<f32>,       // [b, R] tanh(g)
    go: Vec<f32>,       // [b, R] sigmoid(o)
    tanh_c1: Vec<f32>,  // [b, R]
    sig_tanh: Vec<f32>, // [b, Z*K] tanh of the raw log_sig head
    heads: WmHeads,
    ax: Vec<usize>,     // [b] clamped xfer slots (embedding rows)
}

impl CellFwd {
    /// Return every non-head scratch buffer to the arena.
    fn recycle_scratch(self, ws: &mut Workspace) -> WmHeads {
        ws.put_all([
            self.x,
            self.h_prev,
            self.c_prev,
            self.gi,
            self.gf,
            self.gg,
            self.go,
            self.tanh_c1,
            self.sig_tanh,
        ]);
        ws.put_idx(self.ax);
        self.heads
    }
}

impl WmHeads {
    /// Return every buffer except the recurrent state to the arena; hands
    /// `(h1, c1)` back for the teacher-forced advance.
    fn recycle_except_state(self, ws: &mut Workspace) -> (Vec<f32>, Vec<f32>) {
        ws.put_all([
            self.log_pi,
            self.mu,
            self.log_sig,
            self.reward,
            self.mask_logits,
            self.done_logits,
        ]);
        (self.h1, self.c1)
    }
}

impl WmNet {
    pub fn new(zdim: usize, rdim: usize, k: usize, x1: usize, locs: usize, de: usize) -> Self {
        let i_dim = zdim + de + 1;
        let zk = zdim * k;
        let mut layout = ParamLayout::new();
        layout.add("emb", x1 * de, x1);
        layout.add("wxh", i_dim * 4 * rdim, i_dim);
        layout.add("whh", rdim * 4 * rdim, rdim);
        layout.add("bh", 4 * rdim, 0);
        layout.add("wpi", rdim * zk, rdim);
        layout.add("bpi", zk, 0);
        layout.add("wmu", rdim * zk, rdim);
        layout.add("bmu", zk, 0);
        layout.add("wsig", rdim * zk, rdim);
        layout.add("bsig", zk, 0);
        layout.add("wr", rdim, rdim);
        layout.add("br", 1, 0);
        layout.add("wmk", rdim * x1, rdim);
        layout.add("bmk", x1, 0);
        layout.add("wd", rdim, rdim);
        layout.add("bd", 1, 0);
        Self { zdim, rdim, k, x1, locs, de, layout }
    }

    pub fn n_params(&self) -> usize {
        self.layout.total()
    }

    pub fn init(&self, seed: i32) -> Vec<f32> {
        let mut theta =
            self.layout.init(0x776D ^ (seed as u64).wrapping_mul(0x9E3779B97F4A7C15), |_| 0.0);
        // Forget-gate bias starts at 1 (standard LSTM trick).
        let r = self.rdim;
        self.layout.view_mut(&mut theta, "bh")[r..2 * r].fill(1.0);
        theta
    }

    fn i_dim(&self) -> usize {
        self.zdim + self.de + 1
    }

    /// One batched forward step. With `scratch_heads` the head buffers come
    /// from the workspace (the training path recycles them per timestep);
    /// without, they are plain allocations that leave as program outputs.
    fn cell_forward(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        a: &[i32],
        h: &[f32],
        c: &[f32],
        b: usize,
        scratch_heads: bool,
    ) -> CellFwd {
        let (zd, r, i_dim, zk) = (self.zdim, self.rdim, self.i_dim(), self.zdim * self.k);
        let out_buf = |ws: &mut Workspace, len: usize| -> Vec<f32> {
            if scratch_heads {
                ws.take(len)
            } else {
                vec![0.0; len]
            }
        };
        // Assemble the LSTM input rows.
        let emb = self.layout.view(theta, "emb");
        let mut x = ws.take(b * i_dim);
        let mut ax = ws.take_idx();
        for row in 0..b {
            let slot = (a[row * 2].max(0) as usize).min(self.x1 - 1);
            let loc = a[row * 2 + 1].max(0) as f32 / self.locs.max(1) as f32;
            ax.push(slot);
            let xr = &mut x[row * i_dim..(row + 1) * i_dim];
            xr[..zd].copy_from_slice(&z[row * zd..(row + 1) * zd]);
            xr[zd..zd + self.de].copy_from_slice(&emb[slot * self.de..(slot + 1) * self.de]);
            xr[zd + self.de] = loc;
        }

        let mut gates = ws.take(b * 4 * r);
        linear_into(
            kc,
            &x,
            self.layout.view(theta, "wxh"),
            Some(self.layout.view(theta, "bh")),
            b,
            i_dim,
            4 * r,
            Act::None,
            &mut gates,
        );
        let mut rec = ws.take(b * 4 * r);
        linear_into(kc, h, self.layout.view(theta, "whh"), None, b, r, 4 * r, Act::None, &mut rec);
        for (g, rc) in gates.iter_mut().zip(&rec) {
            *g += rc;
        }
        ws.put(rec);

        let mut gi = ws.take(b * r);
        let mut gf = ws.take(b * r);
        let mut gg = ws.take(b * r);
        let mut go = ws.take(b * r);
        let mut c1 = out_buf(ws, b * r);
        let mut tanh_c1 = ws.take(b * r);
        let mut h1 = out_buf(ws, b * r);
        for row in 0..b {
            for j in 0..r {
                let base = row * 4 * r;
                let i_v = sigmoid(gates[base + j]);
                let f_v = sigmoid(gates[base + r + j]);
                let g_v = gates[base + 2 * r + j].tanh();
                let o_v = sigmoid(gates[base + 3 * r + j]);
                let c_v = f_v * c[row * r + j] + i_v * g_v;
                let tc = c_v.tanh();
                gi[row * r + j] = i_v;
                gf[row * r + j] = f_v;
                gg[row * r + j] = g_v;
                go[row * r + j] = o_v;
                c1[row * r + j] = c_v;
                tanh_c1[row * r + j] = tc;
                h1[row * r + j] = o_v * tc;
            }
        }
        ws.put(gates);

        let mut log_pi = out_buf(ws, b * zk);
        linear_into(
            kc,
            &h1,
            self.layout.view(theta, "wpi"),
            Some(self.layout.view(theta, "bpi")),
            b,
            r,
            zk,
            Act::None,
            &mut log_pi,
        );
        let mut mu = out_buf(ws, b * zk);
        linear_into(
            kc,
            &h1,
            self.layout.view(theta, "wmu"),
            Some(self.layout.view(theta, "bmu")),
            b,
            r,
            zk,
            Act::None,
            &mut mu,
        );
        // sig_raw -> tanh -> affine: log_sig in [-4, 2], bounded yet
        // smooth, so gradients never die.
        let mut sig_tanh = ws.take(b * zk);
        linear_into(
            kc,
            &h1,
            self.layout.view(theta, "wsig"),
            Some(self.layout.view(theta, "bsig")),
            b,
            r,
            zk,
            Act::Tanh,
            &mut sig_tanh,
        );
        let mut log_sig = out_buf(ws, b * zk);
        for (ls, t) in log_sig.iter_mut().zip(&sig_tanh) {
            *ls = 3.0 * t - 1.0;
        }
        let mut reward = out_buf(ws, b);
        linear_into(
            kc,
            &h1,
            self.layout.view(theta, "wr"),
            Some(self.layout.view(theta, "br")),
            b,
            r,
            1,
            Act::None,
            &mut reward,
        );
        let mut mask_logits = out_buf(ws, b * self.x1);
        linear_into(
            kc,
            &h1,
            self.layout.view(theta, "wmk"),
            Some(self.layout.view(theta, "bmk")),
            b,
            r,
            self.x1,
            Act::None,
            &mut mask_logits,
        );
        let mut done_logits = out_buf(ws, b);
        linear_into(
            kc,
            &h1,
            self.layout.view(theta, "wd"),
            Some(self.layout.view(theta, "bd")),
            b,
            r,
            1,
            Act::None,
            &mut done_logits,
        );

        CellFwd {
            x,
            h_prev: ws.take_copy(h),
            c_prev: ws.take_copy(c),
            gi,
            gf,
            gg,
            go,
            tanh_c1,
            sig_tanh,
            heads: WmHeads { log_pi, mu, log_sig, reward, mask_logits, done_logits, h1, c1 },
            ax,
        }
    }

    /// The `wm_step_*` forward. Head buffers are plain allocations (they
    /// leave as program outputs); all scratch cycles through `ws`.
    pub fn step(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        a: &[i32],
        h: &[f32],
        c: &[f32],
        b: usize,
    ) -> WmHeads {
        let fwd = self.cell_forward(ws, kc, theta, z, a, h, c, b, false);
        fwd.recycle_scratch(ws)
    }

    /// One teacher-forced Adam step over `[b, t]` sequence batches
    /// (`wm_train`). Returns the component losses.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        t_adam: f32,
        z: &[f32],
        a: &[i32],
        z_next: &[f32],
        r_target: &[f32],
        xm_target: &[f32],
        done_target: &[f32],
        valid: &[f32],
        b: usize,
        t_len: usize,
        lr: f32,
    ) -> WmStepLosses {
        let (r, i_dim, x1) = (self.rdim, self.i_dim(), self.x1);
        let zk = self.zdim * self.k;
        // The valid-step normaliser is a batch-level statistic: it is computed
        // over the whole `[b, t]` batch before any per-sample-group work so
        // every group sees the same value. Part of both orders' contracts.
        let denom = valid.iter().sum::<f32>().max(1.0);
        let theta_ref: &[f32] = theta;

        let (grad, aux) = match kc.effective_order() {
            ReductionOrder::V1Scalar => {
                // One full-range pass: arithmetically identical to the
                // pre-versioning sequential loop, preserving the V1 bit-pins.
                let mut grad = ws.take(theta_ref.len());
                let mut aux = ws.take(4);
                self.accumulate_range(
                    ws, kc, theta_ref, z, a, z_next, r_target, xm_target, done_target, valid,
                    0..b, t_len, denom, &mut grad, &mut aux,
                );
                (grad, aux)
            }
            ReductionOrder::V2LaneTiled => {
                let macs = b * t_len * (i_dim * 4 * r + r * 4 * r + r * (3 * zk + x1 + 2)) * 3;
                v2_accumulate_grads(
                    ws,
                    kc,
                    b,
                    theta_ref.len(),
                    4,
                    macs,
                    |rows, cfg, cw, grad, aux| {
                        self.accumulate_range(
                            cw, cfg, theta_ref, z, a, z_next, r_target, xm_target, done_target,
                            valid, rows, t_len, denom, grad, aux,
                        );
                    },
                )
            }
        };

        adam_step(theta, m, v, t_adam, &grad, lr);
        let losses = WmStepLosses {
            total: aux[0] + aux[1] + aux[2] + aux[3],
            nll: aux[0],
            reward_mse: aux[1],
            mask_bce: aux[2],
            done_bce: aux[3],
        };
        ws.put_all([grad, aux]);
        losses
    }

    /// Teacher-forced forward/backward over `rows` of the sequence batch,
    /// accumulating the parameter gradient into `grad` and the weighted loss
    /// components into `aux` (`[nll, reward_mse, mask_bce, done_bce]`).
    /// Global tensors (`z`, `a`, targets, `valid`) are indexed by the global
    /// row `rows.start + row`; per-range activations by the local row.
    #[allow(clippy::too_many_arguments)]
    fn accumulate_range(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        a: &[i32],
        z_next: &[f32],
        r_target: &[f32],
        xm_target: &[f32],
        done_target: &[f32],
        valid: &[f32],
        rows: std::ops::Range<usize>,
        t_len: usize,
        denom: f32,
        grad: &mut [f32],
        aux: &mut [f32],
    ) {
        let (zd, r, i_dim, k, x1) = (self.zdim, self.rdim, self.i_dim(), self.k, self.x1);
        let zk = zd * k;
        let r0 = rows.start;
        let br = rows.len();

        let mut demb = ws.take(x1 * self.de);
        let mut dwxh = ws.take(i_dim * 4 * r);
        let mut dwhh = ws.take(r * 4 * r);
        let mut dbh = ws.take(4 * r);
        let mut dwpi = ws.take(r * zk);
        let mut dbpi = ws.take(zk);
        let mut dwmu = ws.take(r * zk);
        let mut dbmu = ws.take(zk);
        let mut dwsig = ws.take(r * zk);
        let mut dbsig = ws.take(zk);
        let mut dwr = ws.take(r);
        let mut dbr = ws.take(1);
        let mut dwmk = ws.take(r * x1);
        let mut dbmk = ws.take(x1);
        let mut dwd = ws.take(r);
        let mut dbd = ws.take(1);

        let mut h = ws.take(br * r);
        let mut c = ws.take(br * r);
        let mut lp_buf = ws.take(k);

        for ti in 0..t_len {
            // Gather the time-slice into step-batch layout.
            let mut zs = ws.take(br * zd);
            let mut as_ = ws.take_i32(br * 2);
            for row in 0..br {
                let g = r0 + row;
                let s = (g * t_len + ti) * zd;
                zs[row * zd..(row + 1) * zd].copy_from_slice(&z[s..s + zd]);
                as_[row * 2] = a[(g * t_len + ti) * 2];
                as_[row * 2 + 1] = a[(g * t_len + ti) * 2 + 1];
            }
            let fwd = self.cell_forward(ws, kc, theta, &zs, &as_, &h, &c, br, true);

            // ---- losses + head gradients ---------------------------------
            let mut dlp = ws.take(br * zk);
            let mut dmu = ws.take(br * zk);
            let mut dls = ws.take(br * zk);
            let mut drh = ws.take(br);
            let mut dmk = ws.take(br * x1);
            let mut ddn = ws.take(br);
            for row in 0..br {
                let g = r0 + row;
                let wv = valid[g * t_len + ti] / denom;
                if wv == 0.0 {
                    continue;
                }
                // MDN NLL over each latent dimension.
                let wdim = wv / zd as f32;
                for d in 0..zd {
                    let base = row * zk + d * k;
                    let raw = &fwd.heads.log_pi[base..base + k];
                    let lse_pi = log_sum_exp(raw);
                    let x_t = z_next[(g * t_len + ti) * zd + d];
                    for kk in 0..k {
                        let lsg = fwd.heads.log_sig[base + kk];
                        let sg = lsg.exp();
                        let dev = (x_t - fwd.heads.mu[base + kk]) / sg;
                        lp_buf[kk] = (raw[kk] - lse_pi) - lsg - 0.5 * LN_2PI - 0.5 * dev * dev;
                    }
                    let nll_d = -log_sum_exp(&lp_buf);
                    aux[0] += nll_d * wdim;
                    let gamma = &mut lp_buf;
                    softmax_inplace(gamma);
                    for kk in 0..k {
                        let pi_k = (raw[kk] - lse_pi).exp();
                        let lsg = fwd.heads.log_sig[base + kk];
                        let sg = lsg.exp();
                        let dev = (x_t - fwd.heads.mu[base + kk]) / sg;
                        dlp[base + kk] = (pi_k - gamma[kk]) * wdim;
                        dmu[base + kk] =
                            gamma[kk] * (fwd.heads.mu[base + kk] - x_t) / (sg * sg) * wdim;
                        dls[base + kk] = gamma[kk] * (1.0 - dev * dev) * wdim;
                    }
                }
                // Reward regression.
                let dr = fwd.heads.reward[row] - r_target[g * t_len + ti];
                aux[1] += dr * dr * wv;
                drh[row] = 2.0 * dr * wv;
                // Next-state mask BCE.
                let wmask = wv / x1 as f32;
                for xi in 0..x1 {
                    let logit = fwd.heads.mask_logits[row * x1 + xi];
                    let target = xm_target[(g * t_len + ti) * x1 + xi];
                    aux[2] += (softplus(logit) - target * logit) * wmask;
                    dmk[row * x1 + xi] = (sigmoid(logit) - target) * wmask;
                }
                // Done BCE.
                let dl = fwd.heads.done_logits[row];
                let dt = done_target[g * t_len + ti];
                aux[3] += (softplus(dl) - dt * dl) * wv;
                ddn[row] = (sigmoid(dl) - dt) * wv;
            }

            // ---- backward: heads -> h1 -> one LSTM cell -------------------
            // log_sig = 3 * tanh(sig_raw) - 1.
            let mut dsig_raw = dls;
            for (d, th) in dsig_raw.iter_mut().zip(&fwd.sig_tanh) {
                *d *= 3.0 * (1.0 - th * th);
            }
            let h1 = &fwd.heads.h1;
            acc_xt_dy(kc, h1, &dlp, br, r, zk, &mut dwpi);
            acc_rows(&dlp, br, zk, &mut dbpi);
            acc_xt_dy(kc, h1, &dmu, br, r, zk, &mut dwmu);
            acc_rows(&dmu, br, zk, &mut dbmu);
            acc_xt_dy(kc, h1, &dsig_raw, br, r, zk, &mut dwsig);
            acc_rows(&dsig_raw, br, zk, &mut dbsig);
            acc_xt_dy(kc, h1, &drh, br, r, 1, &mut dwr);
            acc_rows(&drh, br, 1, &mut dbr);
            acc_xt_dy(kc, h1, &dmk, br, r, x1, &mut dwmk);
            acc_rows(&dmk, br, x1, &mut dbmk);
            acc_xt_dy(kc, h1, &ddn, br, r, 1, &mut dwd);
            acc_rows(&ddn, br, 1, &mut dbd);

            let mut dh1 = ws.take(br * r);
            dy_wt_into(kc, &dlp, self.layout.view(theta, "wpi"), br, zk, r, &mut dh1);
            dy_wt_acc(kc, &dmu, self.layout.view(theta, "wmu"), br, zk, r, &mut dh1);
            dy_wt_acc(kc, &dsig_raw, self.layout.view(theta, "wsig"), br, zk, r, &mut dh1);
            dy_wt_acc(kc, &drh, self.layout.view(theta, "wr"), br, 1, r, &mut dh1);
            dy_wt_acc(kc, &dmk, self.layout.view(theta, "wmk"), br, x1, r, &mut dh1);
            dy_wt_acc(kc, &ddn, self.layout.view(theta, "wd"), br, 1, r, &mut dh1);

            let mut dgates = ws.take(br * 4 * r);
            for row in 0..br {
                for j in 0..r {
                    let idx = row * r + j;
                    let o_v = fwd.go[idx];
                    let tc = fwd.tanh_c1[idx];
                    let dh = dh1[idx];
                    let do_pre = dh * tc * o_v * (1.0 - o_v);
                    let dc1 = dh * o_v * (1.0 - tc * tc);
                    let i_v = fwd.gi[idx];
                    let f_v = fwd.gf[idx];
                    let g_v = fwd.gg[idx];
                    let di_pre = dc1 * g_v * i_v * (1.0 - i_v);
                    let df_pre = dc1 * fwd.c_prev[idx] * f_v * (1.0 - f_v);
                    let dg_pre = dc1 * i_v * (1.0 - g_v * g_v);
                    let base = row * 4 * r;
                    dgates[base + j] = di_pre;
                    dgates[base + r + j] = df_pre;
                    dgates[base + 2 * r + j] = dg_pre;
                    dgates[base + 3 * r + j] = do_pre;
                }
            }
            acc_xt_dy(kc, &fwd.x, &dgates, br, i_dim, 4 * r, &mut dwxh);
            acc_xt_dy(kc, &fwd.h_prev, &dgates, br, r, 4 * r, &mut dwhh);
            acc_rows(&dgates, br, 4 * r, &mut dbh);
            let mut dx = ws.take(br * i_dim);
            dy_wt_into(kc, &dgates, self.layout.view(theta, "wxh"), br, 4 * r, i_dim, &mut dx);
            for row in 0..br {
                let slot = fwd.ax[row];
                for e in 0..self.de {
                    demb[slot * self.de + e] += dx[row * i_dim + zd + e];
                }
            }

            ws.put_all([dlp, dmu, dsig_raw, drh, dmk, ddn, dh1, dgates, dx, zs]);
            ws.put_i32(as_);

            // Teacher forcing: advance the (detached) recurrent state and
            // recycle everything else from this timestep.
            let heads = fwd.recycle_scratch(ws);
            let (h1, c1) = heads.recycle_except_state(ws);
            ws.put(std::mem::replace(&mut h, h1));
            ws.put(std::mem::replace(&mut c, c1));
        }

        self.layout.scatter(grad, "emb", &demb);
        self.layout.scatter(grad, "wxh", &dwxh);
        self.layout.scatter(grad, "whh", &dwhh);
        self.layout.scatter(grad, "bh", &dbh);
        self.layout.scatter(grad, "wpi", &dwpi);
        self.layout.scatter(grad, "bpi", &dbpi);
        self.layout.scatter(grad, "wmu", &dwmu);
        self.layout.scatter(grad, "bmu", &dbmu);
        self.layout.scatter(grad, "wsig", &dwsig);
        self.layout.scatter(grad, "bsig", &dbsig);
        self.layout.scatter(grad, "wr", &dwr);
        self.layout.scatter(grad, "br", &dbr);
        self.layout.scatter(grad, "wmk", &dwmk);
        self.layout.scatter(grad, "bmk", &dbmk);
        self.layout.scatter(grad, "wd", &dwd);
        self.layout.scatter(grad, "bd", &dbd);

        ws.put_all([demb, dwxh, dwhh, dbh, dwpi, dbpi, dwmu, dbmu, dwsig, dbsig]);
        ws.put_all([dwr, dbr, dwmk, dbmk, dwd, dbd, h, c, lp_buf]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn net() -> WmNet {
        WmNet::new(4, 6, 2, 5, 10, 3)
    }

    #[test]
    fn step_shapes_and_evolution() {
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let theta = n.init(1);
        let b = 2;
        let z = vec![0.3f32; b * 4];
        let a = vec![1i32, 2, 4, 0];
        let h = vec![0.0f32; b * 6];
        let c = vec![0.0f32; b * 6];
        let out = n.step(&mut ws, &kc, &theta, &z, &a, &h, &c, b);
        assert_eq!(out.log_pi.len(), b * 4 * 2);
        assert_eq!(out.mask_logits.len(), b * 5);
        assert_eq!(out.h1.len(), b * 6);
        assert!(out.h1.iter().any(|v| v.abs() > 0.0), "hidden state did not evolve");
        assert!(out.log_sig.iter().all(|v| (-4.0..=2.0).contains(v)));
        // Deterministic.
        let again = n.step(&mut ws, &kc, &theta, &z, &a, &h, &c, b);
        assert_eq!(out.h1, again.h1);
        assert_eq!(out.log_pi, again.log_pi);
    }

    #[test]
    fn step_is_mode_and_thread_invariant() {
        let n = net();
        let theta = n.init(8);
        let b = 3;
        let mut rng = Rng::new(4);
        let z: Vec<f32> = (0..b * 4).map(|_| rng.normal() * 0.5).collect();
        let a: Vec<i32> = (0..b * 2).map(|i| (i % 5) as i32).collect();
        let h: Vec<f32> = (0..b * 6).map(|_| rng.normal() * 0.2).collect();
        let c: Vec<f32> = (0..b * 6).map(|_| rng.normal() * 0.2).collect();
        let mut ws = Workspace::new();
        let want = n.step(&mut ws, &KernelCfg::reference(), &theta, &z, &a, &h, &c, b);
        for threads in [1, 2, 8] {
            let got = n.step(&mut ws, &KernelCfg::blocked(threads), &theta, &z, &a, &h, &c, b);
            assert_eq!(want.log_pi, got.log_pi);
            assert_eq!(want.mu, got.mu);
            assert_eq!(want.log_sig, got.log_sig);
            assert_eq!(want.reward, got.reward);
            assert_eq!(want.mask_logits, got.mask_logits);
            assert_eq!(want.done_logits, got.done_logits);
            assert_eq!(want.h1, got.h1);
            assert_eq!(want.c1, got.c1);
        }
    }

    #[test]
    fn train_decreases_loss_on_synthetic_dynamics() {
        // z_next = 0.9 z, constant small reward, all-valid masks.
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let mut theta = n.init(3);
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let (b, t) = (3, 4);
        let mut rng = Rng::new(9);
        let z: Vec<f32> = (0..b * t * 4).map(|_| rng.normal() * 0.5).collect();
        let z_next: Vec<f32> = z.iter().map(|x| 0.9 * x).collect();
        let a: Vec<i32> = (0..b * t * 2).map(|i| (i % 5) as i32).collect();
        let r: Vec<f32> = vec![0.05; b * t];
        let xm = vec![1.0f32; b * t * 5];
        let done = vec![0.0f32; b * t];
        let valid = vec![1.0f32; b * t];
        let first = n
            .train_step(
                &mut ws, &kc, &mut theta, &mut m, &mut v, 1.0, &z, &a, &z_next, &r, &xm, &done,
                &valid, b, t, 1e-2,
            )
            .total;
        let mut last = first;
        for step in 2..=60 {
            last = n
                .train_step(
                    &mut ws, &kc, &mut theta, &mut m, &mut v, step as f32, &z, &a, &z_next, &r,
                    &xm, &done, &valid, b, t, 1e-2,
                )
                .total;
        }
        assert!(last.is_finite() && last < first, "wm loss {first} -> {last}");
        assert!(theta.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn train_scratch_is_fully_recycled() {
        for kc in [KernelCfg::blocked(2), KernelCfg::v2(2)] {
            let n = net();
            let mut ws = Workspace::new();
            let mut theta = n.init(7);
            let mut m = vec![0.0f32; theta.len()];
            let mut v = vec![0.0f32; theta.len()];
            let (b, t) = (2, 3);
            let z = vec![0.5f32; b * t * 4];
            let a = vec![1i32; b * t * 2];
            let z_next = vec![0.4f32; b * t * 4];
            let r = vec![0.1f32; b * t];
            let xm = vec![1.0f32; b * t * 5];
            let done = vec![0.0f32; b * t];
            let valid = vec![1.0f32; b * t];
            n.train_step(
                &mut ws, &kc, &mut theta, &mut m, &mut v, 1.0, &z, &a, &z_next, &r, &xm, &done,
                &valid, b, t, 1e-3,
            );
            let warm = ws.stats();
            for step in 2..=6 {
                n.train_step(
                    &mut ws, &kc, &mut theta, &mut m, &mut v, step as f32, &z, &a, &z_next, &r,
                    &xm, &done, &valid, b, t, 1e-3,
                );
            }
            let now = ws.stats();
            assert_eq!(
                warm.alloc_bytes, now.alloc_bytes,
                "steady-state wm_train must allocate no scratch ({:?})",
                kc.order
            );
            assert!(now.reuses > warm.reuses);
        }
    }

    #[test]
    fn v2_train_is_bit_invariant_across_threads_and_lane_widths() {
        let run = |kc: KernelCfg| {
            let n = net();
            let mut ws = Workspace::new();
            let mut theta = n.init(21);
            let mut m = vec![0.0f32; theta.len()];
            let mut v = vec![0.0f32; theta.len()];
            let (b, t) = (5, 3);
            let mut rng = Rng::new(31);
            let z: Vec<f32> = (0..b * t * 4).map(|_| rng.normal() * 0.5).collect();
            let z_next: Vec<f32> = z.iter().map(|x| 0.8 * x + 0.05).collect();
            let a: Vec<i32> = (0..b * t * 2).map(|i| (i % 5) as i32).collect();
            let r: Vec<f32> = (0..b * t).map(|_| rng.normal() * 0.1).collect();
            let xm: Vec<f32> = (0..b * t * 5).map(|i| (i % 2) as f32).collect();
            let done = vec![0.0f32; b * t];
            let valid: Vec<f32> = (0..b * t).map(|i| if i % 7 == 3 { 0.0 } else { 1.0 }).collect();
            let mut losses = Vec::new();
            for step in 1..=4 {
                let l = n.train_step(
                    &mut ws, &kc, &mut theta, &mut m, &mut v, step as f32, &z, &a, &z_next, &r,
                    &xm, &done, &valid, b, t, 1e-2,
                );
                losses.push([l.total, l.nll, l.reward_mse, l.mask_bce, l.done_bce]);
            }
            (theta, losses)
        };
        let want = run(KernelCfg::v2(1).with_lane_groups(1));
        for (threads, lanes) in [(2, 2), (8, 4), (3, 8)] {
            let got = run(KernelCfg::v2(threads).with_lane_groups(lanes));
            assert_eq!(want, got, "wm V2 train diverged at threads={threads} lanes={lanes}");
        }
    }

    #[test]
    fn invalid_steps_carry_no_gradient() {
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let theta0 = n.init(5);
        let mut theta = theta0.clone();
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let (b, t) = (2, 3);
        let losses = n.train_step(
            &mut ws,
            &kc,
            &mut theta,
            &mut m,
            &mut v,
            1.0,
            &vec![0.5; b * t * 4],
            &vec![0i32; b * t * 2],
            &vec![0.4; b * t * 4],
            &vec![0.1; b * t],
            &vec![1.0; b * t * 5],
            &vec![0.0; b * t],
            &vec![0.0; b * t], // nothing valid
            b,
            t,
            1e-2,
        );
        assert_eq!(losses.total, 0.0);
        assert_eq!(theta, theta0, "all-invalid batch must be a no-op");
    }
}
