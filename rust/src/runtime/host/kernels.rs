//! Blocked, multi-threaded dense kernels + the zero-alloc [`Workspace`]
//! scratch arena — the throughput layer under `host::{gnn, ctrl, wm}`.
//!
//! Two kernel modes exist behind [`KernelCfg`]:
//!
//!  * [`KernelMode::Reference`] — the seed scalar triple-loop kernels
//!    (`nn::linear_reference` et al.), kept verbatim as the numeric oracle;
//!  * [`KernelMode::Blocked`] — cache-blocked loops with a fixed row/stripe
//!    partition fanned out over `std::thread::scope`.
//!
//! **Determinism contract.** Every output element is computed wholly by one
//! thread, and its floating-point reduction order (k ascending for
//! `linear_into`, sample-row ascending for `acc_xt_dy`, column ascending
//! for `dy_wt_into` — including the seed kernels' skip of exact-zero
//! inputs) is identical to the scalar reference. Blocking and threading
//! only change *which thread* computes an element and in what wall-clock
//! order elements complete, never the arithmetic applied to any single
//! element. Outputs are therefore bit-identical for any thread count and
//! either mode — the same contract the search engine pins for
//! `TasoConfig::threads` (`tests/host_kernels.rs` pins it here).
//!
//! [`Workspace`] recycles scratch buffers across program calls so the
//! steady-state training loop performs no per-call heap allocation for
//! intermediates: `take` serves a cleared buffer from the free list when
//! one with enough capacity exists and only allocates on first use (or
//! growth), with reuse/allocation counters surfaced per program through
//! [`ExecStats`](crate::runtime::ExecStats).

use super::nn;

/// Column-block width for the blocked GEMM inner loops. Sized so an output
/// block plus one weight-row block stay L1-resident; at the host model's
/// dimensions a row usually fits in a single block, and the structure only
/// engages on wider heads.
const NC: usize = 1024;

/// Minimum multiply-accumulate count before a kernel fans out worker
/// threads; below this, `std::thread` spawn latency outweighs the win.
const PAR_MIN_MACS: usize = 1 << 19;

/// Which kernel implementation a [`HostBackend`](super::HostBackend) runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Seed scalar triple-loop kernels — the bit-exact oracle.
    Reference,
    /// Cache-blocked loops, multi-threaded above [`PAR_MIN_MACS`] work.
    Blocked,
}

/// Kernel selection + thread budget for one backend instance.
#[derive(Debug, Clone, Copy)]
pub struct KernelCfg {
    /// Implementation to run (outputs are bit-identical either way).
    pub mode: KernelMode,
    /// Worker-thread cap for the blocked mode (1 = fully serial).
    pub threads: usize,
}

impl Default for KernelCfg {
    fn default() -> Self {
        Self { mode: KernelMode::Blocked, threads: default_threads() }
    }
}

impl KernelCfg {
    /// The seed scalar kernels (single-threaded oracle).
    pub fn reference() -> Self {
        Self { mode: KernelMode::Reference, threads: 1 }
    }

    /// Blocked kernels at an explicit thread cap.
    pub fn blocked(threads: usize) -> Self {
        Self { mode: KernelMode::Blocked, threads: threads.max(1) }
    }
}

/// Default worker-thread cap: `RLFLOW_HOST_THREADS` when set, else the
/// machine's available parallelism capped at 8 (the host programs' GEMMs
/// are too small to feed more).
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("RLFLOW_HOST_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// Activation fused into the forward GEMM epilogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// Plain affine output.
    None,
    /// `tanh` applied in the same pass over each finished output row.
    Tanh,
}

// ---------------------------------------------------------------------------
// Workspace
// ---------------------------------------------------------------------------

/// Cumulative scratch-arena accounting (monotone counters).
#[derive(Debug, Default, Clone, Copy)]
pub struct WorkspaceStats {
    /// Buffer checkouts served from the free list without allocating.
    pub reuses: u64,
    /// Buffer checkouts that had to allocate fresh memory.
    pub allocations: u64,
    /// Total bytes of fresh scratch memory allocated.
    pub alloc_bytes: u64,
}

/// A free-list arena of reusable `f32` scratch buffers.
///
/// The host nets draw every intermediate (activations, per-tensor gradient
/// buffers, LSTM gate planes) from here and return it before finishing, so
/// after a warm-up call per program the training hot path allocates no
/// scratch memory: `take` finds a parked buffer with enough capacity,
/// clears it and hands it back. Buffers are zero-filled exactly like the
/// `vec![0.0; n]` allocations they replace, so recycling is invisible to
/// the numerics.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    free_idx: Vec<Vec<usize>>,
    free_i32: Vec<Vec<i32>>,
    stats: WorkspaceStats,
}

impl Workspace {
    /// An empty arena (buffers are allocated lazily on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Cumulative reuse/allocation counters.
    pub fn stats(&self) -> WorkspaceStats {
        self.stats
    }

    /// Check out a zero-filled buffer of `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        // Best fit: the smallest parked buffer that already has capacity,
        // so a tiny request never pins the arena's largest buffer.
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in self.free.iter().enumerate() {
            let cap = b.capacity();
            if cap >= len && best.map_or(true, |(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                let mut b = self.free.swap_remove(i);
                b.clear();
                b.resize(len, 0.0);
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.allocations += 1;
                self.stats.alloc_bytes += (len * std::mem::size_of::<f32>()) as u64;
                vec![0.0; len]
            }
        }
    }

    /// Check out a buffer initialised as a copy of `src`.
    pub fn take_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut b = self.take(src.len());
        b.copy_from_slice(src);
        b
    }

    /// Return a buffer to the free list for later reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Return several buffers at once.
    pub fn put_all<I: IntoIterator<Item = Vec<f32>>>(&mut self, bufs: I) {
        for b in bufs {
            self.put(b);
        }
    }

    /// Check out an *empty* index buffer (callers push into it).
    pub fn take_idx(&mut self) -> Vec<usize> {
        match self.free_idx.pop() {
            Some(mut b) => {
                b.clear();
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.allocations += 1;
                Vec::new()
            }
        }
    }

    /// Return an index buffer to the free list.
    pub fn put_idx(&mut self, buf: Vec<usize>) {
        if buf.capacity() > 0 {
            self.free_idx.push(buf);
        }
    }

    /// Check out a zero-filled i32 buffer of `len` elements.
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        match self.free_i32.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                let mut b = self.free_i32.swap_remove(i);
                b.clear();
                b.resize(len, 0);
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.allocations += 1;
                self.stats.alloc_bytes += (len * std::mem::size_of::<i32>()) as u64;
                vec![0; len]
            }
        }
    }

    /// Return an i32 buffer to the free list.
    pub fn put_i32(&mut self, buf: Vec<i32>) {
        if buf.capacity() > 0 {
            self.free_i32.push(buf);
        }
    }
}

// ---------------------------------------------------------------------------
// Threading helper
// ---------------------------------------------------------------------------

/// Worker count for a row-partitioned kernel: 1 in reference mode or
/// unless the config allows more, there are rows to split, and the
/// arithmetic volume clears [`PAR_MIN_MACS`]. Purely a scheduling decision
/// — outputs are identical for every return value. Public so the nets can
/// stripe their own row-independent loops (e.g. the GNN's neighbourhood
/// aggregation) under the same policy.
pub fn plan_threads(cfg: &KernelCfg, rows: usize, macs: usize) -> usize {
    if cfg.mode == KernelMode::Reference || cfg.threads <= 1 || rows <= 1 || macs < PAR_MIN_MACS {
        1
    } else {
        cfg.threads.min(rows)
    }
}

/// Split `out` into `t` contiguous row stripes and run `body(first_row,
/// stripe)` on each, fanning out over scoped threads when `t > 1`. The
/// stripe boundaries depend only on `(rows, t)`, and every row is written
/// by exactly one worker.
pub fn par_row_stripes<F>(out: &mut [f32], rows: usize, row_w: usize, t: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_w);
    if t <= 1 || rows <= 1 {
        body(0, out);
        return;
    }
    let per = (rows + t - 1) / t;
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut r0 = 0;
        while !rest.is_empty() {
            let take = (per * row_w).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            let first = r0;
            let bref = &body;
            scope.spawn(move || bref(first, chunk));
            r0 += take / row_w;
            rest = tail;
        }
    });
}

// ---------------------------------------------------------------------------
// Forward: y = x w (+ bias) (+ activation)
// ---------------------------------------------------------------------------

/// `y = act(x w + bias)` over `m` rows: x `[m,k]`, w `[k,n]`, bias `[n]`
/// (or none for a pure matmul), y `[m,n]`. The fused activation runs in
/// the same pass over each finished row. Bit-identical to
/// [`nn::linear_reference`] followed by a `tanh` sweep, for any thread
/// count.
pub fn linear_into(
    cfg: &KernelCfg,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(y.len(), m * n);
    if let Some(b) = bias {
        debug_assert_eq!(b.len(), n);
    }
    if cfg.mode == KernelMode::Reference {
        for r in 0..m {
            let yr = &mut y[r * n..(r + 1) * n];
            match bias {
                Some(b) => yr.copy_from_slice(b),
                None => yr.fill(0.0),
            }
            for i in 0..k {
                let xv = x[r * k + i];
                if xv == 0.0 {
                    continue;
                }
                let wr = &w[i * n..(i + 1) * n];
                for (yj, wj) in yr.iter_mut().zip(wr) {
                    *yj += xv * wj;
                }
            }
            if act == Act::Tanh {
                nn::tanh_inplace(yr);
            }
        }
        return;
    }
    let t = plan_threads(cfg, m, m * k * n);
    par_row_stripes(y, m, n, t, |r0, chunk| {
        for (ri, yr) in chunk.chunks_exact_mut(n).enumerate() {
            let r = r0 + ri;
            match bias {
                Some(b) => yr.copy_from_slice(b),
                None => yr.fill(0.0),
            }
            let xr = &x[r * k..(r + 1) * k];
            // Column blocks keep the y block and each w row block hot; the
            // per-element accumulation order stays k ascending (with the
            // reference's exact-zero skip), so blocking is invisible to
            // the bit pattern.
            let mut jb = 0;
            while jb < n {
                let je = (jb + NC).min(n);
                for (i, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let wr = &w[i * n + jb..i * n + je];
                    for (yj, wj) in yr[jb..je].iter_mut().zip(wr) {
                        *yj += xv * wj;
                    }
                }
                jb = je;
            }
            if act == Act::Tanh {
                nn::tanh_inplace(yr);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Backward: dw += xᵀ dy
// ---------------------------------------------------------------------------

/// `dw += xᵀ dy`: x `[m,k]`, dy `[m,n]`, dw `[k,n]`. Parallel over stripes
/// of `k` (each worker owns whole dw rows); per-element accumulation order
/// is sample-row ascending, exactly like [`nn::acc_xt_dy_reference`].
pub fn acc_xt_dy(
    cfg: &KernelCfg,
    x: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    if cfg.mode == KernelMode::Reference {
        nn::acc_xt_dy_reference(x, dy, m, k, n, dw);
        return;
    }
    let t = plan_threads(cfg, k, m * k * n);
    par_row_stripes(dw, k, n, t, |i0, chunk| {
        for (ii, dwr) in chunk.chunks_exact_mut(n).enumerate() {
            let i = i0 + ii;
            for r in 0..m {
                let xv = x[r * k + i];
                if xv == 0.0 {
                    continue;
                }
                let dyr = &dy[r * n..(r + 1) * n];
                for (dwj, dyj) in dwr.iter_mut().zip(dyr) {
                    *dwj += xv * dyj;
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Backward: dx = dy wᵀ
// ---------------------------------------------------------------------------

/// `dx = dy wᵀ`: dy `[m,n]`, w `[k,n]`, dx `[m,k]`. Parallel over row
/// stripes of dx; per-element reduction order is column ascending, exactly
/// like [`nn::dy_wt_reference`].
pub fn dy_wt_into(
    cfg: &KernelCfg,
    dy: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    if cfg.mode == KernelMode::Reference {
        for r in 0..m {
            let dyr = &dy[r * n..(r + 1) * n];
            for i in 0..k {
                let wr = &w[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (dyj, wj) in dyr.iter().zip(wr) {
                    acc += dyj * wj;
                }
                dx[r * k + i] = acc;
            }
        }
        return;
    }
    let t = plan_threads(cfg, m, m * k * n);
    par_row_stripes(dx, m, k, t, |r0, chunk| {
        for (ri, dxr) in chunk.chunks_exact_mut(k).enumerate() {
            let dyr = &dy[(r0 + ri) * n..(r0 + ri + 1) * n];
            for (i, dst) in dxr.iter_mut().enumerate() {
                let wr = &w[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (dyj, wj) in dyr.iter().zip(wr) {
                    acc += dyj * wj;
                }
                *dst = acc;
            }
        }
    });
}

/// `dx += dy wᵀ` (accumulating form for head-gradient merges): same
/// reduction order as [`dy_wt_into`] per added term.
pub fn dy_wt_acc(
    cfg: &KernelCfg,
    dy: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dx.len(), m * k);
    if cfg.mode == KernelMode::Reference {
        for r in 0..m {
            let dyr = &dy[r * n..(r + 1) * n];
            for i in 0..k {
                let wr = &w[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (dyj, wj) in dyr.iter().zip(wr) {
                    acc += dyj * wj;
                }
                dx[r * k + i] += acc;
            }
        }
        return;
    }
    let t = plan_threads(cfg, m, m * k * n);
    par_row_stripes(dx, m, k, t, |r0, chunk| {
        for (ri, dxr) in chunk.chunks_exact_mut(k).enumerate() {
            let dyr = &dy[(r0 + ri) * n..(r0 + ri + 1) * n];
            for (i, dst) in dxr.iter_mut().enumerate() {
                let wr = &w[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (dyj, wj) in dyr.iter().zip(wr) {
                    acc += dyj * wj;
                }
                *dst += acc;
            }
        }
    });
}

/// Backward through a fused tanh epilogue: `dpre = dy * (1 - y²)` where
/// `y` is the *activated* forward output, written over `dy` in place.
pub fn tanh_backward_inplace(dy: &mut [f32], y: &[f32]) {
    debug_assert_eq!(dy.len(), y.len());
    for (d, v) in dy.iter_mut().zip(y) {
        *d *= 1.0 - v * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_with_zeros(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if rng.below(5) == 0 { 0.0 } else { rng.normal() })
            .collect()
    }

    #[test]
    fn blocked_linear_matches_reference_for_all_thread_counts() {
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(1, 4, 3), (5, 7, 9), (33, 17, 21), (320, 32, 32)] {
            let x = rand_with_zeros(&mut rng, m * k);
            let w = rand_with_zeros(&mut rng, k * n);
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for act in [Act::None, Act::Tanh] {
                let mut want = vec![0.0f32; m * n];
                linear_into(&KernelCfg::reference(), &x, &w, Some(&b), m, k, n, act, &mut want);
                for threads in [1, 2, 8] {
                    let mut got = vec![0.0f32; m * n];
                    linear_into(
                        &KernelCfg::blocked(threads),
                        &x,
                        &w,
                        Some(&b),
                        m,
                        k,
                        n,
                        act,
                        &mut got,
                    );
                    assert_eq!(want, got, "linear m={m} k={k} n={n} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn fused_tanh_equals_seed_linear_then_tanh() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (6, 5, 4);
        let x = rand_with_zeros(&mut rng, m * k);
        let w = rand_with_zeros(&mut rng, k * n);
        let b = vec![0.25f32; n];
        let mut seed = nn::linear_reference(&x, &w, &b, m, k, n);
        nn::tanh_inplace(&mut seed);
        let mut fused = vec![0.0f32; m * n];
        linear_into(&KernelCfg::blocked(4), &x, &w, Some(&b), m, k, n, Act::Tanh, &mut fused);
        assert_eq!(seed, fused);
    }

    #[test]
    fn blocked_acc_xt_dy_matches_reference_for_all_thread_counts() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(2, 3, 4), (9, 13, 7), (64, 48, 64)] {
            let x = rand_with_zeros(&mut rng, m * k);
            let dy = rand_with_zeros(&mut rng, m * n);
            let init: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.1).collect();
            let mut want = init.clone();
            nn::acc_xt_dy_reference(&x, &dy, m, k, n, &mut want);
            for threads in [1, 2, 8] {
                let mut got = init.clone();
                acc_xt_dy(&KernelCfg::blocked(threads), &x, &dy, m, k, n, &mut got);
                assert_eq!(want, got, "acc_xt_dy m={m} k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn blocked_dy_wt_matches_reference_for_all_thread_counts() {
        let mut rng = Rng::new(13);
        for &(m, n, k) in &[(2, 3, 4), (17, 9, 11), (64, 64, 48)] {
            let dy = rand_with_zeros(&mut rng, m * n);
            let w = rand_with_zeros(&mut rng, k * n);
            let want = nn::dy_wt_reference(&dy, &w, m, n, k);
            for threads in [1, 2, 8] {
                let mut got = vec![0.0f32; m * k];
                dy_wt_into(&KernelCfg::blocked(threads), &dy, &w, m, n, k, &mut got);
                assert_eq!(want, got, "dy_wt m={m} n={n} k={k} threads={threads}");
                let mut acc = want.clone();
                dy_wt_acc(&KernelCfg::blocked(threads), &dy, &w, m, n, k, &mut acc);
                let doubled: Vec<f32> = want.iter().map(|v| v + v).collect();
                assert_eq!(doubled, acc, "dy_wt_acc accumulates");
            }
        }
    }

    #[test]
    fn workspace_reuses_after_warmup() {
        let mut ws = Workspace::new();
        let a = ws.take(64);
        let b = ws.take(128);
        assert_eq!(ws.stats().allocations, 2);
        ws.put(a);
        ws.put(b);
        // Steady state: every take is served from the free list.
        for _ in 0..10 {
            let a = ws.take(64);
            let b = ws.take(100); // fits the 128-capacity buffer
            assert!(a.iter().all(|&v| v == 0.0), "recycled buffers must be zeroed");
            ws.put(a);
            ws.put(b);
        }
        assert_eq!(ws.stats().allocations, 2, "no new allocations after warm-up");
        assert_eq!(ws.stats().reuses, 20);
    }

    #[test]
    fn workspace_best_fit_prefers_smallest_buffer() {
        let mut ws = Workspace::new();
        let big = ws.take(1000);
        let small = ws.take(10);
        ws.put(big);
        ws.put(small);
        let got = ws.take(8);
        assert!(got.capacity() < 1000, "tiny request must not pin the big buffer");
        ws.put(got);
    }

    #[test]
    fn tanh_backward_matches_manual() {
        let y = vec![0.5f32, -0.25, 0.0];
        let mut dy = vec![2.0f32, 2.0, 2.0];
        tanh_backward_inplace(&mut dy, &y);
        assert_eq!(dy, vec![2.0 * (1.0 - 0.25), 2.0 * (1.0 - 0.0625), 2.0]);
    }

    #[test]
    fn par_row_stripes_covers_every_row_once() {
        let rows = 7;
        let mut out = vec![0.0f32; rows * 3];
        par_row_stripes(&mut out, rows, 3, 3, |r0, chunk| {
            for (ri, row) in chunk.chunks_exact_mut(3).enumerate() {
                row.fill((r0 + ri) as f32 + 1.0);
            }
        });
        for r in 0..rows {
            assert!(out[r * 3..(r + 1) * 3].iter().all(|&v| v == r as f32 + 1.0));
        }
    }
}
