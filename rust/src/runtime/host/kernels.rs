//! Blocked, multi-threaded dense kernels + the zero-alloc [`Workspace`]
//! scratch arena — the throughput layer under `host::{gnn, ctrl, wm}`.
//!
//! Two kernel modes exist behind [`KernelCfg`]:
//!
//!  * [`KernelMode::Reference`] — the seed scalar triple-loop kernels
//!    (`nn::linear_reference` et al.), kept verbatim as the numeric oracle;
//!  * [`KernelMode::Blocked`] — cache-blocked loops with a fixed row/stripe
//!    partition fanned out over `std::thread::scope`.
//!
//! **Determinism contract — versioned reduction orders.** Floating-point
//! reduction order is pinned *per version* of [`ReductionOrder`], and
//! within a version every output element's arithmetic is a pure function
//! of the inputs — never of the thread count, stripe boundaries, or the
//! runtime lane width:
//!
//!  * [`ReductionOrder::V1Scalar`] is the seed order: k ascending for
//!    `linear_into`, sample-row ascending for `acc_xt_dy`, column
//!    ascending for `dy_wt_into` — including the seed kernels' skip of
//!    exact-zero inputs. Reference and blocked V1 kernels are bit-identical
//!    to each other for any thread count (the original PR-5 pins).
//!  * [`ReductionOrder::V2LaneTiled`] is a k-blocked, fixed-lane-count
//!    order: dot-product reductions keep [`V2_LANES`] independent partial
//!    sums (lane `ℓ` owns the elements with index ≡ `ℓ` mod `V2_LANES`,
//!    visited ascending) combined by a fixed pairwise tree, and the
//!    branch-free inner loops compile to f32 SIMD. The runtime lane-group
//!    width ([`KernelCfg::lane_groups`]) only unrolls *independent* lanes,
//!    so V2 outputs are bit-identical for any thread count **and any lane
//!    width** — but not to V1: cross-version agreement is a toleranced
//!    parity oracle, not a bit pin (`tests/host_kernels.rs` pins both).
//!
//! On top of V2's order the `*_train` programs accumulate gradients into
//! per-sample-group buffers ([`v2_sample_groups`], a partition that
//! depends only on the batch size) folded by [`tree_reduce_sum`]'s fixed
//! pairwise tree, which unlocks sample-level train parallelism without
//! giving up the per-version bit pin.
//!
//! [`Workspace`] recycles scratch buffers across program calls so the
//! steady-state training loop performs no per-call heap allocation for
//! intermediates: `take` serves a cleared buffer from the free list when
//! one with enough capacity exists and only allocates on first use (or
//! growth), with reuse/allocation counters surfaced per program through
//! [`ExecStats`](crate::runtime::ExecStats). Sample-parallel regions check
//! out whole child arenas ([`Workspace::take_children`]) so each worker's
//! scratch recycles just as well.

use super::nn;

/// Column-block width for the blocked GEMM inner loops. Sized so an output
/// block plus one weight-row block stay L1-resident; at the host model's
/// dimensions a row usually fits in a single block, and the structure only
/// engages on wider heads.
const NC: usize = 1024;

/// Minimum multiply-accumulate count before a kernel fans out worker
/// threads; below this, `std::thread` spawn latency outweighs the win.
const PAR_MIN_MACS: usize = 1 << 19;

/// Fixed logical lane count of the V2 reduction order: dot-product
/// reductions keep this many independent partial sums (lane `ℓ` owns the
/// elements with index ≡ `ℓ` mod `V2_LANES`, visited ascending) combined
/// by a fixed pairwise tree. Part of the V2 bit contract — a *logical*
/// count, never derived from the hardware vector width.
pub const V2_LANES: usize = 8;

/// Depth of the k-blocks in the V2 forward GEMM. Within a block the
/// per-element accumulation order is still k ascending, so the blocking is
/// structural (cache locality), not part of the bit pattern.
pub const V2_KB: usize = 64;

/// Number of contiguous sample groups the V2 `*_train` programs split a
/// batch into ([`v2_sample_groups`]). Fixed so the gradient partition —
/// and therefore the reduced gradient's bit pattern — depends only on the
/// batch size, never on the worker count, and so per-group gradient
/// buffers bound memory at `V2_GRAD_GROUPS × |theta|` per family.
pub const V2_GRAD_GROUPS: usize = 8;

/// Which kernel implementation a [`HostBackend`](super::HostBackend) runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Seed scalar triple-loop kernels — the bit-exact oracle.
    Reference,
    /// Cache-blocked loops, multi-threaded above [`PAR_MIN_MACS`] work.
    Blocked,
}

/// Version of the floating-point reduction order the kernels commit to.
///
/// Determinism is pinned *per version*: a given version produces
/// bit-identical outputs for any thread count and any runtime lane width.
/// Different versions agree only within a small relative error — the
/// cross-version parity oracle in `tests/host_kernels.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReductionOrder {
    /// The seed order: scalar k-ascending accumulation with the exact-zero
    /// input skip. Matches the PR-5 bit pins unchanged.
    V1Scalar,
    /// K-blocked, fixed-lane-count accumulators ([`V2_LANES`] logical
    /// lanes, fixed pairwise combine tree) with branch-free SIMD-friendly
    /// inner loops, plus [`V2_GRAD_GROUPS`]-way sample-parallel gradient
    /// reduction in the train programs.
    #[default]
    V2LaneTiled,
}

/// Kernel selection + thread budget for one backend instance.
#[derive(Debug, Clone, Copy)]
pub struct KernelCfg {
    /// Implementation to run (outputs are bit-identical either way).
    pub mode: KernelMode,
    /// Worker-thread cap for the blocked mode (1 = fully serial).
    pub threads: usize,
    /// Reduction-order version the blocked kernels commit to. Reference
    /// mode always runs the V1 order (it *is* the V1 oracle).
    pub order: ReductionOrder,
    /// Lane-group width hint for the V2 dot kernels: how many
    /// [`V2_LANES`]-wide groups each inner-loop iteration advances. Pure
    /// scheduling — every value yields identical bits (pinned by test).
    pub lane_groups: usize,
}

impl Default for KernelCfg {
    fn default() -> Self {
        Self {
            mode: KernelMode::Blocked,
            threads: default_threads(),
            order: default_reduction(),
            lane_groups: default_lane_groups(),
        }
    }
}

impl KernelCfg {
    /// The seed scalar kernels (single-threaded oracle, V1 order).
    pub fn reference() -> Self {
        Self {
            mode: KernelMode::Reference,
            threads: 1,
            order: ReductionOrder::V1Scalar,
            lane_groups: 1,
        }
    }

    /// Blocked kernels at an explicit thread cap, V1 order (the PR-5
    /// configuration — bit-identical to [`Self::reference`]).
    pub fn blocked(threads: usize) -> Self {
        Self {
            mode: KernelMode::Blocked,
            threads: threads.max(1),
            order: ReductionOrder::V1Scalar,
            lane_groups: 1,
        }
    }

    /// Blocked lane-tiled kernels (V2 order) at an explicit thread cap.
    pub fn v2(threads: usize) -> Self {
        Self {
            mode: KernelMode::Blocked,
            threads: threads.max(1),
            order: ReductionOrder::V2LaneTiled,
            lane_groups: default_lane_groups(),
        }
    }

    /// Same config with an explicit lane-group width (tests sweep this to
    /// pin V2's lane-width invariance).
    pub fn with_lane_groups(mut self, lane_groups: usize) -> Self {
        self.lane_groups = lane_groups.max(1);
        self
    }

    /// The reduction order actually executed: reference mode pins the V1
    /// oracle regardless of the configured `order`.
    pub fn effective_order(&self) -> ReductionOrder {
        if self.mode == KernelMode::Reference {
            ReductionOrder::V1Scalar
        } else {
            self.order
        }
    }
}

/// Parse an `RLFLOW_HOST_THREADS` value: a positive integer.
fn parse_threads(s: &str) -> Option<usize> {
    s.trim().parse::<usize>().ok().filter(|&n| n > 0)
}

/// Parse an `RLFLOW_HOST_REDUCTION` value: `v1` / `v2` (case- and
/// whitespace-insensitive; the long enum names are accepted too).
fn parse_reduction(s: &str) -> Option<ReductionOrder> {
    match s.trim().to_ascii_lowercase().as_str() {
        "v1" | "v1scalar" | "scalar" => Some(ReductionOrder::V1Scalar),
        "v2" | "v2lanetiled" | "lane-tiled" | "lanetiled" => Some(ReductionOrder::V2LaneTiled),
        _ => None,
    }
}

/// Default worker-thread cap: `RLFLOW_HOST_THREADS` when set and valid,
/// else the machine's available parallelism capped at 8 (the host
/// programs' GEMMs are too small to feed more). Invalid values warn on
/// stderr and fall back to the machine default instead of being silently
/// ignored.
pub fn default_threads() -> usize {
    let fallback = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
    match std::env::var("RLFLOW_HOST_THREADS") {
        Ok(s) => parse_threads(&s).unwrap_or_else(|| {
            eprintln!(
                "warning: ignoring invalid RLFLOW_HOST_THREADS={s:?} \
                 (expected a positive integer); using {fallback}"
            );
            fallback
        }),
        Err(std::env::VarError::NotPresent) => fallback,
        Err(std::env::VarError::NotUnicode(raw)) => {
            eprintln!(
                "warning: ignoring non-unicode RLFLOW_HOST_THREADS={raw:?}; using {fallback}"
            );
            fallback
        }
    }
}

/// Default reduction order: `RLFLOW_HOST_REDUCTION` (`v1`/`v2`) when set
/// and valid, else [`ReductionOrder::V2LaneTiled`]. Invalid values warn on
/// stderr and fall back to V2.
pub fn default_reduction() -> ReductionOrder {
    let fallback = ReductionOrder::V2LaneTiled;
    match std::env::var("RLFLOW_HOST_REDUCTION") {
        Ok(s) => parse_reduction(&s).unwrap_or_else(|| {
            eprintln!(
                "warning: ignoring invalid RLFLOW_HOST_REDUCTION={s:?} \
                 (expected \"v1\" or \"v2\"); using v2"
            );
            fallback
        }),
        Err(std::env::VarError::NotPresent) => fallback,
        Err(std::env::VarError::NotUnicode(raw)) => {
            eprintln!("warning: ignoring non-unicode RLFLOW_HOST_REDUCTION={raw:?}; using v2");
            fallback
        }
    }
}

/// Default lane-group width for the V2 dot kernels: 4 groups (32 floats in
/// flight) when the CPU has AVX2, else 2. Pure scheduling — V2 bits are
/// identical for every width, so feature detection never changes results.
#[cfg(target_arch = "x86_64")]
pub fn default_lane_groups() -> usize {
    if std::arch::is_x86_feature_detected!("avx2") {
        4
    } else {
        2
    }
}

/// Default lane-group width for the V2 dot kernels (non-x86 fallback).
#[cfg(not(target_arch = "x86_64"))]
pub fn default_lane_groups() -> usize {
    2
}

/// Activation fused into the forward GEMM epilogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// Plain affine output.
    None,
    /// `tanh` applied in the same pass over each finished output row.
    Tanh,
}

// ---------------------------------------------------------------------------
// Workspace
// ---------------------------------------------------------------------------

/// Cumulative scratch-arena accounting (monotone counters).
#[derive(Debug, Default, Clone, Copy)]
pub struct WorkspaceStats {
    /// Buffer checkouts served from the free list without allocating.
    pub reuses: u64,
    /// Buffer checkouts that had to allocate fresh memory.
    pub allocations: u64,
    /// Total bytes of fresh scratch memory allocated.
    pub alloc_bytes: u64,
}

/// A free-list arena of reusable `f32` scratch buffers.
///
/// The host nets draw every intermediate (activations, per-tensor gradient
/// buffers, LSTM gate planes) from here and return it before finishing, so
/// after a warm-up call per program the training hot path allocates no
/// scratch memory: `take` finds a parked buffer with enough capacity,
/// clears it and hands it back. Buffers are zero-filled exactly like the
/// `vec![0.0; n]` allocations they replace, so recycling is invisible to
/// the numerics.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    free_idx: Vec<Vec<usize>>,
    free_i32: Vec<Vec<i32>>,
    /// Parked child arenas for sample-parallel fan-out
    /// ([`Self::take_children`]); each keeps its own free lists so worker
    /// scratch recycles across checkouts.
    children: Vec<Workspace>,
    stats: WorkspaceStats,
}

impl Workspace {
    /// An empty arena (buffers are allocated lazily on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Cumulative reuse/allocation counters.
    pub fn stats(&self) -> WorkspaceStats {
        self.stats
    }

    /// Check out a zero-filled buffer of `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        // Best fit: the smallest parked buffer that already has capacity,
        // so a tiny request never pins the arena's largest buffer.
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in self.free.iter().enumerate() {
            let cap = b.capacity();
            if cap >= len && best.map_or(true, |(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                let mut b = self.free.swap_remove(i);
                b.clear();
                b.resize(len, 0.0);
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.allocations += 1;
                self.stats.alloc_bytes += (len * std::mem::size_of::<f32>()) as u64;
                vec![0.0; len]
            }
        }
    }

    /// Check out a buffer initialised as a copy of `src`.
    pub fn take_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut b = self.take(src.len());
        b.copy_from_slice(src);
        b
    }

    /// Return a buffer to the free list for later reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Return several buffers at once.
    pub fn put_all<I: IntoIterator<Item = Vec<f32>>>(&mut self, bufs: I) {
        for b in bufs {
            self.put(b);
        }
    }

    /// Check out an *empty* index buffer (callers push into it).
    pub fn take_idx(&mut self) -> Vec<usize> {
        match self.free_idx.pop() {
            Some(mut b) => {
                b.clear();
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.allocations += 1;
                Vec::new()
            }
        }
    }

    /// Return an index buffer to the free list.
    pub fn put_idx(&mut self, buf: Vec<usize>) {
        if buf.capacity() > 0 {
            self.free_idx.push(buf);
        }
    }

    /// Check out a zero-filled i32 buffer of `len` elements.
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        match self.free_i32.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                let mut b = self.free_i32.swap_remove(i);
                b.clear();
                b.resize(len, 0);
                self.stats.reuses += 1;
                b
            }
            None => {
                self.stats.allocations += 1;
                self.stats.alloc_bytes += (len * std::mem::size_of::<i32>()) as u64;
                vec![0; len]
            }
        }
    }

    /// Return an i32 buffer to the free list.
    pub fn put_i32(&mut self, buf: Vec<i32>) {
        if buf.capacity() > 0 {
            self.free_i32.push(buf);
        }
    }

    /// Check out `n` independent child arenas, one per worker of a
    /// sample-parallel region. Children keep their free lists across
    /// checkouts (checkout order is stable, so each group sees the same
    /// arena — and therefore the same parked buffers — every call), which
    /// keeps per-group scratch zero-alloc in steady state.
    pub fn take_children(&mut self, n: usize) -> Vec<Workspace> {
        while self.children.len() < n {
            self.children.push(Workspace::new());
        }
        let at = self.children.len() - n;
        self.children.drain(at..).collect()
    }

    /// Park child arenas again, folding their activity into this arena's
    /// counters. Children report deltas — their counters reset on every
    /// put — so parent stats stay monotone without double counting.
    pub fn put_children(&mut self, children: Vec<Workspace>) {
        for mut child in children {
            let s = std::mem::take(&mut child.stats);
            self.stats.reuses += s.reuses;
            self.stats.allocations += s.allocations;
            self.stats.alloc_bytes += s.alloc_bytes;
            self.children.push(child);
        }
    }
}

// ---------------------------------------------------------------------------
// Threading helper
// ---------------------------------------------------------------------------

/// Worker count for a row-partitioned kernel: 1 in reference mode or
/// unless the config allows more, there are rows to split, and the
/// arithmetic volume clears [`PAR_MIN_MACS`]. Purely a scheduling decision
/// — outputs are identical for every return value. Public so the nets can
/// stripe their own row-independent loops (e.g. the GNN's neighbourhood
/// aggregation) under the same policy.
pub fn plan_threads(cfg: &KernelCfg, rows: usize, macs: usize) -> usize {
    if cfg.mode == KernelMode::Reference || cfg.threads <= 1 || rows <= 1 || macs < PAR_MIN_MACS {
        1
    } else {
        cfg.threads.min(rows)
    }
}

/// Split `out` into `t` contiguous row stripes and run `body(first_row,
/// stripe)` on each, fanning out over scoped threads when `t > 1`. The
/// stripe boundaries depend only on `(rows, t)`, and every row is written
/// by exactly one worker.
pub fn par_row_stripes<F>(out: &mut [f32], rows: usize, row_w: usize, t: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_w);
    if t <= 1 || rows <= 1 {
        body(0, out);
        return;
    }
    let per = (rows + t - 1) / t;
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut r0 = 0;
        while !rest.is_empty() {
            let take = (per * row_w).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            let first = r0;
            let bref = &body;
            scope.spawn(move || bref(first, chunk));
            r0 += take / row_w;
            rest = tail;
        }
    });
}

// ---------------------------------------------------------------------------
// V2 lane primitives
// ---------------------------------------------------------------------------

/// V2 lane-order dot product at a monomorphised lane-group width: lane `ℓ`
/// of a fixed [`V2_LANES`]-wide accumulator array owns the elements with
/// index ≡ `ℓ` (mod `V2_LANES`), visited ascending; the lanes combine in a
/// fixed pairwise tree. `UNROLL` only regroups *independent* lanes into
/// wider straight-line blocks, so every width yields identical bits.
#[inline]
fn dot_v2_groups<const UNROLL: usize>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [0.0f32; V2_LANES];
    let step = V2_LANES * UNROLL;
    let mut i = 0;
    while i + step <= n {
        for u in 0..UNROLL {
            let base = i + u * V2_LANES;
            let (ar, br) = (&a[base..base + V2_LANES], &b[base..base + V2_LANES]);
            for l in 0..V2_LANES {
                acc[l] += ar[l] * br[l];
            }
        }
        i += step;
    }
    while i + V2_LANES <= n {
        let (ar, br) = (&a[i..i + V2_LANES], &b[i..i + V2_LANES]);
        for l in 0..V2_LANES {
            acc[l] += ar[l] * br[l];
        }
        i += V2_LANES;
    }
    // Tail elements land in lanes 0.. in order, matching a final partial
    // lane group.
    for (l, j) in (i..n).enumerate() {
        acc[l] += a[j] * b[j];
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Runtime dispatch over the V2 lane-group width. The width is a pure
/// scheduling hint (see [`KernelCfg::lane_groups`]); bits are identical
/// for every value.
#[inline]
pub fn dot_v2(lane_groups: usize, a: &[f32], b: &[f32]) -> f32 {
    match lane_groups {
        0 | 1 => dot_v2_groups::<1>(a, b),
        2 | 3 => dot_v2_groups::<2>(a, b),
        _ => dot_v2_groups::<4>(a, b),
    }
}

/// `dst += a * src` in the V2 lane idiom: the body is emitted as fixed
/// [`V2_LANES`]-wide straight-line blocks plus a scalar remainder. Each
/// element is independent, so the element order — and the bit pattern —
/// matches the plain zip loop; the chunking only guarantees the compiler a
/// branch-free vectorisable body (the GNN neighbourhood aggregation's V2
/// inner loop).
#[inline]
pub fn axpy_v2(a: f32, src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    let n = dst.len();
    let groups = n / V2_LANES;
    for g in 0..groups {
        let base = g * V2_LANES;
        let s = &src[base..base + V2_LANES];
        let d = &mut dst[base..base + V2_LANES];
        for l in 0..V2_LANES {
            d[l] += a * s[l];
        }
    }
    for j in groups * V2_LANES..n {
        dst[j] += a * src[j];
    }
}

// ---------------------------------------------------------------------------
// V2 sample-parallel gradient reduction
// ---------------------------------------------------------------------------

/// The fixed sample partition of the V2 gradient reduction: `b` samples
/// split into at most [`V2_GRAD_GROUPS`] contiguous, non-empty groups.
/// Depends only on `b`, so the grouping — and therefore the bit pattern of
/// the tree-reduced gradient — is identical for every worker count.
pub fn v2_sample_groups(b: usize) -> Vec<std::ops::Range<usize>> {
    let g = V2_GRAD_GROUPS.min(b).max(1);
    (0..g).map(|i| i * b / g..(i + 1) * b / g).filter(|r| !r.is_empty()).collect()
}

/// Fixed pairwise tree reduction over equal-length buffers: folds
/// `bufs[i + gap]` into `bufs[i]` with stride-doubling gaps, leaving the
/// total in `bufs[0]`. The combine order depends only on `bufs.len()`,
/// never on which worker produced which buffer — part of the V2 bit
/// contract.
pub fn tree_reduce_sum(bufs: &mut [Vec<f32>]) {
    let nb = bufs.len();
    let mut gap = 1;
    while gap < nb {
        let mut i = 0;
        while i + gap < nb {
            let (left, right) = bufs.split_at_mut(i + gap);
            for (d, s) in left[i].iter_mut().zip(&right[0]) {
                *d += s;
            }
            i += 2 * gap;
        }
        gap *= 2;
    }
}

/// Sample-parallel gradient harness for the V2 `*_train` programs.
///
/// Splits the batch with [`v2_sample_groups`] and runs `body(rows, cfg,
/// child_ws, grad, aux)` once per group — each group gets its own child
/// arena, a zeroed `grad_len` gradient buffer, and a zeroed `aux_len` loss
/// accumulator — then folds the group buffers with [`tree_reduce_sum`].
/// Groups fan out over scoped threads when the arithmetic volume (`macs`)
/// clears the threading threshold; the partition and the combine tree are
/// fixed, so the returned `(grad, aux)` buffers are bit-identical for any
/// worker count. All scratch comes from (and returns to) `ws`, keeping the
/// steady state zero-alloc.
pub fn v2_accumulate_grads<F>(
    ws: &mut Workspace,
    cfg: &KernelCfg,
    b: usize,
    grad_len: usize,
    aux_len: usize,
    macs: usize,
    body: F,
) -> (Vec<f32>, Vec<f32>)
where
    F: Fn(std::ops::Range<usize>, &KernelCfg, &mut Workspace, &mut [f32], &mut [f32]) + Sync,
{
    let groups = v2_sample_groups(b);
    let g = groups.len();
    if g == 0 {
        return (ws.take(grad_len), ws.take(aux_len));
    }
    let mut grads: Vec<Vec<f32>> = (0..g).map(|_| ws.take(grad_len)).collect();
    let mut auxs: Vec<Vec<f32>> = (0..g).map(|_| ws.take(aux_len)).collect();
    let mut kids = ws.take_children(g);
    let t = plan_threads(cfg, g, macs);
    if t <= 1 {
        // Serial groups: keep the caller's config so the per-group kernels
        // may still stripe internally (bits are invariant either way).
        for (i, rows) in groups.iter().enumerate() {
            body(rows.clone(), cfg, &mut kids[i], &mut grads[i], &mut auxs[i]);
        }
    } else {
        // Workers own whole groups; the in-group kernels run serial to
        // avoid oversubscription. Purely a schedule — same bits.
        let inner = KernelCfg { threads: 1, ..*cfg };
        let mut items: Vec<_> = groups
            .iter()
            .cloned()
            .zip(kids.iter_mut())
            .zip(grads.iter_mut())
            .zip(auxs.iter_mut())
            .map(|(((rows, kid), grad), aux)| (rows, kid, grad, aux))
            .collect();
        let per = (g + t - 1) / t;
        std::thread::scope(|scope| {
            for chunk in items.chunks_mut(per) {
                let bref = &body;
                let icfg = &inner;
                scope.spawn(move || {
                    for (rows, kid, grad, aux) in chunk.iter_mut() {
                        bref(rows.clone(), icfg, kid, grad, aux);
                    }
                });
            }
        });
    }
    ws.put_children(kids);
    tree_reduce_sum(&mut grads);
    tree_reduce_sum(&mut auxs);
    let grad = grads.swap_remove(0);
    let aux = auxs.swap_remove(0);
    ws.put_all(grads);
    ws.put_all(auxs);
    (grad, aux)
}

// ---------------------------------------------------------------------------
// Forward: y = x w (+ bias) (+ activation)
// ---------------------------------------------------------------------------

/// `y = act(x w + bias)` over `m` rows: x `[m,k]`, w `[k,n]`, bias `[n]`
/// (or none for a pure matmul), y `[m,n]`. The fused activation runs in
/// the same pass over each finished row. Bit-identical to
/// [`nn::linear_reference`] followed by a `tanh` sweep, for any thread
/// count.
pub fn linear_into(
    cfg: &KernelCfg,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(y.len(), m * n);
    if let Some(b) = bias {
        debug_assert_eq!(b.len(), n);
    }
    if cfg.mode == KernelMode::Reference {
        for r in 0..m {
            let yr = &mut y[r * n..(r + 1) * n];
            match bias {
                Some(b) => yr.copy_from_slice(b),
                None => yr.fill(0.0),
            }
            for i in 0..k {
                let xv = x[r * k + i];
                if xv == 0.0 {
                    continue;
                }
                let wr = &w[i * n..(i + 1) * n];
                for (yj, wj) in yr.iter_mut().zip(wr) {
                    *yj += xv * wj;
                }
            }
            if act == Act::Tanh {
                nn::tanh_inplace(yr);
            }
        }
        return;
    }
    let t = plan_threads(cfg, m, m * k * n);
    match cfg.effective_order() {
        ReductionOrder::V1Scalar => par_row_stripes(y, m, n, t, |r0, chunk| {
            for (ri, yr) in chunk.chunks_exact_mut(n).enumerate() {
                let r = r0 + ri;
                match bias {
                    Some(b) => yr.copy_from_slice(b),
                    None => yr.fill(0.0),
                }
                let xr = &x[r * k..(r + 1) * k];
                // Column blocks keep the y block and each w row block hot;
                // the per-element accumulation order stays k ascending
                // (with the reference's exact-zero skip), so blocking is
                // invisible to the bit pattern.
                let mut jb = 0;
                while jb < n {
                    let je = (jb + NC).min(n);
                    for (i, &xv) in xr.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wr = &w[i * n + jb..i * n + je];
                        for (yj, wj) in yr[jb..je].iter_mut().zip(wr) {
                            *yj += xv * wj;
                        }
                    }
                    jb = je;
                }
                if act == Act::Tanh {
                    nn::tanh_inplace(yr);
                }
            }
        }),
        ReductionOrder::V2LaneTiled => par_row_stripes(y, m, n, t, |r0, chunk| {
            for (ri, yr) in chunk.chunks_exact_mut(n).enumerate() {
                let r = r0 + ri;
                match bias {
                    Some(b) => yr.copy_from_slice(b),
                    None => yr.fill(0.0),
                }
                let xr = &x[r * k..(r + 1) * k];
                // V2: k-blocked and branch-free. Each y element still
                // accumulates k ascending (blocks ascending, in-block k
                // ascending) but without the data-dependent zero skip, so
                // the j loop is straight-line lane code the compiler turns
                // into f32 SIMD. Output elements are independent, so
                // neither threads nor lane width can change bits.
                let mut jb = 0;
                while jb < n {
                    let je = (jb + NC).min(n);
                    let mut kb = 0;
                    while kb < k {
                        let ke = (kb + V2_KB).min(k);
                        for i in kb..ke {
                            let xv = xr[i];
                            let wr = &w[i * n + jb..i * n + je];
                            for (yj, wj) in yr[jb..je].iter_mut().zip(wr) {
                                *yj += xv * wj;
                            }
                        }
                        kb = ke;
                    }
                    jb = je;
                }
                if act == Act::Tanh {
                    nn::tanh_inplace(yr);
                }
            }
        }),
    }
}

// ---------------------------------------------------------------------------
// Backward: dw += xᵀ dy
// ---------------------------------------------------------------------------

/// `dw += xᵀ dy`: x `[m,k]`, dy `[m,n]`, dw `[k,n]`. Parallel over stripes
/// of `k` (each worker owns whole dw rows); per-element accumulation order
/// is sample-row ascending, exactly like [`nn::acc_xt_dy_reference`].
pub fn acc_xt_dy(
    cfg: &KernelCfg,
    x: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    if cfg.mode == KernelMode::Reference {
        nn::acc_xt_dy_reference(x, dy, m, k, n, dw);
        return;
    }
    let t = plan_threads(cfg, k, m * k * n);
    match cfg.effective_order() {
        ReductionOrder::V1Scalar => par_row_stripes(dw, k, n, t, |i0, chunk| {
            for (ii, dwr) in chunk.chunks_exact_mut(n).enumerate() {
                let i = i0 + ii;
                for r in 0..m {
                    let xv = x[r * k + i];
                    if xv == 0.0 {
                        continue;
                    }
                    let dyr = &dy[r * n..(r + 1) * n];
                    for (dwj, dyj) in dwr.iter_mut().zip(dyr) {
                        *dwj += xv * dyj;
                    }
                }
            }
        }),
        // V2: same sample-row-ascending per-element order, but branch-free
        // (no zero skip) so the axpy over each dw row vectorises.
        ReductionOrder::V2LaneTiled => par_row_stripes(dw, k, n, t, |i0, chunk| {
            for (ii, dwr) in chunk.chunks_exact_mut(n).enumerate() {
                let i = i0 + ii;
                for r in 0..m {
                    let xv = x[r * k + i];
                    axpy_v2(xv, &dy[r * n..(r + 1) * n], dwr);
                }
            }
        }),
    }
}

// ---------------------------------------------------------------------------
// Backward: dx = dy wᵀ
// ---------------------------------------------------------------------------

/// `dx = dy wᵀ`: dy `[m,n]`, w `[k,n]`, dx `[m,k]`. Parallel over row
/// stripes of dx; per-element reduction order is column ascending, exactly
/// like [`nn::dy_wt_reference`].
pub fn dy_wt_into(
    cfg: &KernelCfg,
    dy: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    if cfg.mode == KernelMode::Reference {
        for r in 0..m {
            let dyr = &dy[r * n..(r + 1) * n];
            for i in 0..k {
                let wr = &w[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (dyj, wj) in dyr.iter().zip(wr) {
                    acc += dyj * wj;
                }
                dx[r * k + i] = acc;
            }
        }
        return;
    }
    let t = plan_threads(cfg, m, m * k * n);
    match cfg.effective_order() {
        ReductionOrder::V1Scalar => par_row_stripes(dx, m, k, t, |r0, chunk| {
            for (ri, dxr) in chunk.chunks_exact_mut(k).enumerate() {
                let dyr = &dy[(r0 + ri) * n..(r0 + ri + 1) * n];
                for (i, dst) in dxr.iter_mut().enumerate() {
                    let wr = &w[i * n..(i + 1) * n];
                    let mut acc = 0.0f32;
                    for (dyj, wj) in dyr.iter().zip(wr) {
                        acc += dyj * wj;
                    }
                    *dst = acc;
                }
            }
        }),
        // V2: the serial dependency chain of the scalar dot is the SIMD
        // blocker here — dot_v2's independent lane accumulators break it.
        ReductionOrder::V2LaneTiled => {
            let lg = cfg.lane_groups.max(1);
            par_row_stripes(dx, m, k, t, |r0, chunk| {
                for (ri, dxr) in chunk.chunks_exact_mut(k).enumerate() {
                    let dyr = &dy[(r0 + ri) * n..(r0 + ri + 1) * n];
                    for (i, dst) in dxr.iter_mut().enumerate() {
                        *dst = dot_v2(lg, dyr, &w[i * n..(i + 1) * n]);
                    }
                }
            });
        }
    }
}

/// `dx += dy wᵀ` (accumulating form for head-gradient merges): same
/// reduction order as [`dy_wt_into`] per added term.
pub fn dy_wt_acc(
    cfg: &KernelCfg,
    dy: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dx.len(), m * k);
    if cfg.mode == KernelMode::Reference {
        for r in 0..m {
            let dyr = &dy[r * n..(r + 1) * n];
            for i in 0..k {
                let wr = &w[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (dyj, wj) in dyr.iter().zip(wr) {
                    acc += dyj * wj;
                }
                dx[r * k + i] += acc;
            }
        }
        return;
    }
    let t = plan_threads(cfg, m, m * k * n);
    match cfg.effective_order() {
        ReductionOrder::V1Scalar => par_row_stripes(dx, m, k, t, |r0, chunk| {
            for (ri, dxr) in chunk.chunks_exact_mut(k).enumerate() {
                let dyr = &dy[(r0 + ri) * n..(r0 + ri + 1) * n];
                for (i, dst) in dxr.iter_mut().enumerate() {
                    let wr = &w[i * n..(i + 1) * n];
                    let mut acc = 0.0f32;
                    for (dyj, wj) in dyr.iter().zip(wr) {
                        acc += dyj * wj;
                    }
                    *dst += acc;
                }
            }
        }),
        ReductionOrder::V2LaneTiled => {
            let lg = cfg.lane_groups.max(1);
            par_row_stripes(dx, m, k, t, |r0, chunk| {
                for (ri, dxr) in chunk.chunks_exact_mut(k).enumerate() {
                    let dyr = &dy[(r0 + ri) * n..(r0 + ri + 1) * n];
                    for (i, dst) in dxr.iter_mut().enumerate() {
                        *dst += dot_v2(lg, dyr, &w[i * n..(i + 1) * n]);
                    }
                }
            });
        }
    }
}

/// Backward through a fused tanh epilogue: `dpre = dy * (1 - y²)` where
/// `y` is the *activated* forward output, written over `dy` in place.
pub fn tanh_backward_inplace(dy: &mut [f32], y: &[f32]) {
    debug_assert_eq!(dy.len(), y.len());
    for (d, v) in dy.iter_mut().zip(y) {
        *d *= 1.0 - v * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_with_zeros(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if rng.below(5) == 0 { 0.0 } else { rng.normal() })
            .collect()
    }

    #[test]
    fn blocked_linear_matches_reference_for_all_thread_counts() {
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(1, 4, 3), (5, 7, 9), (33, 17, 21), (320, 32, 32)] {
            let x = rand_with_zeros(&mut rng, m * k);
            let w = rand_with_zeros(&mut rng, k * n);
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for act in [Act::None, Act::Tanh] {
                let mut want = vec![0.0f32; m * n];
                linear_into(&KernelCfg::reference(), &x, &w, Some(&b), m, k, n, act, &mut want);
                for threads in [1, 2, 8] {
                    let mut got = vec![0.0f32; m * n];
                    linear_into(
                        &KernelCfg::blocked(threads),
                        &x,
                        &w,
                        Some(&b),
                        m,
                        k,
                        n,
                        act,
                        &mut got,
                    );
                    assert_eq!(want, got, "linear m={m} k={k} n={n} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn fused_tanh_equals_seed_linear_then_tanh() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (6, 5, 4);
        let x = rand_with_zeros(&mut rng, m * k);
        let w = rand_with_zeros(&mut rng, k * n);
        let b = vec![0.25f32; n];
        let mut seed = nn::linear_reference(&x, &w, &b, m, k, n);
        nn::tanh_inplace(&mut seed);
        let mut fused = vec![0.0f32; m * n];
        linear_into(&KernelCfg::blocked(4), &x, &w, Some(&b), m, k, n, Act::Tanh, &mut fused);
        assert_eq!(seed, fused);
    }

    #[test]
    fn blocked_acc_xt_dy_matches_reference_for_all_thread_counts() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(2, 3, 4), (9, 13, 7), (64, 48, 64)] {
            let x = rand_with_zeros(&mut rng, m * k);
            let dy = rand_with_zeros(&mut rng, m * n);
            let init: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.1).collect();
            let mut want = init.clone();
            nn::acc_xt_dy_reference(&x, &dy, m, k, n, &mut want);
            for threads in [1, 2, 8] {
                let mut got = init.clone();
                acc_xt_dy(&KernelCfg::blocked(threads), &x, &dy, m, k, n, &mut got);
                assert_eq!(want, got, "acc_xt_dy m={m} k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn blocked_dy_wt_matches_reference_for_all_thread_counts() {
        let mut rng = Rng::new(13);
        for &(m, n, k) in &[(2, 3, 4), (17, 9, 11), (64, 64, 48)] {
            let dy = rand_with_zeros(&mut rng, m * n);
            let w = rand_with_zeros(&mut rng, k * n);
            let want = nn::dy_wt_reference(&dy, &w, m, n, k);
            for threads in [1, 2, 8] {
                let mut got = vec![0.0f32; m * k];
                dy_wt_into(&KernelCfg::blocked(threads), &dy, &w, m, n, k, &mut got);
                assert_eq!(want, got, "dy_wt m={m} n={n} k={k} threads={threads}");
                let mut acc = want.clone();
                dy_wt_acc(&KernelCfg::blocked(threads), &dy, &w, m, n, k, &mut acc);
                let doubled: Vec<f32> = want.iter().map(|v| v + v).collect();
                assert_eq!(doubled, acc, "dy_wt_acc accumulates");
            }
        }
    }

    #[test]
    fn workspace_reuses_after_warmup() {
        let mut ws = Workspace::new();
        let a = ws.take(64);
        let b = ws.take(128);
        assert_eq!(ws.stats().allocations, 2);
        ws.put(a);
        ws.put(b);
        // Steady state: every take is served from the free list.
        for _ in 0..10 {
            let a = ws.take(64);
            let b = ws.take(100); // fits the 128-capacity buffer
            assert!(a.iter().all(|&v| v == 0.0), "recycled buffers must be zeroed");
            ws.put(a);
            ws.put(b);
        }
        assert_eq!(ws.stats().allocations, 2, "no new allocations after warm-up");
        assert_eq!(ws.stats().reuses, 20);
    }

    #[test]
    fn workspace_best_fit_prefers_smallest_buffer() {
        let mut ws = Workspace::new();
        let big = ws.take(1000);
        let small = ws.take(10);
        ws.put(big);
        ws.put(small);
        let got = ws.take(8);
        assert!(got.capacity() < 1000, "tiny request must not pin the big buffer");
        ws.put(got);
    }

    #[test]
    fn tanh_backward_matches_manual() {
        let y = vec![0.5f32, -0.25, 0.0];
        let mut dy = vec![2.0f32, 2.0, 2.0];
        tanh_backward_inplace(&mut dy, &y);
        assert_eq!(dy, vec![2.0 * (1.0 - 0.25), 2.0 * (1.0 - 0.0625), 2.0]);
    }

    #[test]
    fn par_row_stripes_covers_every_row_once() {
        let rows = 7;
        let mut out = vec![0.0f32; rows * 3];
        par_row_stripes(&mut out, rows, 3, 3, |r0, chunk| {
            for (ri, row) in chunk.chunks_exact_mut(3).enumerate() {
                row.fill((r0 + ri) as f32 + 1.0);
            }
        });
        for r in 0..rows {
            assert!(out[r * 3..(r + 1) * 3].iter().all(|&v| v == r as f32 + 1.0));
        }
    }

    #[test]
    fn env_override_parsers_accept_valid_and_reject_garbage() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 \n"), Some(2));
        assert_eq!(parse_threads("0"), None);
        assert_eq!(parse_threads("-3"), None);
        assert_eq!(parse_threads("four"), None);
        assert_eq!(parse_threads(""), None);
        assert_eq!(parse_reduction("v1"), Some(ReductionOrder::V1Scalar));
        assert_eq!(parse_reduction("V1"), Some(ReductionOrder::V1Scalar));
        assert_eq!(parse_reduction(" scalar "), Some(ReductionOrder::V1Scalar));
        assert_eq!(parse_reduction("v2"), Some(ReductionOrder::V2LaneTiled));
        assert_eq!(parse_reduction("V2LaneTiled"), Some(ReductionOrder::V2LaneTiled));
        assert_eq!(parse_reduction("v3"), None);
        assert_eq!(parse_reduction(""), None);
        // The defaults never panic whatever the process env holds, and
        // stay inside the valid domain.
        assert!(default_threads() >= 1);
        let _ = default_reduction();
        assert!(default_lane_groups() >= 1);
    }

    #[test]
    fn reference_mode_pins_the_v1_order() {
        let cfg = KernelCfg {
            mode: KernelMode::Reference,
            threads: 1,
            order: ReductionOrder::V2LaneTiled,
            lane_groups: 4,
        };
        assert_eq!(cfg.effective_order(), ReductionOrder::V1Scalar);
        assert_eq!(KernelCfg::v2(3).effective_order(), ReductionOrder::V2LaneTiled);
        assert_eq!(KernelCfg::blocked(3).effective_order(), ReductionOrder::V1Scalar);
    }

    #[test]
    fn v2_dot_is_lane_width_invariant_on_remainder_shapes() {
        let mut rng = Rng::new(17);
        for n in [0usize, 1, 7, 8, 9, 15, 16, 31, 33, 64, 130] {
            let a = rand_with_zeros(&mut rng, n);
            let b = rand_with_zeros(&mut rng, n);
            let base = dot_v2(1, &a, &b);
            for lg in [2, 3, 4, 8, 16] {
                assert_eq!(
                    base.to_bits(),
                    dot_v2(lg, &a, &b).to_bits(),
                    "dot_v2 n={n} lane_groups={lg}"
                );
            }
        }
    }

    #[test]
    fn axpy_v2_matches_plain_loop_bitwise() {
        let mut rng = Rng::new(19);
        for n in [0usize, 1, 7, 8, 9, 23, 64, 130] {
            let src = rand_with_zeros(&mut rng, n);
            let a = rng.normal();
            let init = rand_with_zeros(&mut rng, n);
            let mut want = init.clone();
            for (d, s) in want.iter_mut().zip(&src) {
                *d += a * s;
            }
            let mut got = init.clone();
            axpy_v2(a, &src, &mut got);
            assert_eq!(want, got, "axpy n={n}");
        }
    }

    #[test]
    fn tree_reduce_is_a_fixed_pairwise_tree() {
        // Single-element buffers chosen so f32 rounding distinguishes the
        // pairwise tree from a left-to-right fold.
        let vals = [1.0e8f32, 1.0, -1.0e8, 1.0, 1.0];
        let mut bufs: Vec<Vec<f32>> = vals.iter().map(|&v| vec![v]).collect();
        tree_reduce_sum(&mut bufs);
        let expected = ((vals[0] + vals[1]) + (vals[2] + vals[3])) + vals[4];
        assert_eq!(bufs[0][0].to_bits(), expected.to_bits());
        let folded = vals.iter().copied().fold(0.0f32, |a, v| a + v);
        assert_ne!(
            bufs[0][0].to_bits(),
            folded.to_bits(),
            "test inputs must actually exercise order sensitivity"
        );
    }

    #[test]
    fn sample_groups_are_fixed_contiguous_and_cover_the_batch() {
        for b in [0usize, 1, 2, 5, 8, 13, 16, 64, 100] {
            let groups = v2_sample_groups(b);
            assert!(groups.len() <= V2_GRAD_GROUPS);
            let mut next = 0;
            for r in &groups {
                assert_eq!(r.start, next, "groups must tile the batch, b={b}");
                assert!(r.end > r.start, "no empty groups, b={b}");
                next = r.end;
            }
            assert_eq!(next, b, "groups must cover the batch, b={b}");
        }
    }

    #[test]
    fn workspace_children_are_recycled_and_fold_stats() {
        let mut ws = Workspace::new();
        let mut kids = ws.take_children(3);
        let b = kids[0].take(32);
        kids[0].put(b);
        ws.put_children(kids);
        let s1 = ws.stats();
        assert_eq!(s1.allocations, 1);
        // Second checkout: same arena order, so the parked buffer is found
        // again and the parent counters fold the delta only.
        let mut kids = ws.take_children(3);
        let b = kids[0].take(32);
        kids[0].put(b);
        ws.put_children(kids);
        let s2 = ws.stats();
        assert_eq!(s2.allocations, 1, "child arenas keep buffers across checkouts");
        assert_eq!(s2.reuses, 1);
        assert_eq!(s2.alloc_bytes, s1.alloc_bytes);
    }

    #[test]
    fn v2_accumulate_grads_bits_invariant_across_worker_counts() {
        let run = |threads: usize| {
            let mut ws = Workspace::new();
            let cfg = KernelCfg::v2(threads);
            // usize::MAX macs forces the threaded path whenever threads>1.
            v2_accumulate_grads(&mut ws, &cfg, 13, 6, 2, usize::MAX, |rows, _cfg, cw, grad, aux| {
                let scratch = cw.take(4);
                for s in rows {
                    for (j, g) in grad.iter_mut().enumerate() {
                        *g += ((s * 7 + j) as f32).sin();
                    }
                    aux[0] += s as f32;
                    aux[1] += 1.0;
                }
                cw.put(scratch);
            })
        };
        let (g1, a1) = run(1);
        assert_eq!(a1[1], 13.0, "every sample visited exactly once");
        for t in [2, 3, 8] {
            let (g, a) = run(t);
            assert_eq!(g1, g, "grad bits at threads={t}");
            assert_eq!(a1, a, "aux bits at threads={t}");
        }
    }
}
