//! Host GNN auto-encoder: one round of neighbourhood message passing, a
//! pooled latent head, and a two-part reconstruction loss (per-node feature
//! decoder + graph-level decoder through the latent) so every parameter
//! tensor receives gradient. Mirrors the `gnn_*` artifact contract:
//! `gnn_init`, `gnn_encode_1`, `gnn_encode_b`, `gnn_ae_train`.
//!
//! All dense math runs through the mode-switchable kernels in
//! [`super::kernels`] (blocked + threaded by default, the seed scalar
//! loops in reference mode — bit-identical either way), and every
//! intermediate buffer is drawn from the caller's [`Workspace`] so
//! steady-state training allocates no scratch memory.

use super::kernels::{
    acc_xt_dy, axpy_v2, dy_wt_into, linear_into, par_row_stripes, plan_threads,
    v2_accumulate_grads, Act, KernelCfg, ReductionOrder, Workspace,
};
use super::nn::{acc_rows, adam_step, ParamLayout};

pub struct GnnNet {
    pub n: usize,
    pub f: usize,
    pub h: usize,
    pub z: usize,
    pub layout: ParamLayout,
}

/// Per-sample forward activations kept for the backward pass. Every buffer
/// is workspace-owned; call [`GnnFwd::recycle`] when done.
struct GnnFwd {
    live: Vec<usize>,
    msg: Vec<f32>,    // [live, F] aggregated neighbourhood features
    hid: Vec<f32>,    // [live, H] tanh hidden rows
    pooled: Vec<f32>, // [H]
    z: Vec<f32>,      // [Z]
    xbar: Vec<f32>,   // [F] mean live feature row
}

impl GnnFwd {
    fn recycle(self, ws: &mut Workspace) {
        ws.put_idx(self.live);
        ws.put_all([self.msg, self.hid, self.pooled, self.z, self.xbar]);
    }
}

impl GnnNet {
    pub fn new(n: usize, f: usize, h: usize, z: usize) -> Self {
        let mut layout = ParamLayout::new();
        layout.add("w1", f * h, f);
        layout.add("b1", h, 0);
        layout.add("w2", h * z, h);
        layout.add("b2", z, 0);
        layout.add("w3", h * f, h);
        layout.add("b3", f, 0);
        layout.add("w4", z * f, z);
        layout.add("b4", f, 0);
        Self { n, f, h, z, layout }
    }

    pub fn n_params(&self) -> usize {
        self.layout.total()
    }

    pub fn init(&self, seed: i32) -> Vec<f32> {
        // Family tag keeps gnn/wm/ctrl streams distinct for equal seeds.
        self.layout.init(0x676E6E ^ (seed as u64).wrapping_mul(0x9E3779B97F4A7C15), |_| 0.0)
    }

    /// Forward one sample. `feats` `[N,F]`, `adj` `[N,N]`, `mask` `[N]`.
    fn forward(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        feats: &[f32],
        adj: &[f32],
        mask: &[f32],
    ) -> GnnFwd {
        let (n, f, h, z) = (self.n, self.f, self.h, self.z);
        let mut live = ws.take_idx();
        live.extend((0..n).filter(|&i| mask[i] > 0.5));
        let l = live.len();
        let denom = l.max(1) as f32;

        // msg_i = (x_i + Σ_j a[j,i] x_j + Σ_j a[i,j] x_j) / deg_i — a fixed
        // linear aggregation, so no gradient flows through it. Rows are
        // independent, so the O(l²·F) loop stripes across threads with the
        // same bit pattern at any count.
        let mut msg = ws.take(l * f);
        let t = plan_threads(kc, l, l * l * f);
        {
            let live = &live;
            par_row_stripes(&mut msg, l, f, t, |r0, chunk| {
                for (ri, row) in chunk.chunks_exact_mut(f).enumerate() {
                    let i = live[r0 + ri];
                    let mut deg = 1.0f32;
                    row.copy_from_slice(&feats[i * f..(i + 1) * f]);
                    for &j in live.iter() {
                        let w_in = adj[j * n + i];
                        let w_out = adj[i * n + j];
                        let w = w_in + w_out;
                        if w > 0.0 {
                            deg += w;
                            // Lane-chunked axpy (bit-identical to the plain
                            // zip loop — elements are independent — so the
                            // aggregation order is shared by both reduction
                            // versions; the chunking just keeps the body
                            // branch-free SIMD lane code).
                            axpy_v2(w, &feats[j * f..(j + 1) * f], row);
                        }
                    }
                    let inv = 1.0 / deg;
                    for r in row.iter_mut() {
                        *r *= inv;
                    }
                }
            });
        }

        let mut hid = ws.take(l * h);
        linear_into(
            kc,
            &msg,
            self.layout.view(theta, "w1"),
            Some(self.layout.view(theta, "b1")),
            l,
            f,
            h,
            Act::Tanh,
            &mut hid,
        );

        let mut pooled = ws.take(h);
        for ri in 0..l {
            for (p, v) in pooled.iter_mut().zip(&hid[ri * h..(ri + 1) * h]) {
                *p += v;
            }
        }
        for p in pooled.iter_mut() {
            *p /= denom;
        }

        let mut zv = ws.take(z);
        linear_into(
            kc,
            &pooled,
            self.layout.view(theta, "w2"),
            Some(self.layout.view(theta, "b2")),
            1,
            h,
            z,
            Act::Tanh,
            &mut zv,
        );

        let mut xbar = ws.take(f);
        for &i in &live {
            for (x, v) in xbar.iter_mut().zip(&feats[i * f..(i + 1) * f]) {
                *x += v;
            }
        }
        for x in xbar.iter_mut() {
            *x /= denom;
        }

        GnnFwd { live, msg, hid, pooled, z: zv, xbar }
    }

    /// Encode a batch of graphs to latents: returns `[b, Z]` row-major.
    pub fn encode(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        feats: &[f32],
        adj: &[f32],
        mask: &[f32],
        b: usize,
    ) -> Vec<f32> {
        let (n, f) = (self.n, self.f);
        let mut out = Vec::with_capacity(b * self.z);
        for s in 0..b {
            let fwd = self.forward(
                ws,
                kc,
                theta,
                &feats[s * n * f..(s + 1) * n * f],
                &adj[s * n * n..(s + 1) * n * n],
                &mask[s * n..(s + 1) * n],
            );
            out.extend_from_slice(&fwd.z);
            fwd.recycle(ws);
        }
        out
    }

    /// One auto-encoder Adam step over a batch; returns the mean loss.
    ///
    /// Under [`ReductionOrder::V1Scalar`] the whole batch accumulates in
    /// one sequential [`Self::accumulate_range`] call — arithmetically
    /// identical to the seed loop, preserving the V1 bit pins. Under
    /// [`ReductionOrder::V2LaneTiled`] the batch splits into fixed sample
    /// groups that accumulate (possibly on worker threads) into per-group
    /// buffers folded by a fixed pairwise tree — bit-identical for any
    /// worker count, toleranced against V1.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        t: f32,
        feats: &[f32],
        adj: &[f32],
        mask: &[f32],
        b: usize,
        lr: f32,
    ) -> f32 {
        let binv = 1.0 / b.max(1) as f32;
        let theta_ref: &[f32] = theta;
        let (grad, aux) = match kc.effective_order() {
            ReductionOrder::V1Scalar => {
                let mut grad = ws.take(theta_ref.len());
                let mut aux = ws.take(1);
                self.accumulate_range(
                    ws, kc, theta_ref, feats, adj, mask, 0..b, binv, &mut grad, &mut aux,
                );
                (grad, aux)
            }
            ReductionOrder::V2LaneTiled => {
                let macs = b * self.n * self.n * self.f + b * self.n * self.f * self.h * 3;
                v2_accumulate_grads(
                    ws,
                    kc,
                    b,
                    theta_ref.len(),
                    1,
                    macs,
                    |rows, cfg, cw, grad, aux| {
                        self.accumulate_range(
                            cw, cfg, theta_ref, feats, adj, mask, rows, binv, grad, aux,
                        );
                    },
                )
            }
        };
        adam_step(theta, m, v, t, &grad, lr);
        let total_loss = aux[0];
        ws.put_all([grad, aux]);
        total_loss
    }

    /// Accumulate the AE gradient and mean-loss contribution of samples
    /// `rows` into `grad` (flat, layout-aligned) and `aux[0]`. The
    /// per-sample arithmetic and the within-range accumulation order are
    /// exactly the seed's, so one full-range call reproduces the V1 bits
    /// while the V2 path runs one call per fixed sample group.
    #[allow(clippy::too_many_arguments)]
    fn accumulate_range(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        feats: &[f32],
        adj: &[f32],
        mask: &[f32],
        rows: std::ops::Range<usize>,
        binv: f32,
        grad: &mut [f32],
        aux: &mut [f32],
    ) {
        let (n, f, h, z) = (self.n, self.f, self.h, self.z);
        let mut dw1 = ws.take(f * h);
        let mut db1 = ws.take(h);
        let mut dw2 = ws.take(h * z);
        let mut db2 = ws.take(z);
        let mut dw3 = ws.take(h * f);
        let mut db3 = ws.take(f);
        let mut dw4 = ws.take(z * f);
        let mut db4 = ws.take(f);

        for s in rows {
            let sf = &feats[s * n * f..(s + 1) * n * f];
            let sm = &mask[s * n..(s + 1) * n];
            let fwd = self.forward(ws, kc, theta, sf, &adj[s * n * n..(s + 1) * n * n], sm);
            let l = fwd.live.len();
            let denom = l.max(1) as f32;

            // Node decoder: xhat = hid w3 + b3, masked MSE against feats.
            let mut xhat = ws.take(l * f);
            linear_into(
                kc,
                &fwd.hid,
                self.layout.view(theta, "w3"),
                Some(self.layout.view(theta, "b3")),
                l,
                h,
                f,
                Act::None,
                &mut xhat,
            );
            let node_scale = 1.0 / (denom * f as f32);
            let mut l_node = 0.0f32;
            let mut dxhat = ws.take(l * f);
            for (ri, &i) in fwd.live.iter().enumerate() {
                for j in 0..f {
                    let d = xhat[ri * f + j] - sf[i * f + j];
                    l_node += d * d * node_scale;
                    dxhat[ri * f + j] = 2.0 * d * node_scale * binv;
                }
            }

            // Graph decoder: xbar_hat = z w4 + b4, MSE against xbar.
            let mut xbar_hat = ws.take(f);
            linear_into(
                kc,
                &fwd.z,
                self.layout.view(theta, "w4"),
                Some(self.layout.view(theta, "b4")),
                1,
                z,
                f,
                Act::None,
                &mut xbar_hat,
            );
            let graph_scale = 1.0 / f as f32;
            let mut l_graph = 0.0f32;
            let mut dxbar_hat = ws.take(f);
            for j in 0..f {
                let d = xbar_hat[j] - fwd.xbar[j];
                l_graph += d * d * graph_scale;
                dxbar_hat[j] = 2.0 * d * graph_scale * binv;
            }
            aux[0] += (l_node + l_graph) * binv;

            // ---- backward ------------------------------------------------
            // Graph head -> latent.
            acc_xt_dy(kc, &fwd.z, &dxbar_hat, 1, z, f, &mut dw4);
            acc_rows(&dxbar_hat, 1, f, &mut db4);
            let mut dz = ws.take(z);
            dy_wt_into(kc, &dxbar_hat, self.layout.view(theta, "w4"), 1, f, z, &mut dz);
            let mut dzpre = ws.take(z);
            for ((dp, d), zv) in dzpre.iter_mut().zip(&dz).zip(&fwd.z) {
                *dp = d * (1.0 - zv * zv);
            }
            acc_xt_dy(kc, &fwd.pooled, &dzpre, 1, h, z, &mut dw2);
            acc_rows(&dzpre, 1, z, &mut db2);
            let mut dpooled = ws.take(h);
            dy_wt_into(kc, &dzpre, self.layout.view(theta, "w2"), 1, z, h, &mut dpooled);

            // Node head -> hidden rows (plus the pooled-path contribution).
            acc_xt_dy(kc, &fwd.hid, &dxhat, l, h, f, &mut dw3);
            acc_rows(&dxhat, l, f, &mut db3);
            let mut dhid = ws.take(l * h);
            dy_wt_into(kc, &dxhat, self.layout.view(theta, "w3"), l, f, h, &mut dhid);
            for ri in 0..l {
                for j in 0..h {
                    dhid[ri * h + j] += dpooled[j] / denom;
                }
            }
            let mut dpre1 = dhid;
            for (dp, hv) in dpre1.iter_mut().zip(&fwd.hid) {
                *dp *= 1.0 - hv * hv;
            }
            acc_xt_dy(kc, &fwd.msg, &dpre1, l, f, h, &mut dw1);
            acc_rows(&dpre1, l, h, &mut db1);

            ws.put_all([xhat, dxhat, xbar_hat, dxbar_hat, dz, dzpre, dpooled, dpre1]);
            fwd.recycle(ws);
        }

        self.layout.scatter(grad, "w1", &dw1);
        self.layout.scatter(grad, "b1", &db1);
        self.layout.scatter(grad, "w2", &dw2);
        self.layout.scatter(grad, "b2", &db2);
        self.layout.scatter(grad, "w3", &dw3);
        self.layout.scatter(grad, "b3", &db3);
        self.layout.scatter(grad, "w4", &dw4);
        self.layout.scatter(grad, "b4", &db4);
        ws.put_all([dw1, db1, dw2, db2, dw3, db3, dw4, db4]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_batch(net: &GnnNet, b: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (n, f) = (net.n, net.f);
        let mut rng = Rng::new(seed);
        let mut feats = vec![0.0f32; b * n * f];
        let mut adj = vec![0.0f32; b * n * n];
        let mut mask = vec![0.0f32; b * n];
        for s in 0..b {
            let live = 3 + rng.below(3);
            for i in 0..live {
                mask[s * n + i] = 1.0;
                for j in 0..f {
                    feats[(s * n + i) * f + j] = rng.normal() * 0.5;
                }
                if i > 0 {
                    adj[s * n * n + (i - 1) * n + i] = 1.0; // chain edges
                }
            }
        }
        (feats, adj, mask)
    }

    #[test]
    fn init_is_seed_deterministic() {
        let net = GnnNet::new(8, 6, 5, 4);
        assert_eq!(net.init(3), net.init(3));
        assert_ne!(net.init(3), net.init(4));
        assert_eq!(net.init(0).len(), net.n_params());
    }

    #[test]
    fn encode_shapes_and_masking() {
        let net = GnnNet::new(8, 6, 5, 4);
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let theta = net.init(1);
        let (feats, adj, mask) = toy_batch(&net, 2, 9);
        let z = net.encode(&mut ws, &kc, &theta, &feats, &adj, &mask, 2);
        assert_eq!(z.len(), 2 * 4);
        assert!(z.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
        // All-dead mask still encodes (zeros latent through the bias path).
        let dead = vec![0.0f32; 8];
        let z0 = net.encode(&mut ws, &kc, &theta, &feats[..8 * 6], &adj[..64], &dead, 1);
        assert!(z0.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn encode_is_mode_and_thread_invariant() {
        let net = GnnNet::new(12, 6, 5, 4);
        let theta = net.init(5);
        let (feats, adj, mask) = toy_batch(&net, 3, 21);
        let mut ws = Workspace::new();
        let want = net.encode(&mut ws, &KernelCfg::reference(), &theta, &feats, &adj, &mask, 3);
        for threads in [1, 2, 8] {
            let got = net.encode(
                &mut ws,
                &KernelCfg::blocked(threads),
                &theta,
                &feats,
                &adj,
                &mask,
                3,
            );
            assert_eq!(want, got, "encode must be bit-identical at {threads} threads");
        }
    }

    #[test]
    fn train_step_decreases_loss() {
        let net = GnnNet::new(8, 6, 5, 4);
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let mut theta = net.init(2);
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let (feats, adj, mask) = toy_batch(&net, 4, 11);
        let first = net.train_step(
            &mut ws, &kc, &mut theta, &mut m, &mut v, 1.0, &feats, &adj, &mask, 4, 1e-2,
        );
        let mut last = first;
        for t in 2..=40 {
            last = net.train_step(
                &mut ws, &kc, &mut theta, &mut m, &mut v, t as f32, &feats, &adj, &mask, 4, 1e-2,
            );
        }
        assert!(last.is_finite() && last < first, "AE loss {first} -> {last}");
    }

    #[test]
    fn train_scratch_is_fully_recycled() {
        // Both reduction orders must be zero-alloc after one warm-up call —
        // V2 additionally exercises the per-group buffers + child arenas.
        for kc in [KernelCfg::blocked(2), KernelCfg::v2(2)] {
            let net = GnnNet::new(8, 6, 5, 4);
            let mut ws = Workspace::new();
            let mut theta = net.init(4);
            let mut m = vec![0.0f32; theta.len()];
            let mut v = vec![0.0f32; theta.len()];
            let (feats, adj, mask) = toy_batch(&net, 4, 13);
            // Warm-up call populates the arena.
            net.train_step(
                &mut ws, &kc, &mut theta, &mut m, &mut v, 1.0, &feats, &adj, &mask, 4, 1e-3,
            );
            let warm = ws.stats();
            for t in 2..=6 {
                net.train_step(
                    &mut ws, &kc, &mut theta, &mut m, &mut v, t as f32, &feats, &adj, &mask, 4,
                    1e-3,
                );
            }
            let now = ws.stats();
            assert_eq!(
                warm.alloc_bytes, now.alloc_bytes,
                "steady-state train steps must allocate no scratch ({:?})",
                kc.order
            );
            assert!(now.reuses > warm.reuses, "steady-state takes must hit the free list");
        }
    }

    #[test]
    fn v2_train_is_bit_invariant_across_threads_and_lane_widths() {
        let run = |kc: KernelCfg| {
            let net = GnnNet::new(8, 6, 5, 4);
            let mut ws = Workspace::new();
            let mut theta = net.init(6);
            let mut m = vec![0.0f32; theta.len()];
            let mut v = vec![0.0f32; theta.len()];
            let (feats, adj, mask) = toy_batch(&net, 5, 29);
            let mut losses = Vec::new();
            for t in 1..=4 {
                losses.push(net.train_step(
                    &mut ws, &kc, &mut theta, &mut m, &mut v, t as f32, &feats, &adj, &mask, 5,
                    1e-3,
                ));
            }
            (theta, losses)
        };
        let want = run(KernelCfg::v2(1).with_lane_groups(1));
        for (threads, lanes) in [(2, 2), (8, 4), (3, 8)] {
            let got = run(KernelCfg::v2(threads).with_lane_groups(lanes));
            assert_eq!(want, got, "V2 train bits at threads={threads} lane_groups={lanes}");
        }
    }
}
