//! Host controller: a shared tanh trunk over `[z, h]` with three heads —
//! transformation logits, location logits and a value estimate — plus the
//! clipped-surrogate PPO train step. Mirrors the `ctrl_*` artifact
//! contract: `ctrl_init`, `ctrl_policy_1`, `ctrl_policy_b`, `ctrl_train`.
//!
//! The location head is trunk-conditioned but shared across transformations
//! (the per-xfer `[X1, L]` block tiles one `[L]` row): a per-xfer offset
//! would be softmax-shift-invariant and receive zero gradient, so the
//! artifact contract's shape is kept without dead parameters.
//!
//! Dense math runs through [`super::kernels`] (fused linear+tanh trunk,
//! blocked/threaded GEMMs, bit-identical to the scalar reference) and all
//! scratch comes from the caller's [`Workspace`].

use super::kernels::{
    acc_xt_dy, dy_wt_acc, dy_wt_into, linear_into, v2_accumulate_grads, Act, KernelCfg,
    ReductionOrder, Workspace,
};
use super::nn::{acc_rows, adam_step, ParamLayout};

pub struct CtrlNet {
    pub zdim: usize,
    pub rdim: usize,
    pub hidden: usize,
    pub x1: usize,
    pub locs: usize,
    pub layout: ParamLayout,
}

pub struct PolicyOut {
    pub xlogits: Vec<f32>, // [b, X1]
    pub llogits: Vec<f32>, // [b, X1 * L] (tiled)
    pub values: Vec<f32>,  // [b]
}

pub struct PpoStepStats {
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
}

/// Forward activations shared by acting and training (workspace-owned).
struct Trunk {
    u: Vec<f32>,  // [b, Z+R]
    tt: Vec<f32>, // [b, C]
}

impl Trunk {
    fn recycle(self, ws: &mut Workspace) {
        ws.put_all([self.u, self.tt]);
    }
}

impl CtrlNet {
    pub fn new(zdim: usize, rdim: usize, hidden: usize, x1: usize, locs: usize) -> Self {
        let u = zdim + rdim;
        let mut layout = ParamLayout::new();
        layout.add("wt", u * hidden, u);
        layout.add("bt", hidden, 0);
        layout.add("wx", hidden * x1, hidden);
        layout.add("bx", x1, 0);
        layout.add("wl", hidden * locs, hidden);
        layout.add("bl", locs, 0);
        layout.add("wv", hidden, hidden);
        layout.add("bv", 1, 0);
        Self { zdim, rdim, hidden, x1, locs, layout }
    }

    pub fn n_params(&self) -> usize {
        self.layout.total()
    }

    pub fn init(&self, seed: i32) -> Vec<f32> {
        self.layout.init(0x6374726C ^ (seed as u64).wrapping_mul(0x9E3779B97F4A7C15), |_| 0.0)
    }

    fn trunk(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        h: &[f32],
        b: usize,
    ) -> Trunk {
        let (zd, rd, c) = (self.zdim, self.rdim, self.hidden);
        let u_dim = zd + rd;
        let mut u = ws.take(b * u_dim);
        for r in 0..b {
            u[r * u_dim..r * u_dim + zd].copy_from_slice(&z[r * zd..(r + 1) * zd]);
            u[r * u_dim + zd..(r + 1) * u_dim].copy_from_slice(&h[r * rd..(r + 1) * rd]);
        }
        let mut tt = ws.take(b * c);
        linear_into(
            kc,
            &u,
            self.layout.view(theta, "wt"),
            Some(self.layout.view(theta, "bt")),
            b,
            u_dim,
            c,
            Act::Tanh,
            &mut tt,
        );
        Trunk { u, tt }
    }

    /// Run one affine head off the trunk into a workspace buffer.
    fn head(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        tt: &[f32],
        w: &'static str,
        bias: &'static str,
        b: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut out = ws.take(b * n);
        linear_into(
            kc,
            tt,
            self.layout.view(theta, w),
            Some(self.layout.view(theta, bias)),
            b,
            self.hidden,
            n,
            Act::None,
            &mut out,
        );
        out
    }

    /// The `ctrl_policy_*` forward. Output vectors are plain allocations
    /// (they leave as program outputs); every intermediate is
    /// workspace-scratch, so the steady-state acting path allocates only
    /// its outputs.
    pub fn policy(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        h: &[f32],
        b: usize,
    ) -> PolicyOut {
        let (c, x1, locs) = (self.hidden, self.x1, self.locs);
        let t = self.trunk(ws, kc, theta, z, h, b);
        let mut xlogits = vec![0.0f32; b * x1];
        linear_into(
            kc,
            &t.tt,
            self.layout.view(theta, "wx"),
            Some(self.layout.view(theta, "bx")),
            b,
            c,
            x1,
            Act::None,
            &mut xlogits,
        );
        let la = self.head(ws, kc, theta, &t.tt, "wl", "bl", b, locs);
        let mut values = vec![0.0f32; b];
        linear_into(
            kc,
            &t.tt,
            self.layout.view(theta, "wv"),
            Some(self.layout.view(theta, "bv")),
            b,
            c,
            1,
            Act::None,
            &mut values,
        );
        let mut llogits = vec![0.0f32; b * x1 * locs];
        for r in 0..b {
            let row = &la[r * locs..(r + 1) * locs];
            for x in 0..x1 {
                llogits[(r * x1 + x) * locs..(r * x1 + x + 1) * locs].copy_from_slice(row);
            }
        }
        ws.put(la);
        t.recycle(ws);
        PolicyOut { xlogits, llogits, values }
    }

    /// One PPO Adam step (`ctrl_train`).
    ///
    /// Batch-level statistics (advantage mean/std) are computed once over
    /// the whole batch and shared by every sample group, so they are part
    /// of both reduction orders' contracts. Under
    /// [`ReductionOrder::V1Scalar`] the batch accumulates in one
    /// sequential [`Self::accumulate_range`] call (the seed bit pattern);
    /// under [`ReductionOrder::V2LaneTiled`] the fixed sample groups
    /// accumulate into per-group buffers folded by a fixed pairwise tree —
    /// bit-identical for any worker count.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        t_step: f32,
        z: &[f32],
        h: &[f32],
        act: &[i32],
        logp_old: &[f32],
        adv: &[f32],
        ret: &[f32],
        xmask: &[f32],
        lmask: &[f32],
        b: usize,
        lr: f32,
        clip: f32,
        ent_coef: f32,
    ) -> PpoStepStats {
        let binv = 1.0 / b.max(1) as f32;
        // Advantage normalisation (batch-level, standard PPO practice).
        let a_mean = adv.iter().sum::<f32>() * binv;
        let a_var = adv.iter().map(|a| (a - a_mean) * (a - a_mean)).sum::<f32>() * binv;
        let a_std = a_var.sqrt().max(1e-6);

        let theta_ref: &[f32] = theta;
        let (grad, aux) = match kc.effective_order() {
            ReductionOrder::V1Scalar => {
                let mut grad = ws.take(theta_ref.len());
                let mut aux = ws.take(4);
                self.accumulate_range(
                    ws, kc, theta_ref, z, h, act, logp_old, adv, ret, xmask, lmask, 0..b, binv,
                    a_mean, a_std, clip, ent_coef, &mut grad, &mut aux,
                );
                (grad, aux)
            }
            ReductionOrder::V2LaneTiled => {
                let c = self.hidden;
                let wide = self.x1 + self.locs + 1;
                let macs = b * ((self.zdim + self.rdim) * c + c * wide) * 3;
                v2_accumulate_grads(
                    ws,
                    kc,
                    b,
                    theta_ref.len(),
                    4,
                    macs,
                    |rows, cfg, cw, grad, aux| {
                        self.accumulate_range(
                            cw, cfg, theta_ref, z, h, act, logp_old, adv, ret, xmask, lmask, rows,
                            binv, a_mean, a_std, clip, ent_coef, grad, aux,
                        );
                    },
                )
            }
        };
        adam_step(theta, m, v, t_step, &grad, lr);
        let stats =
            PpoStepStats { pi_loss: aux[0], v_loss: aux[1], entropy: aux[2], approx_kl: aux[3] };
        ws.put_all([grad, aux]);
        stats
    }

    /// Accumulate the PPO gradient and loss contributions of samples
    /// `rows` into `grad` and `aux` (`[pi_loss, v_loss, entropy,
    /// approx_kl]`). Trunk and head rows are per-sample independent, so
    /// running them over a sub-range reproduces the full-batch rows
    /// bit-exactly; one full-range call therefore reproduces the seed (V1)
    /// bit pattern.
    #[allow(clippy::too_many_arguments)]
    fn accumulate_range(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        h: &[f32],
        act: &[i32],
        logp_old: &[f32],
        adv: &[f32],
        ret: &[f32],
        xmask: &[f32],
        lmask: &[f32],
        rows: std::ops::Range<usize>,
        binv: f32,
        a_mean: f32,
        a_std: f32,
        clip: f32,
        ent_coef: f32,
        grad: &mut [f32],
        aux: &mut [f32],
    ) {
        let (c, x1, locs) = (self.hidden, self.x1, self.locs);
        let (zd, rd) = (self.zdim, self.rdim);
        let u_dim = zd + rd;
        let noop = x1 - 1;
        let r0 = rows.start;
        let br = rows.len();

        let trunk = self.trunk(
            ws,
            kc,
            theta,
            &z[r0 * zd..rows.end * zd],
            &h[r0 * rd..rows.end * rd],
            br,
        );
        let xlogits = self.head(ws, kc, theta, &trunk.tt, "wx", "bx", br, x1);
        let la = self.head(ws, kc, theta, &trunk.tt, "wl", "bl", br, locs);
        let vals = self.head(ws, kc, theta, &trunk.tt, "wv", "bv", br, 1);

        let mut dxlogits = ws.take(br * x1);
        let mut dla = ws.take(br * locs);
        let mut dvals = ws.take(br);
        let mut x_lsm = ws.take(x1);
        let mut px = ws.take(x1);
        let mut l_lsm = ws.take(locs);
        let mut pl = ws.take(locs);

        for ri in 0..br {
            let r = r0 + ri; // global row for the batch input tensors
            let advn = (adv[r] - a_mean) / a_std;
            let xrow = &xlogits[ri * x1..(ri + 1) * x1];
            let xm = |j: usize| j == noop || xmask[r * x1 + j] >= 0.5; // NO-OP always valid
            masked_lsm_into(xrow, xm, &mut x_lsm, &mut px);
            let ax = (act[r * 2] as usize).min(x1 - 1);
            let al = (act[r * 2 + 1] as usize).min(locs - 1);

            let lm = |j: usize| lmask[r * locs + j] >= 0.5;
            let loc_used = ax != noop && (0..locs).any(lm);
            let lrow = &la[ri * locs..(ri + 1) * locs];
            masked_lsm_into(lrow, lm, &mut l_lsm, &mut pl);

            let mut logp = x_lsm[ax];
            if loc_used {
                logp += l_lsm[al];
            }
            let logp = logp.max(-30.0);
            let old = logp_old[r].max(-30.0);
            let ratio = (logp - old).exp();
            let ratio_c = ratio.clamp(1.0 - clip, 1.0 + clip);
            let unclipped = ratio * advn;
            let clipped = ratio_c * advn;
            aux[0] += -unclipped.min(clipped) * binv;
            aux[3] += (old - logp) * binv;

            // d(-min)/dlogp: the clipped branch has zero gradient when active.
            let dlogp = if unclipped <= clipped { -advn * ratio * binv } else { 0.0 };
            for j in 0..x1 {
                let onehot = if j == ax { 1.0 } else { 0.0 };
                dxlogits[ri * x1 + j] += dlogp * (onehot - px[j]);
            }
            if loc_used {
                for j in 0..locs {
                    let onehot = if j == al { 1.0 } else { 0.0 };
                    dla[ri * locs + j] += dlogp * (onehot - pl[j]);
                }
            }

            // Entropy bonus on the transformation head.
            let mut h_row = 0.0f32;
            for j in 0..x1 {
                if px[j] > 0.0 {
                    h_row -= px[j] * x_lsm[j];
                }
            }
            aux[2] += h_row * binv;
            for j in 0..x1 {
                if px[j] > 0.0 {
                    // d(-ent_coef * H)/dl_j = ent_coef * p_j (log p_j + H)
                    dxlogits[ri * x1 + j] += ent_coef * px[j] * (x_lsm[j] + h_row) * binv;
                }
            }

            // Value loss (0.5 coefficient in the total objective).
            let dv = vals[ri] - ret[r];
            aux[1] += dv * dv * binv;
            dvals[ri] = dv * binv; // 0.5 * 2 * (v - ret) / b
        }
        ws.put_all([x_lsm, px, l_lsm, pl]);

        // ---- backward through heads and trunk ----------------------------
        let mut dwx = ws.take(c * x1);
        let mut dbx = ws.take(x1);
        let mut dwl = ws.take(c * locs);
        let mut dbl = ws.take(locs);
        let mut dwv = ws.take(c);
        let mut dbv = ws.take(1);
        acc_xt_dy(kc, &trunk.tt, &dxlogits, br, c, x1, &mut dwx);
        acc_rows(&dxlogits, br, x1, &mut dbx);
        acc_xt_dy(kc, &trunk.tt, &dla, br, c, locs, &mut dwl);
        acc_rows(&dla, br, locs, &mut dbl);
        acc_xt_dy(kc, &trunk.tt, &dvals, br, c, 1, &mut dwv);
        acc_rows(&dvals, br, 1, &mut dbv);

        let mut dtt = ws.take(br * c);
        dy_wt_into(kc, &dxlogits, self.layout.view(theta, "wx"), br, x1, c, &mut dtt);
        dy_wt_acc(kc, &dla, self.layout.view(theta, "wl"), br, locs, c, &mut dtt);
        dy_wt_acc(kc, &dvals, self.layout.view(theta, "wv"), br, 1, c, &mut dtt);
        let mut dpre = dtt;
        for (dp, tv) in dpre.iter_mut().zip(&trunk.tt) {
            *dp *= 1.0 - tv * tv;
        }
        let mut dwt = ws.take(u_dim * c);
        let mut dbt = ws.take(c);
        acc_xt_dy(kc, &trunk.u, &dpre, br, u_dim, c, &mut dwt);
        acc_rows(&dpre, br, c, &mut dbt);

        self.layout.scatter(grad, "wt", &dwt);
        self.layout.scatter(grad, "bt", &dbt);
        self.layout.scatter(grad, "wx", &dwx);
        self.layout.scatter(grad, "bx", &dbx);
        self.layout.scatter(grad, "wl", &dwl);
        self.layout.scatter(grad, "bl", &dbl);
        self.layout.scatter(grad, "wv", &dwv);
        self.layout.scatter(grad, "bv", &dbv);

        ws.put_all([xlogits, la, vals, dxlogits, dla, dvals]);
        ws.put_all([dwx, dbx, dwl, dbl, dwv, dbv, dpre, dwt, dbt]);
        trunk.recycle(ws);
    }
}

/// Masked log-softmax + matching probabilities (0 where masked), written
/// into caller-provided buffers. Bit-identical to the seed's allocating
/// `masked_lsm` (same accumulation order over unmasked entries).
fn masked_lsm_into(
    logits: &[f32],
    mask: impl Fn(usize) -> bool,
    lsm: &mut [f32],
    p: &mut [f32],
) {
    debug_assert_eq!(logits.len(), lsm.len());
    debug_assert_eq!(logits.len(), p.len());
    let mut mx = f32::NEG_INFINITY;
    for (j, &l) in logits.iter().enumerate() {
        if mask(j) {
            mx = mx.max(l);
        }
    }
    if !mx.is_finite() {
        lsm.fill(f32::NEG_INFINITY);
        p.fill(0.0);
        return;
    }
    let mut sum = 0.0f32;
    for (j, &l) in logits.iter().enumerate() {
        if mask(j) {
            sum += (l - mx).exp();
        }
    }
    let lse = sum.ln() + mx;
    for (j, &l) in logits.iter().enumerate() {
        if mask(j) {
            lsm[j] = l - lse;
            p[j] = lsm[j].exp();
        } else {
            lsm[j] = f32::NEG_INFINITY;
            p[j] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn net() -> CtrlNet {
        CtrlNet::new(4, 6, 8, 5, 7)
    }

    #[test]
    fn policy_shapes_and_tiling() {
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let theta = n.init(0);
        let b = 2;
        let z = vec![0.1f32; b * 4];
        let h = vec![0.0f32; b * 6];
        let out = n.policy(&mut ws, &kc, &theta, &z, &h, b);
        assert_eq!(out.xlogits.len(), b * 5);
        assert_eq!(out.llogits.len(), b * 5 * 7);
        assert_eq!(out.values.len(), b);
        // Location block tiles across xfers.
        assert_eq!(out.llogits[..7], out.llogits[7..14]);
    }

    #[test]
    fn policy_is_mode_and_thread_invariant() {
        let n = net();
        let theta = n.init(6);
        let b = 3;
        let mut rng = Rng::new(2);
        let z: Vec<f32> = (0..b * 4).map(|_| rng.normal() * 0.4).collect();
        let h: Vec<f32> = (0..b * 6).map(|_| rng.normal() * 0.2).collect();
        let mut ws = Workspace::new();
        let want = n.policy(&mut ws, &KernelCfg::reference(), &theta, &z, &h, b);
        for threads in [1, 2, 8] {
            let got = n.policy(&mut ws, &KernelCfg::blocked(threads), &theta, &z, &h, b);
            assert_eq!(want.xlogits, got.xlogits);
            assert_eq!(want.llogits, got.llogits);
            assert_eq!(want.values, got.values);
        }
    }

    #[test]
    fn ppo_step_moves_params_and_reports_finite_stats() {
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let mut theta = n.init(1);
        let before = theta.clone();
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let b = 6;
        let mut rng = Rng::new(5);
        let z: Vec<f32> = (0..b * 4).map(|_| rng.normal() * 0.3).collect();
        let h = vec![0.0f32; b * 6];
        let act: Vec<i32> = (0..b).flat_map(|r| [(r % 4) as i32, (r % 7) as i32]).collect();
        let logp_old = vec![-1.5f32; b];
        let adv: Vec<f32> = (0..b).map(|r| if r % 2 == 0 { 1.0 } else { -0.5 }).collect();
        let ret = vec![0.3f32; b];
        let xmask = vec![1.0f32; b * 5];
        let lmask = vec![1.0f32; b * 7];
        let stats = n.train_step(
            &mut ws, &kc, &mut theta, &mut m, &mut v, 1.0, &z, &h, &act, &logp_old, &adv, &ret,
            &xmask, &lmask, b, 3e-3, 0.2, 0.01,
        );
        assert!(stats.pi_loss.is_finite());
        assert!(stats.v_loss > 0.0);
        assert!(stats.entropy > 0.0);
        assert!(stats.approx_kl.is_finite());
        assert_ne!(before, theta, "PPO step should move parameters");
    }

    #[test]
    fn all_invalid_masks_stay_finite() {
        // Zero masks (contract-test shape probing) must not produce NaNs.
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let mut theta = n.init(2);
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let b = 2;
        let stats = n.train_step(
            &mut ws,
            &kc,
            &mut theta,
            &mut m,
            &mut v,
            1.0,
            &vec![0.0; b * 4],
            &vec![0.0; b * 6],
            &vec![0i32; b * 2],
            &vec![0.0; b],
            &vec![0.0; b],
            &vec![0.0; b],
            &vec![0.0; b * 5],
            &vec![0.0; b * 7],
            b,
            1e-3,
            0.2,
            0.01,
        );
        assert!(stats.pi_loss.is_finite() && stats.v_loss.is_finite());
        assert!(theta.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn v2_ppo_step_is_bit_invariant_across_threads_and_lane_widths() {
        let run = |kc: KernelCfg| {
            let n = net();
            let mut ws = Workspace::new();
            let mut theta = n.init(9);
            let mut m = vec![0.0f32; theta.len()];
            let mut v = vec![0.0f32; theta.len()];
            let b = 11; // odd width: uneven sample groups
            let mut rng = Rng::new(41);
            let z: Vec<f32> = (0..b * 4).map(|_| rng.normal() * 0.3).collect();
            let h: Vec<f32> = (0..b * 6).map(|_| rng.normal() * 0.2).collect();
            let act: Vec<i32> = (0..b).flat_map(|r| [(r % 4) as i32, (r % 7) as i32]).collect();
            let logp_old = vec![-1.2f32; b];
            let adv: Vec<f32> = (0..b).map(|r| if r % 2 == 0 { 0.8 } else { -0.4 }).collect();
            let ret = vec![0.2f32; b];
            let xmask = vec![1.0f32; b * 5];
            let lmask = vec![1.0f32; b * 7];
            let mut stats = Vec::new();
            for t in 1..=3 {
                let s = n.train_step(
                    &mut ws, &kc, &mut theta, &mut m, &mut v, t as f32, &z, &h, &act, &logp_old,
                    &adv, &ret, &xmask, &lmask, b, 3e-3, 0.2, 0.01,
                );
                stats.push([s.pi_loss, s.v_loss, s.entropy, s.approx_kl]);
            }
            (theta, stats)
        };
        let want = run(KernelCfg::v2(1).with_lane_groups(1));
        for (threads, lanes) in [(2, 2), (8, 4), (3, 8)] {
            let got = run(KernelCfg::v2(threads).with_lane_groups(lanes));
            assert_eq!(want, got, "V2 PPO bits at threads={threads} lane_groups={lanes}");
        }
    }
}
