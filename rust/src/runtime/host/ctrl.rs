//! Host controller: a shared tanh trunk over `[z, h]` with three heads —
//! transformation logits, location logits and a value estimate — plus the
//! clipped-surrogate PPO train step. Mirrors the `ctrl_*` artifact
//! contract: `ctrl_init`, `ctrl_policy_1`, `ctrl_policy_b`, `ctrl_train`.
//!
//! The location head is trunk-conditioned but shared across transformations
//! (the per-xfer `[X1, L]` block tiles one `[L]` row): a per-xfer offset
//! would be softmax-shift-invariant and receive zero gradient, so the
//! artifact contract's shape is kept without dead parameters.
//!
//! Dense math runs through [`super::kernels`] (fused linear+tanh trunk,
//! blocked/threaded GEMMs, bit-identical to the scalar reference) and all
//! scratch comes from the caller's [`Workspace`].

use super::kernels::{acc_xt_dy, dy_wt_acc, dy_wt_into, linear_into, Act, KernelCfg, Workspace};
use super::nn::{acc_rows, adam_step, ParamLayout};

pub struct CtrlNet {
    pub zdim: usize,
    pub rdim: usize,
    pub hidden: usize,
    pub x1: usize,
    pub locs: usize,
    pub layout: ParamLayout,
}

pub struct PolicyOut {
    pub xlogits: Vec<f32>, // [b, X1]
    pub llogits: Vec<f32>, // [b, X1 * L] (tiled)
    pub values: Vec<f32>,  // [b]
}

pub struct PpoStepStats {
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
}

/// Forward activations shared by acting and training (workspace-owned).
struct Trunk {
    u: Vec<f32>,  // [b, Z+R]
    tt: Vec<f32>, // [b, C]
}

impl Trunk {
    fn recycle(self, ws: &mut Workspace) {
        ws.put_all([self.u, self.tt]);
    }
}

impl CtrlNet {
    pub fn new(zdim: usize, rdim: usize, hidden: usize, x1: usize, locs: usize) -> Self {
        let u = zdim + rdim;
        let mut layout = ParamLayout::new();
        layout.add("wt", u * hidden, u);
        layout.add("bt", hidden, 0);
        layout.add("wx", hidden * x1, hidden);
        layout.add("bx", x1, 0);
        layout.add("wl", hidden * locs, hidden);
        layout.add("bl", locs, 0);
        layout.add("wv", hidden, hidden);
        layout.add("bv", 1, 0);
        Self { zdim, rdim, hidden, x1, locs, layout }
    }

    pub fn n_params(&self) -> usize {
        self.layout.total()
    }

    pub fn init(&self, seed: i32) -> Vec<f32> {
        self.layout.init(0x6374726C ^ (seed as u64).wrapping_mul(0x9E3779B97F4A7C15), |_| 0.0)
    }

    fn trunk(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        h: &[f32],
        b: usize,
    ) -> Trunk {
        let (zd, rd, c) = (self.zdim, self.rdim, self.hidden);
        let u_dim = zd + rd;
        let mut u = ws.take(b * u_dim);
        for r in 0..b {
            u[r * u_dim..r * u_dim + zd].copy_from_slice(&z[r * zd..(r + 1) * zd]);
            u[r * u_dim + zd..(r + 1) * u_dim].copy_from_slice(&h[r * rd..(r + 1) * rd]);
        }
        let mut tt = ws.take(b * c);
        linear_into(
            kc,
            &u,
            self.layout.view(theta, "wt"),
            Some(self.layout.view(theta, "bt")),
            b,
            u_dim,
            c,
            Act::Tanh,
            &mut tt,
        );
        Trunk { u, tt }
    }

    /// Run one affine head off the trunk into a workspace buffer.
    fn head(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        tt: &[f32],
        w: &'static str,
        bias: &'static str,
        b: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut out = ws.take(b * n);
        linear_into(
            kc,
            tt,
            self.layout.view(theta, w),
            Some(self.layout.view(theta, bias)),
            b,
            self.hidden,
            n,
            Act::None,
            &mut out,
        );
        out
    }

    /// The `ctrl_policy_*` forward. Output vectors are plain allocations
    /// (they leave as program outputs); every intermediate is
    /// workspace-scratch, so the steady-state acting path allocates only
    /// its outputs.
    pub fn policy(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &[f32],
        z: &[f32],
        h: &[f32],
        b: usize,
    ) -> PolicyOut {
        let (c, x1, locs) = (self.hidden, self.x1, self.locs);
        let t = self.trunk(ws, kc, theta, z, h, b);
        let mut xlogits = vec![0.0f32; b * x1];
        linear_into(
            kc,
            &t.tt,
            self.layout.view(theta, "wx"),
            Some(self.layout.view(theta, "bx")),
            b,
            c,
            x1,
            Act::None,
            &mut xlogits,
        );
        let la = self.head(ws, kc, theta, &t.tt, "wl", "bl", b, locs);
        let mut values = vec![0.0f32; b];
        linear_into(
            kc,
            &t.tt,
            self.layout.view(theta, "wv"),
            Some(self.layout.view(theta, "bv")),
            b,
            c,
            1,
            Act::None,
            &mut values,
        );
        let mut llogits = vec![0.0f32; b * x1 * locs];
        for r in 0..b {
            let row = &la[r * locs..(r + 1) * locs];
            for x in 0..x1 {
                llogits[(r * x1 + x) * locs..(r * x1 + x + 1) * locs].copy_from_slice(row);
            }
        }
        ws.put(la);
        t.recycle(ws);
        PolicyOut { xlogits, llogits, values }
    }

    /// One PPO Adam step (`ctrl_train`).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        ws: &mut Workspace,
        kc: &KernelCfg,
        theta: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        t_step: f32,
        z: &[f32],
        h: &[f32],
        act: &[i32],
        logp_old: &[f32],
        adv: &[f32],
        ret: &[f32],
        xmask: &[f32],
        lmask: &[f32],
        b: usize,
        lr: f32,
        clip: f32,
        ent_coef: f32,
    ) -> PpoStepStats {
        let (c, x1, locs) = (self.hidden, self.x1, self.locs);
        let u_dim = self.zdim + self.rdim;
        let noop = x1 - 1;
        let binv = 1.0 / b.max(1) as f32;

        let trunk = self.trunk(ws, kc, theta, z, h, b);
        let xlogits = self.head(ws, kc, theta, &trunk.tt, "wx", "bx", b, x1);
        let la = self.head(ws, kc, theta, &trunk.tt, "wl", "bl", b, locs);
        let vals = self.head(ws, kc, theta, &trunk.tt, "wv", "bv", b, 1);

        // Advantage normalisation (batch-level, standard PPO practice).
        let a_mean = adv.iter().sum::<f32>() * binv;
        let a_var = adv.iter().map(|a| (a - a_mean) * (a - a_mean)).sum::<f32>() * binv;
        let a_std = a_var.sqrt().max(1e-6);

        let mut dxlogits = ws.take(b * x1);
        let mut dla = ws.take(b * locs);
        let mut dvals = ws.take(b);
        let mut x_lsm = ws.take(x1);
        let mut px = ws.take(x1);
        let mut l_lsm = ws.take(locs);
        let mut pl = ws.take(locs);
        let (mut pi_loss, mut v_loss, mut entropy, mut kl) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);

        for r in 0..b {
            let advn = (adv[r] - a_mean) / a_std;
            let xrow = &xlogits[r * x1..(r + 1) * x1];
            let xm = |j: usize| j == noop || xmask[r * x1 + j] >= 0.5; // NO-OP always valid
            masked_lsm_into(xrow, xm, &mut x_lsm, &mut px);
            let ax = (act[r * 2] as usize).min(x1 - 1);
            let al = (act[r * 2 + 1] as usize).min(locs - 1);

            let lm = |j: usize| lmask[r * locs + j] >= 0.5;
            let loc_used = ax != noop && (0..locs).any(lm);
            let lrow = &la[r * locs..(r + 1) * locs];
            masked_lsm_into(lrow, lm, &mut l_lsm, &mut pl);

            let mut logp = x_lsm[ax];
            if loc_used {
                logp += l_lsm[al];
            }
            let logp = logp.max(-30.0);
            let old = logp_old[r].max(-30.0);
            let ratio = (logp - old).exp();
            let ratio_c = ratio.clamp(1.0 - clip, 1.0 + clip);
            let unclipped = ratio * advn;
            let clipped = ratio_c * advn;
            pi_loss += -unclipped.min(clipped) * binv;
            kl += (old - logp) * binv;

            // d(-min)/dlogp: the clipped branch has zero gradient when active.
            let dlogp = if unclipped <= clipped { -advn * ratio * binv } else { 0.0 };
            for j in 0..x1 {
                let onehot = if j == ax { 1.0 } else { 0.0 };
                dxlogits[r * x1 + j] += dlogp * (onehot - px[j]);
            }
            if loc_used {
                for j in 0..locs {
                    let onehot = if j == al { 1.0 } else { 0.0 };
                    dla[r * locs + j] += dlogp * (onehot - pl[j]);
                }
            }

            // Entropy bonus on the transformation head.
            let mut h_row = 0.0f32;
            for j in 0..x1 {
                if px[j] > 0.0 {
                    h_row -= px[j] * x_lsm[j];
                }
            }
            entropy += h_row * binv;
            for j in 0..x1 {
                if px[j] > 0.0 {
                    // d(-ent_coef * H)/dl_j = ent_coef * p_j (log p_j + H)
                    dxlogits[r * x1 + j] += ent_coef * px[j] * (x_lsm[j] + h_row) * binv;
                }
            }

            // Value loss (0.5 coefficient in the total objective).
            let dv = vals[r] - ret[r];
            v_loss += dv * dv * binv;
            dvals[r] = dv * binv; // 0.5 * 2 * (v - ret) / b
        }
        ws.put_all([x_lsm, px, l_lsm, pl]);

        // ---- backward through heads and trunk ----------------------------
        let mut grad = ws.take(theta.len());
        let mut dwx = ws.take(c * x1);
        let mut dbx = ws.take(x1);
        let mut dwl = ws.take(c * locs);
        let mut dbl = ws.take(locs);
        let mut dwv = ws.take(c);
        let mut dbv = ws.take(1);
        acc_xt_dy(kc, &trunk.tt, &dxlogits, b, c, x1, &mut dwx);
        acc_rows(&dxlogits, b, x1, &mut dbx);
        acc_xt_dy(kc, &trunk.tt, &dla, b, c, locs, &mut dwl);
        acc_rows(&dla, b, locs, &mut dbl);
        acc_xt_dy(kc, &trunk.tt, &dvals, b, c, 1, &mut dwv);
        acc_rows(&dvals, b, 1, &mut dbv);

        let mut dtt = ws.take(b * c);
        dy_wt_into(kc, &dxlogits, self.layout.view(theta, "wx"), b, x1, c, &mut dtt);
        dy_wt_acc(kc, &dla, self.layout.view(theta, "wl"), b, locs, c, &mut dtt);
        dy_wt_acc(kc, &dvals, self.layout.view(theta, "wv"), b, 1, c, &mut dtt);
        let mut dpre = dtt;
        for (dp, tv) in dpre.iter_mut().zip(&trunk.tt) {
            *dp *= 1.0 - tv * tv;
        }
        let mut dwt = ws.take(u_dim * c);
        let mut dbt = ws.take(c);
        acc_xt_dy(kc, &trunk.u, &dpre, b, u_dim, c, &mut dwt);
        acc_rows(&dpre, b, c, &mut dbt);

        self.layout.scatter(&mut grad, "wt", &dwt);
        self.layout.scatter(&mut grad, "bt", &dbt);
        self.layout.scatter(&mut grad, "wx", &dwx);
        self.layout.scatter(&mut grad, "bx", &dbx);
        self.layout.scatter(&mut grad, "wl", &dwl);
        self.layout.scatter(&mut grad, "bl", &dbl);
        self.layout.scatter(&mut grad, "wv", &dwv);
        self.layout.scatter(&mut grad, "bv", &dbv);
        adam_step(theta, m, v, t_step, &grad, lr);

        ws.put_all([xlogits, la, vals, dxlogits, dla, dvals]);
        ws.put_all([grad, dwx, dbx, dwl, dbl, dwv, dbv, dpre, dwt, dbt]);
        trunk.recycle(ws);

        PpoStepStats { pi_loss, v_loss, entropy, approx_kl: kl }
    }
}

/// Masked log-softmax + matching probabilities (0 where masked), written
/// into caller-provided buffers. Bit-identical to the seed's allocating
/// `masked_lsm` (same accumulation order over unmasked entries).
fn masked_lsm_into(
    logits: &[f32],
    mask: impl Fn(usize) -> bool,
    lsm: &mut [f32],
    p: &mut [f32],
) {
    debug_assert_eq!(logits.len(), lsm.len());
    debug_assert_eq!(logits.len(), p.len());
    let mut mx = f32::NEG_INFINITY;
    for (j, &l) in logits.iter().enumerate() {
        if mask(j) {
            mx = mx.max(l);
        }
    }
    if !mx.is_finite() {
        lsm.fill(f32::NEG_INFINITY);
        p.fill(0.0);
        return;
    }
    let mut sum = 0.0f32;
    for (j, &l) in logits.iter().enumerate() {
        if mask(j) {
            sum += (l - mx).exp();
        }
    }
    let lse = sum.ln() + mx;
    for (j, &l) in logits.iter().enumerate() {
        if mask(j) {
            lsm[j] = l - lse;
            p[j] = lsm[j].exp();
        } else {
            lsm[j] = f32::NEG_INFINITY;
            p[j] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn net() -> CtrlNet {
        CtrlNet::new(4, 6, 8, 5, 7)
    }

    #[test]
    fn policy_shapes_and_tiling() {
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let theta = n.init(0);
        let b = 2;
        let z = vec![0.1f32; b * 4];
        let h = vec![0.0f32; b * 6];
        let out = n.policy(&mut ws, &kc, &theta, &z, &h, b);
        assert_eq!(out.xlogits.len(), b * 5);
        assert_eq!(out.llogits.len(), b * 5 * 7);
        assert_eq!(out.values.len(), b);
        // Location block tiles across xfers.
        assert_eq!(out.llogits[..7], out.llogits[7..14]);
    }

    #[test]
    fn policy_is_mode_and_thread_invariant() {
        let n = net();
        let theta = n.init(6);
        let b = 3;
        let mut rng = Rng::new(2);
        let z: Vec<f32> = (0..b * 4).map(|_| rng.normal() * 0.4).collect();
        let h: Vec<f32> = (0..b * 6).map(|_| rng.normal() * 0.2).collect();
        let mut ws = Workspace::new();
        let want = n.policy(&mut ws, &KernelCfg::reference(), &theta, &z, &h, b);
        for threads in [1, 2, 8] {
            let got = n.policy(&mut ws, &KernelCfg::blocked(threads), &theta, &z, &h, b);
            assert_eq!(want.xlogits, got.xlogits);
            assert_eq!(want.llogits, got.llogits);
            assert_eq!(want.values, got.values);
        }
    }

    #[test]
    fn ppo_step_moves_params_and_reports_finite_stats() {
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let mut theta = n.init(1);
        let before = theta.clone();
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let b = 6;
        let mut rng = Rng::new(5);
        let z: Vec<f32> = (0..b * 4).map(|_| rng.normal() * 0.3).collect();
        let h = vec![0.0f32; b * 6];
        let act: Vec<i32> = (0..b).flat_map(|r| [(r % 4) as i32, (r % 7) as i32]).collect();
        let logp_old = vec![-1.5f32; b];
        let adv: Vec<f32> = (0..b).map(|r| if r % 2 == 0 { 1.0 } else { -0.5 }).collect();
        let ret = vec![0.3f32; b];
        let xmask = vec![1.0f32; b * 5];
        let lmask = vec![1.0f32; b * 7];
        let stats = n.train_step(
            &mut ws, &kc, &mut theta, &mut m, &mut v, 1.0, &z, &h, &act, &logp_old, &adv, &ret,
            &xmask, &lmask, b, 3e-3, 0.2, 0.01,
        );
        assert!(stats.pi_loss.is_finite());
        assert!(stats.v_loss > 0.0);
        assert!(stats.entropy > 0.0);
        assert!(stats.approx_kl.is_finite());
        assert_ne!(before, theta, "PPO step should move parameters");
    }

    #[test]
    fn all_invalid_masks_stay_finite() {
        // Zero masks (contract-test shape probing) must not produce NaNs.
        let n = net();
        let mut ws = Workspace::new();
        let kc = KernelCfg::default();
        let mut theta = n.init(2);
        let mut m = vec![0.0f32; theta.len()];
        let mut v = vec![0.0f32; theta.len()];
        let b = 2;
        let stats = n.train_step(
            &mut ws,
            &kc,
            &mut theta,
            &mut m,
            &mut v,
            1.0,
            &vec![0.0; b * 4],
            &vec![0.0; b * 6],
            &vec![0i32; b * 2],
            &vec![0.0; b],
            &vec![0.0; b],
            &vec![0.0; b],
            &vec![0.0; b * 5],
            &vec![0.0; b * 7],
            b,
            1e-3,
            0.2,
            0.01,
        );
        assert!(stats.pi_loss.is_finite() && stats.v_loss.is_finite());
        assert!(theta.iter().all(|p| p.is_finite()));
    }
}
