//! Shared numeric primitives for the host backend's native networks: flat
//! parameter layouts, the seed scalar matmul forward/backward kernels
//! (kept as `*_reference` oracles for the blocked kernels in
//! [`kernels`](super::kernels)), activations, and the Adam update every
//! `*_train` program applies.
//!
//! Conventions: all matrices are row-major; a weight of shape `[in, out]`
//! maps `y[r, j] = sum_i x[r, i] * w[i, j] + b[j]`. Gradients accumulate
//! into per-tensor buffers that [`ParamLayout::scatter`] folds back into
//! the flat gradient vector aligned with theta.

use std::collections::HashSet;

use crate::util::Rng;

/// Named slices of one family's flat parameter vector. Registration order
/// defines the layout; `init` draws Xavier-uniform values per tensor from a
/// seeded [`Rng`], so parameters are a pure function of the seed.
pub struct ParamLayout {
    entries: Vec<(&'static str, usize, usize, usize)>, // (name, offset, len, fan_in)
    /// Registered names, for the O(1) duplicate probe in `add`.
    names: HashSet<&'static str>,
    total: usize,
}

impl ParamLayout {
    pub fn new() -> Self {
        Self { entries: Vec::new(), names: HashSet::new(), total: 0 }
    }

    /// Register a tensor of `len` elements. `fan_in` scales its init
    /// (`fan_out = len / fan_in`); `fan_in == 0` marks a zero-init bias.
    pub fn add(&mut self, name: &'static str, len: usize, fan_in: usize) {
        let _fresh = self.names.insert(name);
        debug_assert!(_fresh, "duplicate param {name}");
        self.entries.push((name, self.total, len, fan_in));
        self.total += len;
    }

    pub fn total(&self) -> usize {
        self.total
    }

    fn slot(&self, name: &'static str) -> (usize, usize) {
        let e = self
            .entries
            .iter()
            .find(|e| e.0 == name)
            .unwrap_or_else(|| panic!("unknown param tensor '{name}'"));
        (e.1, e.2)
    }

    /// Borrow one tensor out of a flat theta/grad vector.
    pub fn view<'a>(&self, flat: &'a [f32], name: &'static str) -> &'a [f32] {
        let (o, l) = self.slot(name);
        &flat[o..o + l]
    }

    /// Mutably borrow one tensor out of a flat theta vector.
    pub fn view_mut<'a>(&self, flat: &'a mut [f32], name: &'static str) -> &'a mut [f32] {
        let (o, l) = self.slot(name);
        &mut flat[o..o + l]
    }

    /// Accumulate a per-tensor gradient buffer into the flat gradient.
    pub fn scatter(&self, flat: &mut [f32], name: &'static str, grad: &[f32]) {
        let (o, l) = self.slot(name);
        debug_assert_eq!(grad.len(), l);
        for (dst, g) in flat[o..o + l].iter_mut().zip(grad) {
            *dst += g;
        }
    }

    /// Seeded Xavier-uniform init of the whole flat vector. Tensors added
    /// with `fan_in == 0` (biases) start at `bias_fill(name)`.
    pub fn init(&self, seed: u64, bias_fill: impl Fn(&'static str) -> f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut theta = vec![0.0f32; self.total];
        for &(name, off, len, fan_in) in &self.entries {
            if fan_in == 0 {
                theta[off..off + len].fill(bias_fill(name));
            } else {
                let fan_out = len / fan_in.max(1);
                let bound = (6.0 / (fan_in + fan_out.max(1)) as f32).sqrt();
                for v in &mut theta[off..off + len] {
                    *v = (rng.f32() * 2.0 - 1.0) * bound;
                }
            }
        }
        theta
    }
}

// ---------------------------------------------------------------------------
// Dense kernels — the seed scalar implementations, kept as the numeric
// oracles for the blocked/threaded kernels in `super::kernels`.
// ---------------------------------------------------------------------------

/// `y = x w + b` over `m` rows: x `[m,k]`, w `[k,n]`, b `[n]` -> `[m,n]`.
/// Seed scalar triple loop (reduction order: k ascending, exact zeros in x
/// skipped) — the oracle [`kernels::linear_into`](super::kernels::linear_into)
/// must match bit-for-bit.
pub fn linear_reference(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    let mut y = vec![0.0f32; m * n];
    for r in 0..m {
        let yr = &mut y[r * n..(r + 1) * n];
        yr.copy_from_slice(b);
        for i in 0..k {
            let xv = x[r * k + i];
            if xv == 0.0 {
                continue;
            }
            let wr = &w[i * n..(i + 1) * n];
            for (yj, wj) in yr.iter_mut().zip(wr) {
                *yj += xv * wj;
            }
        }
    }
    y
}

/// `dw += xᵀ dy`: x `[m,k]`, dy `[m,n]`, dw `[k,n]`. Seed scalar loop
/// (per-element accumulation order: sample row ascending) — the oracle for
/// [`kernels::acc_xt_dy`](super::kernels::acc_xt_dy).
pub fn acc_xt_dy_reference(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(dw.len(), k * n);
    for r in 0..m {
        for i in 0..k {
            let xv = x[r * k + i];
            if xv == 0.0 {
                continue;
            }
            let dyr = &dy[r * n..(r + 1) * n];
            let dwr = &mut dw[i * n..(i + 1) * n];
            for (dwj, dyj) in dwr.iter_mut().zip(dyr) {
                *dwj += xv * dyj;
            }
        }
    }
}

/// `dx = dy wᵀ`: dy `[m,n]`, w `[k,n]` -> `[m,k]`. Seed scalar loop
/// (reduction order: column ascending) — the oracle for
/// [`kernels::dy_wt_into`](super::kernels::dy_wt_into).
pub fn dy_wt_reference(dy: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    let mut dx = vec![0.0f32; m * k];
    for r in 0..m {
        let dyr = &dy[r * n..(r + 1) * n];
        for i in 0..k {
            let wr = &w[i * n..(i + 1) * n];
            let mut acc = 0.0f32;
            for (dyj, wj) in dyr.iter().zip(wr) {
                acc += dyj * wj;
            }
            dx[r * k + i] = acc;
        }
    }
    dx
}

/// `db += column sums of dy`: dy `[m,n]`, db `[n]`.
pub fn acc_rows(dy: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(db.len(), n);
    for r in 0..m {
        for (dbj, dyj) in db.iter_mut().zip(&dy[r * n..(r + 1) * n]) {
            *dbj += dyj;
        }
    }
}

pub fn tanh_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable `ln(1 + exp(x))`.
pub fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-(x.abs())).exp().ln_1p()
}

/// Stable softmax of one row, in place.
pub fn softmax_inplace(row: &mut [f32]) {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(f32::MIN_POSITIVE);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Stable `ln Σ exp(row)`.
pub fn log_sum_exp(row: &[f32]) -> f32 {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !mx.is_finite() {
        return mx;
    }
    row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx
}

/// Binary cross-entropy with logits, summed grad form: returns
/// `(loss, dlogit)` where `dlogit = sigmoid(logit) - target`.
pub fn bce_with_logits(logit: f32, target: f32) -> (f32, f32) {
    let loss = softplus(logit) - target * logit;
    (loss, sigmoid(logit) - target)
}

// ---------------------------------------------------------------------------
// Adam
// ---------------------------------------------------------------------------

const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// One Adam step in place. `t` is the post-increment step counter (>= 1).
pub fn adam_step(theta: &mut [f32], m: &mut [f32], v: &mut [f32], t: f32, g: &[f32], lr: f32) {
    debug_assert!(t >= 1.0);
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    for i in 0..theta.len() {
        m[i] = BETA1 * m[i] + (1.0 - BETA1) * g[i];
        v[i] = BETA2 * v[i] + (1.0 - BETA2) * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        theta[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_offsets_and_init() {
        let mut l = ParamLayout::new();
        l.add("w", 6, 2);
        l.add("b", 3, 0);
        assert_eq!(l.total(), 9);
        let theta = l.init(7, |_| 0.5);
        assert_eq!(l.view(&theta, "b"), &[0.5, 0.5, 0.5]);
        assert!(l.view(&theta, "w").iter().any(|v| *v != 0.0));
        // Deterministic per seed.
        assert_eq!(theta, l.init(7, |_| 0.5));
        assert_ne!(theta, l.init(8, |_| 0.5));
    }

    #[test]
    fn linear_matches_manual() {
        // x = [[1, 2]], w = [[1, 0, -1], [2, 1, 0]], b = [0.5, 0, 0]
        let y = linear_reference(
            &[1.0, 2.0],
            &[1.0, 0.0, -1.0, 2.0, 1.0, 0.0],
            &[0.5, 0.0, 0.0],
            1,
            2,
            3,
        );
        assert_eq!(y, vec![5.5, 2.0, -1.0]);
    }

    #[test]
    fn linear_grads_match_finite_difference() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (2, 3, 2);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let b = vec![0.1f32; n];
        // Loss: sum of squares of y.
        let loss = |w: &[f32]| -> f32 {
            linear_reference(&x, w, &b, m, k, n).iter().map(|v| v * v).sum()
        };
        let y = linear_reference(&x, &w, &b, m, k, n);
        let dy: Vec<f32> = y.iter().map(|v| 2.0 * v).collect();
        let mut dw = vec![0.0f32; k * n];
        acc_xt_dy_reference(&x, &dy, m, k, n, &mut dw);
        let eps = 1e-3f32;
        for i in 0..w.len() {
            let orig = w[i];
            w[i] = orig + eps;
            let lp = loss(&w);
            w[i] = orig - eps;
            let lm = loss(&w);
            w[i] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - dw[i]).abs() < 2e-2, "dw[{i}]: analytic {} vs numeric {}", dw[i], num);
        }
        // dx against the same loss.
        let dx = dy_wt_reference(&dy, &w, m, n, k);
        assert_eq!(dx.len(), m * k);
    }

    #[test]
    fn softmax_and_lse_consistent() {
        let mut row = vec![1.0f32, 2.0, 3.0];
        let lse = log_sum_exp(&row);
        softmax_inplace(&mut row);
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!((row[2] - (3.0f32 - lse).exp()).abs() < 1e-5);
    }

    #[test]
    fn bce_grad_sign() {
        let (l0, g0) = bce_with_logits(2.0, 1.0);
        assert!(l0 > 0.0 && g0 < 0.0);
        let (l1, g1) = bce_with_logits(2.0, 0.0);
        assert!(l1 > l0 && g1 > 0.0);
    }

    #[test]
    fn adam_descends_quadratic() {
        // Minimise f(x) = x² from x = 1.
        let mut theta = vec![1.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        for t in 1..=200 {
            let g = vec![2.0 * theta[0]];
            adam_step(&mut theta, &mut m, &mut v, t as f32, &g, 0.05);
        }
        assert!(theta[0].abs() < 0.05, "adam stalled at {}", theta[0]);
    }
}
