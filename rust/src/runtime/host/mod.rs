//! [`HostBackend`]: the pure-Rust implementation of the [`Backend`] seam.
//!
//! Implements the five program families natively — GNN auto-encoder
//! forward/train ([`gnn::GnnNet`]), latent encode, `ctrl_policy_*` + PPO
//! train ([`ctrl::CtrlNet`]), `wm_step_*` + WM train ([`wm::WmNet`]) — over
//! plain `f32` buffers, seeded-initialised, so the full RLFlow
//! collect -> AE -> WM -> dream-PPO -> eval loop runs offline and
//! deterministically with no `manifest.json` and no `xla_extension`.
//!
//! The backend publishes a synthetic [`Manifest`] carrying the same
//! hyperparameter keys, parameter sizes and per-program argument/output
//! specs the AOT pipeline would write, and validates every call against it
//! exactly like the PJRT engine — the contract test in
//! `tests/host_backend.rs` drives every program through those specs so the
//! two backends stay interchangeable.

pub mod ctrl;
pub mod gnn;
pub mod kernels;
pub mod nn;
pub mod wm;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::interp::Tensor;

use self::kernels::{KernelCfg, Workspace};
use super::backend::{validate_args, Backend, ExecStats, TensorView};
use super::manifest::{ArgSpec, ArtifactSpec, Dt, Manifest};
use super::params::ParamStore;

/// Host model dimensions. Defaults are sized for the real rule library and
/// the zoo graphs; tests shrink them for speed.
#[derive(Debug, Clone)]
pub struct HostConfig {
    pub max_nodes: usize,
    pub node_feats: usize,
    pub gnn_hidden: usize,
    pub latent: usize,
    pub rnn_hidden: usize,
    pub mdn_k: usize,
    pub act_emb: usize,
    pub ctrl_hidden: usize,
    /// Xfer slot count incl. the NO-OP slot (rule library size + 1).
    pub n_xfers1: usize,
    pub max_locs: usize,
    pub b_dream: usize,
    pub b_wm: usize,
    pub seq_len: usize,
    pub b_ppo: usize,
    pub b_enc: usize,
    /// Kernel implementation, thread budget and reduction-order version.
    /// Outputs are bit-identical for every thread count and lane width
    /// *within* an order; V1↔V2 agree to float tolerance — see [`kernels`].
    /// Defaults honour `RLFLOW_HOST_THREADS` / `RLFLOW_HOST_REDUCTION`.
    pub kernels: KernelCfg,
}

impl Default for HostConfig {
    fn default() -> Self {
        Self {
            max_nodes: 320,
            node_feats: 32,
            gnn_hidden: 32,
            latent: 16,
            rnn_hidden: 32,
            mdn_k: 3,
            act_emb: 8,
            ctrl_hidden: 64,
            n_xfers1: crate::xfer::library::standard_library().len() + 1,
            max_locs: 200,
            b_dream: 8,
            b_wm: 8,
            seq_len: 8,
            b_ppo: 64,
            b_enc: 8,
            kernels: KernelCfg::default(),
        }
    }
}

pub struct HostBackend {
    cfg: HostConfig,
    manifest: Manifest,
    gnn: gnn::GnnNet,
    wm: wm::WmNet,
    ctrl: ctrl::CtrlNet,
    stats: RefCell<HashMap<String, ExecStats>>,
    /// Scratch arena shared by every program (the backend is single-caller
    /// by contract, like the PJRT engine); steady-state calls reuse these
    /// buffers instead of allocating.
    ws: RefCell<Workspace>,
}

impl Default for HostBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl HostBackend {
    pub fn new() -> Self {
        Self::with_config(HostConfig::default())
    }

    pub fn with_config(cfg: HostConfig) -> Self {
        let gnn = gnn::GnnNet::new(cfg.max_nodes, cfg.node_feats, cfg.gnn_hidden, cfg.latent);
        let wm = wm::WmNet::new(
            cfg.latent,
            cfg.rnn_hidden,
            cfg.mdn_k,
            cfg.n_xfers1,
            cfg.max_locs,
            cfg.act_emb,
        );
        let ctrl = ctrl::CtrlNet::new(
            cfg.latent,
            cfg.rnn_hidden,
            cfg.ctrl_hidden,
            cfg.n_xfers1,
            cfg.max_locs,
        );
        let manifest = build_manifest(&cfg, gnn.n_params(), wm.n_params(), ctrl.n_params());
        Self {
            cfg,
            manifest,
            gnn,
            wm,
            ctrl,
            stats: RefCell::new(HashMap::new()),
            ws: RefCell::new(Workspace::new()),
        }
    }

    pub fn config(&self) -> &HostConfig {
        &self.cfg
    }

    /// Cumulative scratch-arena counters (reuses / allocations / bytes).
    pub fn workspace_stats(&self) -> kernels::WorkspaceStats {
        self.ws.borrow().stats()
    }

    fn dispatch(
        &self,
        ws: &mut Workspace,
        program: &str,
        args: &[TensorView],
    ) -> anyhow::Result<Vec<Tensor>> {
        let cfg = &self.cfg;
        let kc = &cfg.kernels;
        let (z, r) = (cfg.latent, cfg.rnn_hidden);
        let (x1, locs, zk) = (cfg.n_xfers1, cfg.max_locs, cfg.latent * cfg.mdn_k);
        match program {
            "gnn_init" | "wm_init" | "ctrl_init" => {
                let seed = args[0].scalar_i32()?;
                let theta = match program {
                    "gnn_init" => self.gnn.init(seed),
                    "wm_init" => self.wm.init(seed),
                    _ => self.ctrl.init(seed),
                };
                let p = theta.len();
                Ok(vec![Tensor::from_vec(&[p], theta)?])
            }
            "gnn_encode_1" | "gnn_encode_b" => {
                let b = if program == "gnn_encode_1" { 1 } else { cfg.b_enc };
                let zs = self.gnn.encode(
                    ws,
                    kc,
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                    args[2].as_f32()?,
                    args[3].as_f32()?,
                    b,
                );
                Ok(vec![Tensor::from_vec(&[b, z], zs)?])
            }
            "gnn_ae_train" => {
                let b = cfg.b_enc;
                let mut theta = args[0].as_f32()?.to_vec();
                let mut mm = args[1].as_f32()?.to_vec();
                let mut vv = args[2].as_f32()?.to_vec();
                let t = args[3].scalar_f32()? + 1.0;
                let lr = args[7].scalar_f32()?;
                let loss = self.gnn.train_step(
                    ws,
                    kc,
                    &mut theta,
                    &mut mm,
                    &mut vv,
                    t,
                    args[4].as_f32()?,
                    args[5].as_f32()?,
                    args[6].as_f32()?,
                    b,
                    lr,
                );
                let p = theta.len();
                Ok(vec![
                    Tensor::from_vec(&[p], theta)?,
                    Tensor::from_vec(&[p], mm)?,
                    Tensor::from_vec(&[p], vv)?,
                    Tensor::from_vec(&[], vec![t])?,
                    Tensor::from_vec(&[], vec![loss])?,
                ])
            }
            "ctrl_policy_1" | "ctrl_policy_b" => {
                let b = if program == "ctrl_policy_1" { 1 } else { cfg.b_dream };
                let out = self.ctrl.policy(
                    ws,
                    kc,
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                    args[2].as_f32()?,
                    b,
                );
                Ok(vec![
                    Tensor::from_vec(&[b, x1], out.xlogits)?,
                    Tensor::from_vec(&[b, x1 * locs], out.llogits)?,
                    Tensor::from_vec(&[b], out.values)?,
                ])
            }
            "ctrl_train" => {
                let b = cfg.b_ppo;
                let mut theta = args[0].as_f32()?.to_vec();
                let mut mm = args[1].as_f32()?.to_vec();
                let mut vv = args[2].as_f32()?.to_vec();
                let t = args[3].scalar_f32()? + 1.0;
                let stats = self.ctrl.train_step(
                    ws,
                    kc,
                    &mut theta,
                    &mut mm,
                    &mut vv,
                    t,
                    args[4].as_f32()?,
                    args[5].as_f32()?,
                    args[6].as_i32()?,
                    args[7].as_f32()?,
                    args[8].as_f32()?,
                    args[9].as_f32()?,
                    args[10].as_f32()?,
                    args[11].as_f32()?,
                    b,
                    args[12].scalar_f32()?,
                    args[13].scalar_f32()?,
                    args[14].scalar_f32()?,
                );
                let p = theta.len();
                Ok(vec![
                    Tensor::from_vec(&[p], theta)?,
                    Tensor::from_vec(&[p], mm)?,
                    Tensor::from_vec(&[p], vv)?,
                    Tensor::from_vec(&[], vec![t])?,
                    Tensor::from_vec(&[], vec![stats.pi_loss])?,
                    Tensor::from_vec(&[], vec![stats.v_loss])?,
                    Tensor::from_vec(&[], vec![stats.entropy])?,
                    Tensor::from_vec(&[], vec![stats.approx_kl])?,
                ])
            }
            "wm_step_1" | "wm_step_b" => {
                let b = if program == "wm_step_1" { 1 } else { cfg.b_dream };
                let out = self.wm.step(
                    ws,
                    kc,
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                    args[2].as_i32()?,
                    args[3].as_f32()?,
                    args[4].as_f32()?,
                    b,
                );
                Ok(vec![
                    Tensor::from_vec(&[b, zk], out.log_pi)?,
                    Tensor::from_vec(&[b, zk], out.mu)?,
                    Tensor::from_vec(&[b, zk], out.log_sig)?,
                    Tensor::from_vec(&[b], out.reward)?,
                    Tensor::from_vec(&[b, x1], out.mask_logits)?,
                    Tensor::from_vec(&[b], out.done_logits)?,
                    Tensor::from_vec(&[b, r], out.h1)?,
                    Tensor::from_vec(&[b, r], out.c1)?,
                ])
            }
            "wm_train" => {
                let (b, t_len) = (cfg.b_wm, cfg.seq_len);
                let mut theta = args[0].as_f32()?.to_vec();
                let mut mm = args[1].as_f32()?.to_vec();
                let mut vv = args[2].as_f32()?.to_vec();
                let t = args[3].scalar_f32()? + 1.0;
                let lr = args[11].scalar_f32()?;
                let losses = self.wm.train_step(
                    ws,
                    kc,
                    &mut theta,
                    &mut mm,
                    &mut vv,
                    t,
                    args[4].as_f32()?,
                    args[5].as_i32()?,
                    args[6].as_f32()?,
                    args[7].as_f32()?,
                    args[8].as_f32()?,
                    args[9].as_f32()?,
                    args[10].as_f32()?,
                    b,
                    t_len,
                    lr,
                );
                let p = theta.len();
                Ok(vec![
                    Tensor::from_vec(&[p], theta)?,
                    Tensor::from_vec(&[p], mm)?,
                    Tensor::from_vec(&[p], vv)?,
                    Tensor::from_vec(&[], vec![t])?,
                    Tensor::from_vec(&[], vec![losses.total])?,
                    Tensor::from_vec(&[], vec![losses.nll])?,
                    Tensor::from_vec(&[], vec![losses.reward_mse])?,
                    Tensor::from_vec(&[], vec![losses.mask_bce])?,
                    Tensor::from_vec(&[], vec![losses.done_bce])?,
                ])
            }
            other => anyhow::bail!("host backend has no program '{other}'"),
        }
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn exec(&self, program: &str, args: &[TensorView]) -> anyhow::Result<Vec<Tensor>> {
        crate::util::failpoint::check("host.exec")?;
        let spec = self.manifest.artifact(program)?;
        validate_args(program, spec, args)?;
        let t0 = Instant::now();
        let mut ws = self.ws.borrow_mut();
        let w0 = ws.stats();
        let outs = self.dispatch(&mut ws, program, args)?;
        let w1 = ws.stats();
        drop(ws);
        anyhow::ensure!(
            outs.len() == spec.outputs.len(),
            "{program}: produced {} outputs, spec says {}",
            outs.len(),
            spec.outputs.len()
        );
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(program.to_string()).or_default();
        s.calls += 1;
        s.total_s += t0.elapsed().as_secs_f64();
        s.alloc_bytes += w1.alloc_bytes - w0.alloc_bytes;
        s.scratch_reuse += w1.reuses - w0.reuses;
        Ok(outs)
    }

    fn exec_batch(
        &self,
        program: &str,
        calls: &[Vec<TensorView>],
    ) -> anyhow::Result<Vec<Vec<Tensor>>> {
        // Amortised path: one manifest lookup, one workspace checkout and
        // one stats update for the whole batch of calls.
        crate::util::failpoint::check("host.exec")?;
        let spec = self.manifest.artifact(program)?;
        let t0 = Instant::now();
        let mut ws = self.ws.borrow_mut();
        let w0 = ws.stats();
        let mut all = Vec::with_capacity(calls.len());
        for args in calls {
            validate_args(program, spec, args)?;
            let outs = self.dispatch(&mut ws, program, args)?;
            anyhow::ensure!(
                outs.len() == spec.outputs.len(),
                "{program}: produced {} outputs, spec says {}",
                outs.len(),
                spec.outputs.len()
            );
            all.push(outs);
        }
        let w1 = ws.stats();
        drop(ws);
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(program.to_string()).or_default();
        s.calls += calls.len() as u64;
        s.total_s += t0.elapsed().as_secs_f64();
        s.alloc_bytes += w1.alloc_bytes - w0.alloc_bytes;
        s.scratch_reuse += w1.reuses - w0.reuses;
        Ok(all)
    }

    fn exec_with_params(
        &self,
        program: &str,
        params: &ParamStore,
        rest: &[TensorView],
    ) -> anyhow::Result<Vec<Tensor>> {
        let n = params.theta.len();
        let mut args: Vec<TensorView> = Vec::with_capacity(rest.len() + 1);
        args.push(TensorView::f32(&params.theta, &[n]));
        args.extend(rest.iter().cloned());
        self.exec(program, &args)
    }

    fn exec_with_params_batch(
        &self,
        program: &str,
        params: &ParamStore,
        rests: &[Vec<TensorView>],
    ) -> anyhow::Result<Vec<Vec<Tensor>>> {
        // Bind theta once for the whole batch.
        let n = params.theta.len();
        let theta = TensorView::f32(&params.theta, &[n]);
        let calls: Vec<Vec<TensorView>> = rests
            .iter()
            .map(|rest| {
                let mut args = Vec::with_capacity(rest.len() + 1);
                args.push(theta.clone());
                args.extend(rest.iter().cloned());
                args
            })
            .collect();
        self.exec_batch(program, &calls)
    }

    fn train_step(
        &self,
        program: &str,
        params: &mut ParamStore,
        rest: &[TensorView],
    ) -> anyhow::Result<Vec<Tensor>> {
        // In-place fast path: the net updates the store's (theta, m, v)
        // vectors directly — no copies through the exec value contract.
        // Arguments are still validated against the full manifest spec.
        let spec = self.manifest.artifact(program)?;
        {
            let mut args = params.train_args();
            args.extend(rest.iter().cloned());
            validate_args(program, spec, &args)?;
        }
        let cfg = &self.cfg;
        let kc = &cfg.kernels;
        let t0 = Instant::now();
        let mut ws = self.ws.borrow_mut();
        let w0 = ws.stats();
        let t_new = params.t + 1.0;
        let outs = match program {
            "gnn_ae_train" => {
                let lr = rest[3].scalar_f32()?;
                let loss = self.gnn.train_step(
                    &mut ws,
                    kc,
                    &mut params.theta,
                    &mut params.m,
                    &mut params.v,
                    t_new,
                    rest[0].as_f32()?,
                    rest[1].as_f32()?,
                    rest[2].as_f32()?,
                    cfg.b_enc,
                    lr,
                );
                vec![Tensor::from_vec(&[], vec![loss])?]
            }
            "ctrl_train" => {
                let stats = self.ctrl.train_step(
                    &mut ws,
                    kc,
                    &mut params.theta,
                    &mut params.m,
                    &mut params.v,
                    t_new,
                    rest[0].as_f32()?,
                    rest[1].as_f32()?,
                    rest[2].as_i32()?,
                    rest[3].as_f32()?,
                    rest[4].as_f32()?,
                    rest[5].as_f32()?,
                    rest[6].as_f32()?,
                    rest[7].as_f32()?,
                    cfg.b_ppo,
                    rest[8].scalar_f32()?,
                    rest[9].scalar_f32()?,
                    rest[10].scalar_f32()?,
                );
                vec![
                    Tensor::from_vec(&[], vec![stats.pi_loss])?,
                    Tensor::from_vec(&[], vec![stats.v_loss])?,
                    Tensor::from_vec(&[], vec![stats.entropy])?,
                    Tensor::from_vec(&[], vec![stats.approx_kl])?,
                ]
            }
            "wm_train" => {
                let losses = self.wm.train_step(
                    &mut ws,
                    kc,
                    &mut params.theta,
                    &mut params.m,
                    &mut params.v,
                    t_new,
                    rest[0].as_f32()?,
                    rest[1].as_i32()?,
                    rest[2].as_f32()?,
                    rest[3].as_f32()?,
                    rest[4].as_f32()?,
                    rest[5].as_f32()?,
                    rest[6].as_f32()?,
                    cfg.b_wm,
                    cfg.seq_len,
                    rest[7].scalar_f32()?,
                );
                vec![
                    Tensor::from_vec(&[], vec![losses.total])?,
                    Tensor::from_vec(&[], vec![losses.nll])?,
                    Tensor::from_vec(&[], vec![losses.reward_mse])?,
                    Tensor::from_vec(&[], vec![losses.mask_bce])?,
                    Tensor::from_vec(&[], vec![losses.done_bce])?,
                ]
            }
            other => anyhow::bail!("'{other}' is not a train program"),
        };
        let w1 = ws.stats();
        drop(ws);
        params.t = t_new;
        params.version += 1;
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(program.to_string()).or_default();
        s.calls += 1;
        s.total_s += t0.elapsed().as_secs_f64();
        s.alloc_bytes += w1.alloc_bytes - w0.alloc_bytes;
        s.scratch_reuse += w1.reuses - w0.reuses;
        Ok(outs)
    }

    fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }
}

// ---------------------------------------------------------------------------
// Synthetic manifest (the host side of the L2 -> L3 contract)
// ---------------------------------------------------------------------------

fn f32a(name: &str, shape: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), shape: shape.to_vec(), dtype: Dt::F32 }
}

fn i32a(name: &str, shape: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), shape: shape.to_vec(), dtype: Dt::I32 }
}

fn outs(names: &[&str]) -> Vec<String> {
    names.iter().map(|s| s.to_string()).collect()
}

fn build_manifest(cfg: &HostConfig, p_gnn: usize, p_wm: usize, p_ctrl: usize) -> Manifest {
    let (n, f, z, r) = (cfg.max_nodes, cfg.node_feats, cfg.latent, cfg.rnn_hidden);
    let (x1, locs) = (cfg.n_xfers1, cfg.max_locs);
    let mut hp = HashMap::new();
    for (key, v) in [
        ("MAX_NODES", n),
        ("NODE_FEATS", f),
        ("LATENT", z),
        ("RNN_HIDDEN", r),
        ("MDN_K", cfg.mdn_k),
        ("N_XFERS", x1 - 1),
        ("N_XFERS1", x1),
        ("MAX_LOCS", locs),
        ("B_DREAM", cfg.b_dream),
        ("B_WM", cfg.b_wm),
        ("SEQ_LEN", cfg.seq_len),
        ("B_PPO", cfg.b_ppo),
        ("B_ENC", cfg.b_enc),
    ] {
        hp.insert(key.to_string(), v as f64);
    }
    let mut param_sizes = HashMap::new();
    param_sizes.insert("gnn".to_string(), p_gnn);
    param_sizes.insert("wm".to_string(), p_wm);
    param_sizes.insert("ctrl".to_string(), p_ctrl);

    let adam_in = |p: usize| {
        vec![f32a("theta", &[p]), f32a("m", &[p]), f32a("v", &[p]), f32a("t", &[])]
    };
    let encode_in = |p: usize, b: usize| {
        vec![
            f32a("theta", &[p]),
            f32a("feats", &[b, n, f]),
            f32a("adj", &[b, n, n]),
            f32a("mask", &[b, n]),
        ]
    };
    let policy_in = |b: usize| {
        vec![f32a("theta", &[p_ctrl]), f32a("z", &[b, z]), f32a("h", &[b, r])]
    };
    let wm_step_in = |b: usize| {
        vec![
            f32a("theta", &[p_wm]),
            f32a("z", &[b, z]),
            i32a("a", &[b, 2]),
            f32a("h", &[b, r]),
            f32a("c", &[b, r]),
        ]
    };
    let wm_step_out = outs(&[
        "log_pi", "mu", "log_sig", "reward", "mask_logits", "done_logits", "h1", "c1",
    ]);
    let adam_out = ["theta", "m", "v", "t"];

    let mut artifacts = HashMap::new();
    let mut put = |name: &str, inputs: Vec<ArgSpec>, outputs: Vec<String>| {
        artifacts.insert(
            name.to_string(),
            ArtifactSpec { file: format!("{name}.host"), inputs, outputs },
        );
    };

    put("gnn_init", vec![i32a("seed", &[])], outs(&["theta"]));
    put("wm_init", vec![i32a("seed", &[])], outs(&["theta"]));
    put("ctrl_init", vec![i32a("seed", &[])], outs(&["theta"]));
    put("gnn_encode_1", encode_in(p_gnn, 1), outs(&["z"]));
    put("gnn_encode_b", encode_in(p_gnn, cfg.b_enc), outs(&["z"]));
    {
        let mut inputs = adam_in(p_gnn);
        inputs.extend(encode_in(p_gnn, cfg.b_enc).into_iter().skip(1));
        inputs.push(f32a("lr", &[]));
        let mut o = adam_out.to_vec();
        o.push("loss");
        put("gnn_ae_train", inputs, outs(&o));
    }
    put("ctrl_policy_1", policy_in(1), outs(&["xlogits", "llogits", "values"]));
    put("ctrl_policy_b", policy_in(cfg.b_dream), outs(&["xlogits", "llogits", "values"]));
    {
        let b = cfg.b_ppo;
        let mut inputs = adam_in(p_ctrl);
        inputs.extend([
            f32a("z", &[b, z]),
            f32a("h", &[b, r]),
            i32a("act", &[b, 2]),
            f32a("logp", &[b]),
            f32a("adv", &[b]),
            f32a("ret", &[b]),
            f32a("xmask", &[b, x1]),
            f32a("lmask", &[b, locs]),
            f32a("lr", &[]),
            f32a("clip", &[]),
            f32a("ent_coef", &[]),
        ]);
        let mut o = adam_out.to_vec();
        o.extend(["pi_loss", "v_loss", "entropy", "approx_kl"]);
        put("ctrl_train", inputs, outs(&o));
    }
    put("wm_step_1", wm_step_in(1), wm_step_out.clone());
    put("wm_step_b", wm_step_in(cfg.b_dream), wm_step_out);
    {
        let (b, t) = (cfg.b_wm, cfg.seq_len);
        let mut inputs = adam_in(p_wm);
        inputs.extend([
            f32a("z", &[b, t, z]),
            i32a("a", &[b, t, 2]),
            f32a("z_next", &[b, t, z]),
            f32a("r", &[b, t]),
            f32a("xm", &[b, t, x1]),
            f32a("done", &[b, t]),
            f32a("valid", &[b, t]),
            f32a("lr", &[]),
        ]);
        let mut o = adam_out.to_vec();
        o.extend(["total", "nll", "reward_mse", "mask_bce", "done_bce"]);
        put("wm_train", inputs, outs(&o));
    }

    Manifest { dir: PathBuf::from("(host)"), hp, param_sizes, artifacts }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> HostBackend {
        HostBackend::with_config(HostConfig {
            max_nodes: 16,
            node_feats: 24,
            gnn_hidden: 8,
            latent: 6,
            rnn_hidden: 8,
            mdn_k: 2,
            act_emb: 4,
            ctrl_hidden: 8,
            n_xfers1: 7,
            max_locs: 12,
            b_dream: 3,
            b_wm: 2,
            seq_len: 3,
            b_ppo: 4,
            b_enc: 2,
            kernels: KernelCfg::default(),
        })
    }

    #[test]
    fn manifest_names_cover_all_program_families() {
        let b = tiny();
        let names: Vec<&str> = vec![
            "gnn_init",
            "gnn_encode_1",
            "gnn_encode_b",
            "gnn_ae_train",
            "ctrl_init",
            "ctrl_policy_1",
            "ctrl_policy_b",
            "ctrl_train",
            "wm_init",
            "wm_step_1",
            "wm_step_b",
            "wm_train",
        ];
        for n in &names {
            assert!(b.manifest().artifact(n).is_ok(), "missing program {n}");
        }
        assert_eq!(b.manifest().artifacts.len(), names.len());
    }

    #[test]
    fn init_validates_and_sizes_match_param_sizes() {
        let b = tiny();
        for fam in ["gnn", "wm", "ctrl"] {
            let out = b.exec(&format!("{fam}_init"), &[TensorView::ScalarI32(9)]).unwrap();
            assert_eq!(out[0].data.len(), b.manifest().param_sizes[fam]);
        }
        // Wrong dtype rejected.
        assert!(b.exec("gnn_init", &[TensorView::ScalarF32(9.0)]).is_err());
        // Wrong arity rejected.
        assert!(b.exec("gnn_init", &[]).is_err());
        // Unknown program rejected.
        assert!(b.exec("nope", &[TensorView::ScalarI32(0)]).is_err());
    }

    #[test]
    fn stats_are_recorded_per_program() {
        let b = tiny();
        let _ = b.exec("ctrl_init", &[TensorView::ScalarI32(0)]).unwrap();
        let _ = b.exec("ctrl_init", &[TensorView::ScalarI32(1)]).unwrap();
        let stats = b.stats();
        assert_eq!(stats["ctrl_init"].calls, 2);
    }
}
