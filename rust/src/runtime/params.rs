//! Parameter + optimiser state for one model family (gnn / wm / ctrl).
//!
//! Parameters are flat f32 vectors (the L2 contract, see model.py). The
//! store owns `(theta, m, v, t)` as host vectors, threads them through
//! train-step artifacts, and persists to a tiny length-prefixed binary
//! format (`.rlw`) so trained agents can be reloaded between runs.

use std::io::{Read, Write};
use std::path::Path;

use xla::Literal;

use super::engine::{lit_f32, lit_scalar_f32, scalar_f32, to_vec_f32, Engine};

#[derive(Debug, Clone)]
pub struct ParamStore {
    pub family: String,
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    /// Monotone counter bumped on every parameter change; keys the
    /// engine's device-resident theta cache.
    pub version: u64,
}

impl ParamStore {
    /// Initialise via the family's `*_init` artifact.
    pub fn init(engine: &Engine, family: &str, seed: i32) -> anyhow::Result<Self> {
        let out = engine.exec(&format!("{family}_init"), &[Literal::scalar(seed)])?;
        let theta = to_vec_f32(&out[0])?;
        let n = theta.len();
        let expected = *engine
            .manifest
            .param_sizes
            .get(family)
            .ok_or_else(|| anyhow::anyhow!("unknown family {family}"))?;
        anyhow::ensure!(n == expected, "{family}: init returned {n} params, manifest says {expected}");
        Ok(Self { family: family.to_string(), theta, m: vec![0.0; n], v: vec![0.0; n], t: 0.0, version: 0 })
    }

    pub fn n_params(&self) -> usize {
        self.theta.len()
    }

    /// The four leading arguments of every `*_train` artifact.
    pub fn train_args(&self) -> anyhow::Result<Vec<Literal>> {
        let n = self.theta.len();
        Ok(vec![
            lit_f32(&self.theta, &[n])?,
            lit_f32(&self.m, &[n])?,
            lit_f32(&self.v, &[n])?,
            lit_scalar_f32(self.t),
        ])
    }

    pub fn theta_lit(&self) -> anyhow::Result<Literal> {
        lit_f32(&self.theta, &[self.theta.len()])
    }

    /// Absorb the four leading outputs of a train-step artifact.
    pub fn absorb(&mut self, outs: &[Literal]) -> anyhow::Result<()> {
        anyhow::ensure!(outs.len() >= 4, "train step returned too few outputs");
        self.theta = to_vec_f32(&outs[0])?;
        self.m = to_vec_f32(&outs[1])?;
        self.v = to_vec_f32(&outs[2])?;
        self.t = scalar_f32(&outs[3])?;
        self.version += 1;
        Ok(())
    }

    // ---- persistence ----------------------------------------------------

    pub fn save<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"RLW1")?;
        let name = self.family.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(self.theta.len() as u64).to_le_bytes())?;
        f.write_all(&self.t.to_le_bytes())?;
        for vec in [&self.theta, &self.m, &self.v] {
            let bytes: Vec<u8> = vec.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load_file<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"RLW1", "bad magic");
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let name_len = u32::from_le_bytes(len4) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let n = u64::from_le_bytes(len8) as usize;
        let mut t4 = [0u8; 4];
        f.read_exact(&mut t4)?;
        let t = f32::from_le_bytes(t4);
        let mut read_vec = |n: usize| -> anyhow::Result<Vec<f32>> {
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            Ok(buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let theta = read_vec(n)?;
        let m = read_vec(n)?;
        let v = read_vec(n)?;
        Ok(Self { family: String::from_utf8(name)?, theta, m, v, t, version: 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let store = ParamStore {
            family: "wm".into(),
            theta: vec![1.5, -2.0, 3.25],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.01, 0.02, 0.03],
            t: 7.0,
            version: 3,
        };
        let path = std::env::temp_dir().join("rlflow_params_test.rlw");
        store.save(&path).unwrap();
        let back = ParamStore::load_file(&path).unwrap();
        assert_eq!(back.family, "wm");
        assert_eq!(back.theta, store.theta);
        assert_eq!(back.m, store.m);
        assert_eq!(back.v, store.v);
        assert_eq!(back.t, 7.0);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let path = std::env::temp_dir().join("rlflow_params_bad.rlw");
        std::fs::write(&path, b"JUNKdata").unwrap();
        assert!(ParamStore::load_file(&path).is_err());
    }
}
