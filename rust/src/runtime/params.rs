//! Parameter + optimiser state for one model family (gnn / wm / ctrl).
//!
//! Parameters are flat f32 vectors (the L2 contract, see model.py). The
//! store owns `(theta, m, v, t)` as host vectors, threads them through the
//! backend's train-step programs, and persists to a tiny length-prefixed
//! binary format (`.rlw`) so trained agents can be reloaded between runs.

use std::io::{Read, Write};
use std::path::Path;

use crate::interp::Tensor;

use super::backend::{Backend, TensorView};

#[derive(Debug, Clone)]
pub struct ParamStore {
    pub family: String,
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    /// Monotone counter bumped on every parameter change; keys the
    /// backend's cached uploaded-theta entries.
    pub version: u64,
}

impl ParamStore {
    /// Initialise via the family's `*_init` program on any backend.
    pub fn init(backend: &dyn Backend, family: &str, seed: i32) -> anyhow::Result<Self> {
        let out = backend.exec(&format!("{family}_init"), &[TensorView::ScalarI32(seed)])?;
        anyhow::ensure!(!out.is_empty(), "{family}_init returned no outputs");
        let theta = out[0].data.clone();
        let n = theta.len();
        let expected = *backend
            .manifest()
            .param_sizes
            .get(family)
            .ok_or_else(|| anyhow::anyhow!("unknown family {family}"))?;
        anyhow::ensure!(
            n == expected,
            "{family}: init returned {n} params, manifest says {expected}"
        );
        Ok(Self {
            family: family.to_string(),
            theta,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0.0,
            version: 0,
        })
    }

    pub fn n_params(&self) -> usize {
        self.theta.len()
    }

    /// The four leading arguments of every `*_train` program.
    pub fn train_args(&self) -> Vec<TensorView<'_>> {
        let n = self.theta.len();
        vec![
            TensorView::f32(&self.theta, &[n]),
            TensorView::f32(&self.m, &[n]),
            TensorView::f32(&self.v, &[n]),
            TensorView::ScalarF32(self.t),
        ]
    }

    /// Absorb the four leading outputs of a train-step program.
    pub fn absorb(&mut self, outs: &[Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(outs.len() >= 4, "train step returned too few outputs");
        for (i, name) in ["theta", "m", "v"].iter().enumerate() {
            anyhow::ensure!(
                outs[i].data.len() == self.theta.len(),
                "{}: train step returned {} values for {name}, store holds {}",
                self.family,
                outs[i].data.len(),
                self.theta.len()
            );
        }
        anyhow::ensure!(outs[3].data.len() == 1, "{}: t output is not a scalar", self.family);
        self.theta = outs[0].data.clone();
        self.m = outs[1].data.clone();
        self.v = outs[2].data.clone();
        self.t = outs[3].data[0];
        self.version += 1;
        Ok(())
    }

    // ---- persistence ----------------------------------------------------

    pub fn save<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"RLW1")?;
        let name = self.family.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(self.theta.len() as u64).to_le_bytes())?;
        f.write_all(&self.t.to_le_bytes())?;
        for vec in [&self.theta, &self.m, &self.v] {
            let bytes: Vec<u8> = vec.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load_file<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"RLW1", "bad magic");
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let name_len = u32::from_le_bytes(len4) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let n = u64::from_le_bytes(len8) as usize;
        let mut t4 = [0u8; 4];
        f.read_exact(&mut t4)?;
        let t = f32::from_le_bytes(t4);
        let mut read_vec = |n: usize| -> anyhow::Result<Vec<f32>> {
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            Ok(buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let theta = read_vec(n)?;
        let m = read_vec(n)?;
        let v = read_vec(n)?;
        Ok(Self { family: String::from_utf8(name)?, theta, m, v, t, version: 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let store = ParamStore {
            family: "wm".into(),
            theta: vec![1.5, -2.0, 3.25],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.01, 0.02, 0.03],
            t: 7.0,
            version: 3,
        };
        let path = std::env::temp_dir().join("rlflow_params_test.rlw");
        store.save(&path).unwrap();
        let back = ParamStore::load_file(&path).unwrap();
        assert_eq!(back.family, "wm");
        assert_eq!(back.theta, store.theta);
        assert_eq!(back.m, store.m);
        assert_eq!(back.v, store.v);
        assert_eq!(back.t, 7.0);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let path = std::env::temp_dir().join("rlflow_params_bad.rlw");
        std::fs::write(&path, b"JUNKdata").unwrap();
        assert!(ParamStore::load_file(&path).is_err());
    }

    #[test]
    fn absorb_bumps_version_and_checks_size() {
        let mut store = ParamStore {
            family: "ctrl".into(),
            theta: vec![0.0; 3],
            m: vec![0.0; 3],
            v: vec![0.0; 3],
            t: 0.0,
            version: 0,
        };
        let outs = vec![
            Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap(),
            Tensor::from_vec(&[3], vec![0.1, 0.1, 0.1]).unwrap(),
            Tensor::from_vec(&[3], vec![0.2, 0.2, 0.2]).unwrap(),
            Tensor::from_vec(&[], vec![1.0]).unwrap(),
        ];
        store.absorb(&outs).unwrap();
        assert_eq!(store.version, 1);
        assert_eq!(store.t, 1.0);
        assert_eq!(store.theta, vec![1.0, 2.0, 3.0]);
        let wrong = vec![Tensor::from_vec(&[1], vec![1.0]).unwrap(); 4];
        assert!(store.absorb(&wrong).is_err());
    }
}
