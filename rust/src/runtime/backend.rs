//! The backend seam: every neural program the RLFlow loop executes —
//! GNN auto-encoder forward/train, latent encode, `ctrl_policy_*`,
//! `wm_step_*`, and the train steps — goes through the [`Backend`] trait.
//!
//! A backend owns three things:
//!  1. a [`Manifest`] describing its program contract (names, argument
//!     shapes/dtypes, output arity, hyperparameters, parameter sizes);
//!  2. execution of named programs over typed [`TensorView`] arguments,
//!     returning [`Tensor`] outputs;
//!  3. parameter handling — `*_init` programs seed a
//!     [`ParamStore`](super::ParamStore), and [`Backend::exec_with_params`]
//!     lets the backend cache an uploaded copy of a store's theta keyed by
//!     its version (the PJRT backend keeps it device-resident).
//!
//! Two implementations exist: [`PjrtBackend`](super::PjrtBackend) runs the
//! AOT-compiled XLA artifacts, and [`HostBackend`](super::HostBackend)
//! implements the same program families natively in Rust so the full
//! collect -> GNN-AE -> WM -> dream-PPO -> eval cycle runs offline and
//! deterministically — no `manifest.json`, no `xla_extension`.

use std::collections::HashMap;

use crate::interp::Tensor;

use super::manifest::{ArtifactSpec, Dt, Manifest};

/// Per-program execution accounting (calls, wall-clock, compile time,
/// scratch-arena traffic).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    /// Number of times the program ran.
    pub calls: u64,
    /// Total wall-clock seconds spent executing.
    pub total_s: f64,
    /// Seconds spent compiling/loading the program (PJRT path).
    pub compile_s: f64,
    /// Bytes of fresh scratch memory the program's calls allocated (host
    /// path; zero in steady state once the workspace is warm).
    pub alloc_bytes: u64,
    /// Scratch-buffer checkouts served from the workspace free list
    /// without allocating (host path).
    pub scratch_reuse: u64,
}

/// A borrowed, typed view of one program argument. Array variants carry an
/// explicit shape; scalar variants are rank-0 and own their value.
#[derive(Debug, Clone)]
pub enum TensorView<'a> {
    /// Borrowed f32 array with an explicit shape.
    F32 {
        /// Flat element buffer (row-major).
        data: &'a [f32],
        /// Logical dimensions; product must equal `data.len()`.
        shape: Vec<usize>,
    },
    /// Borrowed i32 array with an explicit shape.
    I32 {
        /// Flat element buffer (row-major).
        data: &'a [i32],
        /// Logical dimensions; product must equal `data.len()`.
        shape: Vec<usize>,
    },
    /// Owned rank-0 f32 value.
    ScalarF32(f32),
    /// Owned rank-0 i32 value.
    ScalarI32(i32),
}

impl<'a> TensorView<'a> {
    /// View a borrowed f32 buffer under `shape`.
    pub fn f32(data: &'a [f32], shape: &[usize]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len(), "f32 view shape mismatch");
        TensorView::F32 { data, shape: shape.to_vec() }
    }

    /// View a borrowed i32 buffer under `shape`.
    pub fn i32(data: &'a [i32], shape: &[usize]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len(), "i32 view shape mismatch");
        TensorView::I32 { data, shape: shape.to_vec() }
    }

    /// Number of elements the view covers (1 for scalars).
    pub fn n_elems(&self) -> usize {
        match self {
            TensorView::F32 { data, .. } => data.len(),
            TensorView::I32 { data, .. } => data.len(),
            TensorView::ScalarF32(_) | TensorView::ScalarI32(_) => 1,
        }
    }

    /// Element dtype of the view.
    pub fn dtype(&self) -> Dt {
        match self {
            TensorView::F32 { .. } | TensorView::ScalarF32(_) => Dt::F32,
            TensorView::I32 { .. } | TensorView::ScalarI32(_) => Dt::I32,
        }
    }

    /// Logical shape (empty for scalars).
    pub fn shape(&self) -> &[usize] {
        match self {
            TensorView::F32 { shape, .. } | TensorView::I32 { shape, .. } => shape,
            TensorView::ScalarF32(_) | TensorView::ScalarI32(_) => &[],
        }
    }

    /// Borrow the f32 payload (array variants only).
    pub fn as_f32(&self) -> anyhow::Result<&'a [f32]> {
        match self {
            TensorView::F32 { data, .. } => Ok(*data),
            other => anyhow::bail!("expected f32 tensor argument, got {:?}", other.dtype_name()),
        }
    }

    /// Borrow the i32 payload (array variants only).
    pub fn as_i32(&self) -> anyhow::Result<&'a [i32]> {
        match self {
            TensorView::I32 { data, .. } => Ok(*data),
            other => anyhow::bail!("expected i32 tensor argument, got {:?}", other.dtype_name()),
        }
    }

    /// Read a rank-0/1-element f32 argument.
    pub fn scalar_f32(&self) -> anyhow::Result<f32> {
        match self {
            TensorView::ScalarF32(v) => Ok(*v),
            TensorView::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => anyhow::bail!("expected f32 scalar argument, got {:?}", other.dtype_name()),
        }
    }

    /// Read a rank-0/1-element i32 argument.
    pub fn scalar_i32(&self) -> anyhow::Result<i32> {
        match self {
            TensorView::ScalarI32(v) => Ok(*v),
            TensorView::I32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => anyhow::bail!("expected i32 scalar argument, got {:?}", other.dtype_name()),
        }
    }

    fn dtype_name(&self) -> &'static str {
        match self {
            TensorView::F32 { .. } => "f32 tensor",
            TensorView::I32 { .. } => "i32 tensor",
            TensorView::ScalarF32(_) => "f32 scalar",
            TensorView::ScalarI32(_) => "i32 scalar",
        }
    }
}

/// Check an argument list against a program's manifest spec: arity, element
/// counts and dtypes. Both backends route every `exec` through this, so the
/// contract is enforced identically on either side of the seam.
pub fn validate_args(
    program: &str,
    spec: &ArtifactSpec,
    args: &[TensorView],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.len() == spec.inputs.len(),
        "{program}: got {} args, spec says {}",
        args.len(),
        spec.inputs.len()
    );
    for (view, arg) in args.iter().zip(&spec.inputs) {
        anyhow::ensure!(
            view.dtype() == arg.dtype,
            "{program}.{}: argument dtype {:?}, spec says {:?}",
            arg.name,
            view.dtype(),
            arg.dtype
        );
        anyhow::ensure!(
            view.n_elems() == arg.n_elems(),
            "{program}.{}: argument has {} elems, spec wants {} {:?}",
            arg.name,
            view.n_elems(),
            arg.n_elems(),
            arg.shape
        );
    }
    Ok(())
}

/// Backend-agnostic model execution. Object-safe: the coordinator, agents
/// and experiment drivers hold `&dyn Backend` and never know whether
/// programs run as XLA artifacts or native Rust.
pub trait Backend {
    /// Short identifier ("host", "pjrt") for logs and CLI output.
    fn name(&self) -> &'static str;

    /// The program contract: hyperparameters, parameter sizes, and one
    /// [`ArtifactSpec`] per executable program.
    fn manifest(&self) -> &Manifest;

    /// Execute a named program. Arguments are validated against the
    /// manifest spec; outputs arrive in the spec's declared order.
    fn exec(&self, program: &str, args: &[TensorView]) -> anyhow::Result<Vec<Tensor>>;

    /// Execute the same program over several independent argument sets.
    /// Semantically identical to calling [`Backend::exec`] per entry (and
    /// that is the default implementation); backends override it to
    /// amortise per-call overhead — the host backend does one manifest
    /// lookup, one workspace checkout and one stats update per batch.
    fn exec_batch(
        &self,
        program: &str,
        calls: &[Vec<TensorView>],
    ) -> anyhow::Result<Vec<Vec<Tensor>>> {
        calls.iter().map(|args| self.exec(program, args)).collect()
    }

    /// Execute with a parameter store's theta as the implicit leading
    /// argument. Backends may cache an uploaded copy keyed by
    /// `(family, version)` — this is the acting hot path.
    fn exec_with_params(
        &self,
        program: &str,
        params: &super::ParamStore,
        rest: &[TensorView],
    ) -> anyhow::Result<Vec<Tensor>>;

    /// [`Backend::exec_batch`] with a parameter store bound once as the
    /// leading argument of every call — the batched acting hot path
    /// (EnvPool-width observation batches, PPO/WM minibatch sweeps).
    fn exec_with_params_batch(
        &self,
        program: &str,
        params: &super::ParamStore,
        rests: &[Vec<TensorView>],
    ) -> anyhow::Result<Vec<Vec<Tensor>>> {
        rests.iter().map(|rest| self.exec_with_params(program, params, rest)).collect()
    }

    /// Run one `*_train` program against a parameter store: `(theta, m, v,
    /// t)` are taken from the store, the updated values are absorbed back
    /// (version bumped), and only the program's *remaining* outputs — the
    /// loss/stat scalars after the four optimiser tensors — are returned,
    /// in spec order.
    ///
    /// The default implementation routes through [`Backend::exec`] +
    /// [`ParamStore::absorb`](super::ParamStore::absorb) (what every
    /// trainer did by hand before this seam). The host backend overrides
    /// it to update the store's vectors in place, skipping the five full
    /// parameter-vector copies per step that the exec path's
    /// value-semantics contract forces.
    fn train_step(
        &self,
        program: &str,
        params: &mut super::ParamStore,
        rest: &[TensorView],
    ) -> anyhow::Result<Vec<Tensor>> {
        let mut args = params.train_args();
        args.extend(rest.iter().cloned());
        let out = self.exec(program, &args)?;
        drop(args);
        params.absorb(&out)?;
        Ok(out.into_iter().skip(4).collect())
    }

    /// Per-program execution statistics accumulated so far.
    fn stats(&self) -> HashMap<String, ExecStats>;

    /// Hyperparameter lookup (manifest-backed).
    fn hp(&self, key: &str) -> anyhow::Result<usize> {
        self.manifest().hp_usize(key)
    }

    /// Program spec lookup (manifest-backed).
    fn spec(&self, program: &str) -> anyhow::Result<&ArtifactSpec> {
        self.manifest().artifact(program)
    }
}

/// Build a backend by CLI name: `host` (pure Rust, always available),
/// `pjrt` (AOT artifacts; needs `manifest.json` + a linked
/// `xla_extension`), or `auto` (pjrt when artifacts exist, host otherwise).
pub fn backend_by_name(kind: &str) -> anyhow::Result<Box<dyn Backend>> {
    match kind {
        "host" => Ok(Box::new(super::HostBackend::new())),
        "pjrt" => Ok(Box::new(super::PjrtBackend::load_default()?)),
        "auto" => {
            // Prefer the artifacts when they exist AND the PJRT client
            // actually comes up; a stale manifest.json next to the
            // vendored (offline) xla shim must not keep the host path
            // from running.
            if Manifest::default_dir().join("manifest.json").exists() {
                if let Ok(pjrt) = super::PjrtBackend::load_default() {
                    return Ok(Box::new(pjrt));
                }
            }
            Ok(Box::new(super::HostBackend::new()))
        }
        other => anyhow::bail!("unknown backend '{other}' (host|pjrt|auto)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ArgSpec;

    fn spec2() -> ArtifactSpec {
        ArtifactSpec {
            file: String::new(),
            inputs: vec![
                ArgSpec { name: "x".into(), shape: vec![2, 3], dtype: Dt::F32 },
                ArgSpec { name: "s".into(), shape: vec![], dtype: Dt::I32 },
            ],
            outputs: vec!["y".into()],
        }
    }

    #[test]
    fn views_report_shape_and_elems() {
        let data = [1.0f32; 6];
        let v = TensorView::f32(&data, &[2, 3]);
        assert_eq!(v.n_elems(), 6);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(TensorView::ScalarI32(7).n_elems(), 1);
        assert!(TensorView::ScalarF32(1.0).shape().is_empty());
    }

    #[test]
    fn validate_accepts_matching_args() {
        let data = [0.0f32; 6];
        let args = [TensorView::f32(&data, &[2, 3]), TensorView::ScalarI32(1)];
        assert!(validate_args("p", &spec2(), &args).is_ok());
    }

    #[test]
    fn validate_rejects_arity_shape_and_dtype() {
        let data = [0.0f32; 6];
        let short = [TensorView::f32(&data, &[2, 3])];
        assert!(validate_args("p", &spec2(), &short).is_err());
        let bad_elems = [TensorView::f32(&data[..4], &[2, 2]), TensorView::ScalarI32(1)];
        assert!(validate_args("p", &spec2(), &bad_elems).is_err());
        let bad_dtype = [TensorView::f32(&data, &[2, 3]), TensorView::ScalarF32(1.0)];
        assert!(validate_args("p", &spec2(), &bad_dtype).is_err());
    }

    #[test]
    fn scalar_accessors() {
        assert_eq!(TensorView::ScalarF32(2.5).scalar_f32().unwrap(), 2.5);
        assert_eq!(TensorView::ScalarI32(-3).scalar_i32().unwrap(), -3);
        let one = [4.0f32];
        assert_eq!(TensorView::f32(&one, &[1]).scalar_f32().unwrap(), 4.0);
        assert!(TensorView::ScalarI32(0).scalar_f32().is_err());
    }
}
