//! Model execution runtime: the bridge between the Rust coordinator and
//! the neural programs (GNN encoder, MDN-RNN world model, PPO controller).
//!
//! The [`Backend`] trait is the seam: callers execute *named programs over
//! typed tensor views* and never see the substrate. [`PjrtBackend`] runs
//! the AOT artifacts produced by `make artifacts` through the PJRT C API;
//! [`HostBackend`] implements the same program families natively in Rust
//! so the full train/eval loop runs offline (`rlflow train --backend
//! host`). [`backend_by_name`] maps the CLI `--backend {host,pjrt,auto}`
//! flag to a concrete instance.

pub mod backend;
pub mod host;
pub mod manifest;
pub mod params;
pub mod pjrt;

pub use backend::{backend_by_name, validate_args, Backend, ExecStats, TensorView};
pub use host::kernels::{KernelCfg, KernelMode, ReductionOrder, Workspace, WorkspaceStats};
pub use host::{HostBackend, HostConfig};
pub use manifest::{ArgSpec, ArtifactSpec, Dt, Manifest};
pub use params::ParamStore;
pub use pjrt::PjrtBackend;
