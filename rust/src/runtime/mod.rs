//! PJRT runtime: the bridge between the Rust coordinator and the AOT
//! artifacts produced by `make artifacts` (see DESIGN.md architecture).

pub mod engine;
pub mod manifest;
pub mod params;

pub use engine::{lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, scalar_f32, to_vec_f32, zeros_like_spec, Engine};
pub use manifest::{ArgSpec, ArtifactSpec, Dt, Manifest};
pub use params::ParamStore;
