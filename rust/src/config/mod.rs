//! Layered run configuration: defaults -> optional JSON file -> CLI
//! overrides. Every hyperparameter an experiment touches lives here so
//! EXPERIMENTS.md can reference a single config per result.

use std::path::Path;

use crate::agent::PpoCfg;
use crate::cost::DeviceProfile;
use crate::env::{EnvConfig, RewardKind};
use crate::util::json::{parse, Json};
use crate::wm::WmTrainCfg;

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub seed: u64,
    pub graph: String,
    /// Model-execution backend: "host" (pure Rust, offline), "pjrt" (AOT
    /// artifacts) or "auto" (pjrt when artifacts exist, host otherwise).
    pub backend: String,
    pub device: DeviceProfile,
    /// Multiplicative measurement-noise std (0 disables).
    pub cost_noise: f64,
    pub env: EnvConfig,
    /// Batched environments per rollout/eval pass (`EnvPool` width B;
    /// CLI `--envs B`).
    pub envs: usize,
    /// Random-rollout collection.
    pub collect_episodes: usize,
    pub collect_noop_prob: f32,
    pub collect_workers: usize,
    /// GNN auto-encoder.
    pub ae_steps: usize,
    pub ae_lr: f32,
    /// World model.
    pub wm: WmTrainCfg,
    /// Dream controller training.
    pub dream_epochs: usize,
    pub dream_horizon: usize,
    pub temperature: f32,
    pub ppo: PpoCfg,
    /// Model-free baseline.
    pub free_iterations: usize,
    pub free_episodes_per_iter: usize,
    /// Evaluation.
    pub eval_episodes: usize,
    pub eval_greedy: bool,
    /// Run training through the async actor/learner pipeline
    /// (`coordinator::pipeline_async`) instead of the synchronous
    /// reference path. CLI `--async` or `-s async=true`.
    pub train_async: bool,
    /// Actor/learner rounds the async pipeline splits the training
    /// budget across (collection, AE, WM and dream budgets are divided
    /// round-robin over rounds).
    pub async_rounds: usize,
    /// Worker threads per pipeline stage that fans out (the collector's
    /// `EnvPool`).
    pub async_stage_threads: usize,
    /// Capacity of the bounded staging buffer between the collector and
    /// the learner stages (backpressure bound; min 1).
    pub async_staging_cap: usize,
    /// Write an atomic checkpoint every N training rounds (0 disables;
    /// CLI `--checkpoint-every N`). Applies to both the synchronous
    /// round engine and the async pipeline.
    pub checkpoint_every: usize,
    /// Directory checkpoints are written to / resumed from (CLI
    /// `--checkpoint-dir`, overridden by `--resume DIR`).
    pub checkpoint_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            graph: "bert".into(),
            backend: "auto".into(),
            device: DeviceProfile::rtx2070(),
            cost_noise: 0.0,
            env: EnvConfig::default(),
            envs: 4,
            collect_episodes: 48,
            collect_noop_prob: 0.05,
            collect_workers: 4,
            ae_steps: 120,
            ae_lr: 1e-3,
            wm: WmTrainCfg::default(),
            dream_epochs: 60,
            dream_horizon: 24,
            temperature: 1.0,
            ppo: PpoCfg::default(),
            free_iterations: 40,
            free_episodes_per_iter: 4,
            eval_episodes: 5,
            eval_greedy: false,
            train_async: false,
            async_rounds: 2,
            async_stage_threads: 2,
            async_staging_cap: 8,
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
        }
    }
}

impl RunConfig {
    /// A drastically reduced profile for smoke tests and CI.
    pub fn smoke() -> Self {
        Self {
            envs: 2,
            collect_episodes: 6,
            collect_workers: 2,
            ae_steps: 4,
            wm: WmTrainCfg { total_steps: 4, ..Default::default() },
            dream_epochs: 2,
            dream_horizon: 6,
            free_iterations: 2,
            free_episodes_per_iter: 1,
            eval_episodes: 1,
            env: EnvConfig { max_steps: 8, ..Default::default() },
            ..Default::default()
        }
    }

    /// Cost model this config describes: the device profile, with the
    /// §3.1.4 measurement-noise field layered on when `cost_noise > 0`
    /// (seeded from the run seed, so noisy runs replay bit-for-bit). The
    /// single source of truth for `rlflow optimize` and every experiment
    /// driver (`ExperimentCtx::cost_model` delegates here).
    pub fn cost_model(&self) -> crate::cost::CostModel {
        let cm = crate::cost::CostModel::new(self.device);
        if self.cost_noise > 0.0 {
            cm.with_noise(self.cost_noise, self.seed ^ 0xC057_4011)
        } else {
            cm
        }
    }

    pub fn load_json<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = parse(&text)?;
        let mut cfg = Self::default();
        cfg.apply_json(&j)?;
        Ok(cfg)
    }

    /// Apply JSON overrides onto the current config (unknown keys error —
    /// silent typos in experiment configs are worse than failures).
    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        for (key, value) in j.as_obj()? {
            match key.as_str() {
                "seed" => self.seed = value.as_usize()? as u64,
                "graph" => self.graph = value.as_str()?.to_string(),
                "backend" => self.backend = value.as_str()?.to_string(),
                "device" => {
                    self.device = match value.as_str()? {
                        "rtx2070" => DeviceProfile::rtx2070(),
                        "cpu_xeon" => DeviceProfile::cpu_xeon(),
                        "tpu_v4ish" => DeviceProfile::tpu_v4ish(),
                        d => anyhow::bail!("unknown device '{}'", d),
                    }
                }
                "cost_noise" => self.cost_noise = value.as_f64()?,
                "max_steps" => self.env.max_steps = value.as_usize()?,
                "reward" => self.env.reward = RewardKind::preset(value.as_str()?)?,
                "invalid_penalty" => self.env.invalid_penalty = value.as_f64()? as f32,
                "envs" => self.envs = value.as_usize()?,
                "collect_episodes" => self.collect_episodes = value.as_usize()?,
                "collect_noop_prob" => self.collect_noop_prob = value.as_f64()? as f32,
                "collect_workers" => self.collect_workers = value.as_usize()?,
                "ae_steps" => self.ae_steps = value.as_usize()?,
                "ae_lr" => self.ae_lr = value.as_f64()? as f32,
                "wm_steps" => self.wm.total_steps = value.as_usize()?,
                "wm_lr" => self.wm.lr_start = value.as_f64()? as f32,
                "wm_reward_scale" => self.wm.reward_scale = value.as_f64()? as f32,
                "dream_epochs" => self.dream_epochs = value.as_usize()?,
                "dream_horizon" => self.dream_horizon = value.as_usize()?,
                "temperature" => self.temperature = value.as_f64()? as f32,
                "ppo_lr" => self.ppo.lr = value.as_f64()? as f32,
                "ppo_clip" => self.ppo.clip = value.as_f64()? as f32,
                "ppo_epochs" => self.ppo.epochs = value.as_usize()?,
                "ppo_ent_coef" => self.ppo.ent_coef = value.as_f64()? as f32,
                "ppo_gamma" => self.ppo.gamma = value.as_f64()? as f32,
                "free_iterations" => self.free_iterations = value.as_usize()?,
                "free_episodes_per_iter" => self.free_episodes_per_iter = value.as_usize()?,
                "eval_episodes" => self.eval_episodes = value.as_usize()?,
                "eval_greedy" => self.eval_greedy = value.as_bool()?,
                "async" => self.train_async = value.as_bool()?,
                "async_rounds" => self.async_rounds = value.as_usize()?,
                "async_stage_threads" => self.async_stage_threads = value.as_usize()?,
                "async_staging_cap" => self.async_staging_cap = value.as_usize()?,
                "checkpoint_every" => self.checkpoint_every = value.as_usize()?,
                "checkpoint_dir" => self.checkpoint_dir = value.as_str()?.to_string(),
                other => anyhow::bail!("unknown config key '{}'", other),
            }
        }
        Ok(())
    }

    /// Parse a `key=value` CLI override.
    pub fn apply_override(&mut self, kv: &str) -> anyhow::Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("override must be key=value, got '{}'", kv))?;
        // Route through the JSON path for a single source of truth.
        let jv = if let Ok(n) = v.parse::<f64>() {
            Json::Num(n)
        } else if v == "true" || v == "false" {
            Json::Bool(v == "true")
        } else {
            Json::Str(v.to_string())
        };
        let mut obj = Json::obj();
        obj.set(k, jv);
        self.apply_json(&obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_overrides_apply() {
        let mut cfg = RunConfig::default();
        let j = parse(r#"{"graph": "vit", "temperature": 1.5, "wm_steps": 77, "reward": "r5"}"#)
            .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.graph, "vit");
        assert_eq!(cfg.temperature, 1.5);
        assert_eq!(cfg.wm.total_steps, 77);
        assert_eq!(cfg.env.reward, RewardKind::Incremental);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = RunConfig::default();
        let j = parse(r#"{"grpah": "vit"}"#).unwrap();
        assert!(cfg.apply_json(&j).is_err());
    }

    #[test]
    fn cli_override_round_trip() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("dream_epochs=99").unwrap();
        assert_eq!(cfg.dream_epochs, 99);
        cfg.apply_override("graph=resnet18").unwrap();
        assert_eq!(cfg.graph, "resnet18");
        cfg.apply_override("eval_greedy=true").unwrap();
        assert!(cfg.eval_greedy);
        cfg.apply_override("envs=8").unwrap();
        assert_eq!(cfg.envs, 8);
        cfg.apply_override("backend=host").unwrap();
        assert_eq!(cfg.backend, "host");
        cfg.apply_override("async=true").unwrap();
        assert!(cfg.train_async);
        cfg.apply_override("async_rounds=3").unwrap();
        assert_eq!(cfg.async_rounds, 3);
        cfg.apply_override("async_stage_threads=4").unwrap();
        assert_eq!(cfg.async_stage_threads, 4);
        cfg.apply_override("async_staging_cap=2").unwrap();
        assert_eq!(cfg.async_staging_cap, 2);
        cfg.apply_override("checkpoint_every=3").unwrap();
        assert_eq!(cfg.checkpoint_every, 3);
        cfg.apply_override("checkpoint_dir=/tmp/ck").unwrap();
        assert_eq!(cfg.checkpoint_dir, "/tmp/ck");
        assert!(cfg.apply_override("nonsense").is_err());
    }
}
