//! Minimal CSV writer for experiment outputs (no quoting needed for our data).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self { out, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(fields.len() == self.cols, "row width {} != header {}", fields.len(), self.cols);
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Convenience macro: `csv_row!(w; "bert", 1.5, 3)`.
#[macro_export]
macro_rules! csv_row {
    ($w:expr; $($f:expr),+ $(,)?) => {
        $w.row(&[$(format!("{}", $f)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("rlflow_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn rejects_wrong_width() {
        let dir = std::env::temp_dir().join("rlflow_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
    }
}
