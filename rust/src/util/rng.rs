//! Seeded xoshiro256** RNG.
//!
//! Every stochastic component in RLFlow (random agent, measurement noise,
//! GMM sampling, rollout shuffling) draws from one of these, seeded from the
//! experiment config, so every experiment in EXPERIMENTS.md is replayable
//! bit-for-bit.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Raw xoshiro256** state, for checkpointing a stream mid-flight.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a stream from a state captured with [`Rng::state`]; the
    /// restored stream continues the original draw sequence exactly.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn sample_weighted(&mut self, w: &[f32]) -> usize {
        let total: f32 = w.iter().sum();
        if total <= 0.0 {
            return self.below(w.len().max(1));
        }
        let mut r = self.f32() * total;
        for (i, &wi) in w.iter().enumerate() {
            r -= wi;
            if r <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    /// Sample from logits (softmax with temperature 1), respecting a mask.
    /// Masked-out entries (mask=false) are never selected.
    pub fn sample_logits_masked(&mut self, logits: &[f32], mask: &[bool]) -> usize {
        debug_assert_eq!(logits.len(), mask.len());
        let mx = logits
            .iter()
            .zip(mask)
            .filter(|(_, &m)| m)
            .map(|(&l, _)| l)
            .fold(f32::NEG_INFINITY, f32::max);
        if !mx.is_finite() {
            // No valid entry: caller's invariant broken; fall back uniform.
            return self.below(logits.len());
        }
        let w: Vec<f32> = logits
            .iter()
            .zip(mask)
            .map(|(&l, &m)| if m { (l - mx).exp() } else { 0.0 })
            .collect();
        self.sample_weighted(&w)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn masked_sampling_respects_mask() {
        let mut r = Rng::new(5);
        let logits = [0.0_f32, 10.0, 0.0];
        let mask = [true, false, true];
        for _ in 0..200 {
            assert_ne!(r.sample_logits_masked(&logits, &mask), 1);
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[r.sample_weighted(&[1.0, 9.0])] += 1;
        }
        assert!(counts[1] > counts[0] * 4);
    }
}
