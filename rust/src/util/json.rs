//! Minimal JSON value type, parser and writer.
//!
//! The offline build environment carries no serde; this module covers the
//! crate's JSON needs — the artifact manifest (read), the ONNX-style model
//! format (read/write), experiment outputs (write) and the `rlflow serve`
//! wire protocol (read/write of untrusted bytes). It parses the full JSON
//! grammar except exotic escapes (`\uXXXX` is supported).
//!
//! # Untrusted input
//!
//! [`parse`] is safe to run on adversarial bytes: nesting is bounded by
//! [`MAX_DEPTH`] (a `[[[[...` bomb returns `Err` instead of overflowing the
//! recursive parser's stack) and input length by [`MAX_INPUT_BYTES`].
//! Callers with tighter budgets (the serve daemon caps request lines well
//! below the default) use [`parse_with_limits`].

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        }
        self
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors ----------------------------------------------------------

    pub fn get(&self, key: &str) -> anyhow::Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing key '{}'", key)),
            _ => anyhow::bail!("not an object (looking up '{}')", key),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => anyhow::bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let x = self.as_f64()?;
        anyhow::ensure!(x >= 0.0 && x.fract() == 0.0, "not a non-negative integer: {}", x);
        Ok(x as usize)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => anyhow::bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&Vec<Json>> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("not an object"),
        }
    }

    pub fn usize_array(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---- serialisation ------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Default maximum container-nesting depth [`parse`] accepts. Deep enough
/// for every document the crate produces (manifests, graphs, rulesets nest
/// a handful of levels), shallow enough that the recursive-descent parser
/// cannot be driven anywhere near stack exhaustion.
pub const MAX_DEPTH: usize = 128;

/// Default maximum input size [`parse`] accepts (64 MiB).
pub const MAX_INPUT_BYTES: usize = 64 << 20;

/// Parse a complete JSON document under the default limits
/// ([`MAX_DEPTH`], [`MAX_INPUT_BYTES`]). Returns `Err` — never panics or
/// overflows the stack — on malformed, oversized or adversarially nested
/// input.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    parse_with_limits(text, MAX_INPUT_BYTES, MAX_DEPTH)
}

/// [`parse`] with explicit limits: inputs longer than `max_bytes` or
/// nesting containers deeper than `max_depth` are rejected up front /
/// mid-parse with a descriptive error.
pub fn parse_with_limits(text: &str, max_bytes: usize, max_depth: usize) -> anyhow::Result<Json> {
    anyhow::ensure!(
        text.len() <= max_bytes,
        "input too large: {} bytes exceeds the {} byte limit",
        text.len(),
        max_bytes
    );
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos, max_depth)?;
    skip_ws(bytes, &mut pos);
    anyhow::ensure!(pos == bytes.len(), "trailing garbage at byte {}", pos);
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => parse_obj(b, pos, depth),
        b'[' => parse_arr(b, pos, depth),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_num(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        b[*pos..].starts_with(word.as_bytes()),
        "expected '{}' at byte {}",
        word,
        pos
    );
    *pos += word.len();
    Ok(())
}

fn parse_obj(b: &[u8], pos: &mut usize, depth: usize) -> anyhow::Result<Json> {
    anyhow::ensure!(depth > 0, "nesting too deep at byte {}", pos);
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len() && b[*pos] == b':', "expected ':' at byte {}", pos);
        *pos += 1;
        let val = parse_value(b, pos, depth - 1)?;
        map.insert(key, val);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            c => anyhow::bail!("expected ',' or '}}', got '{}'", c as char),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize, depth: usize) -> anyhow::Result<Json> {
    anyhow::ensure!(depth > 0, "nesting too deep at byte {}", pos);
    *pos += 1; // '['
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos, depth - 1)?);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            c => anyhow::bail!("expected ',' or ']', got '{}'", c as char),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    anyhow::ensure!(*pos < b.len() && b[*pos] == b'"', "expected string at byte {}", pos);
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape");
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "short \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("bad escape '\\{}'", c as char),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 scalar.
                let start = *pos;
                let len = utf8_len(b[*pos]);
                anyhow::ensure!(start + len <= b.len(), "truncated utf-8");
                s.push_str(std::str::from_utf8(&b[start..start + len])?);
                *pos += len;
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(text.parse::<f64>().map_err(|e| {
        anyhow::anyhow!("bad number '{}' at byte {}: {}", text, start, e)
    })?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert!(!j.get("d").unwrap().as_bool().unwrap());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"hp": {"MAX_NODES": 160, "lr": 0.001}, "names": ["a", "b"], "flag": true, "none": null}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string_pretty()).unwrap();
        let j3 = parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\": 1} extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn deep_nesting_rejected_not_overflowed() {
        // Far past MAX_DEPTH: must come back as Err long before the
        // recursive parser could threaten the stack. Unbalanced is fine —
        // the depth check fires on the way down.
        for open in ["[", "{\"k\":"] {
            let deep = format!("{}0", open.repeat(50_000));
            assert!(parse(&deep).is_err(), "deep '{open}' input must be rejected");
        }
        for (open, close) in [("[", "]"), ("{\"k\":", "}")] {
            let balanced = format!("{}0{}", open.repeat(200), close.repeat(200));
            assert!(
                parse(&balanced).is_err(),
                "nesting past MAX_DEPTH must be rejected even when balanced"
            );
        }
    }

    #[test]
    fn nesting_within_limit_parses() {
        let depth = MAX_DEPTH - 1;
        let src = format!("{}0{}", "[".repeat(depth), "]".repeat(depth));
        assert!(parse(&src).is_ok(), "nesting under the limit must still parse");
    }

    #[test]
    fn oversized_input_rejected() {
        // Custom tight budget: 11 bytes of input against a 10-byte limit.
        let src = "[1,2,3,4,5]";
        assert_eq!(src.len(), 11);
        assert!(parse_with_limits(src, 10, MAX_DEPTH).is_err());
        assert!(parse_with_limits(src, 11, MAX_DEPTH).is_ok());
    }

    #[test]
    fn custom_depth_limit_applies() {
        assert!(parse_with_limits("[[1]]", MAX_INPUT_BYTES, 2).is_ok());
        assert!(parse_with_limits("[[[1]]]", MAX_INPUT_BYTES, 2).is_err());
        assert!(parse_with_limits("{\"a\":{\"b\":1}}", MAX_INPUT_BYTES, 2).is_ok());
        assert!(parse_with_limits("{\"a\":{\"b\":[1]}}", MAX_INPUT_BYTES, 2).is_err());
    }

    #[test]
    fn usize_array_helper() {
        let j = parse("[1, 2, 3]").unwrap();
        assert_eq!(j.usize_array().unwrap(), vec![1, 2, 3]);
        assert!(parse("[1.5]").unwrap().usize_array().is_err());
    }
}
