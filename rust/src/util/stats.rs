//! Tiny statistics helpers used by the experiment harness.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// (mean, sample standard deviation).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    if xs.len() < 2 {
        return (m, 0.0);
    }
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    (m, var.sqrt())
}

/// Half-width of the 95% confidence interval (normal approximation).
pub fn ci95(xs: &[f64]) -> f64 {
    let (_, sd) = mean_std(xs);
    1.96 * sd / (xs.len().max(1) as f64).sqrt()
}

/// Min-max normalise into [0, 1]; constant series map to 0.5.
pub fn minmax_normalise(xs: &[f64]) -> Vec<f64> {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !(hi - lo).is_finite() || hi - lo < 1e-12 {
        return vec![0.5; xs.len()];
    }
    xs.iter().map(|x| (x - lo) / (hi - lo)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn minmax_bounds() {
        let n = minmax_normalise(&[3.0, 1.0, 2.0]);
        assert_eq!(n, vec![1.0, 0.0, 0.5]);
    }

    #[test]
    fn minmax_constant() {
        assert_eq!(minmax_normalise(&[2.0, 2.0]), vec![0.5, 0.5]);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = ci95(&[1.0, 2.0, 3.0, 4.0]);
        let b = ci95(&[1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0]);
        assert!(b < a);
    }
}
