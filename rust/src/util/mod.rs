//! Small shared utilities: deterministic RNG, sampling, CSV emission,
//! and the failpoint fault-injection registry.

pub mod csv;
pub mod failpoint;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::{mean, mean_std};
