//! Small shared utilities: deterministic RNG, sampling, CSV emission.

pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::{mean, mean_std};
