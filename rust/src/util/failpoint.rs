//! Deterministic fault injection: named failpoint sites with seeded
//! triggers, zero-cost when unarmed.
//!
//! Long-running paths (serve persistence, checkpoint IO, pipeline stage
//! handoffs, host-backend exec) call [`check`] or [`fire`] at named
//! sites. With no configuration installed a hit is a single relaxed
//! atomic load; armed sites perform the configured [`Action`] — return
//! an injected IO error, panic the hitting thread, abort the process
//! (simulated `kill -9`), sleep, or tear a write short.
//!
//! Configuration comes from the `RLFLOW_FAILPOINTS` environment variable
//! (read once, on first hit) or programmatically via [`scoped`] in
//! tests. The grammar is semicolon-separated clauses:
//!
//! ```text
//! site=action[@N[+]][%p~seed]
//! ```
//!
//! * `action` — `err`, `panic`, `exit`, `delay(ms)`, `short(bytes)`, or
//!   `off` (remove the site).
//! * `@N` — fire only on the Nth hit (1-based); `@N+` fires on the Nth
//!   and every later hit. Without `@`, every hit fires.
//! * `%p~seed` — fire with probability `p` drawn from a dedicated
//!   xoshiro stream seeded with `seed`, so probabilistic schedules are
//!   replayable bit-for-bit.
//!
//! Examples: `serve.snapshot.rename=exit@1`,
//! `stage.send=delay(2)%0.5~42`, `serve.log.append=short(7)@2`.
//!
//! The full site inventory lives in ARCHITECTURE.md.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, Once, OnceLock};
use std::time::Duration;

use crate::util::Rng;

/// Process exit code used by the `exit` action, so harnesses can tell a
/// simulated kill from an ordinary failure.
pub const EXIT_CODE: i32 = 86;

/// What an armed failpoint site does on a triggering hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Site unarmed or trigger not met: carry on.
    Proceed,
    /// Return an injected IO error (ENOSPC-style write failure).
    Err,
    /// Panic the hitting thread.
    Panic,
    /// Abort the whole process with [`EXIT_CODE`] (simulated `kill -9`).
    Exit,
    /// Write only the first N bytes, then fail (torn write). Only
    /// meaningful at sites that consult [`hit`] directly; [`check`] and
    /// [`fire`] treat it as `Err`/`Panic` respectively.
    Short(usize),
    /// Sleep this many milliseconds, then proceed.
    Delay(u64),
}

#[derive(Debug, Clone)]
struct Site {
    action: Action,
    /// `(n, onwards)`: fire on the nth hit only, or from the nth onward.
    at: Option<(u64, bool)>,
    /// Seeded coin: fire with probability `p`.
    prob: Option<(f64, Rng)>,
    hits: u64,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();
static SITES: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn sites() -> &'static Mutex<HashMap<String, Site>> {
    SITES.get_or_init(|| Mutex::new(HashMap::new()))
}

fn lock_sites() -> MutexGuard<'static, HashMap<String, Site>> {
    // A panic action never unwinds while holding this lock (the caller
    // panics after `hit` returns), but chaos tests thrash panics enough
    // that we recover from poisoning defensively.
    sites().lock().unwrap_or_else(|e| e.into_inner())
}

fn init_env() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("RLFLOW_FAILPOINTS") {
            if let Err(e) = install(&spec) {
                eprintln!("rlflow: ignoring invalid RLFLOW_FAILPOINTS: {e}");
            }
        }
    });
}

fn install(spec: &str) -> anyhow::Result<()> {
    let map = parse_spec(spec)?;
    let armed = !map.is_empty();
    *lock_sites() = map;
    ARMED.store(armed, Ordering::SeqCst);
    Ok(())
}

/// Install `spec` as the process-wide failpoint configuration,
/// replacing any previous one (including one read from the
/// environment). Prefer [`scoped`] in tests.
pub fn configure(spec: &str) -> anyhow::Result<()> {
    // Consume the env-init Once so a later first hit cannot clobber an
    // explicitly installed configuration.
    ENV_INIT.call_once(|| {});
    install(spec)
}

/// Disarm every failpoint and reset all hit counters.
pub fn clear() {
    ENV_INIT.call_once(|| {});
    lock_sites().clear();
    ARMED.store(false, Ordering::SeqCst);
}

/// Evaluate a site. Returns [`Action::Proceed`] unless the site is
/// armed *and* its trigger (hit count, probability) is met. Unarmed
/// processes pay one relaxed atomic load.
pub fn hit(site: &str) -> Action {
    init_env();
    if !ARMED.load(Ordering::Relaxed) {
        return Action::Proceed;
    }
    let mut map = lock_sites();
    let Some(s) = map.get_mut(site) else {
        return Action::Proceed;
    };
    s.hits += 1;
    if let Some((n, onwards)) = s.at {
        let due = if onwards { s.hits >= n } else { s.hits == n };
        if !due {
            return Action::Proceed;
        }
    }
    if let Some((p, rng)) = s.prob.as_mut() {
        if rng.f64() >= *p {
            return Action::Proceed;
        }
    }
    s.action
}

/// Honour a site in an IO path: `delay` sleeps, `err`/`short` return an
/// injected error, `panic` panics, `exit` aborts the process.
pub fn check(site: &str) -> std::io::Result<()> {
    match hit(site) {
        Action::Proceed => Ok(()),
        Action::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Action::Err | Action::Short(_) => Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("failpoint {site}: injected fault"),
        )),
        Action::Panic => panic!("failpoint {site}: injected panic"),
        Action::Exit => {
            eprintln!("failpoint {site}: simulated kill (exit {EXIT_CODE})");
            std::process::exit(EXIT_CODE);
        }
    }
}

/// Honour a site with no error channel (stage handoffs): `delay`
/// sleeps, `exit` aborts, and every failing action panics the hitting
/// thread.
pub fn fire(site: &str) {
    match hit(site) {
        Action::Proceed => {}
        Action::Delay(ms) => std::thread::sleep(Duration::from_millis(ms)),
        Action::Err | Action::Panic | Action::Short(_) => {
            panic!("failpoint {site}: injected panic")
        }
        Action::Exit => {
            eprintln!("failpoint {site}: simulated kill (exit {EXIT_CODE})");
            std::process::exit(EXIT_CODE);
        }
    }
}

/// A scoped failpoint configuration for tests: serialises every scope
/// in the process (the registry is global), installs `spec`, and
/// disarms everything on drop. Tests that inject faults must hold one
/// of these for their whole body.
pub struct Scope {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for Scope {
    fn drop(&mut self) {
        clear();
    }
}

/// Acquire the test serialisation lock and arm `spec` until the
/// returned [`Scope`] drops. Panics on an invalid spec.
pub fn scoped(spec: &str) -> Scope {
    let lock = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    configure(spec).expect("invalid failpoint spec");
    Scope { _lock: lock }
}

fn parse_action(s: &str) -> anyhow::Result<Option<Action>> {
    if let Some(arg) = s.strip_prefix("delay(").and_then(|r| r.strip_suffix(')')) {
        return Ok(Some(Action::Delay(arg.parse()?)));
    }
    if let Some(arg) = s.strip_prefix("short(").and_then(|r| r.strip_suffix(')')) {
        return Ok(Some(Action::Short(arg.parse()?)));
    }
    match s {
        "err" => Ok(Some(Action::Err)),
        "panic" => Ok(Some(Action::Panic)),
        "exit" => Ok(Some(Action::Exit)),
        "off" => Ok(None),
        other => anyhow::bail!("unknown failpoint action {other:?}"),
    }
}

fn parse_spec(spec: &str) -> anyhow::Result<HashMap<String, Site>> {
    let mut map = HashMap::new();
    for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
        let (site, rest) = clause
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("failpoint clause {clause:?} missing '='"))?;
        let (rest, prob) = match rest.split_once('%') {
            Some((head, p)) => {
                let (p, seed) = p.split_once('~').ok_or_else(|| {
                    anyhow::anyhow!("failpoint probability {p:?} missing '~seed'")
                })?;
                let p: f64 = p.parse()?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "failpoint probability {p} outside [0, 1]"
                );
                (head, Some((p, Rng::new(seed.parse::<u64>()?))))
            }
            None => (rest, None),
        };
        let (action_s, at) = match rest.split_once('@') {
            Some((head, n)) => {
                let (n, onwards) = match n.strip_suffix('+') {
                    Some(n) => (n, true),
                    None => (n, false),
                };
                let n: u64 = n.parse()?;
                anyhow::ensure!(n >= 1, "failpoint hit count is 1-based");
                (head, Some((n, onwards)))
            }
            None => (rest, None),
        };
        match parse_action(action_s.trim())? {
            Some(action) => {
                map.insert(
                    site.trim().to_string(),
                    Site { action, at, prob, hits: 0 },
                );
            }
            None => {
                map.remove(site.trim());
            }
        }
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_sites_proceed() {
        let _s = scoped("");
        assert_eq!(hit("test.nowhere"), Action::Proceed);
        assert!(check("test.nowhere").is_ok());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_spec("no-equals-sign").is_err());
        assert!(parse_spec("a=frobnicate").is_err());
        assert!(parse_spec("a=err@0").is_err(), "hit counts are 1-based");
        assert!(parse_spec("a=err%0.5").is_err(), "probability needs a seed");
        assert!(parse_spec("a=err%1.5~1").is_err(), "probability must be in [0,1]");
        assert!(parse_spec("a=delay(xyz)").is_err());
    }

    #[test]
    fn nth_hit_trigger_fires_exactly_once() {
        let _s = scoped("test.nth=err@2");
        assert_eq!(hit("test.nth"), Action::Proceed);
        assert_eq!(hit("test.nth"), Action::Err);
        assert_eq!(hit("test.nth"), Action::Proceed);
    }

    #[test]
    fn onwards_trigger_fires_from_nth() {
        let _s = scoped("test.on=err@2+");
        assert_eq!(hit("test.on"), Action::Proceed);
        assert_eq!(hit("test.on"), Action::Err);
        assert_eq!(hit("test.on"), Action::Err);
    }

    #[test]
    fn seeded_probability_is_replayable() {
        let take = |seed: u64| -> Vec<bool> {
            let _s = scoped(&format!("test.p=err%0.5~{seed}"));
            (0..32).map(|_| hit("test.p") == Action::Err).collect()
        };
        let a = take(7);
        let b = take(7);
        assert_eq!(a, b, "same seed must make the same decisions");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "p=0.5 should mix");
    }

    #[test]
    fn short_and_off_parse() {
        let _s = scoped("test.w=short(7);test.w=off;test.d=delay(0)");
        assert_eq!(hit("test.w"), Action::Proceed, "off removes the site");
        assert_eq!(hit("test.d"), Action::Delay(0));
    }

    #[test]
    fn scope_drop_disarms() {
        {
            let _s = scoped("test.drop=err");
            assert_eq!(hit("test.drop"), Action::Err);
        }
        let _s = scoped("");
        assert_eq!(hit("test.drop"), Action::Proceed);
    }
}
