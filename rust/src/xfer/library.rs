//! The curated substitution-rule library (§3.2, Fig. 10's x-axis).
//!
//! Around forty semantics-preserving rewrites covering the families TASO's
//! generator discovers: operator fusion (conv/matmul/linear activations,
//! add->layernorm), operator merging (parallel conv/linear/matmul branches,
//! including the Q/K/V projection merge that pays off on BERT/ViT), constant
//! composition (back-to-back 1x1 convs / linears), shape-algebra
//! eliminations (transpose pairs, reshape pairs, concat/split inverses),
//! commutations and deliberate cost-*increasing* enlargements (§3.2: "the
//! specific transformation applied does not need to be strictly optimal").
//!
//! Every rule is verified in two ways:
//!  * unit tests here assert `semantically_equal(before, after)` via the
//!    interpreter on random tensors;
//!  * the generator re-verifies the whole library on randomly sampled
//!    anchor graphs at build time (`rlflow generate-rules --verify`).

use crate::graph::{Activation, Graph, NodeId, OpKind, PadMode, PortRef};
use crate::pred;

use super::apply::{live_op, splice, splice_port};
use super::matcher::{find_chains, find_siblings, sorted_consumers_vec, OpPred, OpRelevance};
use super::{Location, Rule, RuleSet};

/// A rule defined by a pair of closures, plus an optional operator
/// relevance fingerprint for incremental re-matching (`env::incremental`).
pub struct FnRule {
    name: &'static str,
    find: Box<dyn Fn(&Graph) -> Vec<Location> + Send + Sync>,
    apply: Box<dyn Fn(&mut Graph, &Location) -> anyhow::Result<()> + Send + Sync>,
    /// `None` = conservative: the rule re-matches after every rewrite.
    relevant: Option<OpRelevance>,
}

impl Rule for FnRule {
    fn name(&self) -> &'static str {
        self.name
    }
    fn find(&self, g: &Graph) -> Vec<Location> {
        (self.find)(g)
    }
    fn apply(&self, g: &mut Graph, loc: &Location) -> anyhow::Result<()> {
        (self.apply)(g, loc)
    }
    fn op_relevant(&self, op: &OpKind) -> bool {
        match &self.relevant {
            Some(rel) => rel.matches(op),
            None => true,
        }
    }
}

/// Conservative constructor: no relevance fingerprint, so the rule is
/// re-matched after every rewrite. Use for rules whose match validity
/// depends on nodes outside their reported `Location`.
#[allow(dead_code)]
pub(crate) fn rule(
    name: &'static str,
    find: impl Fn(&Graph) -> Vec<Location> + Send + Sync + 'static,
    apply: impl Fn(&mut Graph, &Location) -> anyhow::Result<()> + Send + Sync + 'static,
) -> Box<dyn Rule> {
    Box::new(FnRule { name, find: Box::new(find), apply: Box::new(apply), relevant: None })
}

/// [`rule`] with an [`OpRelevance`] fingerprint. The caller warrants the
/// contract documented on [`Rule::op_relevant`]: the reported `Location`
/// lists every node a match's validity depends on, and every node of every
/// possible match satisfies the fingerprint.
pub(crate) fn rule_with(
    name: &'static str,
    relevant: OpRelevance,
    find: impl Fn(&Graph) -> Vec<Location> + Send + Sync + 'static,
    apply: impl Fn(&mut Graph, &Location) -> anyhow::Result<()> + Send + Sync + 'static,
) -> Box<dyn Rule> {
    Box::new(FnRule {
        name,
        find: Box::new(find),
        apply: Box::new(apply),
        relevant: Some(relevant),
    })
}

/// [`rule_with`] for the common position-predicate-union fingerprint.
pub(crate) fn rule_rel(
    name: &'static str,
    tests: &[fn(&OpKind) -> bool],
    find: impl Fn(&Graph) -> Vec<Location> + Send + Sync + 'static,
    apply: impl Fn(&mut Graph, &Location) -> anyhow::Result<()> + Send + Sync + 'static,
) -> Box<dyn Rule> {
    rule_with(name, OpRelevance::of(tests), find, apply)
}

// ---------------------------------------------------------------------------
// Family 1: activation fusion / unfusion
// ---------------------------------------------------------------------------

fn fuse_act_into(
    name: &'static str,
    base: OpPred,
    act_pred: OpPred,
    act: Activation,
    refit: fn(&OpKind, Activation) -> Option<OpKind>,
) -> Box<dyn Rule> {
    let tests = [base.test, act_pred.test];
    rule_rel(
        name,
        &tests,
        move |g| find_chains(g, &[OpPred { ..base_copy(&base) }, OpPred { ..base_copy(&act_pred) }]),
        move |g, loc| {
            anyhow::ensure!(loc.len() == 2, "{name}: bad location");
            let (op_id, act_id) = (loc[0], loc[1]);
            let fused = refit(live_op(g, op_id)?, act)
                .ok_or_else(|| anyhow::anyhow!("{name}: op not fusable"))?;
            let inputs = g.node(op_id).inputs.clone();
            let new = g.add(fused, &inputs)?;
            splice(g, act_id, PortRef::of(new))?;
            g.kill(op_id);
            Ok(())
        },
    )
}

// OpPred has fn fields; a manual copy helper keeps `fuse_act_into` generic.
fn base_copy(p: &OpPred) -> OpPred {
    OpPred { label: p.label, test: p.test }
}

fn refit_conv(op: &OpKind, act: Activation) -> Option<OpKind> {
    match op {
        OpKind::Conv2d { stride, pad, act: Activation::None } => {
            Some(OpKind::Conv2d { stride: *stride, pad: *pad, act })
        }
        _ => None,
    }
}

fn refit_conv_bias(op: &OpKind, act: Activation) -> Option<OpKind> {
    match op {
        OpKind::ConvBias { stride, pad, act: Activation::None } => {
            Some(OpKind::ConvBias { stride: *stride, pad: *pad, act })
        }
        _ => None,
    }
}

fn refit_matmul(op: &OpKind, act: Activation) -> Option<OpKind> {
    match op {
        OpKind::MatMul { trans_a, trans_b, act: Activation::None } => {
            Some(OpKind::MatMul { trans_a: *trans_a, trans_b: *trans_b, act })
        }
        _ => None,
    }
}

fn refit_linear(op: &OpKind, act: Activation) -> Option<OpKind> {
    match op {
        OpKind::Linear { act: Activation::None } => Some(OpKind::Linear { act }),
        _ => None,
    }
}

/// Unfuse: op{act=A} -> op{none} + A.
fn unfuse_act(
    name: &'static str,
    sel: fn(&OpKind) -> Option<(OpKind, Activation)>,
) -> Box<dyn Rule> {
    rule_with(
        name,
        OpRelevance::from_fn(move |op| sel(op).is_some()),
        move |g| {
            g.live_ids()
                .filter(|&id| sel(&g.node(id).op).is_some())
                .map(|id| vec![id])
                .collect()
        },
        move |g, loc| {
            let id = loc[0];
            let (plain, act) =
                sel(live_op(g, id)?).ok_or_else(|| anyhow::anyhow!("{name}: not fused"))?;
            let inputs = g.node(id).inputs.clone();
            let base = g.add(plain, &inputs)?;
            let act_op = match act {
                Activation::Relu => OpKind::Relu,
                Activation::Gelu => OpKind::Gelu,
                Activation::None => anyhow::bail!("{name}: nothing to unfuse"),
            };
            let a = g.add(act_op, &[PortRef::of(base)])?;
            splice(g, id, PortRef::of(a))
        },
    )
}

// ---------------------------------------------------------------------------
// Family 2: normalisation fusion
// ---------------------------------------------------------------------------

/// conv -> batchnorm  ==>  conv(x, w * scale) + shift  (weights const-folded).
fn fold_bn_into_conv() -> Box<dyn Rule> {
    rule_rel(
        "fold_bn_conv",
        &[
            |op| matches!(op, OpKind::Conv2d { act: Activation::None, .. }),
            |op| matches!(op, OpKind::BatchNorm),
        ],
        |g| {
            find_chains(
                g,
                &[
                    pred!(conv: OpKind::Conv2d { act: Activation::None, .. }),
                    pred!(bn: OpKind::BatchNorm),
                ],
            )
        },
        |g, loc| {
            let (conv_id, bn_id) = (loc[0], loc[1]);
            let OpKind::Conv2d { stride, pad, act: Activation::None } = *live_op(g, conv_id)? else {
                anyhow::bail!("fold_bn_conv: stale conv")
            };
            let conv_in = g.node(conv_id).inputs.clone();
            let bn_in = g.node(bn_id).inputs.clone();
            let (x, w) = (conv_in[0], conv_in[1]);
            let (scale, shift) = (bn_in[1], bn_in[2]);
            let c = g.out_desc(scale)?.shape[0];
            // w' = w * scale[:, None, None, None]  (weight-const, folded)
            let scale_r = g.add(OpKind::Reshape { shape: vec![c, 1, 1, 1] }, &[scale])?;
            let w2 = g.add(OpKind::Mul, &[w, PortRef::of(scale_r)])?;
            // conv_bias(x, w', shift): the bias rides the conv epilogue.
            let out = g.add(
                OpKind::ConvBias { stride, pad, act: Activation::None },
                &[x, PortRef::of(w2), shift],
            )?;
            splice(g, bn_id, PortRef::of(out))?;
            g.kill(conv_id);
            Ok(())
        },
    )
}

/// add -> layernorm  ==>  fused_add_layernorm (§4.10's transformer win).
fn fuse_add_layernorm() -> Box<dyn Rule> {
    rule_rel(
        "fuse_add_ln",
        &[
            |op| matches!(op, OpKind::Add),
            |op| matches!(op, OpKind::LayerNorm),
        ],
        |g| find_chains(g, &[pred!(add: OpKind::Add), pred!(ln: OpKind::LayerNorm)]),
        |g, loc| {
            let (add_id, ln_id) = (loc[0], loc[1]);
            let add_in = g.node(add_id).inputs.clone();
            let ln_in = g.node(ln_id).inputs.clone();
            // Fused op requires equal shapes (no broadcast add).
            anyhow::ensure!(
                g.out_desc(add_in[0])?.shape == g.out_desc(add_in[1])?.shape,
                "fuse_add_ln: broadcast add not fusable"
            );
            let fused = g.add(
                OpKind::FusedAddLayerNorm,
                &[add_in[0], add_in[1], ln_in[1], ln_in[2]],
            )?;
            splice(g, ln_id, PortRef::of(fused))?;
            g.kill(add_id);
            Ok(())
        },
    )
}

fn unfuse_add_layernorm() -> Box<dyn Rule> {
    rule_rel(
        "unfuse_add_ln",
        &[|op| matches!(op, OpKind::FusedAddLayerNorm)],
        |g| {
            g.live_ids()
                .filter(|&id| matches!(g.node(id).op, OpKind::FusedAddLayerNorm))
                .map(|id| vec![id])
                .collect()
        },
        |g, loc| {
            let id = loc[0];
            anyhow::ensure!(matches!(live_op(g, id)?, OpKind::FusedAddLayerNorm));
            let ins = g.node(id).inputs.clone();
            let add = g.add(OpKind::Add, &[ins[0], ins[1]])?;
            let ln = g.add(OpKind::LayerNorm, &[PortRef::of(add), ins[2], ins[3]])?;
            splice(g, id, PortRef::of(ln))
        },
    )
}

// ---------------------------------------------------------------------------
// Family 3: n-ary add fusion
// ---------------------------------------------------------------------------

fn fuse_add_add() -> Box<dyn Rule> {
    rule_rel(
        "fuse_add_add",
        &[|op| matches!(op, OpKind::Add)],
        |g| {
            find_chains(g, &[pred!(a: OpKind::Add), pred!(b: OpKind::Add)])
                .into_iter()
                .filter(|loc| {
                    // AddN needs equal shapes: reject broadcasting adds.
                    let a = g.node(loc[0]).inputs.clone();
                    let b = g.node(loc[1]).inputs.clone();
                    let shapes: Vec<_> = a
                        .iter()
                        .chain(b.iter().skip(1))
                        .filter_map(|p| g.out_desc(*p).ok())
                        .map(|d| d.shape.clone())
                        .collect();
                    shapes.windows(2).all(|w| w[0] == w[1])
                })
                .collect()
        },
        |g, loc| {
            let (a_id, b_id) = (loc[0], loc[1]);
            let a_in = g.node(a_id).inputs.clone();
            let b_in = g.node(b_id).inputs.clone();
            let fused = g.add(OpKind::AddN { n: 3 }, &[a_in[0], a_in[1], b_in[1]])?;
            splice(g, b_id, PortRef::of(fused))?;
            g.kill(a_id);
            Ok(())
        },
    )
}

fn fuse_addn_add() -> Box<dyn Rule> {
    rule_rel(
        "fuse_addn_add",
        &[
            |op| matches!(op, OpKind::AddN { .. }),
            |op| matches!(op, OpKind::Add),
        ],
        |g| find_chains(g, &[pred!(a: OpKind::AddN { .. }), pred!(b: OpKind::Add)]),
        |g, loc| {
            let (a_id, b_id) = (loc[0], loc[1]);
            let mut ins = g.node(a_id).inputs.clone();
            let extra = g.node(b_id).inputs[1];
            anyhow::ensure!(
                g.out_desc(extra)?.shape == g.out_desc(ins[0])?.shape,
                "fuse_addn_add: shape mismatch"
            );
            ins.push(extra);
            let n = ins.len();
            let fused = g.add(OpKind::AddN { n }, &ins)?;
            splice(g, b_id, PortRef::of(fused))?;
            g.kill(a_id);
            Ok(())
        },
    )
}

fn unfuse_addn() -> Box<dyn Rule> {
    rule_rel(
        "unfuse_addn",
        &[|op| matches!(op, OpKind::AddN { .. })],
        |g| {
            g.live_ids()
                .filter(|&id| matches!(g.node(id).op, OpKind::AddN { .. }))
                .map(|id| vec![id])
                .collect()
        },
        |g, loc| {
            let id = loc[0];
            anyhow::ensure!(matches!(live_op(g, id)?, OpKind::AddN { .. }));
            let ins = g.node(id).inputs.clone();
            let mut acc = g.add(OpKind::Add, &[ins[0], ins[1]])?;
            for p in &ins[2..] {
                acc = g.add(OpKind::Add, &[PortRef::of(acc), *p])?;
            }
            splice(g, id, PortRef::of(acc))
        },
    )
}

// ---------------------------------------------------------------------------
// Family 4: parallel-branch merging (the TASO headline rules)
// ---------------------------------------------------------------------------

fn merge_conv_siblings() -> Box<dyn Rule> {
    rule_rel(
        "merge_conv2",
        &[|op| matches!(op, OpKind::Conv2d { .. })],
        |g| {
            find_siblings(g, &pred!(conv: OpKind::Conv2d { .. }), 2)
                .into_iter()
                .filter(|pair| {
                    let (a, b) = (g.node(pair[0]), g.node(pair[1]));
                    if a.op != b.op {
                        return false;
                    }
                    let (wa, wb) = (a.inputs[1], b.inputs[1]);
                    match (g.out_desc(wa), g.out_desc(wb)) {
                        (Ok(da), Ok(db)) => da.shape == db.shape,
                        _ => false,
                    }
                })
                .collect()
        },
        |g, loc| {
            let (a_id, b_id) = (loc[0], loc[1]);
            let op = live_op(g, a_id)?.clone();
            anyhow::ensure!(&op == live_op(g, b_id)?, "merge_conv2: attrs differ");
            let (x, wa) = (g.node(a_id).inputs[0], g.node(a_id).inputs[1]);
            let wb = g.node(b_id).inputs[1];
            anyhow::ensure!(g.node(b_id).inputs[0] == x, "merge_conv2: different inputs");
            let wcat = g.add(OpKind::Concat { axis: 0 }, &[wa, wb])?;
            let conv = g.add(op, &[x, PortRef::of(wcat)])?;
            let split = g.add(OpKind::Split { axis: 1, parts: 2 }, &[PortRef::of(conv)])?;
            splice_port(g, PortRef::of(a_id), PortRef { node: split, port: 0 })?;
            splice_port(g, PortRef::of(b_id), PortRef { node: split, port: 1 })?;
            g.kill(a_id);
            g.kill(b_id);
            Ok(())
        },
    )
}

fn merge_linear_siblings(name: &'static str, k: usize) -> Box<dyn Rule> {
    rule_rel(
        name,
        &[|op| matches!(op, OpKind::Linear { .. })],
        move |g| {
            find_siblings(g, &pred!(lin: OpKind::Linear { .. }), k)
                .into_iter()
                .filter(|grp| {
                    let first = g.node(grp[0]);
                    grp.iter().all(|&id| {
                        let n = g.node(id);
                        n.op == first.op
                            && n.inputs[0] == first.inputs[0]
                            && n.outs[0].shape == first.outs[0].shape
                    })
                })
                .collect()
        },
        move |g, loc| {
            anyhow::ensure!(loc.len() == k, "{name}: bad arity");
            let op = live_op(g, loc[0])?.clone();
            let x = g.node(loc[0]).inputs[0];
            let ws: Vec<PortRef> = loc.iter().map(|&id| g.node(id).inputs[1]).collect();
            let bs: Vec<PortRef> = loc.iter().map(|&id| g.node(id).inputs[2]).collect();
            for &id in loc {
                anyhow::ensure!(&op == live_op(g, id)?, "{name}: attrs differ");
                anyhow::ensure!(g.node(id).inputs[0] == x, "{name}: inputs differ");
            }
            let wcat = g.add(OpKind::Concat { axis: 1 }, &ws)?;
            let bcat = g.add(OpKind::Concat { axis: 0 }, &bs)?;
            let lin = g.add(op, &[x, PortRef::of(wcat), PortRef::of(bcat)])?;
            let rank = g.node(lin).outs[0].shape.len();
            let split = g.add(OpKind::Split { axis: rank - 1, parts: k }, &[PortRef::of(lin)])?;
            for (i, &id) in loc.iter().enumerate() {
                splice_port(g, PortRef::of(id), PortRef { node: split, port: i as u16 })?;
                g.kill(id);
            }
            Ok(())
        },
    )
}

fn merge_matmul_siblings() -> Box<dyn Rule> {
    rule_rel(
        "merge_matmul2",
        &[|op| matches!(op, OpKind::MatMul { trans_a: false, trans_b: false, .. })],
        |g| {
            find_siblings(
                g,
                &pred!(mm: OpKind::MatMul { trans_a: false, trans_b: false, .. }),
                2,
            )
            .into_iter()
            .filter(|pair| {
                let (a, b) = (g.node(pair[0]), g.node(pair[1]));
                if a.op != b.op || a.inputs[0] != b.inputs[0] {
                    return false;
                }
                match (g.out_desc(a.inputs[1]), g.out_desc(b.inputs[1])) {
                    (Ok(da), Ok(db)) => da.shape == db.shape && da.rank() == 2,
                    _ => false,
                }
            })
            .collect()
        },
        |g, loc| {
            let (a_id, b_id) = (loc[0], loc[1]);
            let op = live_op(g, a_id)?.clone();
            let x = g.node(a_id).inputs[0];
            let (ra, rb) = (g.node(a_id).inputs[1], g.node(b_id).inputs[1]);
            let rcat = g.add(OpKind::Concat { axis: 1 }, &[ra, rb])?;
            let mm = g.add(op, &[x, PortRef::of(rcat)])?;
            let rank = g.node(mm).outs[0].shape.len();
            let split = g.add(OpKind::Split { axis: rank - 1, parts: 2 }, &[PortRef::of(mm)])?;
            splice_port(g, PortRef::of(a_id), PortRef { node: split, port: 0 })?;
            splice_port(g, PortRef::of(b_id), PortRef { node: split, port: 1 })?;
            g.kill(a_id);
            g.kill(b_id);
            Ok(())
        },
    )
}

// ---------------------------------------------------------------------------
// Family 5: constant composition
// ---------------------------------------------------------------------------

/// Two back-to-back 1x1 stride-1 convs compose into one (w' = w2 @ w1).
fn compose_1x1_convs() -> Box<dyn Rule> {
    fn is_1x1(g: &Graph, id: NodeId) -> bool {
        let n = g.node(id);
        if !matches!(
            n.op,
            OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None }
        ) {
            return false;
        }
        g.out_desc(n.inputs[1])
            .map(|d| d.shape[2] == 1 && d.shape[3] == 1)
            .unwrap_or(false)
    }
    rule_rel(
        "compose_conv1x1",
        &[|op| matches!(op, OpKind::Conv2d { stride: 1, pad: PadMode::Same, .. })],
        |g| {
            find_chains(
                g,
                &[
                    pred!(c1: OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None }),
                    pred!(c2: OpKind::Conv2d { stride: 1, pad: PadMode::Same, .. }),
                ],
            )
            .into_iter()
            .filter(|loc| is_1x1(g, loc[0]) && {
                let n = g.node(loc[1]);
                g.out_desc(n.inputs[1])
                    .map(|d| d.shape[2] == 1 && d.shape[3] == 1)
                    .unwrap_or(false)
            })
            .collect()
        },
        |g, loc| {
            let (c1, c2) = (loc[0], loc[1]);
            let op2 = live_op(g, c2)?.clone();
            let (x, w1) = (g.node(c1).inputs[0], g.node(c1).inputs[1]);
            let w2 = g.node(c2).inputs[1];
            let d1 = g.out_desc(w1)?.shape.clone(); // [C1, C0, 1, 1]
            let d2 = g.out_desc(w2)?.shape.clone(); // [C2, C1, 1, 1]
            let (c0, c1ch, c2ch) = (d1[1], d1[0], d2[0]);
            let w1m = g.add(OpKind::Reshape { shape: vec![c1ch, c0] }, &[w1])?;
            let w2m = g.add(OpKind::Reshape { shape: vec![c2ch, c1ch] }, &[w2])?;
            let wm = g.add(
                OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
                &[PortRef::of(w2m), PortRef::of(w1m)],
            )?;
            let wr = g.add(OpKind::Reshape { shape: vec![c2ch, c0, 1, 1] }, &[PortRef::of(wm)])?;
            let conv = g.add(op2, &[x, PortRef::of(wr)])?;
            splice(g, c2, PortRef::of(conv))?;
            g.kill(c1);
            Ok(())
        },
    )
}

/// linear(linear(x)) composes when the inner has no activation.
fn compose_linears() -> Box<dyn Rule> {
    rule_rel(
        "compose_linear",
        &[|op| matches!(op, OpKind::Linear { .. })],
        |g| {
            find_chains(
                g,
                &[
                    pred!(l1: OpKind::Linear { act: Activation::None }),
                    pred!(l2: OpKind::Linear { .. }),
                ],
            )
        },
        |g, loc| {
            let (l1, l2) = (loc[0], loc[1]);
            let op2 = live_op(g, l2)?.clone();
            let (x, w1, b1) = (
                g.node(l1).inputs[0],
                g.node(l1).inputs[1],
                g.node(l1).inputs[2],
            );
            let (w2, b2) = (g.node(l2).inputs[1], g.node(l2).inputs[2]);
            let d1 = g.out_desc(w1)?.shape[1];
            let d2 = g.out_desc(w2)?.shape[1];
            // w' = w1 @ w2 ; b' = b1 @ w2 + b2
            let wm = g.add(
                OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
                &[w1, w2],
            )?;
            let b1r = g.add(OpKind::Reshape { shape: vec![1, d1] }, &[b1])?;
            let b1w = g.add(
                OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
                &[PortRef::of(b1r), w2],
            )?;
            let b1f = g.add(OpKind::Reshape { shape: vec![d2] }, &[PortRef::of(b1w)])?;
            let bsum = g.add(OpKind::Add, &[PortRef::of(b1f), b2])?;
            let lin = g.add(op2, &[x, PortRef::of(wm), PortRef::of(bsum)])?;
            splice(g, l2, PortRef::of(lin))?;
            g.kill(l1);
            Ok(())
        },
    )
}

// ---------------------------------------------------------------------------
// Family 6: shape algebra
// ---------------------------------------------------------------------------

fn elim_transpose_pair() -> Box<dyn Rule> {
    rule_rel(
        "elim_transpose2",
        &[|op| matches!(op, OpKind::Transpose { .. })],
        |g| {
            find_chains(g, &[pred!(t1: OpKind::Transpose { .. }), pred!(t2: OpKind::Transpose { .. })])
                .into_iter()
                .filter(|loc| {
                    let (p1, p2) = (perm_of(g, loc[0]), perm_of(g, loc[1]));
                    compose_perm(&p1, &p2).iter().enumerate().all(|(i, &p)| i == p)
                })
                .collect()
        },
        |g, loc| {
            let (t1, t2) = (loc[0], loc[1]);
            let src = g.node(t1).inputs[0];
            splice(g, t2, src)?;
            g.kill(t1);
            Ok(())
        },
    )
}

fn perm_of(g: &Graph, id: NodeId) -> Vec<usize> {
    match &g.node(id).op {
        OpKind::Transpose { perm } => perm.clone(),
        _ => vec![],
    }
}

/// apply p1 then p2 => combined[i] = p1[p2[i]].
fn compose_perm(p1: &[usize], p2: &[usize]) -> Vec<usize> {
    p2.iter().map(|&i| p1[i]).collect()
}

fn merge_transpose_pair() -> Box<dyn Rule> {
    rule_rel(
        "merge_transpose2",
        &[|op| matches!(op, OpKind::Transpose { .. })],
        |g| {
            find_chains(g, &[pred!(t1: OpKind::Transpose { .. }), pred!(t2: OpKind::Transpose { .. })])
                .into_iter()
                .filter(|loc| {
                    let (p1, p2) = (perm_of(g, loc[0]), perm_of(g, loc[1]));
                    // Only when NOT the identity pair (that's elim's job).
                    !compose_perm(&p1, &p2).iter().enumerate().all(|(i, &p)| i == p)
                })
                .collect()
        },
        |g, loc| {
            let (t1, t2) = (loc[0], loc[1]);
            let src = g.node(t1).inputs[0];
            let combined = compose_perm(&perm_of(g, t1), &perm_of(g, t2));
            let t = g.add(OpKind::Transpose { perm: combined }, &[src])?;
            splice(g, t2, PortRef::of(t))?;
            g.kill(t1);
            Ok(())
        },
    )
}

fn merge_reshape_pair() -> Box<dyn Rule> {
    rule_rel(
        "merge_reshape2",
        &[|op| matches!(op, OpKind::Reshape { .. })],
        |g| find_chains(g, &[pred!(r1: OpKind::Reshape { .. }), pred!(r2: OpKind::Reshape { .. })]),
        |g, loc| {
            let (r1, r2) = (loc[0], loc[1]);
            let src = g.node(r1).inputs[0];
            let final_shape = match &g.node(r2).op {
                OpKind::Reshape { shape } => shape.clone(),
                _ => anyhow::bail!("merge_reshape2: stale location"),
            };
            let r = g.add(OpKind::Reshape { shape: final_shape }, &[src])?;
            splice(g, r2, PortRef::of(r))?;
            g.kill(r1);
            Ok(())
        },
    )
}

/// matmul(a, transpose(b)) => matmul{trans_b}(a, b) when the transpose
/// swaps the last two axes.
fn absorb_transpose_rhs() -> Box<dyn Rule> {
    rule_rel(
        "absorb_transpose_rhs",
        &[
            |op| matches!(op, OpKind::Transpose { .. }),
            |op| matches!(op, OpKind::MatMul { trans_b: false, .. }),
        ],
        |g| {
            let cons = sorted_consumers_vec(g);
            let mut out = Vec::new();
            for id in g.live_ids() {
                let n = g.node(id);
                let OpKind::MatMul { trans_a, trans_b: false, act } = n.op else { continue };
                let _ = (trans_a, act);
                let rhs = n.inputs[1];
                if rhs.port != 0 {
                    continue;
                }
                let t = g.node(rhs.node);
                let OpKind::Transpose { perm } = &t.op else { continue };
                let r = perm.len();
                if r < 2 {
                    continue;
                }
                let mut want: Vec<usize> = (0..r).collect();
                want.swap(r - 2, r - 1);
                if perm != &want {
                    continue;
                }
                // Transpose must be exclusively feeding this matmul.
                if cons[rhs.node.index()].len() != 1 {
                    continue;
                }
                out.push(vec![rhs.node, id]);
            }
            out
        },
        |g, loc| {
            let (t_id, mm_id) = (loc[0], loc[1]);
            let OpKind::MatMul { trans_a, trans_b: false, act } = *live_op(g, mm_id)? else {
                anyhow::bail!("absorb_transpose_rhs: stale matmul")
            };
            let a = g.node(mm_id).inputs[0];
            let b_src = g.node(t_id).inputs[0];
            let mm = g.add(OpKind::MatMul { trans_a, trans_b: true, act }, &[a, b_src])?;
            splice(g, mm_id, PortRef::of(mm))?;
            g.kill(t_id);
            Ok(())
        },
    )
}

/// Inverse of the above: matmul{trans_b}(a, b) => matmul(a, transpose(b)).
fn emit_transpose_rhs() -> Box<dyn Rule> {
    rule_rel(
        "emit_transpose_rhs",
        &[|op| matches!(op, OpKind::MatMul { trans_b: true, .. })],
        |g| {
            g.live_ids()
                .filter(|&id| matches!(g.node(id).op, OpKind::MatMul { trans_b: true, .. }))
                .map(|id| vec![id])
                .collect()
        },
        |g, loc| {
            let id = loc[0];
            let OpKind::MatMul { trans_a, trans_b: true, act } = *live_op(g, id)? else {
                anyhow::bail!("emit_transpose_rhs: stale")
            };
            let (a, b) = (g.node(id).inputs[0], g.node(id).inputs[1]);
            let r = g.out_desc(b)?.rank();
            let mut perm: Vec<usize> = (0..r).collect();
            perm.swap(r - 2, r - 1);
            let t = g.add(OpKind::Transpose { perm }, &[b])?;
            let mm = g.add(OpKind::MatMul { trans_a, trans_b: false, act }, &[a, PortRef::of(t)])?;
            splice(g, id, PortRef::of(mm))
        },
    )
}

fn elim_concat_split() -> Box<dyn Rule> {
    rule_rel(
        "elim_concat_split",
        &[
            |op| matches!(op, OpKind::Concat { .. }),
            |op| matches!(op, OpKind::Split { .. }),
        ],
        |g| {
            find_chains(g, &[pred!(c: OpKind::Concat { .. }), pred!(s: OpKind::Split { .. })])
                .into_iter()
                .filter(|loc| {
                    let (c, s) = (g.node(loc[0]), g.node(loc[1]));
                    let (OpKind::Concat { axis: ca }, OpKind::Split { axis: sa, parts }) =
                        (&c.op, &s.op)
                    else {
                        return false;
                    };
                    if ca != sa || c.inputs.len() != *parts {
                        return false;
                    }
                    // All concat inputs must have the shape of the split outputs.
                    c.inputs.iter().all(|p| {
                        g.out_desc(*p).map(|d| d.shape == s.outs[0].shape).unwrap_or(false)
                    })
                })
                .collect()
        },
        |g, loc| {
            let (c_id, s_id) = (loc[0], loc[1]);
            let ins = g.node(c_id).inputs.clone();
            for (i, src) in ins.iter().enumerate() {
                splice_port(g, PortRef { node: s_id, port: i as u16 }, *src)?;
            }
            g.kill(s_id);
            g.kill(c_id);
            Ok(())
        },
    )
}

fn elim_split_concat() -> Box<dyn Rule> {
    rule_rel(
        "elim_split_concat",
        &[
            |op| matches!(op, OpKind::Split { .. }),
            |op| matches!(op, OpKind::Concat { .. }),
        ],
        |g| {
            let mut out = Vec::new();
            let cons = sorted_consumers_vec(g);
            for id in g.live_ids() {
                let n = g.node(id);
                let OpKind::Concat { axis } = n.op else { continue };
                if n.inputs.is_empty() {
                    continue;
                }
                let src = n.inputs[0].node;
                let OpKind::Split { axis: sa, parts } = g.node(src).op else { continue };
                if sa != axis || n.inputs.len() != parts {
                    continue;
                }
                // inputs must be split ports 0..parts in order and the
                // split must feed only this concat.
                let in_order = n
                    .inputs
                    .iter()
                    .enumerate()
                    .all(|(i, p)| p.node == src && p.port as usize == i);
                let sc = &cons[src.index()];
                let sole = !sc.is_empty() && sc.iter().all(|(c, _)| *c == id);
                if in_order && sole {
                    out.push(vec![src, id]);
                }
            }
            out
        },
        |g, loc| {
            let (s_id, c_id) = (loc[0], loc[1]);
            let src = g.node(s_id).inputs[0];
            splice(g, c_id, src)?;
            g.kill(s_id);
            Ok(())
        },
    )
}

// ---------------------------------------------------------------------------
// Family 7: commutation + misc
// ---------------------------------------------------------------------------

/// relu(maxpool(x)) <=> maxpool(relu(x)) — exact for max pooling.
fn swap_relu_maxpool() -> Box<dyn Rule> {
    rule_rel(
        "swap_relu_maxpool",
        &[
            |op| matches!(op, OpKind::Relu),
            |op| matches!(op, OpKind::MaxPool { .. }),
        ],
        |g| find_chains(g, &[pred!(r: OpKind::Relu), pred!(p: OpKind::MaxPool { .. })]),
        |g, loc| {
            let (r_id, p_id) = (loc[0], loc[1]);
            let pool_op = live_op(g, p_id)?.clone();
            let x = g.node(r_id).inputs[0];
            let pool = g.add(pool_op, &[x])?;
            let relu = g.add(OpKind::Relu, &[PortRef::of(pool)])?;
            splice(g, p_id, PortRef::of(relu))?;
            g.kill(r_id);
            Ok(())
        },
    )
}

fn swap_maxpool_relu() -> Box<dyn Rule> {
    rule_rel(
        "swap_maxpool_relu",
        &[
            |op| matches!(op, OpKind::MaxPool { .. }),
            |op| matches!(op, OpKind::Relu),
        ],
        |g| find_chains(g, &[pred!(p: OpKind::MaxPool { .. }), pred!(r: OpKind::Relu)]),
        |g, loc| {
            let (p_id, r_id) = (loc[0], loc[1]);
            let pool_op = live_op(g, p_id)?.clone();
            let x = g.node(p_id).inputs[0];
            let relu = g.add(OpKind::Relu, &[x])?;
            let pool = g.add(pool_op, &[PortRef::of(relu)])?;
            splice(g, r_id, PortRef::of(pool))?;
            g.kill(p_id);
            Ok(())
        },
    )
}

/// matmul(scale(a), b) => scale(matmul(a, b)).
fn hoist_scale_matmul() -> Box<dyn Rule> {
    rule_rel(
        "hoist_scale_matmul",
        &[
            |op| matches!(op, OpKind::Scale { .. }),
            |op| matches!(op, OpKind::MatMul { .. }),
        ],
        |g| {
            find_chains(g, &[pred!(s: OpKind::Scale { .. }), pred!(m: OpKind::MatMul { .. })])
                .into_iter()
                // Chain guarantees matmul reads scale as FIRST input (a side).
                .collect()
        },
        |g, loc| {
            let (s_id, m_id) = (loc[0], loc[1]);
            let scale_op = live_op(g, s_id)?.clone();
            let mm_op = live_op(g, m_id)?.clone();
            anyhow::ensure!(
                matches!(mm_op, OpKind::MatMul { act: Activation::None, .. }),
                "hoist_scale_matmul: fused activation blocks hoist"
            );
            let a = g.node(s_id).inputs[0];
            let b = g.node(m_id).inputs[1];
            let mm = g.add(mm_op, &[a, b])?;
            let sc = g.add(scale_op, &[PortRef::of(mm)])?;
            splice(g, m_id, PortRef::of(sc))?;
            g.kill(s_id);
            Ok(())
        },
    )
}

/// relu(relu(x)) => relu(x).
fn relu_idempotent() -> Box<dyn Rule> {
    rule_rel(
        "relu_idempotent",
        &[|op| matches!(op, OpKind::Relu)],
        |g| find_chains(g, &[pred!(a: OpKind::Relu), pred!(b: OpKind::Relu)]),
        |g, loc| {
            let (a_id, b_id) = (loc[0], loc[1]);
            splice(g, b_id, PortRef::of(a_id))?;
            Ok(())
        },
    )
}

fn elim_identity() -> Box<dyn Rule> {
    rule_rel(
        "elim_identity",
        &[|op| matches!(op, OpKind::Identity)],
        |g| {
            g.live_ids()
                .filter(|&id| {
                    let n = g.node(id);
                    matches!(n.op, OpKind::Identity)
                        && !matches!(
                            g.node(n.inputs[0].node).op,
                            OpKind::Input | OpKind::Weight
                        )
                })
                .map(|id| vec![id])
                .collect()
        },
        |g, loc| {
            let id = loc[0];
            anyhow::ensure!(matches!(live_op(g, id)?, OpKind::Identity));
            let src = g.node(id).inputs[0];
            splice(g, id, src)
        },
    )
}

/// matmul + bias add => linear.
fn fuse_matmul_bias() -> Box<dyn Rule> {
    rule_rel(
        "fuse_matmul_bias",
        &[
            |op| {
                matches!(
                    op,
                    OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }
                )
            },
            |op| matches!(op, OpKind::Add),
        ],
        |g| {
            find_chains(
                g,
                &[
                    pred!(m: OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }),
                    pred!(a: OpKind::Add),
                ],
            )
            .into_iter()
            .filter(|loc| {
                let mm = g.node(loc[0]);
                let add = g.node(loc[1]);
                let w_rank2 = g.out_desc(mm.inputs[1]).map(|d| d.rank() == 2).unwrap_or(false);
                let d_out = mm.outs[0].shape.last().copied().unwrap_or(0);
                let bias_vec = g
                    .out_desc(add.inputs[1])
                    .map(|d| d.shape == vec![d_out])
                    .unwrap_or(false);
                w_rank2 && bias_vec
            })
            .collect()
        },
        |g, loc| {
            let (m_id, a_id) = (loc[0], loc[1]);
            let x = g.node(m_id).inputs[0];
            let w = g.node(m_id).inputs[1];
            let b = g.node(a_id).inputs[1];
            let lin = g.add(OpKind::Linear { act: Activation::None }, &[x, w, b])?;
            splice(g, a_id, PortRef::of(lin))?;
            g.kill(m_id);
            Ok(())
        },
    )
}

fn unfuse_linear() -> Box<dyn Rule> {
    rule_rel(
        "unfuse_linear",
        &[|op| matches!(op, OpKind::Linear { act: Activation::None })],
        |g| {
            g.live_ids()
                .filter(|&id| matches!(g.node(id).op, OpKind::Linear { act: Activation::None }))
                .map(|id| vec![id])
                .collect()
        },
        |g, loc| {
            let id = loc[0];
            anyhow::ensure!(matches!(live_op(g, id)?, OpKind::Linear { act: Activation::None }));
            let ins = g.node(id).inputs.clone();
            let mm = g.add(
                OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
                &[ins[0], ins[1]],
            )?;
            let add = g.add(OpKind::Add, &[PortRef::of(mm), ins[2]])?;
            splice(g, id, PortRef::of(add))
        },
    )
}

/// Kernel enlargement (TASO's `enlarge`): kxk SAME stride-1 conv -> (k+2).
/// Cost-increasing on its own; opens merge opportunities with neighbouring
/// convs of the larger kernel size.
fn enlarge_conv(name: &'static str, from_k: usize) -> Box<dyn Rule> {
    rule_rel(
        name,
        &[|op| matches!(op, OpKind::Conv2d { stride: 1, pad: PadMode::Same, .. })],
        move |g| {
            g.live_ids()
                .filter(|&id| {
                    let n = g.node(id);
                    matches!(
                        n.op,
                        OpKind::Conv2d { stride: 1, pad: PadMode::Same, .. }
                    ) && g
                        .out_desc(n.inputs[1])
                        .map(|d| d.shape[2] == from_k && d.shape[3] == from_k)
                        .unwrap_or(false)
                        // Enlarged SAME conv is only exactly equal when the
                        // spatial input is at least the enlarged kernel.
                        && g.out_desc(n.inputs[0])
                            .map(|d| d.shape[2] >= from_k + 2 && d.shape[3] >= from_k + 2)
                            .unwrap_or(false)
                })
                .map(|id| vec![id])
                .collect()
        },
        move |g, loc| {
            let id = loc[0];
            let op = live_op(g, id)?.clone();
            anyhow::ensure!(matches!(op, OpKind::Conv2d { stride: 1, pad: PadMode::Same, .. }));
            let (x, w) = (g.node(id).inputs[0], g.node(id).inputs[1]);
            let big = g.add(OpKind::Enlarge { kh: from_k + 2, kw: from_k + 2 }, &[w])?;
            let conv = g.add(op, &[x, PortRef::of(big)])?;
            splice(g, id, PortRef::of(conv))
        },
    )
}

// ---------------------------------------------------------------------------
// Library assembly
// ---------------------------------------------------------------------------

/// The standard RLFlow rule library. Order is stable: it defines the agent's
/// xfer-slot indices and the Fig. 10 axis.
pub fn standard_library() -> RuleSet {
    RuleSet::new(vec![
        // fusion
        fuse_act_into(
            "fuse_conv_relu",
            pred!(c: OpKind::Conv2d { act: Activation::None, .. }),
            pred!(r: OpKind::Relu),
            Activation::Relu,
            refit_conv,
        ),
        unfuse_act("unfuse_conv_relu", |op| match op {
            OpKind::Conv2d { stride, pad, act: Activation::Relu } => Some((
                OpKind::Conv2d { stride: *stride, pad: *pad, act: Activation::None },
                Activation::Relu,
            )),
            _ => None,
        }),
        fuse_act_into(
            "fuse_matmul_relu",
            pred!(m: OpKind::MatMul { act: Activation::None, .. }),
            pred!(r: OpKind::Relu),
            Activation::Relu,
            refit_matmul,
        ),
        fuse_act_into(
            "fuse_linear_relu",
            pred!(l: OpKind::Linear { act: Activation::None }),
            pred!(r: OpKind::Relu),
            Activation::Relu,
            refit_linear,
        ),
        fuse_act_into(
            "fuse_linear_gelu",
            pred!(l: OpKind::Linear { act: Activation::None }),
            pred!(r: OpKind::Gelu),
            Activation::Gelu,
            refit_linear,
        ),
        unfuse_act("unfuse_linear_act", |op| match op {
            OpKind::Linear { act: Activation::Relu } => {
                Some((OpKind::Linear { act: Activation::None }, Activation::Relu))
            }
            OpKind::Linear { act: Activation::Gelu } => {
                Some((OpKind::Linear { act: Activation::None }, Activation::Gelu))
            }
            _ => None,
        }),
        fuse_act_into(
            "fuse_convbias_relu",
            pred!(c: OpKind::ConvBias { act: Activation::None, .. }),
            pred!(r: OpKind::Relu),
            Activation::Relu,
            refit_conv_bias,
        ),
        unfuse_act("unfuse_convbias_relu", |op| match op {
            OpKind::ConvBias { stride, pad, act: Activation::Relu } => Some((
                OpKind::ConvBias { stride: *stride, pad: *pad, act: Activation::None },
                Activation::Relu,
            )),
            _ => None,
        }),
        // normalisation
        fold_bn_into_conv(),
        fuse_add_layernorm(),
        unfuse_add_layernorm(),
        // n-ary adds
        fuse_add_add(),
        fuse_addn_add(),
        unfuse_addn(),
        // merging
        merge_conv_siblings(),
        merge_linear_siblings("merge_linear2", 2),
        merge_linear_siblings("merge_linear3", 3),
        merge_matmul_siblings(),
        // composition
        compose_1x1_convs(),
        compose_linears(),
        // shape algebra
        elim_transpose_pair(),
        merge_transpose_pair(),
        merge_reshape_pair(),
        absorb_transpose_rhs(),
        emit_transpose_rhs(),
        elim_concat_split(),
        elim_split_concat(),
        // commutation + misc
        swap_relu_maxpool(),
        swap_maxpool_relu(),
        hoist_scale_matmul(),
        relu_idempotent(),
        elim_identity(),
        fuse_matmul_bias(),
        unfuse_linear(),
        enlarge_conv("enlarge_conv1x1", 1),
        enlarge_conv("enlarge_conv3x3", 3),
    ]
    .into_iter()
    .chain(super::library_ext::extended_rules())
    .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::interp::semantically_equal;
    use crate::xfer::apply_rule;

    /// Apply every location of `rule_name` on `g` (fresh copy each time) and
    /// check the rewrite is semantics-preserving and validates.
    fn check_rule_on(g: &Graph, rule_name: &str) -> usize {
        let lib = standard_library();
        let idx = lib.index_of(rule_name).unwrap_or_else(|| panic!("no rule {rule_name}"));
        let rule = lib.get(idx).unwrap();
        let locs = rule.find(g);
        for loc in &locs {
            let mut g2 = g.clone();
            apply_rule(&mut g2, rule, loc).unwrap();
            g2.validate().unwrap();
            assert!(
                semantically_equal(g, &g2, 2, 1234, 2e-3).unwrap(),
                "{rule_name} at {:?} changed semantics",
                loc
            );
        }
        locs.len()
    }

    fn conv_relu_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        b.finish()
    }

    #[test]
    fn fuse_conv_relu_preserves_semantics() {
        assert_eq!(check_rule_on(&conv_relu_graph(), "fuse_conv_relu"), 1);
    }

    #[test]
    fn fuse_then_unfuse_round_trips_hash() {
        use crate::graph::canonical_hash;
        let g = conv_relu_graph();
        let lib = standard_library();
        let fuse = lib.get(lib.index_of("fuse_conv_relu").unwrap()).unwrap();
        let unfuse = lib.get(lib.index_of("unfuse_conv_relu").unwrap()).unwrap();
        let mut g2 = g.clone();
        let floc = fuse.find(&g2)[0].clone();
        apply_rule(&mut g2, fuse, &floc).unwrap();
        assert_ne!(canonical_hash(&g), canonical_hash(&g2));
        let loc = unfuse.find(&g2)[0].clone();
        apply_rule(&mut g2, unfuse, &loc).unwrap();
        assert_eq!(canonical_hash(&g), canonical_hash(&g2));
    }

    #[test]
    fn fold_bn_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 6, 6]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.batchnorm(c).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "fold_bn_conv"), 1);
    }

    #[test]
    fn convbias_fusion_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 6, 6]);
        let w = b.weight(&[4, 3, 3, 3]);
        let bias = b.weight(&[4]);
        let cb = b
            .op(
                OpKind::ConvBias { stride: 1, pad: PadMode::Same, act: Activation::None },
                &[x, w, bias],
            )
            .unwrap();
        let _ = b.relu(cb).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "fuse_convbias_relu"), 1);
    }

    #[test]
    fn fold_bn_then_relu_fusion_chain() {
        // conv -> bn -> relu: fold_bn gives conv_bias + relu, then
        // fuse_convbias_relu collapses to one op. Launch count 3 -> 1.
        use crate::cost::{CostModel, DeviceProfile};
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 6, 6]);
        let _ = b.conv_bn_relu(x, 4, 3, 1, PadMode::Same).unwrap();
        let g = b.finish();
        let cm = CostModel::new(DeviceProfile::rtx2070());
        let lib = standard_library();
        let before = cm.graph_cost(&g).launches;

        let fold = lib.get(lib.index_of("fold_bn_conv").unwrap()).unwrap();
        let mut g2 = g.clone();
        let loc = fold.find(&g2)[0].clone();
        crate::xfer::apply_rule(&mut g2, fold, &loc).unwrap();
        assert!(crate::interp::semantically_equal(&g, &g2, 2, 5, 2e-3).unwrap());

        let fuse = lib.get(lib.index_of("fuse_convbias_relu").unwrap()).unwrap();
        let loc = fuse.find(&g2)[0].clone();
        crate::xfer::apply_rule(&mut g2, fuse, &loc).unwrap();
        assert!(crate::interp::semantically_equal(&g, &g2, 2, 6, 2e-3).unwrap());
        let after = cm.graph_cost(&g2).launches;
        assert_eq!(before, 3);
        assert_eq!(after, 1);
    }

    #[test]
    fn fuse_add_ln_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 4, 16]);
        let y = b.input(&[1, 4, 16]);
        let s = b.add(x, y).unwrap();
        let _ = b.layernorm(s).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "fuse_add_ln"), 1);
        assert_eq!(check_rule_on(&g, "unfuse_add_ln"), 0);
    }

    #[test]
    fn addn_family_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 8]);
        let y = b.input(&[2, 8]);
        let z = b.input(&[2, 8]);
        let s1 = b.add(x, y).unwrap();
        let _ = b.add(s1, z).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "fuse_add_add"), 1);

        // Build the AddN version and unfuse it back.
        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[2, 8]);
        let y2 = b2.input(&[2, 8]);
        let z2 = b2.input(&[2, 8]);
        let _ = b2.op(OpKind::AddN { n: 3 }, &[x2, y2, z2]).unwrap();
        let g2 = b2.finish();
        assert_eq!(check_rule_on(&g2, "unfuse_addn"), 1);
    }

    #[test]
    fn merge_conv_siblings_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 6, 6]);
        let c1 = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c1).unwrap();
        let _ = b.relu(c2).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "merge_conv2"), 1);
    }

    #[test]
    fn merge_linear3_qkv_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[4, 16]);
        for _ in 0..3 {
            let l = b.linear(x, 16, Activation::None).unwrap();
            b.relu(l).unwrap();
        }
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "merge_linear3"), 1);
        // Pairwise merges also available: C(3,2) = 3.
        assert_eq!(check_rule_on(&g, "merge_linear2"), 3);
    }

    #[test]
    fn compose_linears_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[3, 8]);
        let l1 = b.linear(x, 12, Activation::None).unwrap();
        let node = l1.node;
        let _ = b.linear(l1, 5, Activation::None).unwrap();
        let g = b.finish();
        let _ = node;
        assert_eq!(check_rule_on(&g, "compose_linear"), 1);
    }

    #[test]
    fn compose_1x1_convs_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 5, 5]);
        let c1 = b.conv(x, 6, 1, 1, PadMode::Same).unwrap();
        let _ = b.conv(c1, 4, 1, 1, PadMode::Same).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "compose_conv1x1"), 1);
    }

    #[test]
    fn transpose_pair_rules() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 3, 4]);
        let t1 = b.transpose(x, &[1, 2, 0]).unwrap();
        let _ = b.transpose(t1, &[2, 0, 1]).unwrap(); // inverse of t1
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "elim_transpose2"), 1);
        assert_eq!(check_rule_on(&g, "merge_transpose2"), 0);

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[2, 3, 4]);
        let t1 = b2.transpose(x2, &[1, 2, 0]).unwrap();
        let _ = b2.transpose(t1, &[0, 2, 1]).unwrap(); // NOT inverse
        let g2 = b2.finish();
        assert_eq!(check_rule_on(&g2, "merge_transpose2"), 1);
        assert_eq!(check_rule_on(&g2, "elim_transpose2"), 0);
    }

    #[test]
    fn absorb_and_emit_transpose_rhs() {
        let mut b = GraphBuilder::new();
        let a = b.input(&[2, 4]);
        let c = b.input(&[3, 4]);
        let ct = b.transpose(c, &[1, 0]).unwrap();
        let _ = b
            .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[a, ct])
            .unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "absorb_transpose_rhs"), 1);

        let mut b2 = GraphBuilder::new();
        let a2 = b2.input(&[2, 4]);
        let c2 = b2.input(&[3, 4]);
        let _ = b2
            .op(OpKind::MatMul { trans_a: false, trans_b: true, act: Activation::None }, &[a2, c2])
            .unwrap();
        let g2 = b2.finish();
        assert_eq!(check_rule_on(&g2, "emit_transpose_rhs"), 1);
    }

    #[test]
    fn concat_split_eliminations() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 4, 8]);
        let y = b.input(&[1, 4, 8]);
        let cat = b.concat(1, &[x, y]).unwrap();
        let parts = b.op_multi(OpKind::Split { axis: 1, parts: 2 }, &[cat]).unwrap();
        let _ = b.relu(parts[0]).unwrap();
        let _ = b.relu(parts[1]).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "elim_concat_split"), 1);

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[1, 8, 8]);
        let parts = b2.op_multi(OpKind::Split { axis: 1, parts: 2 }, &[x2]).unwrap();
        let _ = b2.concat(1, &parts).unwrap();
        let g2 = b2.finish();
        assert_eq!(check_rule_on(&g2, "elim_split_concat"), 1);
    }

    #[test]
    fn commutation_rules() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let r = b.relu(x).unwrap();
        let _ = b.maxpool(r, 2, 2).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "swap_relu_maxpool"), 1);

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[1, 3, 8, 8]);
        let p = b2.maxpool(x2, 2, 2).unwrap();
        let _ = b2.relu(p).unwrap();
        let g2 = b2.finish();
        assert_eq!(check_rule_on(&g2, "swap_maxpool_relu"), 1);
    }

    #[test]
    fn hoist_scale_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let a = b.input(&[2, 4]);
        let c = b.input(&[4, 3]);
        let s = b.op(OpKind::Scale { factor: 0.5 }, &[a]).unwrap();
        let _ = b
            .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[s, c])
            .unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "hoist_scale_matmul"), 1);
    }

    #[test]
    fn matmul_bias_linear_round_trip() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let w = b.weight(&[4, 3]);
        let bias = b.weight(&[3]);
        let mm = b
            .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[x, w])
            .unwrap();
        let _ = b.op(OpKind::Add, &[mm, bias]).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "fuse_matmul_bias"), 1);

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[2, 4]);
        let _ = b2.linear(x2, 3, Activation::None).unwrap();
        let g2 = b2.finish();
        assert_eq!(check_rule_on(&g2, "unfuse_linear"), 1);
    }

    #[test]
    fn enlarge_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 2, 8, 8]);
        let _ = b.conv(x, 3, 3, 1, PadMode::Same).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "enlarge_conv3x3"), 1);
        assert_eq!(check_rule_on(&g, "enlarge_conv1x1"), 0);
    }

    #[test]
    fn relu_idempotent_rule() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let r1 = b.relu(x).unwrap();
        let _ = b.relu(r1).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "relu_idempotent"), 1);
    }

    #[test]
    fn library_names_unique_and_sized() {
        let lib = standard_library();
        assert!(lib.len() >= 30, "library has {} rules", lib.len());
        assert!(lib.len() <= 48, "library exceeds xfer slots");
    }

    #[test]
    fn every_rule_fires_somewhere_in_zoo_or_unit_graphs() {
        // Each rule must be reachable: find() returns > 0 on at least one
        // zoo graph or one of the synthetic graphs used above.
        let lib = standard_library();
        let mut graphs: Vec<Graph> = crate::zoo::all().into_iter().map(|(_, g)| g).collect();
        graphs.push(conv_relu_graph());
        // Synthetic coverage graphs for rules the zoo never triggers.
        {
            let mut b = GraphBuilder::new();
            let x = b.input(&[2, 3, 4]);
            let t1 = b.transpose(x, &[1, 2, 0]).unwrap();
            let _ = b.transpose(t1, &[2, 0, 1]).unwrap();
            let t3 = b.transpose(x, &[1, 2, 0]).unwrap();
            let _ = b.transpose(t3, &[0, 2, 1]).unwrap(); // non-inverse pair
            let r1 = b.reshape(x, &[6, 4]).unwrap();
            let _ = b.reshape(r1, &[24]).unwrap();
            graphs.push(b.finish());
        }
        {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 4, 8]);
            let y = b.input(&[1, 4, 8]);
            let cat = b.concat(1, &[x, y]).unwrap();
            let parts = b.op_multi(OpKind::Split { axis: 1, parts: 2 }, &[cat]).unwrap();
            let c2 = b.concat(1, &parts).unwrap();
            let _ = b.op(OpKind::Identity, &[c2]).unwrap();
            graphs.push(b.finish());
        }
        {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 3, 8, 8]);
            let r = b.relu(x).unwrap();
            let r2 = b.relu(r).unwrap();
            let p = b.maxpool(r2, 2, 2).unwrap();
            let _ = b.relu(p).unwrap();
            graphs.push(b.finish());
        }
        {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 3, 6, 6]);
            let c1 = b.conv(x, 6, 1, 1, PadMode::Same).unwrap();
            let c2 = b.conv(c1, 4, 1, 1, PadMode::Same).unwrap();
            let c3 = b.conv(c2, 4, 3, 1, PadMode::Same).unwrap();
            let _ = b.batchnorm(c3).unwrap();
            graphs.push(b.finish());
        }
        {
            let mut b = GraphBuilder::new();
            let x = b.input(&[2, 8]);
            let y = b.input(&[2, 8]);
            let z = b.input(&[2, 8]);
            let n3 = b.op(OpKind::AddN { n: 3 }, &[x, y, z]).unwrap();
            let _ = b.add(n3, x).unwrap();
            let s1 = b.add(x, y).unwrap();
            let _ = b.add(s1, z).unwrap();
            let l1 = b.linear(x, 8, Activation::None).unwrap();
            let _ = b.linear(l1, 4, Activation::Relu).unwrap();
            let lr = b.linear(y, 8, Activation::None).unwrap();
            let _ = b.relu(lr).unwrap();
            let lg = b.linear(z, 8, Activation::None).unwrap();
            let _ = b.gelu(lg).unwrap();
            let w8 = b.weight(&[8, 4]);
            let m1 = b
                .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[x, w8])
                .unwrap();
            let _ = b.relu(m1);
            // Sibling matmuls off the same LHS for merge_matmul2.
            let w8b = b.weight(&[8, 4]);
            let m2 = b
                .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[x, w8b])
                .unwrap();
            let _ = b.op(OpKind::Tanh, &[m2]).unwrap();
            graphs.push(b.finish());
        }
        {
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 3, 6, 6]);
            let c1 = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
            let c2 = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
            let _ = b.add(c1, c2).unwrap();
            let x2 = b.input(&[2, 4]);
            let w2 = b.weight(&[4, 3]);
            let mm = b
                .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
                    &[x2, w2])
                .unwrap();
            let bias = b.weight(&[3]);
            let _ = b.op(OpKind::Add, &[mm, bias]).unwrap();
            graphs.push(b.finish());
        }
        {
            // ConvBias coverage: folded conv followed by relu.
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 3, 8, 8]);
            let w = b.weight(&[4, 3, 3, 3]);
            let bias = b.weight(&[4]);
            let cb = b
                .op(
                    OpKind::ConvBias { stride: 1, pad: PadMode::Same, act: Activation::None },
                    &[x, w, bias],
                )
                .unwrap();
            let _ = b.relu(cb).unwrap();
            let cb2 = b
                .op(
                    OpKind::ConvBias { stride: 1, pad: PadMode::Same, act: Activation::Relu },
                    &[x, w, bias],
                )
                .unwrap();
            let _ = cb2;
            // Identical parallel ConvBias pair for merge_convbias2.
            let wb = b.weight(&[4, 3, 3, 3]);
            let bb = b.weight(&[4]);
            let m1 = b
                .op(
                    OpKind::ConvBias { stride: 1, pad: PadMode::Same, act: Activation::None },
                    &[x, w, bias],
                )
                .unwrap();
            let m2 = b
                .op(
                    OpKind::ConvBias { stride: 1, pad: PadMode::Same, act: Activation::None },
                    &[x, wb, bb],
                )
                .unwrap();
            let _ = b.relu(m1).unwrap();
            let _ = b.relu(m2).unwrap();
            // Stacked VALID max-pools + weight-mul chains + scale-rhs matmul.
            let p1 = b
                .op(OpKind::MaxPool { k: 2, stride: 2, pad: PadMode::Valid }, &[x])
                .unwrap();
            let _ = b
                .op(OpKind::MaxPool { k: 2, stride: 2, pad: PadMode::Valid }, &[p1])
                .unwrap();
            let flat = b.input(&[2, 8]);
            let wv = b.weight(&[8]);
            let wv2 = b.weight(&[8]);
            let mm1 = b.op(OpKind::Mul, &[flat, wv]).unwrap();
            let _ = b.op(OpKind::Mul, &[mm1, wv2]).unwrap();
            let wmat = b.weight(&[8, 5]);
            let swm = b.op(OpKind::Scale { factor: 0.5 }, &[wmat]).unwrap();
            let _ = b
                .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[flat, swm])
                .unwrap();
            let at = b.transpose(flat, &[1, 0]).unwrap();
            let w28 = b.weight(&[2, 6]);
            let _ = b
                .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[at, w28])
                .unwrap();
            let y8 = b.input(&[2, 8]);
            let sadd = b.op(OpKind::Add, &[flat, y8]).unwrap();
            let _ = b.transpose(sadd, &[1, 0]).unwrap();
            let t1 = b.transpose(flat, &[1, 0]).unwrap();
            let t2 = b.transpose(y8, &[1, 0]).unwrap();
            let _ = b.op(OpKind::Add, &[t1, t2]).unwrap();
            let sc1 = b.op(OpKind::Scale { factor: 2.0 }, &[flat]).unwrap();
            let _ = b.op(OpKind::Scale { factor: 0.5 }, &[sc1]).unwrap();
            graphs.push(b.finish());
        }
        {
            // Fused-form coverage: unfuse + emit rules need fused inputs.
            let mut b = GraphBuilder::new();
            let x = b.input(&[1, 3, 8, 8]);
            let w = b.weight(&[4, 3, 3, 3]);
            let _ = b
                .op(
                    OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::Relu },
                    &[x, w],
                )
                .unwrap();
            let a = b.input(&[2, 4]);
            let c = b.input(&[3, 4]);
            let _ = b
                .op(OpKind::MatMul { trans_a: false, trans_b: true, act: Activation::None }, &[a, c])
                .unwrap();
            let ct = b.transpose(c, &[1, 0]).unwrap();
            let _ = b
                .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[a, ct])
                .unwrap();
            let p = b.input(&[1, 4, 16]);
            let q = b.input(&[1, 4, 16]);
            let gamma = b.weight(&[16]);
            let beta = b.weight(&[16]);
            let _ = b.op(OpKind::FusedAddLayerNorm, &[p, q, gamma, beta]).unwrap();
            let sc = b.op(OpKind::Scale { factor: 0.25 }, &[a]).unwrap();
            let w45 = b.weight(&[4, 5]);
            let _ = b
                .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[sc, w45])
                .unwrap();
            graphs.push(b.finish());
        }
        for rule in &lib.rules {
            let hits: usize = graphs.iter().map(|g| rule.find(g).len()).sum();
            assert!(hits > 0, "rule {} never fires", rule.name());
        }
    }

    #[test]
    fn bert_has_transformer_fusion_opportunities() {
        let g = crate::zoo::bert_base();
        let lib = standard_library();
        let addln = lib.get(lib.index_of("fuse_add_ln").unwrap()).unwrap();
        assert_eq!(addln.find(&g).len(), 24); // 2 per encoder layer
        let qkv = lib.get(lib.index_of("merge_linear3").unwrap()).unwrap();
        assert!(!qkv.find(&g).is_empty());
    }

    #[test]
    fn relevance_fingerprint_covers_every_match_node() {
        // The incremental maintenance contract (Rule::op_relevant): every
        // node of every reported location must satisfy the fingerprint —
        // a fingerprint narrower than its `find` would silently miss new
        // matches after a rewrite. Exercised over the whole zoo.
        // Handwritten rules plus a smoke-scale synthesised set: SynthRule
        // carries its own OpRelevance fingerprint and must honour the same
        // contract with no special-casing.
        let synth = crate::xfer::synth::synthesise(&crate::xfer::synth::SynthConfig {
            alphabet: "ewise,act,shape,scale".into(),
            tier: crate::xfer::synth::Tier::All,
            ..Default::default()
        })
        .unwrap();
        let mut rules = standard_library().rules;
        rules.extend(crate::xfer::synth::boxed(synth.rules));
        let lib = RuleSet::new(rules);
        // Zoo graphs plus a small host graph the synthesised alphabet
        // actually fires on (the zoo has no relu∘relu / transpose-pair /
        // scale-pair chains at the synthesis shapes).
        let mut graphs: Vec<Graph> = crate::zoo::all().into_iter().map(|(_, g)| g).collect();
        {
            let mut b = GraphBuilder::new();
            let x = b.input(&[4, 4]);
            let r = b.relu(x).unwrap();
            let r2 = b.relu(r).unwrap();
            let t = b.op(OpKind::Transpose { perm: vec![1, 0] }, &[r2]).unwrap();
            let t2 = b.op(OpKind::Transpose { perm: vec![1, 0] }, &[t]).unwrap();
            let s = b.op(OpKind::Scale { factor: 2.0 }, &[t2]).unwrap();
            let _ = b.op(OpKind::Scale { factor: 0.5 }, &[s]).unwrap();
            graphs.push(b.finish());
        }
        let mut checked = 0usize;
        let mut synth_checked = 0usize;
        for g in &graphs {
            for rule in &lib.rules {
                for loc in rule.find(g) {
                    for &id in &loc {
                        assert!(
                            rule.op_relevant(&g.node(id).op),
                            "{}: match node {:?} ({}) outside relevance fingerprint",
                            rule.name(),
                            id,
                            g.node(id).op.name()
                        );
                        checked += 1;
                        if rule.name().starts_with("synth_") {
                            synth_checked += 1;
                        }
                    }
                }
            }
        }
        assert!(checked > 100, "too few match nodes exercised: {checked}");
        assert!(synth_checked > 0, "no synthesised match nodes exercised");
    }
}
