//! Splice helpers shared by all rule implementations, and the
//! [`ApplyReport`] describing what one application changed.

use crate::graph::{Graph, NodeId, PortRef};

/// What one rule application changed, computed by [`crate::xfer::apply_rule`]
/// as a live-set diff (so it includes nodes collected by the post-rewrite
/// DCE, not just the rule's explicit kills). This is the contract the
/// incremental cost path (`CostModel::delta_runtime_ms`) consumes: every
/// node whose runtime contribution can have changed is either listed here
/// or had its constness flipped.
#[derive(Debug, Clone, Default)]
pub struct ApplyReport {
    /// Arena size before the rewrite: ids at or above this are new slots.
    pub prev_slots: usize,
    /// Nodes live before the rewrite and dead after it.
    pub removed: Vec<NodeId>,
    /// Nodes created by the rewrite and still live after DCE.
    pub added: Vec<NodeId>,
}

impl ApplyReport {
    /// Diff the post-rewrite graph against the pre-rewrite live set.
    pub(crate) fn diff(g: &Graph, prev_slots: usize, live_before: &[bool]) -> Self {
        let mut removed = Vec::new();
        let mut added = Vec::new();
        for (i, was_live) in live_before.iter().enumerate().take(prev_slots) {
            if *was_live && g.nodes[i].dead {
                removed.push(NodeId(i as u32));
            }
        }
        for i in prev_slots..g.n_slots() {
            if !g.nodes[i].dead {
                added.push(NodeId(i as u32));
            }
        }
        Self { prev_slots, removed, added }
    }

    /// All nodes the application touched (removed then added).
    pub fn touched(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.removed.iter().chain(self.added.iter()).copied()
    }
}

/// If `p` refers to a source (Input/Weight), wrap it in an `Identity` op so
/// the spliced value remains an observable graph *output* (sources are never
/// counted as outputs). Rewrites at graph sinks rely on this.
fn op_port(g: &mut Graph, p: PortRef) -> anyhow::Result<PortRef> {
    if matches!(
        g.node(p.node).op,
        crate::graph::OpKind::Input | crate::graph::OpKind::Weight
    ) {
        Ok(PortRef::of(g.add(crate::graph::OpKind::Identity, &[p])?))
    } else {
        Ok(p)
    }
}

/// Redirect all consumers of `old` (port 0) to `new`, then kill `old`.
/// Shapes must match — rewrites may never change an observable tensor.
pub fn splice(g: &mut Graph, old: NodeId, new: PortRef) -> anyhow::Result<()> {
    let old_desc = g.node(old).outs[0].clone();
    let new_desc = g.out_desc(new)?.clone();
    anyhow::ensure!(
        old_desc == new_desc,
        "splice shape mismatch: {} -> {}",
        old_desc,
        new_desc
    );
    let new = op_port(g, new)?;
    g.replace_uses(PortRef::of(old), new);
    g.kill(old);
    Ok(())
}

/// Splice a specific output port of a multi-output node.
pub fn splice_port(g: &mut Graph, old: PortRef, new: PortRef) -> anyhow::Result<()> {
    let old_desc = g.out_desc(old)?.clone();
    let new_desc = g.out_desc(new)?.clone();
    anyhow::ensure!(old_desc == new_desc, "splice shape mismatch");
    let new = op_port(g, new)?;
    g.replace_uses(old, new);
    Ok(())
}

/// Fetch the op of `id`, erroring if the id is stale (dead/out of range).
pub fn live_op(g: &Graph, id: NodeId) -> anyhow::Result<&crate::graph::OpKind> {
    anyhow::ensure!(id.index() < g.n_slots(), "stale node id {:?}", id);
    let n = g.node(id);
    anyhow::ensure!(!n.dead, "node {:?} is dead", id);
    Ok(&n.op)
}

impl Graph {
    /// Arena capacity (including dead slots) — used for staleness checks.
    pub fn n_slots(&self) -> usize {
        self.nodes.len()
    }
}
