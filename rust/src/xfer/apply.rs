//! Splice helpers shared by all rule implementations, and the
//! [`ApplyReport`] describing what one application changed.

use crate::graph::{Graph, NodeId, PortRef};

/// What one rule application changed, computed by [`crate::xfer::apply_rule`]
/// as a live-set diff (so it includes nodes collected by the post-rewrite
/// DCE, not just the rule's explicit kills). This is the contract the
/// incremental cost path (`CostModel::delta_runtime_ms`) consumes: every
/// node whose runtime contribution can have changed is either listed here
/// or had its constness flipped.
#[derive(Debug, Clone, Default)]
pub struct ApplyReport {
    /// Arena size before the rewrite: ids at or above this are new slots.
    pub prev_slots: usize,
    /// Nodes live before the rewrite and dead after it.
    pub removed: Vec<NodeId>,
    /// Nodes created by the rewrite and still live after DCE.
    pub added: Vec<NodeId>,
}

impl ApplyReport {
    /// Diff the post-rewrite graph against the pre-rewrite live set.
    pub(crate) fn diff(g: &Graph, prev_slots: usize, live_before: &[bool]) -> Self {
        let mut removed = Vec::new();
        let mut added = Vec::new();
        for (i, was_live) in live_before.iter().enumerate().take(prev_slots) {
            if *was_live && g.nodes[i].dead {
                removed.push(NodeId(i as u32));
            }
        }
        for i in prev_slots..g.n_slots() {
            if !g.nodes[i].dead {
                added.push(NodeId(i as u32));
            }
        }
        Self { prev_slots, removed, added }
    }

    /// All nodes the application touched (removed then added).
    pub fn touched(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.removed.iter().chain(self.added.iter()).copied()
    }

    /// The full set of nodes whose *local match state* this application
    /// changed — the query surface the incremental environment re-matches
    /// against. See [`DirtyRegion`].
    pub fn dirty_region(&self, before: &Graph, after: &Graph) -> DirtyRegion {
        DirtyRegion::compute(before, after, self)
    }
}

/// Every node whose local match state — operator, input list, or consumer
/// set — one rule application changed. Pattern matches are functions of
/// exactly that per-node state (chains test ops, first-input edges and
/// sole-consumer properties; sibling groups test ops and shared first
/// inputs), so a match can appear, disappear, or reorder only if it
/// contains a node in this set. The environment's incremental match
/// maintenance (`env::incremental`) keeps every cached location that does
/// not intersect it.
///
/// Membership, all in after-graph slot numbering (arena slots are stable
/// across a rewrite):
///  * nodes removed or added by the rewrite (the [`ApplyReport`] diff);
///  * surviving nodes whose input list was rewired (`splice` redirects the
///    consumers of every replaced node);
///  * nodes whose consumer set changed: producers feeding a removed node
///    (before) or an added node (after), and producers a rewired survivor
///    stopped or started reading.
#[derive(Debug, Clone, Default)]
pub struct DirtyRegion {
    /// Membership bitmap indexed by after-arena slot.
    dirty: Vec<bool>,
    /// Dirty nodes still live in the after graph, ascending id order.
    live: Vec<NodeId>,
}

impl DirtyRegion {
    pub fn compute(before: &Graph, after: &Graph, report: &ApplyReport) -> Self {
        let n = after.n_slots();
        let mut dirty = vec![false; n];
        for id in report.touched() {
            dirty[id.index()] = true;
        }
        // Producers of the removed nodes lost a consumer; producers of the
        // added nodes gained one.
        for &id in &report.removed {
            for p in &before.node(id).inputs {
                dirty[p.node.index()] = true;
            }
        }
        for &id in &report.added {
            for p in &after.node(id).inputs {
                dirty[p.node.index()] = true;
            }
        }
        // Surviving nodes whose inputs were rewired, plus the producers on
        // both sides of the rewiring (their consumer sets changed). The
        // direct diff is O(slots) and catches in-place input mutation too,
        // not just `replace_uses` rewiring.
        for idx in 0..report.prev_slots.min(n) {
            let (b, a) = (&before.nodes[idx], &after.nodes[idx]);
            if b.dead || a.dead || b.inputs == a.inputs {
                continue;
            }
            dirty[idx] = true;
            for p in b.inputs.iter().chain(a.inputs.iter()) {
                dirty[p.node.index()] = true;
            }
        }
        let live = dirty
            .iter()
            .enumerate()
            .filter(|&(i, &d)| d && !after.nodes[i].dead)
            .map(|(i, _)| NodeId(i as u32))
            .collect();
        Self { dirty, live }
    }

    /// Was `id`'s local match state changed by the application?
    pub fn contains(&self, id: NodeId) -> bool {
        self.dirty.get(id.index()).copied().unwrap_or(false)
    }

    /// Dirty nodes still live in the after graph.
    pub fn live_nodes(&self) -> &[NodeId] {
        &self.live
    }

    /// Does any live dirty node satisfy `relevant`? (The gains test: a new
    /// match must contain a live changed node, so a rule none of whose
    /// relevant ops appear here cannot have gained one.)
    pub fn any_live<F: Fn(&crate::graph::OpKind) -> bool>(&self, g: &Graph, relevant: F) -> bool {
        self.live.iter().any(|&id| relevant(&g.node(id).op))
    }

    pub fn len(&self) -> usize {
        self.dirty.iter().filter(|&&d| d).count()
    }

    pub fn is_empty(&self) -> bool {
        !self.dirty.iter().any(|&d| d)
    }
}

/// If `p` refers to a source (Input/Weight), wrap it in an `Identity` op so
/// the spliced value remains an observable graph *output* (sources are never
/// counted as outputs). Rewrites at graph sinks rely on this.
fn op_port(g: &mut Graph, p: PortRef) -> anyhow::Result<PortRef> {
    if matches!(
        g.node(p.node).op,
        crate::graph::OpKind::Input | crate::graph::OpKind::Weight
    ) {
        Ok(PortRef::of(g.add(crate::graph::OpKind::Identity, &[p])?))
    } else {
        Ok(p)
    }
}

/// Redirect all consumers of `old` (port 0) to `new`, then kill `old`.
/// Shapes must match — rewrites may never change an observable tensor.
pub fn splice(g: &mut Graph, old: NodeId, new: PortRef) -> anyhow::Result<()> {
    let old_desc = g.node(old).outs[0].clone();
    let new_desc = g.out_desc(new)?.clone();
    anyhow::ensure!(
        old_desc == new_desc,
        "splice shape mismatch: {} -> {}",
        old_desc,
        new_desc
    );
    let new = op_port(g, new)?;
    g.replace_uses(PortRef::of(old), new);
    g.kill(old);
    Ok(())
}

/// Splice a specific output port of a multi-output node.
pub fn splice_port(g: &mut Graph, old: PortRef, new: PortRef) -> anyhow::Result<()> {
    let old_desc = g.out_desc(old)?.clone();
    let new_desc = g.out_desc(new)?.clone();
    anyhow::ensure!(old_desc == new_desc, "splice shape mismatch");
    let new = op_port(g, new)?;
    g.replace_uses(old, new);
    Ok(())
}

/// Fetch the op of `id`, erroring if the id is stale (dead/out of range).
pub fn live_op(g: &Graph, id: NodeId) -> anyhow::Result<&crate::graph::OpKind> {
    anyhow::ensure!(id.index() < g.n_slots(), "stale node id {:?}", id);
    let n = g.node(id);
    anyhow::ensure!(!n.dead, "node {:?} is dead", id);
    Ok(&n.op)
}

impl Graph {
    /// Arena capacity (including dead slots) — used for staleness checks.
    pub fn n_slots(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{GraphBuilder, OpKind, PadMode};
    use crate::xfer::library::standard_library;

    #[test]
    fn dirty_region_covers_touched_neighbourhood() {
        // x -> conv -> relu -> tanh -> sigmoid; fusing conv+relu must dirty
        // the fused pair, the new node, the producers (x, w) and the
        // rewired consumer (tanh) — but not the far sigmoid.
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let r = b.relu(c).unwrap();
        let t = b.op(OpKind::Tanh, &[r]).unwrap();
        let s = b.op(OpKind::Sigmoid, &[t]).unwrap();
        let g = b.finish();

        let lib = standard_library();
        let rule = lib.get(lib.index_of("fuse_conv_relu").unwrap()).unwrap();
        let loc = rule.find(&g)[0].clone();
        let mut g2 = g.clone();
        let report = crate::xfer::apply_rule(&mut g2, rule, &loc).unwrap();
        let dirty = report.dirty_region(&g, &g2);

        assert!(dirty.contains(c.node), "killed conv must be dirty");
        assert!(dirty.contains(r.node), "killed relu must be dirty");
        for &id in &report.added {
            assert!(dirty.contains(id), "added node must be dirty");
        }
        assert!(dirty.contains(x.node), "producer lost a consumer");
        assert!(dirty.contains(t.node), "rewired consumer must be dirty");
        assert!(!dirty.contains(s.node), "untouched sink must stay clean");
        // Live set excludes the killed nodes and is relevance-queryable.
        assert!(dirty.live_nodes().iter().all(|&id| !g2.node(id).dead));
        assert!(dirty.any_live(&g2, |op| matches!(op, OpKind::Tanh)));
        assert!(!dirty.any_live(&g2, |op| matches!(op, OpKind::Sigmoid)));
        assert!(!dirty.is_empty());
        assert!(dirty.len() >= 4);
    }
}
