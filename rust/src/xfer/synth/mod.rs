//! Enumerative substitution-rule synthesis (TASO §4 / ruler-style tiering).
//!
//! Pipeline dataflow:
//!
//! ```text
//! alphabet spec ──> enumerate (canonical-hash dedup)
//!                      │
//!                      ▼
//!            fingerprint on shared random 4x4 tensors (interp)
//!                      │ group
//!                      ▼
//!            candidate pairs ──prune──> renamings / common-suffix pairs
//!                      │
//!                      ▼
//!            exact re-verification on fresh draws (semantically_equal)
//!                      │
//!                      ▼
//!     square bit-exactness probe + rectangular shape-generality probe
//!                      │ tier
//!                      ▼
//!       always-safe ⊂ shape-preserving ⊂ all   ──>  [`SynthRule`]s
//! ```
//!
//! The tiers mirror ruler's hierarchy: `always-safe` rules are bit-exact,
//! shape-generic and non-expanding (safe to fire blindly); a
//! `shape-preserving` rule verified at every probe shape within tolerance;
//! `all` additionally admits rules only validated in the square enumeration
//! regime (their matcher restricts sites to that shape class).
//!
//! Output rules implement [`Rule`](crate::xfer::Rule) and carry their own
//! `OpRelevance` fingerprint, so they drop into the incremental matcher and
//! the parallel search engine exactly like handwritten library rules.

pub mod enumerate;
pub mod rule;
pub mod serialize;

pub use enumerate::{alphabet_from_spec, enumerate_with};
pub use rule::SynthRule;
pub use serialize::{load_rules, save_rules};

use std::collections::HashMap;

use crate::graph::{canonical_hash, Graph, NodeId, OpKind, PortRef, TensorDesc};
use crate::interp::{eval_outputs, semantically_equal, Tensor};
use crate::util::Rng;
use crate::xfer::RuleSet;

/// Ruleset tier, ordered by inclusion: `AlwaysSafe ⊂ ShapePreserving ⊂ All`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    /// Bit-exact, verified at every probe shape, never adds ops.
    AlwaysSafe,
    /// Verified (within tolerance) at square and rectangular probe shapes.
    ShapePreserving,
    /// Verified only in the square enumeration regime; the rule's matcher
    /// restricts its sites to that shape class.
    All,
}

impl Tier {
    /// Stable serialisation name.
    pub fn as_str(self) -> &'static str {
        match self {
            Tier::AlwaysSafe => "always-safe",
            Tier::ShapePreserving => "shape-preserving",
            Tier::All => "all",
        }
    }

    /// Inverse of [`Tier::as_str`].
    pub fn parse(s: &str) -> anyhow::Result<Tier> {
        Ok(match s {
            "always-safe" => Tier::AlwaysSafe,
            "shape-preserving" => Tier::ShapePreserving,
            "all" => Tier::All,
            _ => anyhow::bail!(
                "unknown tier '{}' (expected always-safe, shape-preserving or all)",
                s
            ),
        })
    }
}

/// Synthesis parameters. Everything that affects the output is in here, so
/// equal configs produce bit-identical rulesets.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Number of symbolic input slots the enumerator wires ops over.
    pub n_inputs: usize,
    /// Maximum ops per enumerated pattern side.
    pub max_ops: usize,
    /// Seed for fingerprinting and verification draws.
    pub seed: u64,
    /// Comma-separated alphabet group spec (see [`enumerate::GROUPS`]).
    pub alphabet: String,
    /// Keep rules up to (and including) this tier.
    pub tier: Tier,
    /// Cap on emitted rules after tier filtering; 0 means unlimited.
    pub max_rules: usize,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self {
            n_inputs: 2,
            max_ops: 2,
            seed: 42,
            alphabet: "ewise,act,shape,matmul,scale,fused".into(),
            tier: Tier::AlwaysSafe,
            max_rules: 0,
        }
    }
}

/// Pipeline counters, for logging and the determinism property test.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SynthStats {
    /// Graphs surviving enumeration dedup.
    pub enumerated: usize,
    /// Fingerprint groups with at least two members.
    pub groups: usize,
    /// Candidate pairs examined.
    pub candidates: usize,
    /// Pairs pruned as pure input renamings (Fig. 3a).
    pub pruned_renaming: usize,
    /// Pairs pruned as common-suffix variants (Fig. 3b).
    pub pruned_common: usize,
    /// Pairs passing exact re-verification.
    pub verified: usize,
    /// Verified pairs rejected structurally (unbindable rhs sources etc.).
    pub rejected: usize,
    /// Rules assigned the always-safe tier (before tier filtering).
    pub tier_always_safe: usize,
    /// Rules assigned the shape-preserving tier.
    pub tier_shape_preserving: usize,
    /// Rules assigned the all tier.
    pub tier_all: usize,
}

/// Synthesis result: tier-sorted rules plus pipeline counters.
pub struct SynthOutput {
    /// Emitted rules, sorted by (tier, name) — the on-disk order.
    pub rules: Vec<SynthRule>,
    /// Pipeline counters.
    pub stats: SynthStats,
}

/// Evaluate a graph on shared random inputs and hash the (rounded) outputs.
/// Shared with `xfer::generator`'s legacy pipeline.
pub(crate) fn graph_fingerprint(g: &Graph, seed: u64) -> Option<u64> {
    let mut rng = Rng::new(seed);
    let mut feeds = HashMap::new();
    let mut ids: Vec<NodeId> = g
        .live_ids()
        .filter(|id| matches!(g.node(*id).op, OpKind::Input))
        .collect();
    ids.sort();
    for id in ids {
        feeds.insert(id, Tensor::random(&g.node(id).outs[0].shape, &mut rng));
    }
    let outs = eval_outputs(g, &feeds, seed ^ 0xABCD).ok()?;
    let mut h = 0xCBF29CE484222325u64;
    for t in outs {
        for &d in &t.shape {
            h = h.rotate_left(9) ^ (d as u64);
        }
        for v in t.data {
            // Round to 1e-3 so float noise does not split groups; exact
            // verification happens later.
            let q = (v * 1000.0).round() as i64;
            h = h.rotate_left(7).wrapping_mul(0x100000001B3) ^ (q as u64);
        }
    }
    Some(h)
}

/// Worst-case output divergence across `trials` shared random draws.
/// `Some(0.0)` means the two sides are bit-identical on every draw; `None`
/// means evaluation failed or outputs are incomparable.
fn max_divergence(a: &Graph, b: &Graph, trials: usize, seed: u64) -> Option<f32> {
    let collect = |g: &Graph| {
        let mut ids: Vec<NodeId> = g
            .live_ids()
            .filter(|id| matches!(g.node(*id).op, OpKind::Input))
            .collect();
        ids.sort();
        ids
    };
    let (a_in, b_in) = (collect(a), collect(b));
    if a_in.len() != b_in.len() {
        return None;
    }
    let mut rng = Rng::new(seed);
    let mut worst = 0.0f32;
    for trial in 0..trials {
        let mut feeds_a = HashMap::new();
        let mut feeds_b = HashMap::new();
        for (ia, ib) in a_in.iter().zip(&b_in) {
            if a.node(*ia).outs[0].shape != b.node(*ib).outs[0].shape {
                return None;
            }
            let t = Tensor::random(&a.node(*ia).outs[0].shape, &mut rng);
            feeds_a.insert(*ia, t.clone());
            feeds_b.insert(*ib, t);
        }
        let wseed = seed ^ (trial as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let oa = eval_outputs(a, &feeds_a, wseed).ok()?;
        let ob = eval_outputs(b, &feeds_b, wseed).ok()?;
        if oa.len() != ob.len() {
            return None;
        }
        for (ta, tb) in oa.iter().zip(&ob) {
            worst = worst.max(ta.max_abs_diff(tb)?);
        }
    }
    Some(worst)
}

/// Rebuild `g` with its sources (ascending-id order) re-typed to `shapes`.
/// Fails if any op's shape inference rejects the new shapes.
fn rebuild_with_shapes(g: &Graph, shapes: &[Vec<usize>]) -> anyhow::Result<Graph> {
    let (g, _) = g.compact()?;
    let mut out = Graph::new();
    let mut map: HashMap<NodeId, NodeId> = HashMap::new();
    let mut si = 0usize;
    for id in g.live_ids() {
        let n = g.node(id);
        let new = match n.op {
            OpKind::Input | OpKind::Weight => {
                anyhow::ensure!(si < shapes.len(), "not enough probe shapes");
                let d = TensorDesc { shape: shapes[si].clone(), dtype: n.outs[0].dtype };
                si += 1;
                out.add_source(n.op.clone(), d)
            }
            _ => {
                let ins: Vec<PortRef> = n
                    .inputs
                    .iter()
                    .map(|p| PortRef { node: map[&p.node], port: p.port })
                    .collect();
                out.add(n.op.clone(), &ins)?
            }
        };
        map.insert(id, new);
    }
    anyhow::ensure!(si == shapes.len(), "probe shape count mismatch");
    Ok(out)
}

/// Probe a verified square-regime pair at rectangular shapes.
///
/// Returns `(shape_generic, exact)`: `shape_generic` holds iff at least one
/// rectangular assignment builds on both sides and verifies there — and no
/// buildable assignment diverges (a pair that type-checks rectangularly but
/// computes different values is square-only no matter what). `exact` holds
/// if every buildable probe was bit-identical.
fn probe_rectangular(lhs: &Graph, rhs: &Graph, n_src: usize, seed: u64) -> (bool, bool) {
    let assignments: Vec<Vec<Vec<usize>>> = vec![
        vec![vec![2, 6]; n_src],
        vec![vec![6, 2]; n_src],
        (0..n_src).map(|i| if i % 2 == 0 { vec![2, 6] } else { vec![6, 2] }).collect(),
        (0..n_src).map(|i| if i % 2 == 0 { vec![6, 2] } else { vec![2, 6] }).collect(),
    ];
    let mut any_ok = false;
    let mut exact = true;
    for shapes in assignments {
        let (gl, gr) = match (rebuild_with_shapes(lhs, &shapes), rebuild_with_shapes(rhs, &shapes))
        {
            (Ok(a), Ok(b)) => (a, b),
            // An assignment only one side accepts is unreachable at apply
            // time (find-time rhs inference rejects it) — not disqualifying.
            _ => continue,
        };
        match max_divergence(&gl, &gr, 2, seed) {
            Some(d) if d <= 1e-3 => {
                any_ok = true;
                if d > 0.0 {
                    exact = false;
                }
            }
            _ => return (false, false),
        }
    }
    (any_ok, exact)
}

fn op_multiset(g: &Graph) -> Vec<u64> {
    let mut v: Vec<u64> = g
        .live_ids()
        .filter(|id| !matches!(g.node(*id).op, OpKind::Input | OpKind::Weight))
        .map(|id| g.node(id).op.attr_hash())
        .collect();
    v.sort_unstable();
    v
}

/// Run the full synthesis pipeline for `cfg`. Deterministic: equal configs
/// produce equal rule lists (names, tiers, order) and stats.
pub fn synthesise(cfg: &SynthConfig) -> anyhow::Result<SynthOutput> {
    let alphabet = alphabet_from_spec(&cfg.alphabet)?;
    let graphs = enumerate_with(cfg.n_inputs, cfg.max_ops, &alphabet);
    let mut stats = SynthStats { enumerated: graphs.len(), ..SynthStats::default() };

    let mut groups: HashMap<u64, Vec<usize>> = HashMap::new();
    for (i, g) in graphs.iter().enumerate() {
        if let Some(fp) = graph_fingerprint(g, cfg.seed) {
            groups.entry(fp).or_default().push(i);
        }
    }
    stats.groups = groups.values().filter(|v| v.len() > 1).count();

    let mut rules: Vec<SynthRule> = Vec::new();
    let mut keys: Vec<u64> = groups.keys().copied().collect();
    keys.sort_unstable();
    for key in keys {
        let members = &groups[&key];
        for i in 0..members.len() {
            for j in i + 1..members.len() {
                stats.candidates += 1;
                let (a, b) = (&graphs[members[i]], &graphs[members[j]]);
                if canonical_hash(a) == canonical_hash(b) {
                    stats.pruned_renaming += 1;
                    continue;
                }
                if op_multiset(a) == op_multiset(b) && a.n_ops() == b.n_ops() {
                    stats.pruned_common += 1;
                    continue;
                }
                // Orientation: rewrite from the larger side to the smaller
                // (ties broken by canonical hash, descending), flipped only
                // if the preferred direction leaves rhs sources unbindable.
                let (mut lhs, mut rhs) = if a.n_ops() > b.n_ops()
                    || (a.n_ops() == b.n_ops() && canonical_hash(a) > canonical_hash(b))
                {
                    (a, b)
                } else {
                    (b, a)
                };
                if SynthRule::new(lhs, rhs, Tier::All, false).is_err() {
                    std::mem::swap(&mut lhs, &mut rhs);
                    if SynthRule::new(lhs, rhs, Tier::All, false).is_err() {
                        stats.rejected += 1;
                        continue;
                    }
                }
                if !semantically_equal(lhs, rhs, 4, cfg.seed ^ 0x5EED, 1e-4).unwrap_or(false) {
                    continue;
                }
                stats.verified += 1;
                let d_square = max_divergence(lhs, rhs, 3, cfg.seed ^ 0xD1FF);
                let (shape_generic, rect_exact) =
                    probe_rectangular(lhs, rhs, cfg.n_inputs, cfg.seed ^ 0x4EC7);
                let exact = d_square == Some(0.0) && rect_exact;
                let tier = if shape_generic && exact && rhs.n_ops() <= lhs.n_ops() {
                    Tier::AlwaysSafe
                } else if shape_generic {
                    Tier::ShapePreserving
                } else {
                    Tier::All
                };
                match SynthRule::new(lhs, rhs, tier, shape_generic) {
                    Ok(rule) => {
                        match tier {
                            Tier::AlwaysSafe => stats.tier_always_safe += 1,
                            Tier::ShapePreserving => stats.tier_shape_preserving += 1,
                            Tier::All => stats.tier_all += 1,
                        }
                        rules.push(rule);
                    }
                    Err(_) => stats.rejected += 1,
                }
            }
        }
    }

    // Dedup by content name (distinct enumerant pairs can canonicalise to
    // the same rule), filter to the requested tier, stable output order.
    let mut seen = std::collections::HashSet::new();
    rules.retain(|r| seen.insert(r.name()));
    rules.retain(|r| r.tier() <= cfg.tier);
    rules.sort_by(|x, y| (x.tier(), x.name()).cmp(&(y.tier(), y.name())));
    if cfg.max_rules > 0 {
        rules.truncate(cfg.max_rules);
    }
    Ok(SynthOutput { rules, stats })
}

/// Box synthesised rules for [`RuleSet`] composition.
pub fn boxed(rules: Vec<SynthRule>) -> Vec<Box<dyn crate::xfer::Rule>> {
    rules.into_iter().map(|r| Box::new(r) as Box<dyn crate::xfer::Rule>).collect()
}

/// The standard handwritten library, optionally extended with a synthesised
/// ruleset file (the `--rules <path>` flag). Synth rules append after the
/// handwritten slots, so the combined `RuleSet::fingerprint` differs from
/// the plain library's and search caches never mix the two vocabularies.
pub fn library_with_rules(rules_path: Option<&str>) -> anyhow::Result<RuleSet> {
    let mut rules = crate::xfer::library::standard_library().rules;
    if let Some(path) = rules_path {
        rules.extend(boxed(load_rules(path)?));
    }
    Ok(RuleSet::new(rules))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::xfer::Rule;

    fn smoke_cfg() -> SynthConfig {
        SynthConfig {
            alphabet: "ewise,act,shape,scale".into(),
            tier: Tier::All,
            ..SynthConfig::default()
        }
    }

    #[test]
    fn pipeline_finds_and_tiers_known_identities() {
        let out = synthesise(&smoke_cfg()).unwrap();
        assert!(out.stats.enumerated > 10);
        assert!(out.stats.verified > 0, "{:?}", out.stats);
        assert!(!out.rules.is_empty());
        // relu(relu(x)) → relu(x) must be discovered as always-safe.
        let relu_squash = out.rules.iter().any(|r| {
            r.tier() == Tier::AlwaysSafe
                && r.lhs().n_ops() == 2
                && r.rhs().n_ops() == 1
                && r.lhs().live_ids().all(|id| {
                    matches!(r.lhs().node(id).op, OpKind::Relu | OpKind::Input)
                })
                && r.rhs().live_ids().all(|id| {
                    matches!(r.rhs().node(id).op, OpKind::Relu | OpKind::Input)
                })
        });
        assert!(relu_squash, "relu∘relu → relu not found in always-safe tier");
        // Tier sort order: always-safe block first.
        let tiers: Vec<Tier> = out.rules.iter().map(|r| r.tier()).collect();
        let mut sorted = tiers.clone();
        sorted.sort();
        assert_eq!(tiers, sorted);
    }

    #[test]
    fn always_safe_tier_is_nonempty_and_subset() {
        let all = synthesise(&smoke_cfg()).unwrap();
        let safe = synthesise(&SynthConfig { tier: Tier::AlwaysSafe, ..smoke_cfg() }).unwrap();
        assert!(!safe.rules.is_empty());
        assert!(safe.rules.len() <= all.rules.len());
        let all_names: std::collections::HashSet<&str> =
            all.rules.iter().map(|r| r.name()).collect();
        for r in &safe.rules {
            assert_eq!(r.tier(), Tier::AlwaysSafe);
            assert!(all_names.contains(r.name()), "tiering must be a filter");
        }
    }

    #[test]
    fn synthesised_rules_apply_soundly() {
        let out = synthesise(&smoke_cfg()).unwrap();
        let mut b = crate::graph::GraphBuilder::new();
        let x = b.input(&[4, 4]);
        let r = b.relu(x).unwrap();
        let r2 = b.relu(r).unwrap();
        let t = b.op(OpKind::Transpose { perm: vec![1, 0] }, &[r2]).unwrap();
        let t2 = b.op(OpKind::Transpose { perm: vec![1, 0] }, &[t]).unwrap();
        let _ = b.op(OpKind::Scale { factor: 0.5 }, &[t2]).unwrap();
        let g = b.finish();
        let mut applied = 0;
        for rule in &out.rules {
            for loc in rule.find(&g).into_iter().take(1) {
                let mut g2 = g.clone();
                crate::xfer::apply_rule(&mut g2, rule, &loc).unwrap();
                assert!(
                    semantically_equal(&g, &g2, 2, 17, 1e-4).unwrap(),
                    "rule {} unsound on host graph",
                    rule.name()
                );
                applied += 1;
            }
        }
        assert!(applied > 0, "no synthesised rule matched the host graph");
    }

    #[test]
    fn combined_library_composes_and_fingerprints() {
        let dir = std::env::temp_dir().join("rlflow_synth_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rules.json");
        let cfg = SynthConfig { tier: Tier::AlwaysSafe, ..smoke_cfg() };
        let out = synthesise(&cfg).unwrap();
        save_rules(&path, &out.rules, &cfg).unwrap();

        let plain = crate::xfer::library::standard_library();
        let combined = library_with_rules(Some(path.to_str().unwrap())).unwrap();
        assert_eq!(combined.len(), plain.len() + out.rules.len());
        assert_ne!(
            combined.fingerprint(),
            plain.fingerprint(),
            "combined vocabulary must not collide with the plain library"
        );
        // Handwritten slots keep their indices (agent action-space safety).
        for (i, r) in plain.rules.iter().enumerate() {
            assert_eq!(combined.index_of(r.name()), Some(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tier_parse_round_trips() {
        for t in [Tier::AlwaysSafe, Tier::ShapePreserving, Tier::All] {
            assert_eq!(Tier::parse(t.as_str()).unwrap(), t);
        }
        assert!(Tier::parse("fp-unsafe").is_err());
        assert!(Tier::AlwaysSafe < Tier::ShapePreserving);
        assert!(Tier::ShapePreserving < Tier::All);
    }
}
