//! Seeded, deterministic enumeration of small candidate graphs over a
//! configurable operator alphabet (TASO §4 step 1, grown from the
//! `xfer::generator` sketch).
//!
//! The alphabet is assembled from named groups so the CLI can scale the
//! search space: `ewise` (Add/Mul), `act` (Relu/Tanh/Sigmoid/Gelu),
//! `shape` (Identity/Transpose), `matmul` (the transpose variants),
//! `scale` (reciprocal factors, so `scale∘scale` identities exist), and
//! `fused` (MatMul with activation epilogues). Enumeration is exhaustive
//! over ordered input tuples and deduplicates on [`canonical_hash`] — the
//! name-invariant identity that merges pure input renamings while keeping
//! distinct wirings (`add(x, x)` vs `add(x, y)`) apart.

use crate::graph::{canonical_hash, Activation, Graph, OpKind, PortRef, TensorDesc};

/// The operator groups an alphabet spec may name.
pub const GROUPS: [&str; 6] = ["ewise", "act", "shape", "matmul", "scale", "fused"];

/// Ops of one named group, in stable order.
pub fn group_ops(name: &str) -> Option<Vec<OpKind>> {
    let none = Activation::None;
    Some(match name {
        "ewise" => vec![OpKind::Add, OpKind::Mul],
        "act" => vec![OpKind::Relu, OpKind::Tanh, OpKind::Sigmoid, OpKind::Gelu],
        "shape" => vec![OpKind::Identity, OpKind::Transpose { perm: vec![1, 0] }],
        "matmul" => vec![
            OpKind::MatMul { trans_a: false, trans_b: false, act: none },
            OpKind::MatMul { trans_a: false, trans_b: true, act: none },
            OpKind::MatMul { trans_a: true, trans_b: false, act: none },
        ],
        // Reciprocal factors: scale(2)∘scale(0.5) is the exact identity the
        // always-safe tier is seeded with.
        "scale" => vec![OpKind::Scale { factor: 0.5 }, OpKind::Scale { factor: 2.0 }],
        "fused" => vec![
            OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::Relu },
            OpKind::MatMul { trans_a: false, trans_b: true, act: Activation::Relu },
        ],
        _ => return None,
    })
}

/// Parse a comma-separated group spec (e.g. `"ewise,act,scale"`) into a
/// deduplicated op alphabet in spec order. `"all"` expands to every group.
pub fn alphabet_from_spec(spec: &str) -> anyhow::Result<Vec<OpKind>> {
    let mut ops: Vec<OpKind> = Vec::new();
    let names: Vec<&str> = if spec.trim() == "all" {
        GROUPS.to_vec()
    } else {
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
    };
    anyhow::ensure!(!names.is_empty(), "empty alphabet spec");
    for name in names {
        let group = group_ops(name).ok_or_else(|| {
            anyhow::anyhow!("unknown alphabet group '{}' (expected one of {:?})", name, GROUPS)
        })?;
        for op in group {
            if !ops.contains(&op) {
                ops.push(op);
            }
        }
    }
    Ok(ops)
}

/// Enumerate all graphs with exactly `n_inputs` 4x4 inputs and 1..=`max_ops`
/// ops drawn from `alphabet`, keeping single-output graphs. Deterministic:
/// output order is a pure function of (n_inputs, max_ops, alphabet).
///
/// Deduplication keys on [`canonical_hash`] — with the multiplicity
/// disambiguation in `graph::hash`, renamings merge while distinct
/// wirings of same-shaped inputs survive as separate enumerants.
pub fn enumerate_with(n_inputs: usize, max_ops: usize, alphabet: &[OpKind]) -> Vec<Graph> {
    let mut out = Vec::new();
    let base = {
        let mut g = Graph::new();
        for _ in 0..n_inputs {
            g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        }
        g
    };
    let mut frontier = vec![base];
    let mut seen = std::collections::HashSet::new();
    for _depth in 0..max_ops {
        let mut next = Vec::new();
        for g in &frontier {
            let ports: Vec<PortRef> = g.live_ids().map(PortRef::of).collect();
            for op in alphabet {
                let arity = op.arity().unwrap_or(2);
                // All ordered port tuples of length `arity`.
                let mut tuple = vec![0usize; arity];
                loop {
                    let inputs: Vec<PortRef> = tuple.iter().map(|&i| ports[i]).collect();
                    let mut g2 = g.clone();
                    if g2.add(op.clone(), &inputs).is_ok() {
                        let h = canonical_hash(&g2);
                        if seen.insert(h) {
                            next.push(g2.clone());
                            out.push(g2);
                        }
                    }
                    // Advance the tuple counter.
                    let mut i = 0;
                    loop {
                        if i == arity {
                            break;
                        }
                        tuple[i] += 1;
                        if tuple[i] < ports.len() {
                            break;
                        }
                        tuple[i] = 0;
                        i += 1;
                    }
                    if tuple.iter().all(|&t| t == 0) {
                        break;
                    }
                }
            }
        }
        frontier = next;
    }
    // Substitution candidates are single-output graphs only.
    out.retain(|g| g.output_ids().len() == 1);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alphabet_spec_parses_and_dedups() {
        let a = alphabet_from_spec("ewise,act,ewise").unwrap();
        assert_eq!(a.len(), 6); // Add, Mul, Relu, Tanh, Sigmoid, Gelu — no dupes
        assert!(alphabet_from_spec("nosuch").is_err());
        assert!(alphabet_from_spec("").is_err());
        let all = alphabet_from_spec("all").unwrap();
        for g in GROUPS {
            for op in group_ops(g).unwrap() {
                assert!(all.contains(&op), "all missing {:?}", op);
            }
        }
    }

    #[test]
    fn enumeration_is_deterministic() {
        let a = alphabet_from_spec("ewise,shape").unwrap();
        let g1 = enumerate_with(2, 2, &a);
        let g2 = enumerate_with(2, 2, &a);
        assert_eq!(g1.len(), g2.len());
        for (x, y) in g1.iter().zip(&g2) {
            assert_eq!(canonical_hash(x), canonical_hash(y));
        }
    }

    #[test]
    fn distinct_wirings_both_enumerate() {
        // The canonical-hash dedup fix: add(x, y) AND add(x, x) must both
        // survive (previously the shape-only source hash merged them).
        let a = alphabet_from_spec("ewise").unwrap();
        let graphs = enumerate_with(2, 1, &a);
        let adds = graphs
            .iter()
            .filter(|g| {
                g.live_ids()
                    .filter(|&id| matches!(g.node(id).op, OpKind::Add))
                    .count()
                    == 1
            })
            .count();
        assert_eq!(adds, 2, "expected add(x, y) and add(x, x) as distinct enumerants");
    }
}
